//! Seismic-imaging scenario (§1's reverse-time-migration motivation):
//! high-order 3D stencils time-stepped over a velocity volume — the
//! workload class where the paper shows Casper's limits (3D stencils pull
//! significant data from remote LLC slices, §8.1).
//!
//! Runs 7-point and 33-point 3D kernels, reports the locality breakdown
//! that explains the Fig 10 3D results, and sweeps the Fig 14 ablation
//! for the 33-point kernel.
//!
//! ```sh
//! cargo run --release --example seismic_3d
//! ```

use anyhow::Result;

use casper::config::{MappingPolicy, SimConfig, SizeClass, SpuPlacement};
use casper::coordinator::run_casper;
use casper::cpu::run_cpu;
use casper::stencil::{Domain, StencilKind};

fn main() -> Result<()> {
    let cfg = SimConfig::default();
    println!("=== 3D wave-propagation kernels (LLC-class volumes) ===\n");

    for kind in [StencilKind::Heat3D, StencilKind::Points33_3D] {
        let domain = Domain::for_level(kind, SizeClass::Llc);
        let c = run_casper(&cfg, kind, &domain, 1);
        let p = run_cpu(&cfg, kind, &domain, 1);
        println!("{kind} @ {domain}:");
        println!(
            "  speedup {:.2}x | local loads {:.1}% | remote {:.1}% | NoC messages {}",
            p.cycles as f64 / c.cycles as f64,
            100.0 * c.local_fraction(),
            100.0 * (1.0 - c.local_fraction()),
            c.noc_messages
        );
        println!(
            "  (paper §8.1: 3D stencils load much of their input from remote slices,\n   limiting or erasing the speedup — the 33-point case can be a slowdown)\n"
        );
    }

    println!("=== Fig 14-style ablation on the 33-point kernel ===\n");
    let kind = StencilKind::Points33_3D;
    let domain = Domain::for_level(kind, SizeClass::Llc);
    let mut rows = Vec::new();
    for (label, placement, mapping) in [
        ("SPUs near L1, baseline hash", SpuPlacement::NearL1, MappingPolicy::Baseline),
        ("SPUs near L1, stencil hash", SpuPlacement::NearL1, MappingPolicy::StencilSegment),
        ("near LLC, baseline hash", SpuPlacement::NearLlc, MappingPolicy::Baseline),
        ("near LLC, stencil hash (Casper)", SpuPlacement::NearLlc, MappingPolicy::StencilSegment),
    ] {
        let mut c = cfg.clone();
        c.placement = placement;
        c.mapping = mapping;
        let stats = run_casper(&c, kind, &domain, 1);
        rows.push((label, stats.cycles, stats.local_fraction()));
    }
    let base = rows[0].1 as f64;
    for (label, cycles, local) in &rows {
        println!(
            "  {label:<34} {cycles:>10} cycles  ({:.2}x vs ablation baseline, {:.0}% local)",
            base / *cycles as f64,
            100.0 * local
        );
    }
    Ok(())
}
