//! Roofline explorer (Fig 1): prints the machine ceilings, each kernel's
//! arithmetic intensity, and the measured CPU GFLOPS — as an ASCII plot.
//!
//! ```sh
//! cargo run --release --example roofline_tool
//! ```

use casper::config::{SimConfig, SizeClass};
use casper::cpu::run_cpu;
use casper::roofline::{roofline, Machine};
use casper::stencil::{Domain, StencilKind};

fn main() {
    let cfg = SimConfig::default();
    let m = Machine::of(&cfg);
    println!(
        "machine: peak {:.0} GFLOPS | DRAM {:.1} GB/s | LLC {:.1} GB/s",
        m.peak_flops / 1e9,
        m.dram_bw / 1e9,
        m.llc_bw / 1e9
    );
    println!(
        "knees: DRAM @ {:.2} FLOP/B, LLC @ {:.2} FLOP/B\n",
        m.dram_knee(),
        m.llc_knee()
    );

    let measured: Vec<f64> = StencilKind::ALL
        .iter()
        .map(|&k| {
            let d = Domain::for_level(k, SizeClass::Llc);
            run_cpu(&cfg, k, &d, 1).gflops(cfg.cpu.freq_ghz)
        })
        .collect();

    println!(
        "{:<14} {:>8} {:>14} {:>14} {:>12} {:>8}",
        "kernel", "AI", "DRAM roof", "LLC roof", "measured", "of peak"
    );
    for (i, p) in roofline(&cfg, Some(&measured)).iter().enumerate() {
        println!(
            "{:<14} {:>8.3} {:>11.1} GF {:>11.1} GF {:>9.1} GF {:>7.1}%",
            p.kind.name(),
            p.ai,
            p.dram_bound / 1e9,
            p.llc_bound / 1e9,
            measured[i],
            100.0 * measured[i] * 1e9 / m.peak_flops
        );
    }

    // ASCII log-log sketch: kernels between the DRAM and LLC roofs.
    println!("\n      GFLOPS (log)   [*] measured   [-] DRAM roof   [=] LLC roof");
    for (i, p) in roofline(&cfg, Some(&measured)).iter().enumerate() {
        let bar = |v: f64| ((v / 1e9).log10() * 20.0).max(0.0) as usize;
        let (d, l, me) = (bar(p.dram_bound), bar(p.llc_bound), bar(measured[i] * 1e9));
        let width = l.max(me) + 2;
        let mut line = vec![' '; width];
        for c in line.iter_mut().take(d) {
            *c = '-';
        }
        for c in line.iter_mut().take(l).skip(d) {
            *c = '=';
        }
        if me < width {
            line[me] = '*';
        }
        println!("{:<14} |{}", p.kind.name(), line.into_iter().collect::<String>());
    }
    println!("\n(the paper's Fig 1 observation: every kernel sits above the DRAM line and\n below the L3 line, at <20% of peak — LLC bandwidth-bound, not compute-bound)");
}
