//! Weather-model horizontal diffusion scenario (the paper's motivating
//! COSMO workload, §1): repeated 2D smoothing over a large atmospheric
//! field, time-stepped, comparing Casper against the CPU baseline and
//! tracking energy.
//!
//! Uses Blur 2D (a 5×5 Gaussian — the horizontal diffusion operator shape)
//! over LLC-tiled and full DRAM-resident fields, plus Jacobi 2D as the
//! lighter smoothing pass.
//!
//! ```sh
//! cargo run --release --example weather_diffusion
//! ```

use anyhow::Result;

use casper::config::{SimConfig, SizeClass};
use casper::coordinator::run_casper;
use casper::cpu::run_cpu;
use casper::energy::{casper_energy, cpu_energy};
use casper::stencil::{golden, Domain, StencilKind};
use casper::util::human_time_cycles;

fn main() -> Result<()> {
    let cfg = SimConfig::default();
    let steps = 4;

    println!("=== horizontal diffusion pipeline ({steps} time steps/stage) ===\n");
    let mut total_casper = 0u64;
    let mut total_cpu = 0u64;
    let mut energy_casper = 0.0;
    let mut energy_cpu = 0.0;

    for (kind, level, label) in [
        (StencilKind::Jacobi2D, SizeClass::Llc, "smoothing pass (LLC-tiled)"),
        (StencilKind::Blur2D, SizeClass::Llc, "diffusion operator (LLC-tiled)"),
        (StencilKind::Blur2D, SizeClass::Dram, "full-field diffusion (DRAM)"),
    ] {
        let domain = Domain::for_level(kind, level);
        let c = run_casper(&cfg, kind, &domain, steps);
        let p = run_cpu(&cfg, kind, &domain, steps);

        // Functional check per stage.
        let want = golden::run_kind(
            kind,
            &domain,
            steps,
            casper::coordinator::CasperOptions::default().seed,
        );
        let diff = c.output.max_abs_diff(&want);
        anyhow::ensure!(diff < 1e-11, "{label}: diverged {diff}");

        let ce = casper_energy(&cfg, &c);
        let pe = cpu_energy(&cfg, &p);
        total_casper += c.cycles;
        total_cpu += p.cycles;
        energy_casper += ce.total_j();
        energy_cpu += pe.total_j();

        println!("{label}: {kind} @ {domain}");
        println!(
            "  casper {:>24}   cpu {:>24}   speedup {:.2}x",
            human_time_cycles(c.cycles, cfg.cpu.freq_ghz),
            human_time_cycles(p.cycles, cfg.cpu.freq_ghz),
            p.cycles as f64 / c.cycles as f64
        );
        println!(
            "  energy: casper {:.3e} J vs cpu {:.3e} J ({:.0}% of baseline)\n",
            ce.total_j(),
            pe.total_j(),
            100.0 * ce.total_j() / pe.total_j()
        );
    }

    println!("=== pipeline total ===");
    println!(
        "casper {} vs cpu {} — {:.2}x end-to-end, {:.0}% of baseline energy",
        human_time_cycles(total_casper, cfg.cpu.freq_ghz),
        human_time_cycles(total_cpu, cfg.cpu.freq_ghz),
        total_cpu as f64 / total_casper as f64,
        100.0 * energy_casper / energy_cpu
    );
    Ok(())
}
