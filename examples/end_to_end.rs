//! End-to-end driver: proves all layers compose on a real workload.
//!
//! For every stencil kernel:
//!  1. the AOT JAX/Pallas artifact (L1+L2) is loaded and executed through
//!     PJRT from Rust — the production request path;
//!  2. the same input runs on the cycle-level Casper simulator (L3) and
//!     the CPU baseline;
//!  3. outputs are cross-checked bit-tight against the golden reference;
//!  4. the paper's headline metrics (speedup, energy, locality) are
//!     reported and the LLC-class geomean is compared to the paper's
//!     1.65× claim.
//!
//! The run is recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example end_to_end
//! ```

use anyhow::Result;

use casper::config::{SimConfig, SizeClass};
use casper::coordinator::{run_casper_with, CasperOptions};
use casper::cpu::run_cpu;
use casper::energy::{casper_energy, cpu_energy};
use casper::runtime::{artifacts_available, default_artifacts_dir, StencilRuntime};
use casper::stencil::{golden, Domain, StencilKind};
use casper::util::geomean;

fn main() -> Result<()> {
    let cfg = SimConfig::default();

    // --- Phase 1: AOT artifacts through PJRT (the request path). ---
    anyhow::ensure!(
        artifacts_available(),
        "artifacts missing — run `make artifacts` first"
    );
    let mut rt = StencilRuntime::new(&default_artifacts_dir())?;
    println!("=== phase 1: AOT JAX/Pallas artifacts on PJRT ({}) ===\n", rt.platform());
    let seed = 0xE2E_2026;
    for kind in StencilKind::ALL {
        let entry = rt
            .smallest_for(kind, 1)
            .ok_or_else(|| anyhow::anyhow!("no artifact for {kind}"))?
            .clone();
        let d = Domain::new(entry.nx, entry.ny, entry.nz);
        let input = d.alloc_random(seed);
        let t0 = std::time::Instant::now();
        let pjrt_out = rt.execute(&entry.name, &input)?;
        let wall = t0.elapsed();

        // Simulator on the SAME input, plus golden.
        let sim = run_casper_with(&cfg, kind, &d, 1, CasperOptions { seed, ..Default::default() })?;
        let want = golden::run(&kind.descriptor(), &input, 1);

        let pjrt_err = pjrt_out.max_abs_diff(&want);
        let sim_err = sim.output.max_abs_diff(&want);
        let cross = sim.output.max_abs_diff(&pjrt_out);
        anyhow::ensure!(pjrt_err < 1e-11, "{kind}: PJRT diverged {pjrt_err}");
        anyhow::ensure!(sim_err < 1e-11, "{kind}: simulator diverged {sim_err}");
        println!(
            "  {:<12} {:>8} pts  pjrt {:>8.1?}  |pjrt-golden| {:.1e}  |sim-golden| {:.1e}  |sim-pjrt| {:.1e}  OK",
            kind.id(),
            d.points(),
            wall,
            pjrt_err,
            sim_err,
            cross
        );
    }

    // --- Phase 2: the paper's headline sweep (LLC class). ---
    println!("\n=== phase 2: LLC-class sweep (paper Fig 10/11 headline) ===\n");
    println!(
        "{:<14} {:>12} {:>12} {:>9} {:>9} {:>8}",
        "kernel", "casper cyc", "cpu cyc", "speedup", "energy", "local"
    );
    let mut speedups = Vec::new();
    let mut energies = Vec::new();
    for kind in StencilKind::ALL {
        let d = Domain::for_level(kind, SizeClass::Llc);
        let c = run_casper_with(&cfg, kind, &d, 1, CasperOptions::default())?;
        let p = run_cpu(&cfg, kind, &d, 1);
        let s = p.cycles as f64 / c.cycles as f64;
        let e = casper_energy(&cfg, &c).total_j() / cpu_energy(&cfg, &p).total_j();
        speedups.push(s);
        energies.push(e);
        println!(
            "{:<14} {:>12} {:>12} {:>8.2}x {:>8.2} {:>7.0}%",
            kind.name(),
            c.cycles,
            p.cycles,
            s,
            e,
            100.0 * c.local_fraction()
        );
    }
    println!(
        "\nLLC-class geomean speedup: {:.2}x   (paper: 1.65x average)",
        geomean(&speedups)
    );
    println!(
        "LLC-class geomean normalized energy: {:.2}   (paper: 0.45 for LLC sets)",
        geomean(&energies)
    );
    println!("\nend-to-end driver completed: all layers compose and agree.");
    Ok(())
}
