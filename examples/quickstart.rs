//! Quickstart: program a Jacobi-2D stencil through the Table 1 Casper API
//! (the Fig 8 flow), run it on the simulated near-cache hardware, and
//! check the numerics against the golden reference.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;

use casper::config::{SimConfig, SizeClass};
use casper::coordinator::run_casper;
use casper::cpu::run_cpu;
use casper::isa::ProgramBuilder;
use casper::stencil::{golden, Domain, StencilKind};

fn main() -> Result<()> {
    let cfg = SimConfig::default();
    let kind = StencilKind::Jacobi2D;

    // --- 1. Compile the stencil to Casper microcode (Fig 9). ---
    let program = ProgramBuilder::new().build(&kind.descriptor())?;
    println!(
        "Casper microcode for {} ({} instructions, {} streams, {} constants):",
        kind,
        program.instrs.len(),
        program.streams.len(),
        program.constants.len()
    );
    print!("{}", program.disasm());
    println!(
        "encoded: {:?} (15-bit words)\n",
        program.encode().iter().map(|w| format!("{w:#06x}")).collect::<Vec<_>>()
    );

    // --- 2. Run on the near-cache accelerator at the paper's LLC size. ---
    let domain = Domain::for_level(kind, SizeClass::Llc);
    println!("running {kind} on a {domain} grid ({} points)...", domain.points());
    let casper_stats = run_casper(&cfg, kind, &domain, 1);

    // --- 3. Baseline CPU for comparison. ---
    let cpu_stats = run_cpu(&cfg, kind, &domain, 1);

    println!("  casper : {:>10} cycles", casper_stats.cycles);
    println!("  cpu    : {:>10} cycles", cpu_stats.cycles);
    println!(
        "  speedup: {:.2}x  (paper Fig 10 reports ~3.0x for this point)",
        cpu_stats.cycles as f64 / casper_stats.cycles as f64
    );
    println!(
        "  SPU locality: {:.1}% local loads, LLC hit rate {:.1}%",
        100.0 * casper_stats.local_fraction(),
        100.0 * casper_stats.llc_hit_rate()
    );

    // --- 4. Verify the functional result. ---
    let want =
        golden::run_kind(kind, &domain, 1, casper::coordinator::CasperOptions::default().seed);
    let diff = casper_stats.output.max_abs_diff(&want);
    anyhow::ensure!(diff < 1e-12, "numerics diverged: {diff}");
    println!("  functional check vs golden reference: OK (max |err| = {diff:.2e})");
    Ok(())
}
