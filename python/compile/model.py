"""Layer-2 JAX model: the stencil compute graph around the Pallas kernels.

``stencil_step`` composes the L1 Pallas kernel with the shared boundary
policy (interior mask, copy-through halo); ``stencil_run`` adds Jacobi
time stepping. These are the functions ``aot.py`` lowers to HLO text for
the Rust runtime — Python never runs on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.ref import SPECS, grid_shape_3d, interior_mask_jax
from .kernels.stencil import stencil_pallas_raw

jax.config.update("jax_enable_x64", True)


def stencil_step(name: str, grid: jnp.ndarray) -> jnp.ndarray:
    """One Jacobi step: Pallas MAC chain on the interior, copy-through on
    the boundary — bit-compatible with the Rust golden reference's
    convention."""
    if name not in SPECS:
        raise ValueError(f"unknown stencil kernel '{name}'")
    nz, ny, nx = grid_shape_3d(name, grid.shape)
    rows = nz * ny
    flat = grid.reshape(rows, nx)
    raw = stencil_pallas_raw(name, grid)
    # Mask built from iota comparisons, NOT a boolean constant — the AOT
    # converter mis-reads bit-packed pred constants (DESIGN.md §3).
    mask = interior_mask_jax(name, grid.shape)
    out = jnp.where(mask, raw, flat)
    return out.reshape(grid.shape)


def stencil_run(name: str, grid: jnp.ndarray, steps: int) -> jnp.ndarray:
    """``steps`` Jacobi iterations. The step count is a static Python int
    (unrolled at trace time) so the lowered HLO is self-contained."""
    for _ in range(steps):
        grid = stencil_step(name, grid)
    return grid


def make_step_fn(name: str, shape, steps: int = 1):
    """A shape-specialized function ready for `jax.jit(...).lower()`.

    Returns ``(fn, example_spec)`` where ``fn(grid) -> (out,)`` — a 1-tuple
    because the AOT pipeline lowers with ``return_tuple=True`` and the Rust
    side unwraps with ``to_tuple1()``.
    """

    def fn(grid):
        return (stencil_run(name, grid, steps),)

    spec = jax.ShapeDtypeStruct(shape, jnp.float64)
    return fn, spec
