"""AOT compile path: lower the L2 stencil model to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` or serialized protos) is the
interchange format: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids which the Rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Emits ``<kernel>_<class>.hlo.txt`` per entry plus ``manifest.txt`` with
lines ``name kernel nx ny nz steps file`` that the Rust runtime parses.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .kernels.ref import KERNELS, SPECS
from .model import make_step_fn

jax.config.update("jax_enable_x64", True)

# Artifact matrix: every kernel at a small validation shape (fast to
# compile and execute from the Rust tests), plus L2-class shapes for the
# end-to-end example. Natural shapes are (nx,), (ny,nx), (nz,ny,nx).
TINY_SHAPES = {
    "jacobi1d": (256,),
    "pts7_1d": (256,),
    "jacobi2d": (32, 16),
    "blur2d": (32, 16),
    "heat3d": (16, 12, 8),
    "pts33_3d": (16, 12, 8),
}
L2_SHAPES = {
    "jacobi1d": (131072,),
    "jacobi2d": (256, 512),
}


def entries():
    """The artifact build matrix."""
    out = []
    for k in KERNELS:
        out.append((f"{k}_tiny", k, TINY_SHAPES[k], 1))
        # A 3-step variant of the tiny shape exercises multi-step HLO.
        out.append((f"{k}_tiny_s3", k, TINY_SHAPES[k], 3))
    for k, shape in L2_SHAPES.items():
        out.append((f"{k}_l2", k, shape, 1))
    return out


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(kernel: str, shape, steps: int) -> str:
    fn, spec = make_step_fn(kernel, shape, steps)
    lowered = jax.jit(fn).lower(spec)
    return to_hlo_text(lowered)


def natural_to_nzyx(kernel: str, shape):
    dims = SPECS[kernel].dims
    if dims == 1:
        return shape[0], 1, 1
    if dims == 2:
        return shape[1], shape[0], 1
    return shape[2], shape[1], shape[0]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", default=None, help="comma-separated artifact-name filter"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    only = set(args.only.split(",")) if args.only else None

    manifest = []
    for name, kernel, shape, steps in entries():
        if only and name not in only:
            continue
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        text = lower_entry(kernel, shape, steps)
        with open(path, "w") as f:
            f.write(text)
        nx, ny, nz = natural_to_nzyx(kernel, shape)
        manifest.append(f"{name} {kernel} {nx} {ny} {nz} {steps} {fname}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest with {len(manifest)} entries")


if __name__ == "__main__":
    main()
