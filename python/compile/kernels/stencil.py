"""Layer-1 Pallas stencil kernels.

One generic Pallas kernel is specialized per stencil (taps are compile-time
constants, so each ``pallas_call`` lowers to a fixed MAC chain — the
software analogue of Casper's per-kernel microcode).

Execution model (the TPU adaptation of Casper's §3.2 streaming model, see
DESIGN.md §Hardware-Adaptation):

- 2D/3D grids are flattened to ``(rows, nx)``; the Pallas grid iterates
  over *row blocks* — the analogue of Casper's 128 kB stencil blocks
  walking through LLC slices. Each program produces one output block in
  VMEM, gathering the rows its taps need with clamped dynamic slices and
  applying the MAC chain with static in-row shifts (``jnp.roll``) —
  mirroring the SPU's shifted (unaligned) stream loads.
- 1D grids block along x instead: each program loads its segment plus the
  halo (``pl.dslice``, clamped at the edges) and combines *static* slices
  of it — the direct analogue of the §4.1 unaligned loads pulling from two
  adjacent cache lines.
- Clamp/wrap artifacts land only on boundary points, which
  :func:`..model.stencil_step` masks to copy-through — identical boundary
  policy to the Rust golden reference.

Kernels MUST run with ``interpret=True`` on CPU: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import SPECS, grid_shape_3d

# Rows per Pallas program for 2D/3D (output block height).
DEFAULT_BLOCK_ROWS = 8
# Elements per Pallas program for 1D.
DEFAULT_BLOCK_X = 1024


def _row_taps(name: str, ny: int):
    """Collapse taps to (drow, dx, coef) in flattened-row space."""
    return tuple((t[1] + t[2] * ny, t[0], t[3]) for t in SPECS[name].taps)


def _kernel_rows(in_ref, out_ref, *, taps, block_rows):
    """2D/3D kernel body: one block of output rows per program."""
    pid = pl.program_id(0)
    base = pid * block_rows
    for r in range(block_rows):  # static unroll: the per-point microcode
        row = base + r
        acc = None
        for drow, dx, coef in taps:
            # Clamped dynamic row load (the stream for this tap's row).
            src = in_ref[pl.dslice(row + drow, 1), :]
            # Static in-row shift — the SPU's unaligned-load offset.
            shifted = jnp.roll(src, -dx, axis=1) if dx != 0 else src
            term = coef * shifted
            acc = term if acc is None else acc + term
        out_ref[pl.dslice(r, 1), :] = acc


def _kernel_1d(in_ref, out_ref, *, taps, block_x, radius):
    """1D kernel body: one x-segment (plus halo) per program. The input
    reference is physically halo-padded by ``radius`` on both sides, so
    segment loads never leave bounds."""
    pid = pl.program_id(0)
    x0 = pid * block_x
    seg = in_ref[0, pl.dslice(x0, block_x + 2 * radius)]
    acc = None
    for _drow, dx, coef in taps:
        lo = radius + dx
        term = coef * seg[lo : lo + block_x]  # static slice: unaligned load
        acc = term if acc is None else acc + term
    out_ref[0, :] = acc


@functools.lru_cache(maxsize=None)
def _build_rows(name: str, rows: int, nx: int, ny: int, block_rows: int, dtype: str):
    taps = _row_taps(name, ny)
    n_blocks = -(-rows // block_rows)  # ceil
    kernel = functools.partial(_kernel_rows, taps=taps, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((rows, nx), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, nx), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * block_rows, nx), jnp.dtype(dtype)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )


@functools.lru_cache(maxsize=None)
def _build_1d(name: str, nx: int, block_x: int, dtype: str):
    """`nx` is the padded-to-block logical width; the input carries an
    extra `2*radius` halo columns."""
    spec = SPECS[name]
    radius = spec.radius[0]
    taps = _row_taps(name, 1)
    n_blocks = nx // block_x
    kernel = functools.partial(_kernel_1d, taps=taps, block_x=block_x, radius=radius)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((1, nx + 2 * radius), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_x), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, nx), jnp.dtype(dtype)),
        interpret=True,
    )


def stencil_pallas_raw(
    name: str,
    grid: jnp.ndarray,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_x: int = DEFAULT_BLOCK_X,
):
    """Run the Pallas kernel over a natural-shape grid.

    Returns the *unmasked* result flattened to ``(rows, nx)`` — boundary
    values are wrap/clamp artifacts by design; callers apply the interior
    mask (see :func:`..model.stencil_step`).
    """
    nz, ny, nx = grid_shape_3d(name, grid.shape)
    rows = nz * ny
    flat = grid.reshape(rows, nx)
    dtype = str(flat.dtype)

    if SPECS[name].dims == 1:
        radius = SPECS[name].radius[0]
        bx = min(block_x, nx)
        tail = (bx - nx % bx) % bx
        # Physical halo padding left and right (values are masked later).
        flat = jnp.pad(flat, ((0, 0), (radius, radius + tail)), mode="edge")
        call = _build_1d(name, nx + tail, bx, dtype)
        return call(flat)[:, :nx].reshape(rows, nx)

    if rows % block_rows != 0:
        pad = block_rows - rows % block_rows
        flat = jnp.concatenate([flat, jnp.zeros((pad, nx), flat.dtype)], axis=0)
    call = _build_rows(name, flat.shape[0], nx, ny, block_rows, dtype)
    out = call(flat)
    return out[:rows]


def vmem_block_bytes(
    name: str,
    shape,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_x: int = DEFAULT_BLOCK_X,
) -> int:
    """Estimated VMEM footprint of one program's working set (§Perf):
    the output block plus the tap rows (2D/3D) or halo'd segment (1D)."""
    nz, ny, nx = grid_shape_3d(name, shape)
    del nz
    spec = SPECS[name]
    if spec.dims == 1:
        radius = spec.radius[0]
        bx = min(block_x, nx)
        return 8 * (bx + (bx + 2 * radius))
    tap_rows = len({t[1] + t[2] * ny for t in spec.taps})
    return 8 * nx * (block_rows + tap_rows)
