"""Pure-jnp correctness oracle for the stencil kernels.

This is the numerical ground truth on the Python side: every Pallas kernel
(and the lowered AOT artifact executed from Rust) is checked against it.
The stencil specifications — tap offsets, coefficients, and the
copy-through boundary convention — mirror ``rust/src/stencil/`` exactly
(same literals, same normalizations), so the Rust golden reference, the
SPU functional simulation, this oracle, and the Pallas kernels all agree.

Grids are handled in a uniform flattened-2D layout: ``(rows, nx)`` where
``rows = nz * ny``; a tap ``(dx, dy, dz)`` becomes a row offset
``dy + dz * ny`` plus an in-row shift ``dx``.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

# The six kernels of the paper's §7.2, in paper order.
KERNELS = ("jacobi1d", "pts7_1d", "jacobi2d", "blur2d", "heat3d", "pts33_3d")


@dataclass(frozen=True)
class StencilSpec:
    """Tap pattern of one stencil kernel."""

    name: str
    dims: int
    # Tuples of (dx, dy, dz, coef).
    taps: tuple

    @property
    def radius(self):
        rx = max(abs(t[0]) for t in self.taps)
        ry = max(abs(t[1]) for t in self.taps)
        rz = max(abs(t[2]) for t in self.taps)
        return rx, ry, rz

    @property
    def num_points(self):
        return len(self.taps)

    def coef_sum(self):
        return sum(t[3] for t in self.taps)


def _jacobi1d():
    c = 1.0 / 3.0
    return tuple((dx, 0, 0, c) for dx in (-1, 0, 1))


def _pts7_1d():
    c = 1.0 / 7.0
    return tuple((dx, 0, 0, c) for dx in range(-3, 4))


def _jacobi2d():
    c = 0.2
    return ((0, -1, 0, c), (-1, 0, 0, c), (0, 0, 0, c), (1, 0, 0, c), (0, 1, 0, c))


def _blur2d():
    w = np.array(
        [
            [1, 4, 7, 4, 1],
            [4, 16, 26, 16, 4],
            [7, 26, 41, 26, 7],
            [4, 16, 26, 16, 4],
            [1, 4, 7, 4, 1],
        ],
        dtype=np.float64,
    )
    taps = []
    for j in range(5):
        for i in range(5):
            taps.append((i - 2, j - 2, 0, float(w[j, i] / 273.0)))
    return tuple(taps)


def _heat3d():
    taps = [(0, 0, 0, 0.4)]
    for d in ((-1, 0, 0), (1, 0, 0), (0, -1, 0), (0, 1, 0), (0, 0, -1), (0, 0, 1)):
        taps.append((*d, 0.1))
    return tuple(taps)


def _pts33_3d():
    # 27-point box + 6 distance-2 axis points; total class weight 54
    # (see rust/src/stencil/mod.rs).
    taps = []
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                dist = abs(dx) + abs(dy) + abs(dz)
                w = {0: 8.0, 1: 3.0, 2: 1.5, 3: 0.5}[dist] / 54.0
                taps.append((dx, dy, dz, w))
    for d in ((-2, 0, 0), (2, 0, 0), (0, -2, 0), (0, 2, 0), (0, 0, -2), (0, 0, 2)):
        taps.append((*d, 1.0 / 54.0))
    return tuple(taps)


SPECS = {
    "jacobi1d": StencilSpec("jacobi1d", 1, _jacobi1d()),
    "pts7_1d": StencilSpec("pts7_1d", 1, _pts7_1d()),
    "jacobi2d": StencilSpec("jacobi2d", 2, _jacobi2d()),
    "blur2d": StencilSpec("blur2d", 2, _blur2d()),
    "heat3d": StencilSpec("heat3d", 3, _heat3d()),
    "pts33_3d": StencilSpec("pts33_3d", 3, _pts33_3d()),
}


def grid_shape_3d(name: str, shape):
    """Normalize a natural-shape grid spec to (nz, ny, nx)."""
    spec = SPECS[name]
    if spec.dims == 1:
        (nx,) = shape
        return 1, 1, nx
    if spec.dims == 2:
        ny, nx = shape
        return 1, ny, nx
    nz, ny, nx = shape
    return nz, ny, nx


def interior_mask(name: str, shape) -> np.ndarray:
    """Boolean mask of interior points, flattened to (rows, nx).

    Interior = every tap in bounds, the shared boundary convention.
    """
    nz, ny, nx = grid_shape_3d(name, shape)
    rx, ry, rz = SPECS[name].radius
    x = np.arange(nx)
    y = np.arange(ny)
    z = np.arange(nz)
    mx = (x >= rx) & (x < nx - rx)
    my = (y >= ry) & (y < ny - ry)
    mz = (z >= rz) & (z < nz - rz)
    m = mz[:, None, None] & my[None, :, None] & mx[None, None, :]
    return m.reshape(nz * ny, nx)


def interior_mask_jax(name: str, shape) -> jnp.ndarray:
    """Interior mask computed with iota comparisons (no boolean constant).

    Functionally identical to :func:`interior_mask`, but built from
    integer iotas and runtime comparisons: the AOT path must not embed
    bit-packed ``pred`` constants, which xla_extension 0.5.1's MLIR→HLO
    converter mis-reads byte-wise (see DESIGN.md §3 and the probe in
    EXPERIMENTS.md).
    """
    nz, ny, nx = grid_shape_3d(name, shape)
    rx, ry, rz = SPECS[name].radius
    rows = nz * ny
    ix = jax.lax.broadcasted_iota(jnp.int32, (rows, nx), 1)
    irow = jax.lax.broadcasted_iota(jnp.int32, (rows, nx), 0)
    iy = irow % ny
    iz = irow // ny
    mx = (ix >= rx) & (ix < nx - rx)
    my = (iy >= ry) & (iy < ny - ry)
    mz = (iz >= rz) & (iz < nz - rz)
    return mx & my & mz


def ref_step(name: str, grid: jnp.ndarray) -> jnp.ndarray:
    """One Jacobi step of kernel ``name`` over a natural-shape grid."""
    spec = SPECS[name]
    nz, ny, nx = grid_shape_3d(name, grid.shape)
    flat = grid.reshape(nz * ny, nx)
    acc = jnp.zeros_like(flat)
    for dx, dy, dz, c in spec.taps:
        drow = dy + dz * ny
        # roll moves data opposite to the tap offset; wrap artifacts land
        # only on boundary points, which the mask restores below.
        acc = acc + c * jnp.roll(flat, shift=(-drow, -dx), axis=(0, 1))
    mask = jnp.asarray(interior_mask(name, grid.shape))
    out = jnp.where(mask, acc, flat)
    return out.reshape(grid.shape)


def ref_run(name: str, grid: jnp.ndarray, steps: int) -> jnp.ndarray:
    """``steps`` Jacobi iterations (ping-pong is implicit: ref_step is
    functional)."""
    for _ in range(steps):
        grid = ref_step(name, grid)
    return grid
