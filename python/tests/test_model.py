"""L2 model-level tests: masks, time stepping, shape plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import make_step_fn, stencil_run, stencil_step

jax.config.update("jax_enable_x64", True)


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).random(shape, dtype=np.float64))


def test_unknown_kernel_rejected():
    with pytest.raises(ValueError):
        stencil_step("bogus", rand((16,)))


def test_interior_mask_counts():
    # jacobi2d on (ny=12, nx=16): interior = 10 × 14.
    m = ref.interior_mask("jacobi2d", (12, 16))
    assert m.sum() == 10 * 14
    # blur2d radius 2: 8 × 12.
    m = ref.interior_mask("blur2d", (12, 16))
    assert m.sum() == 8 * 12
    # heat3d on (6, 8, 10): 4 × 6 × 8.
    m = ref.interior_mask("heat3d", (6, 8, 10))
    assert m.sum() == 4 * 6 * 8


def test_zero_steps_identity():
    g = rand((12, 16), 1)
    out = stencil_run("jacobi2d", g, 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


def test_steps_compose():
    g = rand((12, 16), 2)
    a = stencil_run("jacobi2d", g, 3)
    b = stencil_step("jacobi2d", stencil_step("jacobi2d", stencil_step("jacobi2d", g)))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


def test_smoothing_contracts_range():
    g = rand((12, 16), 3)
    out = stencil_run("blur2d", g, 2)
    assert float(jnp.max(out)) <= float(jnp.max(g)) + 1e-12
    assert float(jnp.min(out)) >= float(jnp.min(g)) - 1e-12


def test_make_step_fn_returns_tuple():
    fn, spec = make_step_fn("jacobi1d", (64,), steps=1)
    assert spec.shape == (64,)
    out = fn(rand((64,), 4))
    assert isinstance(out, tuple) and len(out) == 1
    want = ref.ref_step("jacobi1d", rand((64,), 4))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(want), rtol=1e-12, atol=1e-14)


def test_jit_matches_eager():
    g = rand((12, 16), 5)
    eager = stencil_step("jacobi2d", g)
    jitted = jax.jit(lambda x: stencil_step("jacobi2d", x))(g)
    np.testing.assert_allclose(np.asarray(eager), np.asarray(jitted), rtol=1e-15, atol=1e-15)
