"""AOT pipeline tests: lowering to HLO text and manifest consistency.

These are build-path tests — they verify the exact artifacts the Rust
runtime consumes (HLO text parseable by xla_extension 0.5.1's text
parser: no 64-bit-id protos, ENTRY present, f64 I/O shapes).
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def test_entries_cover_all_kernels():
    names = {e[1] for e in aot.entries()}
    assert names == set(ref.KERNELS)
    # tiny + tiny_s3 for each kernel, plus the L2 entries.
    assert len(aot.entries()) == 2 * len(ref.KERNELS) + len(aot.L2_SHAPES)


def test_natural_to_nzyx():
    assert aot.natural_to_nzyx("jacobi1d", (256,)) == (256, 1, 1)
    assert aot.natural_to_nzyx("jacobi2d", (12, 16)) == (16, 12, 1)
    assert aot.natural_to_nzyx("heat3d", (6, 8, 10)) == (10, 8, 6)


def test_lower_tiny_produces_hlo_text():
    text = aot.lower_entry("jacobi1d", (64,), 1)
    assert "ENTRY" in text
    assert "f64[64]" in text
    # HLO text, not a serialized proto.
    assert text.lstrip().startswith("HloModule")


def test_lowered_multistep_differs():
    one = aot.lower_entry("jacobi1d", (64,), 1)
    three = aot.lower_entry("jacobi1d", (64,), 3)
    assert len(three) > len(one)


def test_artifact_numerics_match_ref():
    """Execute the lowered computation via jax and compare to the oracle —
    the same check the Rust integration test performs through PJRT."""
    fn, spec = aot.make_step_fn("blur2d", (12, 16), 1)
    g = np.random.default_rng(7).random((12, 16))
    out = jax.jit(fn)(g)[0]
    want = ref.ref_step("blur2d", jax.numpy.asarray(g))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12, atol=1e-14)


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "jacobi1d_tiny"],
        capture_output=True,
        text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 1
    name, kernel, nx, ny, nz, steps, fname = manifest[0].split()
    assert (name, kernel) == ("jacobi1d_tiny", "jacobi1d")
    assert (int(nx), int(ny), int(nz), int(steps)) == (256, 1, 1, 1)
    assert (tmp_path / fname).exists()
