"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.stencil import stencil_pallas_raw, vmem_block_bytes
from compile.model import stencil_step, stencil_run

jax.config.update("jax_enable_x64", True)

SHAPES = {
    "jacobi1d": (64,),
    "pts7_1d": (64,),
    "jacobi2d": (12, 16),
    "blur2d": (12, 16),
    "heat3d": (6, 8, 10),
    "pts33_3d": (6, 8, 10),
}


def rand_grid(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random(shape, dtype=np.float64))


@pytest.mark.parametrize("name", ref.KERNELS)
def test_specs_normalized(name):
    spec = ref.SPECS[name]
    assert abs(spec.coef_sum() - 1.0) < 1e-9
    # Tap counts match the paper's §7.2 table.
    want = {"jacobi1d": 3, "pts7_1d": 7, "jacobi2d": 5, "blur2d": 25,
            "heat3d": 7, "pts33_3d": 33}[name]
    assert spec.num_points == want


@pytest.mark.parametrize("name", ref.KERNELS)
def test_pallas_matches_ref(name):
    g = rand_grid(SHAPES[name], seed=1)
    out = stencil_step(name, g)
    want = ref.ref_step(name, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12, atol=1e-14)


@pytest.mark.parametrize("name", ref.KERNELS)
def test_boundary_copies_through(name):
    g = rand_grid(SHAPES[name], seed=2)
    out = np.asarray(stencil_step(name, g))
    gin = np.asarray(g)
    mask = ref.interior_mask(name, g.shape).reshape(g.shape)
    np.testing.assert_array_equal(out[~mask], gin[~mask])
    # And the interior actually changed (random data is no fixed point).
    assert np.abs(out[mask] - gin[mask]).max() > 1e-6


@pytest.mark.parametrize("name", ref.KERNELS)
def test_constant_grid_is_fixed_point(name):
    g = jnp.full(SHAPES[name], 2.5, dtype=jnp.float64)
    out = stencil_run(name, g, 3)
    np.testing.assert_allclose(np.asarray(out), 2.5, rtol=0, atol=1e-12)


@pytest.mark.parametrize("name", ref.KERNELS)
def test_multistep_matches_ref(name):
    g = rand_grid(SHAPES[name], seed=3)
    out = stencil_run(name, g, 3)
    want = ref.ref_run(name, g, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12, atol=1e-13)


@pytest.mark.parametrize("block_rows", [1, 2, 4, 8, 16])
def test_block_rows_do_not_change_results(block_rows):
    # The HBM→VMEM schedule (block size) must be performance-only: on the
    # interior (the defined region) every block size is bit-identical.
    # Boundary rows hold schedule-dependent clamp/pad garbage by design.
    # (to within 1 ULP — XLA may fuse the MAC chain differently per
    # specialization).
    g = rand_grid((12, 16), seed=4)
    mask = ref.interior_mask("jacobi2d", g.shape)
    raw = np.asarray(stencil_pallas_raw("jacobi2d", g, block_rows=block_rows))
    base = np.asarray(stencil_pallas_raw("jacobi2d", g, block_rows=8))
    np.testing.assert_allclose(raw[mask], base[mask], rtol=1e-14, atol=1e-15)


@settings(max_examples=20, deadline=None)
@given(
    nx=st.integers(min_value=8, max_value=80).map(lambda v: v * 2),
    ny=st.integers(min_value=6, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_matches_ref_hypothesis_2d(nx, ny, seed):
    """Shape sweep: the Pallas kernel agrees with the oracle for arbitrary
    2D domains large enough to hold the blur halo."""
    g = rand_grid((ny, nx), seed=seed)
    for name in ("jacobi2d", "blur2d"):
        out = stencil_step(name, g)
        want = ref.ref_step(name, g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12, atol=1e-14)


@settings(max_examples=15, deadline=None)
@given(
    nx=st.integers(min_value=16, max_value=256),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_matches_ref_hypothesis_1d(nx, seed):
    g = rand_grid((nx,), seed=seed)
    for name in ("jacobi1d", "pts7_1d"):
        out = stencil_step(name, g)
        want = ref.ref_step(name, g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12, atol=1e-14)


@settings(max_examples=10, deadline=None)
@given(
    nz=st.integers(min_value=5, max_value=10),
    ny=st.integers(min_value=5, max_value=12),
    nx=st.integers(min_value=5, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_pallas_matches_ref_hypothesis_3d(nz, ny, nx, seed):
    g = rand_grid((nz, ny, nx), seed=seed)
    for name in ("heat3d", "pts33_3d"):
        out = stencil_step(name, g)
        want = ref.ref_step(name, g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12, atol=1e-14)


def test_float32_input_rejected_or_upcast():
    # The system contract is f64 end to end; a f32 grid must not silently
    # produce f32 garbage. stencil_step preserves dtype via where(), so we
    # simply document that f32 stays f32 and stays close to the oracle.
    g = rand_grid((12, 16), seed=5).astype(jnp.float32)
    out = stencil_step("jacobi2d", g)
    assert out.dtype == g.dtype
    want = ref.ref_step("jacobi2d", g.astype(jnp.float64))
    np.testing.assert_allclose(np.asarray(out, dtype=np.float64), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_vmem_estimate_fits_slice_budget():
    # §Perf: one program's working set stays under the 2 MB analogue for
    # every Table 3 domain.
    domains = {
        "jacobi1d": (4194304,),
        "jacobi2d": (2048, 2048),
        "blur2d": (2048, 2048),
        "heat3d": (64, 256, 256),
        "pts33_3d": (64, 256, 256),
        "pts7_1d": (4194304,),
    }
    for name, shape in domains.items():
        assert vmem_block_bytes(name, shape) <= 2 * 1024 * 1024, name
