//! The open-kernel-registry contract, end to end:
//!
//! - property tests over randomly generated valid [`KernelSpec`]s:
//!   golden `step` ≡ `step_serial` bitwise, ISA program stream count ==
//!   `row_groups() + 1`, and TOML round-trips exactly;
//! - negative validation (radius vs. domain);
//! - the checked-in `examples/kernels/hdiff9.toml` runs through the full
//!   simulator and matches the golden reference — a kernel defined only
//!   in TOML, no Rust changes;
//! - the extended presets (`hdiff`, `star25_3d`, `star17_3d`) behave like
//!   first-class kernels, and the experiment harness sweeps arbitrary
//!   kernel sets;
//! - multi-pass compilation (random 17–40-row specs always split into
//!   passes that each satisfy `Program::validate`, and the pass-split
//!   golden result is bitwise-identical to the unsplit serial oracle),
//!   with the checked-in `examples/kernels/wide17_2d.toml` running the
//!   2-pass path end to end.

use std::path::PathBuf;
use std::sync::Arc;

use casper::config::{SimConfig, SizeClass};
use casper::coordinator::{run_casper_spec, CasperOptions};
use casper::harness::{paper_kernels, run_experiments_with, Experiment, SweepOptions};
use casper::isa::ProgramBuilder;
use casper::stencil::{
    extended_presets, golden, KernelOrigin, KernelRegistry, KernelSpec, StencilPoint,
};
use casper::util::SplitMix64;

/// Generate a random spec that satisfies `KernelSpec::validate` by
/// construction: bounded radii keep the row count inside the 16-entry
/// stream buffer, and coefficients come from a small palette so the
/// constant buffer can't overflow.
fn random_spec(r: &mut SplitMix64, case: usize) -> KernelSpec {
    const PALETTE: [f64; 8] = [0.5, 0.25, 0.125, -0.125, 0.0625, 1.0, -0.5, 0.75];
    let dims = 1 + (r.next_u64() % 3) as usize;
    let rx = 1 + (r.next_u64() % 3) as i64; // 1..=3 <= MAX_SHIFT
    let ry = if dims >= 2 { 1 + (r.next_u64() % 2) as i64 } else { 0 };
    let rz = if dims >= 3 { (r.next_u64() % 2) as i64 } else { 0 };
    let mut points = Vec::new();
    for dz in -rz..=rz {
        for dy in -ry..=ry {
            // Each (dy, dz) row joins with ~60% probability; rows are
            // bounded by (2·2+1)·(2·1+1) = 15 in the worst 3D case, which
            // with the output stream exactly fits the stream buffer.
            if r.chance(0.4) && !(dy == 0 && dz == 0) {
                continue;
            }
            let mut any = false;
            for dx in -rx..=rx {
                // Hard cap well under MAX_INSTRUCTIONS (64): the worst
                // 3D roll is 15 rows × 7 dx candidates = 105 otherwise.
                if points.len() >= 56 {
                    break;
                }
                if r.chance(0.5) {
                    let coef = PALETTE[(r.next_u64() % 8) as usize];
                    points.push(StencilPoint::new(dx, dy, dz, coef));
                    any = true;
                }
            }
            if !any && points.len() < 56 {
                points.push(StencilPoint::new(0, dy, dz, PALETTE[case % 8]));
            }
        }
    }
    if points.is_empty() {
        points.push(StencilPoint::new(0, 0, 0, 0.5));
    }
    KernelSpec::new(
        &format!("prop_{case}"),
        &format!("Property kernel {case}"),
        dims,
        points,
        KernelOrigin::File,
    )
}

#[test]
fn property_random_specs_validate_and_cover_the_isa() {
    let mut rng = SplitMix64::new(0x5EC5);
    for case in 0..96 {
        let spec = random_spec(&mut rng, case);
        spec.validate().unwrap_or_else(|e| panic!("case {case}: {e:#} — {spec:?}"));
        // (b) of the satellite contract: ISA stream count tracks the
        // spec's row structure exactly, and the program validates.
        let prog = ProgramBuilder::new()
            .build(&spec)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        assert_eq!(
            prog.streams.len(),
            spec.row_groups().len() + 1,
            "case {case}: streams != row_groups + 1"
        );
        assert_eq!(prog.instrs.len(), spec.num_points(), "case {case}");
        prog.validate().unwrap();
    }
}

#[test]
fn property_golden_step_is_bitwise_identical_to_serial() {
    // (a) of the satellite contract, over *generated* kernels — not just
    // the presets the old test hard-coded.
    let mut rng = SplitMix64::new(0xB17);
    for case in 0..48 {
        let spec = random_spec(&mut rng, case);
        let d = spec.tiny_domain();
        let src = d.alloc_random(0xB17_1D ^ case as u64);
        let mut want = d.alloc();
        golden::step_serial(&spec, &src, &mut want);
        for threads in [1usize, 3, 8] {
            let mut got = d.alloc();
            golden::step_with_threads(&spec, &src, &mut got, threads);
            assert!(
                got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "case {case} ({}): threads={threads} diverged bitwise",
                spec.id
            );
        }
    }
}

#[test]
fn property_toml_roundtrip_is_exact() {
    // (c) of the satellite contract: write → parse reproduces the spec
    // exactly (ids, taps, coefficient bits, domains).
    let mut rng = SplitMix64::new(0x70A1);
    for case in 0..48 {
        let spec = random_spec(&mut rng, case);
        let text = spec.to_toml_string();
        let parsed = KernelSpec::from_toml_str(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}\n{text}"));
        assert_eq!(parsed, spec, "case {case}");
    }
}

#[test]
fn radius_exceeding_domain_is_rejected() {
    let text = "[kernel]\nid = \"wide\"\ndims = 1\n[domain]\nl2 = \"8\"\n\
                [tap-0]\ndx = -4\ncoef = 0.5\n[tap-1]\ndx = 4\ncoef = 0.5\n";
    let err = KernelSpec::from_toml_str(text).unwrap_err();
    assert!(format!("{err:#}").contains("smaller than halo"), "{err:#}");
    // The same kernel with a big-enough domain is fine.
    let ok = "[kernel]\nid = \"wide\"\ndims = 1\n[domain]\nl2 = \"64\"\n\
              [tap-0]\ndx = -4\ncoef = 0.5\n[tap-1]\ndx = 4\ncoef = 0.5\n";
    KernelSpec::from_toml_str(ok).unwrap();
}

fn example_kernel_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels/hdiff9.toml")
}

#[test]
fn checked_in_example_file_loads() {
    let mut reg = KernelRegistry::builtin();
    let spec = reg.load_file(&example_kernel_path()).unwrap();
    assert_eq!(spec.id.as_str(), "hdiff9");
    assert_eq!(spec.origin, KernelOrigin::File);
    assert_eq!(spec.num_points(), 9);
    assert_eq!(spec.radius(), [2, 2, 0]);
    assert!((spec.coef_sum() - 1.0).abs() < 1e-12);
    assert_eq!(spec.domain(SizeClass::L2).points(), 512 * 256);
    // Irregular coefficients: x and y arms differ (the NERO motivation).
    let x1 = spec.points.iter().find(|p| p.dx == 1 && p.dy == 0).unwrap().coef;
    let y1 = spec.points.iter().find(|p| p.dx == 0 && p.dy == 1).unwrap().coef;
    assert_ne!(x1.to_bits(), y1.to_bits());
}

#[test]
fn file_defined_kernel_runs_end_to_end_golden_verified() {
    // The acceptance criterion: a kernel defined ONLY in a TOML file (no
    // Rust changes) runs through the full simulator and matches golden.
    let cfg = SimConfig::default();
    let mut reg = KernelRegistry::builtin();
    let spec = reg.load_file(&example_kernel_path()).unwrap();
    let d = spec.tiny_domain();
    let opts = CasperOptions::default();
    let stats = run_casper_spec(&cfg, &spec, &d, 2, opts).unwrap();
    let want = golden::run_spec(&spec, &d, 2, opts.seed);
    let diff = stats.output.max_abs_diff(&want);
    assert!(diff < 1e-12, "hdiff9 diverges from golden: {diff}");
    assert!(stats.cycles > 0);
    assert!(stats.total_instrs > 0);
}

#[test]
fn extended_presets_run_end_to_end_golden_verified() {
    let cfg = SimConfig::default();
    for spec in extended_presets() {
        let d = spec.tiny_domain();
        let opts = CasperOptions::default();
        let stats = run_casper_spec(&cfg, &spec, &d, 1, opts).unwrap();
        let want = golden::run_spec(&spec, &d, 1, opts.seed);
        let diff = stats.output.max_abs_diff(&want);
        assert!(diff < 1e-12, "{}: {diff}", spec.id);
    }
}

#[test]
fn file_kernel_appears_in_experiment_report() {
    // Selected into the sweep, the TOML kernel shows up in the tables
    // with `-` in the paper-reference columns.
    let cfg = SimConfig::default();
    let mut reg = KernelRegistry::builtin();
    let spec = reg.load_file(&example_kernel_path()).unwrap();
    let mut kernels = paper_kernels();
    kernels.push(Arc::clone(&spec));
    let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
    let report = run_experiments_with(&cfg, &[Experiment::Fig10], opts, &kernels).unwrap();
    let t = report.get("fig10").unwrap();
    assert_eq!(t.rows.len(), 7);
    let row = t
        .rows
        .iter()
        .find(|r| r[0] == "HDiff 9-point (file)")
        .expect("file kernel missing from fig10");
    assert_eq!(row[5], "-", "{row:?}");
    assert!(row[4].ends_with('x'), "{row:?}");
}

/// Generate a random spec that is *wider than the ISA envelope*: 17–40
/// distinct rows in 3D, so a single program can never hold it (a pass
/// plan must). Taps stay inside the per-tap hard limits (|dx| ≤ 2,
/// palette coefficients), so `validate` must accept every case.
fn random_wide_spec(r: &mut SplitMix64, case: usize) -> KernelSpec {
    const PALETTE: [f64; 8] = [0.5, 0.25, 0.125, -0.125, 0.0625, 1.0, -0.5, 0.75];
    let n_rows = 17 + (r.next_u64() % 24) as usize; // 17..=40
    let mut offsets: Vec<(i64, i64)> = (-4i64..=4)
        .flat_map(|dz| (-4i64..=4).map(move |dy| (dy, dz)))
        .collect();
    // Fisher–Yates over the 81 candidate (dy, dz) rows, take the first n.
    for i in (1..offsets.len()).rev() {
        let j = (r.next_u64() % (i as u64 + 1)) as usize;
        offsets.swap(i, j);
    }
    let mut points = Vec::new();
    for &(dy, dz) in offsets.iter().take(n_rows) {
        let n_taps = 1 + (r.next_u64() % 3) as usize;
        let mut dxs: Vec<i64> = (-2..=2).collect();
        for i in (1..dxs.len()).rev() {
            let j = (r.next_u64() % (i as u64 + 1)) as usize;
            dxs.swap(i, j);
        }
        for &dx in dxs.iter().take(n_taps) {
            let coef = PALETTE[(r.next_u64() % 8) as usize];
            points.push(StencilPoint::new(dx, dy, dz, coef));
        }
    }
    KernelSpec::new(
        &format!("wide_{case}"),
        &format!("Wide property kernel {case}"),
        3,
        points,
        KernelOrigin::File,
    )
}

#[test]
fn property_wide_specs_split_into_validating_passes() {
    // The multi-pass satellite contract: every generated past-the-envelope
    // spec (17–40 rows) validates, plans more than one pass, compiles to
    // per-pass programs that each pass `Program::validate`, covers every
    // row exactly once, and — the core guarantee — the pass-split golden
    // result is BITWISE identical to the unsplit serial oracle over the
    // program-ordered view of the same kernel.
    let mut rng = SplitMix64::new(0x9A55_17);
    for case in 0..24 {
        let spec = random_wide_spec(&mut rng, case);
        spec.validate().unwrap_or_else(|e| panic!("case {case}: {e:#} — {spec:?}"));
        let plan = spec.pass_plan().unwrap();
        let n_rows = spec.row_groups().len();
        assert!(plan.is_multi_pass(), "case {case}: {n_rows} rows fit one pass?");
        let single = ProgramBuilder::new().build(&spec);
        assert!(single.is_err(), "case {case}: single-pass build must reject");

        let programs = ProgramBuilder::build_passes(&spec)
            .unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        assert_eq!(programs.len(), plan.num_passes(), "case {case}");
        for (pi, p) in programs.iter().enumerate() {
            p.validate().unwrap_or_else(|e| panic!("case {case} pass {pi}: {e:#}"));
            assert_eq!(p.accumulates(), pi > 0, "case {case} pass {pi}");
        }
        // Every row appears in exactly one pass (accumulator streams and
        // outputs excluded).
        let rows: usize = programs
            .iter()
            .map(|p| p.streams.iter().filter(|s| !s.is_output && !s.from_output).count())
            .sum();
        assert_eq!(rows, spec.row_groups().len(), "case {case}");

        let d = spec.tiny_domain();
        let src = d.alloc_random(0x1D_5EED ^ case as u64);
        let mut want = d.alloc();
        golden::step_serial(&spec.program_ordered(), &src, &mut want);
        let mut got = d.alloc();
        golden::step_multipass(&spec, &src, &mut got);
        assert!(
            got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "case {case} ({}): pass-split oracle diverged bitwise",
            spec.id
        );
    }
}

fn wide_kernel_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels/wide17_2d.toml")
}

#[test]
fn wide_file_kernel_runs_end_to_end_multipass() {
    // The acceptance path for TOML-defined wide kernels: 17 rows → a
    // 2-pass plan, executed by the full simulator (under whatever
    // CASPER_SPU_THREADS the CI matrix sets) and bitwise-identical to the
    // pass-split golden oracle — the file lists its taps in program
    // order, so all accumulation orders coincide.
    let cfg = SimConfig::default();
    let mut reg = KernelRegistry::builtin();
    let spec = reg.load_file(&wide_kernel_path()).unwrap();
    assert_eq!(spec.id.as_str(), "wide17_2d");
    assert_eq!(spec.row_groups().len(), 17);
    assert_eq!(spec.program_ordered().points, spec.points, "file must be program-ordered");
    assert!((spec.coef_sum() - 1.0).abs() < 1e-12);
    let plan = spec.pass_plan().unwrap();
    assert_eq!(plan.num_passes(), 2);

    let d = spec.tiny_domain();
    let opts = CasperOptions::default();
    let stats = run_casper_spec(&cfg, &spec, &d, 2, opts).unwrap();
    assert_eq!(stats.passes, 2);
    let input = d.alloc_random(opts.seed);
    let want = golden::run_multipass(&spec, &input, 2);
    assert!(
        stats.output.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
        "wide17_2d diverged bitwise from the pass-split golden oracle"
    );
}

#[test]
fn wide_file_kernel_appears_in_experiment_report() {
    // Sweeps and reports handle multi-pass kernels like any other: the
    // wide kernel lands in the fig10 grid with `-` paper-reference cells.
    let cfg = SimConfig::default();
    let mut reg = KernelRegistry::builtin();
    let spec = reg.load_file(&wide_kernel_path()).unwrap();
    let mut kernels = paper_kernels();
    kernels.push(Arc::clone(&spec));
    let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
    let report = run_experiments_with(&cfg, &[Experiment::Fig10], opts, &kernels).unwrap();
    let t = report.get("fig10").unwrap();
    let row = t
        .rows
        .iter()
        .find(|r| r[0] == "Wide 17-row 2D")
        .expect("wide kernel missing from fig10");
    assert_eq!(row[5], "-", "{row:?}");
    assert!(row[4].ends_with('x'), "{row:?}");
}

#[test]
fn duplicate_and_unknown_ids_error_cleanly() {
    let mut reg = KernelRegistry::builtin();
    reg.load_file(&example_kernel_path()).unwrap();
    let err = reg.load_file(&example_kernel_path()).unwrap_err();
    assert!(format!("{err:#}").contains("duplicate kernel id"), "{err:#}");
    assert!(reg.resolve("definitely_not_a_kernel").is_none());
}
