//! Property tests for temporal blocking and fused stencil+reduce passes:
//! random (spec, domain, T) triples must keep the final grid bitwise
//! identical to plain T=1 chaining on both engines, fused reductions must
//! match the golden two-pass reference bitwise, and halos grown past the
//! domain must be rejected, not silently mis-simulated.

use casper::config::SimConfig;
use casper::coordinator::{run_casper_spec, CasperOptions, RunStats};
use casper::isa::ReduceOp;
use casper::stencil::{golden, Domain, KernelOrigin, KernelSpec, ReductionSpec, StencilPoint};

/// xorshift64* — deterministic case generation without external crates.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// A random axis-star spec with radius <= 2, taps listed in program order
/// (rows sorted by (dz, dy), in-row taps by dx) so the engine's
/// accumulation order matches the golden oracle's tap order and every
/// comparison below can be bitwise, not approximate.
fn random_spec(rng: &mut Rng, case: usize) -> KernelSpec {
    let dims = 1 + rng.below(3) as usize;
    let r = 1 + rng.below(2) as i64; // radius 1 or 2
    let mut taps: Vec<(i64, i64, i64)> = vec![(0, 0, 0)];
    for d in 1..=r {
        taps.push((-d, 0, 0));
        taps.push((d, 0, 0));
        if dims >= 2 && rng.below(2) == 0 {
            taps.push((0, -d, 0));
            taps.push((0, d, 0));
        }
        if dims == 3 && rng.below(2) == 0 {
            taps.push((0, 0, -d));
            taps.push((0, 0, d));
        }
    }
    // Program order: rows by (dz, dy), then dx within the row.
    taps.sort_by_key(|&(dx, dy, dz)| (dz, dy, dx));
    let n = taps.len() as f64;
    let points: Vec<StencilPoint> = taps
        .into_iter()
        .map(|(dx, dy, dz)| StencilPoint::new(dx, dy, dz, 1.0 / n))
        .collect();
    let id = format!("prop_tb_{case}");
    KernelSpec::new(&id, &id, dims, points, KernelOrigin::File)
}

/// A random domain comfortably larger than radius-2 x T=3 halos.
fn random_domain(rng: &mut Rng, dims: usize) -> Domain {
    match dims {
        1 => Domain::new(64 + rng.below(64) as usize, 1, 1),
        2 => Domain::new(24 + rng.below(16) as usize, 16 + rng.below(8) as usize, 1),
        _ => Domain::new(
            16 + rng.below(8) as usize,
            14 + rng.below(4) as usize,
            13 + rng.below(3) as usize,
        ),
    }
}

fn run(cfg: &SimConfig, spec: &KernelSpec, d: &Domain, t: usize, threads: usize) -> RunStats {
    let opts = CasperOptions { spu_threads: threads, temporal_block: t, ..Default::default() };
    run_casper_spec(cfg, spec, d, 4, opts)
        .unwrap_or_else(|e| panic!("{} T={t} threads={threads}: {e:#}", spec.id))
}

#[test]
fn blocked_grids_are_bitwise_identical_to_chaining_on_both_engines() {
    let cfg = SimConfig::default();
    let mut rng = Rng(0xB10C_ED00_9E37_79B9);
    for case in 0..8 {
        let spec = random_spec(&mut rng, case);
        spec.validate().expect("generated spec must be valid");
        let d = random_domain(&mut rng, spec.dims);
        let base = run(&cfg, &spec, &d, 1, 1);
        assert_eq!(base.avoided_fills(), 0, "case {case}: T=1 avoids nothing");
        assert_eq!(base.halo_recompute_cells, 0, "case {case}");
        for t in 2..4 {
            let serial = run(&cfg, &spec, &d, t, 1);
            let parallel = run(&cfg, &spec, &d, t, 16);
            assert_eq!(
                serial.grid_digest(),
                base.grid_digest(),
                "case {case} ({} @ {d}): blocked T={t} grid must be bitwise T=1's",
                spec.id
            );
            assert_eq!(serial.output, base.output, "case {case} T={t}");
            assert_eq!(
                serial, parallel,
                "case {case} T={t}: serial and epoch-parallel engines must agree exactly"
            );
            assert_eq!(serial.temporal_block, t);
            assert!(
                serial.avoided_fills() > 0,
                "case {case} T={t}: inner steps must avoid LLC fills"
            );
        }
    }
}

#[test]
fn fused_reductions_match_the_golden_two_pass_reference_bitwise() {
    let cfg = SimConfig::default();
    let mut rng = Rng(0xFEED_FACE_CAFE_F00D);
    let ops = [ReduceOp::Sum, ReduceOp::AbsDiff, ReduceOp::Max];
    for case in 0..6 {
        let mut spec = random_spec(&mut rng, 100 + case);
        spec.reduction = Some(ReductionSpec { op: ops[case % ops.len()] });
        spec.validate().expect("generated spec must be valid");
        let d = random_domain(&mut rng, spec.dims);
        let stats = run(&cfg, &spec, &d, 1, 1);
        let fused = stats.reduction.as_ref().expect("engine must report the fused reduction");
        let input = d.alloc_random(CasperOptions::default().seed);
        let (want_grid, want_vals) = golden::run_reduced(&spec, &input, 4);
        assert_eq!(fused.op, ops[case % ops.len()]);
        assert_eq!(
            fused.values, want_vals,
            "case {case} ({}): fused values must be bitwise the two-pass reference's",
            spec.id
        );
        assert_eq!(stats.output, want_grid, "case {case}: fused pass must not move the grid");
        // Fusion adds no pass: the plan is identical to the plain kernel's.
        let mut plain = spec.clone();
        plain.reduction = None;
        let plain_stats = run(&cfg, &plain, &d, 1, 1);
        assert_eq!(stats.passes, plain_stats.passes, "case {case}: no extra pass for the reduce");
        assert_eq!(stats.output, plain_stats.output, "case {case}");
        // And the engines agree on the reduction bitwise too.
        let par = run(&cfg, &spec, &d, 1, 16);
        assert_eq!(stats, par, "case {case}: engine identity must cover reduction results");
    }
}

#[test]
fn blocked_halos_larger_than_the_domain_are_rejected() {
    let cfg = SimConfig::default();
    let mut rng = Rng(0xDEAD_BEEF_0BAD_F00D);
    for case in 0..4 {
        let spec = random_spec(&mut rng, 200 + case);
        let [rx, ry, rz] = spec.radius();
        let r = rx.max(ry).max(rz);
        // A domain that fits the plain halo but not the T=3 one: the
        // largest axis gets exactly 2*r*3 cells, one short of the bound.
        let squeeze = 2 * r * 3;
        let d = match spec.dims {
            1 => Domain::new(squeeze, 1, 1),
            2 => Domain::new(squeeze.max(2 * r + 1), squeeze, 1),
            _ => Domain::new(squeeze.max(2 * r + 1), squeeze.max(2 * r + 1), squeeze),
        };
        spec.validate_blocked(&d, 1).expect("plain halo must fit");
        let err = spec.validate_blocked(&d, 3).expect_err("T=3 halo must not fit");
        assert!(
            err.to_string().contains("temporally blocked halo"),
            "case {case}: {err:#}"
        );
        let opts = CasperOptions { temporal_block: 3, ..Default::default() };
        let run_err = run_casper_spec(&cfg, &spec, &d, 2, opts)
            .expect_err("the engine must refuse the oversized block");
        assert!(run_err.to_string().contains("temporally blocked halo"), "{run_err:#}");
        // T=0 is rejected before any halo math.
        let zero = CasperOptions { temporal_block: 0, ..Default::default() };
        let zero_err = run_casper_spec(&cfg, &spec, &d, 1, zero).expect_err("T=0 must error");
        assert!(zero_err.to_string().contains(">= 1"), "{zero_err:#}");
    }
}
