//! The optimizing pass planner's equivalence contract, end to end:
//!
//! - the committed corpus under `tests/corpus/` — shrinker-minimized
//!   reproducers of planner edge shapes — replays FIRST, with each
//!   file's plan shape pinned exactly;
//! - planner property tests over the harness's own envelope-stressing
//!   generator: every pass ISA-legal, passes an exact partition of the
//!   row groups, plans deterministic, `passes(Optimized) <=
//!   passes(Greedy)` on every spec;
//! - the strict-win pin: `wide_mix_2d` ships at greedy 4 / optimized 2
//!   passes, while kernels already at their pass-count lower bound
//!   (star17_3d, wide17_2d) stay there under both strategies;
//! - blackbox equivalence: both strategies × both engines, bitwise
//!   against the plan-aware golden oracle (`verify::check_spec`), on the
//!   corpus, the shipped presets, and a fixed-seed random slice (the
//!   release-mode `casper verify --specs 64` CI leg runs the wide sweep);
//! - `KernelSpec::validate` error paths: the planner never sees zero-tap
//!   or duplicate-offset specs, and the 3-bit shift limit survives
//!   reordering because it is checked per tap before any plan exists;
//! - the shrinking loop: a planted mis-plan is caught by
//!   `verify::check_partition`, and `verify::shrink_spec` reduces a
//!   failing spec to a minimal committable TOML reproducer.

use std::path::PathBuf;

use casper::config::SimConfig;
use casper::isa::{PassPlan, PlanStrategy, ProgramBuilder};
use casper::stencil::{extended_presets, KernelOrigin, KernelSpec, StencilPoint};
use casper::util::SplitMix64;
use casper::verify;

fn corpus_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/corpus")
}

/// Every committed corpus spec, sorted by file name (deterministic order).
fn corpus_specs() -> Vec<(String, KernelSpec)> {
    let mut names: Vec<PathBuf> = std::fs::read_dir(corpus_dir())
        .expect("tests/corpus exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "toml"))
        .collect();
    names.sort();
    assert!(!names.is_empty(), "corpus must not be empty");
    names
        .into_iter()
        .map(|p| {
            let text = std::fs::read_to_string(&p).unwrap();
            let spec = KernelSpec::from_toml_str(&text)
                .unwrap_or_else(|e| panic!("{}: {e:#}", p.display()));
            (p.file_name().unwrap().to_string_lossy().into_owned(), spec)
        })
        .collect()
}

fn plan(spec: &KernelSpec, strategy: PlanStrategy) -> PassPlan {
    spec.pass_plan_with(strategy).unwrap_or_else(|e| panic!("{}: {e:#}", spec.id))
}

#[test]
fn corpus_replays_with_pinned_plan_shapes() {
    // Regressions first: each committed reproducer's plan shape is pinned
    // exactly, so a planner change that re-plans one of them fails here
    // with the file name — before the randomized sweep ever runs.
    let specs = corpus_specs();
    assert_eq!(specs.len(), 4, "update the pins when committing new corpus files");
    for (file, spec) in &specs {
        let greedy = plan(spec, PlanStrategy::Greedy);
        let opt = plan(spec, PlanStrategy::Optimized);
        match spec.id.as_str() {
            "dual_family_16" => {
                // The affinity win: twin rows (dy, dy+10) share constants.
                assert_eq!(greedy.num_passes(), 4, "{file}");
                assert_eq!(opt.num_passes(), 2, "{file}");
                assert!(!opt.order_preserving(), "{file}");
                assert_eq!(opt.passes()[0], vec![0, 1, 2, 3, 4, 10, 11, 12, 13, 14], "{file}");
                assert_eq!(opt.passes()[1], vec![5, 6, 7, 8, 9, 15], "{file}");
            }
            "shift_limit_1d" => {
                // MAX_SHIFT at both extremes still fits one program.
                assert_eq!(greedy.num_passes(), 1, "{file}");
                assert_eq!(opt.num_passes(), 1, "{file}");
                assert!(opt.order_preserving(), "{file}");
            }
            "const_budget_2d" => {
                // Split forced by constants, not streams; only one legal
                // 2-pass contiguous split exists, so Optimized == Greedy.
                assert_eq!(greedy.num_passes(), 2, "{file}");
                assert_eq!(opt.passes(), greedy.passes(), "{file}");
                assert!(opt.order_preserving(), "{file}");
            }
            "acc_chain_31" => {
                // 3-pass floor; the DP flattens 15|14|2 to 11|10|10.
                assert_eq!(greedy.num_passes(), 3, "{file}");
                assert_eq!(greedy.passes()[0].len(), 15, "{file}");
                assert_eq!(opt.num_passes(), 3, "{file}");
                assert!(opt.order_preserving(), "{file}");
                let sizes: Vec<usize> = opt.passes().iter().map(Vec::len).collect();
                assert_eq!(sizes, vec![11, 10, 10], "{file}");
                assert!(opt.peak_streams() < greedy.peak_streams(), "{file}");
            }
            other => panic!("{file}: unpinned corpus kernel '{other}'"),
        }
    }
}

#[test]
fn corpus_passes_the_blackbox_equivalence_check() {
    let cfg = SimConfig::default();
    for (file, spec) in &corpus_specs() {
        verify::check_spec(&cfg, spec, &spec.tiny_domain(), 2)
            .unwrap_or_else(|e| panic!("{file}: {e}"));
    }
}

#[test]
fn random_plans_are_legal_partitions_and_deterministic() {
    // The planner property sweep: every generated spec's plans (both
    // strategies) are envelope-legal (every compiled pass satisfies
    // `Program::validate`: <= 16 streams, <= 64 instructions, <= 16
    // constants), partition the row groups exactly, replan identically,
    // and never cost Optimized more passes than Greedy. check_plans is
    // exactly that contract; a failure message names the violated leg.
    for case in 0..48 {
        let spec = verify::random_spec(&mut SplitMix64::new(0x9E12 + case as u64), case);
        spec.validate().unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        verify::check_plans(&spec).unwrap_or_else(|e| panic!("case {case}: {e}"));
    }
}

#[test]
fn optimized_wins_passes_on_the_shipped_wide_preset() {
    // The acceptance pin: a SHIPPED preset where Optimized strictly
    // beats Greedy. star17_3d and wide17_2d already sit at their 2-pass
    // lower bound (16 < rows <= 29 needs >= 2 passes and greedy finds 2),
    // so the strict win ships on wide_mix_2d, built for this shape.
    let mix = extended_presets()
        .into_iter()
        .find(|s| s.id.as_str() == "wide_mix_2d")
        .expect("wide_mix_2d preset");
    let greedy = plan(&mix, PlanStrategy::Greedy);
    let opt = plan(&mix, PlanStrategy::Optimized);
    assert_eq!(greedy.num_passes(), 4);
    assert_eq!(opt.num_passes(), 2);
    assert!(!opt.order_preserving());

    // Kernels already at the lower bound stay there under both
    // strategies: 17 row groups cannot fit 1 pass (15-row limit), and
    // both planners find 2.
    let star = extended_presets()
        .into_iter()
        .find(|s| s.id.as_str() == "star17_3d")
        .expect("star17_3d preset");
    let wide17_path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels/wide17_2d.toml");
    let wide17_text = std::fs::read_to_string(wide17_path).unwrap();
    let wide17 = KernelSpec::from_toml_str(&wide17_text).unwrap();
    for spec in [&star, &wide17] {
        assert_eq!(plan(spec, PlanStrategy::Greedy).num_passes(), 2, "{}", spec.id);
        assert_eq!(plan(spec, PlanStrategy::Optimized).num_passes(), 2, "{}", spec.id);
    }
}

#[test]
fn random_specs_are_blackbox_equivalent_on_both_engines() {
    // A fixed-seed slice of the full `casper verify` sweep, in-tree: the
    // release-mode CI leg runs 64 specs; debug tests keep a smaller
    // count. Seed and generator are shared with the CLI, so a failure
    // here reproduces under `casper verify --seed ... --specs N`.
    let cfg = SimConfig::default();
    let opts = verify::VerifyOptions { specs: 8, seed: 0xCA5_9E12, steps: 2 };
    let report = verify::run_verify(&cfg, &opts);
    if let Some(f) = report.failure {
        panic!(
            "case {} ({}) failed: {}\nminimized reproducer:\n{}",
            f.case, f.spec_id, f.error, f.minimized_toml
        );
    }
    assert_eq!(report.checked, 8);
}

#[test]
fn validate_rejects_planner_hostile_specs() {
    // The planner only ever sees validated specs; these error paths are
    // its input contract.
    let zero_taps = KernelSpec::new("zt", "zero taps", 2, Vec::new(), KernelOrigin::File);
    let err = zero_taps.validate().unwrap_err().to_string();
    assert!(err.contains("at least one tap"), "{err}");

    let dup = KernelSpec::new(
        "dup",
        "duplicate offsets",
        2,
        vec![StencilPoint::new(0, 1, 0, 0.5), StencilPoint::new(0, 1, 0, 0.25)],
        KernelOrigin::File,
    );
    let err = dup.validate().unwrap_err().to_string();
    assert!(err.contains("duplicate tap"), "{err}");

    // |dx| = 8 exceeds the 3-bit shift field. The limit is PER TAP, so
    // no reordering or pass split could ever legalize it — validate
    // rejects it before either strategy plans, and both planners agree.
    let shift = KernelSpec::new(
        "s8",
        "shift 8",
        1,
        vec![StencilPoint::new(8, 0, 0, 0.5), StencilPoint::new(0, 0, 0, 0.5)],
        KernelOrigin::File,
    );
    let err = shift.validate().unwrap_err().to_string();
    assert!(err.contains("3-bit shift"), "{err}");
    for strategy in PlanStrategy::ALL {
        // Planning the groups directly (bypassing validate) still fails:
        // the shift check lives in the pass planner too.
        let r = ProgramBuilder::build_passes_with(&shift, strategy);
        assert!(r.is_err(), "{strategy} accepted |dx| = 8");
    }
}

#[test]
fn planted_mis_plan_is_caught_and_shrinks_to_a_minimal_toml() {
    // The harness end of the loop, demonstrated on a planted bug:
    // (1) a corrupted partition — row group duplicated into two passes,
    // another dropped — is exactly what check_partition rejects;
    let spec = corpus_specs()
        .into_iter()
        .find(|(_, s)| s.id.as_str() == "acc_chain_31")
        .map(|(_, s)| s)
        .unwrap();
    let good = plan(&spec, PlanStrategy::Optimized);
    let n = spec.row_groups().len();
    assert!(verify::check_partition(n, good.passes()).is_ok());
    let mut bad: Vec<Vec<usize>> = good.passes().to_vec();
    bad[2][0] = bad[0][0]; // duplicate group 0, drop the one it replaced
    let err = verify::check_partition(n, &bad).unwrap_err();
    assert!(err.contains("two passes"), "{err}");

    // (2) a failing spec shrinks to a minimal reproducer that round-trips
    // through committable TOML. The planted predicate ("a plan under
    // Optimized still needs more than one pass") bottoms out at 16
    // single-tap rows — one past the 15-row single-pass stream limit, the
    // smallest multi-pass witness inside this spec.
    let min = verify::shrink_spec(&spec, |s| {
        s.pass_plan_with(PlanStrategy::Optimized).map(|p| p.is_multi_pass()).unwrap_or(false)
    });
    assert_eq!(min.points.len(), 16, "one past the 15-row single-pass limit");
    let toml = min.to_toml_string();
    let parsed = KernelSpec::from_toml_str(&toml).unwrap();
    assert_eq!(parsed.points, min.points);
    assert!(plan(&parsed, PlanStrategy::Optimized).is_multi_pass());
}
