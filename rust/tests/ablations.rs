//! Ablation studies as executable assertions: the §4.1 unaligned-load
//! hardware, the §4.2 mapping, §4.4 way reservation, and config-file
//! plumbing.

use casper::config::{MappingPolicy, SimConfig, SizeClass};
use casper::coordinator::{run_casper_with, CasperOptions};
use casper::stencil::{Domain, StencilKind};

#[test]
fn unaligned_hardware_earns_its_area() {
    // §4.1: without the dual-tag/row-shift support, every unaligned
    // vector load costs two LLC accesses; with it, one. For the 7-point
    // 1D kernel (6 of 7 taps unaligned) this must show up as (a) fewer
    // LLC accesses and (b) a real speedup.
    let cfg = SimConfig::default();
    let kind = StencilKind::Points7_1D;
    let d = Domain::for_level(kind, SizeClass::Llc);
    let with_hw = run_casper_with(&cfg, kind, &d, 1, CasperOptions::default()).unwrap();
    let without = run_casper_with(
        &cfg,
        kind,
        &d,
        1,
        CasperOptions { unaligned_hw: false, ..Default::default() },
    )
    .unwrap();
    assert!(with_hw.spu.merged_unaligned > 0);
    assert_eq!(without.spu.merged_unaligned, 0);
    assert!(
        without.llc.accesses() > with_hw.llc.accesses(),
        "splitting must cost extra LLC accesses: {} vs {}",
        without.llc.accesses(),
        with_hw.llc.accesses()
    );
    assert!(
        without.cycles as f64 > with_hw.cycles as f64 * 1.2,
        "expected >20% cost without the hardware: {} vs {}",
        without.cycles,
        with_hw.cycles
    );
    // Fig 4's accounting: 3 aligned-equivalent loads/group with hw, 5+
    // without (6 load/store per 3 MAC).
}

#[test]
fn stencil_mapping_beats_baseline_hash_on_1d() {
    // §4.2 / Fig 14: for 1D kernels the stencil-segment hash keeps all
    // loads local; the baseline hash scatters them across slices.
    let kind = StencilKind::Jacobi1D;
    let d = Domain::for_level(kind, SizeClass::Llc);
    let mut seg_cfg = SimConfig::default();
    seg_cfg.mapping = MappingPolicy::StencilSegment;
    let mut base_cfg = SimConfig::default();
    base_cfg.mapping = MappingPolicy::Baseline;
    let seg = run_casper_with(&seg_cfg, kind, &d, 1, CasperOptions::default()).unwrap();
    let base = run_casper_with(&base_cfg, kind, &d, 1, CasperOptions::default()).unwrap();
    assert!(seg.local_fraction() > 0.95);
    assert!(base.local_fraction() < 0.2);
    assert!(
        base.cycles > seg.cycles,
        "baseline hash should cost cycles: {} vs {}",
        base.cycles,
        seg.cycles
    );
    assert!(base.noc_messages > seg.noc_messages * 5);
}

#[test]
fn way_reservation_costs_little_for_llc_sets() {
    // §4.4: reserving one way for concurrent CPU work leaves 15/16 of
    // the LLC — cache-resident stencils should barely notice vs a
    // hypothetical 0-reservation config.
    let kind = StencilKind::Jacobi2D;
    let d = Domain::for_level(kind, SizeClass::Llc);
    let mut no_reserve = SimConfig::default();
    no_reserve.llc.reserved_ways = 0;
    let reserved = run_casper_with(&SimConfig::default(), kind, &d, 1, CasperOptions::default())
        .unwrap();
    let full = run_casper_with(&no_reserve, kind, &d, 1, CasperOptions::default()).unwrap();
    let ratio = reserved.cycles as f64 / full.cycles as f64;
    assert!((0.95..1.1).contains(&ratio), "reservation overhead too big: {ratio}");
}

#[test]
fn cold_llc_costs_more_than_warm() {
    // The warm-up option models the paper's LLC-resident working sets;
    // a cold run must stream from DRAM and cost strictly more.
    let kind = StencilKind::Jacobi2D;
    let d = Domain::for_level(kind, SizeClass::Llc);
    let cfg = SimConfig::default();
    let warm = run_casper_with(&cfg, kind, &d, 1, CasperOptions::default()).unwrap();
    let cold = run_casper_with(
        &cfg,
        kind,
        &d,
        1,
        CasperOptions { warm_llc: false, ..Default::default() },
    )
    .unwrap();
    assert!(cold.cycles > warm.cycles * 2, "{} vs {}", cold.cycles, warm.cycles);
    assert!(cold.dram_accesses > warm.dram_accesses);
    // Identical numerics either way.
    assert_eq!(cold.output, warm.output);
}

#[test]
fn config_file_roundtrip_drives_the_engine() {
    // End-to-end config plumbing: a TOML file that shrinks the machine
    // must parse, validate, and actually change simulation results.
    let dir = std::env::temp_dir().join("casper_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("small.toml");
    std::fs::write(
        &path,
        r#"
# a 4-slice machine
[cpu]
cores = 4

[llc]
slices = 4

[spu]
count = 4

[noc]
mesh_x = 2
mesh_y = 2

[prefetch]
degree = 2
"#,
    )
    .unwrap();
    let cfg = SimConfig::from_file(&path).unwrap();
    assert_eq!(cfg.llc.slices, 4);
    let kind = StencilKind::Jacobi1D;
    // L2-sized: 8 output blocks → 8 SPUs on the default machine, 4 on
    // the shrunken one, so cycle counts must differ.
    let d = Domain::for_level(kind, SizeClass::L2);
    let small = run_casper_with(&cfg, kind, &d, 1, CasperOptions::default()).unwrap();
    let big = run_casper_with(&SimConfig::default(), kind, &d, 1, CasperOptions::default())
        .unwrap();
    // Same numerics, different machine.
    assert_eq!(small.output, big.output);
    assert_ne!(small.cycles, big.cycles);
}

#[test]
fn steps_scale_work_linearly() {
    let cfg = SimConfig::default();
    let kind = StencilKind::Heat3D;
    let d = Domain::tiny(kind);
    let one = run_casper_with(&cfg, kind, &d, 1, CasperOptions::default()).unwrap();
    let four = run_casper_with(&cfg, kind, &d, 4, CasperOptions::default()).unwrap();
    assert_eq!(four.total_instrs, one.total_instrs * 4);
    let ratio = four.cycles as f64 / one.cycles as f64;
    assert!((3.0..5.5).contains(&ratio), "cycles ratio {ratio}");
}
