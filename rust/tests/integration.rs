//! Cross-layer integration tests: the three layers must agree.
//!
//! - L3 simulator (SPU functional execution) vs the Rust golden reference.
//! - AOT JAX/Pallas artifacts executed through PJRT (L1+L2) vs both.
//! - The Casper programming model driving a real multi-kernel workload.
//!
//! PJRT tests skip gracefully when `make artifacts` hasn't run.

use casper::config::{MappingPolicy, SimConfig, SizeClass, SpuPlacement};
use casper::coordinator::{run_casper, run_casper_with, CasperOptions};
use casper::runtime::{artifacts_available, default_artifacts_dir, StencilRuntime};
use casper::stencil::{golden, Domain, Grid, StencilKind};
use casper::testutil::assert_allclose;
use casper::util::SplitMix64;

fn random_grid(nx: usize, ny: usize, nz: usize, seed: u64) -> Grid {
    Grid::random(nx, ny, nz, seed)
}

#[test]
fn simulator_matches_golden_every_kernel_and_class_l2() {
    // The big functional cross-check at a realistic size (L2 class).
    let cfg = SimConfig::default();
    for kind in StencilKind::ALL {
        let d = Domain::for_level(kind, SizeClass::L2);
        let stats = run_casper(&cfg, kind, &d, 1);
        let want = golden::run_kind(kind, &d, 1, CasperOptions::default().seed);
        let diff = stats.output.max_abs_diff(&want);
        assert!(diff < 1e-12, "{kind}: {diff}");
    }
}

#[test]
fn simulator_matches_golden_under_every_configuration() {
    // Timing knobs must never change the numerics.
    let kind = StencilKind::Blur2D;
    let d = Domain::tiny(kind);
    let want = golden::run_kind(kind, &d, 2, CasperOptions::default().seed);
    for mapping in [MappingPolicy::Baseline, MappingPolicy::StencilSegment] {
        for placement in [SpuPlacement::NearLlc, SpuPlacement::NearL1] {
            for unaligned_hw in [true, false] {
                let mut cfg = SimConfig::default();
                cfg.mapping = mapping;
                cfg.placement = placement;
                let opts = CasperOptions { unaligned_hw, ..Default::default() };
                let stats = run_casper_with(&cfg, kind, &d, 2, opts).unwrap();
                let diff = stats.output.max_abs_diff(&want);
                assert!(
                    diff < 1e-12,
                    "mapping={mapping:?} placement={placement:?} hw={unaligned_hw}: {diff}"
                );
            }
        }
    }
}

#[test]
fn pjrt_artifacts_match_golden() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = StencilRuntime::new(&default_artifacts_dir()).unwrap();
    for kind in StencilKind::ALL {
        let entry = rt
            .smallest_for(kind, 1)
            .unwrap_or_else(|| panic!("no tiny artifact for {kind}"))
            .clone();
        let input = random_grid(entry.nx, entry.ny, entry.nz, 42);
        let out = rt.execute(&entry.name, &input).unwrap();
        let want = golden::run(&kind.descriptor(), &input, 1);
        assert_allclose(&out.data, &want.data, 1e-12, 1e-13);
    }
}

#[test]
fn pjrt_multistep_artifacts_match_golden() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = StencilRuntime::new(&default_artifacts_dir()).unwrap();
    for kind in [StencilKind::Jacobi2D, StencilKind::Heat3D] {
        let entry = rt.smallest_for(kind, 3).expect("s3 artifact").clone();
        let input = random_grid(entry.nx, entry.ny, entry.nz, 77);
        let out = rt.execute(&entry.name, &input).unwrap();
        let want = golden::run(&kind.descriptor(), &input, 3);
        assert_allclose(&out.data, &want.data, 1e-12, 1e-13);
    }
}

#[test]
fn three_layers_agree_end_to_end() {
    // Simulator output == PJRT(JAX/Pallas) output == golden, same input.
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = SimConfig::default();
    let mut rt = StencilRuntime::new(&default_artifacts_dir()).unwrap();
    for kind in StencilKind::ALL {
        let entry = rt.smallest_for(kind, 1).unwrap().clone();
        let d = Domain::new(entry.nx, entry.ny, entry.nz);
        let seed = 0xE2E;
        let sim = run_casper_with(&cfg, kind, &d, 1, CasperOptions { seed, ..Default::default() })
            .unwrap();
        let input = d.alloc_random(seed);
        let pjrt = rt.execute(&entry.name, &input).unwrap();
        assert_allclose(&sim.output.data, &pjrt.data, 1e-12, 1e-13);
    }
}

#[test]
fn pjrt_shape_mismatch_is_an_error() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut rt = StencilRuntime::new(&default_artifacts_dir()).unwrap();
    let entry = rt.smallest_for(StencilKind::Jacobi1D, 1).unwrap().clone();
    let wrong = random_grid(entry.nx + 8, 1, 1, 1);
    assert!(rt.execute(&entry.name, &wrong).is_err());
}

#[test]
fn deterministic_across_runs() {
    let cfg = SimConfig::default();
    let kind = StencilKind::Jacobi2D;
    let d = Domain::tiny(kind);
    let a = run_casper(&cfg, kind, &d, 1);
    let b = run_casper(&cfg, kind, &d, 1);
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.output, b.output);
    assert_eq!(a.total_instrs, b.total_instrs);
}

#[test]
fn property_random_domains_match_golden() {
    // Property: for random (valid) small domains, the simulator equals
    // golden for every kernel.
    let cfg = SimConfig::default();
    let mut rng = SplitMix64::new(0xD0);
    for case in 0..6 {
        for kind in StencilKind::ALL {
            let r = kind.descriptor().radius();
            let d = match kind.dims() {
                1 => Domain::new(64 + rng.range(0, 192), 1, 1),
                2 => Domain::new(
                    2 * r[0] + 4 + rng.range(0, 24),
                    2 * r[1] + 3 + rng.range(0, 12),
                    1,
                ),
                _ => Domain::new(
                    2 * r[0] + 3 + rng.range(0, 8),
                    2 * r[1] + 3 + rng.range(0, 6),
                    2 * r[2] + 3 + rng.range(0, 4),
                ),
            };
            let seed = rng.next_u64();
            let opts = CasperOptions { seed, ..Default::default() };
            let stats = run_casper_with(&cfg, kind, &d, 1, opts).unwrap();
            let want = golden::run_kind(kind, &d, 1, seed);
            let diff = stats.output.max_abs_diff(&want);
            assert!(diff < 1e-12, "case {case} {kind} {d}: {diff}");
        }
    }
}
