//! Smoke tests over the experiment harness and CLI plumbing: the quick
//! (L2-class) sweep must regenerate every table with sane shapes, and the
//! report writers must produce parseable output.

use casper::config::{SimConfig, SizeClass};
use casper::coordinator::{run_casper_with, CasperOptions};
use casper::harness::{run_experiments, Experiment, SweepOptions};
use casper::stencil::{Domain, StencilKind};

fn quick_report() -> casper::harness::Report {
    let cfg = SimConfig::default();
    // Exercise the parallel sweep engine in the smoke path; reports are
    // byte-identical to `jobs: 1` (asserted in `harness::tests`).
    run_experiments(
        &cfg,
        &Experiment::ALL,
        SweepOptions {
            quick: true,
            steps: 1,
            jobs: casper::harness::auto_jobs(),
            spu_threads: 1,
            temporal_block: 1,
        },
    )
    .unwrap()
}

#[test]
fn runstats_digests_identical_across_spu_thread_counts() {
    // The full quick experiment grid (every kernel, L2 class) must hash
    // identically at --spu-threads 1, 4, and 16: the epoch-parallel
    // engine may change wall time only, never a counter or an output bit.
    let cfg = SimConfig::default();
    for kind in StencilKind::ALL {
        let d = Domain::for_level(kind, SizeClass::L2);
        let digests: Vec<u64> = [1usize, 4, 16]
            .into_iter()
            .map(|spu_threads| {
                run_casper_with(
                    &cfg,
                    kind,
                    &d,
                    1,
                    CasperOptions { spu_threads, ..Default::default() },
                )
                .unwrap()
                .digest()
            })
            .collect();
        assert_eq!(digests[0], digests[1], "{kind}: 1 vs 4 threads");
        assert_eq!(digests[0], digests[2], "{kind}: 1 vs 16 threads");
    }
}

#[test]
fn multistep_digests_identical_across_spu_thread_counts() {
    // Multi-step runs cross epoch AND step boundaries (ping-pong swaps,
    // boundary patching) — digests must still match.
    let cfg = SimConfig::default();
    for kind in [StencilKind::Jacobi2D, StencilKind::Heat3D] {
        let d = Domain::tiny(kind);
        let serial = run_casper_with(
            &cfg,
            kind,
            &d,
            4,
            CasperOptions { spu_threads: 1, ..Default::default() },
        )
        .unwrap();
        let parallel = run_casper_with(
            &cfg,
            kind,
            &d,
            4,
            CasperOptions { spu_threads: 16, epoch_rounds: 7, ..Default::default() },
        )
        .unwrap();
        assert_eq!(serial.digest(), parallel.digest(), "{kind}");
    }
}

#[test]
fn every_experiment_regenerates() {
    let report = quick_report();
    assert_eq!(report.tables.len(), Experiment::ALL.len());
    for e in Experiment::ALL {
        let t = report.get(e.id()).unwrap();
        assert!(!t.rows.is_empty(), "{}", e.id());
        assert!(!t.header.is_empty());
    }
}

#[test]
fn fig1_kernels_sit_between_roofs() {
    let report = quick_report();
    let t = report.get("fig1").unwrap();
    // columns: kernel, AI, DRAM roof, L3 roof, measured, %peak
    for row in &t.rows {
        let dram: f64 = row[2].parse().unwrap();
        let llc: f64 = row[3].parse().unwrap();
        let measured: f64 = row[4].parse().unwrap();
        assert!(llc > dram, "{row:?}");
        assert!(measured < llc * 1.5, "measured above the LLC roof: {row:?}");
        assert!(measured > 0.0, "{row:?}");
    }
}

#[test]
fn fig10_contains_paper_reference_column() {
    let report = quick_report();
    let t = report.get("fig10").unwrap();
    assert_eq!(t.rows.len(), 6); // 6 kernels × 1 class in quick mode
    for row in &t.rows {
        assert!(row[5].ends_with('x'), "paper column malformed: {row:?}");
    }
}

#[test]
fn fig14_percentages_sum_to_100() {
    let report = quick_report();
    let t = report.get("fig14").unwrap();
    for row in &t.rows {
        let m: f64 = row[5].trim_end_matches('%').parse().unwrap();
        let n: f64 = row[6].trim_end_matches('%').parse().unwrap();
        assert!((m + n - 100.0).abs() < 0.6 || (m == 0.0 && n == 0.0), "{row:?}");
    }
}

#[test]
fn report_roundtrips_through_files() {
    let report = quick_report();
    let dir = std::env::temp_dir().join("casper_experiments_smoke");
    report.write_to(&dir).unwrap();
    let md = std::fs::read_to_string(dir.join("report.md")).unwrap();
    for e in Experiment::ALL {
        assert!(md.contains(&format!("### {}", e.id())), "{} missing from md", e.id());
        let csv = std::fs::read_to_string(dir.join(format!("{}.csv", e.id()))).unwrap();
        assert!(csv.lines().count() >= 2, "{} csv empty", e.id());
    }
}

#[test]
fn table5_cycles_are_positive() {
    let report = quick_report();
    let t = report.get("table5").unwrap();
    for row in &t.rows {
        let cpu: u64 = row[2].parse().unwrap();
        let gpu: u64 = row[4].parse().unwrap();
        let casper: u64 = row[6].parse().unwrap();
        assert!(cpu > 0 && gpu > 0 && casper > 0, "{row:?}");
    }
}
