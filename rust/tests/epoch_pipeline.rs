//! The pipelined-epoch determinism contract, end to end:
//!
//! - a property sweep over randomly generated valid [`KernelSpec`]s:
//!   pipelined execution (replay on the dedicated worker, overlapped with
//!   the next epoch's fan-out) is byte-identical — full `RunStats`
//!   equality AND `digest()` — to both the phased epoch engine and the
//!   serial round-robin engine, across SPU thread counts and temporal
//!   blocks;
//! - the same identity on a multi-pass kernel (`star17_3d`), where every
//!   pass detaches and restores the timing half around its own pipeline
//!   scope;
//! - the pipeline channel bounds in-flight epochs to
//!   [`PIPELINE_DEPTH`] (one queued + one replaying), via the public
//!   re-exports.

use casper::config::SimConfig;
use casper::coordinator::{
    pipeline_channel, run_casper_spec, CasperOptions, PIPELINE_DEPTH,
};
use casper::stencil::{extended_presets, KernelOrigin, KernelSpec, StencilPoint};
use casper::util::SplitMix64;

/// Generate a random spec that satisfies `KernelSpec::validate` by
/// construction (same scheme as the kernel-registry property tests:
/// bounded radii keep the row count inside the stream buffer, palette
/// coefficients keep the constant buffer small).
fn random_spec(r: &mut SplitMix64, case: usize) -> KernelSpec {
    const PALETTE: [f64; 8] = [0.5, 0.25, 0.125, -0.125, 0.0625, 1.0, -0.5, 0.75];
    let dims = 1 + (r.next_u64() % 3) as usize;
    let rx = 1 + (r.next_u64() % 3) as i64;
    let ry = if dims >= 2 { 1 + (r.next_u64() % 2) as i64 } else { 0 };
    let rz = if dims >= 3 { (r.next_u64() % 2) as i64 } else { 0 };
    let mut points = Vec::new();
    for dz in -rz..=rz {
        for dy in -ry..=ry {
            if r.chance(0.4) && !(dy == 0 && dz == 0) {
                continue;
            }
            let mut any = false;
            for dx in -rx..=rx {
                if points.len() >= 56 {
                    break;
                }
                if r.chance(0.5) {
                    let coef = PALETTE[(r.next_u64() % 8) as usize];
                    points.push(StencilPoint::new(dx, dy, dz, coef));
                    any = true;
                }
            }
            if !any && points.len() < 56 {
                points.push(StencilPoint::new(0, dy, dz, PALETTE[case % 8]));
            }
        }
    }
    if points.is_empty() {
        points.push(StencilPoint::new(0, 0, 0, 0.5));
    }
    KernelSpec::new(
        &format!("pipe_{case}"),
        &format!("Pipeline property kernel {case}"),
        dims,
        points,
        KernelOrigin::File,
    )
}

/// Run one spec under the given engine knobs and return its stats.
fn run(
    cfg: &SimConfig,
    spec: &KernelSpec,
    steps: usize,
    spu_threads: usize,
    temporal_block: usize,
    pipeline: bool,
) -> casper::coordinator::RunStats {
    let d = spec.tiny_domain();
    run_casper_spec(
        cfg,
        spec,
        &d,
        steps,
        CasperOptions { spu_threads, temporal_block, pipeline, ..Default::default() },
    )
    .unwrap_or_else(|e| panic!("{}: {e:#}", spec.id))
}

#[test]
fn property_pipelined_is_byte_identical_across_engines() {
    // The tentpole acceptance property: for every generated kernel,
    // every (spu_threads, temporal_block) combination, pipelined and
    // phased epoch execution produce byte-identical results — and both
    // match the serial round-robin engine.
    let cfg = SimConfig::default();
    let mut rng = SplitMix64::new(0x717E);
    for case in 0..8 {
        let spec = random_spec(&mut rng, case);
        spec.validate().unwrap_or_else(|e| panic!("case {case}: {e:#}"));
        for temporal_block in [1usize, 3] {
            let serial = run(&cfg, &spec, 3, 1, temporal_block, false);
            for spu_threads in [1usize, 16] {
                for pipeline in [false, true] {
                    let got = run(&cfg, &spec, 3, spu_threads, temporal_block, pipeline);
                    let tag = format!(
                        "case {case} ({}) T={temporal_block} threads={spu_threads} \
                         pipeline={pipeline}",
                        spec.id
                    );
                    assert_eq!(serial, got, "{tag}: full RunStats identity");
                    assert_eq!(serial.digest(), got.digest(), "{tag}");
                }
            }
        }
    }
}

#[test]
fn multipass_pipelined_is_byte_identical_across_engines() {
    // star17_3d compiles to a 2-pass plan: each pass runs its own
    // pipeline scope (detach timers/tags → overlap → restore), and the
    // identity must hold across the pass boundary.
    let star = extended_presets()
        .into_iter()
        .find(|s| s.id.as_str() == "star17_3d")
        .expect("star17_3d preset");
    let cfg = SimConfig::default();
    let serial = run(&cfg, &star, 2, 1, 1, false);
    assert_eq!(serial.passes, 2, "star17_3d must plan two passes");
    for spu_threads in [1usize, 16] {
        for pipeline in [false, true] {
            let got = run(&cfg, &star, 2, spu_threads, 1, pipeline);
            let tag = format!("threads={spu_threads} pipeline={pipeline}");
            assert_eq!(serial, got, "{tag}: full RunStats identity");
            assert_eq!(serial.digest(), got.digest(), "{tag}");
        }
    }
}

#[test]
fn pipeline_channel_bounds_in_flight_epochs() {
    // The bounded hand-off contract through the public API: with the
    // replay worker holding one epoch and one queued in the channel, the
    // functional side must block (here: TrySendError) rather than run
    // further ahead — at most PIPELINE_DEPTH epochs are ever in flight.
    assert_eq!(PIPELINE_DEPTH, 2);
    let (tx, rx) = pipeline_channel::<usize>();
    tx.try_send(0).expect("first epoch queues");
    assert!(tx.try_send(1).is_err(), "channel must hold only DEPTH-1 epochs");
    let worker_holds = rx.recv().unwrap(); // replay worker dequeues epoch 0
    assert_eq!(worker_holds, 0);
    tx.try_send(1).expect("slot frees once the worker takes epoch 0");
    assert!(tx.try_send(2).is_err(), "epoch 2 must wait: 1 queued + 1 replaying");
}
