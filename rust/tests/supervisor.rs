//! The supervised sweep runtime, end to end: clean-path byte identity,
//! fault containment under `--keep-going`, deadline timeouts, retry
//! recovery, and checkpoint-resume (including a torn final record).
//!
//! All sweeps here are `--quick` (L2 class) over the paper six — the
//! same cells the harness smoke tests run.

use std::path::PathBuf;
use std::time::Duration;

use casper::config::SimConfig;
use casper::harness::{
    journal_context, paper_kernels, run_experiments, run_experiments_supervised, Experiment,
    FaultKind, FaultPlan, Journal, Report, SupervisorConfig, SupervisorPolicy, SweepCache,
    SweepOptions,
};
use casper::trace::chrome::validate_json;
use casper::trace::EventSink;

fn quick_opts(jobs: usize) -> SweepOptions {
    SweepOptions { quick: true, steps: 1, jobs, spu_threads: 1, temporal_block: 1 }
}

/// Supervisor policy tuned for tests: no retry sleeps.
fn test_policy() -> SupervisorPolicy {
    SupervisorPolicy { backoff_base_ms: 0, ..SupervisorPolicy::default() }
}

fn plant(kind: FaultKind, cells: Vec<usize>) -> FaultPlan {
    FaultPlan { seed: 0, rate: 0.0, kind, cells: Some(cells), delay_ms: 50 }
}

fn clean_report(which: &[Experiment], jobs: usize) -> Report {
    run_experiments(&SimConfig::default(), which, quick_opts(jobs)).unwrap()
}

fn supervised(which: &[Experiment], jobs: usize, sup: &SupervisorConfig) -> anyhow::Result<Report> {
    let kernels = paper_kernels();
    run_experiments_supervised(&SimConfig::default(), which, quick_opts(jobs), &kernels, sup)
}

fn temp_journal(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("casper_sup_{}_{name}.journal", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Fig 10 quick = 6 kernels × 1 class × (casper + cpu) = 12 cells.
const FIG10_CELLS: usize = 12;

#[test]
fn clean_supervised_sweep_is_byte_identical_at_any_job_count() {
    let which = [Experiment::Fig10, Experiment::Fig14];
    let baseline = clean_report(&which, 1);
    for jobs in [1usize, 2, 16] {
        let sup = SupervisorConfig {
            policy: SupervisorPolicy { keep_going: true, ..test_policy() },
            journal: None,
        };
        let report = supervised(&which, jobs, &sup).unwrap();
        assert!(report.failures.is_empty());
        assert_eq!(
            report.to_markdown(),
            baseline.to_markdown(),
            "supervised jobs={jobs} must be byte-identical to the legacy serial sweep"
        );
    }
}

#[test]
fn injected_panic_at_any_cell_never_loses_survivors() {
    // The acceptance property: a panic planted at every cell position in
    // turn; each run keeps every other cell bitwise equal to the clean
    // run, renders the faulty cell as a hole, and reports the failure.
    let which = [Experiment::Fig10];
    let clean = clean_report(&which, 1);
    let clean_rows = &clean.get("fig10").unwrap().rows;
    for i in 0..FIG10_CELLS {
        let sup = SupervisorConfig {
            policy: SupervisorPolicy {
                keep_going: true,
                max_retries: 0,
                faults: Some(plant(FaultKind::Panic, vec![i])),
                ..test_policy()
            },
            journal: None,
        };
        let report = supervised(&which, 2, &sup).unwrap();
        assert_eq!(report.failures.len(), 1, "cell {i}: {:?}", report.failures);
        assert!(report.failures[0].outcome.contains("panicked"), "{:?}", report.failures);
        let rows = &report.get("fig10").unwrap().rows;
        assert_eq!(rows.len(), clean_rows.len(), "cell {i}: no row may vanish");
        let mut holes = 0;
        for (r, c) in rows.iter().zip(clean_rows) {
            if r.iter().any(|cell| cell.starts_with("FAILED:")) {
                holes += 1;
                // Hole rows keep the identifying prefix of the clean row.
                assert_eq!(r[0], c[0], "cell {i}");
                assert_eq!(r[1], c[1], "cell {i}");
            } else {
                assert_eq!(r, c, "cell {i}: survivor row diverged");
            }
        }
        assert_eq!(holes, 1, "cell {i}: exactly one hole");
    }
}

#[test]
fn transient_errors_recover_to_a_byte_identical_report() {
    // Error-kind faults fire only on attempt 0; with retries the sweep
    // self-heals and the report shows no trace — over many seeded plans.
    let which = [Experiment::Fig10];
    let clean = clean_report(&which, 1);
    for seed in 0..8u64 {
        let plan = FaultPlan { seed, rate: 0.35, kind: FaultKind::Error, cells: None, delay_ms: 0 };
        let sup = SupervisorConfig {
            policy: SupervisorPolicy { keep_going: true, faults: Some(plan), ..test_policy() },
            journal: None,
        };
        let report = supervised(&which, 2, &sup).unwrap();
        assert!(report.failures.is_empty(), "seed {seed}: {:?}", report.failures);
        assert_eq!(report.to_markdown(), clean.to_markdown(), "seed {seed}");
    }
}

#[test]
fn delay_past_deadline_becomes_a_timeout_hole() {
    let which = [Experiment::Fig10];
    let sup = SupervisorConfig {
        policy: SupervisorPolicy {
            keep_going: true,
            cell_timeout: Some(Duration::from_millis(500)),
            faults: Some(FaultPlan {
                seed: 0,
                rate: 0.0,
                kind: FaultKind::Delay,
                cells: Some(vec![3]),
                delay_ms: 30_000,
            }),
            ..test_policy()
        },
        journal: None,
    };
    let report = supervised(&which, 2, &sup).unwrap();
    assert_eq!(report.failures.len(), 1, "{:?}", report.failures);
    assert!(report.failures[0].outcome.contains("timed out"), "{:?}", report.failures);
    let md = report.to_markdown();
    assert!(md.contains("FAILED:"), "the timed-out cell must render as a hole");
}

#[test]
fn checkpoint_resume_reruns_only_the_missing_cells() {
    let cfg = SimConfig::default();
    let which = [Experiment::Fig10];
    let kernels = paper_kernels();
    let path = temp_journal("resume");
    let sup = SupervisorConfig { policy: test_policy(), journal: Some(path.clone()) };

    // Full sweep at jobs=16, journaling every completion.
    let mut cache = SweepCache::with_supervisor(&cfg, quick_opts(16), &kernels, &sup).unwrap();
    cache.prefill_checked(&which).unwrap();
    assert_eq!(cache.executed_cells(), FIG10_CELLS);
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1 + FIG10_CELLS, "header + one record per cell");

    // Interrupt: keep the header + 5 complete records, then a torn final
    // record (half a line, no trailing newline) — as a kill mid-write
    // would leave it.
    let keep = 5usize;
    let mut truncated: String = lines[..=keep].iter().map(|l| format!("{l}\n")).collect();
    let torn = &lines[keep + 1][..lines[keep + 1].len() / 2];
    truncated.push_str(torn);
    std::fs::write(&path, &truncated).unwrap();

    // Resume at jobs=1 (the journal context excludes the job count):
    // exactly the missing cells re-run, the torn record among them.
    let mut cache = SweepCache::with_supervisor(&cfg, quick_opts(1), &kernels, &sup).unwrap();
    cache.prefill_checked(&which).unwrap();
    assert_eq!(cache.executed_cells(), FIG10_CELLS - keep);

    // The journal is complete again; a fresh resume runs zero cells and
    // the report is byte-identical to an uninterrupted sweep.
    let resumed = supervised(&which, 2, &sup).unwrap();
    let mut cache = SweepCache::with_supervisor(&cfg, quick_opts(2), &kernels, &sup).unwrap();
    cache.prefill_checked(&which).unwrap();
    assert_eq!(cache.executed_cells(), 0, "every cell must come from the journal");
    assert_eq!(resumed.to_markdown(), clean_report(&which, 1).to_markdown());
    let _ = std::fs::remove_file(&path);
}

/// The string value of `key` in a single-line JSON event, without a
/// JSON parser: events put every field on one line with unescaped keys.
fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\":\"");
    let start = line.find(&tag)? + tag.len();
    let rest = &line[start..];
    rest.find('"').map(|end| &rest[..end])
}

fn count(text: &str, kind: &str) -> usize {
    let tag = format!("\"event\":\"{kind}\"");
    text.lines().filter(|l| l.contains(&tag)).count()
}

/// The `(engine, kernel, class)` identity set of every `kind` event.
fn cells_of(text: &str, kind: &str) -> std::collections::BTreeSet<String> {
    let tag = format!("\"event\":\"{kind}\"");
    text.lines()
        .filter(|l| l.contains(&tag))
        .map(|l| {
            ["engine", "kernel", "class"]
                .iter()
                .map(|k| field(l, k).expect("cell events carry engine/kernel/class"))
                .collect::<Vec<_>>()
                .join("/")
        })
        .collect()
}

#[test]
fn events_log_replays_the_journal_cell_set_on_resume() {
    // Satellite acceptance: the JSONL event log is well-formed, and after
    // a `--resume` the replayed (journal-loaded) cell set seen in the new
    // event log is exactly the cell set the first sweep executed.
    let cfg = SimConfig::default();
    let which = [Experiment::Fig10];
    let kernels = paper_kernels();
    let journal = temp_journal("events");
    let pid = std::process::id();
    let ev1 = std::env::temp_dir().join(format!("casper_sup_ev1_{pid}.jsonl"));
    let ev2 = std::env::temp_dir().join(format!("casper_sup_ev2_{pid}.jsonl"));

    let sup_with = |events: &PathBuf| SupervisorConfig {
        policy: SupervisorPolicy {
            events: Some(EventSink::create(events).unwrap()),
            ..test_policy()
        },
        journal: Some(journal.clone()),
    };
    let opts = quick_opts(2);
    let sup1 = sup_with(&ev1);
    let first = run_experiments_supervised(&cfg, &which, opts, &kernels, &sup1).unwrap();
    let sup2 = sup_with(&ev2);
    let resumed = run_experiments_supervised(&cfg, &which, opts, &kernels, &sup2).unwrap();
    assert_eq!(first.to_markdown(), resumed.to_markdown());

    let t1 = std::fs::read_to_string(&ev1).unwrap();
    let t2 = std::fs::read_to_string(&ev2).unwrap();
    for line in t1.lines().chain(t2.lines()) {
        validate_json(line).unwrap_or_else(|e| panic!("bad event line: {e}\n{line}"));
    }
    // Run 1 scheduled and executed every cell; the resumed run loaded all
    // of them from the journal, so its log is pure `cached` identities.
    assert_eq!(count(&t1, "scheduled"), FIG10_CELLS, "{t1}");
    assert_eq!(count(&t1, "result"), FIG10_CELLS, "{t1}");
    assert_eq!(count(&t2, "cached"), FIG10_CELLS, "{t2}");
    assert_eq!(count(&t2, "started"), 0, "{t2}");
    assert_eq!(cells_of(&t1, "result"), cells_of(&t2, "cached"));

    for p in [&journal, &ev1, &ev2] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn journal_context_mismatch_is_refused() {
    let cfg = SimConfig::default();
    let kernels = paper_kernels();
    let path = temp_journal("ctx");
    let ctx = journal_context(&cfg, quick_opts(1), &kernels);
    let (_j, records) = Journal::open(&path, ctx).unwrap();
    assert!(records.is_empty());
    // Same path, different sweep parameters (steps) → different context.
    let sup = SupervisorConfig { policy: test_policy(), journal: Some(path.clone()) };
    let opts = SweepOptions { steps: 2, ..quick_opts(1) };
    let err = run_experiments_supervised(&cfg, &[Experiment::Fig10], opts, &kernels, &sup)
        .unwrap_err();
    assert!(format!("{err:#}").contains("journal context mismatch"), "{err:#}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fail_fast_aborts_but_preserves_completed_cells() {
    let which = [Experiment::Fig10];
    let path = temp_journal("failfast");
    let sup = SupervisorConfig {
        policy: SupervisorPolicy {
            max_retries: 0,
            faults: Some(plant(FaultKind::Panic, vec![4])),
            ..test_policy()
        },
        journal: Some(path.clone()),
    };
    let err = supervised(&which, 1, &sup).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("fail-fast"), "{msg}");
    assert!(msg.contains("--keep-going"), "{msg}");
    // Cells completed before the fault are in the journal; a clean resume
    // reuses them and lands on the uninterrupted report.
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.lines().count() > 1, "completed cells must be journaled:\n{text}");
    let clean_sup = SupervisorConfig { policy: test_policy(), journal: Some(path.clone()) };
    let resumed = supervised(&which, 1, &clean_sup).unwrap();
    assert_eq!(resumed.to_markdown(), clean_report(&which, 1).to_markdown());
    let _ = std::fs::remove_file(&path);
}
