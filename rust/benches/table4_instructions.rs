//! Regenerates the paper's Table4 (see DESIGN.md §4) and reports the
//! wall-time of the underlying simulation sweep.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment(casper::harness::Experiment::Table4, 2);
}
