//! Microbenchmarks of the simulator's hot paths (the §Perf targets):
//! cache tag access, slice-mapper hashing, SPU group execution, golden
//! stencil step, and CPU trace iteration. These are what the performance
//! pass profiles and optimizes — see EXPERIMENTS.md §Perf and
//! `rust/PERF.md` for the optimization inventory.
//!
//! Wall-time records are persisted to `BENCH_micro.json` (override the
//! path with `CASPER_BENCH_JSON`) so the perf trajectory is tracked
//! across PRs.

#[path = "bench_common.rs"]
mod bench_common;

use bench_common::{
    bench_json_path, measure_stat, print_baseline_delta, write_bench_json, BenchStat,
};
use casper::config::{MappingPolicy, SimConfig, SizeClass};
use casper::coordinator::{run_casper, run_casper_spec, run_casper_with, CasperOptions};
use casper::cpu::run_cpu;
use casper::isa::ProgramBuilder;
use casper::mapping::{SliceMapper, StencilSegment};
use casper::mem::cache::Cache;
use casper::spu::{ShardedMem, Spu};
use casper::stencil::{golden, Domain, StencilKind};

fn main() {
    let cfg = SimConfig::default();
    let mut records: Vec<BenchStat> = Vec::new();
    // CASPER_BENCH_QUICK=1 bounds CI time: fewer samples per case (the
    // workloads themselves stay identical so records remain comparable).
    let quick = std::env::var_os("CASPER_BENCH_QUICK").is_some();
    let n5 = if quick { 2 } else { 5 };
    let n3 = if quick { 1 } else { 3 };

    // --- cache tag path: 1M accesses over a 2 MB slice. ---
    let (hits, st) = measure_stat("cache_access_1M", n5, || {
        let mut c = Cache::new(2 * 1024 * 1024, 16, 64);
        let mut h = 0u64;
        for i in 0..1_000_000u64 {
            // Streaming + 25% reuse mix.
            let addr = (i % 4 != 0) as u64 * (i * 64) + (i % 4 == 0) as u64 * ((i / 8) * 64);
            h += c.access(addr % (8 << 20), false).hit as u64;
        }
        h
    });
    records.push(st);
    assert!(hits > 0);

    // --- slice mapper: 4M hashes. ---
    let mut mapper = SliceMapper::new(&cfg.llc, MappingPolicy::StencilSegment);
    mapper.set_segment(StencilSegment::new(0x1000_0000, 64 << 20));
    let (acc, st) = measure_stat("slice_hash_4M", n5, || {
        let mut acc = 0usize;
        for i in 0..4_000_000u64 {
            acc += mapper.slice_of(std::hint::black_box(0x1000_0000 + i * 64));
        }
        std::hint::black_box(acc)
    });
    records.push(st);
    assert!(acc > 0);

    // --- SPU inner loop: 64k points of Jacobi-1D on one SPU. ---
    let program = ProgramBuilder::new()
        .build(&StencilKind::Jacobi1D.descriptor())
        .unwrap();
    let (_, st) = measure_stat("spu_64k_points", n5, || {
        let mut mem = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        let seg = mem.store.alloc_segment(2 << 20);
        mem.mapper.set_segment(StencilSegment::new(seg, 2 << 20));
        let mut spu = Spu::new(0, 0, &cfg, program.clone());
        spu.init_streams(&[seg + (1 << 20), seg + 8]);
        spu.set_n_elements(65_536);
        while spu.run_group(&mut mem) {}
        spu.finish_time()
    });
    records.push(st);

    // --- golden stencil step: Blur2D over 1024² (parallel vs pinned
    // scalar oracle — the two are asserted bitwise-identical in tests).
    let d = Domain::for_level(StencilKind::Blur2D, SizeClass::Llc);
    let g = d.alloc_random(1);
    let (_, st) = measure_stat("golden_blur2d_llc", n3, || {
        golden::run(&StencilKind::Blur2D.descriptor(), &g, 1)
    });
    records.push(st);
    let (_, st) = measure_stat("golden_blur2d_llc_serial", n3, || {
        let mut out = d.alloc();
        golden::step_serial(&StencilKind::Blur2D.descriptor(), &g, &mut out);
        out
    });
    records.push(st);

    // --- full engines, L2-class Jacobi2D (end-to-end micro). ---
    let d2 = Domain::for_level(StencilKind::Jacobi2D, SizeClass::L2);
    let (_, st) = measure_stat("engine_casper_jacobi2d_l2", n3, || {
        run_casper(&cfg, StencilKind::Jacobi2D, &d2, 1).cycles
    });
    records.push(st);
    let (_, st) = measure_stat("engine_cpu_jacobi2d_l2", n3, || {
        run_cpu(&cfg, StencilKind::Jacobi2D, &d2, 1).cycles
    });
    records.push(st);

    // --- intra-run SPU parallelism: one DRAM-class cell, serial engine
    // vs the epoch-parallel engine on all hardware threads. The ISSUE-3
    // acceptance target is mt ≥ 1.5x faster than t1 on the reference
    // machine; the digests are asserted identical here as a free check.
    let dd = Domain::for_level(StencilKind::Jacobi1D, SizeClass::Dram);
    let mt = casper::util::auto_threads();
    let (serial_stats, st) = measure_stat("engine_casper_jacobi1d_dram_t1", n3, || {
        run_casper_with(
            &cfg,
            StencilKind::Jacobi1D,
            &dd,
            1,
            CasperOptions { spu_threads: 1, ..Default::default() },
        )
        .expect("serial dram cell")
    });
    records.push(st);
    let (mt_stats, st) = measure_stat("engine_casper_jacobi1d_dram_mt", n3, || {
        run_casper_with(
            &cfg,
            StencilKind::Jacobi1D,
            &dd,
            1,
            CasperOptions { spu_threads: mt.max(2), ..Default::default() },
        )
        .expect("parallel dram cell")
    });
    records.push(st);
    assert_eq!(
        serial_stats.digest(),
        mt_stats.digest(),
        "serial and epoch-parallel DRAM cells must be byte-identical"
    );

    // --- pipelined epochs: the same DRAM-class cell at the same thread
    // count, replay inline (phased) vs overlapped with the next epoch's
    // fan-out on the dedicated replay worker. The pipeline buys wall
    // time only — all three digests must coincide.
    let (phased_stats, st) = measure_stat("engine_casper_jacobi1d_dram_mt_phased", n3, || {
        run_casper_with(
            &cfg,
            StencilKind::Jacobi1D,
            &dd,
            1,
            CasperOptions { spu_threads: mt.max(2), pipeline: false, ..Default::default() },
        )
        .expect("phased dram cell")
    });
    records.push(st);
    let (piped_stats, st) = measure_stat("engine_casper_jacobi1d_dram_mt_pipelined", n3, || {
        run_casper_with(
            &cfg,
            StencilKind::Jacobi1D,
            &dd,
            1,
            CasperOptions { spu_threads: mt.max(2), pipeline: true, ..Default::default() },
        )
        .expect("pipelined dram cell")
    });
    records.push(st);
    assert_eq!(
        phased_stats.digest(),
        piped_stats.digest(),
        "phased and pipelined epoch engines must be byte-identical"
    );
    assert_eq!(
        serial_stats.digest(),
        piped_stats.digest(),
        "pipelined engine must match the serial reference digest"
    );

    // --- temporal blocking: 4-step L2-class Jacobi2D, per-step chaining
    // vs a T=4 block. Same grid bitwise (asserted via the T-invariant
    // grid digest); the blocked run serves inner-step tags from wavefront
    // residency instead of LLC probes.
    let (t1_stats, st) = measure_stat("engine_jacobi2d_l2_4steps_t1", n3, || {
        run_casper_with(
            &cfg,
            StencilKind::Jacobi2D,
            &d2,
            4,
            CasperOptions { spu_threads: 1, ..Default::default() },
        )
        .expect("per-step chained run")
    });
    records.push(st);
    let (tb_stats, st) = measure_stat("engine_jacobi2d_l2_4steps_tb4", n3, || {
        run_casper_with(
            &cfg,
            StencilKind::Jacobi2D,
            &d2,
            4,
            CasperOptions { spu_threads: 1, temporal_block: 4, ..Default::default() },
        )
        .expect("temporally blocked run")
    });
    records.push(st);
    assert_eq!(
        t1_stats.grid_digest(),
        tb_stats.grid_digest(),
        "temporal blocking must not move the functional result"
    );
    assert!(tb_stats.avoided_fills() > 0, "T=4 must avoid LLC line fills");

    // --- fused stencil+reduce (one pass per step) vs the golden two-pass
    // reference (stencil sweep, then a second traversal for the reduce).
    let res_spec = casper::stencil::extended_presets()
        .into_iter()
        .find(|s| s.id.as_str() == "jacobi2d_res")
        .expect("jacobi2d_res preset");
    let dr = res_spec.domain(SizeClass::L2);
    let seed = CasperOptions::default().seed;
    let (fused_stats, st) = measure_stat("engine_fused_reduce_jacobi2d", n3, || {
        run_casper_spec(
            &cfg,
            &res_spec,
            &dr,
            4,
            CasperOptions { spu_threads: 1, ..Default::default() },
        )
        .expect("fused reduction run")
    });
    records.push(st);
    let input = dr.alloc_random(seed);
    let (golden_vals, st) = measure_stat("golden_two_pass_reduce_jacobi2d", n3, || {
        golden::run_reduced(&res_spec, &input, 4).1
    });
    records.push(st);
    let fused = fused_stats.reduction.as_ref().expect("reduction result");
    assert_eq!(fused_stats.passes, 1, "fused reduce must not add a pass");
    assert_eq!(
        fused.values, golden_vals,
        "fused reduction must match the two-pass golden reference bitwise"
    );

    let path = bench_json_path("BENCH_micro.json");
    write_bench_json(&path, "micro_hotpath", &records).expect("writing bench json");
    println!("wrote {} records to {}", records.len(), path.display());
    print_baseline_delta(&records);
}
