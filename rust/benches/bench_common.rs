//! Shared mini bench harness (no criterion in the offline registry —
//! DESIGN.md §3): warmup + N samples, median ± MAD wall-time reporting,
//! plus the regenerated paper table for the experiment being benched.
//!
//! Benchmarks can also persist their wall-time records as a small JSON
//! file (`BENCH_micro.json` for the micro suite — see `rust/PERF.md` for
//! the schema) so the perf trajectory is tracked across PRs.

#![allow(dead_code)] // each bench binary uses a subset of this module

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use casper::config::SimConfig;
use casper::harness::{run_experiments, Experiment, SweepOptions};
use casper::util::{median, median_abs_dev};

/// One measured benchmark: the record that lands in the JSON log.
#[derive(Debug, Clone)]
pub struct BenchStat {
    pub name: String,
    pub median_ms: f64,
    pub mad_ms: f64,
    pub samples: usize,
}

/// Time `f` with one warmup and `samples` measured runs, returning the
/// last result together with the wall-time record.
pub fn measure_stat<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> (T, BenchStat) {
    let mut out = f(); // warmup (also warms allocator/caches)
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        out = f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let stat = BenchStat {
        name: name.to_string(),
        median_ms: median(&times),
        mad_ms: median_abs_dev(&times),
        samples,
    };
    println!(
        "bench {name:<28} median {:>9.2} ms  mad {:>7.2} ms  (n={samples})",
        stat.median_ms, stat.mad_ms
    );
    (out, stat)
}

/// Time `f` with one warmup and `samples` measured runs.
pub fn measure<T>(name: &str, samples: usize, f: impl FnMut() -> T) -> T {
    measure_stat(name, samples, f).0
}

/// Where a bench suite's JSON record goes: `$CASPER_BENCH_JSON` if set,
/// else `file_name` in the working directory.
pub fn bench_json_path(file_name: &str) -> PathBuf {
    std::env::var_os("CASPER_BENCH_JSON")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(file_name))
}

/// Write the records as JSON (hand-rolled: no serde offline). Schema:
/// `{"suite": <str>, "unit": "ms", "records": [{"name", "median_ms",
/// "mad_ms", "samples"}, ...]}`.
pub fn write_bench_json(path: &Path, suite: &str, stats: &[BenchStat]) -> std::io::Result<()> {
    let mut out = String::new();
    out.push_str(&format!("{{\n  \"suite\": \"{suite}\",\n  \"unit\": \"ms\",\n  \"records\": [\n"));
    for (i, s) in stats.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"name\": \"{}\", \"median_ms\": {:.4}, \"mad_ms\": {:.4}, \"samples\": {}}}{}\n",
            s.name,
            s.median_ms,
            s.mad_ms,
            s.samples,
            if i + 1 == stats.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write(path, out)
}

/// Where the committed reference record lives: `$CASPER_BENCH_BASELINE`
/// if set, else `benches/baseline/BENCH_micro.json` in the crate (the
/// copy refreshed from the CI reference machine — see `rust/PERF.md`).
pub fn baseline_path() -> PathBuf {
    std::env::var_os("CASPER_BENCH_BASELINE")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            Path::new(env!("CARGO_MANIFEST_DIR")).join("benches/baseline/BENCH_micro.json")
        })
}

/// Parse a bench-JSON file into `name → median_ms` (hand-rolled scan of
/// the schema `write_bench_json` emits; no serde offline).
pub fn parse_bench_json(text: &str) -> HashMap<String, f64> {
    let mut out = HashMap::new();
    for seg in text.split("{\"name\": \"").skip(1) {
        let Some(name_end) = seg.find('"') else { continue };
        let name = &seg[..name_end];
        let Some(idx) = seg.find("\"median_ms\": ") else { continue };
        let rest = &seg[idx + "\"median_ms\": ".len()..];
        let end = rest.find(|c| c == ',' || c == '}').unwrap_or(rest.len());
        if let Ok(v) = rest[..end].trim().parse::<f64>() {
            out.insert(name.to_string(), v);
        }
    }
    out
}

/// Print each record's wall-time delta against the committed baseline
/// (positive = slower than the baseline). Records without a committed
/// reference — including everything while the baseline file is still the
/// empty placeholder — print `(no baseline)`.
pub fn print_baseline_delta(records: &[BenchStat]) {
    let path = baseline_path();
    let base = match std::fs::read_to_string(&path) {
        Ok(text) => parse_bench_json(&text),
        Err(_) => {
            println!("no committed bench baseline at {}", path.display());
            return;
        }
    };
    if base.is_empty() {
        // The seed repo ships an empty placeholder; only the CI reference
        // machine may fill it (see benches/baseline/README.md).
        println!(
            "WARNING: committed bench baseline at {} is the empty placeholder — deltas below are \
             meaningless until the refresh-bench-baseline workflow runs on the CI reference machine",
            path.display()
        );
    }
    println!("delta vs committed baseline ({}):", path.display());
    for r in records {
        match base.get(&r.name) {
            Some(&b) if b > 0.0 => {
                let pct = (r.median_ms - b) / b * 100.0;
                println!(
                    "  {:<28} {:>9.2} ms vs {:>9.2} ms  ({:+.1}%)",
                    r.name, r.median_ms, b, pct
                );
            }
            _ => println!("  {:<28} {:>9.2} ms  (no baseline)", r.name, r.median_ms),
        }
    }
}

/// Standard driver for a one-experiment bench binary: run the experiment
/// sweep (timed), then print the regenerated table. `quick` honours
/// `CASPER_BENCH_QUICK=1` so CI can keep bench time bounded, and
/// `CASPER_BENCH_JOBS=N` opts into the parallel sweep engine (default
/// serial, so per-cell timings stay comparable across PRs).
pub fn bench_experiment(e: Experiment, samples: usize) {
    let cfg = SimConfig::default();
    let quick = std::env::var_os("CASPER_BENCH_QUICK").is_some();
    let jobs = std::env::var("CASPER_BENCH_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let opts = SweepOptions { quick, steps: 1, jobs, spu_threads: 1, temporal_block: 1 };
    let report = measure(e.id(), samples, || {
        run_experiments(&cfg, &[e], opts).expect("experiment failed")
    });
    print!("{}", report.to_markdown());
}
