//! Shared mini bench harness (no criterion in the offline registry —
//! DESIGN.md §3): warmup + N samples, median ± MAD wall-time reporting,
//! plus the regenerated paper table for the experiment being benched.

use std::time::Instant;

use casper::config::SimConfig;
use casper::harness::{run_experiments, Experiment, SweepOptions};
use casper::util::{median, median_abs_dev};

/// Time `f` with one warmup and `samples` measured runs.
pub fn measure<T>(name: &str, samples: usize, mut f: impl FnMut() -> T) -> T {
    let mut out = f(); // warmup (also warms allocator/caches)
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        out = f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    println!(
        "bench {name:<28} median {:>9.2} ms  mad {:>7.2} ms  (n={samples})",
        median(&times),
        median_abs_dev(&times)
    );
    out
}

/// Standard driver for a one-experiment bench binary: run the experiment
/// sweep (timed), then print the regenerated table. `quick` honours
/// `CASPER_BENCH_QUICK=1` so CI can keep bench time bounded.
pub fn bench_experiment(e: Experiment, samples: usize) {
    let cfg = SimConfig::default();
    let quick = std::env::var_os("CASPER_BENCH_QUICK").is_some();
    let opts = SweepOptions { quick, steps: 1 };
    let report = measure(e.id(), samples, || {
        run_experiments(&cfg, &[e], opts).expect("experiment failed")
    });
    print!("{}", report.to_markdown());
}
