//! Regenerates the paper's Fig13 (see DESIGN.md §4) and reports the
//! wall-time of the underlying simulation sweep.

#[path = "bench_common.rs"]
mod bench_common;

fn main() {
    bench_common::bench_experiment(casper::harness::Experiment::Fig13, 2);
}
