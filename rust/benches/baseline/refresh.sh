#!/usr/bin/env bash
# Install the BENCH_micro artifact from the latest green `main` CI run as
# the committed baseline. This is the pull-based half of the refresh flow;
# the push-based half is the `refresh-bench-baseline` workflow
# (.github/workflows/bench-baseline.yml), which runs the bench on the CI
# reference machine and commits the result directly.
#
# Requires the GitHub CLI (`gh`) authenticated against this repository.
set -euo pipefail
cd "$(dirname "$0")"

run_id=$(gh run list --workflow ci --branch main --status success --limit 1 \
  --json databaseId --jq '.[0].databaseId')
if [ -z "${run_id:-}" ] || [ "$run_id" = "null" ]; then
  echo "error: no green main CI run found" >&2
  exit 1
fi

tmp=$(mktemp -d)
trap 'rm -rf "$tmp"' EXIT
gh run download "$run_id" --name BENCH_micro --dir "$tmp"
mv "$tmp/BENCH_micro.json" BENCH_micro.json
echo "WARNING: the CI test-job artifact is produced under CASPER_BENCH_QUICK=1"
echo "(1-2 samples per record) — fine for trend-watching, noisy as a blocking"
echo "baseline. Prefer the refresh-bench-baseline workflow (full samples) for"
echo "the committed record."
echo "installed BENCH_micro.json from CI run $run_id — review the diff and commit:"
echo "  git add rust/benches/baseline/BENCH_micro.json"
echo "  git commit -m 'Refresh BENCH_micro baseline from CI run $run_id'"
