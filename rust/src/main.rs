//! `casper` — the leader binary: CLI entrypoint over the library.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use casper::area::CasperArea;
use casper::cli::{self, Command, KernelsAction, USAGE};
use casper::config::{SimConfig, SizeClass};
use casper::coordinator::run_casper_spec_traced;
use casper::cpu::run_cpu_spec;
use casper::energy::{casper_energy, cpu_energy};
use casper::gpu::GpuModel;
use casper::harness::{
    run_experiments_telemetry, FaultPlan, SupervisorConfig, SupervisorPolicy, SweepOptions,
};
use casper::coordinator::default_plan_strategy;
use casper::isa::{PlanStrategy, ProgramBuilder};
use casper::pims::PimsModel;
use casper::roofline;
use casper::runtime::{default_artifacts_dir, StencilRuntime};
use casper::stencil::{golden, KernelOrigin, KernelSpec};
use casper::trace::{EventSink, Tracer};
use casper::util::human_time_cycles;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<()> {
    let cmd = cli::parse(argv)?;
    dispatch(cmd)
}

fn dispatch(cmd: Command) -> Result<()> {
    match cmd {
        Command::Help => {
            print!("{USAGE}");
            Ok(())
        }
        Command::Info => {
            let cfg = SimConfig::default();
            println!("{cfg:#?}");
            let area = CasperArea::of(&cfg);
            println!(
                "\ncasper area: {:.3} mm² ({:.2}% of a ThunderX2)",
                area.total_mm2(),
                100.0 * area.host_overhead()
            );
            Ok(())
        }
        Command::Roofline => {
            let cfg = SimConfig::default();
            let m = roofline::Machine::of(&cfg);
            println!(
                "peak {:.1} GFLOPS | DRAM {:.1} GB/s (knee @ {:.2} FLOP/B) | LLC {:.1} GB/s (knee @ {:.2} FLOP/B)\n",
                m.peak_flops / 1e9,
                m.dram_bw / 1e9,
                m.dram_knee(),
                m.llc_bw / 1e9,
                m.llc_knee()
            );
            println!("{:<14} {:>10} {:>16} {:>16}", "kernel", "AI", "DRAM roof GF/s", "L3 roof GF/s");
            for p in roofline::roofline(&cfg, None) {
                println!(
                    "{:<14} {:>10.3} {:>16.1} {:>16.1}",
                    p.name,
                    p.ai,
                    p.dram_bound / 1e9,
                    p.llc_bound / 1e9
                );
            }
            Ok(())
        }
        Command::Kernels { action, kernel_files } => {
            let reg = cli::build_registry(&kernel_files)?;
            match action {
                KernelsAction::List => {
                    println!(
                        "{:<12} {:<22} {:>4} {:>5} {:>8} {:>8} {:>6}  {}",
                        "id", "name", "dims", "taps", "radius", "streams", "passes", "origin"
                    );
                    for s in reg.specs() {
                        let r = s.radius();
                        // Registered specs always plan (validate checked).
                        // The passes column reflects the engine default
                        // strategy (CASPER_PLAN, else optimized).
                        let passes = s
                            .pass_plan_with(default_plan_strategy())
                            .map(|p| p.num_passes())
                            .unwrap_or(0);
                        println!(
                            "{:<12} {:<22} {:>4} {:>5} {:>8} {:>8} {:>6}  {}",
                            s.id,
                            s.name,
                            s.dims,
                            s.num_points(),
                            format!("{},{},{}", r[0], r[1], r[2]),
                            s.row_groups().len() + 1,
                            passes,
                            s.origin.name()
                        );
                    }
                    Ok(())
                }
                KernelsAction::Show(id) => {
                    let s = reg.resolve(&id).with_context(|| {
                        format!("unknown kernel '{id}' (see `casper kernels list`)")
                    })?;
                    show_kernel(&s)
                }
            }
        }
        Command::Run {
            kernel,
            level,
            steps,
            spu_threads,
            config,
            kernel_files,
            trace,
            trace_interval,
            temporal_block,
            epoch_rounds,
            plan,
        } => {
            let cfg = cli::load_config(config.as_ref())?;
            let reg = cli::build_registry(&kernel_files)?;
            let spec = reg.resolve(&kernel).with_context(|| {
                format!("unknown kernel '{kernel}' (see `casper kernels list`)")
            })?;
            // Default: one worker per SPU (the epoch-parallel engine).
            let spu_threads = spu_threads.unwrap_or(cfg.spu.count);
            let epoch_rounds =
                epoch_rounds.unwrap_or_else(casper::coordinator::default_epoch_rounds);
            let plan = plan.unwrap_or_else(default_plan_strategy);
            run_one(
                &cfg,
                &spec,
                level,
                steps,
                spu_threads,
                temporal_block,
                epoch_rounds,
                plan,
                trace.as_deref(),
                trace_interval,
            )
        }
        Command::Verify { specs, seed, steps, out } => {
            let cfg = SimConfig::default();
            let opts = casper::verify::VerifyOptions { specs, seed, steps };
            eprintln!(
                "verifying pass-planner equivalence: {specs} random spec(s), seed {seed:#x}, \
                 {steps} step(s) per run ..."
            );
            let report = casper::verify::run_verify(&cfg, &opts);
            match report.failure {
                None => {
                    println!(
                        "verify: {} spec(s) checked — both plan strategies, both engines, \
                         bitwise against the plan-aware golden oracle: all equivalent",
                        report.checked
                    );
                    Ok(())
                }
                Some(f) => {
                    std::fs::write(&out, &f.minimized_toml)
                        .with_context(|| format!("writing reproducer to {}", out.display()))?;
                    eprintln!("verify: case {} ({}) FAILED: {}", f.case, f.spec_id, f.error);
                    eprintln!(
                        "verify: minimized reproducer written to {} — replay it with \
                         `casper kernels show` / `casper run --kernel-file`, or commit it \
                         under rust/tests/corpus/ as a regression",
                        out.display()
                    );
                    anyhow::bail!(
                        "planner equivalence failure on case {} (seed {:#x}, {} spec(s) passed)",
                        f.case,
                        seed,
                        report.checked
                    );
                }
            }
        }
        Command::Experiments {
            only,
            quick,
            steps,
            jobs,
            spu_threads,
            out_dir,
            config,
            kernel_files,
            extended_kernels,
            kernels,
            keep_going,
            cell_timeout_ms,
            retries,
            backoff_ms,
            resume,
            inject_faults,
            events,
            metrics_out,
            progress,
            temporal_block,
        } => {
            let cfg = cli::load_config(config.as_ref())?;
            let registry = cli::build_registry(&kernel_files)?;
            // Default sweep set: the paper six, plus the extended presets
            // under --extended-kernels, plus every file-defined kernel.
            // --kernels replaces the set with an explicit id list.
            let selected: Vec<Arc<KernelSpec>> = match &kernels {
                Some(ids) => ids
                    .iter()
                    .map(|id| {
                        registry.resolve(id).with_context(|| {
                            format!("unknown kernel '{id}' (see `casper kernels list`)")
                        })
                    })
                    .collect::<Result<Vec<_>>>()?,
                None => registry
                    .specs()
                    .iter()
                    .filter(|s| extended_kernels || s.origin != KernelOrigin::Extended)
                    .cloned()
                    .collect(),
            };
            // Default: serial cells (the sweep already fans out; env
            // CASPER_SPU_THREADS can override for CI matrices).
            let spu_threads =
                spu_threads.unwrap_or_else(casper::coordinator::default_spu_threads);
            let opts = SweepOptions { quick, steps, jobs, spu_threads, temporal_block };
            eprintln!(
                "running {} experiment(s) over {} kernel(s), classes: {:?}, jobs: {}, spu-threads: {}, temporal-block: {} ...",
                only.len(),
                selected.len(),
                opts.classes(),
                opts.jobs,
                opts.spu_threads,
                opts.temporal_block
            );
            // --inject-faults wins over the CASPER_FAULTS env (the CI
            // matrix sets the env; explicit flags are for local testing).
            let faults = match inject_faults {
                Some(p) => Some(p),
                None => FaultPlan::from_env()
                    .map_err(|why| anyhow::anyhow!("bad CASPER_FAULTS: {why}"))?,
            };
            // --events: cell-lifecycle JSONL log; created up front so a
            // bad path fails the sweep before any simulation starts.
            let event_sink = match &events {
                Some(path) => {
                    let sink = EventSink::create(path)
                        .with_context(|| format!("creating event log {}", path.display()))?;
                    Some(sink)
                }
                None => None,
            };
            let sup = SupervisorConfig {
                policy: SupervisorPolicy {
                    keep_going,
                    cell_timeout: cell_timeout_ms.map(Duration::from_millis),
                    max_retries: retries,
                    backoff_base_ms: backoff_ms,
                    faults,
                    events: event_sink,
                    progress,
                    ..SupervisorPolicy::default()
                },
                journal: resume,
            };
            let (report, summary) = run_experiments_telemetry(&cfg, &only, opts, &selected, &sup)?;
            print!("{}", report.to_markdown());
            if let Some(dir) = out_dir {
                report.write_to(&dir)?;
                eprintln!("wrote {} tables to {}", report.tables.len(), dir.display());
            }
            if let Some(path) = metrics_out {
                std::fs::write(&path, summary.to_json())
                    .with_context(|| format!("writing sweep summary {}", path.display()))?;
                eprintln!("wrote sweep summary to {}", path.display());
            }
            // Exit nonzero iff any cell failed (--keep-going renders the
            // holes above, but the sweep as a whole did not succeed).
            if !report.failures.is_empty() {
                for f in &report.failures {
                    eprintln!("failed cell: {f}");
                }
                anyhow::bail!("{} sweep cell(s) failed", report.failures.len());
            }
            Ok(())
        }
        Command::Validate { artifacts } => {
            let dir = artifacts.unwrap_or_else(default_artifacts_dir);
            let mut rt = StencilRuntime::new(&dir)?;
            println!("PJRT platform: {}", rt.platform());
            let entries: Vec<_> = rt.entries().cloned().collect();
            let mut checked = 0;
            for entry in entries {
                let input = casper::stencil::Grid::random(entry.nx, entry.ny, entry.nz, 0xC0DE);
                let out = rt.execute(&entry.name, &input)?;
                let want = golden::run(&entry.kernel.descriptor(), &input, entry.steps);
                let diff = out.max_abs_diff(&want);
                anyhow::ensure!(
                    diff < 1e-11,
                    "artifact '{}' diverges from golden: max |err| = {diff}",
                    entry.name
                );
                println!(
                    "  {:<18} {:>9} pts  steps={}  max|err|={:.2e}  OK",
                    entry.name,
                    entry.points(),
                    entry.steps,
                    diff
                );
                checked += 1;
            }
            println!("{checked} artifacts validated against the golden reference.");
            Ok(())
        }
    }
}

/// `casper kernels show`: one kernel's full story.
fn show_kernel(s: &KernelSpec) -> Result<()> {
    let r = s.radius();
    println!("{} ({}, origin: {})", s.name, s.id, s.origin.name());
    println!(
        "  dims {} | {} taps | radius [{},{},{}] | coef sum {:.6} | AI {:.3} FLOP/B",
        s.dims,
        s.num_points(),
        r[0],
        r[1],
        r[2],
        s.coef_sum(),
        s.arithmetic_intensity()
    );
    println!("  domains:");
    for level in SizeClass::ALL {
        let d = s.domain(level);
        println!(
            "    {:<5} {:>16}  ({} points, {:.1} MB working set)",
            level.name(),
            d.to_string(),
            d.points(),
            d.working_set_bytes() as f64 / (1024.0 * 1024.0)
        );
    }
    let groups = s.row_groups();
    println!("  streams: {} ({} input rows + 1 output)", groups.len() + 1, groups.len());
    // Multi-pass plans + per-pass envelope headroom (docs/KERNELS.md):
    // wide kernels split into accumulating passes instead of failing.
    // Both strategies print side by side — the row-group lists show
    // exactly what the optimizing planner moved (rebalanced split points
    // keep `rows a..b` contiguous; affinity reordering does not).
    for strategy in PlanStrategy::ALL {
        let plan = s.pass_plan_with(strategy)?;
        let programs = ProgramBuilder::build_plan(s, &groups, &plan)?;
        let multi = plan.is_multi_pass();
        println!(
            "  {strategy} plan: {} pass{} per step{}{}",
            plan.num_passes(),
            if multi { "es" } else { "" },
            if multi { " (wider than the 16-stream envelope)" } else { "" },
            if plan.order_preserving() { "" } else { " | reorders row groups" }
        );
        for (pi, (pass, prog)) in plan.passes().iter().zip(&programs).enumerate() {
            println!(
                "    pass {pi}: {} | rows {}{}",
                prog.utilization(),
                fmt_row_groups(pass),
                if prog.accumulates() { " | accumulates (out += Σ taps)" } else { "" }
            );
        }
    }
    // The disassembly shows what the engine will actually run: the
    // default strategy (CASPER_PLAN, else optimized).
    let default = default_plan_strategy();
    let programs = ProgramBuilder::build_passes_with(s, default)?;
    for (pi, prog) in programs.iter().enumerate() {
        println!(
            "  pass {pi} program ({default} plan): {} instrs, {} constants — disassembly (c, s, dir, amt, clr, out, adv):",
            prog.instrs.len(),
            prog.constants.len()
        );
        for line in prog.disasm().lines() {
            println!("    {line}");
        }
    }
    Ok(())
}

/// Render a pass's row-group indices compactly: contiguous runs as
/// `a..b`, loose indices as-is (`0..5` vs `0..5, 10..15`).
fn fmt_row_groups(pass: &[usize]) -> String {
    let mut parts = Vec::new();
    let mut i = 0;
    while i < pass.len() {
        let start = pass[i];
        let mut end = start + 1;
        while i + 1 < pass.len() && pass[i + 1] == end {
            i += 1;
            end += 1;
        }
        if end - start > 1 {
            parts.push(format!("{start}..{end}"));
        } else {
            parts.push(format!("{start}"));
        }
        i += 1;
    }
    parts.join(", ")
}

/// `casper run`: one kernel on every engine, with the comparison table.
/// With `trace` set, the Casper engine additionally records a cycle-domain
/// trace (written as Chrome-trace-event JSON) — the simulated timing and
/// the printed report are byte-identical either way.
fn run_one(
    cfg: &SimConfig,
    spec: &Arc<KernelSpec>,
    level: SizeClass,
    steps: usize,
    spu_threads: usize,
    temporal_block: usize,
    epoch_rounds: usize,
    plan: PlanStrategy,
    trace: Option<&Path>,
    trace_interval: u64,
) -> Result<()> {
    let domain = spec.domain(level);
    let casper_opts = casper::coordinator::CasperOptions {
        spu_threads,
        temporal_block,
        epoch_rounds,
        plan,
        ..Default::default()
    };
    // The pipeline only engages on the epoch engine (workers > 1).
    let pipelined = casper_opts.pipeline && spu_threads > 1;
    println!(
        "{} @ {} ({} points, {} steps, {} SPU worker thread(s), temporal block {}, \
         epoch rounds {}, {} plan{})\n",
        spec.name,
        domain,
        domain.points(),
        steps,
        spu_threads,
        temporal_block,
        epoch_rounds,
        plan,
        if pipelined { ", pipelined" } else { "" },
    );

    let tracer = trace.map(|_| Box::new(Tracer::new(cfg, trace_interval)));
    let (casper_stats, tracer) =
        run_casper_spec_traced(cfg, spec, &domain, steps, casper_opts, tracer)?;
    let cpu_stats = run_cpu_spec(cfg, spec, &domain, steps);
    let gpu = GpuModel::default().cycles_spec(cfg, spec, &domain, steps);
    let pims = PimsModel::default().cycles_spec(cfg, spec, &domain, steps);

    println!("{:<10} {:>28}", "engine", "time");
    println!("{:<10} {:>28}", "casper", human_time_cycles(casper_stats.cycles, cfg.cpu.freq_ghz));
    println!("{:<10} {:>28}", "cpu", human_time_cycles(cpu_stats.cycles, cfg.cpu.freq_ghz));
    println!("{:<10} {:>28}", "gpu", human_time_cycles(gpu, cfg.cpu.freq_ghz));
    println!("{:<10} {:>28}", "pims", human_time_cycles(pims, cfg.cpu.freq_ghz));

    println!(
        "\nspeedup vs cpu: {:.2}x | vs pims: {:.2}x | gpu is {:.2}x faster",
        cpu_stats.cycles as f64 / casper_stats.cycles as f64,
        pims as f64 / casper_stats.cycles as f64,
        casper_stats.cycles as f64 / gpu as f64,
    );
    println!(
        "run digest {:016x} | grid digest {:016x} | {} accelerator pass(es) per step",
        casper_stats.digest(),
        casper_stats.grid_digest(),
        casper_stats.passes
    );
    if casper_stats.passes > 1 {
        println!(
            "multi-pass plan: {} accelerator passes per step (kernel wider than one program's envelope)",
            casper_stats.passes
        );
    }
    // Temporal-blocking traffic accounting (all zero at T=1); the grid
    // digest above is T-invariant, which is exactly what CI asserts.
    if casper_stats.temporal_block > 1 {
        println!(
            "temporal block {}: {} LLC line fills avoided | {} halo cells recomputed at chunk cuts",
            casper_stats.temporal_block,
            casper_stats.avoided_fills(),
            casper_stats.halo_recompute_cells,
        );
    }
    if let Some(r) = &casper_stats.reduction {
        let vals: Vec<String> = r.values.iter().map(|v| format!("{v:.6e}")).collect();
        println!(
            "fused reduction ({}, no extra pass): per-step values [{}]",
            r.op.name(),
            vals.join(", ")
        );
    }
    let ce = casper_energy(cfg, &casper_stats);
    let pe = cpu_energy(cfg, &cpu_stats);
    println!("energy casper: {ce}");
    println!("energy cpu:    {pe}");
    println!(
        "\nSPU locality: {:.1}% local loads | LLC hit rate {:.1}% | {} unaligned loads merged",
        100.0 * casper_stats.local_fraction(),
        100.0 * casper_stats.llc_hit_rate(),
        casper_stats.spu.merged_unaligned,
    );
    // Per-slice NoC/DRAM shares (ROADMAP: imbalance studies).
    let remote: u64 = casper_stats.slice_remote_reqs.iter().sum();
    let dram_rd: u64 = casper_stats.slice_dram_reads.iter().sum();
    let dram_wr: u64 = casper_stats.slice_dram_writes.iter().sum();
    println!(
        "per-slice: {} remote reqs (imbalance {:.2}x) | DRAM {} reads / {} writes (rd imbalance {:.2}x)",
        remote,
        casper_stats.remote_req_imbalance(),
        dram_rd,
        dram_wr,
        casper_stats.dram_read_imbalance(),
    );
    // LLC data bandwidth, from per-slice port grants (64 B per grant) —
    // the time-resolved view lives in the trace (--trace).
    let grants: u64 = casper_stats.slice_port_grants.iter().sum();
    println!(
        "LLC ports: {} grants ({} B data each, bw imbalance {:.2}x) | NoC contention {} cycles",
        grants,
        cfg.llc.line_bytes,
        casper_stats.bandwidth_imbalance(),
        casper_stats.noc_contention_cycles,
    );

    // Functional check against the golden reference.
    let want = golden::run_spec(
        spec,
        &domain,
        steps,
        casper::coordinator::CasperOptions::default().seed,
    );
    let diff = casper_stats.output.max_abs_diff(&want);
    anyhow::ensure!(diff < 1e-11, "functional mismatch vs golden: {diff}");
    println!("functional check vs golden reference: OK (max |err| = {diff:.2e})");

    if let Some(path) = trace {
        let tr = tracer.expect("engine returns the tracer it was given");
        std::fs::write(path, tr.to_chrome_string())
            .with_context(|| format!("writing trace to {}", path.display()))?;
        print!(
            "\ntrace: {} samples @ {} cycles/bucket -> {}",
            tr.samples(),
            tr.interval(),
            path.display()
        );
        // The CI temporal-blocking leg greps these: blocked line fills
        // must be <= the unblocked run's at an identical grid digest.
        print!(
            "\ntrace: DRAM line fills {} | avoided fills {}",
            tr.dram_lines_total(),
            tr.avoided_total()
        );
        if let Some((peak, mean)) = tr.llc_utilization_peak_mean() {
            let at = tr.peak_bucket().unwrap_or(0) as u64 * tr.interval();
            print!(
                "\ntrace: LLC bandwidth {:.1}% of aggregate port peak at cycle {at} (mean {:.1}%)",
                100.0 * peak,
                100.0 * mean
            );
        }
        println!();
        if tr.clipped() {
            println!("trace: run outlasted the bucket cap; tail folded into the final sample");
        }
        println!("trace: open in chrome://tracing or https://ui.perfetto.dev (1 \"us\" = 1 cycle)");
    }
    Ok(())
}
