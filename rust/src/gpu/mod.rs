//! Analytical NVIDIA Titan V model (§7.1 / §8.3).
//!
//! The paper reports only end-to-end GPU cycle counts (Table 5) and the
//! 815 mm² die area; we have no CUDA testbed, so the comparator is a
//! calibrated roofline: `time = launch + max(flops/peak, bytes/eff_bw)`.
//! Calibration against the paper's own Table 5 GPU column lands within
//! ~10% for the 1D/2D kernels (see EXPERIMENTS.md): the paper's numbers
//! are consistent with ≈8 B of HBM traffic per point at ~80% of peak
//! bandwidth plus ≈1.5 µs of launch overhead.

use crate::config::SimConfig;
use crate::stencil::{Domain, KernelSpec, StencilKind};

/// Titan V parameters (public spec [165, 171]).
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Peak fp64 throughput, FLOP/s.
    pub fp64_flops: f64,
    /// Peak HBM2 bandwidth, B/s.
    pub mem_bw: f64,
    /// Achievable fraction of peak bandwidth for streaming stencils.
    pub bw_efficiency: f64,
    /// Achievable fraction of peak fp64 for stencil MACs.
    pub flop_efficiency: f64,
    /// Kernel launch + driver overhead per time step, seconds.
    pub launch_overhead_s: f64,
    /// Effective HBM traffic per grid point, bytes (calibrated; §8.3).
    pub bytes_per_point: f64,
    /// Full die area (§7.1 uses the complete 815 mm² die).
    pub area_mm2: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            fp64_flops: 6.9e12,
            mem_bw: 652.8e9,
            bw_efficiency: 0.80,
            flop_efficiency: 0.5,
            launch_overhead_s: 1.5e-6,
            bytes_per_point: 8.0,
            area_mm2: 815.0,
        }
    }
}

impl GpuModel {
    /// Execution time for `steps` stencil steps, in seconds.
    pub fn time_s(&self, kind: StencilKind, domain: &Domain, steps: usize) -> f64 {
        self.time_s_spec(&kind.spec(), domain, steps)
    }

    /// Spec-driven twin of [`time_s`](Self::time_s).
    pub fn time_s_spec(&self, spec: &KernelSpec, domain: &Domain, steps: usize) -> f64 {
        let points = domain.points() as f64;
        let flops = points * spec.flops_per_point() as f64;
        let bytes = points * self.bytes_per_point;
        let compute = flops / (self.fp64_flops * self.flop_efficiency);
        let traffic = bytes / (self.mem_bw * self.bw_efficiency);
        steps as f64 * (self.launch_overhead_s + compute.max(traffic))
    }

    /// Execution time expressed in baseline-CPU clock cycles (how Table 5
    /// reports it).
    pub fn cycles(&self, cfg: &SimConfig, kind: StencilKind, domain: &Domain, steps: usize) -> u64 {
        self.cycles_spec(cfg, &kind.spec(), domain, steps)
    }

    /// Spec-driven twin of [`cycles`](Self::cycles).
    pub fn cycles_spec(
        &self,
        cfg: &SimConfig,
        spec: &KernelSpec,
        domain: &Domain,
        steps: usize,
    ) -> u64 {
        (self.time_s_spec(spec, domain, steps) * cfg.cpu.freq_ghz * 1e9).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SizeClass;

    #[test]
    fn calibration_tracks_table5_gpu_column() {
        // Paper Table 5, GPU cycles: Jacobi 1D = 4030 (L2), 36134 (LLC),
        // 135360 (DRAM). Our analytical model should land within 2×.
        let cfg = SimConfig::default();
        let m = GpuModel::default();
        for (level, paper) in [
            (SizeClass::L2, 4030.0),
            (SizeClass::Llc, 36134.0),
            (SizeClass::Dram, 135360.0),
        ] {
            let d = Domain::for_level(StencilKind::Jacobi1D, level);
            let ours = m.cycles(&cfg, StencilKind::Jacobi1D, &d, 1) as f64;
            let ratio = ours / paper;
            assert!(ratio > 0.5 && ratio < 2.0, "{level}: ours {ours} vs paper {paper}");
        }
    }

    #[test]
    fn bigger_domains_take_longer() {
        let cfg = SimConfig::default();
        let m = GpuModel::default();
        let mut prev = 0u64;
        for level in SizeClass::ALL {
            let d = Domain::for_level(StencilKind::Blur2D, level);
            let c = m.cycles(&cfg, StencilKind::Blur2D, &d, 1);
            assert!(c > prev);
            prev = c;
        }
    }

    #[test]
    fn compute_heavy_kernels_can_be_flop_bound() {
        // The 33-point kernel has 8.25 FLOP per 8 traffic bytes — above
        // the model's compute/bandwidth crossover, so it must cost more
        // than a bandwidth-only estimate.
        let m = GpuModel::default();
        let d = Domain::for_level(StencilKind::Points33_3D, SizeClass::Dram);
        let t = m.time_s(StencilKind::Points33_3D, &d, 1);
        let bw_only = d.points() as f64 * 8.0 / (m.mem_bw * m.bw_efficiency);
        assert!(t > bw_only);
    }

    #[test]
    fn steps_scale_linearly() {
        let m = GpuModel::default();
        let d = Domain::for_level(StencilKind::Jacobi2D, SizeClass::Llc);
        let t1 = m.time_s(StencilKind::Jacobi2D, &d, 1);
        let t4 = m.time_s(StencilKind::Jacobi2D, &d, 4);
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }
}
