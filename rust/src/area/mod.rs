//! Area model (§8.6): SPU area from the Aladdin-derived model [169]
//! scaled to 22 nm, unaligned-load hardware per slice, and the mapping
//! logic — plus the comparator areas used in Fig 12.

use crate::config::SimConfig;

/// Area of the unaligned-load hardware per LLC slice (§8.6), mm².
pub const UNALIGNED_PER_SLICE_MM2: f64 = 0.14;
/// Stencil-segment mapping hardware at all NoC injection points, mm²
/// (two registers + adder + comparator per point; §8.6 calls it minimal).
pub const MAPPING_TOTAL_MM2: f64 = 0.074;
/// Marvell ThunderX2 die area, mm² (16 nm, 32 MB LLC [127]) — the §8.6
/// host-CPU reference for the "<1% overhead" claim.
pub const THUNDERX2_MM2: f64 = 605.0;

/// Casper's added die area (§8.6: "4.65 mm² for a system using 16 SPUs").
#[derive(Debug, Clone, Copy)]
pub struct CasperArea {
    pub spus_mm2: f64,
    pub unaligned_mm2: f64,
    pub mapping_mm2: f64,
}

impl CasperArea {
    pub fn of(cfg: &SimConfig) -> CasperArea {
        CasperArea {
            spus_mm2: cfg.spu.count as f64 * cfg.spu.area_mm2,
            unaligned_mm2: cfg.llc.slices as f64 * UNALIGNED_PER_SLICE_MM2,
            mapping_mm2: MAPPING_TOTAL_MM2,
        }
    }

    pub fn total_mm2(&self) -> f64 {
        self.spus_mm2 + self.unaligned_mm2 + self.mapping_mm2
    }

    /// Fractional area increase over the ThunderX2 host (§8.6: < 1%).
    pub fn host_overhead(&self) -> f64 {
        self.total_mm2() / THUNDERX2_MM2
    }
}

/// Performance-per-area improvement of Casper over a comparator:
/// `(perf_c / area_c) / (perf_x / area_x)` with perf = 1/cycles. The
/// paper's Fig 12 uses the SPU area alone against the full GPU die
/// ("typical GPU-accelerated systems also need a host CPU", §7.1).
pub fn perf_per_area_improvement(
    casper_cycles: u64,
    casper_area_mm2: f64,
    other_cycles: u64,
    other_area_mm2: f64,
) -> f64 {
    let perf_c = 1.0 / casper_cycles as f64 / casper_area_mm2;
    let perf_o = 1.0 / other_cycles as f64 / other_area_mm2;
    perf_c / perf_o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_section_8_6() {
        let cfg = SimConfig::default();
        let a = CasperArea::of(&cfg);
        // 16 × 0.146 = 2.336 mm² of SPUs; +16 × 0.14 unaligned; total
        // ≈ 4.65 mm² and < 1% of the ThunderX2.
        assert!((a.spus_mm2 - 2.336).abs() < 1e-9);
        assert!((a.total_mm2() - 4.65).abs() < 0.01, "{}", a.total_mm2());
        assert!(a.host_overhead() < 0.01);
        assert!(a.host_overhead() > 0.005);
    }

    #[test]
    fn spu_vs_titanv_area_ratio() {
        // §8.3: "16 SPUs occupy 349× less area than the Titan V".
        let cfg = SimConfig::default();
        let a = CasperArea::of(&cfg);
        let ratio = 815.0 / a.spus_mm2;
        assert!((ratio - 349.0).abs() < 1.0, "{ratio}");
    }

    #[test]
    fn perf_per_area_math() {
        // Same speed, 10× smaller → 10× better perf/area.
        assert!((perf_per_area_improvement(100, 10.0, 100, 100.0) - 10.0).abs() < 1e-12);
        // 2× slower, 349× smaller → 174.5×.
        assert!((perf_per_area_improvement(200, 1.0, 100, 349.0) - 174.5).abs() < 1e-9);
    }
}
