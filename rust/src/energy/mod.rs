//! System energy model (Table 2 constants, from CACTI 7.0 [166] and
//! [167, 168]): per-event energies for every cache level, DRAM accesses,
//! and per-instruction core/SPU energy.

use crate::config::SimConfig;
use crate::coordinator::RunStats;
use crate::cpu::CpuRunStats;
use crate::mem::cache::CacheStats;
use crate::mem::hierarchy::MemEvents;

/// Energy breakdown in nanojoules.
#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct EnergyBreakdown {
    pub core_nj: f64,
    pub l1_nj: f64,
    pub l2_nj: f64,
    pub llc_nj: f64,
    pub dram_nj: f64,
    /// Chip static energy over the runtime (see
    /// [`SimConfig::chip_static_watts`](crate::config::SimConfig)).
    pub static_nj: f64,
}

impl EnergyBreakdown {
    /// Dynamic energy only — comparable to the paper's appendix Table 6.
    pub fn dynamic_nj(&self) -> f64 {
        self.core_nj + self.l1_nj + self.l2_nj + self.llc_nj + self.dram_nj
    }

    pub fn dynamic_j(&self) -> f64 {
        self.dynamic_nj() * 1e-9
    }

    /// Total system energy (dynamic + static) — Fig 11's metric.
    pub fn total_nj(&self) -> f64 {
        self.dynamic_nj() + self.static_nj
    }

    pub fn total_j(&self) -> f64 {
        self.total_nj() * 1e-9
    }
}

impl std::fmt::Display for EnergyBreakdown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "total {:.3e} J (dynamic {:.3e} J; core {:.1}%, L1 {:.1}%, L2 {:.1}%, LLC {:.1}%, DRAM {:.1}%, static {:.1}%)",
            self.total_j(),
            self.dynamic_j(),
            100.0 * self.core_nj / self.total_nj(),
            100.0 * self.l1_nj / self.total_nj(),
            100.0 * self.l2_nj / self.total_nj(),
            100.0 * self.llc_nj / self.total_nj(),
            100.0 * self.dram_nj / self.total_nj(),
            100.0 * self.static_nj / self.total_nj(),
        )
    }
}

fn cache_energy_nj(stats: &CacheStats, hit_pj: f64, miss_pj: f64) -> f64 {
    // Prefetch fills cost a miss-path access each; demand hits/misses per
    // Table 2. Writebacks ride the miss energy of the receiving level.
    (stats.hits() as f64 * hit_pj
        + stats.misses() as f64 * miss_pj
        + stats.prefetch_fills as f64 * miss_pj)
        / 1000.0
}

/// Static energy for a run of `cycles` at the configured clock.
fn static_nj(cfg: &SimConfig, cycles: u64) -> f64 {
    let seconds = cycles as f64 / (cfg.cpu.freq_ghz * 1e9);
    cfg.chip_static_watts * seconds * 1e9
}

/// Energy of a baseline-CPU run.
pub fn cpu_energy(cfg: &SimConfig, stats: &CpuRunStats) -> EnergyBreakdown {
    from_events(
        cfg,
        stats.instrs,
        cfg.cpu.energy_per_instr_nj,
        &stats.mem,
        stats.cycles,
    )
}

/// Energy of a Casper run: SPU instructions + LLC + DRAM (no private-cache
/// traffic — that's the whole point of computing near the LLC). The host
/// chip's static power still burns for the duration (§8.2's idle-CPU
/// observation).
pub fn casper_energy(cfg: &SimConfig, stats: &RunStats) -> EnergyBreakdown {
    let mut ev = MemEvents {
        llc: stats.llc,
        dram_accesses: stats.dram_accesses,
        ..Default::default()
    };
    ev.noc_hops = stats.noc_hops;
    from_events(cfg, stats.total_instrs, cfg.spu.energy_per_instr_nj, &ev, stats.cycles)
}

fn from_events(
    cfg: &SimConfig,
    instrs: u64,
    instr_nj: f64,
    ev: &MemEvents,
    cycles: u64,
) -> EnergyBreakdown {
    EnergyBreakdown {
        core_nj: instrs as f64 * instr_nj,
        l1_nj: cache_energy_nj(&ev.l1, cfg.l1.hit_pj, cfg.l1.miss_pj),
        l2_nj: cache_energy_nj(&ev.l2, cfg.l2.hit_pj, cfg.l2.miss_pj),
        llc_nj: cache_energy_nj(&ev.llc, cfg.llc.hit_pj, cfg.llc.miss_pj),
        dram_nj: ev.dram_accesses as f64 * cfg.dram.access_nj,
        static_nj: static_nj(cfg, cycles),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SizeClass;
    use crate::coordinator::run_casper;
    use crate::cpu::run_cpu;
    use crate::stencil::{Domain, StencilKind};

    #[test]
    fn cache_energy_uses_table2_constants() {
        let stats = CacheStats {
            read_hits: 10,
            read_misses: 2,
            write_hits: 5,
            write_misses: 1,
            ..Default::default()
        };
        // 15 hits × 945 pJ + 3 misses × 1904 pJ = 19.887 nJ.
        let nj = cache_energy_nj(&stats, 945.0, 1904.0);
        assert!((nj - (15.0 * 945.0 + 3.0 * 1904.0) / 1000.0).abs() < 1e-9);
    }

    #[test]
    fn casper_beats_cpu_on_llc_sized_2d() {
        // The headline energy claim (Fig 11): LLC-resident stencils use
        // substantially less energy on Casper.
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::for_level(kind, SizeClass::Llc);
        let c = casper_energy(&cfg, &run_casper(&cfg, kind, &d, 1));
        let p = cpu_energy(&cfg, &run_cpu(&cfg, kind, &d, 1));
        assert!(
            c.total_j() < p.total_j(),
            "casper {} vs cpu {}",
            c.total_j(),
            p.total_j()
        );
    }

    #[test]
    fn casper_energy_has_no_private_cache_terms() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi1D;
        let d = Domain::tiny(kind);
        let e = casper_energy(&cfg, &run_casper(&cfg, kind, &d, 1));
        assert_eq!(e.l1_nj, 0.0);
        assert_eq!(e.l2_nj, 0.0);
        assert!(e.llc_nj > 0.0);
    }

    #[test]
    fn breakdown_total_is_sum() {
        let b = EnergyBreakdown {
            core_nj: 1.0,
            l1_nj: 2.0,
            l2_nj: 3.0,
            llc_nj: 4.0,
            dram_nj: 5.0,
            static_nj: 6.0,
        };
        assert_eq!(b.dynamic_nj(), 15.0);
        assert_eq!(b.total_nj(), 21.0);
        assert!((b.total_j() - 21e-9).abs() < 1e-20);
    }

    #[test]
    fn static_energy_scales_with_cycles() {
        let cfg = SimConfig::default();
        // 2 GHz, 60 W → 30 nJ per cycle.
        assert!((super::static_nj(&cfg, 1000) - 30_000.0).abs() < 1e-6);
    }
}
