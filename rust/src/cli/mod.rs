//! Hand-rolled CLI (the offline registry has no `clap`; see DESIGN.md §3).
//!
//! ```text
//! casper experiments [--only fig10,table5] [--quick] [--steps N]
//!                    [--jobs N] [--temporal-block T] [--out-dir DIR]
//!                    [--config FILE]
//!                    [--kernel-file FILE]... [--extended-kernels]
//!                    [--kernels id1,id2] [--keep-going | --fail-fast]
//!                    [--cell-timeout SECS] [--retries N] [--backoff-ms N]
//!                    [--resume FILE] [--inject-faults SPEC]
//!                    [--events FILE] [--metrics-out FILE] [--progress]
//! casper run --kernel jacobi2d --level llc [--steps N] [--config FILE]
//!            [--plan greedy|optimized] [--temporal-block T]
//!            [--epoch-rounds N] [--kernel-file FILE]...
//!            [--trace FILE] [--trace-interval N]
//! casper verify [--specs N] [--seed N] [--steps N] [--out FILE]
//! casper kernels list [--kernel-file FILE]...
//! casper kernels show ID [--kernel-file FILE]...
//! casper validate [--artifacts DIR]
//! casper roofline
//! casper info
//! casper help
//! ```
//!
//! Every bad-input path is a named [`CliError`] variant: the binary
//! prints `error: [<name>] <message>` and exits nonzero — user mistakes
//! never panic.

use std::fmt;
use std::path::PathBuf;

use anyhow::Result;

use crate::config::{SimConfig, SizeClass};
use crate::harness::{Experiment, FaultPlan};
use crate::isa::PlanStrategy;
use crate::stencil::KernelRegistry;

/// Structured CLI parse errors. Each variant has a stable kebab-case
/// [`CliError::name`] that leads the rendered message, so scripts can
/// match on the class of mistake without parsing prose.
#[derive(Debug, Clone, PartialEq)]
pub enum CliError {
    MissingValue { flag: String },
    UnknownFlag { flag: String },
    UnknownCommand { cmd: String },
    UnknownExperiment { id: String },
    UnknownLevel { level: String },
    UnknownKernelsSubcommand { sub: String },
    MissingFlag { cmd: &'static str, flag: &'static str },
    MissingKernelId,
    BadNumber { flag: &'static str, value: String, must: &'static str },
    BadFaultSpec { why: String },
    ConflictingFlags { a: &'static str, b: &'static str },
    UnknownPlan { value: String },
}

impl CliError {
    /// Stable kebab-case error name (the `[<name>]` message prefix).
    pub fn name(&self) -> &'static str {
        match self {
            CliError::MissingValue { .. } => "missing-value",
            CliError::UnknownFlag { .. } => "unknown-flag",
            CliError::UnknownCommand { .. } => "unknown-command",
            CliError::UnknownExperiment { .. } => "unknown-experiment",
            CliError::UnknownLevel { .. } => "unknown-level",
            CliError::UnknownKernelsSubcommand { .. } => "unknown-subcommand",
            CliError::MissingFlag { .. } => "missing-flag",
            CliError::MissingKernelId => "missing-kernel-id",
            CliError::BadNumber { .. } => "bad-number",
            CliError::BadFaultSpec { .. } => "bad-fault-spec",
            CliError::ConflictingFlags { .. } => "conflicting-flags",
            CliError::UnknownPlan { .. } => "unknown-plan",
        }
    }
}

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] ", self.name())?;
        match self {
            CliError::MissingValue { flag } => write!(f, "--{flag} requires a value"),
            CliError::UnknownFlag { flag } => {
                write!(f, "unknown flag --{flag} (see `casper help`)")
            }
            CliError::UnknownCommand { cmd } => {
                write!(f, "unknown command '{cmd}' (see `casper help`)")
            }
            CliError::UnknownExperiment { id } => write!(f, "unknown experiment '{id}'"),
            CliError::UnknownLevel { level } => {
                write!(f, "unknown level '{level}' (l2 | llc | dram)")
            }
            CliError::UnknownKernelsSubcommand { sub } => {
                write!(f, "unknown kernels subcommand '{sub}' (list | show ID)")
            }
            CliError::MissingFlag { cmd, flag } => write!(f, "{cmd} requires --{flag}"),
            CliError::MissingKernelId => write!(f, "kernels show requires a kernel id"),
            CliError::BadNumber { flag, value, must } => {
                write!(f, "bad --{flag} '{value}' ({must})")
            }
            CliError::BadFaultSpec { why } => write!(f, "bad --inject-faults spec: {why}"),
            CliError::ConflictingFlags { a, b } => write!(f, "--{a} conflicts with --{b}"),
            CliError::UnknownPlan { value } => {
                write!(f, "unknown plan strategy '{value}' (greedy | optimized)")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Experiments {
        only: Vec<Experiment>,
        quick: bool,
        steps: usize,
        /// Sweep worker threads (default: one per hardware thread).
        jobs: usize,
        /// Intra-run SPU worker threads per cell (`None` = engine default:
        /// serial, since the sweep already parallelizes across cells).
        spu_threads: Option<usize>,
        out_dir: Option<PathBuf>,
        config: Option<PathBuf>,
        /// TOML kernel-spec files to load into the registry (each kernel
        /// joins the sweep).
        kernel_files: Vec<PathBuf>,
        /// Include the extended built-in presets in the sweep.
        extended_kernels: bool,
        /// Explicit kernel-id selection (overrides the default set).
        kernels: Option<Vec<String>>,
        /// Keep sweeping after a cell fails; failed cells render as
        /// annotated holes (default: fail fast on the first failure).
        keep_going: bool,
        /// Per-cell wall-clock deadline, in milliseconds.
        cell_timeout_ms: Option<u64>,
        /// Retry attempts after a transient cell failure.
        retries: u32,
        /// Base of the exponential retry backoff, in milliseconds.
        backoff_ms: u64,
        /// Checkpoint journal path: resume a sweep, re-running only the
        /// cells the journal is missing.
        resume: Option<PathBuf>,
        /// Deterministic fault-injection plan (testing/CI).
        inject_faults: Option<FaultPlan>,
        /// JSONL cell-lifecycle event log (telemetry; results unchanged).
        events: Option<PathBuf>,
        /// Machine-readable sweep-summary JSON output path.
        metrics_out: Option<PathBuf>,
        /// Live progress line on stderr.
        progress: bool,
        /// Temporal block depth for every Casper cell (default 1 =
        /// plain chaining, the byte-stable paper report).
        temporal_block: usize,
    },
    Run {
        /// Kernel id (preset or file-defined), resolved against the
        /// registry at dispatch time.
        kernel: String,
        level: SizeClass,
        steps: usize,
        /// Intra-run SPU worker threads (`None` = one per SPU).
        spu_threads: Option<usize>,
        config: Option<PathBuf>,
        kernel_files: Vec<PathBuf>,
        /// Chrome-trace (Perfetto) JSON output path; enables the
        /// cycle-domain tracer. Results are byte-identical either way.
        trace: Option<PathBuf>,
        /// Counter-sampling bucket width in cycles (`--trace-interval`).
        trace_interval: u64,
        /// Temporal block depth: T wavefronts stay resident per LLC
        /// slice, halos recomputed instead of re-fetched (default 1).
        temporal_block: usize,
        /// Rounds per epoch for the epoch-parallel engine (`None` =
        /// engine default: `CASPER_EPOCH_ROUNDS`, else 2048). Results
        /// are independent of the value.
        epoch_rounds: Option<usize>,
        /// Pass-plan strategy (`None` = engine default: `CASPER_PLAN`,
        /// else optimized).
        plan: Option<PlanStrategy>,
    },
    /// Randomized blackbox planner-equivalence sweep (`casper verify`).
    Verify {
        /// Number of random specs to generate and check.
        specs: usize,
        /// Master seed of the sweep (deterministic end to end).
        seed: u64,
        /// Jacobi steps per engine run.
        steps: usize,
        /// Where to write the minimized reproducer TOML on failure.
        out: PathBuf,
    },
    Kernels {
        action: KernelsAction,
        kernel_files: Vec<PathBuf>,
    },
    Validate {
        artifacts: Option<PathBuf>,
    },
    Roofline,
    Info,
    Help,
}

/// `casper kernels` subcommands.
#[derive(Debug, Clone, PartialEq)]
pub enum KernelsAction {
    List,
    Show(String),
}

pub const USAGE: &str = "\
casper — near-cache stencil acceleration (full-system reproduction)

USAGE:
  casper experiments [--only IDs] [--quick] [--steps N] [--jobs N]
                     [--spu-threads N] [--temporal-block T]
                     [--out-dir DIR] [--config FILE]
                     [--kernel-file FILE]... [--extended-kernels]
                     [--kernels id1,id2] [--keep-going | --fail-fast]
                     [--cell-timeout SECS] [--retries N] [--backoff-ms N]
                     [--resume FILE] [--inject-faults SPEC]
                     [--events FILE] [--metrics-out FILE] [--progress]
      Regenerate the paper's tables/figures. IDs: fig1 fig10 fig11 fig12
      fig13 fig14 table4 table5 table6 slices blocked (comma-separated;
      default: the paper's nine). --jobs N runs the sweep on N worker
      threads (default: all hardware threads; 1 = serial). --spu-threads N
      additionally parallelizes INSIDE each Casper cell (default 1 here —
      the sweep already fans out across cells). Reports are byte-identical
      at any combination. --temporal-block T runs every Casper cell
      temporally blocked (T wavefronts resident per LLC slice, halos
      recomputed instead of re-fetched; grids are bitwise identical to
      T=1, traffic counters drop); fig1 gains blocked companion points
      and `--only blocked` tabulates the avoided traffic per cell. The
      kernel set defaults to the paper's six;
      --extended-kernels adds the built-in extras, --kernel-file adds
      TOML-defined kernels, --kernels selects an exact id list.
      Supervision: every cell runs panic-isolated with --retries N
      retry attempts (default 2, exponential backoff from --backoff-ms,
      default 25) and an optional --cell-timeout SECS wall-clock deadline.
      --keep-going sweeps past failed cells, rendering them as annotated
      holes and exiting nonzero; --fail-fast (the default) aborts on the
      first failure. --resume FILE journals completed cells to FILE and,
      on restart, re-runs only the missing ones — the resumed report is
      byte-identical to an uninterrupted run. --inject-faults plants
      deterministic faults for testing: seed=N,rate=R,kind=panic|delay|
      error[,cells=i:j:k][,delay-ms=N] (env: CASPER_FAULTS).
      Telemetry (results and report bytes are unchanged by all three):
      --events FILE appends one JSON object per cell lifecycle event
      (scheduled/cached/started/retried/failed/timed-out/finished/result,
      with wall-clock ms and run digests); --metrics-out FILE writes a
      machine-readable sweep summary; --progress keeps a live
      done/failed/ETA line on stderr.
  casper run --kernel ID --level {l2|llc|dram} [--steps N]
             [--spu-threads N] [--plan greedy|optimized]
             [--temporal-block T] [--epoch-rounds N] [--config FILE]
             [--kernel-file FILE]... [--trace FILE] [--trace-interval N]
      Run one stencil on Casper + all baselines and print the comparison.
      ID may be any registry kernel: preset, extended, or file-defined.
      --spu-threads N runs the 16 SPUs epoch-parallel on N workers
      (default: one per SPU; 1 = the serial engine; identical results).
      With workers > 1 the engine also pipelines epochs — each epoch's
      serial timing replay overlaps the next epoch's functional fan-out
      (disable with CASPER_EPOCH_PIPELINE=0; results byte-identical).
      --epoch-rounds N sets the rounds batched per epoch (default 2048,
      env CASPER_EPOCH_ROUNDS); it trades hand-off overhead against
      epoch memory and never changes results.
      --temporal-block T keeps T wavefronts resident per LLC slice:
      the final grid (and its digest) is bitwise identical to T=1 while
      avoided line fills and halo-recompute counters are reported (and
      attributed in the --trace output). Kernels with a `reduction` spec
      print the fused per-step reduction values in either mode.
      --trace FILE writes a Chrome-trace JSON (load in chrome://tracing
      or https://ui.perfetto.dev): per-SPU and pass spans plus per-slice
      LLC bandwidth / hit-rate / DRAM / NoC counter samples every
      --trace-interval cycles (default 1024). The run's counters and
      digest are byte-identical with tracing on or off.
      --plan selects the multi-pass planner: 'greedy' packs row groups
      first-fit in program order, 'optimized' (the default, env
      CASPER_PLAN) additionally balances split points and reorders row
      groups by constant affinity when that saves whole passes. Grids
      are bitwise identical whenever the optimized plan preserves
      program order (see docs/KERNELS.md, \"Pass planning\").
  casper verify [--specs N] [--seed N] [--steps N] [--out FILE]
      Randomized blackbox equivalence sweep over the pass planner:
      generates N envelope-stressing kernel specs (default 64) from
      --seed, runs both plan strategies through both engines, and
      compares every grid bit and reduction value against the
      plan-aware golden oracle. On failure the offending spec is shrunk
      to a minimal reproducer and written to --out (default
      verify-failure.toml) as a --kernel-file TOML; exits nonzero.
  casper kernels list [--kernel-file FILE]...
      List every registered kernel (presets + loaded spec files).
  casper kernels show ID [--kernel-file FILE]...
      Print one kernel's taps, domains, multi-pass plan with per-pass
      buffer utilization, and compiled Casper program(s).
  casper validate [--artifacts DIR]
      Execute the AOT JAX/Pallas artifacts via PJRT and cross-check the
      simulator numerics (requires `make artifacts`).
  casper roofline
      Print the Fig 1 roofline data.
  casper info
      Print the Table 2 machine configuration.
  casper help
      This message.

KERNELS: jacobi1d pts7_1d jacobi2d blur2d heat3d pts33_3d (paper);
         hdiff star25_3d star17_3d jacobi2d_res wide_mix_2d (extended);
         plus any --kernel-file specs. Kernels wider than the 16-stream
         ISA envelope compile as multi-pass plans (see docs/KERNELS.md).
";

/// A tiny flag parser: `--key value` pairs plus boolean flags.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, CliError> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let boolean = matches!(
                    name,
                    "quick" | "help" | "extended-kernels" | "keep-going" | "fail-fast" | "progress"
                );
                if boolean {
                    flags.push((name.to_string(), None));
                } else {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| CliError::MissingValue { flag: name.to_string() })?;
                    flags.push((name.to_string(), Some(v.clone())));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    /// Every occurrence of a repeatable flag, in order.
    fn get_all(&self, name: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(n, _)| n == name)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn reject_unknown(&self, allowed: &[&str]) -> Result<(), CliError> {
        for (n, _) in &self.flags {
            if !allowed.contains(&n.as_str()) {
                return Err(CliError::UnknownFlag { flag: n.clone() });
            }
        }
        Ok(())
    }
}

/// Parse a full argv (without the binary name).
pub fn parse(argv: &[String]) -> Result<Command, CliError> {
    if argv.is_empty() {
        return Ok(Command::Help);
    }
    let cmd = argv[0].as_str();
    let rest = Args::parse(&argv[1..])?;
    if rest.has("help") {
        return Ok(Command::Help);
    }
    match cmd {
        "experiments" => {
            rest.reject_unknown(&[
                "only",
                "quick",
                "steps",
                "jobs",
                "spu-threads",
                "temporal-block",
                "out-dir",
                "config",
                "kernel-file",
                "extended-kernels",
                "kernels",
                "keep-going",
                "fail-fast",
                "cell-timeout",
                "retries",
                "backoff-ms",
                "resume",
                "inject-faults",
                "events",
                "metrics-out",
                "progress",
            ])?;
            let only = match rest.get("only") {
                None => Experiment::ALL.to_vec(),
                Some(s) => s
                    .split(',')
                    .map(|id| {
                        Experiment::parse(id)
                            .ok_or_else(|| CliError::UnknownExperiment { id: id.to_string() })
                    })
                    .collect::<Result<Vec<_>, CliError>>()?,
            };
            if rest.has("keep-going") && rest.has("fail-fast") {
                return Err(CliError::ConflictingFlags { a: "keep-going", b: "fail-fast" });
            }
            let inject_faults = match rest.get("inject-faults") {
                None => None,
                Some(s) => {
                    Some(FaultPlan::parse(s).map_err(|why| CliError::BadFaultSpec { why })?)
                }
            };
            Ok(Command::Experiments {
                only,
                quick: rest.has("quick"),
                steps: parse_steps(&rest)?,
                jobs: parse_jobs(&rest)?,
                spu_threads: parse_spu_threads(&rest)?,
                out_dir: rest.get("out-dir").map(PathBuf::from),
                config: rest.get("config").map(PathBuf::from),
                kernel_files: kernel_file_flags(&rest),
                extended_kernels: rest.has("extended-kernels"),
                kernels: rest
                    .get("kernels")
                    .map(|s| s.split(',').map(|k| k.trim().to_string()).collect()),
                keep_going: rest.has("keep-going"),
                cell_timeout_ms: parse_cell_timeout(&rest)?,
                retries: parse_u32_flag(&rest, "retries", 2)?,
                backoff_ms: parse_u64_flag(&rest, "backoff-ms", 25)?,
                resume: rest.get("resume").map(PathBuf::from),
                inject_faults,
                events: rest.get("events").map(PathBuf::from),
                metrics_out: rest.get("metrics-out").map(PathBuf::from),
                progress: rest.has("progress"),
                temporal_block: parse_temporal_block(&rest)?,
            })
        }
        "run" => {
            rest.reject_unknown(&[
                "kernel",
                "level",
                "steps",
                "spu-threads",
                "temporal-block",
                "epoch-rounds",
                "config",
                "kernel-file",
                "trace",
                "trace-interval",
                "plan",
            ])?;
            let kernel = rest
                .get("kernel")
                .ok_or(CliError::MissingFlag { cmd: "run", flag: "kernel" })?
                .to_string();
            let level_s =
                rest.get("level").ok_or(CliError::MissingFlag { cmd: "run", flag: "level" })?;
            let level = SizeClass::parse(level_s)
                .ok_or_else(|| CliError::UnknownLevel { level: level_s.to_string() })?;
            Ok(Command::Run {
                kernel,
                level,
                steps: parse_steps(&rest)?,
                spu_threads: parse_spu_threads(&rest)?,
                config: rest.get("config").map(PathBuf::from),
                kernel_files: kernel_file_flags(&rest),
                trace: rest.get("trace").map(PathBuf::from),
                trace_interval: parse_trace_interval(&rest)?,
                temporal_block: parse_temporal_block(&rest)?,
                epoch_rounds: parse_epoch_rounds(&rest)?,
                plan: parse_plan(&rest)?,
            })
        }
        "verify" => {
            rest.reject_unknown(&["specs", "seed", "steps", "out"])?;
            Ok(Command::Verify {
                specs: parse_usize_flag(&rest, "specs", 64)?,
                seed: parse_u64_flag(&rest, "seed", 0xCA5_9E12)?,
                steps: parse_usize_flag(&rest, "steps", 2)?,
                out: rest
                    .get("out")
                    .map(PathBuf::from)
                    .unwrap_or_else(|| PathBuf::from("verify-failure.toml")),
            })
        }
        "kernels" => {
            rest.reject_unknown(&["kernel-file"])?;
            let action = match rest.positional.first().map(String::as_str) {
                None | Some("list") => KernelsAction::List,
                Some("show") => {
                    let id = rest.positional.get(1).ok_or(CliError::MissingKernelId)?;
                    KernelsAction::Show(id.clone())
                }
                Some(other) => {
                    return Err(CliError::UnknownKernelsSubcommand { sub: other.to_string() })
                }
            };
            Ok(Command::Kernels { action, kernel_files: kernel_file_flags(&rest) })
        }
        "validate" => {
            rest.reject_unknown(&["artifacts"])?;
            Ok(Command::Validate { artifacts: rest.get("artifacts").map(PathBuf::from) })
        }
        "roofline" => {
            rest.reject_unknown(&[])?;
            Ok(Command::Roofline)
        }
        "info" => {
            rest.reject_unknown(&[])?;
            Ok(Command::Info)
        }
        "help" | "--help" | "-h" => Ok(Command::Help),
        other => Err(CliError::UnknownCommand { cmd: other.to_string() }),
    }
}

fn kernel_file_flags(args: &Args) -> Vec<PathBuf> {
    args.get_all("kernel-file").into_iter().map(PathBuf::from).collect()
}

fn parse_steps(args: &Args) -> Result<usize, CliError> {
    match args.get("steps") {
        None => Ok(1),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(CliError::BadNumber {
                flag: "steps",
                value: s.to_string(),
                must: "must be an integer >= 1",
            }),
        },
    }
}

fn parse_jobs(args: &Args) -> Result<usize, CliError> {
    match args.get("jobs") {
        None => Ok(crate::harness::sweep::auto_jobs()),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(CliError::BadNumber {
                flag: "jobs",
                value: s.to_string(),
                must: "must be an integer >= 1",
            }),
        },
    }
}

fn parse_spu_threads(args: &Args) -> Result<Option<usize>, CliError> {
    match args.get("spu-threads") {
        None => Ok(None),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Some(n)),
            _ => Err(CliError::BadNumber {
                flag: "spu-threads",
                value: s.to_string(),
                must: "must be an integer >= 1",
            }),
        },
    }
}

/// `--temporal-block T`: wavefronts kept resident per LLC slice
/// (default 1 = plain chaining). Halo-vs-domain validation happens at
/// dispatch time, where the kernel and level are known.
fn parse_temporal_block(args: &Args) -> Result<usize, CliError> {
    match args.get("temporal-block") {
        None => Ok(1),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(CliError::BadNumber {
                flag: "temporal-block",
                value: s.to_string(),
                must: "must be an integer >= 1 (wavefronts per block)",
            }),
        },
    }
}

/// `--epoch-rounds N`: rounds batched per epoch in the epoch-parallel
/// engine (`None` = engine default; see `CASPER_EPOCH_ROUNDS`). Results
/// are independent of the value, so any positive integer is legal.
fn parse_epoch_rounds(args: &Args) -> Result<Option<usize>, CliError> {
    match args.get("epoch-rounds") {
        None => Ok(None),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Some(n)),
            _ => Err(CliError::BadNumber {
                flag: "epoch-rounds",
                value: s.to_string(),
                must: "must be an integer >= 1 (rounds per epoch)",
            }),
        },
    }
}

/// `--cell-timeout SECS` (fractional allowed) → whole milliseconds.
fn parse_cell_timeout(args: &Args) -> Result<Option<u64>, CliError> {
    match args.get("cell-timeout") {
        None => Ok(None),
        Some(s) => match s.parse::<f64>() {
            Ok(secs) if secs > 0.0 && secs.is_finite() => Ok(Some((secs * 1000.0).ceil() as u64)),
            _ => Err(CliError::BadNumber {
                flag: "cell-timeout",
                value: s.to_string(),
                must: "must be a positive number of seconds",
            }),
        },
    }
}

/// `--trace-interval N`: cycles per counter-sample bucket (default 1024).
fn parse_trace_interval(args: &Args) -> Result<u64, CliError> {
    match args.get("trace-interval") {
        None => Ok(1024),
        Some(s) => match s.parse::<u64>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(CliError::BadNumber {
                flag: "trace-interval",
                value: s.to_string(),
                must: "must be an integer >= 1 (cycles per sample bucket)",
            }),
        },
    }
}

/// `--plan greedy|optimized` (`None` = engine default, which also reads
/// `CASPER_PLAN`).
fn parse_plan(args: &Args) -> Result<Option<PlanStrategy>, CliError> {
    match args.get("plan") {
        None => Ok(None),
        Some(s) => PlanStrategy::parse(s)
            .map(Some)
            .ok_or_else(|| CliError::UnknownPlan { value: s.to_string() }),
    }
}

fn parse_usize_flag(args: &Args, flag: &'static str, default: usize) -> Result<usize, CliError> {
    match args.get(flag) {
        None => Ok(default),
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => Err(CliError::BadNumber {
                flag,
                value: s.to_string(),
                must: "must be an integer >= 1",
            }),
        },
    }
}

fn parse_u32_flag(args: &Args, flag: &'static str, default: u32) -> Result<u32, CliError> {
    match args.get(flag) {
        None => Ok(default),
        Some(s) => s.parse::<u32>().map_err(|_| CliError::BadNumber {
            flag,
            value: s.to_string(),
            must: "must be a non-negative integer",
        }),
    }
}

fn parse_u64_flag(args: &Args, flag: &'static str, default: u64) -> Result<u64, CliError> {
    match args.get(flag) {
        None => Ok(default),
        Some(s) => s.parse::<u64>().map_err(|_| CliError::BadNumber {
            flag,
            value: s.to_string(),
            must: "must be a non-negative integer",
        }),
    }
}

/// Load the config, with file override.
pub fn load_config(path: Option<&PathBuf>) -> Result<SimConfig> {
    match path {
        None => Ok(SimConfig::default()),
        Some(p) => SimConfig::from_file(p),
    }
}

/// Build the kernel registry a command resolves ids against: every
/// built-in preset (paper + extended) plus the `--kernel-file` specs.
pub fn build_registry(kernel_files: &[PathBuf]) -> Result<KernelRegistry> {
    let mut reg = KernelRegistry::builtin();
    for f in kernel_files {
        reg.load_file(f)?;
    }
    Ok(reg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::FaultKind;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_experiments() {
        let c = parse(&argv("experiments --only fig10,table5 --quick --out-dir out")).unwrap();
        match c {
            Command::Experiments { only, quick, steps, jobs, out_dir, kernels, .. } => {
                assert_eq!(only, vec![Experiment::Fig10, Experiment::Table5]);
                assert!(quick);
                assert_eq!(steps, 1);
                assert!(jobs >= 1, "default --jobs is auto (>= 1)");
                assert_eq!(out_dir.unwrap().to_str().unwrap(), "out");
                assert_eq!(kernels, None);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_jobs_flag() {
        match parse(&argv("experiments --jobs 4")).unwrap() {
            Command::Experiments { jobs, .. } => assert_eq!(jobs, 4),
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("experiments --jobs 0")).is_err());
        assert!(parse(&argv("experiments --jobs two")).is_err());
    }

    #[test]
    fn parses_spu_threads_flag() {
        match parse(&argv("experiments --spu-threads 16")).unwrap() {
            Command::Experiments { spu_threads, .. } => assert_eq!(spu_threads, Some(16)),
            other => panic!("{other:?}"),
        }
        match parse(&argv("experiments")).unwrap() {
            Command::Experiments { spu_threads, .. } => assert_eq!(spu_threads, None),
            other => panic!("{other:?}"),
        }
        match parse(&argv("run --kernel jacobi2d --level llc --spu-threads 1")).unwrap() {
            Command::Run { spu_threads, .. } => assert_eq!(spu_threads, Some(1)),
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("run --kernel jacobi2d --level llc --spu-threads 0")).is_err());
        assert!(parse(&argv("experiments --spu-threads x")).is_err());
    }

    #[test]
    fn parses_supervisor_flags() {
        let c = parse(&argv(
            "experiments --keep-going --cell-timeout 0.5 --retries 5 --backoff-ms 10 \
             --resume ckpt.journal --inject-faults seed=7,rate=0.25,kind=error",
        ))
        .unwrap();
        match c {
            Command::Experiments {
                keep_going,
                cell_timeout_ms,
                retries,
                backoff_ms,
                resume,
                inject_faults,
                ..
            } => {
                assert!(keep_going);
                assert_eq!(cell_timeout_ms, Some(500));
                assert_eq!(retries, 5);
                assert_eq!(backoff_ms, 10);
                assert_eq!(resume, Some(PathBuf::from("ckpt.journal")));
                let plan = inject_faults.unwrap();
                assert_eq!(plan.seed, 7);
                assert_eq!(plan.rate, 0.25);
                assert_eq!(plan.kind, FaultKind::Error);
            }
            other => panic!("{other:?}"),
        }
        // Defaults: fail-fast, no timeout, 2 retries, 25 ms backoff.
        match parse(&argv("experiments")).unwrap() {
            Command::Experiments {
                keep_going,
                cell_timeout_ms,
                retries,
                backoff_ms,
                resume,
                inject_faults,
                ..
            } => {
                assert!(!keep_going);
                assert_eq!(cell_timeout_ms, None);
                assert_eq!(retries, 2);
                assert_eq!(backoff_ms, 25);
                assert_eq!(resume, None);
                assert_eq!(inject_faults, None);
            }
            other => panic!("{other:?}"),
        }
        // `--fail-fast` is accepted (it is the default, spelled out).
        match parse(&argv("experiments --fail-fast")).unwrap() {
            Command::Experiments { keep_going, .. } => assert!(!keep_going),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn supervisor_flag_errors_are_named() {
        let err = parse(&argv("experiments --keep-going --fail-fast")).unwrap_err();
        assert_eq!(err.name(), "conflicting-flags");
        let err = parse(&argv("experiments --cell-timeout -1")).unwrap_err();
        assert_eq!(err.name(), "bad-number");
        let err = parse(&argv("experiments --inject-faults seed=1")).unwrap_err();
        assert_eq!(err.name(), "bad-fault-spec");
        assert!(err.to_string().contains("[bad-fault-spec]"), "{err}");
        let err = parse(&argv("experiments --retries nope")).unwrap_err();
        assert_eq!(err.name(), "bad-number");
    }

    #[test]
    fn errors_render_name_and_message() {
        let err = parse(&argv("experiments --bogus x")).unwrap_err();
        assert_eq!(err.name(), "unknown-flag");
        assert!(err.to_string().contains("[unknown-flag]"), "{err}");
        assert!(err.to_string().contains("--bogus"), "{err}");
        let err = parse(&argv("frobnicate")).unwrap_err();
        assert_eq!(err.name(), "unknown-command");
        let err = parse(&argv("experiments --only fig99")).unwrap_err();
        assert_eq!(err.name(), "unknown-experiment");
        let err = parse(&argv("run --level llc")).unwrap_err();
        assert_eq!(err.name(), "missing-flag");
        let err = parse(&argv("run --kernel jacobi2d --level bogus")).unwrap_err();
        assert_eq!(err.name(), "unknown-level");
        let err = parse(&argv("experiments --steps")).unwrap_err();
        assert_eq!(err.name(), "missing-value");
        let err = parse(&argv("kernels show")).unwrap_err();
        assert_eq!(err.name(), "missing-kernel-id");
    }

    #[test]
    fn parses_run() {
        let c = parse(&argv("run --kernel jacobi2d --level llc --steps 3")).unwrap();
        assert_eq!(
            c,
            Command::Run {
                kernel: "jacobi2d".to_string(),
                level: SizeClass::Llc,
                steps: 3,
                spu_threads: None,
                config: None,
                kernel_files: Vec::new(),
                trace: None,
                trace_interval: 1024,
                temporal_block: 1,
                epoch_rounds: None,
                plan: None,
            }
        );
    }

    #[test]
    fn parses_plan_flag() {
        match parse(&argv("run --kernel jacobi2d --level llc --plan greedy")).unwrap() {
            Command::Run { plan, .. } => assert_eq!(plan, Some(PlanStrategy::Greedy)),
            other => panic!("{other:?}"),
        }
        match parse(&argv("run --kernel jacobi2d --level llc --plan optimized")).unwrap() {
            Command::Run { plan, .. } => assert_eq!(plan, Some(PlanStrategy::Optimized)),
            other => panic!("{other:?}"),
        }
        // Default: engine decides (env CASPER_PLAN, else optimized).
        match parse(&argv("run --kernel jacobi2d --level llc")).unwrap() {
            Command::Run { plan, .. } => assert_eq!(plan, None),
            other => panic!("{other:?}"),
        }
        let err = parse(&argv("run --kernel jacobi2d --level llc --plan frobnicated")).unwrap_err();
        assert_eq!(err.name(), "unknown-plan");
        assert!(err.to_string().contains("greedy | optimized"), "{err}");
        // The flag belongs to `run` only.
        assert!(parse(&argv("experiments --plan greedy")).is_err());
    }

    #[test]
    fn parses_verify() {
        assert_eq!(
            parse(&argv("verify")).unwrap(),
            Command::Verify {
                specs: 64,
                seed: 0xCA5_9E12,
                steps: 2,
                out: PathBuf::from("verify-failure.toml"),
            }
        );
        assert_eq!(
            parse(&argv("verify --specs 8 --seed 7 --steps 1 --out min.toml")).unwrap(),
            Command::Verify { specs: 8, seed: 7, steps: 1, out: PathBuf::from("min.toml") }
        );
        assert_eq!(parse(&argv("verify --specs 0")).unwrap_err().name(), "bad-number");
        assert_eq!(parse(&argv("verify --seed x")).unwrap_err().name(), "bad-number");
        assert_eq!(parse(&argv("verify --plan greedy")).unwrap_err().name(), "unknown-flag");
    }

    #[test]
    fn parses_epoch_rounds_flag() {
        match parse(&argv("run --kernel jacobi2d --level llc --epoch-rounds 512")).unwrap() {
            Command::Run { epoch_rounds, .. } => assert_eq!(epoch_rounds, Some(512)),
            other => panic!("{other:?}"),
        }
        // Default: engine decides (env, else 2048).
        match parse(&argv("run --kernel jacobi2d --level llc")).unwrap() {
            Command::Run { epoch_rounds, .. } => assert_eq!(epoch_rounds, None),
            other => panic!("{other:?}"),
        }
        let err = parse(&argv("run --kernel jacobi2d --level llc --epoch-rounds 0")).unwrap_err();
        assert_eq!(err.name(), "bad-number");
        assert!(parse(&argv("run --kernel jacobi2d --level llc --epoch-rounds x")).is_err());
        // The flag belongs to `run` only.
        assert!(parse(&argv("experiments --epoch-rounds 64")).is_err());
    }

    #[test]
    fn parses_temporal_block_flag() {
        match parse(&argv("run --kernel jacobi2d --level llc --temporal-block 4")).unwrap() {
            Command::Run { temporal_block, .. } => assert_eq!(temporal_block, 4),
            other => panic!("{other:?}"),
        }
        match parse(&argv("experiments --temporal-block 2 --only blocked")).unwrap() {
            Command::Experiments { temporal_block, only, .. } => {
                assert_eq!(temporal_block, 2);
                assert_eq!(only, vec![Experiment::Blocked]);
            }
            other => panic!("{other:?}"),
        }
        // Default is 1 on both commands.
        match parse(&argv("experiments")).unwrap() {
            Command::Experiments { temporal_block, .. } => assert_eq!(temporal_block, 1),
            other => panic!("{other:?}"),
        }
        let err =
            parse(&argv("run --kernel jacobi2d --level llc --temporal-block 0")).unwrap_err();
        assert_eq!(err.name(), "bad-number");
        assert!(parse(&argv("experiments --temporal-block x")).is_err());
        // The flag belongs to run/experiments only.
        assert!(parse(&argv("kernels --temporal-block 2")).is_err());
    }

    #[test]
    fn parses_trace_flags() {
        match parse(&argv("run --kernel jacobi2d --level l2 --trace t.json")).unwrap() {
            Command::Run { trace, trace_interval, .. } => {
                assert_eq!(trace, Some(PathBuf::from("t.json")));
                assert_eq!(trace_interval, 1024, "default sampling interval");
            }
            other => panic!("{other:?}"),
        }
        match parse(&argv("run --kernel jacobi2d --level l2 --trace-interval 256")).unwrap() {
            Command::Run { trace_interval, .. } => assert_eq!(trace_interval, 256),
            other => panic!("{other:?}"),
        }
        let err = parse(&argv("run --kernel jacobi2d --level l2 --trace-interval 0")).unwrap_err();
        assert_eq!(err.name(), "bad-number");
        // `--trace` belongs to `run` only.
        assert!(parse(&argv("experiments --trace t.json")).is_err());
    }

    #[test]
    fn parses_telemetry_flags() {
        let c = parse(&argv(
            "experiments --events ev.jsonl --metrics-out summary.json --progress",
        ))
        .unwrap();
        match c {
            Command::Experiments { events, metrics_out, progress, .. } => {
                assert_eq!(events, Some(PathBuf::from("ev.jsonl")));
                assert_eq!(metrics_out, Some(PathBuf::from("summary.json")));
                assert!(progress);
            }
            other => panic!("{other:?}"),
        }
        match parse(&argv("experiments")).unwrap() {
            Command::Experiments { events, metrics_out, progress, .. } => {
                assert_eq!(events, None);
                assert_eq!(metrics_out, None);
                assert!(!progress);
            }
            other => panic!("{other:?}"),
        }
        // `--events` / `--progress` belong to `experiments` only.
        assert!(parse(&argv("run --kernel jacobi2d --level l2 --progress")).is_err());
    }

    #[test]
    fn run_requires_kernel_and_level() {
        assert!(parse(&argv("run --level llc")).is_err());
        assert!(parse(&argv("run --kernel jacobi2d")).is_err());
        // Unknown kernel ids now surface at dispatch time (the registry
        // may hold file-defined kernels the parser can't know about).
        assert!(parse(&argv("run --kernel anything --level llc")).is_ok());
        assert!(parse(&argv("run --kernel jacobi2d --level bogus")).is_err());
    }

    #[test]
    fn parses_kernel_files_and_extended_flag() {
        let c = parse(&argv(
            "experiments --kernel-file a.toml --extended-kernels --kernel-file b.toml --kernels hdiff,jacobi2d",
        ))
        .unwrap();
        match c {
            Command::Experiments { kernel_files, extended_kernels, kernels, .. } => {
                assert_eq!(kernel_files, vec![PathBuf::from("a.toml"), PathBuf::from("b.toml")]);
                assert!(extended_kernels);
                assert_eq!(kernels, Some(vec!["hdiff".to_string(), "jacobi2d".to_string()]));
            }
            other => panic!("{other:?}"),
        }
        match parse(&argv("run --kernel hdiff9 --level l2 --kernel-file k.toml")).unwrap() {
            Command::Run { kernel, kernel_files, .. } => {
                assert_eq!(kernel, "hdiff9");
                assert_eq!(kernel_files, vec![PathBuf::from("k.toml")]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_kernels_subcommands() {
        assert_eq!(
            parse(&argv("kernels list")).unwrap(),
            Command::Kernels { action: KernelsAction::List, kernel_files: Vec::new() }
        );
        assert_eq!(
            parse(&argv("kernels")).unwrap(),
            Command::Kernels { action: KernelsAction::List, kernel_files: Vec::new() }
        );
        assert_eq!(
            parse(&argv("kernels show hdiff --kernel-file x.toml")).unwrap(),
            Command::Kernels {
                action: KernelsAction::Show("hdiff".into()),
                kernel_files: vec![PathBuf::from("x.toml")],
            }
        );
        assert!(parse(&argv("kernels show")).is_err());
        assert!(parse(&argv("kernels frobnicate")).is_err());
    }

    #[test]
    fn parses_slices_experiment_id() {
        match parse(&argv("experiments --only slices")).unwrap() {
            Command::Experiments { only, .. } => assert_eq!(only, vec![Experiment::Slices]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_unknown_flags_and_commands() {
        assert!(parse(&argv("experiments --bogus x")).is_err());
        assert!(parse(&argv("frobnicate")).is_err());
        assert!(parse(&argv("experiments --only fig99")).is_err());
        assert!(parse(&argv("experiments --steps 0")).is_err());
        assert!(parse(&argv("kernels --extended-kernels")).is_err());
    }

    #[test]
    fn empty_and_help() {
        assert_eq!(parse(&[]).unwrap(), Command::Help);
        assert_eq!(parse(&argv("help")).unwrap(), Command::Help);
        assert_eq!(parse(&argv("run --help")).unwrap(), Command::Help);
    }

    #[test]
    fn build_registry_has_builtins() {
        let reg = build_registry(&[]).unwrap();
        assert!(reg.get("jacobi2d").is_some());
        assert!(reg.get("hdiff").is_some());
        assert!(build_registry(&[PathBuf::from("/nonexistent/k.toml")]).is_err());
    }
}
