//! Hand-rolled CLI (the offline registry has no `clap`; see DESIGN.md §3).
//!
//! ```text
//! casper experiments [--only fig10,table5] [--quick] [--steps N]
//!                    [--jobs N] [--out-dir DIR] [--config FILE]
//! casper run --kernel jacobi2d --level llc [--steps N] [--config FILE]
//! casper validate [--artifacts DIR]
//! casper roofline
//! casper info
//! casper help
//! ```

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::{SimConfig, SizeClass};
use crate::harness::Experiment;
use crate::stencil::StencilKind;

/// Parsed command line.
#[derive(Debug, Clone, PartialEq)]
pub enum Command {
    Experiments {
        only: Vec<Experiment>,
        quick: bool,
        steps: usize,
        /// Sweep worker threads (default: one per hardware thread).
        jobs: usize,
        /// Intra-run SPU worker threads per cell (`None` = engine default:
        /// serial, since the sweep already parallelizes across cells).
        spu_threads: Option<usize>,
        out_dir: Option<PathBuf>,
        config: Option<PathBuf>,
    },
    Run {
        kernel: StencilKind,
        level: SizeClass,
        steps: usize,
        /// Intra-run SPU worker threads (`None` = one per SPU).
        spu_threads: Option<usize>,
        config: Option<PathBuf>,
    },
    Validate {
        artifacts: Option<PathBuf>,
    },
    Roofline,
    Info,
    Help,
}

pub const USAGE: &str = "\
casper — near-cache stencil acceleration (full-system reproduction)

USAGE:
  casper experiments [--only IDs] [--quick] [--steps N] [--jobs N]
                     [--spu-threads N] [--out-dir DIR] [--config FILE]
      Regenerate the paper's tables/figures. IDs: fig1 fig10 fig11 fig12
      fig13 fig14 table4 table5 table6 (comma-separated; default all).
      --jobs N runs the sweep on N worker threads (default: all hardware
      threads; 1 = serial). --spu-threads N additionally parallelizes
      INSIDE each Casper cell (default 1 here — the sweep already fans
      out across cells). Reports are byte-identical at any combination.
  casper run --kernel NAME --level {l2|llc|dram} [--steps N]
             [--spu-threads N] [--config FILE]
      Run one stencil on Casper + all baselines and print the comparison.
      --spu-threads N runs the 16 SPUs epoch-parallel on N workers
      (default: one per SPU; 1 = the serial engine; identical results).
  casper validate [--artifacts DIR]
      Execute the AOT JAX/Pallas artifacts via PJRT and cross-check the
      simulator numerics (requires `make artifacts`).
  casper roofline
      Print the Fig 1 roofline data.
  casper info
      Print the Table 2 machine configuration.
  casper help
      This message.

KERNELS: jacobi1d pts7_1d jacobi2d blur2d heat3d pts33_3d
";

/// A tiny flag parser: `--key value` pairs plus boolean flags.
struct Args {
    positional: Vec<String>,
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let boolean = matches!(name, "quick" | "help");
                if boolean {
                    flags.push((name.to_string(), None));
                } else {
                    let v = argv
                        .get(i + 1)
                        .with_context(|| format!("--{name} requires a value"))?;
                    flags.push((name.to_string(), Some(v.clone())));
                    i += 1;
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { positional, flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .and_then(|(_, v)| v.as_deref())
    }

    fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|(n, _)| n == name)
    }

    fn reject_unknown(&self, allowed: &[&str]) -> Result<()> {
        for (n, _) in &self.flags {
            if !allowed.contains(&n.as_str()) {
                bail!("unknown flag --{n} (see `casper help`)");
            }
        }
        Ok(())
    }
}

/// Parse a full argv (without the binary name).
pub fn parse(argv: &[String]) -> Result<Command> {
    if argv.is_empty() {
        return Ok(Command::Help);
    }
    let cmd = argv[0].as_str();
    let rest = Args::parse(&argv[1..])?;
    if rest.has("help") {
        return Ok(Command::Help);
    }
    match cmd {
        "experiments" => {
            rest.reject_unknown(&["only", "quick", "steps", "jobs", "spu-threads", "out-dir", "config"])?;
            let only = match rest.get("only") {
                None => Experiment::ALL.to_vec(),
                Some(s) => s
                    .split(',')
                    .map(|id| {
                        Experiment::parse(id)
                            .with_context(|| format!("unknown experiment '{id}'"))
                    })
                    .collect::<Result<Vec<_>>>()?,
            };
            Ok(Command::Experiments {
                only,
                quick: rest.has("quick"),
                steps: parse_steps(&rest)?,
                jobs: parse_jobs(&rest)?,
                spu_threads: parse_spu_threads(&rest)?,
                out_dir: rest.get("out-dir").map(PathBuf::from),
                config: rest.get("config").map(PathBuf::from),
            })
        }
        "run" => {
            rest.reject_unknown(&["kernel", "level", "steps", "spu-threads", "config"])?;
            let kernel = rest
                .get("kernel")
                .context("run requires --kernel")
                .and_then(|s| StencilKind::parse(s).with_context(|| format!("unknown kernel '{s}'")))?;
            let level = rest
                .get("level")
                .context("run requires --level")
                .and_then(|s| SizeClass::parse(s).with_context(|| format!("unknown level '{s}'")))?;
            Ok(Command::Run {
                kernel,
                level,
                steps: parse_steps(&rest)?,
                spu_threads: parse_spu_threads(&rest)?,
                config: rest.get("config").map(PathBuf::from),
            })
        }
        "validate" => {
            rest.reject_unknown(&["artifacts"])?;
            Ok(Command::Validate { artifacts: rest.get("artifacts").map(PathBuf::from) })
        }
        "roofline" => {
            rest.reject_unknown(&[])?;
            Ok(Command::Roofline)
        }
        "info" => {
            rest.reject_unknown(&[])?;
            Ok(Command::Info)
        }
        "help" | "--help" | "-h" => Ok(Command::Help),
        other => bail!("unknown command '{other}' (see `casper help`)"),
    }
}

fn parse_steps(args: &Args) -> Result<usize> {
    match args.get("steps") {
        None => Ok(1),
        Some(s) => {
            let n: usize = s.parse().with_context(|| format!("bad --steps '{s}'"))?;
            anyhow::ensure!(n >= 1, "--steps must be >= 1");
            Ok(n)
        }
    }
}

fn parse_jobs(args: &Args) -> Result<usize> {
    match args.get("jobs") {
        None => Ok(crate::harness::sweep::auto_jobs()),
        Some(s) => {
            let n: usize = s.parse().with_context(|| format!("bad --jobs '{s}'"))?;
            anyhow::ensure!(n >= 1, "--jobs must be >= 1");
            Ok(n)
        }
    }
}

fn parse_spu_threads(args: &Args) -> Result<Option<usize>> {
    match args.get("spu-threads") {
        None => Ok(None),
        Some(s) => {
            let n: usize = s.parse().with_context(|| format!("bad --spu-threads '{s}'"))?;
            anyhow::ensure!(n >= 1, "--spu-threads must be >= 1");
            Ok(Some(n))
        }
    }
}

/// Load the config, with file override.
pub fn load_config(path: Option<&PathBuf>) -> Result<SimConfig> {
    match path {
        None => Ok(SimConfig::default()),
        Some(p) => SimConfig::from_file(p),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_experiments() {
        let c = parse(&argv("experiments --only fig10,table5 --quick --out-dir out")).unwrap();
        match c {
            Command::Experiments { only, quick, steps, jobs, out_dir, .. } => {
                assert_eq!(only, vec![Experiment::Fig10, Experiment::Table5]);
                assert!(quick);
                assert_eq!(steps, 1);
                assert!(jobs >= 1, "default --jobs is auto (>= 1)");
                assert_eq!(out_dir.unwrap().to_str().unwrap(), "out");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parses_jobs_flag() {
        match parse(&argv("experiments --jobs 4")).unwrap() {
            Command::Experiments { jobs, .. } => assert_eq!(jobs, 4),
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("experiments --jobs 0")).is_err());
        assert!(parse(&argv("experiments --jobs two")).is_err());
    }

    #[test]
    fn parses_spu_threads_flag() {
        match parse(&argv("experiments --spu-threads 16")).unwrap() {
            Command::Experiments { spu_threads, .. } => assert_eq!(spu_threads, Some(16)),
            other => panic!("{other:?}"),
        }
        match parse(&argv("experiments")).unwrap() {
            Command::Experiments { spu_threads, .. } => assert_eq!(spu_threads, None),
            other => panic!("{other:?}"),
        }
        match parse(&argv("run --kernel jacobi2d --level llc --spu-threads 1")).unwrap() {
            Command::Run { spu_threads, .. } => assert_eq!(spu_threads, Some(1)),
            other => panic!("{other:?}"),
        }
        assert!(parse(&argv("run --kernel jacobi2d --level llc --spu-threads 0")).is_err());
        assert!(parse(&argv("experiments --spu-threads x")).is_err());
    }

    #[test]
    fn parses_run() {
        let c = parse(&argv("run --kernel jacobi2d --level llc --steps 3")).unwrap();
        assert_eq!(
            c,
            Command::Run {
                kernel: StencilKind::Jacobi2D,
                level: SizeClass::Llc,
                steps: 3,
                spu_threads: None,
                config: None
            }
        );
    }

    #[test]
    fn run_requires_kernel_and_level() {
        assert!(parse(&argv("run --level llc")).is_err());
        assert!(parse(&argv("run --kernel jacobi2d")).is_err());
        assert!(parse(&argv("run --kernel bogus --level llc")).is_err());
    }

    #[test]
    fn rejects_unknown_flags_and_commands() {
        assert!(parse(&argv("experiments --bogus x")).is_err());
        assert!(parse(&argv("frobnicate")).is_err());
        assert!(parse(&argv("experiments --only fig99")).is_err());
        assert!(parse(&argv("experiments --steps 0")).is_err());
    }

    #[test]
    fn empty_and_help() {
        assert_eq!(parse(&[]).unwrap(), Command::Help);
        assert_eq!(parse(&argv("help")).unwrap(), Command::Help);
        assert_eq!(parse(&argv("run --help")).unwrap(), Command::Help);
    }
}
