//! JSONL sweep-telemetry sink (`casper experiments --events FILE`).
//!
//! One self-contained JSON object per line, in completion order: cell
//! lifecycle events (`scheduled` / `cached` / `started` / `retried` /
//! `timed-out` / `failed` / `finished` / `result`) stamped with
//! wall-clock milliseconds since the sink was opened. This is the
//! admission/monitoring stream the `casper serve` daemon (ROADMAP) will
//! forward to clients.
//!
//! Telemetry must never take a sweep down: write errors are swallowed
//! (the supervisor's own journal — `harness/journal.rs` — remains the
//! durable record). Lines are flushed per event so a crashed sweep keeps
//! every event it got to.

use super::chrome::escape;
use std::fs::File;
use std::io::Write;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Debug)]
struct EventLog {
    file: File,
    start: Instant,
}

/// Shared handle to an append-only JSONL event log. Cheap to clone (an
/// `Arc`), so it rides inside
/// [`SupervisorPolicy`](crate::harness::SupervisorPolicy) without
/// disturbing its `Clone`/`Debug` derives; writers serialize on an
/// internal mutex so concurrent sweep workers never interleave lines.
#[derive(Debug, Clone)]
pub struct EventSink {
    inner: Arc<Mutex<EventLog>>,
}

impl EventSink {
    /// Create (truncate) the event log at `path`.
    pub fn create(path: &Path) -> std::io::Result<EventSink> {
        let file = File::create(path)?;
        let log = EventLog { file, start: Instant::now() };
        Ok(EventSink { inner: Arc::new(Mutex::new(log)) })
    }

    /// Append one event line. `fields` were built by [`Event`]; the sink
    /// adds the leading timestamp.
    pub fn emit(&self, event: Event) {
        let mut log = match self.inner.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let ms = log.start.elapsed().as_secs_f64() * 1e3;
        let mut line = format!("{{\"ts_ms\":{ms:.3},\"event\":\"{}\"", escape(&event.kind));
        for part in &event.parts {
            line.push(',');
            line.push_str(part);
        }
        line.push('}');
        let _ = writeln!(log.file, "{line}");
        let _ = log.file.flush();
    }
}

/// Builder for one event line: a kind plus typed key/value fields.
#[derive(Debug)]
pub struct Event {
    kind: String,
    parts: Vec<String>,
}

impl Event {
    pub fn new(kind: &str) -> Event {
        Event { kind: kind.to_string(), parts: Vec::new() }
    }

    pub fn num(mut self, key: &str, v: u64) -> Event {
        self.parts.push(format!("\"{}\":{v}", escape(key)));
        self
    }

    /// Milliseconds (or any finite float) field; non-finite values are
    /// dropped rather than emitting invalid JSON.
    pub fn float(mut self, key: &str, v: f64) -> Event {
        if v.is_finite() {
            self.parts.push(format!("\"{}\":{v:.3}", escape(key)));
        }
        self
    }

    pub fn str(mut self, key: &str, v: &str) -> Event {
        self.parts.push(format!("\"{}\":\"{}\"", escape(key), escape(v)));
        self
    }

    /// A 16-hex-digit digest field (kept as a string: JSON numbers lose
    /// u64 precision past 2^53).
    pub fn digest(self, key: &str, v: u64) -> Event {
        let hex = format!("{v:016x}");
        self.str(key, &hex)
    }
}

#[cfg(test)]
mod tests {
    use super::super::chrome::validate_json;
    use super::*;

    #[test]
    fn events_are_one_valid_json_object_per_line() {
        let dir = std::env::temp_dir().join(format!("casper-events-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let sink = EventSink::create(&path).unwrap();
        sink.emit(Event::new("scheduled").num("cell", 3).str("kernel", "jacobi2d"));
        sink.emit(
            Event::new("finished")
                .num("cell", 3)
                .float("wall_ms", 12.5)
                .float("bogus", f64::NAN)
                .digest("digest", 0xdead_beef),
        );
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            validate_json(line).unwrap();
        }
        assert!(lines[0].contains("\"event\":\"scheduled\""));
        assert!(lines[0].contains("\"kernel\":\"jacobi2d\""));
        assert!(lines[1].contains("\"digest\":\"00000000deadbeef\""));
        assert!(!lines[1].contains("bogus"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clones_share_one_log() {
        let dir = std::env::temp_dir().join(format!("casper-events2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let sink = EventSink::create(&path).unwrap();
        let clone = sink.clone();
        sink.emit(Event::new("a"));
        clone.emit(Event::new("b"));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
