//! Chrome-trace-event JSON emission (loadable in `chrome://tracing` and
//! Perfetto), plus the minimal JSON validator the tests and CI lean on.
//!
//! Layout of the emitted trace:
//!
//! - **pid 1 — "casper (cycle domain)"**: timestamps are *simulated
//!   cycles*, not microseconds (load the trace knowing 1 "µs" = 1 cycle).
//!   - tid 1: one `X` span per accelerator pass per step;
//!   - tid 100+i: one `X` span per SPU *i* per step × pass (its busy
//!     interval);
//!   - `C` counter samples per bucket: per-slice LLC bandwidth (each
//!     series scaled so the stacked sum reads as % of the aggregate port
//!     peak), LLC hit rate, per-channel DRAM bytes, DRAM queue-wait
//!     cycles, NoC messages + contention.
//! - **pid 2 — "casper host (wall clock)"**: real-microsecond spans for
//!   the epoch engine's three stages, one triple per epoch. Absent under
//!   the serial engine.
//!   - tid 0: the functional side (functional fan-out + tag reconcile);
//!   - tid 1: the timing replay. Separate rows, because under the
//!     pipelined engine epoch *e*'s replay overlaps epoch *e+1*'s
//!     fan-out — the overlap shows as concurrent spans on the two rows.

use super::{Span, Tracer};
use std::io::{self, Write};

/// Escape a string for inclusion in a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Incremental `traceEvents` array writer: tracks the comma state so each
/// event is emitted as one self-contained JSON object per line (which
/// keeps the file `jq`-friendly).
struct Events<'a, W: Write> {
    w: &'a mut W,
    first: bool,
}

impl<W: Write> Events<'_, W> {
    fn emit(&mut self, body: &str) -> io::Result<()> {
        if self.first {
            self.first = false;
            writeln!(self.w)?;
        } else {
            writeln!(self.w, ",")?;
        }
        write!(self.w, "{{{body}}}")
    }
}

fn meta_process(ev: &mut Events<impl Write>, pid: u32, name: &str) -> io::Result<()> {
    ev.emit(&format!(
        "\"ph\":\"M\",\"pid\":{pid},\"tid\":0,\"name\":\"process_name\",\
         \"args\":{{\"name\":\"{}\"}}",
        escape(name)
    ))
}

fn meta_thread(ev: &mut Events<impl Write>, pid: u32, tid: u32, name: &str) -> io::Result<()> {
    ev.emit(&format!(
        "\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"thread_name\",\
         \"args\":{{\"name\":\"{}\"}}",
        escape(name)
    ))
}

fn span_event(
    ev: &mut Events<impl Write>,
    pid: u32,
    tid: u32,
    cat: &str,
    name: &str,
    start: u64,
    end: u64,
) -> io::Result<()> {
    ev.emit(&format!(
        "\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"ts\":{start},\"dur\":{},\
         \"cat\":\"{cat}\",\"name\":\"{}\"",
        end.saturating_sub(start),
        escape(name)
    ))
}

fn counter_event(
    ev: &mut Events<impl Write>,
    name: &str,
    ts: u64,
    series: &[(String, String)],
) -> io::Result<()> {
    let args: Vec<String> = series.iter().map(|(k, v)| format!("\"{k}\":{v}")).collect();
    ev.emit(&format!(
        "\"ph\":\"C\",\"pid\":1,\"tid\":0,\"ts\":{ts},\"name\":\"{}\",\"args\":{{{}}}",
        escape(name),
        args.join(",")
    ))
}

fn pct(num: f64, den: f64) -> String {
    if den > 0.0 {
        format!("{:.3}", 100.0 * num / den)
    } else {
        "0".to_string()
    }
}

impl Tracer {
    /// Serialize the recorded trace as Chrome-trace-event JSON.
    pub fn write_chrome<W: Write>(&self, w: &mut W) -> io::Result<()> {
        write!(w, "{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
        let mut ev = Events { w, first: true };

        meta_process(&mut ev, 1, "casper (cycle domain)")?;
        meta_process(&mut ev, 2, "casper host (wall clock)")?;
        meta_thread(&mut ev, 1, 1, "passes")?;
        let mut spus: Vec<usize> = self.spu_spans().iter().map(|&(s, _)| s).collect();
        spus.sort_unstable();
        spus.dedup();
        for spu in spus {
            meta_thread(&mut ev, 1, 100 + spu as u32, &format!("spu {spu}"))?;
        }
        if !self.epochs().is_empty() {
            meta_thread(&mut ev, 2, 0, "epoch fan-out + reconcile")?;
            meta_thread(&mut ev, 2, 1, "epoch replay worker")?;
        }

        for &Span { step, pass, start, end } in self.pass_spans() {
            span_event(&mut ev, 1, 1, "pass", &format!("step {step} pass {pass}"), start, end)?;
        }
        for &(spu, Span { step, pass, start, end }) in self.spu_spans() {
            let name = format!("s{step}p{pass}");
            span_event(&mut ev, 1, 100 + spu as u32, "spu", &name, start, end)?;
        }
        for (i, ep) in self.epochs().iter().enumerate() {
            for (k, (name, ph)) in
                ["functional", "reconcile", "replay"].iter().zip(ep.phases.iter()).enumerate()
            {
                // Replay rides its own row (tid 1): under the pipelined
                // engine it belongs to the replay worker and overlaps the
                // next epoch's tid-0 spans in wall-clock time.
                let tid = if k == 2 { 1 } else { 0 };
                span_event(&mut ev, 2, tid, "epoch", &format!("{name} (epoch {i})"), ph[0], ph[1])?;
            }
        }

        let interval = self.interval();
        let slice_peak = interval as f64 * self.slice_peak_bytes_per_cycle();
        let agg_peak = slice_peak * self.slice_count() as f64;
        for (i, b) in self.buckets().iter().enumerate() {
            let ts = i as u64 * interval;
            // Per-slice bandwidth, each series as % of the *aggregate*
            // peak so the stacked counter sums to total utilization.
            let bw: Vec<(String, String)> = (0..self.slice_count())
                .map(|s| (format!("s{s}"), pct(b.slice_bytes[s] as f64, agg_peak)))
                .collect();
            counter_event(&mut ev, "llc bw (% of peak)", ts, &bw)?;

            let probes = b.slice_hits.iter().sum::<u64>() + b.slice_misses.iter().sum::<u64>();
            if probes > 0 {
                let hits = b.slice_hits.iter().sum::<u64>() as f64;
                counter_event(
                    &mut ev,
                    "llc hit rate (%)",
                    ts,
                    &[("hit".to_string(), pct(hits, probes as f64))],
                )?;
            }

            // Temporal blocking: probes served by wavefront residency
            // (avoided DRAM fills). Only emitted when present, so T=1
            // traces keep their historical track set.
            let avoided = b.slice_avoided.iter().sum::<u64>();
            if avoided > 0 {
                counter_event(
                    &mut ev,
                    "llc avoided fills",
                    ts,
                    &[("avoided".to_string(), avoided.to_string())],
                )?;
            }

            let dram: Vec<(String, String)> = (0..self.channel_count())
                .map(|c| (format!("d{c}"), b.chan_bytes[c].to_string()))
                .collect();
            counter_event(&mut ev, "dram bytes", ts, &dram)?;
            counter_event(
                &mut ev,
                "dram queue wait (cycles)",
                ts,
                &[("wait".to_string(), b.dram_queue_cycles.to_string())],
            )?;
            counter_event(
                &mut ev,
                "noc",
                ts,
                &[
                    ("messages".to_string(), b.noc_messages.to_string()),
                    ("contention".to_string(), b.noc_contention_cycles.to_string()),
                ],
            )?;
        }

        writeln!(ev.w)?;
        write!(
            ev.w,
            "],\"otherData\":{{\"interval_cycles\":{},\"samples\":{},\"clipped\":{}}}}}",
            interval,
            self.samples(),
            self.clipped()
        )?;
        writeln!(ev.w)
    }

    /// Convenience for tests: the Chrome trace as a `String`.
    pub fn to_chrome_string(&self) -> String {
        let mut buf = Vec::new();
        self.write_chrome(&mut buf).expect("write to Vec cannot fail");
        String::from_utf8(buf).expect("trace JSON is UTF-8")
    }
}

/// Validate that `s` is exactly one well-formed JSON value (minimal
/// recursive-descent check — structure only, no number-range pedantry).
/// Used by the trace/events tests; CI re-checks the real files with
/// `python3 -m json.tool` and `jq`.
pub fn validate_json(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    skip_ws(b, &mut pos);
    value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    if pos != b.len() {
        return Err(format!("trailing garbage at byte {pos}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn value(b: &[u8], pos: &mut usize) -> Result<(), String> {
    match b.get(*pos) {
        Some(b'{') => object(b, pos),
        Some(b'[') => array(b, pos),
        Some(b'"') => string(b, pos),
        Some(b't') => literal(b, pos, b"true"),
        Some(b'f') => literal(b, pos, b"false"),
        Some(b'n') => literal(b, pos, b"null"),
        Some(c) if c.is_ascii_digit() || *c == b'-' => number(b, pos),
        Some(c) => Err(format!("unexpected byte '{}' at {pos:?}", *c as char)),
        None => Err("unexpected end of input".to_string()),
    }
}

fn literal(b: &[u8], pos: &mut usize, lit: &[u8]) -> Result<(), String> {
    if b.len() >= *pos + lit.len() && &b[*pos..*pos + lit.len()] == lit {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at byte {pos:?}"))
    }
}

fn number(b: &[u8], pos: &mut usize) -> Result<(), String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let digits = |b: &[u8], pos: &mut usize| {
        let s = *pos;
        while *pos < b.len() && b[*pos].is_ascii_digit() {
            *pos += 1;
        }
        *pos > s
    };
    if !digits(b, pos) {
        return Err(format!("bad number at byte {start}"));
    }
    if b.get(*pos) == Some(&b'.') {
        *pos += 1;
        if !digits(b, pos) {
            return Err(format!("bad fraction at byte {start}"));
        }
    }
    if matches!(b.get(*pos), Some(b'e') | Some(b'E')) {
        *pos += 1;
        if matches!(b.get(*pos), Some(b'+') | Some(b'-')) {
            *pos += 1;
        }
        if !digits(b, pos) {
            return Err(format!("bad exponent at byte {start}"));
        }
    }
    Ok(())
}

fn string(b: &[u8], pos: &mut usize) -> Result<(), String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'"' => {
                *pos += 1;
                return Ok(());
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't') => *pos += 1,
                    Some(b'u') => {
                        let hex = b.get(*pos + 1..*pos + 5);
                        if !hex.is_some_and(|h| h.iter().all(u8::is_ascii_hexdigit)) {
                            return Err(format!("bad \\u escape at byte {pos:?}"));
                        }
                        *pos += 5;
                    }
                    _ => return Err(format!("bad escape at byte {pos:?}")),
                }
            }
            c if c < 0x20 => return Err(format!("raw control byte in string at {pos:?}")),
            _ => *pos += 1,
        }
    }
    Err("unterminated string".to_string())
}

fn object(b: &[u8], pos: &mut usize) -> Result<(), String> {
    *pos += 1; // '{'
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b'"') {
            return Err(format!("expected object key at byte {pos:?}"));
        }
        string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {pos:?}"));
        }
        *pos += 1;
        skip_ws(b, pos);
        value(b, pos)?;
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or '}}' at byte {pos:?}")),
        }
    }
}

fn array(b: &[u8], pos: &mut usize) -> Result<(), String> {
    *pos += 1; // '['
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(());
    }
    loop {
        skip_ws(b, pos);
        value(b, pos)?;
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or ']' at byte {pos:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{EpochPhases, TraceSink, Tracer};
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn validator_accepts_and_rejects() {
        for ok in [
            "{}",
            "[]",
            "null",
            "-1.5e-3",
            "\"a\\nb\\u00ff\"",
            "{\"a\":[1,2,{\"b\":true}],\"c\":null}",
            " [ 1 , 2 ] ",
        ] {
            assert!(validate_json(ok).is_ok(), "{ok}");
        }
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{'a':1}",
            "NaN",
            "1 2",
            "{\"a\":1,}",
            "\"unterminated",
        ] {
            assert!(validate_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn escape_handles_specials() {
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert!(validate_json(&format!("\"{}\"", escape("x\u{1}\ty"))).is_ok());
    }

    #[test]
    fn emitted_trace_is_valid_json_with_expected_tracks() {
        let mut t = Tracer::new(&SimConfig::default(), 64);
        t.slice_request(0, 10, 3, 1, 0, &[64, 4096], 12, false);
        t.slice_request(15, 70, 0, 1, 2, &[128], 0, true);
        t.pass_span(0, 0, 0, 120);
        t.spu_span(0, 0, 0, 5, 90);
        t.spu_span(15, 0, 0, 8, 110);
        t.epoch_phases(EpochPhases { phases: [[0, 40], [40, 55], [55, 200]] });
        let json = t.to_chrome_string();
        validate_json(&json).unwrap();
        assert!(json.contains("\"process_name\""));
        assert!(json.contains("spu 15"));
        assert!(json.contains("step 0 pass 0"));
        assert!(json.contains("llc bw (% of peak)"));
        assert!(json.contains("llc avoided fills"));
        assert!(json.contains("functional (epoch 0)"));
        // The replay span rides the dedicated worker row (pid 2, tid 1),
        // so pipelined overlap renders as concurrent spans on two rows.
        assert!(json.contains("epoch replay worker"));
        assert!(json.contains(
            "\"ph\":\"X\",\"pid\":2,\"tid\":1,\"ts\":55,\"dur\":145,\
             \"cat\":\"epoch\",\"name\":\"replay (epoch 0)\""
        ));
        assert!(json.contains("\"interval_cycles\":64"));
    }

    #[test]
    fn empty_trace_is_still_valid_json() {
        let t = Tracer::new(&SimConfig::default(), 1024);
        validate_json(&t.to_chrome_string()).unwrap();
    }
}
