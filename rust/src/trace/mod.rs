//! Cycle-domain tracing and interval-sampled telemetry.
//!
//! The simulator's headline claim — stencils running "at the peak bandwidth
//! of the LLC" — was previously visible only as end-of-run aggregates in
//! [`RunStats`](crate::coordinator::RunStats). This module renders it as
//! data over *time*: a [`Tracer`] threaded through the memory system
//! ([`ShardedMem`](crate::spu::ShardedMem)) and both engines records
//!
//! - **interval-sampled time series** (bucketed counters every
//!   `--trace-interval` cycles): per-slice LLC bandwidth utilization, LLC
//!   hit rate, per-channel DRAM bytes, DRAM queue waiting, NoC traffic and
//!   contention;
//! - **spans**: one track per SPU (busy interval per step × pass), a pass
//!   track (multi-pass kernels from PR 5 show their per-pass timing), and
//!   wall-clock spans for the epoch engine's three phases;
//!
//! and emits them as Chrome-trace-event JSON ([`chrome`]) loadable in
//! `chrome://tracing` / Perfetto.
//!
//! # Sampling model: bucket attribution
//!
//! The simulator is timestamp-driven — there is no global cycle loop to
//! sample from, and request timestamps are *not* monotonic across SPUs. So
//! the tracer never "samples at cycle T"; instead every observed request
//! adds its contribution to the bucket `t / interval` of its port-claim
//! cycle. Addition commutes, and both engines issue the identical request
//! set at identical cycles, so the bucket contents are engine-identical by
//! construction. Buckets are capped at [`MAX_BUCKETS`]; anything beyond
//! folds into the last bucket (and the trace records that it clipped).
//!
//! # Zero cost when off
//!
//! The tracer lives as an `Option<Box<Tracer>>` on `ShardedMem`; every
//! hook site is a single `if let Some(..)` on that option after the
//! request's normal accounting, and **no hook ever feeds back into
//! timing** — tracing on or off, `RunStats::digest` is byte-identical
//! (pinned by tests in `coordinator/engine.rs` and by CI).

pub mod chrome;
pub mod events;

pub use events::{Event, EventSink};

use crate::config::SimConfig;
use std::time::Instant;

/// Hard cap on the number of sample buckets a trace will hold (2^16).
/// With the default `--trace-interval 1024` this covers runs of 67M
/// cycles; longer tails fold into the final bucket rather than growing
/// without bound.
pub const MAX_BUCKETS: usize = 1 << 16;

/// Cap on recorded span counts (per span kind) — bounds trace size on
/// pathological step counts without perturbing the simulation.
const MAX_SPANS: usize = 1 << 16;

/// One sampling interval's worth of accumulated counters.
#[derive(Debug, Clone)]
pub struct Bucket {
    /// Data bytes granted by each slice's LLC port (64 B per grant).
    pub slice_bytes: Vec<u64>,
    /// Tag-probe hits per slice.
    pub slice_hits: Vec<u64>,
    /// Tag-probe misses per slice.
    pub slice_misses: Vec<u64>,
    /// Tag probes served by temporal-block wavefront residency — each one
    /// a potential DRAM line fill the blocked schedule avoided.
    pub slice_avoided: Vec<u64>,
    /// Bytes moved per DRAM channel (miss fills + dirty writebacks).
    pub chan_bytes: Vec<u64>,
    /// DRAM channel-queue waiting cycles accrued by requests in this bucket.
    pub dram_queue_cycles: u64,
    /// NoC messages injected (remote request/response pairs + leader hops).
    pub noc_messages: u64,
    /// NoC contention cycles accrued by leader aggregation in this bucket.
    pub noc_contention_cycles: u64,
}

impl Bucket {
    fn new(slices: usize, channels: usize) -> Bucket {
        Bucket {
            slice_bytes: vec![0; slices],
            slice_hits: vec![0; slices],
            slice_misses: vec![0; slices],
            slice_avoided: vec![0; slices],
            chan_bytes: vec![0; channels],
            dram_queue_cycles: 0,
            noc_messages: 0,
            noc_contention_cycles: 0,
        }
    }
}

/// A closed cycle-domain interval attributed to a pass or an SPU.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub step: usize,
    pub pass: usize,
    pub start: u64,
    pub end: u64,
}

/// Wall-clock timing of one epoch of the parallel engine: `[start_us,
/// end_us]` offsets from the trace origin for each of the three phases
/// (functional fan-out, tag reconciliation, timing replay).
#[derive(Debug, Clone, Copy)]
pub struct EpochPhases {
    pub phases: [[u64; 2]; 3],
}

/// Observation hooks the memory system and engines call while tracing.
///
/// The trait exists to document the observation surface in one place:
/// every method is *write-only* from the simulator's point of view — a
/// sink never returns data into the caller, so it cannot perturb timing.
/// [`Tracer`] is the one in-tree implementation.
pub trait TraceSink {
    /// One LLC slice request (load or store), observed at its port-claim
    /// cycle `start`: `hits`/`misses` tag probes, `avoided` probes served
    /// by temporal-block wavefront residency (avoided fills), up to four
    /// DRAM line transfers in `dram_lines`, `queue_delta` DRAM queue-wait
    /// cycles, and whether the request arrived over the NoC (`remote`).
    fn slice_request(
        &mut self,
        slice: usize,
        start: u64,
        hits: u32,
        misses: u32,
        avoided: u32,
        dram_lines: &[u64],
        queue_delta: u64,
        remote: bool,
    );

    /// Leader-aggregation NoC traffic at cycle `at`: `messages` sends and
    /// `contention_delta` link-contention cycles.
    fn noc_leader(&mut self, at: u64, messages: u64, contention_delta: u64);

    /// One completed accelerator pass of one time step, in cycles.
    fn pass_span(&mut self, step: usize, pass: usize, start: u64, end: u64);

    /// One SPU's busy interval for one step × pass, in cycles.
    fn spu_span(&mut self, spu: usize, step: usize, pass: usize, start: u64, end: u64);

    /// Wall-clock phase timing of one epoch (parallel engine only).
    fn epoch_phases(&mut self, phases: EpochPhases);
}

/// The concrete trace recorder. Constructed by the CLI (`--trace`),
/// installed into `ShardedMem` by
/// [`run_casper_spec_traced`](crate::coordinator::run_casper_spec_traced)
/// after warm-up, and returned to the caller for serialization.
///
/// Contains only plain owned data (`Vec`s, integers, an `Instant`), so a
/// `ShardedMem` holding one stays `Send + Sync` for the epoch engine's
/// scoped-thread fan-out (which only ever reads `&ShardedMem`).
#[derive(Debug)]
pub struct Tracer {
    interval: u64,
    slices: usize,
    channels: usize,
    line_bytes: u64,
    buckets: Vec<Bucket>,
    pass_spans: Vec<Span>,
    spu_spans: Vec<(usize, Span)>,
    epochs: Vec<EpochPhases>,
    origin: Instant,
    clipped: bool,
}

impl Tracer {
    /// Create a tracer sampling every `interval` cycles (clamped to ≥ 1 —
    /// the CLI accepts `--trace-interval 0` and we refuse to divide by it).
    pub fn new(cfg: &SimConfig, interval: u64) -> Tracer {
        Tracer {
            interval: interval.max(1),
            slices: cfg.llc.slices,
            channels: cfg.dram.channels,
            line_bytes: cfg.llc.line_bytes as u64,
            buckets: Vec::new(),
            pass_spans: Vec::new(),
            spu_spans: Vec::new(),
            epochs: Vec::new(),
            origin: Instant::now(),
            clipped: false,
        }
    }

    /// The sampling interval in cycles (post-clamp).
    pub fn interval(&self) -> u64 {
        self.interval
    }

    /// Wall-clock origin of this trace; epoch-phase offsets are measured
    /// from it.
    pub fn origin(&self) -> Instant {
        self.origin
    }

    /// Number of sample buckets recorded so far.
    pub fn samples(&self) -> usize {
        self.buckets.len()
    }

    /// Whether the run outran [`MAX_BUCKETS`] and folded its tail.
    pub fn clipped(&self) -> bool {
        self.clipped
    }

    pub(crate) fn buckets(&self) -> &[Bucket] {
        &self.buckets
    }

    pub(crate) fn pass_spans(&self) -> &[Span] {
        &self.pass_spans
    }

    pub(crate) fn spu_spans(&self) -> &[(usize, Span)] {
        &self.spu_spans
    }

    pub(crate) fn epochs(&self) -> &[EpochPhases] {
        &self.epochs
    }

    pub(crate) fn slice_count(&self) -> usize {
        self.slices
    }

    pub(crate) fn channel_count(&self) -> usize {
        self.channels
    }

    /// Peak data bandwidth of one slice port in bytes/cycle: one grant
    /// per cycle, one line per grant.
    pub fn slice_peak_bytes_per_cycle(&self) -> f64 {
        self.line_bytes as f64
    }

    /// Which DRAM channel serves `addr` — mirrors
    /// `DramModel::channel_of` (line-interleaved across channels).
    fn channel_of(&self, addr: u64) -> usize {
        ((addr / self.line_bytes) % self.channels as u64) as usize
    }

    fn bucket_at(&mut self, t: u64) -> &mut Bucket {
        let mut idx = (t / self.interval) as usize;
        if idx >= MAX_BUCKETS {
            idx = MAX_BUCKETS - 1;
            self.clipped = true;
        }
        if idx >= self.buckets.len() {
            let template = Bucket::new(self.slices, self.channels);
            self.buckets.resize(idx + 1, template);
        }
        &mut self.buckets[idx]
    }

    /// Aggregate LLC bandwidth utilization per bucket, as a fraction of
    /// the aggregate port peak (`slices × line_bytes` bytes/cycle). The
    /// final bucket may cover fewer than `interval` live cycles and
    /// therefore undercounts — callers that report a mean should know.
    pub fn llc_utilization(&self) -> Vec<f64> {
        let peak = self.interval as f64 * self.slices as f64 * self.line_bytes as f64;
        self.buckets
            .iter()
            .map(|b| b.slice_bytes.iter().sum::<u64>() as f64 / peak)
            .collect()
    }

    /// `(peak, mean)` aggregate LLC bandwidth utilization over all
    /// buckets, or `None` if nothing was recorded.
    pub fn llc_utilization_peak_mean(&self) -> Option<(f64, f64)> {
        let u = self.llc_utilization();
        if u.is_empty() {
            return None;
        }
        let peak = u.iter().cloned().fold(0.0f64, f64::max);
        let mean = u.iter().sum::<f64>() / u.len() as f64;
        Some((peak, mean))
    }

    /// Index of the busiest bucket (by aggregate LLC bytes), if any.
    pub fn peak_bucket(&self) -> Option<usize> {
        (0..self.buckets.len()).max_by_key(|&i| self.buckets[i].slice_bytes.iter().sum::<u64>())
    }

    /// Total DRAM line transfers recorded across all buckets (miss fills
    /// plus dirty writebacks) — the traffic a `--temporal-block` run
    /// shrinks; the CI blocked-vs-unblocked assertion compares this.
    pub fn dram_lines_total(&self) -> u64 {
        let bytes: u64 =
            self.buckets.iter().map(|b| b.chan_bytes.iter().sum::<u64>()).sum();
        bytes / self.line_bytes
    }

    /// Total tag probes served by wavefront residency (avoided fills)
    /// across all buckets.
    pub fn avoided_total(&self) -> u64 {
        self.buckets.iter().map(|b| b.slice_avoided.iter().sum::<u64>()).sum()
    }
}

impl TraceSink for Tracer {
    fn slice_request(
        &mut self,
        slice: usize,
        start: u64,
        hits: u32,
        misses: u32,
        avoided: u32,
        dram_lines: &[u64],
        queue_delta: u64,
        remote: bool,
    ) {
        // Resolve channels before borrowing the bucket mutably.
        let mut chans = [0usize; 4];
        let n = dram_lines.len().min(4);
        for (c, &line) in chans.iter_mut().zip(dram_lines.iter()) {
            *c = self.channel_of(line);
        }
        let line_bytes = self.line_bytes;
        let b = self.bucket_at(start);
        b.slice_bytes[slice] += line_bytes;
        b.slice_hits[slice] += hits as u64;
        b.slice_misses[slice] += misses as u64;
        b.slice_avoided[slice] += avoided as u64;
        for &c in &chans[..n] {
            b.chan_bytes[c] += line_bytes;
        }
        b.dram_queue_cycles += queue_delta;
        if remote {
            // Request + response message pair over the mesh.
            b.noc_messages += 2;
        }
    }

    fn noc_leader(&mut self, at: u64, messages: u64, contention_delta: u64) {
        let b = self.bucket_at(at);
        b.noc_messages += messages;
        b.noc_contention_cycles += contention_delta;
    }

    fn pass_span(&mut self, step: usize, pass: usize, start: u64, end: u64) {
        if self.pass_spans.len() < MAX_SPANS {
            self.pass_spans.push(Span { step, pass, start, end });
        }
    }

    fn spu_span(&mut self, spu: usize, step: usize, pass: usize, start: u64, end: u64) {
        if self.spu_spans.len() < MAX_SPANS {
            self.spu_spans.push((spu, Span { step, pass, start, end }));
        }
    }

    fn epoch_phases(&mut self, phases: EpochPhases) {
        if self.epochs.len() < MAX_SPANS {
            self.epochs.push(phases);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tracer(interval: u64) -> Tracer {
        Tracer::new(&SimConfig::default(), interval)
    }

    #[test]
    fn interval_is_clamped_to_one() {
        assert_eq!(tracer(0).interval(), 1);
        assert_eq!(tracer(1024).interval(), 1024);
    }

    #[test]
    fn requests_land_in_their_cycle_bucket() {
        let mut t = tracer(100);
        t.slice_request(3, 0, 2, 1, 0, &[64], 5, false);
        t.slice_request(3, 99, 1, 0, 1, &[], 0, true);
        t.slice_request(7, 100, 0, 1, 0, &[128, 192], 7, false);
        assert_eq!(t.samples(), 2);
        let b0 = &t.buckets()[0];
        assert_eq!(b0.slice_bytes[3], 128); // two 64 B grants
        assert_eq!(b0.slice_hits[3], 3);
        assert_eq!(b0.slice_misses[3], 1);
        assert_eq!(b0.slice_avoided[3], 1);
        assert_eq!(b0.dram_queue_cycles, 5);
        assert_eq!(b0.noc_messages, 2); // one remote request
        let b1 = &t.buckets()[1];
        assert_eq!(b1.slice_bytes[7], 64);
        assert_eq!(b1.chan_bytes.iter().sum::<u64>(), 128);
        assert_eq!(t.avoided_total(), 1);
        assert_eq!(t.dram_lines_total(), 3);
        assert!(!t.clipped());
    }

    #[test]
    fn channel_attribution_is_line_interleaved() {
        let mut t = tracer(10);
        // Lines 0..4 hit channels 0..4 in order (64 B lines, 4 channels).
        t.slice_request(0, 0, 0, 4, 0, &[0, 64, 128, 192], 0, false);
        let b = &t.buckets()[0];
        assert_eq!(b.chan_bytes, vec![64, 64, 64, 64]);
    }

    #[test]
    fn tail_folds_into_last_bucket() {
        let mut t = tracer(1);
        t.slice_request(0, (MAX_BUCKETS as u64) + 5, 1, 0, 0, &[], 0, false);
        assert!(t.clipped());
        assert_eq!(t.samples(), MAX_BUCKETS);
        assert_eq!(t.buckets()[MAX_BUCKETS - 1].slice_bytes[0], 64);
    }

    #[test]
    fn utilization_reflects_port_peak() {
        let mut t = tracer(2);
        // Two grants on one slice in a 2-cycle bucket = that slice fully
        // busy = 1/16 of aggregate peak.
        t.slice_request(5, 0, 1, 0, 0, &[], 0, false);
        t.slice_request(5, 1, 1, 0, 0, &[], 0, false);
        let u = t.llc_utilization();
        assert_eq!(u.len(), 1);
        assert!((u[0] - 1.0 / 16.0).abs() < 1e-12);
        let (peak, mean) = t.llc_utilization_peak_mean().unwrap();
        assert_eq!(peak, mean);
        assert_eq!(t.peak_bucket(), Some(0));
    }

    #[test]
    fn spans_are_recorded_in_order() {
        let mut t = tracer(1024);
        t.pass_span(0, 0, 0, 500);
        t.pass_span(0, 1, 500, 900);
        t.spu_span(4, 0, 0, 10, 480);
        t.epoch_phases(EpochPhases { phases: [[0, 5], [5, 9], [9, 30]] });
        assert_eq!(t.pass_spans().len(), 2);
        assert_eq!(t.pass_spans()[1].pass, 1);
        assert_eq!(t.spu_spans()[0].0, 4);
        assert_eq!(t.epochs()[0].phases[2], [9, 30]);
    }
}
