//! 15-bit Casper instruction: encoding, decoding, and field semantics —
//! plus the bit-15 *reduce* extension flag (fused stencil–reduction).

use anyhow::{bail, Result};

/// Reduction operator of a fused stencil–reduction pass: the per-SPU
/// accumulator folds every output element it streams, and the leader
/// combines the partials in deterministic `(round, spu, seq)` order —
/// architecturally equal to a fold over the output array in ascending
/// linear element order, which is exactly how the coordinator (and the
/// golden two-pass oracle) computes it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    /// Σ out[i] — plain sum of the streamed output.
    Sum = 1,
    /// Σ |out[i] − in[i]| — the Jacobi residual norm (L1) between the
    /// pass's output and its center input.
    AbsDiff = 2,
    /// max out[i] — running maximum of the streamed output.
    Max = 3,
}

impl ReduceOp {
    pub const ALL: [ReduceOp; 3] = [ReduceOp::Sum, ReduceOp::AbsDiff, ReduceOp::Max];

    /// TOML / CLI spelling.
    pub fn name(self) -> &'static str {
        match self {
            ReduceOp::Sum => "sum",
            ReduceOp::AbsDiff => "abs_diff",
            ReduceOp::Max => "max",
        }
    }

    pub fn parse(s: &str) -> Option<ReduceOp> {
        match s {
            "sum" => Some(ReduceOp::Sum),
            "abs_diff" => Some(ReduceOp::AbsDiff),
            "max" => Some(ReduceOp::Max),
            _ => None,
        }
    }

    /// Stable wire/journal discriminant (1-based; 0 is "no reduction").
    pub fn discriminant(self) -> u64 {
        self as u64
    }

    pub fn from_discriminant(d: u64) -> Option<ReduceOp> {
        match d {
            1 => Some(ReduceOp::Sum),
            2 => Some(ReduceOp::AbsDiff),
            3 => Some(ReduceOp::Max),
            _ => None,
        }
    }
}

impl std::fmt::Display for ReduceOp {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Shift direction for unaligned stream accesses (Fig 7 / Fig 9).
///
/// `Right` accesses *lower* addresses (`A[i - amount]`), `Left` accesses
/// *higher* addresses (`A[i + amount]`) — matching the paper's Fig 9
/// comments (`shift right by 1` loads `A[j][i-1]`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShiftDir {
    /// Toward higher addresses (`A[i + amount]`).
    Left = 0,
    /// Toward lower addresses (`A[i - amount]`).
    Right = 1,
}

/// One decoded Casper instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CasperInstr {
    /// Constant-buffer index (4 bits).
    pub const_idx: u8,
    /// Stream-buffer index (4 bits).
    pub stream_idx: u8,
    /// Shift direction (1 bit); meaningful when `shift_amount > 0`.
    pub shift_dir: ShiftDir,
    /// Shift amount in elements (3 bits, 0–7).
    pub shift_amount: u8,
    /// Control: reset the accumulator before this MAC.
    pub clear_acc: bool,
    /// Control: store the accumulator to the output stream after this MAC.
    pub enable_output: bool,
    /// Control: advance this instruction's stream pointer afterwards.
    pub advance_stream: bool,
    /// Extension (bit 15, previously reserved): fold the output element
    /// into the SPU's reduction accumulator as it is stored. Only legal on
    /// an `enable_output` instruction of a program carrying a
    /// [`ReduceOp`]; the base 15-bit ISA is unchanged when clear.
    pub reduce: bool,
}

impl CasperInstr {
    /// Width of the base wire encoding in bits (the `reduce` extension
    /// flag occupies the previously reserved bit 15).
    pub const BITS: u32 = 15;

    /// Element offset within the stream's row: `+amount` for left shifts,
    /// `-amount` for right shifts.
    pub fn dx(&self) -> i64 {
        match self.shift_dir {
            ShiftDir::Left => self.shift_amount as i64,
            ShiftDir::Right => -(self.shift_amount as i64),
        }
    }

    /// Build an instruction from a row-relative element offset.
    pub fn with_dx(const_idx: u8, stream_idx: u8, dx: i64) -> Result<CasperInstr> {
        if dx.unsigned_abs() > 7 {
            bail!("shift amount {dx} exceeds the 3-bit field (|dx| <= 7)");
        }
        Ok(CasperInstr {
            const_idx,
            stream_idx,
            shift_dir: if dx < 0 { ShiftDir::Right } else { ShiftDir::Left },
            shift_amount: dx.unsigned_abs() as u8,
            clear_acc: false,
            enable_output: false,
            advance_stream: false,
            reduce: false,
        })
    }

    /// Encode to the wire format: the base 15 bits, plus bit 15 for the
    /// `reduce` extension flag (clear for every pre-extension program, so
    /// legacy encodings are unchanged).
    ///
    /// Layout (bit 15 down to bit 0):
    /// `[reduce:1][const:4][stream:4][dir:1][amount:3][clear:1][output:1][advance:1]`
    pub fn encode(&self) -> u16 {
        debug_assert!(self.const_idx < 16 && self.stream_idx < 16 && self.shift_amount < 8);
        ((self.reduce as u16) << 15)
            | ((self.const_idx as u16) << 11)
            | ((self.stream_idx as u16) << 7)
            | ((self.shift_dir as u16) << 6)
            | ((self.shift_amount as u16) << 3)
            | ((self.clear_acc as u16) << 2)
            | ((self.enable_output as u16) << 1)
            | (self.advance_stream as u16)
    }

    /// Decode from the wire format. Bit 15 (`reduce`) is only legal on an
    /// `enable_output` instruction — any other bit-15 word stays an error,
    /// exactly as when the bit was reserved.
    pub fn decode(word: u16) -> Result<CasperInstr> {
        let reduce = word & 0x8000 != 0;
        let enable_output = (word >> 1) & 1 == 1;
        if reduce && !enable_output {
            bail!(
                "bit 15 (reduce) set without enable_output in Casper instruction word {word:#06x}"
            );
        }
        Ok(CasperInstr {
            const_idx: ((word >> 11) & 0xF) as u8,
            stream_idx: ((word >> 7) & 0xF) as u8,
            shift_dir: if (word >> 6) & 1 == 1 { ShiftDir::Right } else { ShiftDir::Left },
            shift_amount: ((word >> 3) & 0x7) as u8,
            clear_acc: (word >> 2) & 1 == 1,
            enable_output,
            advance_stream: word & 1 == 1,
            reduce,
        })
    }

    /// Fig 9-style disassembly: `c0, s2, 1, 1, 0, 0, 0` (reduce-flagged
    /// instructions append `, R`).
    pub fn disasm(&self) -> String {
        let base = format!(
            "c{}, s{}, {}, {}, {}, {}, {}",
            self.const_idx,
            self.stream_idx,
            self.shift_dir as u8,
            self.shift_amount,
            self.clear_acc as u8,
            self.enable_output as u8,
            self.advance_stream as u8
        );
        if self.reduce {
            format!("{base}, R")
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;
    use crate::util::SplitMix64;

    fn arbitrary(r: &mut SplitMix64) -> CasperInstr {
        let enable_output = r.chance(0.5);
        CasperInstr {
            const_idx: (r.next_u64() & 0xF) as u8,
            stream_idx: (r.next_u64() & 0xF) as u8,
            shift_dir: if r.chance(0.5) { ShiftDir::Right } else { ShiftDir::Left },
            shift_amount: (r.next_u64() % 8) as u8,
            clear_acc: r.chance(0.5),
            enable_output,
            advance_stream: r.chance(0.5),
            // The reduce flag is only encodable with enable_output.
            reduce: enable_output && r.chance(0.5),
        }
    }

    #[test]
    fn encode_decode_roundtrip_property() {
        testutil::check("instr roundtrip", 2048, arbitrary, |i| {
            CasperInstr::decode(i.encode()).map(|d| d == *i).unwrap_or(false)
        });
    }

    #[test]
    fn encoding_fits_15_bits() {
        // The base encoding stays 15-bit; only the reduce extension flag
        // occupies bit 15.
        testutil::check("15-bit", 2048, arbitrary, |i| {
            (i.encode() < (1 << 15)) == !i.reduce
        });
    }

    #[test]
    fn reduce_flag_roundtrips_and_marks_disasm() {
        let mut i = CasperInstr::with_dx(0, 0, 0).unwrap();
        i.enable_output = true;
        i.reduce = true;
        let d = CasperInstr::decode(i.encode()).unwrap();
        assert_eq!(d, i);
        assert!(d.disasm().ends_with(", R"));
    }

    #[test]
    fn fig9_first_instruction() {
        // Fig 9 line 2: `c0, s1, 0, 0, 1, 0, 1` — no shift, clear acc,
        // advance stream.
        let i = CasperInstr {
            const_idx: 0,
            stream_idx: 1,
            shift_dir: ShiftDir::Left,
            shift_amount: 0,
            clear_acc: true,
            enable_output: false,
            advance_stream: true,
            reduce: false,
        };
        assert_eq!(i.disasm(), "c0, s1, 0, 0, 1, 0, 1");
        assert_eq!(i.dx(), 0);
    }

    #[test]
    fn shift_right_is_negative_dx() {
        // Fig 9 line 4: `c0, s2, 1, 1, ...` loads A[j][i-1].
        let i = CasperInstr::decode(0b0000_0001_0100_1000).unwrap();
        assert_eq!(i.stream_idx, 2);
        assert_eq!(i.shift_dir, ShiftDir::Right);
        assert_eq!(i.shift_amount, 1);
        assert_eq!(i.dx(), -1);
    }

    #[test]
    fn with_dx_bounds() {
        assert!(CasperInstr::with_dx(0, 0, 7).is_ok());
        assert!(CasperInstr::with_dx(0, 0, -7).is_ok());
        assert!(CasperInstr::with_dx(0, 0, 8).is_err());
        assert_eq!(CasperInstr::with_dx(1, 2, -3).unwrap().dx(), -3);
    }

    #[test]
    fn decode_rejects_msb() {
        assert!(CasperInstr::decode(0x8000).is_err());
    }
}
