//! The Casper instruction set (§5.1) and programming library (§5.2).
//!
//! Every Casper instruction is 15 bits: 4 b constant-buffer index, 4 b
//! stream-buffer index, 1 b shift direction, 3 b shift amount, and 3
//! control bits (`clear accumulator`, `enable output`, `advance stream`).
//! The same instruction sequence is replayed for every (vector of) grid
//! point(s), which is why stencil code fits in a 64-entry buffer.
//!
//! [`ProgramBuilder`] is the paper's "programming library": it statically
//! analyzes a [`StencilDesc`](crate::stencil::StencilDesc) and emits the
//! instruction sequence, constant table, and stream specifications — the
//! Fig 9 code, generated. Stencils wider than the hardware envelope (more
//! distinct rows than the 16-entry stream buffer holds, or overflowing
//! the instruction/constant buffers) compile through
//! [`ProgramBuilder::build_passes`] into an ordered [`PassPlan`] of
//! envelope-legal programs that accumulate into the output grid — see
//! [`program`] and `docs/KERNELS.md`. Pass planning is strategy-selectable
//! ([`PlanStrategy`]: greedy first-fit vs. the optimizing planner), with
//! blackbox equivalence between the strategies checked by
//! [`verify`](crate::verify).

pub mod instr;
pub mod program;

pub use instr::{CasperInstr, ReduceOp, ShiftDir};
pub use program::{CasperProgram, PassPlan, PlanStrategy, ProgramBuilder, StreamSpec};
