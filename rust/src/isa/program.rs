//! Casper programs and the static program builder (§5.2, Fig 8/9),
//! including **multi-pass compilation** for stencils wider than the ISA
//! envelope (see `docs/KERNELS.md`).
//!
//! A program is the per-grid-point instruction sequence plus the constant
//! table and the stream *shapes* (row offsets relative to the walked grid
//! point). Per-SPU stream base addresses are bound later by the
//! coordinator via `init_stream` — the same split as the paper's API.
//!
//! The SPU front-end is small (Table 2 / §5.1): 64 instruction-buffer
//! entries, 16 stream-buffer entries, 16 constant-buffer entries, a 3-bit
//! shift field. A stencil whose distinct rows (plus the output stream)
//! exceed 16 — e.g. the isotropic radius-4 3D star, 17 rows — cannot be
//! expressed as a single program. [`PassPlan`] partitions such a kernel's
//! row groups into an *ordered* sequence of envelope-legal passes, and
//! [`ProgramBuilder::build_passes`] compiles one [`CasperProgram`] per
//! pass: pass 0 writes partial sums to the output array, and every later
//! pass starts from an *accumulator stream* (an input stream bound to the
//! pass's own output row, [`StreamSpec::from_output`]) so it computes
//! `out = 1.0·out + Σ taps` — plain ISA instructions, no new hardware.
//!
//! Two planners exist behind the [`PlanStrategy`] knob (CLI `--plan`, env
//! `CASPER_PLAN`): the original greedy first-fit over program order, and
//! an optimizing planner that reorders row groups by constant affinity
//! when (and only when) that strictly cuts the pass count, and otherwise
//! rebalances the order-preserving split points to minimize peak stream
//! pressure. Correctness is checked blackbox by the randomized
//! equivalence harness in `rust/src/verify/` (`casper verify`).

use anyhow::{bail, ensure, Result};

use super::instr::{CasperInstr, ReduceOp};
use crate::stencil::{RowGroup, StencilDesc};

/// Instruction-buffer capacity of the SPU front-end (Table 2 / §3.3).
pub const MAX_INSTRUCTIONS: usize = 64;
/// Stream-buffer capacity (also the reach of the 4-bit stream-id field).
pub const MAX_STREAMS: usize = 16;
/// Constant-buffer capacity (also the reach of the 4-bit constant index).
pub const MAX_CONSTANTS: usize = 16;
/// Max |dx| encodable in the 3-bit shift-amount field.
pub const MAX_SHIFT: i64 = 7;
/// Sanity cap on multi-pass plans (~900 input rows' worth of passes).
/// Unlike the buffer limits above this is a policy bound, not hardware:
/// a spec needing more passes is *expressible* (the row-offset sanity
/// bound admits far larger footprints) but is rejected with a clear
/// error rather than scheduling thousands of accelerator passes.
pub const MAX_PASSES: usize = 64;

/// Shape of one stream: the row offset it walks, relative to the current
/// output point. The output stream has `is_output = true`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSpec {
    /// Row offset along y, in rows.
    pub dy: i64,
    /// Row offset along z, in planes.
    pub dz: i64,
    /// True for the (single) output stream, always stream 0.
    pub is_output: bool,
    /// True for an *accumulator* input stream: the coordinator binds it to
    /// the pass's own output row in the output array, so a later pass of a
    /// multi-pass plan reads the partial sums the previous pass stored.
    /// Must have `dy == dz == 0` (it aliases exactly the elements the pass
    /// writes, which is what makes the read-before-write race-free).
    pub from_output: bool,
}

/// A complete Casper program: what `initStencilcode` + `initConstant`
/// broadcast to the SPUs.
#[derive(Debug, Clone, PartialEq)]
pub struct CasperProgram {
    /// Per-grid-point instruction sequence (replayed for every vector of
    /// grid points).
    pub instrs: Vec<CasperInstr>,
    /// Constant table (`initConstant` values).
    pub constants: Vec<f64>,
    /// Stream table; index = stream id. Stream 0 is always the output.
    pub streams: Vec<StreamSpec>,
    /// Fused reduction carried by this pass, if any: the output
    /// instruction's bit-15 `reduce` flag folds each stored element into
    /// the SPU's reduction accumulator, and the leader combines the
    /// partials in `(round, spu, seq)` order. Only the *final* pass of a
    /// multi-pass plan may carry one (it sees the completed sums).
    pub reduce: Option<ReduceOp>,
}

impl CasperProgram {
    /// Index of the output stream (fixed to 0, as in Fig 8).
    pub const OUT_STREAM: u8 = 0;

    /// True when this program is a later pass of a multi-pass plan: it
    /// carries an accumulator stream and adds onto the output array's
    /// partial sums instead of overwriting them.
    pub fn accumulates(&self) -> bool {
        self.streams.iter().any(|s| s.from_output)
    }

    /// One-line buffer-utilization summary against the hardware envelope,
    /// as printed by `casper kernels show`.
    pub fn utilization(&self) -> String {
        format!(
            "{:>2}/{MAX_INSTRUCTIONS} instrs | {:>2}/{MAX_STREAMS} streams | {:>2}/{MAX_CONSTANTS} constants",
            self.instrs.len(),
            self.streams.len(),
            self.constants.len()
        )
    }

    /// Validate against the hardware limits and structural rules.
    pub fn validate(&self) -> Result<()> {
        if self.instrs.is_empty() {
            bail!("empty program");
        }
        if self.instrs.len() > MAX_INSTRUCTIONS {
            bail!("{} instructions exceed the {MAX_INSTRUCTIONS}-entry buffer", self.instrs.len());
        }
        if self.streams.len() > MAX_STREAMS {
            bail!("{} streams exceed the {MAX_STREAMS}-entry stream buffer", self.streams.len());
        }
        if self.constants.len() > MAX_CONSTANTS {
            bail!("{} constants exceed the {MAX_CONSTANTS}-entry constant buffer", self.constants.len());
        }
        if self.streams.is_empty() || !self.streams[0].is_output {
            bail!("stream 0 must be the output stream");
        }
        if self.streams.iter().skip(1).any(|s| s.is_output) {
            bail!("exactly one output stream allowed");
        }
        // Accumulator streams (multi-pass): at most one, never the output
        // stream itself, and pinned to the output row (dy = dz = 0).
        if self.streams.iter().filter(|s| s.from_output).count() > 1 {
            bail!("at most one accumulator (from_output) stream allowed");
        }
        for (sid, s) in self.streams.iter().enumerate() {
            if s.from_output && s.is_output {
                bail!("stream s{sid}: from_output set on the output stream");
            }
            if s.from_output && (s.dy != 0 || s.dz != 0) {
                bail!("stream s{sid}: accumulator stream must have dy = dz = 0");
            }
        }
        // First instruction must clear the accumulator; exactly the last
        // must emit output (one store per grid point, §6).
        if !self.instrs[0].clear_acc {
            bail!("first instruction must set clear_acc");
        }
        let outs = self.instrs.iter().filter(|i| i.enable_output).count();
        if outs != 1 || !self.instrs.last().unwrap().enable_output {
            bail!("exactly the final instruction must set enable_output");
        }
        for (n, i) in self.instrs.iter().enumerate() {
            if i.const_idx as usize >= self.constants.len() {
                bail!("instr {n}: constant c{} out of range", i.const_idx);
            }
            let sid = i.stream_idx as usize;
            if sid >= self.streams.len() {
                bail!("instr {n}: stream s{} out of range", i.stream_idx);
            }
            if self.streams[sid].is_output {
                bail!("instr {n}: loads from the output stream");
            }
            if self.streams[sid].from_output && i.shift_amount != 0 {
                // A shifted accumulator load would read a neighbouring
                // output element another SPU may be writing this pass —
                // the same race the dy = dz = 0 rule blocks along rows.
                bail!("instr {n}: shifted load from the accumulator stream (dx must be 0)");
            }
        }
        // Every input stream must be advanced exactly once per grid point,
        // by its last-consuming instruction (§6: "has to be set in the last
        // instruction consuming data from each stream").
        for sid in 1..self.streams.len() {
            let consumers: Vec<usize> = self
                .instrs
                .iter()
                .enumerate()
                .filter(|(_, i)| i.stream_idx as usize == sid)
                .map(|(n, _)| n)
                .collect();
            if consumers.is_empty() {
                bail!("stream s{sid} is never consumed");
            }
            let advances: Vec<usize> = consumers
                .iter()
                .copied()
                .filter(|&n| self.instrs[n].advance_stream)
                .collect();
            if advances.len() != 1 || advances[0] != *consumers.last().unwrap() {
                bail!("stream s{sid} must be advanced exactly once, by its last consumer");
            }
        }
        // Fused-reduction rules: the bit-15 reduce flag lives exactly on
        // the output instruction of a program that carries a [`ReduceOp`]
        // — nowhere else, and never without one.
        for (n, i) in self.instrs.iter().enumerate() {
            if i.reduce && !i.enable_output {
                bail!("instr {n}: reduce flag set without enable_output");
            }
        }
        match self.reduce {
            Some(op) => {
                if !self.instrs.last().unwrap().reduce {
                    bail!("program carries reduction '{op}' but its output instruction lacks the reduce flag");
                }
            }
            None => {
                if self.instrs.iter().any(|i| i.reduce) {
                    bail!("reduce-flagged instruction in a program without a reduction op");
                }
            }
        }
        Ok(())
    }

    /// Encode to the compressed wire form (one 15-bit word per
    /// instruction, packed little-endian into `u16`s).
    pub fn encode(&self) -> Vec<u16> {
        self.instrs.iter().map(|i| i.encode()).collect()
    }

    /// Fig 9-style listing.
    pub fn disasm(&self) -> String {
        let mut out = String::new();
        for i in &self.instrs {
            out.push_str(&i.disasm());
            out.push('\n');
        }
        out
    }

    /// Dynamic Casper instructions needed for `points` grid points at a
    /// given SIMD width (Table 4 accounting: the sequence replays once per
    /// vector of grid points).
    pub fn dynamic_instrs(&self, points: usize, simd_lanes: usize) -> u64 {
        let groups = points.div_ceil(simd_lanes) as u64;
        groups * self.instrs.len() as u64
    }
}

/// How the compiler partitions a kernel's row groups into passes.
///
/// - [`PlanStrategy::Greedy`] is the original planner: first-fit over
///   program order (rows sorted by `(dz, dy)`), splitting whenever the
///   next row group would overflow the envelope. Simple, and pass-count
///   minimal *among order-preserving plans* — but it can leave pass count
///   on the table when rows interleave distinct coefficient families, and
///   it front-loads passes (pass 0 packed to the brim, the last pass
///   nearly empty).
/// - [`PlanStrategy::Optimized`] first tries a constant-affinity
///   reordering of the row groups (rows sharing coefficients packed into
///   the same pass), adopted only when it *strictly* reduces the pass
///   count. Otherwise it keeps program order — so the compiled result is
///   bitwise-identical to Greedy — and rebalances the split points among
///   all minimum-pass contiguous plans to minimize peak per-pass stream
///   pressure.
///
/// `passes(Optimized) <= passes(Greedy)` holds for every spec by
/// construction; the randomized blackbox harness (`rust/src/verify/`,
/// `casper verify`) re-checks it anyway, along with functional
/// equivalence of both strategies on both engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlanStrategy {
    /// First-fit over program order (the historical behaviour).
    Greedy,
    /// Minimize pass count first (constant-affinity reordering), then
    /// peak per-pass stream pressure (balanced split points). The engine
    /// default (override with `--plan greedy` / `CASPER_PLAN=greedy`).
    #[default]
    Optimized,
}

impl PlanStrategy {
    /// Both strategies, in comparison order (`kernels show` prints both).
    pub const ALL: [PlanStrategy; 2] = [PlanStrategy::Greedy, PlanStrategy::Optimized];

    /// Stable lowercase name (the CLI `--plan` / env `CASPER_PLAN` value).
    pub fn name(self) -> &'static str {
        match self {
            PlanStrategy::Greedy => "greedy",
            PlanStrategy::Optimized => "optimized",
        }
    }

    /// Parse a `--plan` / `CASPER_PLAN` value. Case-insensitive; `None`
    /// for anything other than `greedy` / `optimized`.
    pub fn parse(s: &str) -> Option<PlanStrategy> {
        match s.trim().to_ascii_lowercase().as_str() {
            "greedy" => Some(PlanStrategy::Greedy),
            "optimized" => Some(PlanStrategy::Optimized),
            _ => None,
        }
    }
}

impl std::fmt::Display for PlanStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Incremental envelope accounting for one pass, kept in lockstep with
/// what `emit_pass` actually emits (accumulator = 1 stream +
/// 1 instruction + the constant 1.0; constants deduped by bit pattern).
/// That agreement is what lets `KernelSpec::validate` promise that every
/// accepted spec compiles; the property tests in
/// `rust/tests/kernel_registry.rs` and `rust/tests/plan_equivalence.rs`
/// pin it over random wide specs.
#[derive(Debug, Clone)]
struct PassBudget {
    instrs: usize,
    streams: usize,
    coefs: Vec<u64>,
}

impl PassBudget {
    /// Fresh budget; `accumulate` charges the accumulator stream,
    /// instruction, and constant that passes after the first carry.
    fn new(accumulate: bool) -> PassBudget {
        PassBudget {
            instrs: accumulate as usize,
            streams: 1 + accumulate as usize,
            coefs: if accumulate { vec![1.0f64.to_bits()] } else { Vec::new() },
        }
    }

    /// Distinct constants `g` would add on top of the pass so far.
    fn new_constants(&self, g: &RowGroup) -> usize {
        let mut fresh: Vec<u64> = Vec::new();
        for &(_, c) in &g.taps {
            let bits = c.to_bits();
            if !self.coefs.contains(&bits) && !fresh.contains(&bits) {
                fresh.push(bits);
            }
        }
        fresh.len()
    }

    /// Would admitting `g` keep the pass inside the envelope?
    fn fits(&self, g: &RowGroup) -> bool {
        self.streams + 1 <= MAX_STREAMS
            && self.instrs + g.taps.len() <= MAX_INSTRUCTIONS
            && self.coefs.len() + self.new_constants(g) <= MAX_CONSTANTS
    }

    /// Admit `g` into the pass (caller has checked [`Self::fits`]).
    fn admit(&mut self, g: &RowGroup) {
        self.streams += 1;
        self.instrs += g.taps.len();
        for &(_, c) in &g.taps {
            let bits = c.to_bits();
            if !self.coefs.contains(&bits) {
                self.coefs.push(bits);
            }
        }
    }
}

/// The 3-bit shift field is a per-tap hard limit: no pass split or
/// reordering widens an encoding, so both planners reject it up front.
fn check_shifts(groups: &[RowGroup]) -> Result<()> {
    for g in groups {
        for &(dx, _) in &g.taps {
            ensure!(
                dx.unsigned_abs() <= MAX_SHIFT as u64,
                "tap dx {dx} exceeds the 3-bit shift field (|dx| <= {MAX_SHIFT}); \
                 multi-pass splitting cannot widen the shift encoding"
            );
        }
    }
    Ok(())
}

/// Greedy first-fit over program order: fill each pass until the next row
/// group would overflow the envelope, then cut. Pass-count minimal among
/// order-preserving partitions (pass feasibility is prefix-closed, so
/// taking every group that fits never hurts a later cut).
fn greedy_passes(groups: &[RowGroup]) -> Result<Vec<Vec<usize>>> {
    let mut passes: Vec<Vec<usize>> = Vec::new();
    let mut start = 0usize;
    while start < groups.len() {
        // Later passes spend one stream, one instruction, and the
        // constant 1.0 on the accumulator.
        let mut budget = PassBudget::new(!passes.is_empty());
        let mut end = start;
        while end < groups.len() && budget.fits(&groups[end]) {
            budget.admit(&groups[end]);
            end += 1;
        }
        ensure!(
            end > start,
            "row group {start} alone exceeds the ISA envelope \
             ({} taps vs {MAX_INSTRUCTIONS}-entry instruction / {MAX_CONSTANTS}-entry constant buffers)",
            groups[start].taps.len()
        );
        passes.push((start..end).collect());
        start = end;
    }
    ensure!(
        passes.len() <= MAX_PASSES,
        "{} passes exceed the {MAX_PASSES}-pass sanity bound",
        passes.len()
    );
    Ok(passes)
}

/// Constant-affinity bin packing: build each pass by repeatedly admitting
/// the remaining row group that introduces the fewest new constants (ties
/// broken toward the lowest program-order index, so plans are
/// deterministic), then sort each pass's groups back into program order.
/// Rows drawing on the same coefficient family cluster into the same pass
/// instead of dragging every family into every pass.
fn affinity_passes(groups: &[RowGroup]) -> Result<Vec<Vec<usize>>> {
    let mut remaining: Vec<usize> = (0..groups.len()).collect();
    let mut passes: Vec<Vec<usize>> = Vec::new();
    while !remaining.is_empty() {
        let mut budget = PassBudget::new(!passes.is_empty());
        let mut pass: Vec<usize> = Vec::new();
        loop {
            let mut best: Option<(usize, usize)> = None; // (new constants, slot)
            for (slot, &gi) in remaining.iter().enumerate() {
                if !budget.fits(&groups[gi]) {
                    continue;
                }
                let fresh = budget.new_constants(&groups[gi]);
                if best.is_none_or(|(b, _)| fresh < b) {
                    best = Some((fresh, slot));
                }
            }
            match best {
                Some((_, slot)) => {
                    let gi = remaining.remove(slot);
                    budget.admit(&groups[gi]);
                    pass.push(gi);
                }
                None => break,
            }
        }
        // A group that fits no fresh accumulating pass (e.g. one with
        // MAX_INSTRUCTIONS taps, placeable only in pass 0) strands the
        // packing; the caller falls back to the order-preserving plan.
        ensure!(!pass.is_empty(), "row group {} alone exceeds the ISA envelope", remaining[0]);
        pass.sort_unstable();
        passes.push(pass);
        ensure!(
            passes.len() <= MAX_PASSES,
            "{} passes exceed the {MAX_PASSES}-pass sanity bound",
            passes.len()
        );
    }
    Ok(passes)
}

/// Among all order-preserving partitions of `groups` into exactly
/// `target` envelope-legal passes, pick one minimizing the maximum
/// per-pass stream count (deterministic: earliest split achieving the
/// optimum). `None` when no such partition exists or the group count is
/// past the DP size guard — callers fall back to the greedy shape.
fn balanced_passes(groups: &[RowGroup], target: usize) -> Option<Vec<Vec<usize>>> {
    let n = groups.len();
    if target == 0 || n == 0 || n > 512 {
        return None;
    }
    // Furthest j such that groups[i..j) fits one pass; feasibility is
    // prefix-closed, so the feasible ends form the range (i, reach].
    let reach = |i: usize, accumulate: bool| -> usize {
        let mut budget = PassBudget::new(accumulate);
        let mut j = i;
        while j < n && budget.fits(&groups[j]) {
            budget.admit(&groups[j]);
            j += 1;
        }
        j
    };
    // best[k][i]: minimal achievable peak stream count covering
    // groups[i..n) with exactly k accumulating passes (usize::MAX = Ø).
    let mut best = vec![vec![usize::MAX; n + 1]; target];
    best[0][n] = 0;
    for k in 1..target {
        for i in (0..n).rev() {
            let r = reach(i, true);
            for j in (i + 1)..=r {
                if best[k - 1][j] == usize::MAX {
                    continue;
                }
                // A later pass over j - i groups holds output +
                // accumulator + one stream per group.
                let peak = (2 + (j - i)).max(best[k - 1][j]);
                if peak < best[k][i] {
                    best[k][i] = peak;
                }
            }
        }
    }
    // Pass 0 (no accumulator): pick the earliest cut minimizing the peak.
    let mut choice: Option<(usize, usize)> = None; // (peak, first cut)
    for j in 1..=reach(0, false) {
        let tail = best[target - 1][j];
        if tail == usize::MAX {
            continue;
        }
        let peak = (1 + j).max(tail);
        if choice.is_none_or(|(p, _)| peak < p) {
            choice = Some((peak, j));
        }
    }
    let (_, first) = choice?;
    let mut cuts = vec![0usize, first];
    let mut i = first;
    let mut k = target - 1;
    while k > 0 {
        let r = reach(i, true);
        let mut next: Option<usize> = None;
        for j in (i + 1)..=r {
            if best[k - 1][j] == usize::MAX {
                continue;
            }
            if (2 + (j - i)).max(best[k - 1][j]) == best[k][i] {
                next = Some(j);
                break;
            }
        }
        i = next?;
        cuts.push(i);
        k -= 1;
    }
    if *cuts.last().unwrap() != n {
        return None;
    }
    Some(cuts.windows(2).map(|w| (w[0]..w[1]).collect()).collect())
}

/// An ordered partition of a kernel's row groups into ISA-envelope-legal
/// passes (multi-pass compilation; see the module docs and
/// `docs/KERNELS.md`).
///
/// Each pass lists the indices (into
/// [`KernelSpec::row_groups`](crate::stencil::KernelSpec::row_groups)) of
/// the row groups it covers, in emission order. When the concatenated
/// lists read `0, 1, 2, …` the plan is *order-preserving*
/// ([`Self::order_preserving`]): the multi-pass accumulation performs the
/// same left-to-right float additions as the single-program order, which
/// the golden pass-split oracle pins **bitwise**. A reordered plan (the
/// optimized planner's constant-affinity tier) is mathematically the same
/// sum in a different association — bitwise-pinned against the
/// plan-aware oracle ([`golden::step_planned`](crate::stencil::golden)),
/// tolerance-checked against the naive order. A one-element plan means
/// the kernel fits a single program.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassPlan {
    passes: Vec<Vec<usize>>,
    strategy: PlanStrategy,
    order_preserving: bool,
}

impl PassPlan {
    /// Partition `groups` with the **greedy** strategy (first-fit over
    /// program order — the historical planner; see [`PlanStrategy`]).
    ///
    /// Errors when a tap offset exceeds the 3-bit shift field (no pass
    /// split can fix that), when a single row group alone overflows the
    /// envelope, or when the plan would exceed [`MAX_PASSES`].
    pub fn for_groups(groups: &[RowGroup]) -> Result<PassPlan> {
        Self::for_groups_with(groups, PlanStrategy::Greedy)
    }

    /// Partition `groups` under `strategy`. Per pass, the streams (output
    /// + accumulator for passes after the first + one per group) stay
    /// within [`MAX_STREAMS`], the instructions (accumulator + one per
    /// tap) within [`MAX_INSTRUCTIONS`], and the distinct coefficients
    /// (plus the accumulator's 1.0) within [`MAX_CONSTANTS`].
    ///
    /// The optimized strategy never plans more passes than the greedy one
    /// (it adopts its reordering only on a strict win and otherwise
    /// repartitions the greedy pass count), and it fails only when greedy
    /// fails — so `KernelSpec::validate`'s "every accepted spec compiles"
    /// guarantee is strategy-independent.
    pub fn for_groups_with(groups: &[RowGroup], strategy: PlanStrategy) -> Result<PassPlan> {
        ensure!(!groups.is_empty(), "at least one row group required");
        check_shifts(groups)?;
        let greedy = greedy_passes(groups)?;
        let passes = match strategy {
            PlanStrategy::Greedy => greedy,
            PlanStrategy::Optimized => match affinity_passes(groups) {
                Ok(aff) if aff.len() < greedy.len() => aff,
                _ => {
                    let target = greedy.len();
                    balanced_passes(groups, target).unwrap_or(greedy)
                }
            },
        };
        let order_preserving = passes.iter().flatten().copied().eq(0..groups.len());
        Ok(PassPlan { passes, strategy, order_preserving })
    }

    /// Per-pass row-group index lists into the kernel's `row_groups()`,
    /// in execution order.
    pub fn passes(&self) -> &[Vec<usize>] {
        &self.passes
    }

    /// Number of accelerator passes per time step.
    pub fn num_passes(&self) -> usize {
        self.passes.len()
    }

    /// True when the kernel needs more than one pass per time step.
    pub fn is_multi_pass(&self) -> bool {
        self.passes.len() > 1
    }

    /// The strategy that produced this plan.
    pub fn strategy(&self) -> PlanStrategy {
        self.strategy
    }

    /// True when the concatenated passes visit the row groups in program
    /// order — the condition under which multi-pass execution is
    /// bitwise-identical to the single-program accumulation order (and
    /// hence to the greedy plan's result).
    pub fn order_preserving(&self) -> bool {
        self.order_preserving
    }

    /// The maximum per-pass stream count this plan reaches (the
    /// optimized planner's secondary minimization objective).
    pub fn peak_streams(&self) -> usize {
        self.passes
            .iter()
            .enumerate()
            .map(|(pi, pass)| 1 + usize::from(pi > 0) + pass.len())
            .max()
            .unwrap_or(0)
    }
}

/// The paper's "programming library": compile a stencil descriptor into a
/// Casper program — or, past the ISA envelope, an ordered sequence of
/// them ([`Self::build_passes`]).
#[derive(Debug, Default)]
pub struct ProgramBuilder {
    constants: Vec<f64>,
}

impl ProgramBuilder {
    /// Fresh builder with an empty constant table.
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a constant, returning its buffer index.
    fn constant(&mut self, v: f64) -> Result<u8> {
        if let Some(i) = self.constants.iter().position(|&c| c.to_bits() == v.to_bits()) {
            return Ok(i as u8);
        }
        if self.constants.len() >= MAX_CONSTANTS {
            bail!("constant buffer overflow (> {MAX_CONSTANTS} distinct coefficients)");
        }
        self.constants.push(v);
        Ok((self.constants.len() - 1) as u8)
    }

    /// Compile a stencil that fits the envelope in a single pass: one
    /// stream per distinct `(dy, dz)` row (plus the output stream), one
    /// MAC instruction per tap, in-row taps expressed as shifted
    /// (unaligned) accesses of the shared stream — exactly the Fig 8/9
    /// scheme. Errors for wider stencils; use [`Self::build_passes`] to
    /// get their multi-pass plan instead.
    pub fn build(self, desc: &StencilDesc) -> Result<CasperProgram> {
        let groups = desc.row_groups();
        if groups.len() + 1 > MAX_STREAMS {
            bail!(
                "{} row groups need {} streams (> {MAX_STREAMS}); \
                 use build_passes for a multi-pass plan",
                groups.len(),
                groups.len() + 1
            );
        }
        let prog = self.emit_pass(&groups, false)?;
        match desc.reduction {
            Some(r) => Self::attach_reduction(prog, r.op),
            None => Ok(prog),
        }
    }

    /// Compile a stencil of any width into its ordered multi-pass plan
    /// under the **greedy** strategy: one envelope-legal [`CasperProgram`]
    /// per [`PassPlan`] entry. Pass 0 overwrites the output array with
    /// partial sums; every later pass leads with an accumulator
    /// instruction (`acc = 1.0 · out[i]`) over a
    /// [`StreamSpec::from_output`] stream, then adds its own taps — so
    /// running the passes back-to-back computes the full stencil in the
    /// same tap order as the single-pass program would have. Kernels that
    /// fit the envelope return a one-element plan identical to
    /// [`Self::build`]. See [`Self::build_passes_with`] for the
    /// strategy-selectable variant the engine uses.
    pub fn build_passes(desc: &StencilDesc) -> Result<Vec<CasperProgram>> {
        Self::build_passes_with(desc, PlanStrategy::Greedy)
    }

    /// [`Self::build_passes`] with an explicit [`PlanStrategy`].
    pub fn build_passes_with(
        desc: &StencilDesc,
        strategy: PlanStrategy,
    ) -> Result<Vec<CasperProgram>> {
        let groups = desc.row_groups();
        let plan = PassPlan::for_groups_with(&groups, strategy)?;
        Self::build_plan(desc, &groups, &plan)
    }

    /// Compile one program per pass of an already-computed `plan` over
    /// `groups` (as returned by `desc.row_groups()`), attaching the
    /// kernel's fused reduction to the final pass. Shared by both
    /// strategies so greedy and optimized plans compile identically
    /// pass-for-pass.
    pub fn build_plan(
        desc: &StencilDesc,
        groups: &[RowGroup],
        plan: &PassPlan,
    ) -> Result<Vec<CasperProgram>> {
        let mut progs: Vec<CasperProgram> = plan
            .passes()
            .iter()
            .enumerate()
            .map(|(pi, pass)| {
                let sel: Vec<RowGroup> = pass.iter().map(|&gi| groups[gi].clone()).collect();
                ProgramBuilder::new().emit_pass(&sel, pi > 0)
            })
            .collect::<Result<_>>()?;
        if let Some(r) = desc.reduction {
            // Only the final pass sees the completed sums, so the fused
            // reduction rides on it — earlier passes stream partials.
            let last = progs.pop().expect("PassPlan yields at least one pass");
            progs.push(Self::attach_reduction(last, r.op)?);
        }
        Ok(progs)
    }

    /// Fuse a reduction onto a compiled pass: flag its output instruction
    /// and record the op. Shared by [`Self::build`] and
    /// [`Self::build_passes`] so single- and multi-pass plans fuse
    /// identically.
    fn attach_reduction(mut prog: CasperProgram, op: ReduceOp) -> Result<CasperProgram> {
        prog.reduce = Some(op);
        prog.instrs.last_mut().expect("validated pass is non-empty").reduce = true;
        prog.validate()?;
        Ok(prog)
    }

    /// Emit one pass over `groups`. `accumulate` prepends the accumulator
    /// stream + instruction (passes after the first of a multi-pass plan).
    fn emit_pass(mut self, groups: &[RowGroup], accumulate: bool) -> Result<CasperProgram> {
        let mut streams = vec![StreamSpec { dy: 0, dz: 0, is_output: true, from_output: false }];
        let mut instrs: Vec<CasperInstr> = Vec::new();

        if accumulate {
            // `acc = 1.0 · out[i]`: reload the previous pass's partial sum
            // (multiplying by 1.0 is exact, so the bits carry through).
            streams.push(StreamSpec { dy: 0, dz: 0, is_output: false, from_output: true });
            let mut instr = CasperInstr::with_dx(self.constant(1.0)?, 1, 0)?;
            instr.advance_stream = true;
            instrs.push(instr);
        }

        for group in groups {
            let stream_idx = streams.len() as u8;
            streams.push(StreamSpec {
                dy: group.dy,
                dz: group.dz,
                is_output: false,
                from_output: false,
            });
            let last_tap = group.taps.len() - 1;
            for (ti, &(dx, coef)) in group.taps.iter().enumerate() {
                if dx.unsigned_abs() as i64 > MAX_SHIFT {
                    bail!("tap dx {dx} not encodable in the 3-bit shift field");
                }
                let mut instr = CasperInstr::with_dx(self.constant(coef)?, stream_idx, dx)?;
                instr.advance_stream = ti == last_tap;
                instrs.push(instr);
            }
        }

        if instrs.len() > MAX_INSTRUCTIONS {
            bail!("{} instructions exceed the instruction buffer", instrs.len());
        }
        instrs.first_mut().unwrap().clear_acc = true;
        instrs.last_mut().unwrap().enable_output = true;

        let prog = CasperProgram { instrs, constants: self.constants, streams, reduce: None };
        prog.validate()?;
        Ok(prog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::{extended_presets, StencilKind, StencilPoint};

    #[test]
    fn jacobi2d_matches_fig9() {
        // Fig 9: five instructions, three input streams, one constant.
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();
        assert_eq!(prog.instrs.len(), 5);
        assert_eq!(prog.streams.len(), 4); // output + 3 inputs
        assert_eq!(prog.constants, vec![0.2]);
        // First: clear_acc + advance (single-tap row dy=-1).
        assert!(prog.instrs[0].clear_acc);
        assert!(prog.instrs[0].advance_stream);
        // Middle row: shifts right(-1), none(0), left(+1); advance on last.
        assert_eq!(prog.instrs[1].dx(), -1);
        assert_eq!(prog.instrs[2].dx(), 0);
        assert_eq!(prog.instrs[3].dx(), 1);
        assert!(!prog.instrs[1].advance_stream);
        assert!(prog.instrs[3].advance_stream);
        // Last: enable_output + advance.
        assert!(prog.instrs[4].enable_output);
        assert!(prog.instrs[4].advance_stream);
    }

    #[test]
    fn all_kernels_compile_and_validate() {
        for k in StencilKind::ALL {
            let prog = ProgramBuilder::new().build(&k.descriptor()).unwrap();
            prog.validate().unwrap();
            assert_eq!(prog.instrs.len(), k.descriptor().num_points(), "{k}");
            assert!(prog.instrs.len() <= MAX_INSTRUCTIONS, "{k}");
            assert!(prog.streams.len() <= MAX_STREAMS, "{k}");
            assert!(prog.constants.len() <= MAX_CONSTANTS, "{k}");
        }
    }

    #[test]
    fn single_pass_plan_matches_build_exactly() {
        // For every in-envelope kernel, build_passes must return exactly
        // the single program `build` emits — the multi-pass machinery may
        // not perturb the paper kernels (byte-stable default report).
        for k in StencilKind::ALL {
            let desc = k.descriptor();
            let single = ProgramBuilder::new().build(&desc).unwrap();
            let passes = ProgramBuilder::build_passes(&desc).unwrap();
            assert_eq!(passes, vec![single], "{k}");
            assert!(!passes[0].accumulates(), "{k}");
        }
    }

    #[test]
    fn constants_are_interned() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Blur2D.descriptor())
            .unwrap();
        // 5×5 Gaussian has 6 distinct weights {1,4,7,16,26,41}/273.
        assert_eq!(prog.constants.len(), 6);
    }

    #[test]
    fn encode_roundtrip_through_wire() {
        for k in StencilKind::ALL {
            let prog = ProgramBuilder::new().build(&k.descriptor()).unwrap();
            let wire = prog.encode();
            let decoded: Vec<CasperInstr> = wire
                .iter()
                .map(|&w| CasperInstr::decode(w).unwrap())
                .collect();
            assert_eq!(decoded, prog.instrs, "{k}");
        }
    }

    #[test]
    fn dynamic_instr_count() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();
        // 16 points at 8 lanes = 2 vector groups × 5 instrs.
        assert_eq!(prog.dynamic_instrs(16, 8), 10);
        // Non-multiple rounds up.
        assert_eq!(prog.dynamic_instrs(17, 8), 15);
    }

    #[test]
    fn validate_rejects_broken_programs() {
        let mut prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        prog.instrs[0].clear_acc = false;
        assert!(prog.validate().is_err());

        let mut prog2 = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        prog2.instrs[1].enable_output = true; // two outputs
        assert!(prog2.validate().is_err());

        let mut prog3 = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        prog3.instrs[0].stream_idx = 9; // dangling stream
        assert!(prog3.validate().is_err());
    }

    #[test]
    fn validate_rejects_malformed_accumulator_streams() {
        // An accumulator stream off the output row — or a *shifted* load
        // from it — would read neighbours' in-flight partial sums: data
        // races the validator must reject. Start from a real accumulating
        // pass (star17_3d pass 1), which must itself validate.
        let star = extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "star17_3d")
            .expect("star17_3d preset");
        let good = ProgramBuilder::build_passes(&star).unwrap().remove(1);
        good.validate().unwrap();

        let mut off_row = good.clone();
        off_row.streams[1].dy = 1;
        let err = off_row.validate().unwrap_err().to_string();
        assert!(err.contains("accumulator"), "{err}");

        let mut shifted = good.clone();
        shifted.instrs[0].shift_amount = 1;
        let err = shifted.validate().unwrap_err().to_string();
        assert!(err.contains("accumulator"), "{err}");

        let mut two_accs = good.clone();
        two_accs.streams[2] = StreamSpec { dy: 0, dz: 0, is_output: false, from_output: true };
        let err = two_accs.validate().unwrap_err().to_string();
        assert!(err.contains("at most one accumulator"), "{err}");

        let mut on_output = good.clone();
        on_output.streams[0].from_output = true; // the output stream itself
        assert!(on_output.validate().is_err());
    }

    fn single_tap_rows(n: usize) -> Vec<RowGroup> {
        (0..n)
            .map(|i| RowGroup { dy: i as i64, dz: 0, taps: vec![(0, 0.5)] })
            .collect()
    }

    fn contig(r: std::ops::Range<usize>) -> Vec<usize> {
        r.collect()
    }

    #[test]
    fn plan_splits_on_the_stream_budget() {
        // 20 single-tap rows: pass 0 holds 15 (output + 15 = 16 streams),
        // pass 1 holds the rest (output + accumulator + 5).
        let plan = PassPlan::for_groups(&single_tap_rows(20)).unwrap();
        assert_eq!(plan.passes().to_vec(), vec![contig(0..15), contig(15..20)]);
        assert!(plan.is_multi_pass());
        assert!(plan.order_preserving());
        assert_eq!(plan.strategy(), PlanStrategy::Greedy);
        // 35 rows: 15 + 14 (accumulator costs a stream) + 6.
        let plan = PassPlan::for_groups(&single_tap_rows(35)).unwrap();
        assert_eq!(plan.passes().to_vec(), vec![contig(0..15), contig(15..29), contig(29..35)]);
        // 15 rows fit a single pass.
        let plan = PassPlan::for_groups(&single_tap_rows(15)).unwrap();
        assert_eq!(plan.passes().to_vec(), vec![contig(0..15)]);
        assert!(!plan.is_multi_pass());
    }

    #[test]
    fn plan_splits_on_the_instruction_and_constant_budgets() {
        // 10 rows × 7 taps = 70 instructions: the instruction buffer (64)
        // splits before the stream buffer would.
        let rows: Vec<RowGroup> = (0..10)
            .map(|i| RowGroup {
                dy: i as i64,
                dz: 0,
                taps: (-3..=3).map(|dx| (dx, 0.25)).collect(),
            })
            .collect();
        let plan = PassPlan::for_groups(&rows).unwrap();
        assert_eq!(plan.passes().to_vec(), vec![contig(0..9), contig(9..10)]);
        // 9 rows × 2 taps with 18 distinct coefficients: the constant
        // buffer (16) splits first — after 8 rows (16 constants, 9
        // streams, 16 instructions) only the constants are exhausted.
        let rows: Vec<RowGroup> = (0..9)
            .map(|i| RowGroup {
                dy: i as i64,
                dz: 0,
                taps: vec![(0, 1.0 / (2 * i + 2) as f64), (1, 1.0 / (2 * i + 3) as f64)],
            })
            .collect();
        let plan = PassPlan::for_groups(&rows).unwrap();
        assert_eq!(plan.passes().to_vec(), vec![contig(0..8), contig(8..9)]);
    }

    #[test]
    fn plan_strategy_parses_stable_names() {
        assert_eq!(PlanStrategy::parse("greedy"), Some(PlanStrategy::Greedy));
        assert_eq!(PlanStrategy::parse(" Optimized "), Some(PlanStrategy::Optimized));
        assert_eq!(PlanStrategy::parse("fastest"), None);
        assert_eq!(PlanStrategy::default(), PlanStrategy::Optimized);
        for s in PlanStrategy::ALL {
            assert_eq!(PlanStrategy::parse(s.name()), Some(s));
            assert_eq!(format!("{s}"), s.name());
        }
    }

    #[test]
    fn optimized_rebalances_split_points_without_reordering() {
        // 20 single-tap rows share one coefficient: no reordering can beat
        // the 2-pass greedy plan, so the optimized planner must keep
        // program order (bitwise-identical execution) and only move the
        // cut — 15+5 (peak 16 streams) becomes 10+10 (peak 12).
        let rows = single_tap_rows(20);
        let greedy = PassPlan::for_groups_with(&rows, PlanStrategy::Greedy).unwrap();
        let opt = PassPlan::for_groups_with(&rows, PlanStrategy::Optimized).unwrap();
        assert_eq!(opt.num_passes(), greedy.num_passes());
        assert!(opt.order_preserving());
        assert_eq!(opt.passes().to_vec(), vec![contig(0..10), contig(10..20)]);
        assert_eq!(greedy.peak_streams(), 16);
        assert_eq!(opt.peak_streams(), 12);
        // The compiled programs still validate pass-for-pass.
        let spec = crate::stencil::KernelSpec::new(
            "balance20",
            "balance 20-row",
            2,
            (-10i64..10).map(|dy| StencilPoint::new(0, dy, 0, 0.05)).collect(),
            crate::stencil::KernelOrigin::File,
        );
        let progs = ProgramBuilder::build_passes_with(&spec, PlanStrategy::Optimized).unwrap();
        assert_eq!(progs.len(), 2);
        for p in &progs {
            p.validate().unwrap();
        }
        assert_eq!(progs[0].streams.len(), 11); // output + 10 rows
        assert_eq!(progs[1].streams.len(), 12); // output + accum + 10 rows
    }

    /// Rows alternating between two 15-constant coefficient families: the
    /// shape where greedy first-fit pays for the interleaving (every pass
    /// accrues both families' constants) while a family-clustered order
    /// packs each family into one pass.
    fn dual_family_rows() -> Vec<RowGroup> {
        (0..20)
            .map(|ri| {
                let k = ri / 2;
                let fam_a = ri % 2 == 0;
                let taps: Vec<(i64, f64)> = (0..3)
                    .map(|t| {
                        let i = (3 * k + t) % 15;
                        let num = if fam_a { 32 + 2 * i } else { 2 * i + 1 };
                        (t as i64 - 1, num as f64 / 2048.0)
                    })
                    .collect();
                RowGroup { dy: ri as i64 - 10, dz: 0, taps }
            })
            .collect()
    }

    #[test]
    fn optimized_reorders_for_a_strict_pass_count_win() {
        let rows = dual_family_rows();
        let greedy = PassPlan::for_groups_with(&rows, PlanStrategy::Greedy).unwrap();
        assert_eq!(greedy.num_passes(), 4, "{:?}", greedy.passes());
        let opt = PassPlan::for_groups_with(&rows, PlanStrategy::Optimized).unwrap();
        assert_eq!(opt.num_passes(), 2, "{:?}", opt.passes());
        assert!(!opt.order_preserving());
        // The reordering is a permutation: every group exactly once.
        let mut seen: Vec<usize> = opt.passes().iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, contig(0..20));
        // Affinity packing pairs each row with its constant-sharing twin
        // (row k and row k+10 reuse the same 3 family coefficients), so
        // pass 0 absorbs five such pairs (15 constants) and the
        // accumulating pass 1 takes the remaining five pairs (15 + 1.0).
        assert_eq!(opt.passes()[0], vec![0, 1, 2, 3, 4, 10, 11, 12, 13, 14]);
        assert_eq!(opt.passes()[1], vec![5, 6, 7, 8, 9, 15, 16, 17, 18, 19]);
        // Plans are deterministic across runs.
        assert_eq!(opt, PassPlan::for_groups_with(&rows, PlanStrategy::Optimized).unwrap());
    }

    #[test]
    fn optimized_never_plans_more_passes_than_greedy() {
        // Random row-group soups: the construction guarantee the harness
        // re-checks blackbox. Coefficients from a small palette so the
        // constant budget is exercised alongside streams/instructions.
        const PALETTE: [f64; 20] = [
            0.5, 0.25, 0.125, -0.125, 0.0625, 1.0, -0.5, 0.75, 0.3, 0.7, 0.9, -0.0625, 0.11, 0.13,
            0.17, 0.19, 0.23, 0.29, 0.31, 0.37,
        ];
        let mut rng = crate::util::SplitMix64::new(0x9_1A57_CA5E);
        for case in 0..200 {
            let n = 1 + (rng.next_u64() % 30) as usize;
            let rows: Vec<RowGroup> = (0..n)
                .map(|i| {
                    let taps = (0..1 + (rng.next_u64() % 4) as usize)
                        .map(|t| {
                            (t as i64, PALETTE[(rng.next_u64() % PALETTE.len() as u64) as usize])
                        })
                        .collect();
                    RowGroup { dy: i as i64, dz: 0, taps }
                })
                .collect();
            let greedy = PassPlan::for_groups_with(&rows, PlanStrategy::Greedy).unwrap();
            let opt = PassPlan::for_groups_with(&rows, PlanStrategy::Optimized).unwrap();
            assert!(
                opt.num_passes() <= greedy.num_passes(),
                "case {case}: optimized {} > greedy {} passes",
                opt.num_passes(),
                greedy.num_passes()
            );
            assert!(opt.peak_streams() <= MAX_STREAMS, "case {case}");
            // Union of packed groups is exactly the input set.
            let mut seen: Vec<usize> = opt.passes().iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(seen, (0..n).collect::<Vec<_>>(), "case {case}");
            if opt.num_passes() == greedy.num_passes() {
                assert!(opt.order_preserving(), "case {case}: no win yet reordered");
                assert!(opt.peak_streams() <= greedy.peak_streams(), "case {case}");
            }
        }
    }

    #[test]
    fn plan_rejects_unsplittable_shifts() {
        let rows = vec![RowGroup { dy: 0, dz: 0, taps: vec![(8, 1.0)] }];
        let err = PassPlan::for_groups(&rows).unwrap_err().to_string();
        assert!(err.contains("3-bit shift field"), "{err}");
        assert!(PassPlan::for_groups(&[]).is_err());
    }

    #[test]
    fn star17_compiles_as_two_accumulating_passes() {
        // The previously-impossible kernel: the isotropic radius-4 3D star
        // has 17 input rows (> 15 the stream buffer can hold next to the
        // output), so PR 4 had to reject it. It now compiles as 2 passes.
        let star = extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "star17_3d")
            .expect("star17_3d preset");
        assert_eq!(star.row_groups().len(), 17);
        assert!(ProgramBuilder::new().build(&star).is_err(), "single-pass must still reject");

        let passes = ProgramBuilder::build_passes(&star).unwrap();
        assert_eq!(passes.len(), 2);
        for (pi, p) in passes.iter().enumerate() {
            p.validate().unwrap_or_else(|e| panic!("pass {pi}: {e:#}"));
            assert!(p.streams.len() <= MAX_STREAMS, "pass {pi}");
        }
        // Pass 0: greedy-filled to the stream budget, plain partial sums.
        assert!(!passes[0].accumulates());
        assert_eq!(passes[0].streams.len(), MAX_STREAMS);
        // Pass 1: accumulator stream + the 2 remaining rows.
        assert!(passes[1].accumulates());
        assert_eq!(passes[1].streams.len(), 4); // output + accum + 2 rows
        let acc = passes[1].instrs[0];
        assert!(acc.clear_acc && acc.advance_stream && !acc.enable_output);
        assert_eq!(acc.dx(), 0);
        assert_eq!(passes[1].constants[acc.const_idx as usize], 1.0);
        assert!(passes[1].streams[acc.stream_idx as usize].from_output);
        // Together the passes cover every tap exactly once (plus 1 accum).
        let taps: usize = passes.iter().map(|p| p.instrs.len()).sum();
        assert_eq!(taps, star.num_points() + 1);
    }

    #[test]
    fn reduction_fuses_onto_the_final_pass_only() {
        // The Jacobi residual preset: same taps as Jacobi2D plus a fused
        // abs-diff reduction — still ONE pass per step (the acceptance
        // criterion), with the reduce flag on exactly the output instr.
        let res = extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "jacobi2d_res")
            .expect("jacobi2d_res preset");
        let passes = ProgramBuilder::build_passes(&res).unwrap();
        assert_eq!(passes.len(), 1, "fused reduction must not add a pass");
        let p = &passes[0];
        assert_eq!(p.reduce, Some(ReduceOp::AbsDiff));
        assert_eq!(p.instrs.iter().filter(|i| i.reduce).count(), 1);
        assert!(p.instrs.last().unwrap().reduce);
        assert_eq!(p, &ProgramBuilder::new().build(&res).unwrap());

        // A wide reduced kernel: only the last of its passes reduces.
        let mut points = Vec::new();
        for dy in -20i64..20 {
            points.push(StencilPoint::new(0, dy, 0, 0.025));
        }
        let mut spec = crate::stencil::KernelSpec::new(
            "wide40r",
            "wide 40-row reduced",
            2,
            points,
            crate::stencil::KernelOrigin::File,
        );
        spec.reduction = Some(crate::stencil::ReductionSpec { op: ReduceOp::Sum });
        let passes = ProgramBuilder::build_passes(&spec).unwrap();
        assert_eq!(passes.len(), 3);
        assert!(passes[..2].iter().all(|p| p.reduce.is_none()));
        assert_eq!(passes[2].reduce, Some(ReduceOp::Sum));
        for p in &passes {
            p.validate().unwrap();
        }
    }

    #[test]
    fn validate_rejects_mismatched_reduce_flags() {
        let base = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();

        // Op recorded but output instruction not flagged.
        let mut unflagged = base.clone();
        unflagged.reduce = Some(ReduceOp::Sum);
        let err = unflagged.validate().unwrap_err().to_string();
        assert!(err.contains("lacks the reduce flag"), "{err}");

        // Flag set without a recorded op.
        let mut orphan = base.clone();
        orphan.instrs.last_mut().unwrap().reduce = true;
        let err = orphan.validate().unwrap_err().to_string();
        assert!(err.contains("without a reduction op"), "{err}");

        // Flag on a non-output instruction.
        let mut misplaced = base.clone();
        misplaced.reduce = Some(ReduceOp::Max);
        misplaced.instrs.last_mut().unwrap().reduce = true;
        misplaced.instrs[0].reduce = true;
        let err = misplaced.validate().unwrap_err().to_string();
        assert!(err.contains("without enable_output"), "{err}");
    }

    #[test]
    fn disasm_has_one_line_per_instr() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Heat3D.descriptor())
            .unwrap();
        assert_eq!(prog.disasm().lines().count(), 7);
    }

    #[test]
    fn utilization_reports_the_three_buffers() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();
        let u = prog.utilization();
        assert!(u.contains("5/64 instrs"), "{u}");
        assert!(u.contains("4/16 streams"), "{u}");
        assert!(u.contains("1/16 constants"), "{u}");
    }

    #[test]
    fn wide_synthetic_kernel_round_trips_through_passes() {
        // A 1D-ish synthetic with 40 rows in y: every pass validates, the
        // row coverage is a partition, and only pass 0 overwrites.
        let mut points = Vec::new();
        for dy in -20i64..20 {
            points.push(StencilPoint::new(0, dy, 0, 0.025));
        }
        let spec = crate::stencil::KernelSpec::new(
            "wide40",
            "wide 40-row",
            2,
            points,
            crate::stencil::KernelOrigin::File,
        );
        let passes = ProgramBuilder::build_passes(&spec).unwrap();
        assert_eq!(passes.len(), 3); // 15 + 14 + 11 rows
        assert!(!passes[0].accumulates());
        assert!(passes[1].accumulates() && passes[2].accumulates());
        let rows: usize = passes
            .iter()
            .map(|p| p.streams.iter().filter(|s| !s.is_output && !s.from_output).count())
            .sum();
        assert_eq!(rows, 40);
    }
}
