//! Casper programs and the static program builder (§5.2, Fig 8/9).
//!
//! A program is the per-grid-point instruction sequence plus the constant
//! table and the stream *shapes* (row offsets relative to the walked grid
//! point). Per-SPU stream base addresses are bound later by the
//! coordinator via `init_stream` — the same split as the paper's API.

use anyhow::{bail, Result};

use super::instr::CasperInstr;
use crate::stencil::StencilDesc;

/// Hardware limits of the SPU front-end (Table 2 / §3.3 / §5.1).
pub const MAX_INSTRUCTIONS: usize = 64;
pub const MAX_STREAMS: usize = 16;
pub const MAX_CONSTANTS: usize = 16;
/// Max |dx| encodable in the 3-bit shift-amount field.
pub const MAX_SHIFT: i64 = 7;

/// Shape of one stream: the row offset it walks, relative to the current
/// output point. The output stream has `is_output = true`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSpec {
    pub dy: i64,
    pub dz: i64,
    pub is_output: bool,
}

/// A complete Casper program: what `initStencilcode` + `initConstant`
/// broadcast to the SPUs.
#[derive(Debug, Clone, PartialEq)]
pub struct CasperProgram {
    /// Per-grid-point instruction sequence (replayed for every vector of
    /// grid points).
    pub instrs: Vec<CasperInstr>,
    /// Constant table (`initConstant` values).
    pub constants: Vec<f64>,
    /// Stream table; index = stream id. Stream 0 is always the output.
    pub streams: Vec<StreamSpec>,
}

impl CasperProgram {
    /// Index of the output stream (fixed to 0, as in Fig 8).
    pub const OUT_STREAM: u8 = 0;

    /// Validate against the hardware limits and structural rules.
    pub fn validate(&self) -> Result<()> {
        if self.instrs.is_empty() {
            bail!("empty program");
        }
        if self.instrs.len() > MAX_INSTRUCTIONS {
            bail!("{} instructions exceed the {MAX_INSTRUCTIONS}-entry buffer", self.instrs.len());
        }
        if self.streams.len() > MAX_STREAMS {
            bail!("{} streams exceed the {MAX_STREAMS}-entry stream buffer", self.streams.len());
        }
        if self.constants.len() > MAX_CONSTANTS {
            bail!("{} constants exceed the {MAX_CONSTANTS}-entry constant buffer", self.constants.len());
        }
        if self.streams.is_empty() || !self.streams[0].is_output {
            bail!("stream 0 must be the output stream");
        }
        if self.streams.iter().skip(1).any(|s| s.is_output) {
            bail!("exactly one output stream allowed");
        }
        // First instruction must clear the accumulator; exactly the last
        // must emit output (one store per grid point, §6).
        if !self.instrs[0].clear_acc {
            bail!("first instruction must set clear_acc");
        }
        let outs = self.instrs.iter().filter(|i| i.enable_output).count();
        if outs != 1 || !self.instrs.last().unwrap().enable_output {
            bail!("exactly the final instruction must set enable_output");
        }
        for (n, i) in self.instrs.iter().enumerate() {
            if i.const_idx as usize >= self.constants.len() {
                bail!("instr {n}: constant c{} out of range", i.const_idx);
            }
            let sid = i.stream_idx as usize;
            if sid >= self.streams.len() {
                bail!("instr {n}: stream s{} out of range", i.stream_idx);
            }
            if self.streams[sid].is_output {
                bail!("instr {n}: loads from the output stream");
            }
        }
        // Every input stream must be advanced exactly once per grid point,
        // by its last-consuming instruction (§6: "has to be set in the last
        // instruction consuming data from each stream").
        for sid in 1..self.streams.len() {
            let consumers: Vec<usize> = self
                .instrs
                .iter()
                .enumerate()
                .filter(|(_, i)| i.stream_idx as usize == sid)
                .map(|(n, _)| n)
                .collect();
            if consumers.is_empty() {
                bail!("stream s{sid} is never consumed");
            }
            let advances: Vec<usize> = consumers
                .iter()
                .copied()
                .filter(|&n| self.instrs[n].advance_stream)
                .collect();
            if advances.len() != 1 || advances[0] != *consumers.last().unwrap() {
                bail!("stream s{sid} must be advanced exactly once, by its last consumer");
            }
        }
        Ok(())
    }

    /// Encode to the compressed wire form (one 15-bit word per
    /// instruction, packed little-endian into `u16`s).
    pub fn encode(&self) -> Vec<u16> {
        self.instrs.iter().map(|i| i.encode()).collect()
    }

    /// Fig 9-style listing.
    pub fn disasm(&self) -> String {
        let mut out = String::new();
        for i in &self.instrs {
            out.push_str(&i.disasm());
            out.push('\n');
        }
        out
    }

    /// Dynamic Casper instructions needed for `points` grid points at a
    /// given SIMD width (Table 4 accounting: the sequence replays once per
    /// vector of grid points).
    pub fn dynamic_instrs(&self, points: usize, simd_lanes: usize) -> u64 {
        let groups = points.div_ceil(simd_lanes) as u64;
        groups * self.instrs.len() as u64
    }
}

/// The paper's "programming library": compile a stencil descriptor into a
/// Casper program.
#[derive(Debug, Default)]
pub struct ProgramBuilder {
    constants: Vec<f64>,
}

impl ProgramBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Intern a constant, returning its buffer index.
    fn constant(&mut self, v: f64) -> Result<u8> {
        if let Some(i) = self.constants.iter().position(|&c| c.to_bits() == v.to_bits()) {
            return Ok(i as u8);
        }
        if self.constants.len() >= MAX_CONSTANTS {
            bail!("constant buffer overflow (> {MAX_CONSTANTS} distinct coefficients)");
        }
        self.constants.push(v);
        Ok((self.constants.len() - 1) as u8)
    }

    /// Compile a stencil: one stream per distinct `(dy, dz)` row (plus the
    /// output stream), one MAC instruction per tap, in-row taps expressed
    /// as shifted (unaligned) accesses of the shared stream — exactly the
    /// Fig 8/9 scheme.
    pub fn build(mut self, desc: &StencilDesc) -> Result<CasperProgram> {
        let groups = desc.row_groups();
        if groups.len() + 1 > MAX_STREAMS {
            bail!(
                "{} row groups need {} streams (> {MAX_STREAMS})",
                groups.len(),
                groups.len() + 1
            );
        }

        let mut streams = vec![StreamSpec { dy: 0, dz: 0, is_output: true }];
        let mut instrs: Vec<CasperInstr> = Vec::new();

        for (gi, group) in groups.iter().enumerate() {
            let stream_idx = (gi + 1) as u8;
            streams.push(StreamSpec { dy: group.dy, dz: group.dz, is_output: false });
            let last_tap = group.taps.len() - 1;
            for (ti, &(dx, coef)) in group.taps.iter().enumerate() {
                if dx.unsigned_abs() as i64 > MAX_SHIFT {
                    bail!("tap dx {dx} not encodable in the 3-bit shift field");
                }
                let mut instr = CasperInstr::with_dx(self.constant(coef)?, stream_idx, dx)?;
                instr.advance_stream = ti == last_tap;
                instrs.push(instr);
            }
        }

        if instrs.len() > MAX_INSTRUCTIONS {
            bail!("{} instructions exceed the instruction buffer", instrs.len());
        }
        instrs.first_mut().unwrap().clear_acc = true;
        instrs.last_mut().unwrap().enable_output = true;

        let prog = CasperProgram { instrs, constants: self.constants, streams };
        prog.validate()?;
        Ok(prog)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::StencilKind;

    #[test]
    fn jacobi2d_matches_fig9() {
        // Fig 9: five instructions, three input streams, one constant.
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();
        assert_eq!(prog.instrs.len(), 5);
        assert_eq!(prog.streams.len(), 4); // output + 3 inputs
        assert_eq!(prog.constants, vec![0.2]);
        // First: clear_acc + advance (single-tap row dy=-1).
        assert!(prog.instrs[0].clear_acc);
        assert!(prog.instrs[0].advance_stream);
        // Middle row: shifts right(-1), none(0), left(+1); advance on last.
        assert_eq!(prog.instrs[1].dx(), -1);
        assert_eq!(prog.instrs[2].dx(), 0);
        assert_eq!(prog.instrs[3].dx(), 1);
        assert!(!prog.instrs[1].advance_stream);
        assert!(prog.instrs[3].advance_stream);
        // Last: enable_output + advance.
        assert!(prog.instrs[4].enable_output);
        assert!(prog.instrs[4].advance_stream);
    }

    #[test]
    fn all_kernels_compile_and_validate() {
        for k in StencilKind::ALL {
            let prog = ProgramBuilder::new().build(&k.descriptor()).unwrap();
            prog.validate().unwrap();
            assert_eq!(prog.instrs.len(), k.descriptor().num_points(), "{k}");
            assert!(prog.instrs.len() <= MAX_INSTRUCTIONS, "{k}");
            assert!(prog.streams.len() <= MAX_STREAMS, "{k}");
            assert!(prog.constants.len() <= MAX_CONSTANTS, "{k}");
        }
    }

    #[test]
    fn constants_are_interned() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Blur2D.descriptor())
            .unwrap();
        // 5×5 Gaussian has 6 distinct weights {1,4,7,16,26,41}/273.
        assert_eq!(prog.constants.len(), 6);
    }

    #[test]
    fn encode_roundtrip_through_wire() {
        for k in StencilKind::ALL {
            let prog = ProgramBuilder::new().build(&k.descriptor()).unwrap();
            let wire = prog.encode();
            let decoded: Vec<CasperInstr> = wire
                .iter()
                .map(|&w| CasperInstr::decode(w).unwrap())
                .collect();
            assert_eq!(decoded, prog.instrs, "{k}");
        }
    }

    #[test]
    fn dynamic_instr_count() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();
        // 16 points at 8 lanes = 2 vector groups × 5 instrs.
        assert_eq!(prog.dynamic_instrs(16, 8), 10);
        // Non-multiple rounds up.
        assert_eq!(prog.dynamic_instrs(17, 8), 15);
    }

    #[test]
    fn validate_rejects_broken_programs() {
        let mut prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        prog.instrs[0].clear_acc = false;
        assert!(prog.validate().is_err());

        let mut prog2 = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        prog2.instrs[1].enable_output = true; // two outputs
        assert!(prog2.validate().is_err());

        let mut prog3 = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        prog3.instrs[0].stream_idx = 9; // dangling stream
        assert!(prog3.validate().is_err());
    }

    #[test]
    fn disasm_has_one_line_per_instr() {
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Heat3D.descriptor())
            .unwrap();
        assert_eq!(prog.disasm().lines().count(), 7);
    }
}
