//! PIMS comparator (§8.4): a processing-near-memory stencil accelerator
//! in the logic layer of a Hybrid Memory Cube [34].
//!
//! Following the paper's own methodology, PIMS is modelled *favourably*:
//! only the latency of the HMC atomic-add operations is charged, at the
//! peak atomic throughput reported by [157], bounded additionally by the
//! HMC's internal bandwidth. Host-side multiplies and result readback are
//! NOT charged (the paper's "conservative" setup). Because PIMS computes
//! inside the memory device, its performance is independent of whether
//! the working set fits in the CPU caches — which is exactly why Casper
//! wins on cache-resident sets and loses on DRAM-sized ones (Fig 13).

use crate::config::SimConfig;
use crate::stencil::{Domain, KernelSpec, StencilKind};

/// HMC-based PIMS parameters.
#[derive(Debug, Clone, Copy)]
pub struct PimsModel {
    /// Aggregate atomic-operation throughput, ops/s (peak from [157]).
    pub atomic_ops_per_s: f64,
    /// HMC internal bandwidth available to the atomic units, B/s.
    pub internal_bw: f64,
    /// Bytes moved inside the cube per atomic op (read-modify-write of an
    /// 8 B operand within a 16 B atomic request).
    pub bytes_per_op: f64,
}

impl Default for PimsModel {
    fn default() -> Self {
        PimsModel {
            atomic_ops_per_s: 35e9,
            internal_bw: 320e9,
            bytes_per_op: 16.0,
        }
    }
}

impl PimsModel {
    /// One atomic add per stencil tap per grid point.
    pub fn atomic_ops(&self, kind: StencilKind, domain: &Domain, steps: usize) -> u64 {
        self.atomic_ops_spec(&kind.spec(), domain, steps)
    }

    /// Spec-driven twin of [`atomic_ops`](Self::atomic_ops).
    pub fn atomic_ops_spec(&self, spec: &KernelSpec, domain: &Domain, steps: usize) -> u64 {
        (domain.points() * spec.num_points() * steps) as u64
    }

    /// Execution time in seconds.
    pub fn time_s(&self, kind: StencilKind, domain: &Domain, steps: usize) -> f64 {
        self.time_s_spec(&kind.spec(), domain, steps)
    }

    /// Spec-driven twin of [`time_s`](Self::time_s).
    pub fn time_s_spec(&self, spec: &KernelSpec, domain: &Domain, steps: usize) -> f64 {
        let ops = self.atomic_ops_spec(spec, domain, steps) as f64;
        let throughput_bound = ops / self.atomic_ops_per_s;
        let bw_bound = ops * self.bytes_per_op / self.internal_bw;
        throughput_bound.max(bw_bound)
    }

    /// In baseline-CPU cycles, for Fig 13.
    pub fn cycles(&self, cfg: &SimConfig, kind: StencilKind, domain: &Domain, steps: usize) -> u64 {
        self.cycles_spec(cfg, &kind.spec(), domain, steps)
    }

    /// Spec-driven twin of [`cycles`](Self::cycles).
    pub fn cycles_spec(
        &self,
        cfg: &SimConfig,
        spec: &KernelSpec,
        domain: &Domain,
        steps: usize,
    ) -> u64 {
        (self.time_s_spec(spec, domain, steps) * cfg.cpu.freq_ghz * 1e9).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SizeClass;

    #[test]
    fn op_counts() {
        let m = PimsModel::default();
        let d = Domain::new(100, 1, 1);
        assert_eq!(m.atomic_ops(StencilKind::Jacobi1D, &d, 1), 300);
        assert_eq!(m.atomic_ops(StencilKind::Jacobi1D, &d, 2), 600);
    }

    #[test]
    fn atomic_throughput_is_the_bottleneck() {
        // With the default parameters the throughput bound dominates the
        // internal-bandwidth bound (35 Gops × 16 B = 560 GB/s > 320 GB/s —
        // so actually bandwidth binds; either way time is positive and
        // monotone in taps).
        let m = PimsModel::default();
        let d = Domain::for_level(StencilKind::Jacobi2D, SizeClass::Llc);
        let t5 = m.time_s(StencilKind::Jacobi2D, &d, 1);
        let t25 = m.time_s(StencilKind::Blur2D, &d, 1);
        assert!(t25 > t5 * 4.0);
    }

    #[test]
    fn independent_of_cache_fit() {
        // PIMS time depends only on point × tap count — L2 vs LLC-sized
        // sets of the same point count would cost the same. (Different
        // domains here, so just check strict scaling with points.)
        let m = PimsModel::default();
        let small = Domain::new(1024, 1, 1);
        let big = Domain::new(4096, 1, 1);
        let ts = m.time_s(StencilKind::Jacobi1D, &small, 1);
        let tb = m.time_s(StencilKind::Jacobi1D, &big, 1);
        assert!((tb / ts - 4.0).abs() < 1e-9);
    }
}
