//! Randomized blackbox equivalence harness for the pass planner.
//!
//! The optimizing planner ([`PlanStrategy::Optimized`]) is *not* trusted
//! by construction: this module generates seeded random
//! envelope-stressing kernel specs, runs **both** plan strategies through
//! **both** engines (serial and epoch-parallel), and compares every grid
//! bit and reduction value against the plan-aware golden oracle
//! ([`golden::step_planned`]), plus the planner invariants that hold for
//! any legal plan:
//!
//! - every pass is ISA-envelope-legal (its compiled program validates);
//! - the passes partition the row groups exactly — no duplicate, no drop
//!   ([`check_partition`]);
//! - plans are deterministic for a given spec;
//! - `passes(Optimized) <= passes(Greedy)` on every spec;
//! - an order-preserving Optimized plan is **bitwise** the Greedy result;
//!   a reordering plan agrees to reassociation tolerance.
//!
//! On failure the offending spec is shrunk ([`shrink_spec`], built on
//! [`testutil::shrink_vec`](crate::testutil::shrink_vec)) to a minimal
//! reproducer and serialized as ready-to-commit kernel TOML — committed
//! reproducers live under `rust/tests/corpus/` and are replayed first by
//! `tests/plan_equivalence.rs`. The `casper verify` subcommand drives
//! [`run_verify`] from the CLI and CI (see `DESIGN.md`, "Blackbox plan
//! equivalence").

use crate::config::{SimConfig, SizeClass};
use crate::coordinator::{run_casper_spec, CasperOptions};
use crate::isa::{PassPlan, PlanStrategy, ProgramBuilder, ReduceOp};
use crate::stencil::{golden, Domain, Grid, KernelOrigin, KernelSpec, ReductionSpec, StencilPoint};
use crate::util::SplitMix64;

/// Knobs of one verification sweep (`casper verify`).
#[derive(Debug, Clone, Copy)]
pub struct VerifyOptions {
    /// Number of random specs to generate and check.
    pub specs: usize,
    /// Master seed: the whole sweep is a deterministic function of it.
    pub seed: u64,
    /// Jacobi steps per engine run (2 exercises the ping-pong swap).
    pub steps: usize,
}

impl Default for VerifyOptions {
    fn default() -> Self {
        VerifyOptions { specs: 64, seed: 0xCA5_9E12, steps: 2 }
    }
}

/// A failing case, minimized: everything needed to reproduce and commit.
#[derive(Debug, Clone)]
pub struct VerifyFailure {
    /// Index of the failing case within the sweep.
    pub case: usize,
    /// Id of the generated (pre-shrink) spec.
    pub spec_id: String,
    /// What the equivalence check reported.
    pub error: String,
    /// The shrunk reproducer, serialized in `--kernel-file` TOML format.
    pub minimized_toml: String,
}

/// Outcome of [`run_verify`]: how many specs passed, and the first
/// (minimized) failure if any.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// Specs that passed before the sweep stopped.
    pub checked: usize,
    /// First failure, already shrunk; `None` means the sweep is clean.
    pub failure: Option<VerifyFailure>,
}

/// Check that `passes` is an exact partition of `0..n_groups`: every
/// group exactly once, no empty pass, no out-of-range index. This is the
/// invariant separating "a different plan" from "a wrong plan", and it is
/// exposed on raw index lists (not [`PassPlan`]) so tests can plant a
/// deliberately corrupted partition and watch the harness catch it.
pub fn check_partition(n_groups: usize, passes: &[Vec<usize>]) -> Result<(), String> {
    if passes.is_empty() {
        return Err("plan has no passes".to_string());
    }
    let mut seen = vec![false; n_groups];
    for (pi, pass) in passes.iter().enumerate() {
        if pass.is_empty() {
            return Err(format!("pass {pi} is empty"));
        }
        for &gi in pass {
            if gi >= n_groups {
                return Err(format!(
                    "pass {pi} names row group {gi}, but the spec has only {n_groups}"
                ));
            }
            if seen[gi] {
                return Err(format!("row group {gi} is packed into two passes"));
            }
            seen[gi] = true;
        }
    }
    if let Some(gi) = seen.iter().position(|&s| !s) {
        return Err(format!("row group {gi} was dropped from the plan"));
    }
    Ok(())
}

/// The pure-planner invariants (no simulation): both strategies produce
/// exact-partition, envelope-legal, deterministic plans, and the
/// optimizing planner never plans more passes than greedy.
pub fn check_plans(spec: &KernelSpec) -> Result<(), String> {
    let groups = spec.row_groups();
    let mut counts = [0usize; 2];
    for (si, strategy) in PlanStrategy::ALL.into_iter().enumerate() {
        let plan = PassPlan::for_groups_with(&groups, strategy)
            .map_err(|e| format!("{strategy}: planning failed: {e:#}"))?;
        check_partition(groups.len(), plan.passes()).map_err(|e| format!("{strategy}: {e}"))?;
        let again = PassPlan::for_groups_with(&groups, strategy)
            .map_err(|e| format!("{strategy}: replanning failed: {e:#}"))?;
        if again != plan {
            return Err(format!("{strategy}: plan is not deterministic"));
        }
        let progs = ProgramBuilder::build_plan(spec, &groups, &plan)
            .map_err(|e| format!("{strategy}: pass compilation failed: {e:#}"))?;
        if progs.len() != plan.num_passes() {
            return Err(format!(
                "{strategy}: {} programs for a {}-pass plan",
                progs.len(),
                plan.num_passes()
            ));
        }
        for (pi, p) in progs.iter().enumerate() {
            p.validate()
                .map_err(|e| format!("{strategy}: pass {pi} violates the ISA envelope: {e:#}"))?;
        }
        counts[si] = plan.num_passes();
    }
    if counts[1] > counts[0] {
        return Err(format!(
            "optimized plans {} passes where greedy needs only {}",
            counts[1], counts[0]
        ));
    }
    Ok(())
}

/// Run the plan-aware oracle for `steps` with the engine's per-step fused
/// reduction semantics (a [`golden::reduce_arrays`] fold over each step's
/// input/output pair — bitwise what the leader computes).
fn oracle_run(desc: &KernelSpec, plan: &PassPlan, initial: &Grid, steps: usize) -> (Grid, Vec<f64>) {
    let mut a = initial.clone();
    let mut b = initial.clone();
    let mut values = Vec::new();
    for _ in 0..steps {
        golden::step_planned(desc, plan, &a, &mut b);
        if let Some(r) = desc.reduction {
            values.push(golden::reduce_arrays(r.op, &a.data, &b.data));
        }
        std::mem::swap(&mut a, &mut b);
    }
    (a, values)
}

fn bits_eq(a: &[f64], b: &[f64]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let err = (x - y).abs();
        if err > atol + rtol * y.abs() {
            return Err(format!("idx {i}: {x} vs {y} (|err| = {err:e})"));
        }
    }
    Ok(())
}

/// The full blackbox equivalence check for one spec at one domain: both
/// strategies × both engines, each pinned **bitwise** (grids and
/// reduction values) against the plan-aware golden oracle executing the
/// same plan, plus the cross-strategy contract — bitwise identity when
/// the optimized plan preserves program order, reassociation-tolerance
/// agreement when it reorders.
pub fn check_spec(
    cfg: &SimConfig,
    spec: &KernelSpec,
    domain: &Domain,
    steps: usize,
) -> Result<(), String> {
    check_plans(spec)?;
    let greedy = spec.pass_plan_with(PlanStrategy::Greedy).map_err(|e| format!("{e:#}"))?;
    let opt = spec.pass_plan_with(PlanStrategy::Optimized).map_err(|e| format!("{e:#}"))?;
    let input = domain.alloc_random(CasperOptions::default().seed);
    let mut oracle_grids: Vec<Grid> = Vec::new();
    for (strategy, plan) in [(PlanStrategy::Greedy, &greedy), (PlanStrategy::Optimized, &opt)] {
        let (want_grid, want_vals) = oracle_run(spec, plan, &input, steps);
        for threads in [1usize, 16] {
            let tag = format!("{strategy} threads={threads}");
            let opts = CasperOptions { plan: strategy, spu_threads: threads, ..Default::default() };
            let stats = run_casper_spec(cfg, spec, domain, steps, opts)
                .map_err(|e| format!("{tag}: engine error: {e:#}"))?;
            if stats.passes != plan.num_passes() {
                return Err(format!(
                    "{tag}: engine ran {} passes, plan has {}",
                    stats.passes,
                    plan.num_passes()
                ));
            }
            if !bits_eq(&stats.output.data, &want_grid.data) {
                return Err(format!("{tag}: grid diverged bitwise from the plan-aware oracle"));
            }
            match (&stats.reduction, spec.reduction) {
                (Some(r), Some(_)) => {
                    if !bits_eq(&r.values, &want_vals) {
                        return Err(format!(
                            "{tag}: reduction values diverged bitwise from the oracle"
                        ));
                    }
                }
                (None, Some(_)) => return Err(format!("{tag}: reduction result missing")),
                (Some(_), None) => return Err(format!("{tag}: unexpected reduction result")),
                (None, None) => {}
            }
        }
        oracle_grids.push(want_grid);
    }
    if opt.order_preserving() {
        if !bits_eq(&oracle_grids[0].data, &oracle_grids[1].data) {
            return Err(
                "order-preserving optimized plan diverged bitwise from greedy".to_string()
            );
        }
    } else {
        allclose(&oracle_grids[1].data, &oracle_grids[0].data, 1e-9, 1e-9)
            .map_err(|e| format!("reordered optimized plan left tolerance vs greedy: {e}"))?;
    }
    Ok(())
}

/// Shrink a failing spec to a minimal reproducer: greedily drop tap
/// chunks ([`testutil::shrink_vec`](crate::testutil::shrink_vec)) while
/// the candidate still validates and `fails` still holds. The result
/// keeps the original id/domains and serializes straight to committable
/// TOML via [`KernelSpec::to_toml_string`].
pub fn shrink_spec<F>(spec: &KernelSpec, mut fails: F) -> KernelSpec
where
    F: FnMut(&KernelSpec) -> bool,
{
    let min_points = crate::testutil::shrink_vec(spec.points.clone(), |pts| {
        let cand = KernelSpec { points: pts.to_vec(), ..spec.clone() };
        cand.validate().is_ok() && fails(&cand)
    });
    KernelSpec { points: min_points, ..spec.clone() }
}

/// Deterministic envelope-stressing spec generator. `case` selects the
/// stress mode (round-robin), `rng` everything else:
///
/// - `narrow`: fits a single program — the planner must degrade to the
///   trivial one-pass plan under both strategies.
/// - `wide`: 17–34 distinct rows — stream-buffer splits, multi-pass.
/// - `mix`: rows alternating two disjoint coefficient families — the
///   shape where affinity reordering wins passes.
/// - `shift`: few rows, many taps at `|dx|` up to the 3-bit shift limit,
///   every coefficient fresh — constant/instruction-budget splits and
///   maximal unaligned-load shifts.
pub fn random_spec(rng: &mut SplitMix64, case: usize) -> KernelSpec {
    let mut spec = match case % 4 {
        0 => narrow_spec(rng, case),
        1 => wide_spec(rng, case),
        2 => mix_spec(rng, case),
        _ => shift_spec(rng, case),
    };
    if rng.chance(0.3) {
        let op = [ReduceOp::Sum, ReduceOp::AbsDiff, ReduceOp::Max][rng.range(0, 3)];
        spec.reduction = Some(ReductionSpec { op });
    }
    let [rx, ry, rz] = spec.radius();
    let d = Domain::new(
        2 * rx + 4 + rng.range(0, 13),
        if spec.dims >= 2 { 2 * ry + 3 + rng.range(1, 9) } else { 1 },
        if spec.dims >= 3 { 2 * rz + 3 + rng.range(1, 5) } else { 1 },
    );
    spec.domains = [d; 3];
    spec
}

/// All (dy, dz) row offsets within the box, shuffled, first `n` taken.
fn pick_rows(rng: &mut SplitMix64, dims: usize, n: usize, ry: i64, rz: i64) -> Vec<(i64, i64)> {
    let mut combos: Vec<(i64, i64)> = Vec::new();
    for dz in -rz..=rz {
        for dy in -ry..=ry {
            if (dims < 3 && dz != 0) || (dims < 2 && dy != 0) {
                continue;
            }
            combos.push((dy, dz));
        }
    }
    for i in (1..combos.len()).rev() {
        let j = rng.range(0, i + 1);
        combos.swap(i, j);
    }
    combos.truncate(n);
    combos
}

/// Distinct in-row tap offsets: `k` values from `-rx..=rx`, shuffled.
fn pick_taps(rng: &mut SplitMix64, k: usize, rx: i64) -> Vec<i64> {
    let mut dxs: Vec<i64> = (-rx..=rx).collect();
    for i in (1..dxs.len()).rev() {
        let j = rng.range(0, i + 1);
        dxs.swap(i, j);
    }
    dxs.truncate(k);
    dxs
}

/// Mostly-shared coefficients (a small palette) keep constant pressure
/// realistic without forcing a split per row.
const PALETTE: [f64; 8] = [0.5, 0.25, 0.125, 0.0625, 0.03125, 0.2, 0.1, 0.05];

fn narrow_spec(rng: &mut SplitMix64, case: usize) -> KernelSpec {
    let dims = rng.range(1, 4);
    let rows = if dims == 1 { 1 } else { rng.range(1, 6) };
    let mut pts = Vec::new();
    for (dy, dz) in pick_rows(rng, dims, rows, 2, 1) {
        let k = rng.range(1, 4);
        for dx in pick_taps(rng, k, 2) {
            pts.push(StencilPoint::new(dx, dy, dz, PALETTE[rng.range(0, PALETTE.len())]));
        }
    }
    KernelSpec::new(
        &format!("verify_narrow_{case}"),
        &format!("verify narrow {case}"),
        dims,
        pts,
        KernelOrigin::File,
    )
}

fn wide_spec(rng: &mut SplitMix64, case: usize) -> KernelSpec {
    let dims = rng.range(2, 4);
    let (rows, ry, rz) = if dims == 2 {
        (rng.range(17, 22), 10, 0)
    } else {
        (rng.range(17, 35), 4, 4)
    };
    let mut pts = Vec::new();
    for (dy, dz) in pick_rows(rng, dims, rows, ry, rz) {
        let k = rng.range(1, 4);
        for dx in pick_taps(rng, k, 2) {
            // Fresh coefficients on a minority of taps stress the
            // constant buffer alongside the stream buffer.
            let coef = if rng.chance(0.25) {
                rng.next_f64() * 0.2 + 0.001
            } else {
                PALETTE[rng.range(0, PALETTE.len())]
            };
            pts.push(StencilPoint::new(dx, dy, dz, coef));
        }
    }
    KernelSpec::new(
        &format!("verify_wide_{case}"),
        &format!("verify wide {case}"),
        dims,
        pts,
        KernelOrigin::File,
    )
}

fn mix_spec(rng: &mut SplitMix64, case: usize) -> KernelSpec {
    // Interleaved disjoint coefficient families (positive vs negative
    // values, so they can never collide bitwise) — the wide_mix_2d shape,
    // randomized.
    let pairs = rng.range(5, 11) as i64;
    let fam_a: Vec<f64> = (0..15).map(|i| (i as f64 + 1.0 + rng.next_f64()) / 64.0).collect();
    let fam_b: Vec<f64> = (0..15).map(|i| -(i as f64 + 1.0 + rng.next_f64()) / 64.0).collect();
    let mut pts = Vec::new();
    for gi in 0..2 * pairs {
        let k = (gi / 2) as usize;
        let fam = if gi % 2 == 0 { &fam_a } else { &fam_b };
        for t in 0..3usize {
            pts.push(StencilPoint::new(t as i64 - 1, gi - pairs, 0, fam[(3 * k + t) % 15]));
        }
    }
    KernelSpec::new(
        &format!("verify_mix_{case}"),
        &format!("verify mix {case}"),
        2,
        pts,
        KernelOrigin::File,
    )
}

fn shift_spec(rng: &mut SplitMix64, case: usize) -> KernelSpec {
    let dims = rng.range(1, 4);
    let rows = if dims == 1 { 1 } else { rng.range(2, 5) };
    let mut pts = Vec::new();
    for (dy, dz) in pick_rows(rng, dims, rows, 1, 1) {
        let k = rng.range(4, 9);
        for dx in pick_taps(rng, k, 7) {
            // Every coefficient fresh: the constant buffer fills long
            // before the stream buffer does.
            pts.push(StencilPoint::new(dx, dy, dz, rng.next_f64() * 0.1 + 0.001));
        }
    }
    KernelSpec::new(
        &format!("verify_shift_{case}"),
        &format!("verify shift {case}"),
        dims,
        pts,
        KernelOrigin::File,
    )
}

/// Run a whole verification sweep: generate `opts.specs` random specs
/// from `opts.seed`, check each with [`check_spec`], and on the first
/// failure shrink it to a minimal reproducer. Deterministic end to end.
pub fn run_verify(cfg: &SimConfig, opts: &VerifyOptions) -> VerifyReport {
    let mut master = SplitMix64::new(opts.seed);
    for case in 0..opts.specs {
        let sub = master.next_u64();
        let spec = random_spec(&mut SplitMix64::new(sub), case);
        if let Err(e) = spec.validate() {
            // A generator bug is a harness failure too: report the raw
            // spec rather than silently skipping the case.
            return VerifyReport {
                checked: case,
                failure: Some(VerifyFailure {
                    case,
                    spec_id: spec.id.to_string(),
                    error: format!("generated spec does not validate: {e:#}"),
                    minimized_toml: spec.to_toml_string(),
                }),
            };
        }
        let domain = spec.domain(SizeClass::L2);
        if let Err(error) = check_spec(cfg, &spec, &domain, opts.steps) {
            let min = shrink_spec(&spec, |s| {
                check_spec(cfg, s, &s.domain(SizeClass::L2), opts.steps).is_err()
            });
            return VerifyReport {
                checked: case,
                failure: Some(VerifyFailure {
                    case,
                    spec_id: spec.id.to_string(),
                    error,
                    minimized_toml: min.to_toml_string(),
                }),
            };
        }
    }
    VerifyReport { checked: opts.specs, failure: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::StencilKind;

    #[test]
    fn partition_checker_catches_malformed_plans() {
        assert!(check_partition(3, &[vec![0, 1, 2]]).is_ok());
        assert!(check_partition(3, &[vec![2], vec![0, 1]]).is_ok());
        assert!(check_partition(3, &[]).unwrap_err().contains("no passes"));
        assert!(check_partition(3, &[vec![0, 1, 2], vec![]])
            .unwrap_err()
            .contains("empty"));
        assert!(check_partition(3, &[vec![0, 1], vec![1, 2]])
            .unwrap_err()
            .contains("two passes"));
        assert!(check_partition(3, &[vec![0, 2]]).unwrap_err().contains("dropped"));
        assert!(check_partition(3, &[vec![0, 1, 3]])
            .unwrap_err()
            .contains("only 3"));
    }

    #[test]
    fn generated_specs_validate_and_are_deterministic() {
        for case in 0..24 {
            let spec = random_spec(&mut SplitMix64::new(1000 + case as u64), case);
            spec.validate().unwrap_or_else(|e| panic!("case {case}: {e:#}"));
            check_plans(&spec).unwrap_or_else(|e| panic!("case {case}: {e}"));
            let again = random_spec(&mut SplitMix64::new(1000 + case as u64), case);
            assert_eq!(spec, again, "case {case}: generator must be deterministic");
            // Wide cases really exceed one program's envelope.
            if case % 4 == 1 {
                assert!(
                    spec.pass_plan().unwrap().is_multi_pass(),
                    "case {case}: wide spec fit a single pass"
                );
            }
        }
    }

    #[test]
    fn presets_pass_the_blackbox_check() {
        // The shipped kernels cover all three plan shapes: single-pass
        // (jacobi2d), order-preserving multi-pass (star17_3d), reordered
        // multi-pass (wide_mix_2d) — plus a fused reduction
        // (jacobi2d_res).
        let cfg = SimConfig::default();
        let mut specs = vec![StencilKind::Jacobi2D.descriptor()];
        specs.extend(
            crate::stencil::extended_presets()
                .into_iter()
                .filter(|s| matches!(s.id.as_str(), "star17_3d" | "wide_mix_2d" | "jacobi2d_res")),
        );
        assert_eq!(specs.len(), 4);
        for spec in &specs {
            check_spec(&cfg, spec, &spec.tiny_domain(), 2)
                .unwrap_or_else(|e| panic!("{}: {e}", spec.id));
        }
    }

    #[test]
    fn verify_sweep_smoke() {
        let cfg = SimConfig::default();
        let opts = VerifyOptions { specs: 4, seed: 0xCA5_9E12, steps: 1 };
        let report = run_verify(&cfg, &opts);
        assert!(report.failure.is_none(), "{:?}", report.failure);
        assert_eq!(report.checked, 4);
    }

    #[test]
    fn shrinker_minimizes_to_the_offending_tap() {
        // Plant a failure predicate ("the spec still contains the dx = 2
        // tap") on a fat spec: the shrinker must strip everything else.
        let mut pts: Vec<StencilPoint> =
            (-2..=2).map(|d| StencilPoint::new(d, 0, 0, 0.2)).collect();
        pts.extend((1..=2).flat_map(|d| {
            [StencilPoint::new(0, d, 0, 0.1), StencilPoint::new(0, -d, 0, 0.1)]
        }));
        let spec = KernelSpec::new("shrinkme", "shrink me", 2, pts, KernelOrigin::File);
        spec.validate().unwrap();
        let min = shrink_spec(&spec, |s| s.points.iter().any(|p| p.dx == 2));
        assert_eq!(min.points, vec![StencilPoint::new(2, 0, 0, 0.2)]);
        // The reproducer round-trips through the committable TOML format.
        let parsed = KernelSpec::from_toml_str(&min.to_toml_string()).unwrap();
        assert_eq!(parsed.points, min.points);
    }
}
