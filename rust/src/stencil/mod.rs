//! Stencil definitions: the open, data-driven kernel layer.
//!
//! [`KernelSpec`] (see [`spec`]) is the single source of truth for a
//! kernel — name, id, taps, dimensionality, per-size-class domain sizes
//! (see [`crate::config::SizeClass`]) — and [`KernelRegistry`] holds
//! the built-in presets plus any TOML-defined kernels. The six kernels of
//! the paper's §7.2 remain available through the [`StencilKind`] enum,
//! which is now just a preset constructor over the registry:
//!
//! | kernel       | dims | points | source                         |
//! |--------------|------|--------|--------------------------------|
//! | Jacobi 1D    | 1    | 3      | PolyBench `jacobi-1d`          |
//! | 7-point 1D   | 1    | 7      | Holewinski et al. [174]        |
//! | Jacobi 2D    | 2    | 5      | PolyBench `jacobi-2d`          |
//! | Blur 2D      | 2    | 25     | 5×5 Gaussian blur [173]        |
//! | 7-point 3D   | 3    | 7      | PolyBench `heat-3d` (1 stage)  |
//! | 33-point 3D  | 3    | 33     | high-order 3D stencil [43,175] |
//!
//! All are Jacobi-style stencils (disjoint read/write arrays) over
//! double-precision grids. The 33-point stencil is a 27-point box plus
//! the six distance-2 axis points; the paper does not publish the exact
//! coefficient set, so we use a normalized symmetric one (DESIGN.md §3).
//!
//! Beyond the paper, [`spec::extended_presets`] ships `hdiff` (NERO-style
//! horizontal diffusion), `star25_3d` (25-point high-order anisotropic 3D
//! star), `star17_3d` (the isotropic radius-4 star whose 17 rows
//! exceed the stream buffer — it compiles as a 2-pass plan, see
//! `docs/KERNELS.md`), `jacobi2d_res` (Jacobi 2D with a fused
//! `abs_diff` residual reduction), and `wide_mix_2d` (a 20-row
//! dual-coefficient-family column stencil where the optimizing pass
//! planner halves the greedy pass count), and user kernels load from
//! TOML files — see DESIGN.md, "Kernel registry".

pub mod domain;
pub mod golden;
pub mod grid;
pub mod spec;

use std::sync::{Arc, OnceLock};

pub use domain::Domain;
pub use grid::Grid;
pub use spec::{
    extended_presets, KernelId, KernelOrigin, KernelRegistry, KernelSpec, ReductionSpec, RowGroup,
    StencilPoint,
};

/// Historical name for a kernel's compute pattern; the spec now carries
/// identity and domains too, so the two types merged.
pub type StencilDesc = KernelSpec;

/// The six stencil kernels evaluated in the paper (§7.2), kept as a thin
/// preset constructor over [`KernelSpec`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StencilKind {
    Jacobi1D,
    Points7_1D,
    Jacobi2D,
    Blur2D,
    Heat3D,
    Points33_3D,
}

impl StencilKind {
    /// Paper ordering (used by every figure/table).
    pub const ALL: [StencilKind; 6] = [
        StencilKind::Jacobi1D,
        StencilKind::Points7_1D,
        StencilKind::Jacobi2D,
        StencilKind::Blur2D,
        StencilKind::Heat3D,
        StencilKind::Points33_3D,
    ];

    /// Name as printed in the paper's figures.
    pub fn name(self) -> &'static str {
        match self {
            StencilKind::Jacobi1D => "Jacobi 1D",
            StencilKind::Points7_1D => "7-point 1D",
            StencilKind::Jacobi2D => "Jacobi 2D",
            StencilKind::Blur2D => "Blur 2D",
            StencilKind::Heat3D => "7-point 3D",
            StencilKind::Points33_3D => "33-point 3D",
        }
    }

    /// Short machine-friendly id (artifact file names, CLI, registry key).
    pub fn id(self) -> &'static str {
        match self {
            StencilKind::Jacobi1D => "jacobi1d",
            StencilKind::Points7_1D => "pts7_1d",
            StencilKind::Jacobi2D => "jacobi2d",
            StencilKind::Blur2D => "blur2d",
            StencilKind::Heat3D => "heat3d",
            StencilKind::Points33_3D => "pts33_3d",
        }
    }

    pub fn parse(s: &str) -> Option<StencilKind> {
        let k = s.to_ascii_lowercase();
        StencilKind::ALL
            .into_iter()
            .find(|x| x.id() == k || x.name().to_ascii_lowercase().replace(' ', "") == k.replace([' ', '-', '_'], ""))
    }

    /// Grid dimensionality (1, 2, or 3).
    pub fn dims(self) -> usize {
        match self {
            StencilKind::Jacobi1D | StencilKind::Points7_1D => 1,
            StencilKind::Jacobi2D | StencilKind::Blur2D => 2,
            StencilKind::Heat3D | StencilKind::Points33_3D => 3,
        }
    }

    /// The shared preset spec (cheap `Arc` clone; built once per process).
    pub fn spec(self) -> Arc<KernelSpec> {
        static PAPER: OnceLock<[Arc<KernelSpec>; 6]> = OnceLock::new();
        let all = PAPER.get_or_init(|| StencilKind::ALL.map(|k| Arc::new(spec::paper_preset(k))));
        all[self as usize].clone()
    }

    /// An owned copy of the preset (the historical `descriptor()` shape).
    pub fn descriptor(self) -> StencilDesc {
        (*self.spec()).clone()
    }
}

impl std::fmt::Display for StencilKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tap_counts_match_paper() {
        assert_eq!(StencilKind::Jacobi1D.descriptor().num_points(), 3);
        assert_eq!(StencilKind::Points7_1D.descriptor().num_points(), 7);
        assert_eq!(StencilKind::Jacobi2D.descriptor().num_points(), 5);
        assert_eq!(StencilKind::Blur2D.descriptor().num_points(), 25);
        assert_eq!(StencilKind::Heat3D.descriptor().num_points(), 7);
        assert_eq!(StencilKind::Points33_3D.descriptor().num_points(), 33);
    }

    #[test]
    fn coefficients_normalized() {
        for k in StencilKind::ALL {
            let s = k.descriptor().coef_sum();
            assert!((s - 1.0).abs() < 1e-9, "{k}: coef sum {s}");
        }
    }

    #[test]
    fn radii() {
        assert_eq!(StencilKind::Jacobi1D.descriptor().radius(), [1, 0, 0]);
        assert_eq!(StencilKind::Points7_1D.descriptor().radius(), [3, 0, 0]);
        assert_eq!(StencilKind::Jacobi2D.descriptor().radius(), [1, 1, 0]);
        assert_eq!(StencilKind::Blur2D.descriptor().radius(), [2, 2, 0]);
        assert_eq!(StencilKind::Heat3D.descriptor().radius(), [1, 1, 1]);
        assert_eq!(StencilKind::Points33_3D.descriptor().radius(), [2, 2, 2]);
    }

    #[test]
    fn row_groups_match_streams() {
        // Jacobi 2D: rows dy=-1, dy=0 (3 taps), dy=+1 → 3 input streams,
        // exactly the Fig 8 example.
        let g = StencilKind::Jacobi2D.descriptor().row_groups();
        assert_eq!(g.len(), 3);
        assert_eq!(g[1].taps.len(), 3);
        // Blur 2D: 5 rows of 5 taps.
        let g = StencilKind::Blur2D.descriptor().row_groups();
        assert_eq!(g.len(), 5);
        assert!(g.iter().all(|r| r.taps.len() == 5));
        // 33-point: 9 z/y rows of 3 + 2 distance-2 y rows + 2 distance-2 z
        // rows... just check total taps add up.
        let g = StencilKind::Points33_3D.descriptor().row_groups();
        let taps: usize = g.iter().map(|r| r.taps.len()).sum();
        assert_eq!(taps, 33);
    }

    #[test]
    fn arithmetic_intensity_is_low() {
        // The paper's Fig 1 quotes AI between 0.09 and 0.2 FLOP/B for these
        // kernels; our traffic accounting lands in the same band (≤0.25).
        for k in StencilKind::ALL {
            let ai = k.descriptor().arithmetic_intensity();
            assert!(ai > 0.05 && ai < 0.3, "{k}: AI {ai}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for k in StencilKind::ALL {
            assert_eq!(StencilKind::parse(k.id()), Some(k));
        }
        assert_eq!(StencilKind::parse("jacobi2d"), Some(StencilKind::Jacobi2D));
        assert_eq!(StencilKind::parse("nope"), None);
    }

    #[test]
    fn spec_is_shared_and_matches_descriptor() {
        for k in StencilKind::ALL {
            let a = k.spec();
            let b = k.spec();
            assert!(Arc::ptr_eq(&a, &b), "{k}: preset must be interned");
            assert_eq!(*a, k.descriptor(), "{k}");
        }
    }
}
