//! Stencil definitions: the six kernels of §7.2, their coefficient
//! patterns, grids, domain sizes (Table 3), and a scalar golden reference.
//!
//! All six are Jacobi-style stencils (disjoint read/write arrays) over
//! double-precision grids, matching the paper:
//!
//! | kernel       | dims | points | source                         |
//! |--------------|------|--------|--------------------------------|
//! | Jacobi 1D    | 1    | 3      | PolyBench `jacobi-1d`          |
//! | 7-point 1D   | 1    | 7      | Holewinski et al. [174]        |
//! | Jacobi 2D    | 2    | 5      | PolyBench `jacobi-2d`          |
//! | Blur 2D      | 2    | 25     | 5×5 Gaussian blur [173]        |
//! | 7-point 3D   | 3    | 7      | PolyBench `heat-3d` (1 stage)  |
//! | 33-point 3D  | 3    | 33     | high-order 3D stencil [43,175] |
//!
//! The 33-point stencil is a 27-point box plus the six distance-2 axis
//! points — a standard higher-order discretization shape; the paper does
//! not publish the exact coefficient set, so we use a normalized symmetric
//! one (documented in DESIGN.md §3).

pub mod domain;
pub mod golden;
pub mod grid;

pub use domain::Domain;
pub use grid::Grid;

/// The six stencil kernels evaluated in the paper (§7.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum StencilKind {
    Jacobi1D,
    Points7_1D,
    Jacobi2D,
    Blur2D,
    Heat3D,
    Points33_3D,
}

impl StencilKind {
    /// Paper ordering (used by every figure/table).
    pub const ALL: [StencilKind; 6] = [
        StencilKind::Jacobi1D,
        StencilKind::Points7_1D,
        StencilKind::Jacobi2D,
        StencilKind::Blur2D,
        StencilKind::Heat3D,
        StencilKind::Points33_3D,
    ];

    /// Name as printed in the paper's figures.
    pub fn name(self) -> &'static str {
        match self {
            StencilKind::Jacobi1D => "Jacobi 1D",
            StencilKind::Points7_1D => "7-point 1D",
            StencilKind::Jacobi2D => "Jacobi 2D",
            StencilKind::Blur2D => "Blur 2D",
            StencilKind::Heat3D => "7-point 3D",
            StencilKind::Points33_3D => "33-point 3D",
        }
    }

    /// Short machine-friendly id (artifact file names, CLI).
    pub fn id(self) -> &'static str {
        match self {
            StencilKind::Jacobi1D => "jacobi1d",
            StencilKind::Points7_1D => "pts7_1d",
            StencilKind::Jacobi2D => "jacobi2d",
            StencilKind::Blur2D => "blur2d",
            StencilKind::Heat3D => "heat3d",
            StencilKind::Points33_3D => "pts33_3d",
        }
    }

    pub fn parse(s: &str) -> Option<StencilKind> {
        let k = s.to_ascii_lowercase();
        StencilKind::ALL
            .into_iter()
            .find(|x| x.id() == k || x.name().to_ascii_lowercase().replace(' ', "") == k.replace([' ', '-', '_'], ""))
    }

    /// Grid dimensionality (1, 2, or 3).
    pub fn dims(self) -> usize {
        match self {
            StencilKind::Jacobi1D | StencilKind::Points7_1D => 1,
            StencilKind::Jacobi2D | StencilKind::Blur2D => 2,
            StencilKind::Heat3D | StencilKind::Points33_3D => 3,
        }
    }

    /// The coefficient pattern.
    pub fn descriptor(self) -> StencilDesc {
        StencilDesc::of(self)
    }
}

impl std::fmt::Display for StencilKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One tap of a stencil: offset (in elements) and coefficient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StencilPoint {
    pub dx: i64,
    pub dy: i64,
    pub dz: i64,
    pub coef: f64,
}

impl StencilPoint {
    pub const fn new(dx: i64, dy: i64, dz: i64, coef: f64) -> Self {
        StencilPoint { dx, dy, dz, coef }
    }
}

/// Full description of a stencil's compute pattern.
#[derive(Debug, Clone, PartialEq)]
pub struct StencilDesc {
    pub kind: StencilKind,
    pub points: Vec<StencilPoint>,
}

impl StencilDesc {
    pub fn of(kind: StencilKind) -> StencilDesc {
        let points = match kind {
            StencilKind::Jacobi1D => {
                // PolyBench: B[i] = (A[i-1] + A[i] + A[i+1]) / 3
                let c = 1.0 / 3.0;
                vec![
                    StencilPoint::new(-1, 0, 0, c),
                    StencilPoint::new(0, 0, 0, c),
                    StencilPoint::new(1, 0, 0, c),
                ]
            }
            StencilKind::Points7_1D => {
                // Holewinski et al. 7-point 1D: symmetric radius-3 average.
                let c = 1.0 / 7.0;
                (-3..=3).map(|d| StencilPoint::new(d, 0, 0, c)).collect()
            }
            StencilKind::Jacobi2D => {
                // Paper §2.1 / Fig 8: 5-point, every tap × 0.2.
                let c = 0.2;
                vec![
                    StencilPoint::new(0, -1, 0, c),
                    StencilPoint::new(-1, 0, 0, c),
                    StencilPoint::new(0, 0, 0, c),
                    StencilPoint::new(1, 0, 0, c),
                    StencilPoint::new(0, 1, 0, c),
                ]
            }
            StencilKind::Blur2D => {
                // Canonical 5×5 Gaussian blur (σ≈1), integer kernel / 273.
                const W: [[f64; 5]; 5] = [
                    [1.0, 4.0, 7.0, 4.0, 1.0],
                    [4.0, 16.0, 26.0, 16.0, 4.0],
                    [7.0, 26.0, 41.0, 26.0, 7.0],
                    [4.0, 16.0, 26.0, 16.0, 4.0],
                    [1.0, 4.0, 7.0, 4.0, 1.0],
                ];
                let mut pts = Vec::with_capacity(25);
                for (j, row) in W.iter().enumerate() {
                    for (i, w) in row.iter().enumerate() {
                        pts.push(StencilPoint::new(i as i64 - 2, j as i64 - 2, 0, w / 273.0));
                    }
                }
                pts
            }
            StencilKind::Heat3D => {
                // 7-point heat diffusion: 0.4·center + 0.1·(6 face points).
                let mut pts = vec![StencilPoint::new(0, 0, 0, 0.4)];
                for (dx, dy, dz) in [
                    (-1, 0, 0),
                    (1, 0, 0),
                    (0, -1, 0),
                    (0, 1, 0),
                    (0, 0, -1),
                    (0, 0, 1),
                ] {
                    pts.push(StencilPoint::new(dx, dy, dz, 0.1));
                }
                pts
            }
            StencilKind::Points33_3D => {
                // 27-point box + 6 distance-2 axis points = 33 taps.
                // Weights by tap class, normalized to sum to 1 (total
                // weight 8 + 6·3 + 12·1.5 + 8·0.5 + 6·1 = 54):
                //   center 8/54, face(6) 3/54, edge(12) 1.5/54,
                //   corner(8) 0.5/54, axis-2(6) 1/54.
                let mut pts = Vec::with_capacity(33);
                for dz in -1i64..=1 {
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let dist = dx.abs() + dy.abs() + dz.abs();
                            let w = match dist {
                                0 => 8.0,
                                1 => 3.0,
                                2 => 1.5,
                                _ => 0.5,
                            } / 54.0;
                            pts.push(StencilPoint::new(dx, dy, dz, w));
                        }
                    }
                }
                for (dx, dy, dz) in [
                    (-2, 0, 0),
                    (2, 0, 0),
                    (0, -2, 0),
                    (0, 2, 0),
                    (0, 0, -2),
                    (0, 0, 2),
                ] {
                    pts.push(StencilPoint::new(dx, dy, dz, 1.0 / 54.0));
                }
                pts
            }
        };
        StencilDesc { kind, points }
    }

    /// Number of taps (input grid points per output point).
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    /// Halo radius along each axis `[rx, ry, rz]`.
    pub fn radius(&self) -> [usize; 3] {
        let mut r = [0i64; 3];
        for p in &self.points {
            r[0] = r[0].max(p.dx.abs());
            r[1] = r[1].max(p.dy.abs());
            r[2] = r[2].max(p.dz.abs());
        }
        [r[0] as usize, r[1] as usize, r[2] as usize]
    }

    /// FLOPs per output point: one MAC (2 flops) per tap.
    pub fn flops_per_point(&self) -> usize {
        2 * self.num_points()
    }

    /// Distinct `(dy, dz)` row-offsets — these become Casper *streams*:
    /// taps within one row share a stream and use shifted (unaligned)
    /// loads (§6). One extra stream is the output.
    pub fn row_groups(&self) -> Vec<RowGroup> {
        let mut groups: Vec<RowGroup> = Vec::new();
        for p in &self.points {
            match groups.iter_mut().find(|g| g.dy == p.dy && g.dz == p.dz) {
                Some(g) => g.taps.push((p.dx, p.coef)),
                None => groups.push(RowGroup {
                    dy: p.dy,
                    dz: p.dz,
                    taps: vec![(p.dx, p.coef)],
                }),
            }
        }
        for g in &mut groups {
            g.taps.sort_by_key(|t| t.0);
        }
        // Deterministic order: by (dz, dy).
        groups.sort_by_key(|g| (g.dz, g.dy));
        groups
    }

    /// Sum of coefficients (≈1.0 for all our kernels — averaging stencils).
    pub fn coef_sum(&self) -> f64 {
        self.points.iter().map(|p| p.coef).sum()
    }

    /// Arithmetic intensity in FLOP/B for the roofline (Fig 1): every tap
    /// read from cache plus the output store and its write-allocate fill,
    /// 8 B each — the no-register-reuse traffic a cache-level roofline sees.
    pub fn arithmetic_intensity(&self) -> f64 {
        let flops = self.flops_per_point() as f64;
        let bytes = (self.num_points() as f64 + 2.0) * 8.0;
        flops / bytes
    }
}

/// Taps sharing one row (same `dy`,`dz`): a single Casper stream.
#[derive(Debug, Clone, PartialEq)]
pub struct RowGroup {
    pub dy: i64,
    pub dz: i64,
    /// `(dx, coef)` per tap, sorted by `dx`.
    pub taps: Vec<(i64, f64)>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tap_counts_match_paper() {
        assert_eq!(StencilKind::Jacobi1D.descriptor().num_points(), 3);
        assert_eq!(StencilKind::Points7_1D.descriptor().num_points(), 7);
        assert_eq!(StencilKind::Jacobi2D.descriptor().num_points(), 5);
        assert_eq!(StencilKind::Blur2D.descriptor().num_points(), 25);
        assert_eq!(StencilKind::Heat3D.descriptor().num_points(), 7);
        assert_eq!(StencilKind::Points33_3D.descriptor().num_points(), 33);
    }

    #[test]
    fn coefficients_normalized() {
        for k in StencilKind::ALL {
            let s = k.descriptor().coef_sum();
            assert!((s - 1.0).abs() < 1e-9, "{k}: coef sum {s}");
        }
    }

    #[test]
    fn radii() {
        assert_eq!(StencilKind::Jacobi1D.descriptor().radius(), [1, 0, 0]);
        assert_eq!(StencilKind::Points7_1D.descriptor().radius(), [3, 0, 0]);
        assert_eq!(StencilKind::Jacobi2D.descriptor().radius(), [1, 1, 0]);
        assert_eq!(StencilKind::Blur2D.descriptor().radius(), [2, 2, 0]);
        assert_eq!(StencilKind::Heat3D.descriptor().radius(), [1, 1, 1]);
        assert_eq!(StencilKind::Points33_3D.descriptor().radius(), [2, 2, 2]);
    }

    #[test]
    fn row_groups_match_streams() {
        // Jacobi 2D: rows dy=-1, dy=0 (3 taps), dy=+1 → 3 input streams,
        // exactly the Fig 8 example.
        let g = StencilKind::Jacobi2D.descriptor().row_groups();
        assert_eq!(g.len(), 3);
        assert_eq!(g[1].taps.len(), 3);
        // Blur 2D: 5 rows of 5 taps.
        let g = StencilKind::Blur2D.descriptor().row_groups();
        assert_eq!(g.len(), 5);
        assert!(g.iter().all(|r| r.taps.len() == 5));
        // 33-point: 9 z/y rows of 3 + 2 distance-2 y rows + 2 distance-2 z
        // rows... just check total taps add up.
        let g = StencilKind::Points33_3D.descriptor().row_groups();
        let taps: usize = g.iter().map(|r| r.taps.len()).sum();
        assert_eq!(taps, 33);
    }

    #[test]
    fn arithmetic_intensity_is_low() {
        // The paper's Fig 1 quotes AI between 0.09 and 0.2 FLOP/B for these
        // kernels; our traffic accounting lands in the same band (≤0.25).
        for k in StencilKind::ALL {
            let ai = k.descriptor().arithmetic_intensity();
            assert!(ai > 0.05 && ai < 0.3, "{k}: AI {ai}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for k in StencilKind::ALL {
            assert_eq!(StencilKind::parse(k.id()), Some(k));
        }
        assert_eq!(StencilKind::parse("jacobi2d"), Some(StencilKind::Jacobi2D));
        assert_eq!(StencilKind::parse("nope"), None);
    }
}
