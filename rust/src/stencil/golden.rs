//! Scalar golden reference for every stencil — the correctness oracle the
//! SPU functional simulation and the PJRT-executed JAX artifacts are
//! checked against.
//!
//! Boundary convention (shared by the Rust simulator, the JAX model, and
//! the Pallas kernels): only interior points — those whose full tap set is
//! in bounds — are updated; boundary points copy through unchanged. This is
//! the PolyBench Jacobi convention generalized to each kernel's radius.

use super::{Domain, Grid, StencilDesc, StencilKind};

/// Apply one stencil step: read `src`, write `dst` (disjoint arrays,
/// Jacobi-style). Grids must share the domain shape.
pub fn step(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through.
    dst.data.copy_from_slice(&src.data);

    // Precompute linear offsets once (hot loop below is pure FMA).
    let offs: Vec<(isize, f64)> = desc
        .points
        .iter()
        .map(|p| (src.tap_offset(p.dx, p.dy, p.dz) as isize, p.coef))
        .collect();

    for z in rz..nz - rz {
        for y in ry..ny - ry {
            let row = src.index(0, y, z);
            for x in rx..nx - rx {
                let i = row + x;
                let mut acc = 0.0f64;
                for &(o, c) in &offs {
                    // Safety not needed: bounds guaranteed by interior loop
                    // ranges; use indexing to keep the oracle obviously safe.
                    acc += c * src.data[(i as isize + o) as usize];
                }
                dst.data[i] = acc;
            }
        }
    }
}

/// Run `steps` Jacobi iterations with array swapping. Returns the final
/// grid (which is `a` after an even number of steps, `b` after odd).
pub fn run(desc: &StencilDesc, initial: &Grid, steps: usize) -> Grid {
    let mut a = initial.clone();
    let mut b = initial.clone();
    for _ in 0..steps {
        step(desc, &a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// Convenience: run a kernel at a domain from a seeded random grid.
pub fn run_kind(kind: StencilKind, domain: &Domain, steps: usize, seed: u64) -> Grid {
    let desc = kind.descriptor();
    let g = domain.alloc_random(seed);
    run(&desc, &g, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_allclose;

    #[test]
    fn jacobi1d_hand_computed() {
        let desc = StencilKind::Jacobi1D.descriptor();
        let mut src = Grid::zeros(5, 1, 1);
        src.data.copy_from_slice(&[3.0, 6.0, 9.0, 12.0, 15.0]);
        let mut dst = Grid::zeros(5, 1, 1);
        step(&desc, &src, &mut dst);
        // interior: mean of 3 neighbours; boundary copied.
        assert_allclose(&dst.data, &[3.0, 6.0, 9.0, 12.0, 15.0], 1e-12, 1e-12);
        // non-linear data:
        src.data.copy_from_slice(&[1.0, 2.0, 4.0, 8.0, 16.0]);
        step(&desc, &src, &mut dst);
        assert_allclose(
            &dst.data,
            &[1.0, 7.0 / 3.0, 14.0 / 3.0, 28.0 / 3.0, 16.0],
            1e-12,
            1e-12,
        );
    }

    #[test]
    fn jacobi2d_hand_computed() {
        let desc = StencilKind::Jacobi2D.descriptor();
        let mut src = Grid::zeros(3, 3, 1);
        for (i, v) in (1..=9).enumerate() {
            src.data[i] = v as f64;
        }
        let mut dst = Grid::zeros(3, 3, 1);
        step(&desc, &src, &mut dst);
        // Only the center (1,1)=5 updates: 0.2*(2+4+5+6+8)=5.
        let mut want = src.data.clone();
        want[4] = 5.0;
        assert_allclose(&dst.data, &want, 1e-12, 1e-12);
    }

    #[test]
    fn constant_field_is_fixed_point() {
        // Coefficients sum to 1 → a constant grid is a fixed point for
        // every kernel (interior equals boundary). Strong whole-pattern
        // check.
        for k in StencilKind::ALL {
            let desc = k.descriptor();
            let d = Domain::tiny(k);
            let mut g = d.alloc();
            g.data.iter_mut().for_each(|v| *v = 2.5);
            let out = run(&desc, &g, 3);
            assert!(out.max_abs_diff(&g) < 1e-12, "{k}");
        }
    }

    #[test]
    fn smoothing_contracts_range() {
        // Averaging stencils shrink the value range on the interior.
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let g = d.alloc_random(99);
            let out = run(&k.descriptor(), &g, 2);
            let max_in = g.data.iter().cloned().fold(f64::MIN, f64::max);
            let max_out = out.data.iter().cloned().fold(f64::MIN, f64::max);
            assert!(max_out <= max_in + 1e-12, "{k}");
        }
    }

    #[test]
    fn symmetry_preserved() {
        // All kernels are symmetric in x: mirroring the input mirrors the
        // output.
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let g = d.alloc_random(7);
            let mut gm = g.clone();
            for z in 0..d.nz {
                for y in 0..d.ny {
                    for x in 0..d.nx {
                        gm.set(x, y, z, g.get(d.nx - 1 - x, y, z));
                    }
                }
            }
            let out = run(&k.descriptor(), &g, 1);
            let outm = run(&k.descriptor(), &gm, 1);
            for z in 0..d.nz {
                for y in 0..d.ny {
                    for x in 0..d.nx {
                        let a = out.get(d.nx - 1 - x, y, z);
                        let b = outm.get(x, y, z);
                        assert!((a - b).abs() < 1e-12, "{k} at ({x},{y},{z})");
                    }
                }
            }
        }
    }

    #[test]
    fn run_zero_steps_is_identity() {
        let d = Domain::tiny(StencilKind::Heat3D);
        let g = d.alloc_random(1);
        let out = run(&StencilKind::Heat3D.descriptor(), &g, 0);
        assert_eq!(out, g);
    }

    #[test]
    #[should_panic(expected = "domain smaller than halo")]
    fn rejects_too_small_domain() {
        let desc = StencilKind::Points7_1D.descriptor();
        let src = Grid::zeros(6, 1, 1);
        let mut dst = Grid::zeros(6, 1, 1);
        step(&desc, &src, &mut dst);
    }
}
