//! Golden reference for every stencil — the correctness oracle the SPU
//! functional simulation and the PJRT-executed JAX artifacts are checked
//! against.
//!
//! Boundary convention (shared by the Rust simulator, the JAX model, and
//! the Pallas kernels): only interior points — those whose full tap set is
//! in bounds — are updated; boundary points copy through unchanged. This is
//! the PolyBench Jacobi convention generalized to each kernel's radius.
//!
//! Two implementations, pinned bitwise-identical by test:
//!
//! - [`step_serial`] — the original scalar oracle, kept obviously correct.
//! - [`step`] / [`step_with_threads`] — the fast path: interior rows are
//!   partitioned into contiguous row bands farmed out over scoped threads,
//!   and each row runs a tap-outer kernel whose inner loop is a contiguous
//!   multiply-add over the row (autovectorizes). Per element it performs
//!   the *same additions in the same order* as the scalar oracle, so the
//!   result is bitwise identical at any thread count — which is what lets
//!   the functional cross-checks at DRAM-class sizes stop dominating wall
//!   time without weakening the oracle.
//!
//! (`f64::mul_add` is deliberately NOT used: without `-C target-feature=
//! +fma` it lowers to a libm call — slower, and bitwise-divergent from the
//! SPU model's `acc += c * v`.)

use super::{Domain, Grid, KernelSpec, StencilDesc, StencilKind};
use crate::util::auto_threads;

/// Apply one stencil step: read `src`, write `dst` (disjoint arrays,
/// Jacobi-style). Grids must share the domain shape. Parallel over row
/// bands; bitwise identical to [`step_serial`].
pub fn step(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    step_with_threads(desc, src, dst, auto_threads());
}

/// [`step`] with an explicit worker count (`1` runs on the caller's
/// thread). The result is independent of `threads`.
pub fn step_with_threads(desc: &StencilDesc, src: &Grid, dst: &mut Grid, threads: usize) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through.
    dst.data.copy_from_slice(&src.data);

    // Precompute linear offsets once (hot loop below is pure mul-add).
    let offs: Vec<(isize, f64)> = desc
        .points
        .iter()
        .map(|p| (src.tap_offset(p.dx, p.dy, p.dz) as isize, p.coef))
        .collect();

    // Partition the full (z, y) row space into contiguous bands; each band
    // owns a contiguous `dst` range (band rows × nx), so bands are handed
    // to scoped threads as disjoint `&mut` chunks. Boundary rows inside a
    // band are simply skipped — they were already copied through.
    let n_rows = ny * nz;
    let threads = threads.max(1).min(n_rows);
    let rows_per_band = n_rows.div_ceil(threads);
    let interior_row = |row: usize| {
        let (z, y) = (row / ny, row % ny);
        z >= rz && z < nz - rz && y >= ry && y < ny - ry
    };

    if threads == 1 {
        for row in 0..n_rows {
            if interior_row(row) {
                let band = &mut dst.data[row * nx..(row + 1) * nx];
                row_kernel(&offs, &src.data, band, row * nx, rx, nx);
            }
        }
        return;
    }

    let src_data = &src.data;
    let offs = &offs;
    std::thread::scope(|scope| {
        for (band_idx, band) in dst.data.chunks_mut(rows_per_band * nx).enumerate() {
            scope.spawn(move || {
                let row0 = band_idx * rows_per_band;
                let band_rows = band.len() / nx;
                for local in 0..band_rows {
                    let row = row0 + local;
                    if interior_row(row) {
                        let row_slice = &mut band[local * nx..(local + 1) * nx];
                        row_kernel(offs, src_data, row_slice, row * nx, rx, nx);
                    }
                }
            });
        }
    });
}

/// Compute one interior row's `[rx, nx - rx)` span into `dst_row` (the
/// full row slice). Tap-outer / x-inner: per element this accumulates the
/// taps in the same order as the scalar oracle (zero-init then `+= c * v`),
/// so the bits match; the inner loop is a contiguous mul-add the compiler
/// vectorizes.
#[inline]
fn row_kernel(
    offs: &[(isize, f64)],
    src: &[f64],
    dst_row: &mut [f64],
    row_base: usize,
    rx: usize,
    nx: usize,
) {
    let lo = rx;
    let hi = nx - rx;
    let out = &mut dst_row[lo..hi];
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for &(o, c) in offs {
        let start = (row_base + lo) as isize + o;
        let taps = &src[start as usize..start as usize + (hi - lo)];
        for (a, &v) in out.iter_mut().zip(taps) {
            *a += c * v;
        }
    }
}

/// The original scalar oracle, kept verbatim as the bitwise reference for
/// the vectorized/parallel [`step`].
pub fn step_serial(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through.
    dst.data.copy_from_slice(&src.data);

    // Precompute linear offsets once (hot loop below is pure FMA).
    let offs: Vec<(isize, f64)> = desc
        .points
        .iter()
        .map(|p| (src.tap_offset(p.dx, p.dy, p.dz) as isize, p.coef))
        .collect();

    for z in rz..nz - rz {
        for y in ry..ny - ry {
            let row = src.index(0, y, z);
            for x in rx..nx - rx {
                let i = row + x;
                let mut acc = 0.0f64;
                for &(o, c) in &offs {
                    // Safety not needed: bounds guaranteed by interior loop
                    // ranges; use indexing to keep the oracle obviously safe.
                    acc += c * src.data[(i as isize + o) as usize];
                }
                dst.data[i] = acc;
            }
        }
    }
}

/// The pass-split oracle: apply one stencil step exactly as the
/// multi-pass Casper engine does — pass by pass over the kernel's
/// [`PassPlan`](crate::isa::PassPlan), pass 0 writing partial sums and
/// every later pass accumulating on top (`acc = 1.0 · dst[i] + Σ taps`).
///
/// Taps accumulate in *program order* (row groups sorted by `(dz, dy)`,
/// in-row taps by `dx` — the `ProgramBuilder` emission order), and the
/// passes are contiguous ranges of that order, so the multi-pass sum is
/// the same left-to-right addition sequence as a single program's and the
/// result is **bitwise identical** to [`step_serial`] over the
/// program-ordered view of the kernel
/// ([`KernelSpec::program_ordered`](crate::stencil::KernelSpec::program_ordered))
/// — pinned by test here and property-tested over random wide specs in
/// `rust/tests/kernel_registry.rs`. For single-pass kernels it degrades
/// to exactly one plain partial-sum pass.
pub fn step_multipass(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through (identical to the single-pass oracles).
    dst.data.copy_from_slice(&src.data);

    let groups = desc.row_groups();
    let plan = desc.pass_plan().expect("validated spec must plan");
    for (pi, pass) in plan.passes().iter().enumerate() {
        // This pass's taps, flattened in program order.
        let mut offs: Vec<(isize, f64)> = Vec::new();
        for g in &groups[pass.clone()] {
            for &(dx, c) in &g.taps {
                offs.push((src.tap_offset(dx, g.dy, g.dz) as isize, c));
            }
        }
        for z in rz..nz - rz {
            for y in ry..ny - ry {
                let row = src.index(0, y, z);
                for x in rx..nx - rx {
                    let i = row + x;
                    // Later passes reload the previous pass's partial sum
                    // through the accumulator stream: `acc = 1.0 · out[i]`
                    // (exact, so the bits carry through — written here as
                    // the identity it is).
                    let mut acc = if pi == 0 { 0.0f64 } else { dst.data[i] };
                    for &(o, c) in &offs {
                        acc += c * src.data[(i as isize + o) as usize];
                    }
                    dst.data[i] = acc;
                }
            }
        }
    }
}

/// [`run`] through the pass-split oracle [`step_multipass`]: `steps`
/// Jacobi iterations with array swapping.
pub fn run_multipass(desc: &StencilDesc, initial: &Grid, steps: usize) -> Grid {
    let mut a = initial.clone();
    let mut b = initial.clone();
    for _ in 0..steps {
        step_multipass(desc, &a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// Run `steps` Jacobi iterations with array swapping. Returns the final
/// grid (which is `a` after an even number of steps, `b` after odd).
pub fn run(desc: &StencilDesc, initial: &Grid, steps: usize) -> Grid {
    let mut a = initial.clone();
    let mut b = initial.clone();
    for _ in 0..steps {
        step(desc, &a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// Convenience: run a preset kernel at a domain from a seeded random grid.
pub fn run_kind(kind: StencilKind, domain: &Domain, steps: usize, seed: u64) -> Grid {
    run_spec(&kind.spec(), domain, steps, seed)
}

/// Convenience: run any [`KernelSpec`] at a domain from a seeded random
/// grid — the spec-driven twin of [`run_kind`].
pub fn run_spec(spec: &KernelSpec, domain: &Domain, steps: usize, seed: u64) -> Grid {
    let g = domain.alloc_random(seed);
    run(spec, &g, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_allclose;

    #[test]
    fn jacobi1d_hand_computed() {
        let desc = StencilKind::Jacobi1D.descriptor();
        let mut src = Grid::zeros(5, 1, 1);
        src.data.copy_from_slice(&[3.0, 6.0, 9.0, 12.0, 15.0]);
        let mut dst = Grid::zeros(5, 1, 1);
        step(&desc, &src, &mut dst);
        // interior: mean of 3 neighbours; boundary copied.
        assert_allclose(&dst.data, &[3.0, 6.0, 9.0, 12.0, 15.0], 1e-12, 1e-12);
        // non-linear data:
        src.data.copy_from_slice(&[1.0, 2.0, 4.0, 8.0, 16.0]);
        step(&desc, &src, &mut dst);
        assert_allclose(
            &dst.data,
            &[1.0, 7.0 / 3.0, 14.0 / 3.0, 28.0 / 3.0, 16.0],
            1e-12,
            1e-12,
        );
    }

    #[test]
    fn jacobi2d_hand_computed() {
        let desc = StencilKind::Jacobi2D.descriptor();
        let mut src = Grid::zeros(3, 3, 1);
        for (i, v) in (1..=9).enumerate() {
            src.data[i] = v as f64;
        }
        let mut dst = Grid::zeros(3, 3, 1);
        step(&desc, &src, &mut dst);
        // Only the center (1,1)=5 updates: 0.2*(2+4+5+6+8)=5.
        let mut want = src.data.clone();
        want[4] = 5.0;
        assert_allclose(&dst.data, &want, 1e-12, 1e-12);
    }

    #[test]
    fn parallel_step_is_bitwise_identical_to_serial() {
        // The satellite contract: the banded/vectorized step must equal
        // the scalar oracle BIT FOR BIT, for every kernel, at several
        // thread counts (including more threads than rows).
        for k in StencilKind::ALL {
            let desc = k.descriptor();
            let d = Domain::tiny(k);
            let src = d.alloc_random(0xB17_1D);
            let mut want = d.alloc();
            step_serial(&desc, &src, &mut want);
            for threads in [1usize, 2, 3, 7, 16, 64] {
                let mut got = d.alloc();
                step_with_threads(&desc, &src, &mut got, threads);
                assert!(
                    got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{k}: threads={threads} diverged bitwise from the scalar oracle"
                );
            }
        }
    }

    #[test]
    fn multipass_step_is_bitwise_identical_to_program_ordered_serial() {
        // The pass-split contract: splitting a kernel into accumulating
        // passes must not change a single bit relative to the unsplit
        // scalar oracle accumulating in the same (program) order — for
        // the paper six (1 pass) AND the extended presets including the
        // 2-pass star17_3d.
        let mut specs: Vec<KernelSpec> = StencilKind::ALL.iter().map(|k| k.descriptor()).collect();
        specs.extend(crate::stencil::extended_presets());
        for spec in &specs {
            let d = spec.tiny_domain();
            let src = d.alloc_random(0x9A55);
            let mut want = d.alloc();
            step_serial(&spec.program_ordered(), &src, &mut want);
            let mut got = d.alloc();
            step_multipass(spec, &src, &mut got);
            assert!(
                got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{}: pass-split oracle diverged bitwise from the serial oracle",
                spec.id
            );
        }
    }

    #[test]
    fn multipass_run_swaps_like_run() {
        // Multi-step ping-pong through the pass-split oracle: for a spec
        // whose taps already sit in program order, run_multipass must be
        // bitwise-identical to the plain banded `run`.
        let spec = StencilKind::Blur2D.descriptor();
        assert_eq!(spec.program_ordered().points, spec.points, "Blur2D is program-ordered");
        let d = spec.tiny_domain();
        let g = d.alloc_random(0x5EED);
        let a = run(&spec, &g, 3);
        let b = run_multipass(&spec, &g, 3);
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "multi-step pass-split run diverged"
        );
        assert_eq!(run_multipass(&spec, &g, 0), g);
    }

    #[test]
    fn constant_field_is_fixed_point() {
        // Coefficients sum to 1 → a constant grid is a fixed point for
        // every kernel (interior equals boundary). Strong whole-pattern
        // check.
        for k in StencilKind::ALL {
            let desc = k.descriptor();
            let d = Domain::tiny(k);
            let mut g = d.alloc();
            g.data.iter_mut().for_each(|v| *v = 2.5);
            let out = run(&desc, &g, 3);
            assert!(out.max_abs_diff(&g) < 1e-12, "{k}");
        }
    }

    #[test]
    fn smoothing_contracts_range() {
        // Averaging stencils shrink the value range on the interior.
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let g = d.alloc_random(99);
            let out = run(&k.descriptor(), &g, 2);
            let max_in = g.data.iter().cloned().fold(f64::MIN, f64::max);
            let max_out = out.data.iter().cloned().fold(f64::MIN, f64::max);
            assert!(max_out <= max_in + 1e-12, "{k}");
        }
    }

    #[test]
    fn symmetry_preserved() {
        // All kernels are symmetric in x: mirroring the input mirrors the
        // output.
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let g = d.alloc_random(7);
            let mut gm = g.clone();
            for z in 0..d.nz {
                for y in 0..d.ny {
                    for x in 0..d.nx {
                        gm.set(x, y, z, g.get(d.nx - 1 - x, y, z));
                    }
                }
            }
            let out = run(&k.descriptor(), &g, 1);
            let outm = run(&k.descriptor(), &gm, 1);
            for z in 0..d.nz {
                for y in 0..d.ny {
                    for x in 0..d.nx {
                        let a = out.get(d.nx - 1 - x, y, z);
                        let b = outm.get(x, y, z);
                        assert!((a - b).abs() < 1e-12, "{k} at ({x},{y},{z})");
                    }
                }
            }
        }
    }

    #[test]
    fn run_zero_steps_is_identity() {
        let d = Domain::tiny(StencilKind::Heat3D);
        let g = d.alloc_random(1);
        let out = run(&StencilKind::Heat3D.descriptor(), &g, 0);
        assert_eq!(out, g);
    }

    #[test]
    #[should_panic(expected = "domain smaller than halo")]
    fn rejects_too_small_domain() {
        let desc = StencilKind::Points7_1D.descriptor();
        let src = Grid::zeros(6, 1, 1);
        let mut dst = Grid::zeros(6, 1, 1);
        step(&desc, &src, &mut dst);
    }

    #[test]
    #[should_panic(expected = "domain smaller than halo")]
    fn serial_rejects_too_small_domain() {
        let desc = StencilKind::Points7_1D.descriptor();
        let src = Grid::zeros(6, 1, 1);
        let mut dst = Grid::zeros(6, 1, 1);
        step_serial(&desc, &src, &mut dst);
    }
}
