//! Golden reference for every stencil — the correctness oracle the SPU
//! functional simulation and the PJRT-executed JAX artifacts are checked
//! against.
//!
//! Boundary convention (shared by the Rust simulator, the JAX model, and
//! the Pallas kernels): only interior points — those whose full tap set is
//! in bounds — are updated; boundary points copy through unchanged. This is
//! the PolyBench Jacobi convention generalized to each kernel's radius.
//!
//! Two implementations, pinned bitwise-identical by test:
//!
//! - [`step_serial`] — the original scalar oracle, kept obviously correct.
//! - [`step`] / [`step_with_threads`] — the fast path: interior rows are
//!   partitioned into contiguous row bands farmed out over scoped threads,
//!   and each row runs a tap-outer kernel whose inner loop is a contiguous
//!   multiply-add over the row (autovectorizes). Per element it performs
//!   the *same additions in the same order* as the scalar oracle, so the
//!   result is bitwise identical at any thread count — which is what lets
//!   the functional cross-checks at DRAM-class sizes stop dominating wall
//!   time without weakening the oracle.
//!
//! (`f64::mul_add` is deliberately NOT used: without `-C target-feature=
//! +fma` it lowers to a libm call — slower, and bitwise-divergent from the
//! SPU model's `acc += c * v`.)

use super::{Domain, Grid, KernelSpec, StencilDesc, StencilKind};
use crate::isa::{PassPlan, ReduceOp};
use crate::util::auto_threads;

/// Apply one stencil step: read `src`, write `dst` (disjoint arrays,
/// Jacobi-style). Grids must share the domain shape. Parallel over row
/// bands; bitwise identical to [`step_serial`].
pub fn step(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    step_with_threads(desc, src, dst, auto_threads());
}

/// [`step`] with an explicit worker count (`1` runs on the caller's
/// thread). The result is independent of `threads`.
pub fn step_with_threads(desc: &StencilDesc, src: &Grid, dst: &mut Grid, threads: usize) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through.
    dst.data.copy_from_slice(&src.data);

    // Precompute linear offsets once (hot loop below is pure mul-add).
    let offs: Vec<(isize, f64)> = desc
        .points
        .iter()
        .map(|p| (src.tap_offset(p.dx, p.dy, p.dz) as isize, p.coef))
        .collect();

    // Partition the full (z, y) row space into contiguous bands; each band
    // owns a contiguous `dst` range (band rows × nx), so bands are handed
    // to scoped threads as disjoint `&mut` chunks. Boundary rows inside a
    // band are simply skipped — they were already copied through.
    let n_rows = ny * nz;
    let threads = threads.max(1).min(n_rows);
    let rows_per_band = n_rows.div_ceil(threads);
    let interior_row = |row: usize| {
        let (z, y) = (row / ny, row % ny);
        z >= rz && z < nz - rz && y >= ry && y < ny - ry
    };

    if threads == 1 {
        for row in 0..n_rows {
            if interior_row(row) {
                let band = &mut dst.data[row * nx..(row + 1) * nx];
                row_kernel(&offs, &src.data, band, row * nx, rx, nx);
            }
        }
        return;
    }

    let src_data = &src.data;
    let offs = &offs;
    std::thread::scope(|scope| {
        for (band_idx, band) in dst.data.chunks_mut(rows_per_band * nx).enumerate() {
            scope.spawn(move || {
                let row0 = band_idx * rows_per_band;
                let band_rows = band.len() / nx;
                for local in 0..band_rows {
                    let row = row0 + local;
                    if interior_row(row) {
                        let row_slice = &mut band[local * nx..(local + 1) * nx];
                        row_kernel(offs, src_data, row_slice, row * nx, rx, nx);
                    }
                }
            });
        }
    });
}

/// Compute one interior row's `[rx, nx - rx)` span into `dst_row` (the
/// full row slice). Tap-outer / x-inner: per element this accumulates the
/// taps in the same order as the scalar oracle (zero-init then `+= c * v`),
/// so the bits match; the inner loop is a contiguous mul-add the compiler
/// vectorizes.
#[inline]
fn row_kernel(
    offs: &[(isize, f64)],
    src: &[f64],
    dst_row: &mut [f64],
    row_base: usize,
    rx: usize,
    nx: usize,
) {
    let lo = rx;
    let hi = nx - rx;
    let out = &mut dst_row[lo..hi];
    for v in out.iter_mut() {
        *v = 0.0;
    }
    for &(o, c) in offs {
        let start = (row_base + lo) as isize + o;
        let taps = &src[start as usize..start as usize + (hi - lo)];
        for (a, &v) in out.iter_mut().zip(taps) {
            *a += c * v;
        }
    }
}

/// The original scalar oracle, kept verbatim as the bitwise reference for
/// the vectorized/parallel [`step`].
pub fn step_serial(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through.
    dst.data.copy_from_slice(&src.data);

    // Precompute linear offsets once (hot loop below is pure FMA).
    let offs: Vec<(isize, f64)> = desc
        .points
        .iter()
        .map(|p| (src.tap_offset(p.dx, p.dy, p.dz) as isize, p.coef))
        .collect();

    for z in rz..nz - rz {
        for y in ry..ny - ry {
            let row = src.index(0, y, z);
            for x in rx..nx - rx {
                let i = row + x;
                let mut acc = 0.0f64;
                for &(o, c) in &offs {
                    // Safety not needed: bounds guaranteed by interior loop
                    // ranges; use indexing to keep the oracle obviously safe.
                    acc += c * src.data[(i as isize + o) as usize];
                }
                dst.data[i] = acc;
            }
        }
    }
}

/// The pass-split oracle: apply one stencil step exactly as the
/// multi-pass Casper engine does — pass by pass over the kernel's
/// [`PassPlan`](crate::isa::PassPlan), pass 0 writing partial sums and
/// every later pass accumulating on top (`acc = 1.0 · dst[i] + Σ taps`).
///
/// Taps accumulate in *program order* (row groups sorted by `(dz, dy)`,
/// in-row taps by `dx` — the `ProgramBuilder` emission order), and the
/// passes are contiguous ranges of that order, so the multi-pass sum is
/// the same left-to-right addition sequence as a single program's and the
/// result is **bitwise identical** to [`step_serial`] over the
/// program-ordered view of the kernel
/// ([`KernelSpec::program_ordered`](crate::stencil::KernelSpec::program_ordered))
/// — pinned by test here and property-tested over random wide specs in
/// `rust/tests/kernel_registry.rs`. For single-pass kernels it degrades
/// to exactly one plain partial-sum pass.
///
/// This is the greedy-plan wrapper around [`step_planned`]; the
/// equivalence harness ([`crate::verify`]) calls `step_planned` directly
/// to oracle arbitrary (possibly reordered) plans.
pub fn step_multipass(desc: &StencilDesc, src: &Grid, dst: &mut Grid) {
    let plan = desc.pass_plan().expect("validated spec must plan");
    step_planned(desc, &plan, src, dst);
}

/// The pass-split oracle under an explicit [`PassPlan`]: apply one step
/// pass by pass, each pass accumulating exactly the row groups the plan
/// assigns it (in the plan's order — for an order-preserving plan this is
/// program order and the result is bitwise [`step_multipass`]; a
/// reordered plan accumulates in *its* order, which is what the engine
/// executing the same plan does too).
pub fn step_planned(desc: &StencilDesc, plan: &PassPlan, src: &Grid, dst: &mut Grid) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    // Boundary copy-through (identical to the single-pass oracles).
    dst.data.copy_from_slice(&src.data);

    let groups = desc.row_groups();
    for (pi, pass) in plan.passes().iter().enumerate() {
        // This pass's taps, flattened in the plan's group order.
        let mut offs: Vec<(isize, f64)> = Vec::new();
        for &gi in pass {
            let g = &groups[gi];
            for &(dx, c) in &g.taps {
                offs.push((src.tap_offset(dx, g.dy, g.dz) as isize, c));
            }
        }
        for z in rz..nz - rz {
            for y in ry..ny - ry {
                let row = src.index(0, y, z);
                for x in rx..nx - rx {
                    let i = row + x;
                    // Later passes reload the previous pass's partial sum
                    // through the accumulator stream: `acc = 1.0 · out[i]`
                    // (exact, so the bits carry through — written here as
                    // the identity it is).
                    let mut acc = if pi == 0 { 0.0f64 } else { dst.data[i] };
                    for &(o, c) in &offs {
                        acc += c * src.data[(i as isize + o) as usize];
                    }
                    dst.data[i] = acc;
                }
            }
        }
    }
}

/// [`run`] through the pass-split oracle [`step_multipass`]: `steps`
/// Jacobi iterations with array swapping.
pub fn run_multipass(desc: &StencilDesc, initial: &Grid, steps: usize) -> Grid {
    let plan = desc.pass_plan().expect("validated spec must plan");
    run_planned(desc, &plan, initial, steps)
}

/// [`run`] through [`step_planned`] under an explicit plan: `steps`
/// Jacobi iterations with array swapping — the blackbox oracle the
/// equivalence harness compares both plan strategies against.
pub fn run_planned(desc: &StencilDesc, plan: &PassPlan, initial: &Grid, steps: usize) -> Grid {
    let mut a = initial.clone();
    let mut b = initial.clone();
    for _ in 0..steps {
        step_planned(desc, plan, &a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// One stencil step restricted to the flattened `(z, y)` row range
/// `[row_lo, row_hi)` — the building block of the temporal-blocking
/// oracle [`run_blocked`]. Everything outside the range (and every
/// boundary point inside it) copies through from `src`, exactly like the
/// full-step oracles; interior rows inside the range accumulate taps in
/// the same order as [`step_serial`], so a computed element is bitwise
/// what the full step would have produced.
pub fn step_blocked(desc: &StencilDesc, src: &Grid, dst: &mut Grid, row_lo: usize, row_hi: usize) {
    assert_eq!((src.nx, src.ny, src.nz), (dst.nx, dst.ny, dst.nz), "shape mismatch");
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (src.nx, src.ny, src.nz);
    assert!(nx > 2 * rx && ny > 2 * ry && nz > 2 * rz, "domain smaller than halo");

    dst.data.copy_from_slice(&src.data);

    let offs: Vec<(isize, f64)> = desc
        .points
        .iter()
        .map(|p| (src.tap_offset(p.dx, p.dy, p.dz) as isize, p.coef))
        .collect();

    for row in row_lo..row_hi.min(ny * nz) {
        let (z, y) = (row / ny, row % ny);
        if z < rz || z >= nz - rz || y < ry || y >= ny - ry {
            continue;
        }
        let base = row * nx;
        for x in rx..nx - rx {
            let i = base + x;
            let mut acc = 0.0f64;
            for &(o, c) in &offs {
                acc += c * src.data[(i as isize + o) as usize];
            }
            dst.data[i] = acc;
        }
    }
}

/// The temporal-blocking oracle: `steps` iterations processed in time
/// blocks of up to `t` steps over `bands` row bands. Within a block each
/// band advances its own rows `t_blk` steps on private scratch grids,
/// *recomputing* a halo of `r_row · (t_blk − 1 − s)` extra rows at inner
/// step `s` instead of exchanging them (`r_row = rz·ny + ry`, the
/// dependency footprint in flattened row space) — the trapezoid scheme
/// the Casper engine's `--temporal-block` mode models. The shrinking row
/// ranges guarantee every element a band keeps is computed from exactly
/// the values plain chaining would have used, so the result is **bitwise
/// identical** to [`run`] for every `t` and `bands` (pinned by test).
pub fn run_blocked(desc: &StencilDesc, initial: &Grid, steps: usize, t: usize, bands: usize) -> Grid {
    assert!(t >= 1, "temporal block must be >= 1");
    let (nx, ny, nz) = (initial.nx, initial.ny, initial.nz);
    let n_rows = ny * nz;
    let [_, ry, rz] = desc.radius();
    let r_row = rz * ny + ry;
    let bands = bands.max(1).min(n_rows);
    let rows_per_band = n_rows.div_ceil(bands);

    let mut cur = initial.clone();
    let mut out = initial.clone();
    let mut done = 0usize;
    while done < steps {
        let t_blk = t.min(steps - done);
        for band in 0..bands {
            let lo = band * rows_per_band;
            if lo >= n_rows {
                break;
            }
            let hi = (lo + rows_per_band).min(n_rows);
            // Private ping-pong scratch seeded from the block input: the
            // halo rows are *recomputed* here rather than fetched from
            // neighbouring bands mid-block.
            let mut a = cur.clone();
            let mut b = cur.clone();
            for s in 0..t_blk {
                let grow = r_row * (t_blk - 1 - s);
                step_blocked(desc, &a, &mut b, lo.saturating_sub(grow), hi + grow);
                std::mem::swap(&mut a, &mut b);
            }
            out.data[lo * nx..hi * nx].copy_from_slice(&a.data[lo * nx..hi * nx]);
        }
        std::mem::swap(&mut cur, &mut out);
        done += t_blk;
    }
    cur
}

/// Fold an output array (and, for `abs_diff`, its input) into one scalar
/// in ascending linear element order — the architected semantics of a
/// fused reduction (the leader's deterministic `(round, spu, seq)`
/// combining order is exactly this order). Shared by the golden two-pass
/// reference and the engine, so "bitwise equal" is by construction.
pub fn reduce_arrays(op: ReduceOp, input: &[f64], output: &[f64]) -> f64 {
    assert_eq!(input.len(), output.len(), "shape mismatch");
    match op {
        ReduceOp::Sum => output.iter().fold(0.0f64, |acc, &v| acc + v),
        ReduceOp::AbsDiff => output
            .iter()
            .zip(input)
            .fold(0.0f64, |acc, (&o, &i)| acc + (o - i).abs()),
        ReduceOp::Max => output.iter().fold(f64::NEG_INFINITY, |acc, &v| acc.max(v)),
    }
}

/// The two-pass reduction reference: run `steps` iterations, computing
/// each step's reduction as a *separate* pass over the arrays after the
/// stencil pass — the unfused baseline the fused engine is pinned
/// against. Returns the final grid and the per-step reduction values.
/// `desc` must carry a [`reduction`](KernelSpec::reduction) section.
pub fn run_reduced(desc: &StencilDesc, initial: &Grid, steps: usize) -> (Grid, Vec<f64>) {
    let op = desc
        .reduction
        .expect("run_reduced needs a kernel with a [reduction] section")
        .op;
    let mut a = initial.clone();
    let mut b = initial.clone();
    let mut values = Vec::with_capacity(steps);
    for _ in 0..steps {
        step(desc, &a, &mut b);
        values.push(reduce_arrays(op, &a.data, &b.data));
        std::mem::swap(&mut a, &mut b);
    }
    (a, values)
}

/// Run `steps` Jacobi iterations with array swapping. Returns the final
/// grid (which is `a` after an even number of steps, `b` after odd).
pub fn run(desc: &StencilDesc, initial: &Grid, steps: usize) -> Grid {
    let mut a = initial.clone();
    let mut b = initial.clone();
    for _ in 0..steps {
        step(desc, &a, &mut b);
        std::mem::swap(&mut a, &mut b);
    }
    a
}

/// Convenience: run a preset kernel at a domain from a seeded random grid.
pub fn run_kind(kind: StencilKind, domain: &Domain, steps: usize, seed: u64) -> Grid {
    run_spec(&kind.spec(), domain, steps, seed)
}

/// Convenience: run any [`KernelSpec`] at a domain from a seeded random
/// grid — the spec-driven twin of [`run_kind`].
pub fn run_spec(spec: &KernelSpec, domain: &Domain, steps: usize, seed: u64) -> Grid {
    let g = domain.alloc_random(seed);
    run(spec, &g, steps)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_allclose;

    #[test]
    fn jacobi1d_hand_computed() {
        let desc = StencilKind::Jacobi1D.descriptor();
        let mut src = Grid::zeros(5, 1, 1);
        src.data.copy_from_slice(&[3.0, 6.0, 9.0, 12.0, 15.0]);
        let mut dst = Grid::zeros(5, 1, 1);
        step(&desc, &src, &mut dst);
        // interior: mean of 3 neighbours; boundary copied.
        assert_allclose(&dst.data, &[3.0, 6.0, 9.0, 12.0, 15.0], 1e-12, 1e-12);
        // non-linear data:
        src.data.copy_from_slice(&[1.0, 2.0, 4.0, 8.0, 16.0]);
        step(&desc, &src, &mut dst);
        assert_allclose(
            &dst.data,
            &[1.0, 7.0 / 3.0, 14.0 / 3.0, 28.0 / 3.0, 16.0],
            1e-12,
            1e-12,
        );
    }

    #[test]
    fn jacobi2d_hand_computed() {
        let desc = StencilKind::Jacobi2D.descriptor();
        let mut src = Grid::zeros(3, 3, 1);
        for (i, v) in (1..=9).enumerate() {
            src.data[i] = v as f64;
        }
        let mut dst = Grid::zeros(3, 3, 1);
        step(&desc, &src, &mut dst);
        // Only the center (1,1)=5 updates: 0.2*(2+4+5+6+8)=5.
        let mut want = src.data.clone();
        want[4] = 5.0;
        assert_allclose(&dst.data, &want, 1e-12, 1e-12);
    }

    #[test]
    fn parallel_step_is_bitwise_identical_to_serial() {
        // The satellite contract: the banded/vectorized step must equal
        // the scalar oracle BIT FOR BIT, for every kernel, at several
        // thread counts (including more threads than rows).
        for k in StencilKind::ALL {
            let desc = k.descriptor();
            let d = Domain::tiny(k);
            let src = d.alloc_random(0xB17_1D);
            let mut want = d.alloc();
            step_serial(&desc, &src, &mut want);
            for threads in [1usize, 2, 3, 7, 16, 64] {
                let mut got = d.alloc();
                step_with_threads(&desc, &src, &mut got, threads);
                assert!(
                    got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{k}: threads={threads} diverged bitwise from the scalar oracle"
                );
            }
        }
    }

    #[test]
    fn multipass_step_is_bitwise_identical_to_program_ordered_serial() {
        // The pass-split contract: splitting a kernel into accumulating
        // passes must not change a single bit relative to the unsplit
        // scalar oracle accumulating in the same (program) order — for
        // the paper six (1 pass) AND the extended presets including the
        // 2-pass star17_3d.
        let mut specs: Vec<KernelSpec> = StencilKind::ALL.iter().map(|k| k.descriptor()).collect();
        specs.extend(crate::stencil::extended_presets());
        for spec in &specs {
            let d = spec.tiny_domain();
            let src = d.alloc_random(0x9A55);
            let mut want = d.alloc();
            step_serial(&spec.program_ordered(), &src, &mut want);
            let mut got = d.alloc();
            step_multipass(spec, &src, &mut got);
            assert!(
                got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                "{}: pass-split oracle diverged bitwise from the serial oracle",
                spec.id
            );
        }
    }

    #[test]
    fn planned_step_oracles_reordered_plans() {
        use crate::isa::PlanStrategy;
        let mix = crate::stencil::extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "wide_mix_2d")
            .unwrap();
        let d = mix.tiny_domain();
        let src = d.alloc_random(0x9A55_ED);
        // step_planned under the greedy plan IS step_multipass, bitwise.
        let mut a = d.alloc();
        step_multipass(&mix, &src, &mut a);
        let greedy = mix.pass_plan().unwrap();
        let mut b = d.alloc();
        step_planned(&mix, &greedy, &src, &mut b);
        assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        // The optimized plan reorders this kernel's rows (4 passes → 2):
        // the accumulation order changes, so equality with the greedy
        // oracle is mathematical (reassociation-tolerance), not bitwise.
        let opt = mix.pass_plan_with(PlanStrategy::Optimized).unwrap();
        assert_eq!(opt.num_passes(), 2);
        assert!(!opt.order_preserving());
        let mut c = d.alloc();
        step_planned(&mix, &opt, &src, &mut c);
        assert_allclose(&c.data, &a.data, 1e-12, 1e-12);
    }

    #[test]
    fn multipass_run_swaps_like_run() {
        // Multi-step ping-pong through the pass-split oracle: for a spec
        // whose taps already sit in program order, run_multipass must be
        // bitwise-identical to the plain banded `run`.
        let spec = StencilKind::Blur2D.descriptor();
        assert_eq!(spec.program_ordered().points, spec.points, "Blur2D is program-ordered");
        let d = spec.tiny_domain();
        let g = d.alloc_random(0x5EED);
        let a = run(&spec, &g, 3);
        let b = run_multipass(&spec, &g, 3);
        assert!(
            a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()),
            "multi-step pass-split run diverged"
        );
        assert_eq!(run_multipass(&spec, &g, 0), g);
    }

    #[test]
    fn blocked_run_is_bitwise_identical_to_chaining() {
        // The temporal-blocking contract: for every kernel, block depth,
        // band count, and step count (including steps not divisible by
        // T), the blocked oracle must equal plain chaining BIT FOR BIT —
        // halo recomputation is traffic restructuring, not a numerical
        // scheme change.
        for k in [StencilKind::Jacobi1D, StencilKind::Jacobi2D, StencilKind::Heat3D] {
            let desc = k.descriptor();
            let d = Domain::tiny(k);
            let g = d.alloc_random(0xB10C);
            for steps in [1usize, 4, 5] {
                let want = run(&desc, &g, steps);
                for t in 1..=4usize {
                    for bands in [1usize, 3] {
                        let got = run_blocked(&desc, &g, steps, t, bands);
                        assert!(
                            got.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                            "{k}: steps={steps} T={t} bands={bands} diverged bitwise"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn reduced_run_matches_manual_two_pass() {
        // The fused-reduction reference: run_reduced's per-step values
        // must equal a hand-rolled step-then-fold loop bitwise, and the
        // grid evolution must be untouched by the reduction (jacobi2d_res
        // shares jacobi2d's taps verbatim).
        let res = crate::stencil::extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "jacobi2d_res")
            .unwrap();
        let d = res.tiny_domain();
        let g = d.alloc_random(0x2ED5);
        let steps = 3;
        let (grid, values) = run_reduced(&res, &g, steps);
        assert_eq!(values.len(), steps);
        assert!(values.iter().all(|v| *v > 0.0), "residual of a random grid is positive");
        // Residuals shrink as Jacobi smooths.
        assert!(values[steps - 1] < values[0]);
        let plain = run(&StencilKind::Jacobi2D.descriptor(), &g, steps);
        assert_eq!(grid.data, plain.data, "reduction must not perturb the grid");
        let mut a = g.clone();
        let mut b = g.clone();
        for (s, &v) in values.iter().enumerate() {
            step(&res, &a, &mut b);
            let want: f64 =
                a.data.iter().zip(&b.data).fold(0.0, |acc, (&x, &y)| acc + (y - x).abs());
            assert_eq!(v.to_bits(), want.to_bits(), "step {s}");
            std::mem::swap(&mut a, &mut b);
        }
    }

    #[test]
    fn reduce_array_ops() {
        let input = [1.0f64, 2.0, 3.0];
        let output = [4.0f64, 1.0, 5.0];
        assert_eq!(reduce_arrays(ReduceOp::Sum, &input, &output), 10.0);
        assert_eq!(reduce_arrays(ReduceOp::AbsDiff, &input, &output), 3.0 + 1.0 + 2.0);
        assert_eq!(reduce_arrays(ReduceOp::Max, &input, &output), 5.0);
        assert_eq!(reduce_arrays(ReduceOp::Max, &[], &[]), f64::NEG_INFINITY);
    }

    #[test]
    fn constant_field_is_fixed_point() {
        // Coefficients sum to 1 → a constant grid is a fixed point for
        // every kernel (interior equals boundary). Strong whole-pattern
        // check.
        for k in StencilKind::ALL {
            let desc = k.descriptor();
            let d = Domain::tiny(k);
            let mut g = d.alloc();
            g.data.iter_mut().for_each(|v| *v = 2.5);
            let out = run(&desc, &g, 3);
            assert!(out.max_abs_diff(&g) < 1e-12, "{k}");
        }
    }

    #[test]
    fn smoothing_contracts_range() {
        // Averaging stencils shrink the value range on the interior.
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let g = d.alloc_random(99);
            let out = run(&k.descriptor(), &g, 2);
            let max_in = g.data.iter().cloned().fold(f64::MIN, f64::max);
            let max_out = out.data.iter().cloned().fold(f64::MIN, f64::max);
            assert!(max_out <= max_in + 1e-12, "{k}");
        }
    }

    #[test]
    fn symmetry_preserved() {
        // All kernels are symmetric in x: mirroring the input mirrors the
        // output.
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let g = d.alloc_random(7);
            let mut gm = g.clone();
            for z in 0..d.nz {
                for y in 0..d.ny {
                    for x in 0..d.nx {
                        gm.set(x, y, z, g.get(d.nx - 1 - x, y, z));
                    }
                }
            }
            let out = run(&k.descriptor(), &g, 1);
            let outm = run(&k.descriptor(), &gm, 1);
            for z in 0..d.nz {
                for y in 0..d.ny {
                    for x in 0..d.nx {
                        let a = out.get(d.nx - 1 - x, y, z);
                        let b = outm.get(x, y, z);
                        assert!((a - b).abs() < 1e-12, "{k} at ({x},{y},{z})");
                    }
                }
            }
        }
    }

    #[test]
    fn run_zero_steps_is_identity() {
        let d = Domain::tiny(StencilKind::Heat3D);
        let g = d.alloc_random(1);
        let out = run(&StencilKind::Heat3D.descriptor(), &g, 0);
        assert_eq!(out, g);
    }

    #[test]
    #[should_panic(expected = "domain smaller than halo")]
    fn rejects_too_small_domain() {
        let desc = StencilKind::Points7_1D.descriptor();
        let src = Grid::zeros(6, 1, 1);
        let mut dst = Grid::zeros(6, 1, 1);
        step(&desc, &src, &mut dst);
    }

    #[test]
    #[should_panic(expected = "domain smaller than halo")]
    fn serial_rejects_too_small_domain() {
        let desc = StencilKind::Points7_1D.descriptor();
        let src = Grid::zeros(6, 1, 1);
        let mut dst = Grid::zeros(6, 1, 1);
        step_serial(&desc, &src, &mut dst);
    }
}
