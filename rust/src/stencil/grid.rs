//! Row-major double-precision grids (x fastest, then y, then z).

use crate::util::SplitMix64;

/// A dense 3D grid of `f64` (1D/2D grids set the unused extents to 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Grid {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    pub data: Vec<f64>,
}

impl Grid {
    /// Zero-filled grid.
    pub fn zeros(nx: usize, ny: usize, nz: usize) -> Grid {
        assert!(nx > 0 && ny > 0 && nz > 0, "grid extents must be positive");
        Grid {
            nx,
            ny,
            nz,
            data: vec![0.0; nx * ny * nz],
        }
    }

    /// Grid initialized with deterministic pseudo-random values in
    /// `[0, 1)` — the workload generator used throughout the experiments.
    pub fn random(nx: usize, ny: usize, nz: usize, seed: u64) -> Grid {
        let mut g = Grid::zeros(nx, ny, nz);
        let mut rng = SplitMix64::new(seed);
        rng.fill_f64(&mut g.data, 0.0, 1.0);
        g
    }

    /// Smooth deterministic initialization (PolyBench-style ramp), useful
    /// for numerics checks where random data would hide sign errors.
    pub fn ramp(nx: usize, ny: usize, nz: usize) -> Grid {
        let mut g = Grid::zeros(nx, ny, nz);
        for z in 0..nz {
            for y in 0..ny {
                for x in 0..nx {
                    let i = g.index(x, y, z);
                    g.data[i] =
                        (x as f64 + 1.0) * 0.5 + (y as f64) * 0.25 + (z as f64) * 0.125;
                }
            }
        }
        g
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Size of one array in bytes.
    pub fn bytes(&self) -> usize {
        self.len() * 8
    }

    #[inline]
    pub fn index(&self, x: usize, y: usize, z: usize) -> usize {
        debug_assert!(x < self.nx && y < self.ny && z < self.nz);
        (z * self.ny + y) * self.nx + x
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize, z: usize) -> f64 {
        self.data[self.index(x, y, z)]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, z: usize, v: f64) {
        let i = self.index(x, y, z);
        self.data[i] = v;
    }

    /// Element offset (may be negative conceptually; caller guarantees the
    /// tap stays in bounds) for a stencil tap relative to linear index `i`.
    #[inline]
    pub fn tap_offset(&self, dx: i64, dy: i64, dz: i64) -> i64 {
        dx + dy * self.nx as i64 + dz * (self.nx * self.ny) as i64
    }

    /// Maximum absolute difference against another grid of the same shape.
    pub fn max_abs_diff(&self, other: &Grid) -> f64 {
        assert_eq!(self.data.len(), other.data.len(), "shape mismatch");
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let g = Grid::zeros(4, 3, 2);
        assert_eq!(g.index(0, 0, 0), 0);
        assert_eq!(g.index(1, 0, 0), 1);
        assert_eq!(g.index(0, 1, 0), 4);
        assert_eq!(g.index(0, 0, 1), 12);
        assert_eq!(g.len(), 24);
    }

    #[test]
    fn tap_offset_matches_indexing() {
        let g = Grid::zeros(7, 5, 3);
        let i = g.index(3, 2, 1) as i64;
        assert_eq!(i + g.tap_offset(1, 0, 0), g.index(4, 2, 1) as i64);
        assert_eq!(i + g.tap_offset(-1, 1, 0), g.index(2, 3, 1) as i64);
        assert_eq!(i + g.tap_offset(0, 0, -1), g.index(3, 2, 0) as i64);
    }

    #[test]
    fn random_is_deterministic() {
        let a = Grid::random(8, 8, 1, 3);
        let b = Grid::random(8, 8, 1, 3);
        assert_eq!(a, b);
        let c = Grid::random(8, 8, 1, 4);
        assert_ne!(a, c);
    }

    #[test]
    fn max_abs_diff_zero_for_self() {
        let g = Grid::random(16, 4, 1, 1);
        assert_eq!(g.max_abs_diff(&g), 0.0);
    }

    #[test]
    #[should_panic]
    fn zero_extent_panics() {
        let _ = Grid::zeros(0, 1, 1);
    }
}
