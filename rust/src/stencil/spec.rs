//! The data-driven kernel layer: [`KernelSpec`] is the single source of
//! truth every other layer consumes — taps, dimensionality, and the
//! per-[`SizeClass`] domain sizes that used to be hard-coded per
//! `StencilKind` arm across config, harness, CLI, and golden reference.
//!
//! The paper's six kernels (§7.2) are *presets* built through the same
//! type (`paper_preset`, crate-internal); anything the SPU datapath can
//! execute is
//! expressible as a spec, including kernels loaded from TOML files at
//! runtime (`--kernel-file`, parsed with the in-tree
//! [`toml_mini`](crate::config::toml_mini) subset) — the paper's six are
//! evaluation points, not the design's limit.
//!
//! [`KernelSpec::validate`] enforces both the physical constraints
//! (radius vs. domain, dimensionality consistency) and the Casper ISA
//! envelope (§5.1: 3-bit shift field, 16-entry stream/constant buffers,
//! 64-entry instruction buffer). Kernels wider than one program's
//! envelope — more distinct rows than the stream buffer holds, say — are
//! no longer rejected: validation instead requires a *multi-pass plan*
//! ([`KernelSpec::pass_plan`]), so every registered kernel is guaranteed
//! to compile with
//! [`ProgramBuilder::build_passes`](crate::isa::ProgramBuilder::build_passes)
//! (length 1 for envelope-sized kernels). Only per-tap hard limits (the
//! 3-bit shift field) remain outright rejections.

use std::path::Path;
use std::sync::Arc;

use anyhow::{ensure, Context, Result};

use crate::config::toml_mini::TomlDoc;
use crate::config::SizeClass;
use crate::isa::instr::ReduceOp;
use crate::isa::program::{PassPlan, PlanStrategy, MAX_SHIFT};

use super::domain::table3;
use super::{Domain, StencilKind};

/// Interned kernel identifier: the machine-friendly id used in CLI flags,
/// artifact file names, and sweep-cache keys. Cloning is an `Arc` bump.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct KernelId(Arc<str>);

impl KernelId {
    pub fn new(id: &str) -> KernelId {
        KernelId(Arc::from(id))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl std::fmt::Display for KernelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

/// Where a spec came from — paper preset, extended built-in, or a user
/// TOML file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelOrigin {
    /// One of the six §7.2 kernels (always in the default sweep).
    Paper,
    /// Built-in beyond the paper (behind `--extended-kernels`).
    Extended,
    /// Loaded from a `--kernel-file` TOML spec.
    File,
}

impl KernelOrigin {
    pub fn name(self) -> &'static str {
        match self {
            KernelOrigin::Paper => "paper",
            KernelOrigin::Extended => "extended",
            KernelOrigin::File => "file",
        }
    }
}

/// One tap of a stencil: offset (in elements) and coefficient.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StencilPoint {
    pub dx: i64,
    pub dy: i64,
    pub dz: i64,
    pub coef: f64,
}

impl StencilPoint {
    pub const fn new(dx: i64, dy: i64, dz: i64, coef: f64) -> Self {
        StencilPoint { dx, dy, dz, coef }
    }
}

/// Fused reduction attached to a kernel: after each step the kernel also
/// yields one scalar ([`ReduceOp`] over the output grid), folded by the
/// SPUs as they stream the output and combined by the leader in
/// deterministic `(round, spu, seq)` order — no extra pass, no extra
/// DRAM traffic (see `docs/KERNELS.md`, "Fused reductions").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReductionSpec {
    pub op: ReduceOp,
}

/// Taps sharing one row (same `dy`,`dz`): a single Casper stream.
#[derive(Debug, Clone, PartialEq)]
pub struct RowGroup {
    pub dy: i64,
    pub dz: i64,
    /// `(dx, coef)` per tap, sorted by `dx`.
    pub taps: Vec<(i64, f64)>,
}

/// Full description of one stencil kernel: identity, compute pattern, and
/// the per-size-class domains (Table 3 for the built-ins).
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    pub id: KernelId,
    /// Human name, as printed in tables and figures.
    pub name: String,
    /// Grid dimensionality (1, 2, or 3).
    pub dims: usize,
    pub points: Vec<StencilPoint>,
    /// Domains in `[L2, LLC, DRAM]` order (see [`SizeClass::index`]).
    pub domains: [Domain; 3],
    pub origin: KernelOrigin,
    /// Optional fused reduction: the final compiled pass of every step
    /// also folds the output grid into one scalar (`[reduction]` in TOML).
    pub reduction: Option<ReductionSpec>,
}

impl KernelSpec {
    /// Plain constructor with the Table-3 default domains for `dims`.
    /// Call [`validate`](Self::validate) before use.
    pub fn new(
        id: &str,
        name: &str,
        dims: usize,
        points: Vec<StencilPoint>,
        origin: KernelOrigin,
    ) -> KernelSpec {
        KernelSpec {
            id: KernelId::new(id),
            name: name.to_string(),
            dims,
            points,
            domains: default_domains(dims),
            origin,
            reduction: None,
        }
    }

    /// Preset descriptor of a built-in kernel (compat shim for the old
    /// `StencilDesc::of`).
    pub fn of(kind: StencilKind) -> KernelSpec {
        kind.descriptor()
    }

    /// Number of taps (input grid points per output point).
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    /// Halo radius along each axis `[rx, ry, rz]`. Unsigned arithmetic:
    /// `i64::MIN` offsets in a hostile spec file must not overflow `abs`.
    pub fn radius(&self) -> [usize; 3] {
        let mut r = [0u64; 3];
        for p in &self.points {
            r[0] = r[0].max(p.dx.unsigned_abs());
            r[1] = r[1].max(p.dy.unsigned_abs());
            r[2] = r[2].max(p.dz.unsigned_abs());
        }
        [r[0] as usize, r[1] as usize, r[2] as usize]
    }

    /// FLOPs per output point: one MAC (2 flops) per tap.
    pub fn flops_per_point(&self) -> usize {
        2 * self.num_points()
    }

    /// Distinct `(dy, dz)` row-offsets — these become Casper *streams*:
    /// taps within one row share a stream and use shifted (unaligned)
    /// loads (§6). One extra stream is the output.
    pub fn row_groups(&self) -> Vec<RowGroup> {
        let mut groups: Vec<RowGroup> = Vec::new();
        for p in &self.points {
            match groups.iter_mut().find(|g| g.dy == p.dy && g.dz == p.dz) {
                Some(g) => g.taps.push((p.dx, p.coef)),
                None => groups.push(RowGroup {
                    dy: p.dy,
                    dz: p.dz,
                    taps: vec![(p.dx, p.coef)],
                }),
            }
        }
        for g in &mut groups {
            g.taps.sort_by_key(|t| t.0);
        }
        // Deterministic order: by (dz, dy).
        groups.sort_by_key(|g| (g.dz, g.dy));
        groups
    }

    /// The multi-pass compilation plan for this kernel: an ordered
    /// partition of [`row_groups`](Self::row_groups) into ISA-envelope-
    /// legal passes (length 1 when the kernel fits a single program).
    /// Errors only for kernels [`validate`](Self::validate) would reject.
    pub fn pass_plan(&self) -> Result<PassPlan> {
        PassPlan::for_groups(&self.row_groups())
    }

    /// [`pass_plan`](Self::pass_plan) under an explicit
    /// [`PlanStrategy`] — [`PlanStrategy::Greedy`] reproduces
    /// `pass_plan()` exactly; [`PlanStrategy::Optimized`] may reorder or
    /// rebalance (see `docs/KERNELS.md`, "Pass planning").
    pub fn pass_plan_with(&self, strategy: PlanStrategy) -> Result<PassPlan> {
        PassPlan::for_groups_with(&self.row_groups(), strategy)
    }

    /// This kernel with its taps re-sorted into *program order* — the
    /// `(dz, dy)`-then-`dx` order in which
    /// [`ProgramBuilder`](crate::isa::ProgramBuilder) emits MAC
    /// instructions and hence the order the SPU (and the multi-pass
    /// golden oracle) accumulates in. Floating-point addition is not
    /// associative, so bitwise comparisons between the tap-order oracle
    /// (`golden::step_serial`) and program-order execution go through
    /// this view.
    pub fn program_ordered(&self) -> KernelSpec {
        let mut points = Vec::with_capacity(self.points.len());
        for g in self.row_groups() {
            for &(dx, coef) in &g.taps {
                points.push(StencilPoint::new(dx, g.dy, g.dz, coef));
            }
        }
        KernelSpec { points, ..self.clone() }
    }

    /// Sum of coefficients (≈1.0 for averaging stencils).
    pub fn coef_sum(&self) -> f64 {
        self.points.iter().map(|p| p.coef).sum()
    }

    /// Arithmetic intensity in FLOP/B for the roofline (Fig 1): every tap
    /// read from cache plus the output store and its write-allocate fill,
    /// 8 B each — the no-register-reuse traffic a cache-level roofline sees.
    pub fn arithmetic_intensity(&self) -> f64 {
        let flops = self.flops_per_point() as f64;
        let bytes = (self.num_points() as f64 + 2.0) * 8.0;
        flops / bytes
    }

    /// The domain of one size class (Table 3 for built-ins; spec files may
    /// override per class).
    pub fn domain(&self, level: SizeClass) -> Domain {
        self.domains[level.index()]
    }

    /// A small domain of the right dimensionality for unit tests: big
    /// enough for this kernel's halo, small enough to simulate fast.
    /// Matches the historical `Domain::tiny` values for the paper six.
    pub fn tiny_domain(&self) -> Domain {
        let [rx, ry, rz] = self.radius();
        let (bx, by, bz) = match self.dims {
            1 => (256, 1, 1),
            2 => (32, 16, 1),
            _ => (16, 12, 8),
        };
        Domain::new(
            bx.max(2 * rx + 4),
            if self.dims >= 2 { by.max(2 * ry + 4) } else { 1 },
            if self.dims >= 3 { bz.max(2 * rz + 4) } else { 1 },
        )
    }

    /// Validate the spec: identity, physical shape (dimensionality, taps,
    /// radius vs. every configured domain) and the Casper ISA envelope.
    pub fn validate(&self) -> Result<()> {
        let id = self.id.as_str();
        ensure!(!id.is_empty(), "kernel id must be non-empty");
        ensure!(
            id.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_'),
            "kernel id '{id}' must be lowercase [a-z0-9_]"
        );
        ensure!(!self.name.is_empty(), "kernel '{id}': name must be non-empty");
        ensure!(!self.name.contains('"'), "kernel '{id}': name must not contain quotes");
        ensure!((1..=3).contains(&self.dims), "kernel '{id}': dims must be 1, 2, or 3");
        ensure!(!self.points.is_empty(), "kernel '{id}': at least one tap required");
        for p in &self.points {
            ensure!(
                p.coef.is_finite(),
                "kernel '{id}': non-finite coefficient at ({},{},{})",
                p.dx,
                p.dy,
                p.dz
            );
            if self.dims < 2 {
                ensure!(p.dy == 0, "kernel '{id}': dy offsets need dims >= 2");
            }
            if self.dims < 3 {
                ensure!(p.dz == 0, "kernel '{id}': dz offsets need dims = 3");
            }
        }
        for (i, a) in self.points.iter().enumerate() {
            for b in &self.points[i + 1..] {
                ensure!(
                    (a.dx, a.dy, a.dz) != (b.dx, b.dy, b.dz),
                    "kernel '{id}': duplicate tap at ({},{},{})",
                    a.dx,
                    a.dy,
                    a.dz
                );
            }
        }
        for p in &self.points {
            // Per-tap hard limit of the Casper ISA (§5.1): the 3-bit
            // shift field. No pass split can widen it.
            ensure!(
                p.dx.unsigned_abs() <= MAX_SHIFT as u64,
                "kernel '{id}': tap dx {} exceeds the 3-bit shift field (|dx| <= {MAX_SHIFT})",
                p.dx
            );
            // Row offsets have no ISA field limit, but a halo beyond any
            // plausible domain is a spec bug — and the bound keeps the
            // `2 * radius` domain arithmetic below overflow-free for
            // hostile i64 offsets.
            const MAX_ROW_OFFSET: u64 = 1024;
            ensure!(
                p.dy.unsigned_abs() <= MAX_ROW_OFFSET && p.dz.unsigned_abs() <= MAX_ROW_OFFSET,
                "kernel '{id}': tap row offset ({}, {}) exceeds the sanity bound of {MAX_ROW_OFFSET}",
                p.dy,
                p.dz
            );
        }
        // Casper ISA envelope (§5.1): the kernel must admit a compilation
        // plan — a single program when everything fits, a multi-pass plan
        // otherwise. The planner's errors name the offending buffer.
        self.pass_plan()
            .with_context(|| format!("kernel '{id}': no ISA-legal pass plan"))?;
        // Radius vs. every configured domain: boundary copy-through needs
        // a non-empty interior in each class.
        let [rx, ry, rz] = self.radius();
        for level in SizeClass::ALL {
            let d = self.domain(level);
            ensure!(
                d.nx > 0 && d.ny > 0 && d.nz > 0,
                "kernel '{id}': empty {level} domain"
            );
            if self.dims < 2 {
                ensure!(
                    d.ny == 1 && d.nz == 1,
                    "kernel '{id}': 1D kernel with 2D/3D {level} domain {d}"
                );
            }
            if self.dims < 3 {
                ensure!(
                    d.nz == 1,
                    "kernel '{id}': {}D kernel with 3D {level} domain {d}",
                    self.dims
                );
            }
            ensure!(
                d.nx > 2 * rx && d.ny > 2 * ry && d.nz > 2 * rz,
                "kernel '{id}': {level} domain {d} smaller than halo (radius [{rx},{ry},{rz}])"
            );
        }
        Ok(())
    }

    /// Validate a temporal block of `t` steps against `domain`: blocking
    /// recomputes halos instead of re-fetching them, so the *effective*
    /// halo a sweep needs grows to `radius · t` per axis — the boundary
    /// copy-through still needs a non-empty interior beyond it.
    pub fn validate_blocked(&self, domain: &Domain, t: usize) -> Result<()> {
        let id = self.id.as_str();
        ensure!(t >= 1, "kernel '{id}': temporal block must be >= 1 (got {t})");
        let [rx, ry, rz] = self.radius();
        let grown = |r: usize| 2usize.saturating_mul(r).saturating_mul(t);
        ensure!(
            domain.nx > grown(rx) && domain.ny > grown(ry) && domain.nz > grown(rz),
            "kernel '{id}': domain {domain} smaller than the temporally blocked halo \
             (radius [{rx},{ry},{rz}] x T={t})"
        );
        Ok(())
    }

    /// Parse a spec from a TOML-subset file (see `to_toml_string` for the
    /// format, and `examples/kernels/hdiff9.toml` for a worked example).
    pub fn from_file(path: &Path) -> Result<KernelSpec> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading kernel spec {}", path.display()))?;
        Self::from_toml_str(&text)
            .with_context(|| format!("parsing kernel spec {}", path.display()))
    }

    /// Parse a spec from TOML text:
    ///
    /// ```toml
    /// [kernel]
    /// id = "hdiff9"          # lowercase [a-z0-9_]
    /// name = "HDiff 9-point" # optional (defaults to the id)
    /// dims = 2
    ///
    /// [domain]               # optional: Table-3 defaults by dims
    /// l2 = "512x256"
    /// llc = "1024x1024"
    /// dram = "2048x2048"
    ///
    /// [tap-0]                # one section per tap, numbered from 0
    /// dx = 0                 # omitted offsets default to 0
    /// dy = 0
    /// coef = 0.2
    ///
    /// [reduction]            # optional: fused per-step reduction
    /// op = "abs_diff"        # sum | abs_diff | max
    /// ```
    pub fn from_toml_str(text: &str) -> Result<KernelSpec> {
        let doc = TomlDoc::parse(text)?;
        let id = doc.get_str("kernel.id")?.context("missing kernel.id")?;
        let name = match doc.get_str("kernel.name")? {
            Some(n) => n,
            None => id.clone(),
        };
        let dims = doc.get_int("kernel.dims")?.context("missing kernel.dims")? as usize;

        let mut points = Vec::new();
        loop {
            let sect = format!("tap-{}", points.len());
            if doc.get(&format!("{sect}.coef")).is_none() {
                break;
            }
            let coef = doc
                .get_float(&format!("{sect}.coef"))?
                .with_context(|| format!("missing {sect}.coef"))?;
            let dx = doc.get_int(&format!("{sect}.dx"))?.unwrap_or(0);
            let dy = doc.get_int(&format!("{sect}.dy"))?.unwrap_or(0);
            let dz = doc.get_int(&format!("{sect}.dz"))?.unwrap_or(0);
            points.push(StencilPoint::new(dx, dy, dz, coef));
        }
        ensure!(!points.is_empty(), "no tap sections found ([tap-0], [tap-1], ...)");
        // Reject stray tap sections outside the consecutive 0..n run
        // (a numbering gap would silently drop taps otherwise).
        for key in doc.keys() {
            if let Some(rest) = key.strip_prefix("tap-") {
                let n = rest.split('.').next().unwrap_or("");
                let n: usize = n
                    .parse()
                    .with_context(|| format!("bad tap section 'tap-{n}'"))?;
                ensure!(
                    n < points.len(),
                    "tap-{n} is out of sequence: tap sections must be numbered consecutively from tap-0 and each needs a coef"
                );
            }
        }

        let mut spec = KernelSpec::new(&id, &name, dims, points, KernelOrigin::File);
        for (key, slot) in [("domain.l2", 0usize), ("domain.llc", 1), ("domain.dram", 2)] {
            if let Some(s) = doc.get_str(key)? {
                spec.domains[slot] =
                    parse_domain(&s).with_context(|| format!("bad {key}"))?;
            }
        }
        if let Some(op) = doc.get_str("reduction.op")? {
            let op = ReduceOp::parse(&op)
                .with_context(|| format!("bad reduction.op '{op}' (use sum | abs_diff | max)"))?;
            spec.reduction = Some(ReductionSpec { op });
        }
        spec.validate()?;
        Ok(spec)
    }

    /// Serialize to the TOML-subset format [`from_toml_str`] reads.
    /// Coefficients use Rust's shortest-roundtrip float formatting, so
    /// write → parse is bit-exact.
    pub fn to_toml_string(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "# Casper kernel spec (see DESIGN.md, \"Kernel registry\")");
        let _ = writeln!(out, "[kernel]");
        let _ = writeln!(out, "id = \"{}\"", self.id);
        let _ = writeln!(out, "name = \"{}\"", self.name);
        let _ = writeln!(out, "dims = {}", self.dims);
        let _ = writeln!(out, "\n[domain]");
        let _ = writeln!(out, "l2 = \"{}\"", self.domains[0]);
        let _ = writeln!(out, "llc = \"{}\"", self.domains[1]);
        let _ = writeln!(out, "dram = \"{}\"", self.domains[2]);
        if let Some(r) = &self.reduction {
            let _ = writeln!(out, "\n[reduction]");
            let _ = writeln!(out, "op = \"{}\"", r.op);
        }
        for (i, p) in self.points.iter().enumerate() {
            let _ = writeln!(out, "\n[tap-{i}]");
            let _ = writeln!(out, "dx = {}", p.dx);
            let _ = writeln!(out, "dy = {}", p.dy);
            let _ = writeln!(out, "dz = {}", p.dz);
            let _ = writeln!(out, "coef = {:?}", p.coef);
        }
        out
    }
}

impl std::fmt::Display for KernelSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

/// Table-3 default domains for a dimensionality, `[L2, LLC, DRAM]`.
fn default_domains(dims: usize) -> [Domain; 3] {
    let dims = dims.clamp(1, 3);
    [
        table3(dims, SizeClass::L2),
        table3(dims, SizeClass::Llc),
        table3(dims, SizeClass::Dram),
    ]
}

/// Parse `"NX"`, `"NXxNY"`, or `"NXxNYxNZ"` (underscores allowed).
fn parse_domain(s: &str) -> Result<Domain> {
    let parts: Vec<&str> = s.split('x').collect();
    ensure!(
        (1..=3).contains(&parts.len()),
        "bad domain '{s}' (use \"NX\", \"NXxNY\", or \"NXxNYxNZ\")"
    );
    let mut v = [1usize; 3];
    for (i, p) in parts.iter().enumerate() {
        let cleaned: String = p.trim().chars().filter(|&c| c != '_').collect();
        v[i] = cleaned
            .parse()
            .with_context(|| format!("bad domain '{s}'"))?;
    }
    Ok(Domain::new(v[0], v[1], v[2]))
}

/// The tap pattern of one paper kernel (§7.2) — moved verbatim from the
/// old closed `StencilDesc::of` match so presets are bit-identical to the
/// historical definitions.
pub(super) fn paper_preset(kind: StencilKind) -> KernelSpec {
    let points = match kind {
        StencilKind::Jacobi1D => {
            // PolyBench: B[i] = (A[i-1] + A[i] + A[i+1]) / 3
            let c = 1.0 / 3.0;
            vec![
                StencilPoint::new(-1, 0, 0, c),
                StencilPoint::new(0, 0, 0, c),
                StencilPoint::new(1, 0, 0, c),
            ]
        }
        StencilKind::Points7_1D => {
            // Holewinski et al. 7-point 1D: symmetric radius-3 average.
            let c = 1.0 / 7.0;
            (-3..=3).map(|d| StencilPoint::new(d, 0, 0, c)).collect()
        }
        StencilKind::Jacobi2D => {
            // Paper §2.1 / Fig 8: 5-point, every tap × 0.2.
            let c = 0.2;
            vec![
                StencilPoint::new(0, -1, 0, c),
                StencilPoint::new(-1, 0, 0, c),
                StencilPoint::new(0, 0, 0, c),
                StencilPoint::new(1, 0, 0, c),
                StencilPoint::new(0, 1, 0, c),
            ]
        }
        StencilKind::Blur2D => {
            // Canonical 5×5 Gaussian blur (σ≈1), integer kernel / 273.
            const W: [[f64; 5]; 5] = [
                [1.0, 4.0, 7.0, 4.0, 1.0],
                [4.0, 16.0, 26.0, 16.0, 4.0],
                [7.0, 26.0, 41.0, 26.0, 7.0],
                [4.0, 16.0, 26.0, 16.0, 4.0],
                [1.0, 4.0, 7.0, 4.0, 1.0],
            ];
            let mut pts = Vec::with_capacity(25);
            for (j, row) in W.iter().enumerate() {
                for (i, w) in row.iter().enumerate() {
                    pts.push(StencilPoint::new(i as i64 - 2, j as i64 - 2, 0, w / 273.0));
                }
            }
            pts
        }
        StencilKind::Heat3D => {
            // 7-point heat diffusion: 0.4·center + 0.1·(6 face points).
            let mut pts = vec![StencilPoint::new(0, 0, 0, 0.4)];
            for (dx, dy, dz) in [
                (-1, 0, 0),
                (1, 0, 0),
                (0, -1, 0),
                (0, 1, 0),
                (0, 0, -1),
                (0, 0, 1),
            ] {
                pts.push(StencilPoint::new(dx, dy, dz, 0.1));
            }
            pts
        }
        StencilKind::Points33_3D => {
            // 27-point box + 6 distance-2 axis points = 33 taps.
            // Weights by tap class, normalized to sum to 1 (total
            // weight 8 + 6·3 + 12·1.5 + 8·0.5 + 6·1 = 54):
            //   center 8/54, face(6) 3/54, edge(12) 1.5/54,
            //   corner(8) 0.5/54, axis-2(6) 1/54.
            let mut pts = Vec::with_capacity(33);
            for dz in -1i64..=1 {
                for dy in -1i64..=1 {
                    for dx in -1i64..=1 {
                        let dist = dx.abs() + dy.abs() + dz.abs();
                        let w = match dist {
                            0 => 8.0,
                            1 => 3.0,
                            2 => 1.5,
                            _ => 0.5,
                        } / 54.0;
                        pts.push(StencilPoint::new(dx, dy, dz, w));
                    }
                }
            }
            for (dx, dy, dz) in [
                (-2, 0, 0),
                (2, 0, 0),
                (0, -2, 0),
                (0, 2, 0),
                (0, 0, -2),
                (0, 0, 2),
            ] {
                pts.push(StencilPoint::new(dx, dy, dz, 1.0 / 54.0));
            }
            pts
        }
    };
    KernelSpec::new(kind.id(), kind.name(), kind.dims(), points, KernelOrigin::Paper)
}

/// The built-in kernels beyond the paper (behind `--extended-kernels`).
///
/// - `hdiff`: a NERO-style (Singh et al., 2020) 9-point radius-2
///   horizontal-diffusion star in 2D — the irregular-coefficient weather
///   workload class.
/// - `star25_3d`: a 25-point high-order 3D star (seismic RTM shape) in
///   the anisotropic variant common in RTM codes (x ±5, y ±4, z ±3):
///   25 taps over exactly 15 input rows, saturating the stream buffer at
///   its single-program limit.
/// - `star17_3d`: the *isotropic* radius-4 25-point 3D star. Its 17 input
///   rows exceed the 16-entry stream buffer, so a single program cannot
///   express it — it compiles as a 2-pass plan
///   ([`KernelSpec::pass_plan`]), the kernel class multi-pass compilation
///   exists for.
/// - `jacobi2d_res`: the paper's Jacobi 2D with a fused `abs_diff`
///   reduction — the L1 residual a convergence loop tests — computed in
///   the same single pass (the kernel class fused stencil–reduction
///   pipelines exist for).
/// - `wide_mix_2d`: a 20-row 2D column stencil whose rows alternate
///   between two disjoint 15-constant coefficient families. Greedy
///   program-order planning pays both families' constants in every pass
///   (4 passes); the optimizing planner's constant-affinity reordering
///   packs each family's rows together and reaches the 2-pass minimum —
///   the kernel class the [`PlanStrategy::Optimized`] planner exists for.
pub fn extended_presets() -> Vec<KernelSpec> {
    vec![
        hdiff_preset(),
        star25_preset(),
        star17_preset(),
        jacobi2d_res_preset(),
        wide_mix_preset(),
    ]
}

fn hdiff_preset() -> KernelSpec {
    // Radius-2 star: center 1/3, distance-1 arms 1/8, distance-2 arms
    // 1/24 (sums to 1: 1/3 + 4/8 + 4/24).
    let mut pts = vec![StencilPoint::new(0, 0, 0, 1.0 / 3.0)];
    for (d, c) in [(1i64, 1.0 / 8.0), (2, 1.0 / 24.0)] {
        for s in [-1i64, 1] {
            pts.push(StencilPoint::new(s * d, 0, 0, c));
            pts.push(StencilPoint::new(0, s * d, 0, c));
        }
    }
    KernelSpec::new("hdiff", "HDiff 2D", 2, pts, KernelOrigin::Extended)
}

fn star25_preset() -> KernelSpec {
    // Per-arm weights by distance, /50 (center 5.5: the total is
    // 5.5 + 2·7.75 + 2·7.5 + 2·7 = 50, so coefficients sum to 1).
    const W: [f64; 5] = [4.0, 2.0, 1.0, 0.5, 0.25];
    let mut pts = vec![StencilPoint::new(0, 0, 0, 5.5 / 50.0)];
    for s in [-1i64, 1] {
        for (i, &w) in W.iter().enumerate() {
            pts.push(StencilPoint::new(s * (i as i64 + 1), 0, 0, w / 50.0));
        }
        for (i, &w) in W[..4].iter().enumerate() {
            pts.push(StencilPoint::new(0, s * (i as i64 + 1), 0, w / 50.0));
        }
        for (i, &w) in W[..3].iter().enumerate() {
            pts.push(StencilPoint::new(0, 0, s * (i as i64 + 1), w / 50.0));
        }
    }
    KernelSpec::new("star25_3d", "25-point 3D star", 3, pts, KernelOrigin::Extended)
}

fn star17_preset() -> KernelSpec {
    // Isotropic radius-4 star: center + 6 arms of 4. Per-arm weights by
    // distance /25 (center 2.5: total 2.5 + 6·(2 + 1 + 0.5 + 0.25) = 25,
    // so coefficients sum to 1). 17 distinct rows → 2 passes.
    //
    // The taps are listed in *program order* — rows sorted by (dz, dy),
    // in-row taps by dx — so the tap-order golden oracle accumulates in
    // exactly the order the compiled passes do, and the engine-vs-golden
    // check for this kernel is bitwise (see `coordinator::engine` tests).
    const W: [f64; 4] = [2.0 / 25.0, 1.0 / 25.0, 0.5 / 25.0, 0.25 / 25.0];
    let arm = |d: i64| W[(d.unsigned_abs() - 1) as usize];
    let mut pts = Vec::with_capacity(25);
    for dz in -4i64..=-1 {
        pts.push(StencilPoint::new(0, 0, dz, arm(dz)));
    }
    for dy in -4i64..=-1 {
        pts.push(StencilPoint::new(0, dy, 0, arm(dy)));
    }
    for dx in -4i64..=4 {
        let c = if dx == 0 { 2.5 / 25.0 } else { arm(dx) };
        pts.push(StencilPoint::new(dx, 0, 0, c));
    }
    for dy in 1i64..=4 {
        pts.push(StencilPoint::new(0, dy, 0, arm(dy)));
    }
    for dz in 1i64..=4 {
        pts.push(StencilPoint::new(0, 0, dz, arm(dz)));
    }
    KernelSpec::new("star17_3d", "17-row 3D star", 3, pts, KernelOrigin::Extended)
}

fn jacobi2d_res_preset() -> KernelSpec {
    // The paper's Jacobi 2D taps, verbatim, plus a fused L1-residual
    // reduction (Σ|out − in|): the convergence-test iteration pattern.
    // Same taps → same compiled MAC sequence → the grid evolution is
    // bit-identical to `jacobi2d`; only the reduction rides along.
    let mut spec = paper_preset(StencilKind::Jacobi2D);
    spec.id = KernelId::new("jacobi2d_res");
    spec.name = "Jacobi 2D residual".to_string();
    spec.origin = KernelOrigin::Extended;
    spec.reduction = Some(ReductionSpec { op: ReduceOp::AbsDiff });
    spec
}

fn wide_mix_preset() -> KernelSpec {
    // Two interleaved 15-constant coefficient families over a 20-row
    // column: rows at dy = -10..=9, three taps per row (dx in {-1,0,1}).
    // Even row-group indices draw from family A (numerators 32+2i over
    // 2048), odd from family B (numerators 1,3,..,27 and 138 over 2048);
    // family row k uses coefficient indices (3k+t) mod 15, so rows k and
    // k+10 of a family reuse exactly the same three constants while
    // adjacent rows share none. Greedy program-order splitting refills
    // the 16-entry constant buffer every ~5 rows (4 passes); affinity
    // reordering co-locates each family's rows (2 passes, the minimum —
    // 20 rows can never fit one program's 16 streams).
    //
    // Every coefficient is dyadic (n/2048, exact in f64), each constant
    // is used exactly twice, and the numerators sum to 2·1024 = 2048, so
    // the tap sum is exactly 1.0 in every accumulation order.
    let num_a = |i: usize| (32 + 2 * i) as f64;
    let num_b = |i: usize| if i == 14 { 138.0 } else { (2 * i + 1) as f64 };
    let mut pts = Vec::with_capacity(60);
    for gi in 0..20i64 {
        let k = (gi / 2) as usize;
        let fam_a = gi % 2 == 0;
        for t in 0..3usize {
            let i = (3 * k + t) % 15;
            let n = if fam_a { num_a(i) } else { num_b(i) };
            pts.push(StencilPoint::new(t as i64 - 1, gi - 10, 0, n / 2048.0));
        }
    }
    KernelSpec::new("wide_mix_2d", "Wide dual-family 2D", 2, pts, KernelOrigin::Extended)
}

/// The open kernel registry: presets plus user-loaded TOML specs, looked
/// up by id (or fuzzy name, as the CLI always accepted for the paper six).
#[derive(Debug, Clone, Default)]
pub struct KernelRegistry {
    specs: Vec<Arc<KernelSpec>>,
}

impl KernelRegistry {
    /// The six paper kernels, in paper order.
    pub fn paper() -> KernelRegistry {
        KernelRegistry { specs: StencilKind::ALL.iter().map(|k| k.spec()).collect() }
    }

    /// Paper six plus the extended presets.
    pub fn builtin() -> KernelRegistry {
        let mut r = KernelRegistry::paper();
        for s in extended_presets() {
            r.add(s).expect("extended presets are valid and unique");
        }
        r
    }

    /// Register a spec (validated; duplicate ids are an error).
    pub fn add(&mut self, spec: KernelSpec) -> Result<Arc<KernelSpec>> {
        spec.validate()?;
        ensure!(
            self.get(spec.id.as_str()).is_none(),
            "duplicate kernel id '{}'",
            spec.id
        );
        let spec = Arc::new(spec);
        self.specs.push(spec.clone());
        Ok(spec)
    }

    /// Load and register one spec from a TOML file.
    pub fn load_file(&mut self, path: &Path) -> Result<Arc<KernelSpec>> {
        let spec = KernelSpec::from_file(path)?;
        self.add(spec)
            .with_context(|| format!("registering kernel from {}", path.display()))
    }

    /// All registered specs, in registration order (paper order first).
    pub fn specs(&self) -> &[Arc<KernelSpec>] {
        &self.specs
    }

    /// Exact id lookup.
    pub fn get(&self, id: &str) -> Option<Arc<KernelSpec>> {
        self.specs.iter().find(|s| s.id.as_str() == id).cloned()
    }

    /// CLI-style lookup: exact id, or the human name with separators
    /// squeezed out (`"jacobi 2d"`, `"Jacobi-2D"` → `jacobi2d`).
    pub fn resolve(&self, s: &str) -> Option<Arc<KernelSpec>> {
        let k = s.to_ascii_lowercase();
        let squeezed = k.replace([' ', '-', '_'], "");
        self.specs
            .iter()
            .find(|sp| {
                sp.id.as_str() == k
                    || sp.name.to_ascii_lowercase().replace(' ', "") == squeezed
            })
            .cloned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::MAX_STREAMS;

    #[test]
    fn paper_presets_validate_and_match_kinds() {
        for k in StencilKind::ALL {
            let s = k.spec();
            s.validate().unwrap();
            assert_eq!(s.id.as_str(), k.id());
            assert_eq!(s.name, k.name());
            assert_eq!(s.dims, k.dims());
            assert_eq!(s.origin, KernelOrigin::Paper);
            for level in SizeClass::ALL {
                assert_eq!(s.domain(level), Domain::for_level(k, level), "{k} {level}");
            }
            assert_eq!(s.tiny_domain(), Domain::tiny(k), "{k}");
        }
    }

    #[test]
    fn extended_presets_validate() {
        for s in extended_presets() {
            s.validate().unwrap();
            assert_eq!(s.origin, KernelOrigin::Extended);
            assert!((s.coef_sum() - 1.0).abs() < 1e-9, "{}", s.id);
        }
        let ext = extended_presets();
        let hdiff = &ext[0];
        assert_eq!(hdiff.num_points(), 9);
        assert_eq!(hdiff.radius(), [2, 2, 0]);
        let star = &ext[1];
        assert_eq!(star.num_points(), 25);
        assert_eq!(star.radius(), [5, 4, 3]);
        // Exactly saturates the stream buffer: 15 input rows + 1 output.
        assert_eq!(star.row_groups().len() + 1, MAX_STREAMS);
        assert_eq!(star.pass_plan().unwrap().num_passes(), 1);
        // The isotropic radius-4 star: one row past the envelope → 2
        // passes. PR 4 had to reject this exact kernel.
        let iso = &ext[2];
        assert_eq!(iso.id.as_str(), "star17_3d");
        assert_eq!(iso.num_points(), 25);
        assert_eq!(iso.radius(), [4, 4, 4]);
        assert_eq!(iso.row_groups().len(), 17);
        let plan = iso.pass_plan().unwrap();
        assert!(plan.is_multi_pass());
        assert_eq!(plan.num_passes(), 2);
        // The residual preset: Jacobi 2D taps verbatim + fused abs-diff.
        let res = &ext[3];
        assert_eq!(res.id.as_str(), "jacobi2d_res");
        assert_eq!(res.points, StencilKind::Jacobi2D.descriptor().points);
        assert_eq!(res.reduction, Some(ReductionSpec { op: ReduceOp::AbsDiff }));
        assert_eq!(res.pass_plan().unwrap().num_passes(), 1);
        // The dual-family preset: greedy pays the constant interleaving
        // (4 passes), the optimizing planner reaches the 2-pass minimum.
        let mix = &ext[4];
        assert_eq!(mix.id.as_str(), "wide_mix_2d");
        assert_eq!(mix.num_points(), 60);
        assert_eq!(mix.radius(), [1, 10, 0]);
        assert_eq!(mix.row_groups().len(), 20);
        assert_eq!(mix.coef_sum(), 1.0); // dyadic numerators, exact sum
        assert_eq!(mix.pass_plan().unwrap().num_passes(), 4);
        let opt = mix.pass_plan_with(PlanStrategy::Optimized).unwrap();
        assert_eq!(opt.num_passes(), 2);
        assert!(!opt.order_preserving());
    }

    #[test]
    fn star17_points_are_in_program_order() {
        // The preset's tap list must equal its own program-ordered view,
        // so tap-order and program-order accumulation coincide and the
        // engine-vs-golden check can be bitwise.
        let iso = extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "star17_3d")
            .unwrap();
        assert_eq!(iso.program_ordered().points, iso.points);
    }

    #[test]
    fn program_ordered_is_a_sorted_permutation() {
        for k in StencilKind::ALL {
            let spec = k.descriptor();
            let ordered = spec.program_ordered();
            ordered.validate().unwrap();
            assert_eq!(ordered.num_points(), spec.num_points(), "{k}");
            assert_eq!(ordered.row_groups(), spec.row_groups(), "{k}");
            // Sorted by (dz, dy, dx) — the ProgramBuilder emission order.
            let keys: Vec<_> = ordered.points.iter().map(|p| (p.dz, p.dy, p.dx)).collect();
            let mut sorted = keys.clone();
            sorted.sort_unstable();
            assert_eq!(keys, sorted, "{k}");
        }
    }

    #[test]
    fn wide_specs_validate_with_a_pass_plan() {
        // 21 single-tap rows in y: impossible as one program (22 streams),
        // accepted now because a 2-pass plan exists.
        let mut pts = Vec::new();
        for dy in -10i64..=10 {
            pts.push(StencilPoint::new(0, dy, 0, 1.0 / 21.0));
        }
        let mut wide = KernelSpec::new("wide21", "Wide 21", 2, pts, KernelOrigin::File);
        wide.domains = [Domain::new(64, 64, 1); 3];
        wide.validate().unwrap();
        assert_eq!(wide.pass_plan().unwrap().num_passes(), 2);
        // A tap past the 3-bit shift field stays a hard rejection.
        let bad = KernelSpec::new(
            "wide_bad",
            "x",
            1,
            vec![StencilPoint::new(-8, 0, 0, 0.5), StencilPoint::new(8, 0, 0, 0.5)],
            KernelOrigin::File,
        );
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("3-bit shift field"), "{err}");
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let tap = vec![StencilPoint::new(0, 0, 0, 1.0)];
        assert!(KernelSpec::new("Bad-Id", "x", 1, tap.clone(), KernelOrigin::File)
            .validate()
            .is_err());
        assert!(KernelSpec::new("k", "x", 4, tap.clone(), KernelOrigin::File)
            .validate()
            .is_err());
        assert!(KernelSpec::new("k", "x", 1, vec![], KernelOrigin::File).validate().is_err());
        // dy offset on a 1D kernel.
        assert!(KernelSpec::new(
            "k",
            "x",
            1,
            vec![StencilPoint::new(0, 1, 0, 1.0)],
            KernelOrigin::File
        )
        .validate()
        .is_err());
        // Duplicate tap.
        assert!(KernelSpec::new(
            "k",
            "x",
            1,
            vec![StencilPoint::new(0, 0, 0, 0.5), StencilPoint::new(0, 0, 0, 0.5)],
            KernelOrigin::File
        )
        .validate()
        .is_err());
        // Shift field overflow.
        assert!(KernelSpec::new(
            "k",
            "x",
            1,
            vec![StencilPoint::new(8, 0, 0, 1.0)],
            KernelOrigin::File
        )
        .validate()
        .is_err());
    }

    #[test]
    fn validate_rejects_radius_exceeding_domain() {
        let mut s = KernelSpec::new(
            "k",
            "k",
            1,
            (-3..=3).map(|d| StencilPoint::new(d, 0, 0, 1.0 / 7.0)).collect(),
            KernelOrigin::File,
        );
        s.domains[0] = Domain::new(6, 1, 1); // nx == 2 * radius
        let err = s.validate().unwrap_err().to_string();
        assert!(err.contains("smaller than halo"), "{err}");
    }

    #[test]
    fn toml_roundtrip_paper_six() {
        for k in StencilKind::ALL {
            let spec = k.descriptor();
            let parsed = KernelSpec::from_toml_str(&spec.to_toml_string()).unwrap();
            assert_eq!(parsed.id, spec.id, "{k}");
            assert_eq!(parsed.name, spec.name, "{k}");
            assert_eq!(parsed.dims, spec.dims, "{k}");
            assert_eq!(parsed.points, spec.points, "{k}");
            assert_eq!(parsed.domains, spec.domains, "{k}");
            assert_eq!(parsed.origin, KernelOrigin::File);
            assert_eq!(parsed.reduction, None, "{k}");
            assert!(!spec.to_toml_string().contains("[reduction]"), "{k}");
        }
    }

    #[test]
    fn toml_roundtrip_reduction() {
        let res = extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "jacobi2d_res")
            .unwrap();
        let text = res.to_toml_string();
        assert!(text.contains("[reduction]"), "{text}");
        assert!(text.contains("op = \"abs_diff\""), "{text}");
        let parsed = KernelSpec::from_toml_str(&text).unwrap();
        assert_eq!(parsed.reduction, res.reduction);
        assert_eq!(parsed.points, res.points);
        // An unknown op spelling is rejected with the valid spellings.
        let bad = text.replace("abs_diff", "l2norm");
        let err = format!("{:#}", KernelSpec::from_toml_str(&bad).unwrap_err());
        assert!(err.contains("sum | abs_diff | max"), "{err}");
    }

    #[test]
    fn blocked_halo_validation() {
        let spec = StencilKind::Jacobi2D.descriptor();
        let d = Domain::new(16, 16, 1);
        spec.validate_blocked(&d, 1).unwrap();
        spec.validate_blocked(&d, 7).unwrap(); // effective halo 2·1·7 = 14 < 16
        let err = spec.validate_blocked(&d, 8).unwrap_err().to_string();
        assert!(err.contains("temporally blocked halo"), "{err}");
        assert!(spec.validate_blocked(&d, 0).is_err());
        // 1D kernels are unconstrained along y/z no matter how big T is.
        let j1 = StencilKind::Jacobi1D.descriptor();
        j1.validate_blocked(&Domain::new(256, 1, 1), 100).unwrap();
        assert!(j1.validate_blocked(&Domain::new(256, 1, 1), 128).is_err());
    }

    #[test]
    fn toml_parse_rejects_malformed() {
        assert!(KernelSpec::from_toml_str("").is_err());
        assert!(KernelSpec::from_toml_str("[kernel]\nid = \"k\"\ndims = 1\n").is_err());
        // Gap in tap numbering.
        let gap = "[kernel]\nid = \"k\"\ndims = 1\n[tap-0]\ncoef = 1.0\n[tap-2]\ncoef = 1.0\n";
        assert!(KernelSpec::from_toml_str(gap).is_err());
        // Radius exceeding an explicit domain.
        let small = "[kernel]\nid = \"k\"\ndims = 1\n[domain]\nl2 = \"4\"\n\
                     [tap-0]\ndx = -3\ncoef = 0.5\n[tap-1]\ndx = 3\ncoef = 0.5\n";
        let err = KernelSpec::from_toml_str(small).unwrap_err();
        assert!(format!("{err:#}").contains("smaller than halo"), "{err:#}");
    }

    #[test]
    fn domain_string_forms() {
        assert_eq!(parse_domain("131072").unwrap(), Domain::new(131_072, 1, 1));
        assert_eq!(parse_domain("1_024x1024").unwrap(), Domain::new(1024, 1024, 1));
        assert_eq!(parse_domain("64x64x32").unwrap(), Domain::new(64, 64, 32));
        assert!(parse_domain("1x2x3x4").is_err());
        assert!(parse_domain("ax2").is_err());
    }

    #[test]
    fn registry_lookup_and_duplicates() {
        let mut reg = KernelRegistry::builtin();
        assert_eq!(reg.specs().len(), 11);
        assert_eq!(reg.get("jacobi2d").unwrap().name, "Jacobi 2D");
        assert_eq!(reg.resolve("Jacobi 2D").unwrap().id.as_str(), "jacobi2d");
        assert_eq!(reg.resolve("jacobi-2d").unwrap().id.as_str(), "jacobi2d");
        assert_eq!(reg.resolve("hdiff").unwrap().origin, KernelOrigin::Extended);
        assert!(reg.resolve("nope").is_none());
        let dup = KernelSpec::new(
            "jacobi2d",
            "dup",
            1,
            vec![StencilPoint::new(0, 0, 0, 1.0)],
            KernelOrigin::File,
        );
        assert!(reg.add(dup).is_err());
    }
}
