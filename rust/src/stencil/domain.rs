//! Domain sizes: the paper's Table 3 plus arbitrary custom domains.

use super::{Grid, StencilKind};
use crate::config::SizeClass;

/// A problem domain: grid extents (elements) per axis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Domain {
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
}

/// Table 3: default domain size for a dimensionality and size class —
/// the built-in specs' domains; file-defined specs may override per class.
///
/// | Level | 1D        | 2D        | 3D          |
/// |-------|-----------|-----------|-------------|
/// | L2    | 131,072   | 512×256   | 64×64×32    |
/// | L3    | 1,048,576 | 1024×1024 | 128×128×64  |
/// | DRAM  | 4,194,304 | 2048×2048 | 256×256×64  |
pub fn table3(dims: usize, level: SizeClass) -> Domain {
    match (dims, level) {
        (1, SizeClass::L2) => Domain::new(131_072, 1, 1),
        (1, SizeClass::Llc) => Domain::new(1_048_576, 1, 1),
        (1, SizeClass::Dram) => Domain::new(4_194_304, 1, 1),
        (2, SizeClass::L2) => Domain::new(512, 256, 1),
        (2, SizeClass::Llc) => Domain::new(1024, 1024, 1),
        (2, SizeClass::Dram) => Domain::new(2048, 2048, 1),
        (3, SizeClass::L2) => Domain::new(64, 64, 32),
        (3, SizeClass::Llc) => Domain::new(128, 128, 64),
        (3, SizeClass::Dram) => Domain::new(256, 256, 64),
        _ => unreachable!("dims is always 1..=3"),
    }
}

impl Domain {
    pub const fn new(nx: usize, ny: usize, nz: usize) -> Domain {
        Domain { nx, ny, nz }
    }

    /// Table-3 domain of a paper kernel (see [`table3`]; preset specs
    /// carry the same values via [`KernelSpec::domain`](super::KernelSpec::domain)).
    pub fn for_level(kind: StencilKind, level: SizeClass) -> Domain {
        table3(kind.dims(), level)
    }

    /// A small domain of the right dimensionality for unit tests — big
    /// enough for the stencil's halo, small enough to simulate fast.
    pub fn tiny(kind: StencilKind) -> Domain {
        kind.spec().tiny_domain()
    }

    pub fn points(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    /// Bytes of one f64 array over this domain.
    pub fn array_bytes(&self) -> usize {
        self.points() * 8
    }

    /// Bytes of the working set (input + output array).
    pub fn working_set_bytes(&self) -> usize {
        2 * self.array_bytes()
    }

    pub fn alloc(&self) -> Grid {
        Grid::zeros(self.nx, self.ny, self.nz)
    }

    pub fn alloc_random(&self, seed: u64) -> Grid {
        Grid::random(self.nx, self.ny, self.nz, seed)
    }
}

impl std::fmt::Display for Domain {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.nz > 1 {
            write!(f, "{}x{}x{}", self.nx, self.ny, self.nz)
        } else if self.ny > 1 {
            write!(f, "{}x{}", self.nx, self.ny)
        } else {
            write!(f, "{}", self.nx)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_sizes() {
        // Spot-check every row of Table 3.
        assert_eq!(
            Domain::for_level(StencilKind::Jacobi1D, SizeClass::L2).points(),
            131_072
        );
        assert_eq!(
            Domain::for_level(StencilKind::Jacobi1D, SizeClass::Llc).points(),
            1_048_576
        );
        assert_eq!(
            Domain::for_level(StencilKind::Points7_1D, SizeClass::Dram).points(),
            4_194_304
        );
        assert_eq!(
            Domain::for_level(StencilKind::Jacobi2D, SizeClass::L2),
            Domain::new(512, 256, 1)
        );
        assert_eq!(
            Domain::for_level(StencilKind::Blur2D, SizeClass::Dram),
            Domain::new(2048, 2048, 1)
        );
        assert_eq!(
            Domain::for_level(StencilKind::Heat3D, SizeClass::Llc),
            Domain::new(128, 128, 64)
        );
        assert_eq!(
            Domain::for_level(StencilKind::Points33_3D, SizeClass::L2),
            Domain::new(64, 64, 32)
        );
    }

    #[test]
    fn llc_class_fits_llc() {
        // The LLC-class working sets (2 arrays) fit in the 32 MB LLC,
        // and exceed the 4 MB of total private L2.
        for k in StencilKind::ALL {
            let d = Domain::for_level(k, SizeClass::Llc);
            assert!(d.working_set_bytes() <= 32 * 1024 * 1024, "{k}");
            assert!(d.working_set_bytes() > 16 * 256 * 1024, "{k}");
        }
        // DRAM-class exceeds the LLC for 1D/2D kernels (the paper's 3D
        // DRAM domains are 256×256×64 = 32 MB working set, borderline).
        for k in [StencilKind::Jacobi1D, StencilKind::Jacobi2D] {
            let d = Domain::for_level(k, SizeClass::Dram);
            assert!(d.working_set_bytes() > 32 * 1024 * 1024, "{k}");
        }
    }

    #[test]
    fn tiny_fits_halo() {
        for k in StencilKind::ALL {
            let d = Domain::tiny(k);
            let r = k.descriptor().radius();
            assert!(d.nx > 2 * r[0] && d.ny > 2 * r[1] && d.nz > 2 * r[2]);
        }
    }

    #[test]
    fn display_forms() {
        assert_eq!(Domain::new(128, 1, 1).to_string(), "128");
        assert_eq!(Domain::new(8, 4, 1).to_string(), "8x4");
        assert_eq!(Domain::new(8, 4, 2).to_string(), "8x4x2");
    }
}
