//! Shared memory-system context for the Casper engine: the sliced LLC,
//! the NoC, DRAM, the slice mapper, and the functional backing store.

use crate::config::{LlcConfig, MappingPolicy, SimConfig};
use crate::mapping::SliceMapper;
use crate::mem::cache::Cache;
use crate::mem::dram::DramModel;
use crate::mem::hierarchy::SlicedLlc;
use crate::noc::MeshNoc;

/// Functional backing store for the (single, physically contiguous)
/// stencil segment. Addresses are simulated physical addresses.
#[derive(Debug, Clone)]
pub struct SimStore {
    base: u64,
    data: Vec<f64>,
}

impl SimStore {
    /// An empty store; call [`alloc_segment`](Self::alloc_segment) first.
    pub fn new() -> SimStore {
        SimStore { base: 0, data: Vec::new() }
    }

    /// Allocate the stencil segment (`initStencilSegment`): a contiguous
    /// region of `bytes` zeroed f64s at a fixed, 2 MB-aligned simulated
    /// physical base.
    pub fn alloc_segment(&mut self, bytes: u64) -> u64 {
        assert_eq!(bytes % 8, 0);
        // A recognizable, 2 MB-aligned physical base.
        self.base = 0x1000_0000;
        self.data = vec![0.0; (bytes / 8) as usize];
        self.base
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    pub fn len_bytes(&self) -> u64 {
        self.data.len() as u64 * 8
    }

    #[inline]
    fn index(&self, addr: u64) -> usize {
        debug_assert!(addr >= self.base, "address below segment");
        debug_assert_eq!(addr % 8, 0, "unaligned f64 access");
        let i = ((addr - self.base) / 8) as usize;
        debug_assert!(i < self.data.len(), "address past segment end");
        i
    }

    #[inline]
    pub fn read_f64(&self, addr: u64) -> f64 {
        self.data[self.index(addr)]
    }

    #[inline]
    pub fn write_f64(&mut self, addr: u64, v: f64) {
        let i = self.index(addr);
        self.data[i] = v;
    }

    /// Bulk copy a slice of f64s into the segment at `addr`.
    pub fn write_slice(&mut self, addr: u64, src: &[f64]) {
        let i = self.index(addr);
        self.data[i..i + src.len()].copy_from_slice(src);
    }

    /// Bulk read `n` f64s from `addr`.
    pub fn read_vec(&self, addr: u64, n: usize) -> Vec<f64> {
        let i = self.index(addr);
        self.data[i..i + n].to_vec()
    }

    /// Borrow `n` f64s starting at `addr` (hot-path vector load).
    #[inline]
    pub fn read_slice(&self, addr: u64, n: usize) -> &[f64] {
        let i = self.index(addr);
        &self.data[i..i + n]
    }
}

impl Default for SimStore {
    fn default() -> Self {
        Self::new()
    }
}

/// Everything the SPUs share: timing models + functional store.
pub struct SharedMem {
    pub llc: SlicedLlc,
    pub noc: MeshNoc,
    pub dram: DramModel,
    pub mapper: SliceMapper,
    pub store: SimStore,
    pub llc_cfg: LlcConfig,
    pub spu_local_latency: u64,
    /// §4.1 hardware present? (ablation knob)
    pub unaligned_hw: bool,
    /// Fig-14 `NearL1` placement: per-SPU private L1 tag models checked
    /// before the LLC, plus their hit latency.
    pub spu_l1: Option<Vec<Cache>>,
    pub spu_l1_latency: u64,
}

impl SharedMem {
    pub fn new(cfg: &SimConfig, policy: MappingPolicy) -> SharedMem {
        SharedMem {
            llc: SlicedLlc::new(cfg),
            noc: MeshNoc::new(&cfg.noc),
            dram: DramModel::new(&cfg.dram, cfg.llc.line_bytes),
            mapper: SliceMapper::new(&cfg.llc, policy),
            store: SimStore::new(),
            llc_cfg: cfg.llc,
            spu_local_latency: cfg.llc.spu_local_latency,
            unaligned_hw: true,
            spu_l1: None,
            spu_l1_latency: cfg.l1.latency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(1024);
        s.write_f64(base, 1.5);
        s.write_f64(base + 8, -2.0);
        assert_eq!(s.read_f64(base), 1.5);
        assert_eq!(s.read_f64(base + 8), -2.0);
        assert_eq!(s.read_f64(base + 16), 0.0);
    }

    #[test]
    fn base_is_2mb_aligned() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(8);
        assert_eq!(base % (2 << 20), 0);
    }

    #[test]
    fn bulk_ops() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(256);
        s.write_slice(base + 16, &[1.0, 2.0, 3.0]);
        assert_eq!(s.read_vec(base + 16, 3), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_segment_panics_in_debug() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(64);
        let _ = s.read_f64(base + 64);
    }
}
