//! The Stencil Processing Unit (§3.3): a pipelined near-cache engine with
//! an instruction buffer, a 10-entry load queue, stream + constant
//! buffers, and a 512-bit (8 × f64) MAC vector unit.
//!
//! The model is *functional and timed*: it really computes the stencil on
//! `f64` data (validated against the golden reference and the PJRT-run JAX
//! artifact) while tracking cycles through the shared LLC/NoC/DRAM models.
//! Timing uses the timestamp style: instructions issue at one per cycle,
//! loads occupy load-queue slots until their (possibly remote / DRAM)
//! completion, and the MAC retires in order — giving exactly the stall
//! behaviour §3.3 describes without a global cycle loop.
//!
//! Two execution modes share this model (see `rust/DESIGN-parallel.md`):
//!
//! - [`Spu::run_group`] — the serial path: one vector group, functional +
//!   timed, directly against the [`ShardedMem`] facade.
//! - `Spu::run_group_functional` + `Spu::replay_group_timing` — the
//!   epoch-parallel split: phase 1 runs the functional side and queues
//!   every tag access as an epoch message; phase 3 replays the identical
//!   timing arithmetic with the reconciled tag outcomes injected.

pub mod sharded;
pub mod slice_state;

pub use sharded::ShardedMem;
pub use sharded::SimStore;
pub use slice_state::{SliceState, TagBank};

use crate::config::SimConfig;
use crate::isa::{CasperProgram, StreamSpec};
use crate::mem::cache::Cache;

use sharded::{FunMem, InstrRec, OutRun, SpuTrace, TagOutStream, TagReq, TimingMem, NO_LINE};

/// SIMD lanes of one SPU (512-bit over f64).
pub const LANES: usize = 8;

/// A stream bound to concrete addresses for one SPU (`initStream`).
#[derive(Debug, Clone, Copy)]
pub struct BoundStream {
    pub spec: StreamSpec,
    /// Current element byte address.
    pub addr: u64,
}

/// Per-SPU event counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct SpuStats {
    /// Dynamic Casper instructions executed.
    pub instrs: u64,
    /// Vector groups (instruction-sequence replays) completed.
    pub groups: u64,
    pub loads: u64,
    pub stores: u64,
    /// Loads served entirely by the local slice.
    pub local_loads: u64,
    /// Loads that touched at least one remote slice.
    pub remote_loads: u64,
    /// Unaligned loads merged into one access by the §4.1 hardware.
    pub merged_unaligned: u64,
    /// Unaligned loads split in two (cross-slice).
    pub split_unaligned: u64,
    /// Cycles the issue stage stalled on a full load queue. Live
    /// accounting happens on the detachable [`SpuTimer`]
    /// ([`SpuTimer::lq_stalls()`]); the engine folds it into this field when
    /// aggregating run stats, so digests and checkpoints are unchanged.
    pub lq_stall_cycles: u64,
}

impl SpuStats {
    pub fn add(&mut self, o: &SpuStats) {
        self.instrs += o.instrs;
        self.groups += o.groups;
        self.loads += o.loads;
        self.stores += o.stores;
        self.local_loads += o.local_loads;
        self.remote_loads += o.remote_loads;
        self.merged_unaligned += o.merged_unaligned;
        self.split_unaligned += o.split_unaligned;
        self.lq_stall_cycles += o.lq_stall_cycles;
    }
}

/// Fixed-capacity ring buffer of in-flight load completion times — the
/// hardware's 10-entry load queue. Replaces a `VecDeque` on the group
/// hot path: capacity is fixed at construction, so push/pop are two or
/// three arithmetic ops on a flat slice with no growth or wrap-masking
/// machinery (§Perf, `spu_64k_points`).
#[derive(Debug, Clone)]
struct LoadQueue {
    slots: Box<[u64]>,
    head: usize,
    len: usize,
}

impl LoadQueue {
    fn new(capacity: usize) -> LoadQueue {
        assert!(capacity >= 1, "load queue needs at least one entry");
        LoadQueue { slots: vec![0; capacity].into_boxed_slice(), head: 0, len: 0 }
    }

    /// Zero-capacity stand-in installed while the real timer is lent out
    /// via [`Spu::take_timer`]. Must never be exercised.
    fn placeholder() -> LoadQueue {
        LoadQueue { slots: Box::new([]), head: 0, len: 0 }
    }

    #[inline]
    fn is_full(&self) -> bool {
        self.len == self.slots.len()
    }

    #[inline]
    fn pop_front(&mut self) -> u64 {
        debug_assert!(self.len > 0);
        let v = self.slots[self.head];
        self.head += 1;
        if self.head == self.slots.len() {
            self.head = 0;
        }
        self.len -= 1;
        v
    }

    #[inline]
    fn push_back(&mut self, v: u64) {
        debug_assert!(self.len < self.slots.len());
        let mut tail = self.head + self.len;
        if tail >= self.slots.len() {
            tail -= self.slots.len();
        }
        self.slots[tail] = v;
        self.len += 1;
    }
}

/// The timing half of one SPU: pipeline clock, retire clock, the load
/// queue, and the stall accounting — exactly the state the phase-3 replay
/// mutates and the functional fan-out never touches. The pipelined engine
/// lends it to the dedicated replay worker via `Spu::take_timer` /
/// `Spu::restore_timer` while the rest of the SPU keeps fanning out the
/// next epoch.
#[derive(Debug, Clone)]
pub struct SpuTimer {
    /// Local pipeline time (next issue cycle).
    pub now: u64,
    /// Completion time of the latest retired group.
    pub done: u64,
    /// Completion times of in-flight loads (bounded by the LQ size).
    lq: LoadQueue,
    /// Cycles the issue stage stalled on a full load queue. Folded into
    /// the aggregated [`SpuStats::lq_stall_cycles`] at run end (the digest
    /// and checkpoint journal see the same totals as ever).
    lq_stalls: u64,
}

impl SpuTimer {
    fn new(load_queue: usize) -> SpuTimer {
        SpuTimer { now: 0, done: 0, lq: LoadQueue::new(load_queue), lq_stalls: 0 }
    }

    /// Load-queue stall cycles accumulated so far (see
    /// [`SpuStats::lq_stall_cycles`]).
    pub fn lq_stalls(&self) -> u64 {
        self.lq_stalls
    }

    /// Drain: the SPU is finished when its pipeline AND last memory
    /// operation complete.
    pub fn finish_time(&self) -> u64 {
        self.done.max(self.now)
    }

    /// Epoch phase 3: replay one group's timing (issue, load queue,
    /// ports, NoC, DRAM) for the SPU homed at `home_slice`, with the
    /// reconciled tag outcomes injected from `outs[slice]`. Mirrors the
    /// timing half of [`Spu::run_group`] exactly; lives on the timer so
    /// the pipelined replay worker can run it with only the timing halves
    /// in hand.
    pub(crate) fn replay_group(
        &mut self,
        mem: &mut TimingMem<'_>,
        home_slice: usize,
        recs: &[InstrRec],
        outs: &mut [TagOutStream],
    ) {
        let mut group_ready: u64 = self.now;
        for rec in recs {
            let mut t = self.now;
            if self.lq.is_full() {
                let free_at = self.lq.pop_front();
                if free_at > t {
                    self.lq_stalls += free_at - t;
                    t = free_at;
                }
            }
            let completion = if rec.l1_hit {
                t + mem.spu_l1_latency
            } else {
                let mut ready = t;
                for r in 0..rec.n_reqs as usize {
                    let slice = rec.slices[r] as usize;
                    let lines: &[u64] =
                        if rec.merged { &rec.lines[..2] } else { &rec.lines[r..r + 1] };
                    let out = outs[slice].next();
                    ready = ready.max(mem.load_slice_request(home_slice, slice, lines, t, Some(&out)));
                }
                ready
            };
            self.lq.push_back(completion);
            group_ready = group_ready.max(completion);
            if rec.has_store {
                let slice = rec.store_slice as usize;
                let out = outs[slice].next();
                let st = mem.store_request(home_slice, slice, rec.store_addr, t, Some(&out));
                group_ready = group_ready.max(st);
            }
            self.now = t + 1;
        }
        self.done = self.done.max(group_ready);
    }
}

/// One stencil processing unit attached to LLC slice `slice`.
#[derive(Debug, Clone)]
pub struct Spu {
    pub id: usize,
    /// Home slice = NoC node.
    pub slice: usize,
    program: CasperProgram,
    streams: Vec<BoundStream>,
    /// The timing half (pipeline/retire clocks, load queue, stalls) —
    /// detachable for the pipelined engine's replay worker.
    pub timer: SpuTimer,
    /// Vector accumulator.
    acc: [f64; LANES],
    pub stats: SpuStats,
    /// Remaining output elements (`setNElements` countdown).
    remaining: u64,
    simd_lanes: usize,
    /// Fig-14 `NearL1` placement: a per-SPU private L1 tag model checked
    /// before the LLC (owned by the SPU so phase 1 can run it without
    /// touching shared state).
    l1: Option<Cache>,
}

impl Spu {
    pub fn new(id: usize, slice: usize, cfg: &SimConfig, program: CasperProgram) -> Spu {
        let n_streams = program.streams.len();
        Spu {
            id,
            slice,
            program,
            streams: Vec::with_capacity(n_streams),
            timer: SpuTimer::new(cfg.spu.load_queue),
            acc: [0.0; LANES],
            stats: SpuStats::default(),
            remaining: 0,
            simd_lanes: cfg.spu.simd_lanes().min(LANES),
            l1: None,
        }
    }

    /// Detach the timing half for a pipelined step (the replay worker owns
    /// it until [`restore_timer`](Self::restore_timer)). The placeholder
    /// left behind must not be exercised — the functional fan-out never
    /// touches timer state, which is the point of the split.
    pub(crate) fn take_timer(&mut self) -> SpuTimer {
        std::mem::replace(
            &mut self.timer,
            SpuTimer { now: 0, done: 0, lq: LoadQueue::placeholder(), lq_stalls: 0 },
        )
    }

    /// Re-attach the timing half after a pipelined step.
    pub(crate) fn restore_timer(&mut self, timer: SpuTimer) {
        debug_assert!(self.timer.lq.slots.is_empty(), "timer restored twice");
        self.timer = timer;
    }

    /// Attach (or detach) the NearL1 private L1 tag model, preserving any
    /// existing tag state the caller hands back.
    pub fn set_l1(&mut self, l1: Option<Cache>) {
        self.l1 = l1;
    }

    /// Take the private L1 out (e.g. to survive an SPU rebuild).
    pub fn take_l1(&mut self) -> Option<Cache> {
        self.l1.take()
    }

    /// Bind stream base addresses for the next work chunk (`initStream`).
    /// `bases[i]` is the byte address of stream `i`'s first element.
    pub fn init_streams(&mut self, bases: &[u64]) {
        assert_eq!(bases.len(), self.program.streams.len(), "one base per stream");
        self.streams = self
            .program
            .streams
            .iter()
            .zip(bases)
            .map(|(spec, &addr)| BoundStream { spec: *spec, addr })
            .collect();
    }

    /// Bind a single stream (the `initStream` API call). Streams may be
    /// bound piecemeal; unbound streams default to the segment base only
    /// after all are set.
    pub fn set_stream(&mut self, stream_id: usize, addr: u64) -> anyhow::Result<()> {
        let n = self.program.streams.len();
        anyhow::ensure!(stream_id < n, "stream {stream_id} out of range (program has {n})");
        if self.streams.len() != n {
            self.streams = self
                .program
                .streams
                .iter()
                .map(|spec| BoundStream { spec: *spec, addr: 0 })
                .collect();
        }
        self.streams[stream_id].addr = addr;
        Ok(())
    }

    /// `setNElements`: how many output elements to produce.
    pub fn set_n_elements(&mut self, n: u64) {
        self.remaining = n;
    }

    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    pub fn is_done(&self) -> bool {
        self.remaining == 0
    }

    pub fn program(&self) -> &CasperProgram {
        &self.program
    }

    /// Swap in a new (validated) program in place — the multi-pass path
    /// between accelerator passes. Unlike rebuilding via [`Spu::new`],
    /// this preserves the timing state (`now`/`done`/load queue), the
    /// event counters, and any private L1 tags, so passes account
    /// back-to-back on one continuous SPU timeline. Stream bindings are
    /// cleared (the stream table changed); the next `bind_chunk`
    /// rebinds them.
    pub fn set_program(&mut self, program: CasperProgram) {
        self.program = program;
        self.streams.clear();
    }

    /// Execute one vector group (≤ 8 output elements; the tail group may
    /// be narrower). Returns false when no work remains.
    pub fn run_group(&mut self, mem: &mut ShardedMem) -> bool {
        if self.remaining == 0 {
            return false;
        }
        let lanes = (self.remaining as usize).min(self.simd_lanes);
        let lanes_bytes = (lanes * 8) as u64;
        let n_instrs = self.program.instrs.len();
        let mut group_ready: u64 = self.timer.now;

        for k in 0..n_instrs {
            let instr = self.program.instrs[k];
            let sidx = instr.stream_idx as usize;
            // Hoisted stream lookup: only the bound address is needed
            // here, not the whole BoundStream record.
            let base = self.streams[sidx].addr.wrapping_add_signed(instr.dx() * 8);
            // Issue: 1 instruction per cycle.
            let mut t = self.timer.now;

            // Load-queue back-pressure: wait for the oldest entry.
            if self.timer.lq.is_full() {
                let free_at = self.timer.lq.pop_front();
                if free_at > t {
                    self.timer.lq_stalls += free_at - t;
                    t = free_at;
                }
            }

            // Timed load of the 64 B operand (8 B-aligned).
            let completion = self.timed_load(mem, base, t);
            self.timer.lq.push_back(completion);
            group_ready = group_ready.max(completion);

            // Functional MAC across lanes (one contiguous vector load —
            // the 512-bit operand).
            let c = self.program.constants[instr.const_idx as usize];
            if instr.clear_acc {
                self.acc = [0.0; LANES];
            }
            let operand = mem.store.read_slice(base, lanes);
            for (a, &v) in self.acc.iter_mut().zip(operand) {
                *a += c * v;
            }

            self.stats.instrs += 1;
            self.stats.loads += 1;

            if instr.enable_output {
                // Store the accumulator through the output stream. The
                // store enters the LLC queue at issue time (the data
                // follows once the accumulator retires); its completion
                // cannot precede the group's last load.
                let out_addr = self.streams[CasperProgram::OUT_STREAM as usize].addr;
                mem.store.write_slice(out_addr, &self.acc[..lanes]);
                let st = self.timed_store(mem, out_addr, t);
                group_ready = group_ready.max(st);
                self.stats.stores += 1;
            }
            if instr.advance_stream {
                self.streams[sidx].addr += lanes_bytes;
            }
            self.timer.now = t + 1;
        }
        // Output stream advances implicitly with each group.
        self.streams[CasperProgram::OUT_STREAM as usize].addr += lanes_bytes;

        self.remaining -= lanes as u64;
        self.stats.groups += 1;
        self.timer.done = self.timer.done.max(group_ready);
        true
    }

    /// Epoch phase 1: execute one vector group *functionally* — real loads
    /// from the (step-immutable) input array, the MAC, and a staged output
    /// write — while queueing every LLC tag access as an epoch message in
    /// `trace` and recording the per-instruction request geometry for the
    /// phase-3 timing replay. Mirrors [`run_group`](Self::run_group)
    /// exactly minus the timing state (the [`SpuTimer`]), which
    /// [`SpuTimer::replay_group`] advances later; the engine identity
    /// tests pin the equivalence. Takes the shared-read [`FunMem`] view so
    /// the pipelined engine can fan out while the timing half is away.
    pub(crate) fn run_group_functional(
        &mut self,
        mem: FunMem<'_>,
        round: u32,
        trace: &mut SpuTrace,
    ) -> bool {
        if self.remaining == 0 {
            return false;
        }
        let lanes = (self.remaining as usize).min(self.simd_lanes);
        let lanes_bytes = (lanes * 8) as u64;
        let n_instrs = self.program.instrs.len();

        for k in 0..n_instrs {
            let instr = self.program.instrs[k];
            let sidx = instr.stream_idx as usize;
            let base = self.streams[sidx].addr.wrapping_add_signed(instr.dx() * 8);

            let req = crate::mem::unaligned::decompose(base, mem.llc_cfg, mem.mapper);
            let mut rec = if self.l1_serves(&req.lines[..req.n_lines]) {
                self.stats.local_loads += 1;
                InstrRec::l1_served()
            } else {
                let merged = req.n_lines == 2 && req.single_access && mem.unaligned_hw;
                if req.n_lines == 2 {
                    if merged {
                        self.stats.merged_unaligned += 1;
                    } else {
                        self.stats.split_unaligned += 1;
                    }
                }
                let n_reqs = req.llc_requests(mem.unaligned_hw);
                if (0..req.n_lines).all(|i| req.slices[i] == self.slice) {
                    self.stats.local_loads += 1;
                } else {
                    self.stats.remote_loads += 1;
                }
                for r in 0..n_reqs {
                    let slice = req.slices[r.min(req.n_lines - 1)];
                    let (line0, line1) = if merged {
                        (req.lines[0], req.lines[1])
                    } else {
                        (req.lines[r], NO_LINE)
                    };
                    trace.tagq[slice].push(TagReq { round, line0, line1, write: false });
                }
                InstrRec {
                    l1_hit: false,
                    n_reqs: n_reqs as u8,
                    merged,
                    slices: [req.slices[0] as u16, req.slices[1] as u16],
                    lines: req.lines,
                    has_store: false,
                    store_slice: 0,
                    store_addr: 0,
                }
            };

            // Functional MAC (identical to the serial path).
            let c = self.program.constants[instr.const_idx as usize];
            if instr.clear_acc {
                self.acc = [0.0; LANES];
            }
            let operand = mem.store.read_slice(base, lanes);
            for (a, &v) in self.acc.iter_mut().zip(operand) {
                *a += c * v;
            }

            self.stats.instrs += 1;
            self.stats.loads += 1;

            if instr.enable_output {
                let out_addr = self.streams[CasperProgram::OUT_STREAM as usize].addr;
                // Stage the output write instead of touching the shared
                // store: chunks are disjoint across SPUs and never read
                // back within the current `run_step` (pass) — a later
                // pass's accumulator stream re-reads them only after this
                // pass fully flushed — so epoch-end application is
                // invisible.
                match trace.outs.last_mut() {
                    Some(run) if run.addr + run.data.len() as u64 * 8 == out_addr => {
                        run.data.extend_from_slice(&self.acc[..lanes]);
                    }
                    _ => trace.outs.push(OutRun { addr: out_addr, data: self.acc[..lanes].to_vec() }),
                }
                let slice = mem.mapper.slice_of(out_addr);
                rec.has_store = true;
                rec.store_slice = slice as u16;
                rec.store_addr = out_addr;
                let line0 = out_addr & !(mem.llc_cfg.line_bytes as u64 - 1);
                trace.tagq[slice].push(TagReq { round, line0, line1: NO_LINE, write: true });
                self.stats.stores += 1;
            }
            if instr.advance_stream {
                self.streams[sidx].addr += lanes_bytes;
            }
            trace.instrs.push(rec);
        }
        self.streams[CasperProgram::OUT_STREAM as usize].addr += lanes_bytes;

        self.remaining -= lanes as u64;
        self.stats.groups += 1;
        trace.groups += 1;
        true
    }

    /// Epoch phase 3 with the facade still whole (phased / test paths):
    /// delegates to [`SpuTimer::replay_group`] through a transient timing
    /// view.
    pub(crate) fn replay_group_timing(
        &mut self,
        mem: &mut ShardedMem,
        recs: &[InstrRec],
        outs: &mut [TagOutStream],
    ) {
        let mut tv = mem.timing_view();
        self.timer.replay_group(&mut tv, self.slice, recs, outs);
    }

    /// Drain: the SPU is finished when its pipeline AND last memory
    /// operation complete.
    pub fn finish_time(&self) -> u64 {
        self.timer.finish_time()
    }

    /// NearL1 check shared by both execution modes: probe (and fill) the
    /// private L1 tags for every line of the request; true when the L1
    /// serves the whole load. A miss still installs the lines for reuse.
    #[inline]
    fn l1_serves(&mut self, lines: &[u64]) -> bool {
        match self.l1.as_mut() {
            None => false,
            Some(l1) => {
                let mut all_hit = true;
                for &line in lines {
                    all_hit &= l1.access(line, false).hit;
                }
                all_hit
            }
        }
    }

    /// Timed 64 B load at 8 B-aligned `addr`, issued at `t`; returns the
    /// data-ready cycle. Implements §4.1 (merged unaligned access when
    /// both lines share the local... any single slice) and remote-slice
    /// NoC round trips.
    fn timed_load(&mut self, mem: &mut ShardedMem, addr: u64, t: u64) -> u64 {
        let req = crate::mem::unaligned::decompose(addr, &mem.llc_cfg, &mem.mapper);

        // Fig-14 NearL1 placement: a private L1 fronts the LLC. On a miss
        // the lines are now resident in the L1 tags for future reuse.
        if self.l1_serves(&req.lines[..req.n_lines]) {
            self.stats.local_loads += 1;
            return t + mem.spu_l1_latency;
        }
        let merged = req.n_lines == 2 && req.single_access && mem.unaligned_hw;
        if req.n_lines == 2 {
            if merged {
                self.stats.merged_unaligned += 1;
            } else {
                self.stats.split_unaligned += 1;
            }
        }
        let n_reqs = req.llc_requests(mem.unaligned_hw);
        if (0..req.n_lines).all(|i| req.slices[i] == self.slice) {
            self.stats.local_loads += 1;
        } else {
            self.stats.remote_loads += 1;
        }

        let mut ready = t;
        for r in 0..n_reqs {
            let slice = req.slices[r.min(req.n_lines - 1)];
            // A merged unaligned access checks BOTH lines under one port
            // slot (dual tag port).
            let lines: &[u64] =
                if merged { &req.lines[..2] } else { std::slice::from_ref(&req.lines[r]) };
            ready = ready.max(mem.load_slice_request(self.slice, slice, lines, t, None));
        }
        ready
    }

    /// Timed 64 B store of the accumulator at `t`.
    fn timed_store(&mut self, mem: &mut ShardedMem, addr: u64, t: u64) -> u64 {
        let slice = mem.mapper.slice_of(addr);
        mem.store_request(self.slice, slice, addr, t, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingPolicy, SimConfig};
    use crate::isa::ProgramBuilder;
    use crate::mapping::StencilSegment;
    use crate::stencil::StencilKind;

    fn setup(kind: StencilKind) -> (SimConfig, ShardedMem, Spu) {
        let cfg = SimConfig::default();
        let mut mem = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        let seg = mem.store.alloc_segment(4 << 20);
        mem.mapper.set_segment(StencilSegment::new(seg, 4 << 20));
        let prog = ProgramBuilder::new().build(&kind.descriptor()).unwrap();
        let spu = Spu::new(0, 0, &cfg, prog);
        (cfg, mem, spu)
    }

    #[test]
    fn jacobi1d_functional_correctness() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // Input: 64 doubles at segment start; output at +2048 bytes.
        let n = 64u64;
        for i in 0..n {
            mem.store.write_f64(base + i * 8, (i * i % 23) as f64);
        }
        let out_base = base + 2048;
        // Compute interior points [1, 63): 62 outputs starting at x=1.
        // Streams: 0=output at B[1]; 1=input row (single row group for 1D
        // radius-1: row dy=0 holds all three taps).
        spu.init_streams(&[out_base + 8, base + 8]);
        spu.set_n_elements(n - 2);
        while spu.run_group(&mut mem) {}
        for i in 1..n - 1 {
            let want = ((i - 1) * (i - 1) % 23) as f64 / 3.0
                + (i * i % 23) as f64 / 3.0
                + ((i + 1) * (i + 1) % 23) as f64 / 3.0;
            let got = mem.store.read_f64(out_base + i * 8);
            assert!((got - want).abs() < 1e-12, "i={i} got={got} want={want}");
        }
        assert!(spu.is_done());
        assert_eq!(spu.stats.groups, 8); // 62 points / 8 lanes → 8 groups
        assert_eq!(spu.stats.stores, 8);
    }

    #[test]
    fn tail_group_is_narrow() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        spu.init_streams(&[base + 4096, base + 8]);
        spu.set_n_elements(11); // 8 + 3
        assert!(spu.run_group(&mut mem));
        assert_eq!(spu.remaining(), 3);
        assert!(spu.run_group(&mut mem));
        assert_eq!(spu.remaining(), 0);
        assert!(!spu.run_group(&mut mem));
    }

    #[test]
    fn local_loads_dominate_on_local_block() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // All streams inside block 0 → slice 0 = SPU 0's slice.
        spu.init_streams(&[base + 64 * 1024, base + 8]);
        spu.set_n_elements(512);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.remote_loads == 0, "{:?}", spu.stats);
        assert!(spu.stats.local_loads > 0);
    }

    #[test]
    fn remote_block_counts_remote_loads() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // Input stream points into block 1 (slice 1) while the SPU sits at
        // slice 0.
        spu.init_streams(&[base + 8, base + 128 * 1024 + 8]);
        spu.set_n_elements(64);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.remote_loads > 0);
        assert!(mem.noc.messages > 0);
        assert!(mem.llc.bank(1).remote_reqs > 0, "target slice saw remote requests");
    }

    #[test]
    fn unaligned_loads_merge_with_hardware() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // Offset +8: the 3-tap row makes dx=-1,0,+1 accesses; the ±1 are
        // unaligned and (same block) merge.
        spu.init_streams(&[base + (1 << 16), base + 8]);
        spu.set_n_elements(64);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.merged_unaligned > 0);
        assert_eq!(spu.stats.split_unaligned, 0);
    }

    #[test]
    fn unaligned_split_without_hardware() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        mem.unaligned_hw = false;
        let base = mem.store.base();
        spu.init_streams(&[base + (1 << 16), base + 8]);
        spu.set_n_elements(64);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.split_unaligned > 0);
        assert_eq!(spu.stats.merged_unaligned, 0);
    }

    #[test]
    fn timing_advances_and_throughput_is_sane() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi2D);
        let base = mem.store.base();
        let out = base + (2 << 20);
        let row = 1024u64; // bytes per notional row
        spu.init_streams(&[out, base, base + row, base + 2 * row]);
        spu.set_n_elements(1024);
        while spu.run_group(&mut mem) {}
        let t = spu.finish_time();
        // 1024 points / 8 lanes × 5 instrs = 640 issue cycles minimum. The
        // LLC starts cold here, so every line streams from DRAM with the
        // 10-entry load queue bounding the overlap — well above the issue
        // bound but still bounded.
        assert!(t >= 640, "too fast: {t}");
        assert!(t < 60_000, "too slow: {t}");
    }

    #[test]
    fn functional_plus_replay_equals_run_group() {
        // The split execution (phase 1 functional + phase 3 replay) must
        // reproduce the serial path bit for bit on a single SPU, including
        // timing, stats, and bank state.
        for offset in [0u64, 8, 128 * 1024 - 8] {
            let (_cfg, mut mem_a, mut spu_a) = setup(StencilKind::Jacobi1D);
            let (_cfg, mut mem_b, mut spu_b) = setup(StencilKind::Jacobi1D);
            let base = mem_a.store.base();
            for i in 0..4096u64 {
                let v = (i % 97) as f64;
                mem_a.store.write_f64(base + i * 8, v);
                mem_b.store.write_f64(base + i * 8, v);
            }
            let streams = [base + (1 << 20), base + offset + 8];
            spu_a.init_streams(&streams);
            spu_a.set_n_elements(300);
            while spu_a.run_group(&mut mem_a) {}

            spu_b.init_streams(&streams);
            spu_b.set_n_elements(300);
            // Phase 1: functional + trace.
            let mut trace = SpuTrace::new(mem_b.llc_cfg.slices);
            let mut round = 0u32;
            while spu_b.run_group_functional(mem_b.fun_view(), round, &mut trace) {
                round += 1;
            }
            for run in trace.outs.drain(..) {
                mem_b.store.write_slice(run.addr, &run.data);
            }
            // Phase 2: per-slice tag reconciliation through the REAL
            // reconciliation code (single SPU → trivial merge order),
            // against the same banks the serial path used.
            let way_limit = mem_b.llc.way_limit();
            let mut streams_out: Vec<TagOutStream> = Vec::new();
            for (s, q) in trace.tagq.iter().enumerate() {
                let outs = crate::coordinator::epoch::drain_slice_requests(
                    &mut mem_b.llc.bank_mut(s).tags,
                    std::slice::from_ref(q),
                    way_limit,
                );
                streams_out.push(TagOutStream::new(outs.into_iter().next().unwrap()));
            }
            // Phase 3: timing replay, group by group.
            let n_instrs = spu_b.program().instrs.len();
            for g in 0..trace.groups as usize {
                let recs = &trace.instrs[g * n_instrs..(g + 1) * n_instrs];
                spu_b.replay_group_timing(&mut mem_b, recs, &mut streams_out);
            }

            assert_eq!(spu_a.stats, spu_b.stats, "offset {offset}");
            assert_eq!(spu_a.timer.lq_stalls(), spu_b.timer.lq_stalls(), "offset {offset}");
            assert_eq!(spu_a.finish_time(), spu_b.finish_time(), "offset {offset}");
            assert_eq!(mem_a.llc.stats(), mem_b.llc.stats(), "offset {offset}");
            assert_eq!(mem_a.dram.accesses, mem_b.dram.accesses, "offset {offset}");
            assert_eq!(mem_a.noc.messages, mem_b.noc.messages, "offset {offset}");
            let a_out = mem_a.store.read_vec(base + (1 << 20), 300);
            let b_out = mem_b.store.read_vec(base + (1 << 20), 300);
            assert_eq!(a_out, b_out, "offset {offset}");
            assert!(streams_out.iter().all(|s| s.fully_consumed()));
        }
    }
}
