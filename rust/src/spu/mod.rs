//! The Stencil Processing Unit (§3.3): a pipelined near-cache engine with
//! an instruction buffer, a 10-entry load queue, stream + constant
//! buffers, and a 512-bit (8 × f64) MAC vector unit.
//!
//! The model is *functional and timed*: it really computes the stencil on
//! `f64` data (validated against the golden reference and the PJRT-run JAX
//! artifact) while tracking cycles through the shared LLC/NoC/DRAM models.
//! Timing uses the timestamp style: instructions issue at one per cycle,
//! loads occupy load-queue slots until their (possibly remote / DRAM)
//! completion, and the MAC retires in order — giving exactly the stall
//! behaviour §3.3 describes without a global cycle loop.

pub mod shared;

pub use shared::SharedMem;

use crate::config::SimConfig;
use crate::isa::{CasperProgram, StreamSpec};

/// SIMD lanes of one SPU (512-bit over f64).
pub const LANES: usize = 8;

/// A stream bound to concrete addresses for one SPU (`initStream`).
#[derive(Debug, Clone, Copy)]
pub struct BoundStream {
    pub spec: StreamSpec,
    /// Current element byte address.
    pub addr: u64,
}

/// Per-SPU event counters.
#[derive(Debug, Default, Clone, Copy)]
pub struct SpuStats {
    /// Dynamic Casper instructions executed.
    pub instrs: u64,
    /// Vector groups (instruction-sequence replays) completed.
    pub groups: u64,
    pub loads: u64,
    pub stores: u64,
    /// Loads served entirely by the local slice.
    pub local_loads: u64,
    /// Loads that touched at least one remote slice.
    pub remote_loads: u64,
    /// Unaligned loads merged into one access by the §4.1 hardware.
    pub merged_unaligned: u64,
    /// Unaligned loads split in two (cross-slice).
    pub split_unaligned: u64,
    /// Cycles the issue stage stalled on a full load queue.
    pub lq_stall_cycles: u64,
}

impl SpuStats {
    pub fn add(&mut self, o: &SpuStats) {
        self.instrs += o.instrs;
        self.groups += o.groups;
        self.loads += o.loads;
        self.stores += o.stores;
        self.local_loads += o.local_loads;
        self.remote_loads += o.remote_loads;
        self.merged_unaligned += o.merged_unaligned;
        self.split_unaligned += o.split_unaligned;
        self.lq_stall_cycles += o.lq_stall_cycles;
    }
}

/// Fixed-capacity ring buffer of in-flight load completion times — the
/// hardware's 10-entry load queue. Replaces a `VecDeque` on the group
/// hot path: capacity is fixed at construction, so push/pop are two or
/// three arithmetic ops on a flat slice with no growth or wrap-masking
/// machinery (§Perf, `spu_64k_points`).
#[derive(Debug, Clone)]
struct LoadQueue {
    slots: Box<[u64]>,
    head: usize,
    len: usize,
}

impl LoadQueue {
    fn new(capacity: usize) -> LoadQueue {
        assert!(capacity >= 1, "load queue needs at least one entry");
        LoadQueue { slots: vec![0; capacity].into_boxed_slice(), head: 0, len: 0 }
    }

    #[inline]
    fn is_full(&self) -> bool {
        self.len == self.slots.len()
    }

    #[inline]
    fn pop_front(&mut self) -> u64 {
        debug_assert!(self.len > 0);
        let v = self.slots[self.head];
        self.head += 1;
        if self.head == self.slots.len() {
            self.head = 0;
        }
        self.len -= 1;
        v
    }

    #[inline]
    fn push_back(&mut self, v: u64) {
        debug_assert!(self.len < self.slots.len());
        let mut tail = self.head + self.len;
        if tail >= self.slots.len() {
            tail -= self.slots.len();
        }
        self.slots[tail] = v;
        self.len += 1;
    }
}

/// One stencil processing unit attached to LLC slice `slice`.
#[derive(Debug, Clone)]
pub struct Spu {
    pub id: usize,
    /// Home slice = NoC node.
    pub slice: usize,
    program: CasperProgram,
    streams: Vec<BoundStream>,
    /// Completion times of in-flight loads (bounded by the LQ size).
    lq: LoadQueue,
    /// Local pipeline time (next issue cycle).
    pub now: u64,
    /// Completion time of the latest retired group.
    pub done: u64,
    /// Vector accumulator.
    acc: [f64; LANES],
    pub stats: SpuStats,
    /// Remaining output elements (`setNElements` countdown).
    remaining: u64,
    simd_lanes: usize,
}

impl Spu {
    pub fn new(id: usize, slice: usize, cfg: &SimConfig, program: CasperProgram) -> Spu {
        let n_streams = program.streams.len();
        Spu {
            id,
            slice,
            program,
            streams: Vec::with_capacity(n_streams),
            lq: LoadQueue::new(cfg.spu.load_queue),
            now: 0,
            done: 0,
            acc: [0.0; LANES],
            stats: SpuStats::default(),
            remaining: 0,
            simd_lanes: cfg.spu.simd_lanes().min(LANES),
        }
    }

    /// Bind stream base addresses for the next work chunk (`initStream`).
    /// `bases[i]` is the byte address of stream `i`'s first element.
    pub fn init_streams(&mut self, bases: &[u64]) {
        assert_eq!(bases.len(), self.program.streams.len(), "one base per stream");
        self.streams = self
            .program
            .streams
            .iter()
            .zip(bases)
            .map(|(spec, &addr)| BoundStream { spec: *spec, addr })
            .collect();
    }

    /// Bind a single stream (the `initStream` API call). Streams may be
    /// bound piecemeal; unbound streams default to the segment base only
    /// after all are set.
    pub fn set_stream(&mut self, stream_id: usize, addr: u64) -> anyhow::Result<()> {
        let n = self.program.streams.len();
        anyhow::ensure!(stream_id < n, "stream {stream_id} out of range (program has {n})");
        if self.streams.len() != n {
            self.streams = self
                .program
                .streams
                .iter()
                .map(|spec| BoundStream { spec: *spec, addr: 0 })
                .collect();
        }
        self.streams[stream_id].addr = addr;
        Ok(())
    }

    /// `setNElements`: how many output elements to produce.
    pub fn set_n_elements(&mut self, n: u64) {
        self.remaining = n;
    }

    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    pub fn is_done(&self) -> bool {
        self.remaining == 0
    }

    pub fn program(&self) -> &CasperProgram {
        &self.program
    }

    /// Execute one vector group (≤ 8 output elements; the tail group may
    /// be narrower). Returns false when no work remains.
    pub fn run_group(&mut self, mem: &mut SharedMem) -> bool {
        if self.remaining == 0 {
            return false;
        }
        let lanes = (self.remaining as usize).min(self.simd_lanes);
        let lanes_bytes = (lanes * 8) as u64;
        let n_instrs = self.program.instrs.len();
        let mut group_ready: u64 = self.now;

        for k in 0..n_instrs {
            let instr = self.program.instrs[k];
            let sidx = instr.stream_idx as usize;
            // Hoisted stream lookup: only the bound address is needed
            // here, not the whole BoundStream record.
            let base = self.streams[sidx].addr.wrapping_add_signed(instr.dx() * 8);
            // Issue: 1 instruction per cycle.
            let mut t = self.now;

            // Load-queue back-pressure: wait for the oldest entry.
            if self.lq.is_full() {
                let free_at = self.lq.pop_front();
                if free_at > t {
                    self.stats.lq_stall_cycles += free_at - t;
                    t = free_at;
                }
            }

            // Timed load of the 64 B operand (8 B-aligned).
            let completion = self.timed_load(mem, base, t);
            self.lq.push_back(completion);
            group_ready = group_ready.max(completion);

            // Functional MAC across lanes (one contiguous vector load —
            // the 512-bit operand).
            let c = self.program.constants[instr.const_idx as usize];
            if instr.clear_acc {
                self.acc = [0.0; LANES];
            }
            let operand = mem.store.read_slice(base, lanes);
            for (a, &v) in self.acc.iter_mut().zip(operand) {
                *a += c * v;
            }

            self.stats.instrs += 1;
            self.stats.loads += 1;

            if instr.enable_output {
                // Store the accumulator through the output stream. The
                // store enters the LLC queue at issue time (the data
                // follows once the accumulator retires); its completion
                // cannot precede the group's last load.
                let out_addr = self.streams[CasperProgram::OUT_STREAM as usize].addr;
                mem.store.write_slice(out_addr, &self.acc[..lanes]);
                let st = self.timed_store(mem, out_addr, t);
                group_ready = group_ready.max(st);
                self.stats.stores += 1;
            }
            if instr.advance_stream {
                self.streams[sidx].addr += lanes_bytes;
            }
            self.now = t + 1;
        }
        // Output stream advances implicitly with each group.
        self.streams[CasperProgram::OUT_STREAM as usize].addr += lanes_bytes;

        self.remaining -= lanes as u64;
        self.stats.groups += 1;
        self.done = self.done.max(group_ready);
        true
    }

    /// Drain: the SPU is finished when its pipeline AND last memory
    /// operation complete.
    pub fn finish_time(&self) -> u64 {
        self.done.max(self.now)
    }

    /// Timed 64 B load at 8 B-aligned `addr`, issued at `t`; returns the
    /// data-ready cycle. Implements §4.1 (merged unaligned access when
    /// both lines share the local... any single slice) and remote-slice
    /// NoC round trips.
    fn timed_load(&mut self, mem: &mut SharedMem, addr: u64, t: u64) -> u64 {
        let req = crate::mem::unaligned::decompose(addr, &mem.llc_cfg, &mem.mapper);

        // Fig-14 NearL1 placement: a private L1 fronts the LLC.
        if let Some(l1s) = mem.spu_l1.as_mut() {
            let l1 = &mut l1s[self.id];
            let mut all_hit = true;
            for i in 0..req.n_lines {
                all_hit &= l1.access(req.lines[i], false).hit;
            }
            if all_hit {
                self.stats.local_loads += 1;
                return t + mem.spu_l1_latency;
            }
            // Miss: fall through to the LLC path (lines now resident in
            // the L1 tags for future reuse).
        }
        let merged = req.n_lines == 2 && req.single_access && mem.unaligned_hw;
        if req.n_lines == 2 {
            if merged {
                self.stats.merged_unaligned += 1;
            } else {
                self.stats.split_unaligned += 1;
            }
        }
        let mut ready = t;
        let n_reqs = req.llc_requests(mem.unaligned_hw);
        let all_local = (0..req.n_lines).all(|i| req.slices[i] == self.slice);
        if all_local {
            self.stats.local_loads += 1;
        } else {
            self.stats.remote_loads += 1;
        }

        for r in 0..n_reqs {
            let slice = req.slices[r.min(req.n_lines - 1)];
            // Request traversal to the slice (free when local). Remote
            // messages pay NoC latency; the contended resource is the
            // slice's single load/store port, arbitrated below.
            let arrive = if slice == self.slice {
                t
            } else {
                mem.noc.record(self.slice, slice);
                t + mem.noc.latency(self.slice, slice, 8)
            };
            let start = mem.llc.claim_port(slice, arrive);
            // Tag/data access. A merged unaligned access checks BOTH lines
            // under one port slot (dual tag port).
            let lines_here: &[u64] = if merged {
                &req.lines[..2]
            } else {
                std::slice::from_ref(&req.lines[r])
            };
            let mut data_at = start + mem.spu_local_latency;
            for (k, &line) in lines_here.iter().enumerate() {
                // A merged access is ONE data-array access with a dual
                // tag match: only the first line counts as the access.
                let out = if k == 0 {
                    mem.llc.access(slice, line, false)
                } else {
                    mem.llc.access_second_tag(slice, line)
                };
                if !out.hit {
                    let done = mem.dram.access(line, false, start);
                    if let Some(wb) = out.writeback {
                        mem.dram.access(wb * mem.llc_cfg.line_bytes as u64, true, start);
                    }
                    data_at = data_at.max(done);
                }
            }
            // Response traversal back.
            let resp = if slice == self.slice {
                data_at
            } else {
                mem.noc.record(slice, self.slice);
                data_at + mem.noc.latency(slice, self.slice, 64)
            };
            ready = ready.max(resp);
            if merged {
                break; // one access covered both lines
            }
        }
        ready
    }

    /// Timed 64 B store of the accumulator at `t`.
    fn timed_store(&mut self, mem: &mut SharedMem, addr: u64, t: u64) -> u64 {
        let slice = mem.mapper.slice_of(addr);
        let arrive = if slice == self.slice {
            t
        } else {
            mem.noc.record(self.slice, slice);
            t + mem.noc.latency(self.slice, slice, 64)
        };
        let start = mem.llc.claim_port(slice, arrive);
        let out = mem.llc.access(slice, addr & !(mem.llc_cfg.line_bytes as u64 - 1), true);
        let mut done = start + mem.spu_local_latency;
        if !out.hit {
            // Write-allocate fill from DRAM (or lower): coherence §4.3 —
            // the LLC obtains the line in writable state.
            done = done.max(mem.dram.access(addr, false, start));
        }
        if let Some(wb) = out.writeback {
            mem.dram.access(wb * mem.llc_cfg.line_bytes as u64, true, start);
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingPolicy, SimConfig};
    use crate::isa::ProgramBuilder;
    use crate::mapping::StencilSegment;
    use crate::stencil::StencilKind;

    fn setup(kind: StencilKind) -> (SimConfig, SharedMem, Spu) {
        let cfg = SimConfig::default();
        let mut mem = SharedMem::new(&cfg, MappingPolicy::StencilSegment);
        let seg = mem.store.alloc_segment(4 << 20);
        mem.mapper.set_segment(StencilSegment::new(seg, 4 << 20));
        let prog = ProgramBuilder::new().build(&kind.descriptor()).unwrap();
        let spu = Spu::new(0, 0, &cfg, prog);
        (cfg, mem, spu)
    }

    #[test]
    fn jacobi1d_functional_correctness() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // Input: 64 doubles at segment start; output at +2048 bytes.
        let n = 64u64;
        for i in 0..n {
            mem.store.write_f64(base + i * 8, (i * i % 23) as f64);
        }
        let out_base = base + 2048;
        // Compute interior points [1, 63): 62 outputs starting at x=1.
        // Streams: 0=output at B[1]; 1=input row (single row group for 1D
        // radius-1: row dy=0 holds all three taps).
        spu.init_streams(&[out_base + 8, base + 8]);
        spu.set_n_elements(n - 2);
        while spu.run_group(&mut mem) {}
        for i in 1..n - 1 {
            let want = ((i - 1) * (i - 1) % 23) as f64 / 3.0
                + (i * i % 23) as f64 / 3.0
                + ((i + 1) * (i + 1) % 23) as f64 / 3.0;
            let got = mem.store.read_f64(out_base + i * 8);
            assert!((got - want).abs() < 1e-12, "i={i} got={got} want={want}");
        }
        assert!(spu.is_done());
        assert_eq!(spu.stats.groups, 8); // 62 points / 8 lanes → 8 groups
        assert_eq!(spu.stats.stores, 8);
    }

    #[test]
    fn tail_group_is_narrow() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        spu.init_streams(&[base + 4096, base + 8]);
        spu.set_n_elements(11); // 8 + 3
        assert!(spu.run_group(&mut mem));
        assert_eq!(spu.remaining(), 3);
        assert!(spu.run_group(&mut mem));
        assert_eq!(spu.remaining(), 0);
        assert!(!spu.run_group(&mut mem));
    }

    #[test]
    fn local_loads_dominate_on_local_block() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // All streams inside block 0 → slice 0 = SPU 0's slice.
        spu.init_streams(&[base + 64 * 1024, base + 8]);
        spu.set_n_elements(512);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.remote_loads == 0, "{:?}", spu.stats);
        assert!(spu.stats.local_loads > 0);
    }

    #[test]
    fn remote_block_counts_remote_loads() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // Input stream points into block 1 (slice 1) while the SPU sits at
        // slice 0.
        spu.init_streams(&[base + 8, base + 128 * 1024 + 8]);
        spu.set_n_elements(64);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.remote_loads > 0);
        assert!(mem.noc.messages > 0);
    }

    #[test]
    fn unaligned_loads_merge_with_hardware() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        let base = mem.store.base();
        // Offset +8: the 3-tap row makes dx=-1,0,+1 accesses; the ±1 are
        // unaligned and (same block) merge.
        spu.init_streams(&[base + (1 << 16), base + 8]);
        spu.set_n_elements(64);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.merged_unaligned > 0);
        assert_eq!(spu.stats.split_unaligned, 0);
    }

    #[test]
    fn unaligned_split_without_hardware() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi1D);
        mem.unaligned_hw = false;
        let base = mem.store.base();
        spu.init_streams(&[base + (1 << 16), base + 8]);
        spu.set_n_elements(64);
        while spu.run_group(&mut mem) {}
        assert!(spu.stats.split_unaligned > 0);
        assert_eq!(spu.stats.merged_unaligned, 0);
    }

    #[test]
    fn timing_advances_and_throughput_is_sane() {
        let (_cfg, mut mem, mut spu) = setup(StencilKind::Jacobi2D);
        let base = mem.store.base();
        let out = base + (2 << 20);
        let row = 1024u64; // bytes per notional row
        spu.init_streams(&[out, base, base + row, base + 2 * row]);
        spu.set_n_elements(1024);
        while spu.run_group(&mut mem) {}
        let t = spu.finish_time();
        // 1024 points / 8 lanes × 5 instrs = 640 issue cycles minimum. The
        // LLC starts cold here, so every line streams from DRAM with the
        // 10-entry load queue bounding the overlap — well above the issue
        // bound but still bounded.
        assert!(t >= 640, "too fast: {t}");
        assert!(t < 60_000, "too slow: {t}");
    }
}
