//! Slice-private memory-system state: everything one LLC slice owns.
//!
//! The epoch-parallel engine (see `rust/DESIGN-parallel.md`) relies on the
//! fact that the contended per-slice resources — the tag/data bank and the
//! single load/store port — are *independently owned*: during the tag
//! reconciliation phase each slice's [`TagBank`] is handed to exactly one
//! worker thread, so slices are simulated concurrently without locks. The
//! pipelined engine goes one step further and moves the tag banks to the
//! functional side of the pipeline outright (via
//! [`SlicedLlc::take_tag_banks`](crate::mem::hierarchy::SlicedLlc::take_tag_banks))
//! while the port/NoC/DRAM counters stay with the timing replay — legal
//! because replay-mode requests never touch tags (see
//! `TimingMem` in `crate::spu::sharded`). The serial path uses the
//! very same state through the
//! [`SlicedLlc`](crate::mem::hierarchy::SlicedLlc) facade, which keeps the
//! execution modes byte-identical.

use crate::mem::cache::{AccessOutcome, Cache};
use crate::mem::ratelimit::RateLimiter;

/// The tag half of one LLC slice: the set-associative tag bank plus the
/// temporal-blocking residency filter. This is the state phase 2 (tag
/// reconciliation) owns exclusively; it carries no timing-domain counters,
/// which is what lets the pipelined engine reconcile epoch *e+1* while
/// epoch *e* is still replaying.
#[derive(Debug, Clone)]
pub struct TagBank {
    /// The slice's set-associative tag bank.
    pub cache: Cache,
    /// Temporal blocking (§temporal-block): the wavefront the SPUs are
    /// consuming this step was produced into this slice on the previous
    /// inner step and is guaranteed resident, so tag probes are bypassed
    /// and no line fill can occur. The coordinator raises the flag on
    /// every inner step of a block (`step % T != 0`) and clears it on
    /// block boundaries.
    pub wavefront_resident: bool,
    /// Tag probes served by wavefront residency — each one a potential
    /// DRAM line fill the blocked schedule avoided.
    pub avoided_fills: u64,
}

impl TagBank {
    pub fn new(slice_bytes: usize, ways: usize, line_bytes: usize) -> TagBank {
        TagBank {
            cache: Cache::new(slice_bytes, ways, line_bytes),
            wavefront_resident: false,
            avoided_fills: 0,
        }
    }

    /// Stand-in installed while the real bank is lent out via
    /// [`SlicedLlc::take_tag_banks`](crate::mem::hierarchy::SlicedLlc::take_tag_banks).
    /// Must never be accessed — replay-mode requests bypass tags entirely.
    pub(crate) fn placeholder() -> TagBank {
        TagBank::new(64, 1, 64)
    }

    /// Demand tag access through the residency filter: the single seam
    /// both engines resolve LLC tags through (the serial path via
    /// [`SlicedLlc`](crate::mem::hierarchy::SlicedLlc), the epoch-parallel
    /// path via its per-slice reconciliation), so temporal blocking is
    /// byte-identical across engines by construction.
    pub fn tag_access(&mut self, addr: u64, write: bool, way_limit: usize) -> AccessOutcome {
        if self.wavefront_resident {
            self.avoided_fills += 1;
            // Stats see a hit (the data is served from the slice); the
            // `avoided` bit lets the tracer attribute it separately.
            if write {
                self.cache.stats.write_hits += 1;
            } else {
                self.cache.stats.read_hits += 1;
            }
            return AccessOutcome {
                hit: true,
                writeback: None,
                prefetch_hit: false,
                avoided: true,
            };
        }
        self.cache.access_ways(addr, write, way_limit)
    }

    /// Second-tag access (merged unaligned pair) through the residency
    /// filter. Mirrors [`Cache::access_second_tag`]: no hit is counted —
    /// the merged access's first line carried the access.
    pub fn tag_access_second(&mut self, addr: u64, way_limit: usize) -> AccessOutcome {
        if self.wavefront_resident {
            self.avoided_fills += 1;
            return AccessOutcome {
                hit: true,
                writeback: None,
                prefetch_hit: false,
                avoided: true,
            };
        }
        self.cache.access_second_tag(addr, way_limit)
    }

    /// Reset tags and the residency filter (new run).
    pub fn reset(&mut self) {
        self.cache.reset();
        self.wavefront_resident = false;
        self.avoided_fills = 0;
    }
}

/// One LLC slice's private state: the [`TagBank`] (tag half), the
/// single-ported bank scheduler, NoC injection-point counters, and this
/// slice's share of the DRAM queue (the requests it issued on
/// misses/writebacks) — the latter three being the timing half that stays
/// with the replay stage when the tag banks are lent to the pipeline's
/// functional side.
#[derive(Debug, Clone)]
pub struct SliceState {
    /// The tag half: set-associative bank + residency filter.
    pub tags: TagBank,
    /// The slice's single load/store port (1 access/cycle, 64 B).
    pub port: RateLimiter,
    /// NoC port counter: requests that arrived from a remote SPU.
    pub remote_reqs: u64,
    /// DRAM-queue share: line fetches this slice issued on misses.
    pub dram_reads: u64,
    /// DRAM-queue share: dirty writebacks this slice issued.
    pub dram_writes: u64,
}

impl SliceState {
    pub fn new(slice_bytes: usize, ways: usize, line_bytes: usize) -> SliceState {
        SliceState {
            tags: TagBank::new(slice_bytes, ways, line_bytes),
            port: RateLimiter::new(1, 64),
            remote_reqs: 0,
            dram_reads: 0,
            dram_writes: 0,
        }
    }

    /// Demand tag access (delegates to the [`TagBank`] residency seam).
    pub fn tag_access(&mut self, addr: u64, write: bool, way_limit: usize) -> AccessOutcome {
        self.tags.tag_access(addr, write, way_limit)
    }

    /// Second-tag access (merged unaligned pair; see [`TagBank`]).
    pub fn tag_access_second(&mut self, addr: u64, way_limit: usize) -> AccessOutcome {
        self.tags.tag_access_second(addr, way_limit)
    }

    /// Reset tags, port clock, and counters (new run).
    pub fn reset(&mut self) {
        self.tags.reset();
        self.port.reset();
        self.remote_reqs = 0;
        self.dram_reads = 0;
        self.dram_writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_state_is_clean() {
        let s = SliceState::new(2 * 1024 * 1024, 16, 64);
        assert_eq!(s.tags.cache.stats.accesses(), 0);
        assert_eq!((s.remote_reqs, s.dram_reads, s.dram_writes), (0, 0, 0));
        assert!(!s.tags.wavefront_resident);
        assert_eq!(s.tags.avoided_fills, 0);
    }

    #[test]
    fn reset_clears_counters_and_tags() {
        let mut s = SliceState::new(256, 2, 64);
        s.tags.cache.access(0x40, true);
        s.port.claim(0);
        s.remote_reqs = 3;
        s.dram_reads = 2;
        s.dram_writes = 1;
        s.tags.wavefront_resident = true;
        s.tags.avoided_fills = 7;
        s.reset();
        assert!(!s.tags.cache.probe(0x40));
        assert_eq!((s.remote_reqs, s.dram_reads, s.dram_writes), (0, 0, 0));
        assert_eq!(s.port.grants, 0);
        assert!(!s.tags.wavefront_resident);
        assert_eq!(s.tags.avoided_fills, 0);
    }

    #[test]
    fn resident_access_bypasses_tags_and_counts_avoided() {
        let mut s = SliceState::new(256, 2, 64);
        // Normal path: a cold access misses and installs the tag.
        let o = s.tag_access(0x40, false, 2);
        assert!(!o.hit && !o.avoided);
        // Residency: an address never touched hits, counts an avoided
        // fill, and installs nothing.
        s.tags.wavefront_resident = true;
        let o = s.tag_access(0x1000, false, 2);
        assert!(o.hit && o.avoided && o.writeback.is_none());
        let o2 = s.tag_access_second(0x2000, 2);
        assert!(o2.hit && o2.avoided);
        assert_eq!(s.tags.avoided_fills, 2);
        assert!(!s.tags.cache.probe(0x1000), "resident access must not install tags");
        // First access counted a hit in stats; second-tag counted none.
        assert_eq!(s.tags.cache.stats.read_hits, 1);
        // Flag off: the same address misses for real again.
        s.tags.wavefront_resident = false;
        assert!(!s.tag_access(0x1000, false, 2).hit);
    }
}
