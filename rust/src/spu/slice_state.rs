//! Slice-private memory-system state: everything one LLC slice owns.
//!
//! The epoch-parallel engine (see `rust/DESIGN-parallel.md`) relies on the
//! fact that the contended per-slice resources — the tag/data bank and the
//! single load/store port — are *independently owned*: during the tag
//! reconciliation phase each [`SliceState`] is handed to exactly one worker
//! thread, so slices are simulated concurrently without locks. The serial
//! path uses the very same states through the
//! [`SlicedLlc`](crate::mem::hierarchy::SlicedLlc) facade, which keeps the
//! two execution modes byte-identical.

use crate::mem::cache::{AccessOutcome, Cache};
use crate::mem::ratelimit::RateLimiter;

/// One LLC slice's private state: tag/data bank, the single-ported bank
/// scheduler, NoC injection-point counters, and this slice's share of the
/// DRAM queue (the requests it issued on misses/writebacks).
#[derive(Debug, Clone)]
pub struct SliceState {
    /// The slice's set-associative tag bank.
    pub cache: Cache,
    /// The slice's single load/store port (1 access/cycle, 64 B).
    pub port: RateLimiter,
    /// NoC port counter: requests that arrived from a remote SPU.
    pub remote_reqs: u64,
    /// DRAM-queue share: line fetches this slice issued on misses.
    pub dram_reads: u64,
    /// DRAM-queue share: dirty writebacks this slice issued.
    pub dram_writes: u64,
    /// Temporal blocking (§temporal-block): the wavefront the SPUs are
    /// consuming this step was produced into this slice on the previous
    /// inner step and is guaranteed resident, so tag probes are bypassed
    /// and no line fill can occur. The coordinator raises the flag on
    /// every inner step of a block (`step % T != 0`) and clears it on
    /// block boundaries.
    pub wavefront_resident: bool,
    /// Tag probes served by wavefront residency — each one a potential
    /// DRAM line fill the blocked schedule avoided.
    pub avoided_fills: u64,
}

impl SliceState {
    pub fn new(slice_bytes: usize, ways: usize, line_bytes: usize) -> SliceState {
        SliceState {
            cache: Cache::new(slice_bytes, ways, line_bytes),
            port: RateLimiter::new(1, 64),
            remote_reqs: 0,
            dram_reads: 0,
            dram_writes: 0,
            wavefront_resident: false,
            avoided_fills: 0,
        }
    }

    /// Demand tag access through the residency filter: the single seam
    /// both engines resolve LLC tags through (the serial path via
    /// [`SlicedLlc`](crate::mem::hierarchy::SlicedLlc), the epoch-parallel
    /// path via its per-slice reconciliation), so temporal blocking is
    /// byte-identical across engines by construction.
    pub fn tag_access(&mut self, addr: u64, write: bool, way_limit: usize) -> AccessOutcome {
        if self.wavefront_resident {
            self.avoided_fills += 1;
            // Stats see a hit (the data is served from the slice); the
            // `avoided` bit lets the tracer attribute it separately.
            if write {
                self.cache.stats.write_hits += 1;
            } else {
                self.cache.stats.read_hits += 1;
            }
            return AccessOutcome {
                hit: true,
                writeback: None,
                prefetch_hit: false,
                avoided: true,
            };
        }
        self.cache.access_ways(addr, write, way_limit)
    }

    /// Second-tag access (merged unaligned pair) through the residency
    /// filter. Mirrors [`Cache::access_second_tag`]: no hit is counted —
    /// the merged access's first line carried the access.
    pub fn tag_access_second(&mut self, addr: u64, way_limit: usize) -> AccessOutcome {
        if self.wavefront_resident {
            self.avoided_fills += 1;
            return AccessOutcome {
                hit: true,
                writeback: None,
                prefetch_hit: false,
                avoided: true,
            };
        }
        self.cache.access_second_tag(addr, way_limit)
    }

    /// Reset tags, port clock, and counters (new run).
    pub fn reset(&mut self) {
        self.cache.reset();
        self.port.reset();
        self.remote_reqs = 0;
        self.dram_reads = 0;
        self.dram_writes = 0;
        self.wavefront_resident = false;
        self.avoided_fills = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_state_is_clean() {
        let s = SliceState::new(2 * 1024 * 1024, 16, 64);
        assert_eq!(s.cache.stats.accesses(), 0);
        assert_eq!((s.remote_reqs, s.dram_reads, s.dram_writes), (0, 0, 0));
        assert!(!s.wavefront_resident);
        assert_eq!(s.avoided_fills, 0);
    }

    #[test]
    fn reset_clears_counters_and_tags() {
        let mut s = SliceState::new(256, 2, 64);
        s.cache.access(0x40, true);
        s.port.claim(0);
        s.remote_reqs = 3;
        s.dram_reads = 2;
        s.dram_writes = 1;
        s.wavefront_resident = true;
        s.avoided_fills = 7;
        s.reset();
        assert!(!s.cache.probe(0x40));
        assert_eq!((s.remote_reqs, s.dram_reads, s.dram_writes), (0, 0, 0));
        assert_eq!(s.port.grants, 0);
        assert!(!s.wavefront_resident);
        assert_eq!(s.avoided_fills, 0);
    }

    #[test]
    fn resident_access_bypasses_tags_and_counts_avoided() {
        let mut s = SliceState::new(256, 2, 64);
        // Normal path: a cold access misses and installs the tag.
        let o = s.tag_access(0x40, false, 2);
        assert!(!o.hit && !o.avoided);
        // Residency: an address never touched hits, counts an avoided
        // fill, and installs nothing.
        s.wavefront_resident = true;
        let o = s.tag_access(0x1000, false, 2);
        assert!(o.hit && o.avoided && o.writeback.is_none());
        let o2 = s.tag_access_second(0x2000, 2);
        assert!(o2.hit && o2.avoided);
        assert_eq!(s.avoided_fills, 2);
        assert!(!s.cache.probe(0x1000), "resident access must not install tags");
        // First access counted a hit in stats; second-tag counted none.
        assert_eq!(s.cache.stats.read_hits, 1);
        // Flag off: the same address misses for real again.
        s.wavefront_resident = false;
        assert!(!s.tag_access(0x1000, false, 2).hit);
    }
}
