//! Slice-private memory-system state: everything one LLC slice owns.
//!
//! The epoch-parallel engine (see `rust/DESIGN-parallel.md`) relies on the
//! fact that the contended per-slice resources — the tag/data bank and the
//! single load/store port — are *independently owned*: during the tag
//! reconciliation phase each [`SliceState`] is handed to exactly one worker
//! thread, so slices are simulated concurrently without locks. The serial
//! path uses the very same states through the
//! [`SlicedLlc`](crate::mem::hierarchy::SlicedLlc) facade, which keeps the
//! two execution modes byte-identical.

use crate::mem::cache::Cache;
use crate::mem::ratelimit::RateLimiter;

/// One LLC slice's private state: tag/data bank, the single-ported bank
/// scheduler, NoC injection-point counters, and this slice's share of the
/// DRAM queue (the requests it issued on misses/writebacks).
#[derive(Debug, Clone)]
pub struct SliceState {
    /// The slice's set-associative tag bank.
    pub cache: Cache,
    /// The slice's single load/store port (1 access/cycle, 64 B).
    pub port: RateLimiter,
    /// NoC port counter: requests that arrived from a remote SPU.
    pub remote_reqs: u64,
    /// DRAM-queue share: line fetches this slice issued on misses.
    pub dram_reads: u64,
    /// DRAM-queue share: dirty writebacks this slice issued.
    pub dram_writes: u64,
}

impl SliceState {
    pub fn new(slice_bytes: usize, ways: usize, line_bytes: usize) -> SliceState {
        SliceState {
            cache: Cache::new(slice_bytes, ways, line_bytes),
            port: RateLimiter::new(1, 64),
            remote_reqs: 0,
            dram_reads: 0,
            dram_writes: 0,
        }
    }

    /// Reset tags, port clock, and counters (new run).
    pub fn reset(&mut self) {
        self.cache.reset();
        self.port.reset();
        self.remote_reqs = 0;
        self.dram_reads = 0;
        self.dram_writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_state_is_clean() {
        let s = SliceState::new(2 * 1024 * 1024, 16, 64);
        assert_eq!(s.cache.stats.accesses(), 0);
        assert_eq!((s.remote_reqs, s.dram_reads, s.dram_writes), (0, 0, 0));
    }

    #[test]
    fn reset_clears_counters_and_tags() {
        let mut s = SliceState::new(256, 2, 64);
        s.cache.access(0x40, true);
        s.port.claim(0);
        s.remote_reqs = 3;
        s.dram_reads = 2;
        s.dram_writes = 1;
        s.reset();
        assert!(!s.cache.probe(0x40));
        assert_eq!((s.remote_reqs, s.dram_reads, s.dram_writes), (0, 0, 0));
        assert_eq!(s.port.grants, 0);
    }
}
