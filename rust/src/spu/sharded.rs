//! The sharded memory-system facade for the Casper engine, plus the epoch
//! message types of the parallel engine.
//!
//! [`ShardedMem`] replaces the old monolithic `SharedMem`: the LLC is a set
//! of independently owned [`SliceState`](crate::spu::SliceState)s behind the
//! [`SlicedLlc`](crate::mem::hierarchy::SlicedLlc) facade, while the NoC,
//! DRAM channels, slice mapper, and the functional backing store remain
//! facade-level (they are either immutable during parallel phases or only
//! touched by the deterministic serial replay — see
//! `rust/DESIGN-parallel.md`).
//!
//! The timed per-slice request logic (`TimingMem::load_slice_request`,
//! `TimingMem::store_request` — crate-internal) is written ONCE and used
//! by every execution mode: the serial path resolves tag outcomes inline
//! (`pre = None`), the epoch replay injects outcomes that the per-slice
//! reconciliation computed (`pre = Some(..)`). Keeping a single copy of
//! this arithmetic is what makes the modes byte-identical.
//!
//! For the pipelined engine, `ShardedMem::split_halves` splits the facade
//! into two disjoint borrows: a `FunHalf` (backing store + mapper +
//! geometry — everything phase 1/2 reads) that stays with the functional
//! side, and a `TimingMem` (LLC ports/counters, NoC, DRAM, tracer) that
//! moves into the dedicated replay worker. The split is sound because
//! replay-mode requests (`pre = Some`) never probe tags — the tag banks
//! themselves are lent to the functional side separately via
//! [`SlicedLlc::take_tag_banks`](crate::mem::hierarchy::SlicedLlc::take_tag_banks).

use crate::config::{LlcConfig, MappingPolicy, SimConfig};
use crate::mapping::SliceMapper;
use crate::mem::cache::AccessOutcome;
use crate::mem::dram::DramModel;
use crate::mem::hierarchy::SlicedLlc;
use crate::noc::MeshNoc;
use crate::trace::{TraceSink, Tracer};

/// Functional backing store for the (single, physically contiguous)
/// stencil segment. Addresses are simulated physical addresses.
#[derive(Debug, Clone)]
pub struct SimStore {
    base: u64,
    data: Vec<f64>,
}

impl SimStore {
    /// An empty store; call [`alloc_segment`](Self::alloc_segment) first.
    pub fn new() -> SimStore {
        SimStore { base: 0, data: Vec::new() }
    }

    /// Allocate the stencil segment (`initStencilSegment`): a contiguous
    /// region of `bytes` zeroed f64s at a fixed, 2 MB-aligned simulated
    /// physical base.
    pub fn alloc_segment(&mut self, bytes: u64) -> u64 {
        assert_eq!(bytes % 8, 0);
        // A recognizable, 2 MB-aligned physical base.
        self.base = 0x1000_0000;
        self.data = vec![0.0; (bytes / 8) as usize];
        self.base
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    pub fn len_bytes(&self) -> u64 {
        self.data.len() as u64 * 8
    }

    #[inline]
    fn index(&self, addr: u64) -> usize {
        debug_assert!(addr >= self.base, "address below segment");
        debug_assert_eq!(addr % 8, 0, "unaligned f64 access");
        let i = ((addr - self.base) / 8) as usize;
        debug_assert!(i < self.data.len(), "address past segment end");
        i
    }

    #[inline]
    pub fn read_f64(&self, addr: u64) -> f64 {
        self.data[self.index(addr)]
    }

    #[inline]
    pub fn write_f64(&mut self, addr: u64, v: f64) {
        let i = self.index(addr);
        self.data[i] = v;
    }

    /// Bulk copy a slice of f64s into the segment at `addr`.
    pub fn write_slice(&mut self, addr: u64, src: &[f64]) {
        let i = self.index(addr);
        self.data[i..i + src.len()].copy_from_slice(src);
    }

    /// Bulk read `n` f64s from `addr`.
    pub fn read_vec(&self, addr: u64, n: usize) -> Vec<f64> {
        let i = self.index(addr);
        self.data[i..i + n].to_vec()
    }

    /// Borrow `n` f64s starting at `addr` (hot-path vector load).
    #[inline]
    pub fn read_slice(&self, addr: u64, n: usize) -> &[f64] {
        let i = self.index(addr);
        &self.data[i..i + n]
    }
}

impl Default for SimStore {
    fn default() -> Self {
        Self::new()
    }
}

/// `line1` / writeback sentinel: "no line".
pub const NO_LINE: u64 = u64::MAX;

/// Precomputed tag outcomes of one slice request — what the per-slice
/// reconciliation hands the timing replay. `wb[k] == NO_LINE` means the
/// tag access evicted nothing dirty.
#[derive(Debug, Clone, Copy)]
pub struct TagOut {
    pub hit: [bool; 2],
    pub wb: [u64; 2],
    /// Served by temporal-block wavefront residency (an avoided fill) —
    /// carried so the epoch replay attributes it exactly as the serial
    /// path does.
    pub avoided: [bool; 2],
}

impl TagOut {
    pub fn single(o: AccessOutcome) -> TagOut {
        TagOut {
            hit: [o.hit, true],
            wb: [o.writeback.unwrap_or(NO_LINE), NO_LINE],
            avoided: [o.avoided, false],
        }
    }

    pub fn pair(o0: AccessOutcome, o1: AccessOutcome) -> TagOut {
        TagOut {
            hit: [o0.hit, o1.hit],
            wb: [o0.writeback.unwrap_or(NO_LINE), o1.writeback.unwrap_or(NO_LINE)],
            avoided: [o0.avoided, o1.avoided],
        }
    }
}

/// One queued tag-array access: the "epoch message" an SPU sends to a
/// slice it touched during phase 1. `line1 != NO_LINE` marks a §4.1
/// merged dual-tag access (first line = data access, second = tag-only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TagReq {
    /// Epoch-local round the issuing SPU executed this group in.
    pub round: u32,
    pub line0: u64,
    pub line1: u64,
    pub write: bool,
}

/// Phase-1 record of one executed SPU instruction; phase 3 replays its
/// timing against the shared models with the reconciled tag outcomes.
#[derive(Debug, Clone, Copy)]
pub struct InstrRec {
    /// NearL1 ablation: the private L1 served the whole load (no LLC
    /// requests were issued).
    pub l1_hit: bool,
    /// Number of LLC requests (1, or 2 for a split unaligned load).
    pub n_reqs: u8,
    /// Merged dual-tag access: one request covers both `lines`.
    pub merged: bool,
    /// `u16`, not `u8`: `SimConfig::validate` puts no upper bound on
    /// `llc.slices`, and a silent truncation here would desync the
    /// replay's outcome streams for >256-slice configs.
    pub slices: [u16; 2],
    pub lines: [u64; 2],
    /// `enable_output` store issued by this instruction.
    pub has_store: bool,
    pub store_slice: u16,
    pub store_addr: u64,
}

impl InstrRec {
    /// Record for a load the private L1 served entirely.
    pub fn l1_served() -> InstrRec {
        InstrRec {
            l1_hit: true,
            n_reqs: 0,
            merged: false,
            slices: [0; 2],
            lines: [0; 2],
            has_store: false,
            store_slice: 0,
            store_addr: 0,
        }
    }
}

/// A contiguous staged functional output write (applied at epoch end;
/// output chunks are disjoint across SPUs by §4.2 block ownership, and
/// loads never read the output array within a time step, so deferring the
/// writes is invisible).
#[derive(Debug)]
pub struct OutRun {
    pub addr: u64,
    pub data: Vec<f64>,
}

/// Per-SPU product of one phase-1 epoch.
#[derive(Debug)]
pub struct SpuTrace {
    /// One record per executed instruction, group-major (`groups` groups of
    /// exactly `program.instrs.len()` records each).
    pub instrs: Vec<InstrRec>,
    /// Per-destination-slice tag-request queues (epoch messages), each in
    /// issue order (ascending `round`).
    pub tagq: Vec<Vec<TagReq>>,
    /// Staged functional output writes.
    pub outs: Vec<OutRun>,
    /// Vector groups executed this epoch (= rounds this SPU was active).
    pub groups: u32,
}

impl SpuTrace {
    pub fn new(slices: usize) -> SpuTrace {
        SpuTrace {
            instrs: Vec::new(),
            tagq: (0..slices).map(|_| Vec::new()).collect(),
            outs: Vec::new(),
            groups: 0,
        }
    }

    /// Clear for reuse on the next epoch, keeping every buffer's capacity
    /// (the pipelined engine cycles a fixed pool of traces arena-style).
    pub fn reset(&mut self) {
        self.instrs.clear();
        for q in &mut self.tagq {
            q.clear();
        }
        self.outs.clear();
        self.groups = 0;
    }
}

/// Cursor over one slice's reconciled outcomes for one SPU, consumed by
/// the phase-3 replay in issue order.
#[derive(Debug, Default)]
pub struct TagOutStream {
    pub outs: Vec<TagOut>,
    pub pos: usize,
}

impl TagOutStream {
    pub fn new(outs: Vec<TagOut>) -> TagOutStream {
        TagOutStream { outs, pos: 0 }
    }

    #[inline]
    pub fn next(&mut self) -> TagOut {
        let o = self.outs[self.pos];
        self.pos += 1;
        o
    }

    pub fn fully_consumed(&self) -> bool {
        self.pos == self.outs.len()
    }
}

/// Everything the SPUs share: the sliced LLC (per-slice private states),
/// NoC, DRAM, slice mapper, and the functional backing store.
pub struct ShardedMem {
    pub llc: SlicedLlc,
    pub noc: MeshNoc,
    pub dram: DramModel,
    pub mapper: SliceMapper,
    pub store: SimStore,
    pub llc_cfg: LlcConfig,
    pub spu_local_latency: u64,
    /// §4.1 hardware present? (ablation knob)
    pub unaligned_hw: bool,
    /// Fig-14 `NearL1` hit latency (the L1 tag models live on the SPUs).
    pub spu_l1_latency: u64,
    /// Cycle-domain trace recorder (`--trace`). `None` — the default —
    /// keeps every request on the exact untraced path: the hook sites are
    /// a single `Option` check each and never feed back into timing.
    pub trace: Option<Box<Tracer>>,
}

/// The functional half of [`ShardedMem`]: the shared-read state phase 1
/// (functional fan-out) needs — backing store, slice mapper, geometry, and
/// the §4.1 ablation knob. `Copy` so worker threads can each take one.
#[derive(Clone, Copy)]
pub(crate) struct FunMem<'a> {
    pub store: &'a SimStore,
    pub mapper: &'a SliceMapper,
    pub llc_cfg: &'a LlcConfig,
    pub unaligned_hw: bool,
}

/// Owning borrow of the functional half: like [`FunMem`] but with the
/// backing store mutable, so the epoch loop can apply staged [`OutRun`]s
/// between fan-outs while the timing half is away in the replay worker.
pub(crate) struct FunHalf<'a> {
    pub store: &'a mut SimStore,
    pub mapper: &'a SliceMapper,
    pub llc_cfg: &'a LlcConfig,
    pub unaligned_hw: bool,
}

impl FunHalf<'_> {
    /// Reborrow as the shared-read view phase-1 workers take.
    pub(crate) fn view(&self) -> FunMem<'_> {
        FunMem {
            store: &*self.store,
            mapper: self.mapper,
            llc_cfg: self.llc_cfg,
            unaligned_hw: self.unaligned_hw,
        }
    }
}

/// The timing half of [`ShardedMem`]: slice ports + NoC/DRAM counters,
/// the DRAM and NoC models, and the tracer — everything the (serial)
/// timing replay mutates. Built either as a transient view over the whole
/// facade ([`ShardedMem::timing_view`], serial/phased paths) or as one arm
/// of [`ShardedMem::split_halves`] (pipelined path, moved into the replay
/// worker). Holds the request arithmetic so it exists exactly once.
pub(crate) struct TimingMem<'a> {
    pub llc: &'a mut SlicedLlc,
    pub noc: &'a mut MeshNoc,
    pub dram: &'a mut DramModel,
    pub llc_cfg: &'a LlcConfig,
    pub spu_local_latency: u64,
    pub spu_l1_latency: u64,
    pub trace: &'a mut Option<Box<Tracer>>,
}

impl ShardedMem {
    pub fn new(cfg: &SimConfig, policy: MappingPolicy) -> ShardedMem {
        ShardedMem {
            llc: SlicedLlc::new(cfg),
            noc: MeshNoc::new(&cfg.noc),
            dram: DramModel::new(&cfg.dram, cfg.llc.line_bytes),
            mapper: SliceMapper::new(&cfg.llc, policy),
            store: SimStore::new(),
            llc_cfg: cfg.llc,
            spu_local_latency: cfg.llc.spu_local_latency,
            unaligned_hw: true,
            spu_l1_latency: cfg.l1.latency,
            trace: None,
        }
    }

    /// The shared-read functional view (phase-1 fan-out from the phased /
    /// serial paths, where the facade is still whole).
    pub(crate) fn fun_view(&self) -> FunMem<'_> {
        FunMem {
            store: &self.store,
            mapper: &self.mapper,
            llc_cfg: &self.llc_cfg,
            unaligned_hw: self.unaligned_hw,
        }
    }

    /// Transient timing view over the whole facade (serial timed path and
    /// non-pipelined replay).
    pub(crate) fn timing_view(&mut self) -> TimingMem<'_> {
        TimingMem {
            llc: &mut self.llc,
            noc: &mut self.noc,
            dram: &mut self.dram,
            llc_cfg: &self.llc_cfg,
            spu_local_latency: self.spu_local_latency,
            spu_l1_latency: self.spu_l1_latency,
            trace: &mut self.trace,
        }
    }

    /// Split the facade into its two disjoint halves for a pipelined step:
    /// the [`FunHalf`] stays on the functional side of the pipeline, the
    /// [`TimingMem`] moves into the replay worker. Field-level borrows, so
    /// both live until the pipeline scope ends.
    pub(crate) fn split_halves(&mut self) -> (FunHalf<'_>, TimingMem<'_>) {
        (
            FunHalf {
                store: &mut self.store,
                mapper: &self.mapper,
                llc_cfg: &self.llc_cfg,
                unaligned_hw: self.unaligned_hw,
            },
            TimingMem {
                llc: &mut self.llc,
                noc: &mut self.noc,
                dram: &mut self.dram,
                llc_cfg: &self.llc_cfg,
                spu_local_latency: self.spu_local_latency,
                spu_l1_latency: self.spu_l1_latency,
                trace: &mut self.trace,
            },
        )
    }

    /// Timed load request — see [`TimingMem::load_slice_request`].
    pub(crate) fn load_slice_request(
        &mut self,
        from_slice: usize,
        slice: usize,
        lines: &[u64],
        t: u64,
        pre: Option<&TagOut>,
    ) -> u64 {
        self.timing_view().load_slice_request(from_slice, slice, lines, t, pre)
    }

    /// Timed store request — see [`TimingMem::store_request`].
    pub(crate) fn store_request(
        &mut self,
        from_slice: usize,
        slice: usize,
        addr: u64,
        t: u64,
        pre: Option<&TagOut>,
    ) -> u64 {
        self.timing_view().store_request(from_slice, slice, addr, t, pre)
    }
}

impl TimingMem<'_> {
    /// Timed 64 B load request from the SPU at `from_slice` to `slice`,
    /// issued at `t`; returns the data-ready cycle. `lines` holds one
    /// line-aligned address, or two for a §4.1 merged dual-tag access.
    /// `pre` injects reconciled tag outcomes (epoch replay — never touches
    /// the tag banks, which is what lets the pipelined engine lend them
    /// out); `None` resolves them inline against the bank (serial path).
    /// All modes run this exact arithmetic — the identity tests pin that.
    pub(crate) fn load_slice_request(
        &mut self,
        from_slice: usize,
        slice: usize,
        lines: &[u64],
        t: u64,
        pre: Option<&TagOut>,
    ) -> u64 {
        // Request traversal to the slice (free when local). Remote
        // messages pay NoC latency; the contended resource is the slice's
        // single load/store port, arbitrated by its rate limiter.
        let remote = slice != from_slice;
        let arrive = if remote {
            self.llc.bank_mut(slice).remote_reqs += 1;
            t + self.noc.record_latency(from_slice, slice, 8)
        } else {
            t
        };
        let start = self.llc.claim_port(slice, arrive);
        let mut data_at = start + self.spu_local_latency;
        let queue0 = self.dram.queue_cycles;
        let (mut hits, mut misses, mut avoided) = (0u32, 0u32, 0u32);
        let mut dram_lines = [0u64; 4];
        let mut n_dram = 0usize;
        for (k, &line) in lines.iter().enumerate() {
            // A merged access is ONE data-array access with a dual tag
            // match: only the first line counts as the access.
            let (hit, wb, avd) = match pre {
                None => {
                    let out = if k == 0 {
                        self.llc.access(slice, line, false)
                    } else {
                        self.llc.access_second_tag(slice, line)
                    };
                    (out.hit, out.writeback.unwrap_or(NO_LINE), out.avoided)
                }
                Some(o) => (o.hit[k], o.wb[k], o.avoided[k]),
            };
            if !hit {
                misses += 1;
                let done = self.dram.access(line, false, start);
                self.llc.bank_mut(slice).dram_reads += 1;
                dram_lines[n_dram] = line;
                n_dram += 1;
                if wb != NO_LINE {
                    let wb_addr = wb * self.llc_cfg.line_bytes as u64;
                    self.dram.access(wb_addr, true, start);
                    self.llc.bank_mut(slice).dram_writes += 1;
                    dram_lines[n_dram] = wb_addr;
                    n_dram += 1;
                }
                data_at = data_at.max(done);
            } else if avd {
                avoided += 1;
            } else {
                hits += 1;
            }
        }
        if let Some(tr) = self.trace.as_deref_mut() {
            let dq = self.dram.queue_cycles - queue0;
            tr.slice_request(slice, start, hits, misses, avoided, &dram_lines[..n_dram], dq, remote);
        }
        // Response traversal back.
        if remote {
            data_at + self.noc.record_latency(slice, from_slice, 64)
        } else {
            data_at
        }
    }

    /// Timed 64 B store of the accumulator at `addr`, issued at `t`.
    /// Same dual-mode contract as
    /// [`load_slice_request`](Self::load_slice_request).
    pub(crate) fn store_request(
        &mut self,
        from_slice: usize,
        slice: usize,
        addr: u64,
        t: u64,
        pre: Option<&TagOut>,
    ) -> u64 {
        let remote = slice != from_slice;
        let arrive = if remote {
            self.llc.bank_mut(slice).remote_reqs += 1;
            t + self.noc.record_latency(from_slice, slice, 64)
        } else {
            t
        };
        let start = self.llc.claim_port(slice, arrive);
        let (hit, wb, avd) = match pre {
            None => {
                let line = addr & !(self.llc_cfg.line_bytes as u64 - 1);
                let out = self.llc.access(slice, line, true);
                (out.hit, out.writeback.unwrap_or(NO_LINE), out.avoided)
            }
            Some(o) => (o.hit[0], o.wb[0], o.avoided[0]),
        };
        let queue0 = self.dram.queue_cycles;
        let mut dram_lines = [0u64; 4];
        let mut n_dram = 0usize;
        let mut done = start + self.spu_local_latency;
        if !hit {
            // Write-allocate fill from DRAM (or lower): coherence §4.3 —
            // the LLC obtains the line in writable state.
            done = done.max(self.dram.access(addr, false, start));
            self.llc.bank_mut(slice).dram_reads += 1;
            dram_lines[n_dram] = addr;
            n_dram += 1;
        }
        if wb != NO_LINE {
            let wb_addr = wb * self.llc_cfg.line_bytes as u64;
            self.dram.access(wb_addr, true, start);
            self.llc.bank_mut(slice).dram_writes += 1;
            dram_lines[n_dram] = wb_addr;
            n_dram += 1;
        }
        if let Some(tr) = self.trace.as_deref_mut() {
            let dq = self.dram.queue_cycles - queue0;
            let (h, m, a) = if avd { (0, 0, 1) } else if hit { (1, 0, 0) } else { (0, 1, 0) };
            tr.slice_request(slice, start, h, m, a, &dram_lines[..n_dram], dq, remote);
        }
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(1024);
        s.write_f64(base, 1.5);
        s.write_f64(base + 8, -2.0);
        assert_eq!(s.read_f64(base), 1.5);
        assert_eq!(s.read_f64(base + 8), -2.0);
        assert_eq!(s.read_f64(base + 16), 0.0);
    }

    #[test]
    fn base_is_2mb_aligned() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(8);
        assert_eq!(base % (2 << 20), 0);
    }

    #[test]
    fn bulk_ops() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(256);
        s.write_slice(base + 16, &[1.0, 2.0, 3.0]);
        assert_eq!(s.read_vec(base + 16, 3), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn out_of_segment_panics_in_debug() {
        let mut s = SimStore::new();
        let base = s.alloc_segment(64);
        let _ = s.read_f64(base + 64);
    }

    #[test]
    fn injected_outcomes_match_direct_resolution() {
        // The dual-mode contract in miniature: resolving tags inline and
        // replaying the recorded outcomes must produce the same cycle.
        let cfg = SimConfig::default();
        let mut a = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        let mut b = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        let lines = [0x1000_0000u64, 0x1000_0040];
        // Direct: record what the tag bank said.
        let o0 = a.llc.access(3, lines[0], false);
        let o1 = a.llc.access_second_tag(3, lines[1]);
        // Fresh mem `b`: run the same request with pre-resolved outcomes;
        // then run `a`'s request on a third mem directly and compare.
        let pre = TagOut::pair(o0, o1);
        let direct = {
            let mut c = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
            c.load_slice_request(0, 3, &lines, 100, None)
        };
        let replayed = b.load_slice_request(0, 3, &lines, 100, Some(&pre));
        assert_eq!(direct, replayed);
        assert_eq!(b.noc.messages, 2, "remote request + response recorded");
    }

    #[test]
    fn tracing_does_not_change_request_timing() {
        let cfg = SimConfig::default();
        let mut plain = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        let mut traced = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        traced.trace = Some(Box::new(Tracer::new(&cfg, 64)));
        let lines = [0x1000_0000u64, 0x1000_0040];
        assert_eq!(
            plain.load_slice_request(0, 3, &lines, 100, None),
            traced.load_slice_request(0, 3, &lines, 100, None)
        );
        assert_eq!(
            plain.store_request(2, 2, 0x1000_2000, 50, None),
            traced.store_request(2, 2, 0x1000_2000, 50, None)
        );
        let tr = traced.trace.take().unwrap();
        assert!(tr.samples() > 0, "hooks recorded the requests");
    }

    #[test]
    fn resident_requests_avoid_dram_and_stay_injectable() {
        // Temporal blocking: with the wavefront flag raised, a cold pair
        // of lines is served without DRAM traffic, the avoided fills are
        // counted, and injected replay matches direct resolution cycle
        // for cycle (the engine-identity contract).
        let cfg = SimConfig::default();
        let mut a = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        a.llc.set_wavefront_resident(true);
        let lines = [0x1000_0000u64, 0x1000_0040];
        let o0 = a.llc.access(3, lines[0], false);
        let o1 = a.llc.access_second_tag(3, lines[1]);
        assert!(o0.avoided && o1.avoided && o0.hit && o1.hit);
        let pre = TagOut::pair(o0, o1);
        let direct = {
            let mut c = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
            c.llc.set_wavefront_resident(true);
            c.load_slice_request(0, 3, &lines, 100, None)
        };
        let mut b = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        let replayed = b.load_slice_request(0, 3, &lines, 100, Some(&pre));
        assert_eq!(direct, replayed);
        let mut c2 = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        c2.llc.set_wavefront_resident(true);
        c2.load_slice_request(0, 3, &lines, 100, None);
        assert_eq!(c2.llc.bank(3).dram_reads, 0, "resident request must not fill");
        assert_eq!(c2.llc.bank(3).tags.avoided_fills, 2);
    }

    #[test]
    fn remote_request_counts_on_target_slice() {
        let cfg = SimConfig::default();
        let mut m = ShardedMem::new(&cfg, MappingPolicy::StencilSegment);
        m.load_slice_request(0, 5, &[0x2000], 0, None);
        assert_eq!(m.llc.bank(5).remote_reqs, 1);
        assert_eq!(m.llc.bank(5).dram_reads, 1, "cold miss fetches the line");
        m.load_slice_request(2, 2, &[0x4000], 0, None);
        assert_eq!(m.llc.bank(2).remote_reqs, 0, "local requests are not remote");
    }
}
