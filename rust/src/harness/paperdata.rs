//! The paper's published numbers (appendix Tables 4, 5, 6), used as the
//! reference column in every regenerated table/figure.
//!
//! Indexing: `[kernel][class]` with kernels in paper order
//! (`StencilKind::ALL`) and classes in `[L2, LLC, DRAM]` order.

use crate::config::SizeClass;
use crate::stencil::StencilKind;

/// Table 4 — dynamic instruction count, baseline CPU (16 cores).
pub const CPU_INSTRS: [[u64; 3]; 6] = [
    [165_840, 1_312_867, 5_245_651],
    [297_277, 2_361_924, 9_440_116],
    [537_100, 4_311_784, 17_255_191],
    [1_804_260, 16_552_680, 66_329_169],
    [736_767, 6_083_864, 24_330_380],
    [2_452_622, 20_958_248, 83_845_023],
];

/// Table 4 — dynamic instruction count, Casper (16 SPUs; per-SPU scale).
pub const CASPER_INSTRS: [[u64; 3]; 6] = [
    [3_106, 23_038, 3_034_882],
    [26_470, 211_402, 3_422_962],
    [5_482, 186_718, 12_640_918],
    [38_350, 337_858, 4_135_498],
    [20_002, 198_730, 21_826_798],
    [261_562, 1_050_790, 9_321_778],
];

/// Table 5 — execution cycles, baseline CPU (16 cores).
pub const CPU_CYCLES: [[u64; 3]; 6] = [
    [13_358, 95_251, 3_838_447],
    [14_702, 125_138, 5_715_526],
    [26_457, 178_032, 8_720_011],
    [95_428, 742_734, 22_729_495],
    [39_029, 296_436, 7_986_968],
    [115_884, 1_009_021, 9_060_219],
];

/// Table 5 — execution cycles, GPU (NVIDIA Titan V).
pub const GPU_CYCLES: [[u64; 3]; 6] = [
    [4_030, 36_134, 135_360],
    [4_108, 36_594, 139_320],
    [4_646, 37_248, 140_160],
    [6_950, 41_318, 153_480],
    [5_184, 36_633, 140_856],
    [6_758, 52_491, 278_784],
];

/// Table 5 — execution cycles, Casper (16 SPUs).
pub const CASPER_CYCLES: [[u64; 3]; 6] = [
    [4_569, 33_220, 4_370_993],
    [8_449, 66_393, 4_514_872],
    [7_658, 58_734, 3_931_701],
    [55_764, 446_300, 5_454_431],
    [29_572, 286_675, 6_784_185],
    [100_243, 1_385_955, 13_420_984],
];

/// Table 6 — energy (J), baseline CPU (16 cores). Dynamic energy; see
/// EXPERIMENTS.md for the Fig 11 (total-system) reconciliation.
pub const CPU_ENERGY_J: [[f64; 3]; 6] = [
    [0.00012, 0.00113, 0.2631221],
    [0.000144, 0.00145, 0.28253],
    [0.000256, 0.002, 0.3483945],
    [0.0009, 0.0075, 0.64639877],
    [0.000386, 0.003364, 0.469465],
    [0.0011542, 0.010266, 0.4424779],
];

/// Table 6 — energy (J), Casper (16 SPUs).
pub const CASPER_ENERGY_J: [[f64; 3]; 6] = [
    [0.000468, 0.00341, 0.3114322],
    [0.000629, 0.00469, 0.59888],
    [0.00073, 0.0055, 0.8809648],
    [0.0015, 0.0118, 1.19655244],
    [0.001737, 0.014002, 1.4752518],
    [0.0028739, 0.027749, 1.8090142],
];

/// Index of a kernel in paper order.
pub fn kernel_index(kind: StencilKind) -> usize {
    StencilKind::ALL.iter().position(|&k| k == kind).unwrap()
}

/// Index of a kernel *id* in paper order — `None` for kernels beyond the
/// paper's six (extended presets, file-defined specs), whose report cells
/// have no published reference column.
pub fn kernel_index_of(id: &str) -> Option<usize> {
    StencilKind::ALL.iter().position(|k| k.id() == id)
}

/// `table[kernel][class]` lookup by kernel id; `None` off the paper grid.
fn lookup<T: Copy>(table: &[[T; 3]; 6], id: &str, level: SizeClass) -> Option<T> {
    kernel_index_of(id).map(|k| table[k][class_index(level)])
}

pub fn cpu_instrs_of(id: &str, level: SizeClass) -> Option<u64> {
    lookup(&CPU_INSTRS, id, level)
}

pub fn casper_instrs_of(id: &str, level: SizeClass) -> Option<u64> {
    lookup(&CASPER_INSTRS, id, level)
}

pub fn cpu_cycles_of(id: &str, level: SizeClass) -> Option<u64> {
    lookup(&CPU_CYCLES, id, level)
}

pub fn gpu_cycles_of(id: &str, level: SizeClass) -> Option<u64> {
    lookup(&GPU_CYCLES, id, level)
}

pub fn casper_cycles_of(id: &str, level: SizeClass) -> Option<u64> {
    lookup(&CASPER_CYCLES, id, level)
}

pub fn cpu_energy_of(id: &str, level: SizeClass) -> Option<f64> {
    lookup(&CPU_ENERGY_J, id, level)
}

pub fn casper_energy_of(id: &str, level: SizeClass) -> Option<f64> {
    lookup(&CASPER_ENERGY_J, id, level)
}

/// Paper speedup by kernel id; `None` for non-paper kernels.
pub fn paper_speedup_of(id: &str, level: SizeClass) -> Option<f64> {
    let cpu = cpu_cycles_of(id, level)?;
    let casper = casper_cycles_of(id, level)?;
    Some(cpu as f64 / casper as f64)
}

/// Paper Casper-vs-GPU slowdown by kernel id; `None` off the paper grid.
pub fn paper_gpu_ratio_of(id: &str, level: SizeClass) -> Option<f64> {
    let casper = casper_cycles_of(id, level)?;
    let gpu = gpu_cycles_of(id, level)?;
    Some(casper as f64 / gpu as f64)
}

/// Index of a size class in `[L2, LLC, DRAM]` order (the same slot order
/// [`SizeClass::index`] defines — single source of truth).
pub fn class_index(level: SizeClass) -> usize {
    level.index()
}

/// Paper speedup of Casper over the CPU (derived from Table 5).
pub fn paper_speedup(kind: StencilKind, level: SizeClass) -> f64 {
    let (k, c) = (kernel_index(kind), class_index(level));
    CPU_CYCLES[k][c] as f64 / CASPER_CYCLES[k][c] as f64
}

/// Paper Casper-vs-GPU slowdown (derived from Table 5).
pub fn paper_gpu_ratio(kind: StencilKind, level: SizeClass) -> f64 {
    let (k, c) = (kernel_index(kind), class_index(level));
    CASPER_CYCLES[k][c] as f64 / GPU_CYCLES[k][c] as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::geomean;

    #[test]
    fn indices_roundtrip() {
        for (i, k) in StencilKind::ALL.iter().enumerate() {
            assert_eq!(kernel_index(*k), i);
            assert_eq!(kernel_index_of(k.id()), Some(i));
        }
        assert_eq!(class_index(SizeClass::Llc), 1);
        assert_eq!(kernel_index_of("hdiff"), None);
    }

    #[test]
    fn id_lookups_match_kind_lookups() {
        for k in StencilKind::ALL {
            for c in SizeClass::ALL {
                assert_eq!(paper_speedup_of(k.id(), c), Some(paper_speedup(k, c)));
                assert_eq!(paper_gpu_ratio_of(k.id(), c), Some(paper_gpu_ratio(k, c)));
                assert_eq!(
                    cpu_instrs_of(k.id(), c),
                    Some(CPU_INSTRS[kernel_index(k)][class_index(c)])
                );
            }
        }
        assert_eq!(paper_speedup_of("star25_3d", SizeClass::Llc), None);
    }

    #[test]
    fn headline_claims_derive_from_tables() {
        // §8.1: "for datasets that fit within the LLC ... average speedup
        // of 1.65×"; max 4.16× (Blur 2D, DRAM).
        let llc: Vec<f64> = StencilKind::ALL
            .iter()
            .map(|&k| paper_speedup(k, SizeClass::Llc))
            .collect();
        let avg = geomean(&llc);
        assert!((1.4..1.9).contains(&avg), "LLC geomean {avg}");
        let blur_dram = paper_speedup(StencilKind::Blur2D, SizeClass::Dram);
        assert!((4.0..4.3).contains(&blur_dram), "{blur_dram}");
    }

    #[test]
    fn gpu_outperforms_casper_per_paper() {
        // §8.3: GPU wins on raw performance for every class.
        for k in StencilKind::ALL {
            for c in crate::config::SizeClass::ALL {
                assert!(paper_gpu_ratio(k, c) > 0.9, "{k} {c}");
            }
        }
    }
}
