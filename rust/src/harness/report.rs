//! Report tables: markdown + CSV emission for the experiment harness.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

/// One regenerated table/figure.
#[derive(Debug, Clone)]
pub struct Table {
    /// Experiment id (`fig10`, `table5`, ...).
    pub id: String,
    /// Human title (as in the paper).
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes appended under the table.
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(id: &str, title: &str, header: &[&str]) -> Table {
        Table {
            id: id.to_string(),
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch in {}", self.id);
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render a failed sweep cell as an annotated hole: the identifying
    /// `prefix` columns (kernel, class, ...) survive, the first data
    /// column carries the failure, the rest are `-`. The table keeps its
    /// shape so surviving rows stay byte-identical to a clean run.
    pub fn hole(&mut self, prefix: Vec<String>, why: &str) {
        assert!(prefix.len() < self.header.len(), "hole prefix must leave data columns");
        let mut cells = prefix;
        cells.push(format!("FAILED: {why}"));
        while cells.len() < self.header.len() {
            cells.push("-".to_string());
        }
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {} — {}\n", self.id, self.title);
        let _ = writeln!(out, "| {} |", self.header.join(" | "));
        let _ = writeln!(out, "|{}|", self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|"));
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "\n> {n}");
        }
        out.push('\n');
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "{}", csv_line(&self.header));
        for r in &self.rows {
            let _ = writeln!(out, "{}", csv_line(r));
        }
        out
    }
}

fn csv_line(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// One sweep cell that did not complete (see the supervised runtime in
/// [`crate::harness::sweep`]). Rendered as an annotated hole in its
/// tables and listed in the report's "failed cells" section.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CellFailure {
    /// Cell kind: `casper`, `cpu`, or `ablation`.
    pub kind: String,
    /// Kernel id.
    pub kernel: String,
    /// Size-class name.
    pub level: String,
    /// Terminal outcome text ([`crate::harness::sweep::CellOutcome::describe`]).
    pub outcome: String,
}

impl std::fmt::Display for CellFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {}@{}: {}", self.kind, self.kernel, self.level, self.outcome)
    }
}

/// The full experiment report.
#[derive(Debug, Default, Clone)]
pub struct Report {
    pub tables: Vec<Table>,
    /// Cells that failed under `--keep-going` (empty on a clean sweep —
    /// and on a clean sweep the markdown is byte-identical to a report
    /// that predates failure tracking).
    pub failures: Vec<CellFailure>,
}

impl Report {
    pub fn to_markdown(&self) -> String {
        let mut out: String = self.tables.iter().map(|t| t.to_markdown()).collect();
        if !self.failures.is_empty() {
            out.push_str("### failed cells\n\n");
            for f in &self.failures {
                let _ = writeln!(out, "- {f}");
            }
            out.push('\n');
        }
        out
    }

    /// Write `<id>.csv` per table plus `report.md` into `dir`.
    pub fn write_to(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        for t in &self.tables {
            std::fs::write(dir.join(format!("{}.csv", t.id)), t.to_csv())?;
        }
        std::fs::write(dir.join("report.md"), self.to_markdown())?;
        Ok(())
    }

    pub fn get(&self, id: &str) -> Option<&Table> {
        self.tables.iter().find(|t| t.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("fig0", "Sample", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        t.note("a note");
        t
    }

    #[test]
    fn markdown_shape() {
        let md = sample().to_markdown();
        assert!(md.contains("### fig0 — Sample"));
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | x,y |"));
        assert!(md.contains("> a note"));
    }

    #[test]
    fn csv_quotes_commas() {
        let csv = sample().to_csv();
        assert!(csv.contains("1,\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", "t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn holes_pad_to_header_width() {
        let mut t = Table::new("x", "t", &["kernel", "class", "v1", "v2"]);
        t.hole(vec!["jacobi2d".into(), "LLC".into()], "panicked: boom");
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0], vec!["jacobi2d", "LLC", "FAILED: panicked: boom", "-"]);
    }

    #[test]
    fn failures_section_renders_only_when_present() {
        let mut r = Report::default();
        r.tables.push(sample());
        assert!(!r.to_markdown().contains("failed cells"));
        r.failures.push(CellFailure {
            kind: "casper".into(),
            kernel: "jacobi2d".into(),
            level: "LLC".into(),
            outcome: "timed out after 10 ms (attempt 1)".into(),
        });
        let md = r.to_markdown();
        assert!(md.contains("### failed cells"));
        assert!(md.contains("- casper jacobi2d@LLC: timed out"));
    }

    #[test]
    fn report_write(/* uses temp dir */) {
        let dir = std::env::temp_dir().join("casper_report_test");
        let mut r = Report::default();
        r.tables.push(sample());
        r.write_to(&dir).unwrap();
        assert!(dir.join("fig0.csv").exists());
        assert!(dir.join("report.md").exists());
        assert!(r.get("fig0").is_some());
        assert!(r.get("nope").is_none());
    }
}
