//! Checkpoint journal: crash-safe persistence for finished sweep cells.
//!
//! A long sweep appends one line per completed cell to a plain-text
//! journal. `casper experiments --resume <path>` reloads the journal,
//! skips every cell it already holds, and re-runs only the missing ones —
//! the final report is byte-identical to an uninterrupted run, because
//! cells are deterministic and the builders consume them in a fixed order
//! regardless of where their numbers came from.
//!
//! ## Format
//!
//! ```text
//! casper-journal v1 ctx <16-hex-digit context digest>
//! C <kernel-id> <class> <digest> <counters...> ;<fnv64 of the line body>
//! P <kernel-id> <class> <counters...> ;<fnv64>
//! A <kernel-id> <class> <near-l1-base> <near-l1-mapped> ;<fnv64>
//! ```
//!
//! - The header's **context digest** binds the journal to the sweep that
//!   wrote it (config, steps, quick flag, kernel set — *not* job count or
//!   SPU threads, which never change results). Resuming under a different
//!   context is refused rather than silently mixing incompatible numbers.
//! - Every record line carries an FNV-1a checksum of its body. A torn
//!   final record (the process died mid-write) or any corrupted line
//!   simply fails its checksum and is dropped — that one cell re-runs.
//! - `C` (Casper) records persist every [`RunStats`] counter plus the
//!   output-grid dimensions and the recorded [`RunStats::digest`]. The
//!   grid *data* is not persisted: no report builder reads it, and the
//!   recorded digest preserves the run's identity for auditing.

use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{ensure, Context, Result};

use crate::config::SizeClass;
use crate::coordinator::RunStats;
use crate::cpu::CpuRunStats;
use crate::mem::{CacheStats, MemEvents};
use crate::spu::SpuStats;
use crate::stencil::Grid;

/// First line of every journal file; the context digest follows.
pub const HEADER_PREFIX: &str = "casper-journal v1 ctx ";

/// FNV-1a over a string (same constants as [`RunStats::digest`]'s mixer).
fn fnv64(s: &str) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Digest of the sweep context (config + steps + quick + kernel ids).
pub fn context_digest(parts: &[&str]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for p in parts {
        h = h.wrapping_mul(0x0000_0100_0000_01B3) ^ fnv64(p);
    }
    h
}

/// One journaled cell result.
#[derive(Debug, Clone)]
pub enum Record {
    /// A Casper simulation cell: full counters + recorded digest.
    Casper { id: String, level: SizeClass, digest: u64, stats: RunStats },
    /// A CPU-baseline cell.
    Cpu { id: String, level: SizeClass, stats: CpuRunStats },
    /// A Fig 14 near-L1 ablation pair (baseline, +mapping) in cycles.
    Ablation { id: String, level: SizeClass, near_l1_base: u64, near_l1_mapped: u64 },
}

impl Record {
    /// `(tag, kernel-id, class)` — the record's cell key.
    pub fn key(&self) -> (char, &str, SizeClass) {
        match self {
            Record::Casper { id, level, .. } => ('C', id, *level),
            Record::Cpu { id, level, .. } => ('P', id, *level),
            Record::Ablation { id, level, .. } => ('A', id, *level),
        }
    }
}

fn push_u64s(out: &mut String, vals: &[u64]) {
    for v in vals {
        out.push(' ');
        out.push_str(&v.to_string());
    }
}

fn push_vec(out: &mut String, vals: &[u64]) {
    out.push(' ');
    out.push_str(&vals.len().to_string());
    push_u64s(out, vals);
}

fn cache_fields(c: &CacheStats) -> [u64; 8] {
    [
        c.read_hits,
        c.read_misses,
        c.write_hits,
        c.write_misses,
        c.evictions,
        c.writebacks,
        c.prefetch_fills,
        c.prefetch_hits,
    ]
}

fn body_of(r: &Record) -> String {
    match r {
        Record::Casper { id, level, digest, stats } => {
            let mut s = format!("C {id} {} {digest:016x}", level_tag(*level));
            push_u64s(
                &mut s,
                &[stats.cycles, stats.total_instrs, stats.per_spu_instrs, stats.passes as u64],
            );
            let sp = &stats.spu;
            push_u64s(
                &mut s,
                &[
                    sp.instrs,
                    sp.groups,
                    sp.loads,
                    sp.stores,
                    sp.local_loads,
                    sp.remote_loads,
                    sp.merged_unaligned,
                    sp.split_unaligned,
                    sp.lq_stall_cycles,
                ],
            );
            push_u64s(&mut s, &cache_fields(&stats.llc));
            push_u64s(
                &mut s,
                &[
                    stats.dram_accesses,
                    stats.noc_messages,
                    stats.noc_hops,
                    stats.noc_contention_cycles,
                ],
            );
            push_vec(&mut s, &stats.slice_remote_reqs);
            push_vec(&mut s, &stats.slice_dram_reads);
            push_vec(&mut s, &stats.slice_dram_writes);
            push_vec(&mut s, &stats.slice_port_grants);
            push_u64s(&mut s, &[stats.temporal_block as u64, stats.halo_recompute_cells]);
            push_vec(&mut s, &stats.slice_avoided_fills);
            // Reduction: op discriminant (0 = none), then the per-step
            // values as raw f64 bits — exact, so the recomputed digest on
            // resume matches the recorded one.
            match &stats.reduction {
                None => push_u64s(&mut s, &[0]),
                Some(r) => {
                    push_u64s(&mut s, &[r.op.discriminant()]);
                    let bits: Vec<u64> = r.values.iter().map(|v| v.to_bits()).collect();
                    push_vec(&mut s, &bits);
                }
            }
            push_u64s(
                &mut s,
                &[stats.output.nx as u64, stats.output.ny as u64, stats.output.nz as u64],
            );
            s
        }
        Record::Cpu { id, level, stats } => {
            let mut s = format!("P {id} {}", level_tag(*level));
            push_u64s(&mut s, &[stats.cycles, stats.instrs, stats.flops]);
            push_u64s(&mut s, &cache_fields(&stats.mem.l1));
            push_u64s(&mut s, &cache_fields(&stats.mem.l2));
            push_u64s(&mut s, &cache_fields(&stats.mem.llc));
            push_u64s(&mut s, &[stats.mem.dram_accesses, stats.mem.noc_hops]);
            push_vec(&mut s, &stats.per_core_cycles);
            s
        }
        Record::Ablation { id, level, near_l1_base, near_l1_mapped } => {
            format!("A {id} {} {near_l1_base} {near_l1_mapped}", level_tag(*level))
        }
    }
}

fn level_tag(level: SizeClass) -> String {
    level.name().to_ascii_lowercase()
}

/// Encode a record as one checksummed journal line (no newline).
pub fn encode_record(r: &Record) -> String {
    let body = body_of(r);
    let sum = fnv64(&body);
    format!("{body} ;{sum:016x}")
}

fn next_u64<'a>(it: &mut impl Iterator<Item = &'a str>) -> Option<u64> {
    it.next()?.parse().ok()
}

fn next_usize<'a>(it: &mut impl Iterator<Item = &'a str>) -> Option<usize> {
    it.next()?.parse().ok()
}

fn next_vec<'a>(it: &mut impl Iterator<Item = &'a str>) -> Option<Vec<u64>> {
    let n = next_usize(it)?;
    // A sane ceiling so a corrupt length can't balloon allocation.
    if n > 1 << 20 {
        return None;
    }
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(next_u64(it)?);
    }
    Some(out)
}

fn next_cache<'a>(it: &mut impl Iterator<Item = &'a str>) -> Option<CacheStats> {
    Some(CacheStats {
        read_hits: next_u64(it)?,
        read_misses: next_u64(it)?,
        write_hits: next_u64(it)?,
        write_misses: next_u64(it)?,
        evictions: next_u64(it)?,
        writebacks: next_u64(it)?,
        prefetch_fills: next_u64(it)?,
        prefetch_hits: next_u64(it)?,
    })
}

fn decode_body(body: &str) -> Option<Record> {
    let mut it = body.split_whitespace();
    let tag = it.next()?;
    let id = it.next()?.to_string();
    let level = SizeClass::parse(it.next()?)?;
    let rec = match tag {
        "C" => {
            let digest = u64::from_str_radix(it.next()?, 16).ok()?;
            let cycles = next_u64(&mut it)?;
            let total_instrs = next_u64(&mut it)?;
            let per_spu_instrs = next_u64(&mut it)?;
            let passes = next_usize(&mut it)?;
            let spu = SpuStats {
                instrs: next_u64(&mut it)?,
                groups: next_u64(&mut it)?,
                loads: next_u64(&mut it)?,
                stores: next_u64(&mut it)?,
                local_loads: next_u64(&mut it)?,
                remote_loads: next_u64(&mut it)?,
                merged_unaligned: next_u64(&mut it)?,
                split_unaligned: next_u64(&mut it)?,
                lq_stall_cycles: next_u64(&mut it)?,
            };
            let llc = next_cache(&mut it)?;
            let dram_accesses = next_u64(&mut it)?;
            let noc_messages = next_u64(&mut it)?;
            let noc_hops = next_u64(&mut it)?;
            let noc_contention_cycles = next_u64(&mut it)?;
            let slice_remote_reqs = next_vec(&mut it)?;
            let slice_dram_reads = next_vec(&mut it)?;
            let slice_dram_writes = next_vec(&mut it)?;
            let slice_port_grants = next_vec(&mut it)?;
            let temporal_block = next_usize(&mut it)?;
            let halo_recompute_cells = next_u64(&mut it)?;
            let slice_avoided_fills = next_vec(&mut it)?;
            let reduction = match next_u64(&mut it)? {
                0 => None,
                d => {
                    let op = crate::isa::ReduceOp::from_discriminant(d)?;
                    let values: Vec<f64> =
                        next_vec(&mut it)?.into_iter().map(f64::from_bits).collect();
                    Some(crate::coordinator::ReductionResult { op, values })
                }
            };
            let nx = next_usize(&mut it)?;
            let ny = next_usize(&mut it)?;
            let nz = next_usize(&mut it)?;
            if nx == 0 || ny == 0 || nz == 0 {
                return None;
            }
            Record::Casper {
                id,
                level,
                digest,
                stats: RunStats {
                    cycles,
                    total_instrs,
                    per_spu_instrs,
                    passes,
                    spu,
                    llc,
                    dram_accesses,
                    noc_messages,
                    noc_hops,
                    noc_contention_cycles,
                    slice_remote_reqs,
                    slice_dram_reads,
                    slice_dram_writes,
                    slice_port_grants,
                    temporal_block,
                    slice_avoided_fills,
                    halo_recompute_cells,
                    reduction,
                    // The grid data is not persisted (no builder reads
                    // it); the recorded digest carries the run identity.
                    output: Grid::zeros(nx, ny, nz),
                },
            }
        }
        "P" => {
            let cycles = next_u64(&mut it)?;
            let instrs = next_u64(&mut it)?;
            let flops = next_u64(&mut it)?;
            let l1 = next_cache(&mut it)?;
            let l2 = next_cache(&mut it)?;
            let llc = next_cache(&mut it)?;
            let dram_accesses = next_u64(&mut it)?;
            let noc_hops = next_u64(&mut it)?;
            let per_core_cycles = next_vec(&mut it)?;
            Record::Cpu {
                id,
                level,
                stats: CpuRunStats {
                    cycles,
                    instrs,
                    flops,
                    mem: MemEvents { l1, l2, llc, dram_accesses, noc_hops },
                    per_core_cycles,
                },
            }
        }
        "A" => {
            let near_l1_base = next_u64(&mut it)?;
            let near_l1_mapped = next_u64(&mut it)?;
            Record::Ablation { id, level, near_l1_base, near_l1_mapped }
        }
        _ => return None,
    };
    // Trailing garbage means the line is not what we wrote — drop it.
    if it.next().is_some() {
        return None;
    }
    Some(rec)
}

/// Decode one journal line; `None` for torn/corrupt lines (the cell will
/// simply re-run).
pub fn decode_line(line: &str) -> Option<Record> {
    let (body, sum) = line.rsplit_once(" ;")?;
    let want = u64::from_str_radix(sum.trim(), 16).ok()?;
    if fnv64(body) != want {
        return None;
    }
    decode_body(body)
}

/// An open, append-mode checkpoint journal.
#[derive(Debug)]
pub struct Journal {
    path: PathBuf,
    file: File,
}

impl Journal {
    /// Open (or create) a journal bound to context `ctx`, returning the
    /// handle plus every valid record already present. A journal written
    /// under a *different* context is refused. A torn final record (no
    /// trailing newline) is dropped and the next append starts cleanly on
    /// its own line.
    pub fn open(path: &Path, ctx: u64) -> Result<(Journal, Vec<Record>)> {
        let existing = match std::fs::read_to_string(path) {
            Ok(text) => Some(text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => None,
            Err(e) => {
                return Err(e).with_context(|| format!("reading journal {}", path.display()))
            }
        };
        let mut records = Vec::new();
        let mut needs_header = true;
        let mut needs_newline = false;
        if let Some(text) = &existing {
            if !text.trim().is_empty() {
                let first = text.lines().next().unwrap_or("");
                let got = first
                    .strip_prefix(HEADER_PREFIX)
                    .and_then(|h| u64::from_str_radix(h.trim(), 16).ok())
                    .with_context(|| {
                        format!("{}: not a casper checkpoint journal (bad header)", path.display())
                    })?;
                ensure!(
                    got == ctx,
                    "{}: journal context mismatch (journal {got:016x}, this sweep {ctx:016x}) — \
                     it was written by a sweep with a different config/steps/kernel set; delete \
                     it or point --resume elsewhere",
                    path.display()
                );
                needs_header = false;
                for line in text.lines().skip(1) {
                    if let Some(r) = decode_line(line) {
                        records.push(r);
                    }
                }
                needs_newline = !text.ends_with('\n');
            }
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let mut file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening journal {}", path.display()))?;
        if needs_newline {
            file.write_all(b"\n")
                .with_context(|| format!("repairing torn record in {}", path.display()))?;
        }
        if needs_header {
            writeln!(file, "{HEADER_PREFIX}{ctx:016x}")
                .with_context(|| format!("writing journal header to {}", path.display()))?;
            file.flush()?;
        }
        Ok((Journal { path: path.to_path_buf(), file }, records))
    }

    /// Append one finished cell. Each record is flushed immediately so a
    /// crash loses at most the line being written (which the checksum
    /// then drops on resume).
    pub fn append(&mut self, r: &Record) -> Result<()> {
        writeln!(self.file, "{}", encode_record(r))
            .and_then(|()| self.file.flush())
            .with_context(|| format!("appending to journal {}", self.path.display()))
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_casper() -> Record {
        let mut stats = RunStats {
            cycles: 123,
            total_instrs: 456,
            per_spu_instrs: 78,
            passes: 2,
            spu: SpuStats { instrs: 9, groups: 8, loads: 7, stores: 6, ..Default::default() },
            llc: CacheStats { read_hits: 5, writebacks: 4, ..Default::default() },
            dram_accesses: 3,
            noc_messages: 2,
            noc_hops: 1,
            noc_contention_cycles: 11,
            slice_remote_reqs: vec![1, 2, 3],
            slice_dram_reads: vec![4, 5, 6],
            slice_dram_writes: vec![7, 8, 9],
            slice_port_grants: vec![10, 11, 12],
            temporal_block: 1,
            slice_avoided_fills: vec![0, 0, 0],
            halo_recompute_cells: 0,
            reduction: None,
            output: Grid::zeros(4, 3, 2),
        };
        stats.spu.local_loads = 10;
        let digest = stats.digest();
        Record::Casper { id: "jacobi2d".into(), level: SizeClass::Llc, digest, stats }
    }

    fn sample_blocked_reduced() -> Record {
        let Record::Casper { id, level, mut stats, .. } = sample_casper() else {
            unreachable!()
        };
        stats.temporal_block = 4;
        stats.slice_avoided_fills = vec![13, 14, 15];
        stats.halo_recompute_cells = 96;
        stats.reduction = Some(crate::coordinator::ReductionResult {
            op: crate::isa::ReduceOp::AbsDiff,
            values: vec![0.5, 0.25, 1.0 / 3.0],
        });
        let digest = stats.digest();
        Record::Casper { id, level, digest, stats }
    }

    fn sample_cpu() -> Record {
        Record::Cpu {
            id: "heat3d".into(),
            level: SizeClass::L2,
            stats: CpuRunStats {
                cycles: 1000,
                instrs: 2000,
                flops: 3000,
                mem: MemEvents {
                    l1: CacheStats { read_hits: 1, ..Default::default() },
                    l2: CacheStats { read_misses: 2, ..Default::default() },
                    llc: CacheStats { write_hits: 3, ..Default::default() },
                    dram_accesses: 4,
                    noc_hops: 5,
                },
                per_core_cycles: vec![10, 20, 30, 40],
            },
        }
    }

    fn sample_ablation() -> Record {
        Record::Ablation {
            id: "blur2d".into(),
            level: SizeClass::Dram,
            near_l1_base: 999,
            near_l1_mapped: 888,
        }
    }

    fn assert_roundtrips(r: &Record) {
        let line = encode_record(r);
        let back = decode_line(&line).expect("line should decode");
        assert_eq!(encode_record(&back), line, "re-encode must be byte-identical");
        assert_eq!(back.key(), r.key());
    }

    #[test]
    fn records_roundtrip() {
        assert_roundtrips(&sample_casper());
        assert_roundtrips(&sample_cpu());
        assert_roundtrips(&sample_ablation());
        assert_roundtrips(&sample_blocked_reduced());
    }

    #[test]
    fn blocked_and_reduced_counters_survive_exactly() {
        // The f64 reduction values persist as raw bits, so the digest
        // recomputed from a resumed record matches the recorded one.
        let r = sample_blocked_reduced();
        let line = encode_record(&r);
        let Record::Casper { digest: d0, stats: s0, .. } = r else {
            panic!("expected a Casper record");
        };
        let Some(Record::Casper { digest, stats, .. }) = decode_line(&line) else {
            panic!("line should decode to a Casper record");
        };
        assert_eq!(digest, d0);
        assert_eq!(stats.temporal_block, s0.temporal_block);
        assert_eq!(stats.slice_avoided_fills, s0.slice_avoided_fills);
        assert_eq!(stats.halo_recompute_cells, s0.halo_recompute_cells);
        assert_eq!(stats.reduction, s0.reduction, "reduction values must be bit-exact");
        // A corrupt reduction op discriminant drops the record body even
        // if someone re-checksummed it.
        let body = line.rsplit_once(" ;").unwrap().0;
        let mut toks: Vec<&str> = body.split_whitespace().collect();
        // 4 head + 4 scalars + 9 spu + 8 llc + 4 noc/dram + 4×(1+3) vecs
        // + 2 blocked scalars + (1+3) avoided vec = token 51 is the op.
        assert_eq!(toks[51], "2", "op discriminant field moved — update the index");
        toks[51] = "9";
        assert!(super::decode_body(&toks.join(" ")).is_none());
    }

    #[test]
    fn casper_record_preserves_counters_and_digest() {
        let r = sample_casper();
        let line = encode_record(&r);
        let Record::Casper { digest: d0, stats: s0, .. } = r else {
            panic!("expected a Casper record");
        };
        let Some(Record::Casper { digest, stats, .. }) = decode_line(&line) else {
            panic!("line should decode to a Casper record");
        };
        assert_eq!(digest, d0, "recorded digest survives");
        assert_eq!(stats.cycles, s0.cycles);
        assert_eq!(stats.spu, s0.spu);
        assert_eq!(stats.llc, s0.llc);
        assert_eq!(stats.slice_remote_reqs, s0.slice_remote_reqs);
        assert_eq!(stats.slice_port_grants, s0.slice_port_grants);
        assert_eq!(
            (stats.output.nx, stats.output.ny, stats.output.nz),
            (s0.output.nx, s0.output.ny, s0.output.nz),
            "grid dimensions survive (data intentionally does not)"
        );
    }

    #[test]
    fn corrupt_lines_are_dropped() {
        let line = encode_record(&sample_casper());
        // Flip a digit in the body: checksum fails.
        let tampered = line.replacen("123", "124", 1);
        assert!(decode_line(&tampered).is_none());
        // Torn line (no checksum separator).
        assert!(decode_line("C jacobi2d llc deadbeef 12 34").is_none());
        // Bad checksum hex.
        assert!(decode_line("C x llc ;zzzz").is_none());
        assert!(decode_line("").is_none());
    }

    #[test]
    fn journal_open_append_reload() {
        let path = std::env::temp_dir().join(format!("casper_journal_{}.log", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let ctx = context_digest(&["cfg", "steps=1", "quick=true", "jacobi2d"]);
        {
            let (mut j, loaded) = Journal::open(&path, ctx).unwrap();
            assert!(loaded.is_empty());
            j.append(&sample_casper()).unwrap();
            j.append(&sample_cpu()).unwrap();
        }
        let (mut j, loaded) = Journal::open(&path, ctx).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].key(), ('C', "jacobi2d", SizeClass::Llc));
        assert_eq!(loaded[1].key(), ('P', "heat3d", SizeClass::L2));
        j.append(&sample_ablation()).unwrap();
        let (_, loaded) = Journal::open(&path, ctx).unwrap();
        assert_eq!(loaded.len(), 3);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_final_record_is_dropped_and_repaired() {
        let path = std::env::temp_dir().join(format!("casper_torn_{}.log", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let ctx = 7;
        {
            let (mut j, _) = Journal::open(&path, ctx).unwrap();
            j.append(&sample_casper()).unwrap();
        }
        // Simulate a crash mid-write: append half a record, no newline.
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "P heat3d l2 1000 20").unwrap();
        }
        let (mut j, loaded) = Journal::open(&path, ctx).unwrap();
        assert_eq!(loaded.len(), 1, "torn record dropped");
        j.append(&sample_cpu()).unwrap();
        let (_, loaded) = Journal::open(&path, ctx).unwrap();
        assert_eq!(loaded.len(), 2, "append after torn record starts on a fresh line");
    }

    #[test]
    fn context_mismatch_is_refused() {
        let path = std::env::temp_dir().join(format!("casper_ctx_{}.log", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let (mut j, _) = Journal::open(&path, 1).unwrap();
            j.append(&sample_casper()).unwrap();
        }
        let err = Journal::open(&path, 2).unwrap_err();
        assert!(format!("{err:#}").contains("context mismatch"), "{err:#}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn non_journal_file_is_refused() {
        let path = std::env::temp_dir().join(format!("casper_notj_{}.log", std::process::id()));
        std::fs::write(&path, "hello world\n").unwrap();
        assert!(Journal::open(&path, 1).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn context_digest_is_order_and_content_sensitive() {
        let a = context_digest(&["x", "y"]);
        assert_eq!(a, context_digest(&["x", "y"]));
        assert_ne!(a, context_digest(&["y", "x"]));
        assert_ne!(a, context_digest(&["x", "z"]));
        assert_ne!(a, context_digest(&["x"]));
    }
}
