//! Parallel experiment-sweep engine: fan independent simulation cells out
//! over a scoped worker pool.
//!
//! Every cell of the paper's evaluation grid (kernel × size class ×
//! configuration) is an independent, deterministic simulation — the fig/
//! table builders only ever combine *finished* cell results. That makes
//! the sweep embarrassingly parallel: [`parallel_map`] runs the cells on
//! `jobs` worker threads (work-stealing via a shared atomic cursor) and
//! returns the results **in submission order**, so a parallel sweep
//! produces byte-identical reports to a serial one.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers to use when the caller doesn't specify: one per
/// available hardware thread (see [`crate::util::auto_threads`]).
pub fn auto_jobs() -> usize {
    crate::util::auto_threads()
}

/// Apply `f` to every item, using up to `jobs` worker threads, returning
/// results in the order of `items` regardless of completion order.
///
/// `jobs <= 1` (or a single item) degenerates to a plain serial map on the
/// calling thread — no threads are spawned, so serial runs stay exactly as
/// debuggable (and deterministic) as before. A panic inside `f` on any
/// worker propagates to the caller when the scope joins.
pub fn parallel_map<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let jobs = jobs.max(1).min(n.max(1));
    if jobs == 1 {
        return items.into_iter().map(f).collect();
    }

    // One slot per item: the input is taken by whichever worker claims the
    // index, the output is written back to the same index. The mutex is
    // per-slot and touched twice per (seconds-long) cell — contention-free.
    let slots: Vec<Mutex<(Option<T>, Option<R>)>> =
        items.into_iter().map(|t| Mutex::new((Some(t), None))).collect();
    let cursor = AtomicUsize::new(0);
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i]
                    .lock()
                    .expect("sweep slot poisoned")
                    .0
                    .take()
                    .expect("sweep item claimed twice");
                let out = f(item);
                slots[i].lock().expect("sweep slot poisoned").1 = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("sweep slot poisoned")
                .1
                .expect("sweep item never completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(items.clone(), 8, |x| x * 3);
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..57).collect();
        let f = |x: u64| x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
        let serial = parallel_map(items.clone(), 1, f);
        for jobs in [2, 3, 16] {
            assert_eq!(parallel_map(items.clone(), jobs, f), serial, "jobs={jobs}");
        }
    }

    #[test]
    fn more_jobs_than_items() {
        let out = parallel_map(vec![1, 2, 3], 64, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<i32> = Vec::new();
        assert!(parallel_map(empty, 4, |x: i32| x).is_empty());
        assert_eq!(parallel_map(vec![9], 4, |x| x - 9), vec![0]);
    }

    #[test]
    fn auto_jobs_is_positive() {
        assert!(auto_jobs() >= 1);
    }

    #[test]
    fn non_copy_payloads_move_through() {
        let items: Vec<String> = (0..20).map(|i| format!("cell-{i}")).collect();
        let out = parallel_map(items, 4, |s| s.len());
        assert_eq!(out.len(), 20);
        assert!(out.iter().all(|&l| (6..=7).contains(&l)));
    }
}
