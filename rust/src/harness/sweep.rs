//! Parallel experiment-sweep engine: fan independent simulation cells out
//! over a *supervised* scoped worker pool.
//!
//! Every cell of the paper's evaluation grid (kernel × size class ×
//! configuration) is an independent, deterministic simulation — the fig/
//! table builders only ever combine *finished* cell results. That makes
//! the sweep embarrassingly parallel: [`supervised_map`] runs the cells on
//! `jobs` worker threads (work-stealing via a shared atomic cursor) and
//! returns the outcomes **in submission order**, so a parallel sweep
//! produces byte-identical reports to a serial one.
//!
//! Unlike a bare thread-scope map, the supervisor *contains* cell
//! failures instead of propagating them:
//!
//! - a panicking cell is caught with `catch_unwind` and reported as
//!   [`CellOutcome::Panicked`];
//! - a cell that exceeds the configured wall-clock deadline is abandoned
//!   by a watchdog and reported as [`CellOutcome::TimedOut`];
//! - an `Err` from the cell function becomes [`CellOutcome::Failed`];
//! - panics and errors retry with bounded exponential backoff before a
//!   terminal outcome is recorded ([`SupervisorPolicy::max_retries`]);
//! - under fail-fast (the default policy) the first terminal failure
//!   stops workers from *claiming* further cells (already-running cells
//!   finish; unclaimed ones come back [`CellOutcome::Skipped`]).
//!
//! A deterministic, seeded fault-injection layer ([`FaultPlan`]) plants
//! panics, delays, or errors at chosen cell indices so every one of those
//! paths is testable — and CI-gated — without any nondeterminism.
//!
//! The legacy [`parallel_map`] survives for fail-together callers (micro
//! benches); the experiment harness itself always goes through the
//! supervisor.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::trace::{Event, EventSink};
use crate::util::SplitMix64;

/// Number of workers to use when the caller doesn't specify: one per
/// available hardware thread (see [`crate::util::auto_threads`]).
pub fn auto_jobs() -> usize {
    crate::util::auto_threads()
}

/// Lock a slot even if a previous holder panicked: the supervisor owns
/// failure reporting, so mutex poisoning must not cascade one cell's
/// panic into every later slot access.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Terminal result of one supervised cell.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CellOutcome<R> {
    /// The cell completed; its result is bitwise identical to what an
    /// unsupervised serial run would have produced.
    Ok(R),
    /// Every attempt panicked; `msg` is the last panic payload.
    Panicked { msg: String, attempts: u32 },
    /// The watchdog gave up waiting. The attempt thread is abandoned (it
    /// may still be running); timeouts are not retried.
    TimedOut { limit_ms: u64, attempts: u32 },
    /// Every attempt returned an error; `err` is the last one.
    Failed { err: String, attempts: u32 },
    /// Never claimed: an earlier cell failed under fail-fast.
    Skipped,
}

impl<R> CellOutcome<R> {
    pub fn is_ok(&self) -> bool {
        matches!(self, CellOutcome::Ok(_))
    }

    pub fn into_ok(self) -> Option<R> {
        match self {
            CellOutcome::Ok(v) => Some(v),
            _ => None,
        }
    }

    /// Human-readable outcome (the report's annotated-hole text).
    pub fn describe(&self) -> String {
        match self {
            CellOutcome::Ok(_) => "ok".to_string(),
            CellOutcome::Panicked { msg, attempts } => {
                format!("panicked after {attempts} attempt(s): {msg}")
            }
            CellOutcome::TimedOut { limit_ms, attempts } => {
                format!("timed out after {limit_ms} ms (attempt {attempts})")
            }
            CellOutcome::Failed { err, attempts } => {
                format!("failed after {attempts} attempt(s): {err}")
            }
            CellOutcome::Skipped => "skipped (fail-fast after an earlier failure)".to_string(),
        }
    }
}

/// How the supervisor treats failing cells.
#[derive(Debug, Clone)]
pub struct SupervisorPolicy {
    /// `true`: record the failure and keep sweeping the remaining cells.
    /// `false` (default): stop claiming new cells after the first terminal
    /// failure — unclaimed cells come back [`CellOutcome::Skipped`].
    pub keep_going: bool,
    /// Wall-clock deadline per attempt. `None` (default) runs the cell
    /// inline on the worker; `Some` runs it on a watchdogged thread that
    /// is abandoned on expiry.
    pub cell_timeout: Option<Duration>,
    /// Extra attempts after a panic or error (timeouts never retry).
    pub max_retries: u32,
    /// Backoff before retry `k` is `base * 2^k`, capped at
    /// [`SupervisorPolicy::backoff_cap_ms`].
    pub backoff_base_ms: u64,
    pub backoff_cap_ms: u64,
    /// Deterministic fault-injection plan (testing/CI only).
    pub faults: Option<FaultPlan>,
    /// JSONL lifecycle-event sink (`--events FILE`). Telemetry only: cell
    /// results and report bytes are identical with or without it.
    pub events: Option<EventSink>,
    /// Live `\r`-rewritten progress line on stderr (`--progress`).
    pub progress: bool,
}

impl Default for SupervisorPolicy {
    fn default() -> Self {
        SupervisorPolicy {
            keep_going: false,
            cell_timeout: None,
            max_retries: 2,
            backoff_base_ms: 25,
            backoff_cap_ms: 1_000,
            faults: None,
            events: None,
            progress: false,
        }
    }
}

impl SupervisorPolicy {
    fn backoff(&self, attempt: u32) {
        let ms = self
            .backoff_base_ms
            .saturating_mul(1u64 << attempt.min(16))
            .min(self.backoff_cap_ms);
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }
}

/// What an injected fault does to the attempt it fires on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// `panic!` inside the cell (exercises `catch_unwind` containment).
    Panic,
    /// Sleep [`FaultPlan::delay_ms`] before the real work (exercises the
    /// deadline watchdog when a `cell_timeout` is set; otherwise the cell
    /// is merely slow and the sweep output is unchanged).
    Delay,
    /// Return `Err` from the cell. Errors are *transient*: they fire only
    /// on attempt 0, so a retrying supervisor recovers byte-identically.
    Error,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::Panic => "panic",
            FaultKind::Delay => "delay",
            FaultKind::Error => "error",
        }
    }
}

/// A deterministic, seeded fault plan: which cell indices (positions in
/// the sweep's work list) fault, and how. Parsed from
/// `--inject-faults seed=7,rate=0.25,kind=panic` or the `CASPER_FAULTS`
/// env var; an explicit `cells=0:3:7` list overrides the seeded rate.
///
/// Faults are keyed purely by cell *index* via an independent
/// [`SplitMix64`] stream per index, so the plan is identical at any job
/// count and any claim order.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Probability that a given cell index is planted (ignored when
    /// `cells` is set).
    pub rate: f64,
    pub kind: FaultKind,
    /// Explicit planted indices (overrides `rate`).
    pub cells: Option<Vec<usize>>,
    /// Sleep length for [`FaultKind::Delay`].
    pub delay_ms: u64,
}

impl FaultPlan {
    /// Parse the `key=value,...` spec string (see module docs / USAGE).
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut seed = 0u64;
        let mut rate = 0.0f64;
        let mut kind = None;
        let mut cells: Option<Vec<usize>> = None;
        let mut delay_ms = 50u64;
        for part in s.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (k, v) = part
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got '{part}'"))?;
            let v = v.trim();
            match k.trim() {
                "seed" => seed = v.parse().map_err(|_| format!("bad seed '{v}'"))?,
                "rate" => {
                    rate = v.parse().map_err(|_| format!("bad rate '{v}'"))?;
                    if !(0.0..=1.0).contains(&rate) {
                        return Err(format!("rate must be in [0,1], got {rate}"));
                    }
                }
                "kind" => {
                    kind = Some(match v {
                        "panic" => FaultKind::Panic,
                        "delay" => FaultKind::Delay,
                        "error" => FaultKind::Error,
                        other => {
                            return Err(format!(
                                "unknown fault kind '{other}' (panic | delay | error)"
                            ))
                        }
                    })
                }
                "cells" => {
                    let parsed: Result<Vec<usize>, _> =
                        v.split(':').map(|c| c.trim().parse::<usize>()).collect();
                    cells = Some(parsed.map_err(|_| {
                        format!("bad cells list '{v}' (colon-separated indices, e.g. 0:3:7)")
                    })?);
                }
                "delay-ms" | "delay_ms" => {
                    delay_ms = v.parse().map_err(|_| format!("bad delay-ms '{v}'"))?
                }
                other => {
                    return Err(format!(
                        "unknown fault-plan key '{other}' (seed | rate | kind | cells | delay-ms)"
                    ))
                }
            }
        }
        let kind = kind.ok_or_else(|| "missing kind= (panic | delay | error)".to_string())?;
        if cells.is_none() && rate <= 0.0 {
            return Err("plan plants nothing: set rate= or cells=".to_string());
        }
        Ok(FaultPlan { seed, rate, kind, cells, delay_ms })
    }

    /// Read a plan from the `CASPER_FAULTS` env var (empty/unset = none).
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var("CASPER_FAULTS") {
            Err(_) => Ok(None),
            Ok(s) if s.trim().is_empty() => Ok(None),
            Ok(s) => FaultPlan::parse(&s).map(Some),
        }
    }

    /// Is a fault planted at this cell index? Independent per-index draw,
    /// so the answer does not depend on job count or visit order.
    pub fn planted(&self, index: usize) -> bool {
        if let Some(cells) = &self.cells {
            return cells.contains(&index);
        }
        SplitMix64::new(self.seed ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .chance(self.rate)
    }

    /// Every planted index among `0..n` (test/diagnostic helper).
    pub fn planted_indices(&self, n: usize) -> Vec<usize> {
        (0..n).filter(|&i| self.planted(i)).collect()
    }

    /// Does the fault fire on this attempt? `Error` is transient (attempt
    /// 0 only — retries recover); `Panic` and `Delay` are sticky.
    pub fn fires(&self, index: usize, attempt: u32) -> Option<FaultKind> {
        if !self.planted(index) {
            return None;
        }
        match self.kind {
            FaultKind::Error if attempt > 0 => None,
            kind => Some(kind),
        }
    }
}

/// Run one attempt body: injected fault first (if any fires), then the
/// real cell function.
fn exec_attempt<T, R>(
    f: &impl Fn(&T) -> Result<R, String>,
    item: &T,
    index: usize,
    attempt: u32,
    faults: Option<&FaultPlan>,
) -> Result<R, String> {
    if let Some(kind) = faults.and_then(|p| p.fires(index, attempt)) {
        match kind {
            FaultKind::Panic => panic!("injected fault: panic at cell {index}"),
            FaultKind::Error => return Err(format!("injected fault: error at cell {index}")),
            FaultKind::Delay => {
                let ms = faults.map(|p| p.delay_ms).unwrap_or(0);
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
    }
    f(item)
}

/// Wall-clock milliseconds since a cell's first attempt began (`0` when
/// telemetry is off and no start timestamp was taken).
fn wall_ms(start: Option<Instant>) -> u64 {
    start.map_or(0, |t| t.elapsed().as_millis() as u64)
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one cell to a terminal [`CellOutcome`]: catch panics, watchdog the
/// deadline, retry panics/errors with bounded exponential backoff.
fn run_cell<T, R, F>(
    f: &Arc<F>,
    items: &Arc<Vec<T>>,
    index: usize,
    policy: &SupervisorPolicy,
) -> CellOutcome<R>
where
    T: Clone + Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&T) -> Result<R, String> + Send + Sync + 'static,
{
    let events = policy.events.as_ref();
    let cell_start = events.map(|_| Instant::now());
    let mut attempt: u32 = 0;
    loop {
        if let Some(sink) = events {
            sink.emit(
                Event::new("started").num("cell", index as u64).num("attempt", attempt as u64 + 1),
            );
        }
        // Outer Err = the attempt panicked; inner Err = it returned one.
        let result: Result<Result<R, String>, String> = match policy.cell_timeout {
            None => catch_unwind(AssertUnwindSafe(|| {
                exec_attempt(&**f, &items[index], index, attempt, policy.faults.as_ref())
            }))
            .map_err(panic_message),
            Some(limit) => {
                let (tx, rx) = mpsc::channel();
                let f = Arc::clone(f);
                let item = items[index].clone();
                let faults = policy.faults.clone();
                let spawned = std::thread::Builder::new()
                    .name(format!("casper-cell-{index}"))
                    .spawn(move || {
                        let r = catch_unwind(AssertUnwindSafe(|| {
                            exec_attempt(&*f, &item, index, attempt, faults.as_ref())
                        }))
                        .map_err(panic_message);
                        let _ = tx.send(r);
                    });
                match spawned {
                    Err(e) => Ok(Err(format!("cell worker spawn failed: {e}"))),
                    // The handle is dropped either way: on timeout the
                    // attempt thread is abandoned (it parks no results —
                    // the send just fails) rather than joined, so a hung
                    // simulation cannot hang the sweep.
                    Ok(_handle) => match rx.recv_timeout(limit) {
                        Ok(r) => r,
                        Err(_) => {
                            if let Some(sink) = events {
                                sink.emit(
                                    Event::new("timed-out")
                                        .num("cell", index as u64)
                                        .num("limit_ms", limit.as_millis() as u64)
                                        .num("wall_ms", wall_ms(cell_start)),
                                );
                            }
                            return CellOutcome::TimedOut {
                                limit_ms: limit.as_millis() as u64,
                                attempts: attempt + 1,
                            };
                        }
                    },
                }
            }
        };
        match result {
            Ok(Ok(v)) => {
                if let Some(sink) = events {
                    sink.emit(
                        Event::new("finished")
                            .num("cell", index as u64)
                            .num("attempts", attempt as u64 + 1)
                            .num("wall_ms", wall_ms(cell_start)),
                    );
                }
                return CellOutcome::Ok(v);
            }
            Ok(Err(err)) => {
                if attempt < policy.max_retries {
                    if let Some(sink) = events {
                        sink.emit(
                            Event::new("retried")
                                .num("cell", index as u64)
                                .num("attempt", attempt as u64 + 1)
                                .str("reason", &err),
                        );
                    }
                    policy.backoff(attempt);
                    attempt += 1;
                    continue;
                }
                if let Some(sink) = events {
                    sink.emit(
                        Event::new("failed")
                            .num("cell", index as u64)
                            .num("attempts", attempt as u64 + 1)
                            .num("wall_ms", wall_ms(cell_start))
                            .str("reason", &err),
                    );
                }
                return CellOutcome::Failed { err, attempts: attempt + 1 };
            }
            Err(msg) => {
                if attempt < policy.max_retries {
                    if let Some(sink) = events {
                        sink.emit(
                            Event::new("retried")
                                .num("cell", index as u64)
                                .num("attempt", attempt as u64 + 1)
                                .str("mode", "panic")
                                .str("reason", &msg),
                        );
                    }
                    policy.backoff(attempt);
                    attempt += 1;
                    continue;
                }
                if let Some(sink) = events {
                    sink.emit(
                        Event::new("failed")
                            .num("cell", index as u64)
                            .num("attempts", attempt as u64 + 1)
                            .num("wall_ms", wall_ms(cell_start))
                            .str("mode", "panic")
                            .str("reason", &msg),
                    );
                }
                return CellOutcome::Panicked { msg, attempts: attempt + 1 };
            }
        }
    }
}

/// Apply `f` to every item under supervision, using up to `jobs` worker
/// threads, returning one [`CellOutcome`] per item in the order of
/// `items` regardless of completion order.
///
/// With no faults injected and no cell failing, this is observably
/// identical to [`parallel_map`] — same results, same order — at any job
/// count (including `jobs == 1`, which runs the whole loop inline on the
/// calling thread).
pub fn supervised_map<T, R, F>(
    items: Vec<T>,
    jobs: usize,
    policy: &SupervisorPolicy,
    f: F,
) -> Vec<CellOutcome<R>>
where
    T: Clone + Send + Sync + 'static,
    R: Send + 'static,
    F: Fn(&T) -> Result<R, String> + Send + Sync + 'static,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let jobs = jobs.clamp(1, n);
    let f: Arc<F> = Arc::new(f);
    let items: Arc<Vec<T>> = Arc::new(items);
    let slots: Vec<Mutex<Option<CellOutcome<R>>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let done = AtomicUsize::new(0);
    let failed = AtomicUsize::new(0);
    let sweep_start = Instant::now();
    let worker = || loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let i = cursor.fetch_add(1, Ordering::SeqCst);
        if i >= n {
            break;
        }
        let out = run_cell(&f, &items, i, policy);
        let ok = out.is_ok();
        *lock_clean(&slots[i]) = Some(out);
        if policy.progress {
            if !ok {
                failed.fetch_add(1, Ordering::SeqCst);
            }
            let d = done.fetch_add(1, Ordering::SeqCst) + 1;
            print_progress(d, n, failed.load(Ordering::SeqCst), sweep_start);
        }
        if !ok && !policy.keep_going {
            stop.store(true, Ordering::SeqCst);
        }
    };
    if jobs == 1 {
        worker();
    } else {
        std::thread::scope(|scope| {
            for _ in 0..jobs {
                scope.spawn(&worker);
            }
        });
    }
    if policy.progress {
        eprintln!();
    }
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .unwrap_or(CellOutcome::Skipped)
        })
        .collect()
}

/// One `\r`-rewritten status line on stderr (`--progress`): stderr keeps
/// the report on stdout clean for redirection, and the coarse ETA comes
/// from the mean completed-cell rate so far.
fn print_progress(done: usize, total: usize, failed: usize, start: Instant) {
    let elapsed = start.elapsed().as_secs_f64();
    let rate = done as f64 / elapsed.max(1e-9);
    let left = (total - done) as f64 / rate.max(1e-9);
    eprint!("\r[sweep] {done}/{total} done, {failed} failed, ~{left:.0}s left   ");
}

/// Apply `f` to every item, using up to `jobs` worker threads, returning
/// results in the order of `items` regardless of completion order.
///
/// `jobs <= 1` (or a single item) degenerates to a plain serial map on the
/// calling thread — no threads are spawned, so serial runs stay exactly as
/// debuggable (and deterministic) as before. A panic inside `f` on any
/// worker propagates to the caller when the scope joins (fail-together
/// semantics; the experiment harness uses [`supervised_map`] instead).
pub fn parallel_map<T, R, F>(items: Vec<T>, jobs: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    let jobs = jobs.max(1).min(n.max(1));
    if jobs == 1 {
        return items.into_iter().map(f).collect();
    }

    // One slot per item: the input is taken by whichever worker claims the
    // index, the output is written back to the same index. The mutex is
    // per-slot and touched twice per (seconds-long) cell — contention-free.
    // Poisoned slots are recovered, not propagated: the panic itself
    // resurfaces at scope join, and cascading it into every later slot
    // access would only bury the real failure.
    let slots: Vec<Mutex<(Option<T>, Option<R>)>> =
        items.into_iter().map(|t| Mutex::new((Some(t), None))).collect();
    let cursor = AtomicUsize::new(0);
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..jobs {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = lock_clean(&slots[i]).0.take().expect("sweep item claimed twice");
                let out = f(item);
                lock_clean(&slots[i]).1 = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap_or_else(|poisoned| poisoned.into_inner())
                .1
                .expect("sweep item never completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = parallel_map(items.clone(), 8, |x| x * 3);
        assert_eq!(out, items.iter().map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_and_parallel_agree() {
        let items: Vec<u64> = (0..57).collect();
        let f = |x: u64| x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17);
        let serial = parallel_map(items.clone(), 1, f);
        for jobs in [2, 3, 16] {
            assert_eq!(parallel_map(items.clone(), jobs, f), serial, "jobs={jobs}");
        }
    }

    #[test]
    fn more_jobs_than_items() {
        let out = parallel_map(vec![1, 2, 3], 64, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<i32> = Vec::new();
        assert!(parallel_map(empty, 4, |x: i32| x).is_empty());
        assert_eq!(parallel_map(vec![9], 4, |x| x - 9), vec![0]);
    }

    #[test]
    fn auto_jobs_is_positive() {
        assert!(auto_jobs() >= 1);
    }

    #[test]
    fn non_copy_payloads_move_through() {
        let items: Vec<String> = (0..20).map(|i| format!("cell-{i}")).collect();
        let out = parallel_map(items, 4, |s| s.len());
        assert_eq!(out.len(), 20);
        assert!(out.iter().all(|&l| (6..=7).contains(&l)));
    }

    // ---- supervised_map ------------------------------------------------

    /// A no-retry-delay policy for fast tests.
    fn quick_policy() -> SupervisorPolicy {
        SupervisorPolicy { backoff_base_ms: 0, ..Default::default() }
    }

    fn oks(outs: Vec<CellOutcome<u64>>) -> Vec<u64> {
        outs.into_iter().map(|o| o.into_ok().expect("expected Ok outcome")).collect()
    }

    #[test]
    fn supervised_matches_parallel_map_when_clean() {
        let items: Vec<u64> = (0..57).collect();
        let f = |x: &u64| Ok(x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17));
        let want: Vec<u64> = items
            .iter()
            .map(|x| x.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(17))
            .collect();
        for jobs in [1, 2, 16] {
            let policy = quick_policy();
            assert_eq!(oks(supervised_map(items.clone(), jobs, &policy, f)), want, "jobs={jobs}");
        }
    }

    #[test]
    fn supervised_empty_input() {
        let policy = quick_policy();
        let out: Vec<CellOutcome<u64>> = supervised_map(Vec::<u64>::new(), 4, &policy, |x| Ok(*x));
        assert!(out.is_empty());
    }

    #[test]
    fn panic_is_contained_and_survivors_complete() {
        let items: Vec<u64> = (0..12).collect();
        for jobs in [1, 2, 16] {
            let policy = SupervisorPolicy { keep_going: true, ..quick_policy() };
            let outs = supervised_map(items.clone(), jobs, &policy, |x: &u64| {
                if *x == 5 {
                    panic!("boom {x}");
                }
                Ok(*x * 2)
            });
            for (i, o) in outs.iter().enumerate() {
                if i == 5 {
                    match o {
                        CellOutcome::Panicked { msg, attempts } => {
                            assert_eq!(msg, "boom 5");
                            assert_eq!(*attempts, 3, "default policy = 1 try + 2 retries");
                        }
                        other => panic!("jobs={jobs}: expected Panicked, got {other:?}"),
                    }
                } else {
                    assert_eq!(o.clone().into_ok(), Some(i as u64 * 2), "jobs={jobs} cell {i}");
                }
            }
        }
    }

    #[test]
    fn transient_error_recovers_via_retry() {
        use std::sync::atomic::AtomicU32;
        let tries = Arc::new(AtomicU32::new(0));
        let t = tries.clone();
        let policy = quick_policy();
        let outs = supervised_map(vec![7u64], 1, &policy, move |x: &u64| {
            if t.fetch_add(1, Ordering::SeqCst) == 0 {
                return Err("flaky".to_string());
            }
            Ok(*x)
        });
        assert_eq!(outs[0], CellOutcome::Ok(7));
        assert_eq!(tries.load(Ordering::SeqCst), 2, "one failure + one successful retry");
    }

    #[test]
    fn persistent_error_exhausts_retries() {
        let policy = SupervisorPolicy { max_retries: 1, keep_going: true, ..quick_policy() };
        let outs = supervised_map(vec![1u64], 4, &policy, |_: &u64| {
            Err::<u64, _>("always".to_string())
        });
        assert_eq!(outs[0], CellOutcome::Failed { err: "always".into(), attempts: 2 });
    }

    #[test]
    fn deadline_watchdog_times_out_hung_cells() {
        let policy = SupervisorPolicy {
            cell_timeout: Some(Duration::from_millis(50)),
            keep_going: true,
            ..quick_policy()
        };
        let outs = supervised_map(vec![0u64, 1], 2, &policy, |x: &u64| {
            if *x == 0 {
                std::thread::sleep(Duration::from_secs(30));
            }
            Ok(*x)
        });
        assert_eq!(outs[0], CellOutcome::TimedOut { limit_ms: 50, attempts: 1 });
        assert_eq!(outs[1], CellOutcome::Ok(1));
    }

    #[test]
    fn fail_fast_skips_unclaimed_cells() {
        // Serial + fail-fast: cell 0 fails terminally, so cells 1.. are
        // never claimed and come back Skipped.
        let policy = SupervisorPolicy { max_retries: 0, ..quick_policy() };
        let outs = supervised_map(vec![0u64, 1, 2, 3], 1, &policy, |x: &u64| {
            if *x == 0 {
                return Err("fatal".to_string());
            }
            Ok(*x)
        });
        assert_eq!(outs[0], CellOutcome::Failed { err: "fatal".into(), attempts: 1 });
        for o in &outs[1..] {
            assert_eq!(*o, CellOutcome::Skipped);
        }
    }

    #[test]
    fn injected_fault_plan_is_deterministic_and_order_independent() {
        let plan = FaultPlan {
            seed: 42,
            rate: 0.3,
            kind: FaultKind::Panic,
            cells: None,
            delay_ms: 0,
        };
        let planted = plan.planted_indices(64);
        assert!(!planted.is_empty(), "rate 0.3 over 64 cells should plant something");
        assert!(planted.len() < 40, "rate 0.3 over 64 cells should not plant everything");
        // Same seed → same plan; different seed → (almost surely) different.
        assert_eq!(planted, plan.planted_indices(64));
        let other = FaultPlan { seed: 43, ..plan.clone() };
        assert_ne!(planted, other.planted_indices(64));
    }

    #[test]
    fn injected_panic_only_hits_planted_cells() {
        let items: Vec<u64> = (0..16).collect();
        let plan =
            FaultPlan { seed: 9, rate: 0.4, kind: FaultKind::Panic, cells: None, delay_ms: 0 };
        let planted = plan.planted_indices(items.len());
        for jobs in [1, 2, 16] {
            let policy = SupervisorPolicy {
                keep_going: true,
                max_retries: 0,
                faults: Some(plan.clone()),
                ..quick_policy()
            };
            let outs = supervised_map(items.clone(), jobs, &policy, |x: &u64| Ok(*x + 100));
            for (i, o) in outs.iter().enumerate() {
                if planted.contains(&i) {
                    assert!(
                        matches!(o, CellOutcome::Panicked { .. }),
                        "jobs={jobs} cell {i}: {o:?}"
                    );
                } else {
                    assert_eq!(o.clone().into_ok(), Some(i as u64 + 100), "jobs={jobs} cell {i}");
                }
            }
        }
    }

    #[test]
    fn injected_error_is_transient_under_retry() {
        let plan = FaultPlan {
            seed: 1,
            rate: 0.0,
            kind: FaultKind::Error,
            cells: Some(vec![2]),
            delay_ms: 0,
        };
        assert_eq!(plan.fires(2, 0), Some(FaultKind::Error));
        assert_eq!(plan.fires(2, 1), None, "error faults fire on attempt 0 only");
        assert_eq!(plan.fires(1, 0), None);
        let policy = SupervisorPolicy { faults: Some(plan), ..quick_policy() };
        let outs = supervised_map((0..4u64).collect(), 2, &policy, |x: &u64| Ok(*x));
        assert_eq!(oks(outs), vec![0, 1, 2, 3], "retry must recover the transient fault");
    }

    #[test]
    fn fault_spec_parses() {
        let p = FaultPlan::parse("seed=7,rate=0.25,kind=panic").unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.rate, 0.25);
        assert_eq!(p.kind, FaultKind::Panic);
        assert_eq!(p.cells, None);

        let p = FaultPlan::parse("kind=delay,cells=0:3:7,delay-ms=5").unwrap();
        assert_eq!(p.cells, Some(vec![0, 3, 7]));
        assert_eq!(p.delay_ms, 5);
        assert!(p.planted(3) && !p.planted(1));

        assert!(FaultPlan::parse("rate=0.5").is_err(), "kind is required");
        assert!(FaultPlan::parse("kind=panic").is_err(), "needs rate or cells");
        assert!(FaultPlan::parse("kind=frob,rate=0.5").is_err());
        assert!(FaultPlan::parse("kind=panic,rate=1.5").is_err());
        assert!(FaultPlan::parse("kind=panic,cells=a:b").is_err());
        assert!(FaultPlan::parse("bogus").is_err());
        assert!(FaultPlan::parse("kind=panic,rate=0.5,junk=1").is_err());
    }

    #[test]
    fn events_record_cell_lifecycle() {
        let dir = std::env::temp_dir().join(format!("casper-sweep-ev-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ev.jsonl");
        let sink = EventSink::create(&path).unwrap();
        let policy = SupervisorPolicy { events: Some(sink), keep_going: true, ..quick_policy() };
        let outs = supervised_map((0..4u64).collect(), 2, &policy, |x: &u64| {
            if *x == 1 {
                return Err("bad".to_string());
            }
            Ok(*x)
        });
        assert_eq!(outs.iter().filter(|o| o.is_ok()).count(), 3);
        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines() {
            crate::trace::chrome::validate_json(line)
                .unwrap_or_else(|e| panic!("bad event line {line:?}: {e}"));
        }
        let count = |kind: &str| {
            let tag = format!("\"event\":\"{kind}\"");
            text.lines().filter(|l| l.contains(&tag)).count()
        };
        assert_eq!(count("finished"), 3);
        assert_eq!(count("failed"), 1);
        assert_eq!(count("retried"), 2, "default policy retries the failing cell twice");
        assert_eq!(count("started"), 6, "one per attempt: 3 clean + 3 for the failing cell");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn outcome_descriptions() {
        assert!(CellOutcome::<u64>::Skipped.describe().contains("fail-fast"));
        let p = CellOutcome::<u64>::Panicked { msg: "m".into(), attempts: 3 };
        assert!(p.describe().contains("panicked after 3"));
        let t = CellOutcome::<u64>::TimedOut { limit_ms: 10, attempts: 1 };
        assert!(t.describe().contains("timed out after 10 ms"));
        let f = CellOutcome::<u64>::Failed { err: "e".into(), attempts: 1 };
        assert!(f.describe().contains("failed after 1"));
        assert_eq!(CellOutcome::Ok(1u64).describe(), "ok");
    }
}
