//! Experiment harness: one registered experiment per paper table/figure.
//!
//! Each experiment regenerates the paper artifact from the simulator and
//! prints our measured value next to the paper's published value (appendix
//! tables), with the ratio — the format EXPERIMENTS.md records.
//!
//! The sweep grid is `kernels × size classes`. The kernel set defaults to
//! the paper's six ([`paper_kernels`]); [`run_experiments_with`] accepts
//! any [`KernelSpec`] list — extended presets, TOML-defined kernels —
//! and the paper-reference columns print `-` for kernels the paper never
//! measured.

pub mod journal;
pub mod paperdata;
pub mod report;
pub mod sweep;

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::area::{perf_per_area_improvement, CasperArea};
use crate::config::{MappingPolicy, SimConfig, SizeClass, SpuPlacement};
use crate::coordinator::{default_spu_threads, run_casper_spec, CasperOptions, RunStats};
use crate::cpu::{run_cpu_spec, CpuRunStats};
use crate::energy::{casper_energy, cpu_energy};
use crate::gpu::GpuModel;
use crate::pims::PimsModel;
use crate::roofline;
use crate::stencil::{KernelId, KernelSpec, StencilKind};
use crate::trace::{Event, EventSink};
use crate::util::geomean;

pub use journal::{Journal, Record};
pub use report::{CellFailure, Report, Table};
pub use sweep::{
    auto_jobs, parallel_map, supervised_map, CellOutcome, FaultKind, FaultPlan, SupervisorPolicy,
};

/// The experiments — one per paper table/figure, plus repo-grown extras
/// (not in [`Experiment::ALL`], so the default report stays the paper's).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Experiment {
    Fig1,
    Fig10,
    Fig11,
    Fig12,
    Fig13,
    Fig14,
    Table4,
    Table5,
    Table6,
    /// Per-slice NoC/DRAM imbalance (ROADMAP open item; `--only slices`).
    Slices,
    /// Temporal-blocking traffic table: avoided LLC fills, halo
    /// recompute, DRAM reads, fused-reduction results per kernel/class
    /// (`--only blocked`, typically with `--temporal-block > 1`).
    Blocked,
}

impl Experiment {
    /// The paper's tables/figures — the default `experiments` selection.
    pub const ALL: [Experiment; 9] = [
        Experiment::Fig1,
        Experiment::Fig10,
        Experiment::Fig11,
        Experiment::Fig12,
        Experiment::Fig13,
        Experiment::Fig14,
        Experiment::Table4,
        Experiment::Table5,
        Experiment::Table6,
    ];

    /// Extra experiments selectable via `--only` but not in the default
    /// report (which must stay byte-stable against the paper set).
    pub const EXTRA: [Experiment; 2] = [Experiment::Slices, Experiment::Blocked];

    pub fn id(self) -> &'static str {
        match self {
            Experiment::Fig1 => "fig1",
            Experiment::Fig10 => "fig10",
            Experiment::Fig11 => "fig11",
            Experiment::Fig12 => "fig12",
            Experiment::Fig13 => "fig13",
            Experiment::Fig14 => "fig14",
            Experiment::Table4 => "table4",
            Experiment::Table5 => "table5",
            Experiment::Table6 => "table6",
            Experiment::Slices => "slices",
            Experiment::Blocked => "blocked",
        }
    }

    pub fn parse(s: &str) -> Option<Experiment> {
        let q = s.trim().to_ascii_lowercase();
        Experiment::ALL
            .into_iter()
            .chain(Experiment::EXTRA)
            .find(|e| e.id() == q)
    }

    pub fn title(self) -> &'static str {
        match self {
            Experiment::Fig1 => "Roofline for the multi-core baseline running six stencils",
            Experiment::Fig10 => "Speedup compared to the baseline multi-core system",
            Experiment::Fig11 => "Normalized energy consumption vs the 16-core baseline",
            Experiment::Fig12 => "Performance/area vs an NVIDIA Titan V",
            Experiment::Fig13 => "Speedup compared to PIMS",
            Experiment::Fig14 => "Contribution of custom mapping vs near-cache placement",
            Experiment::Table4 => "Dynamic instruction counts",
            Experiment::Table5 => "Execution cycles (CPU / GPU / Casper)",
            Experiment::Table6 => "Energy consumption (J)",
            Experiment::Slices => "Per-slice NoC/DRAM imbalance",
            Experiment::Blocked => "Temporal blocking: avoided fills, halo recompute, reductions",
        }
    }
}

/// The six paper kernels as specs, in paper order — the default sweep set.
pub fn paper_kernels() -> Vec<Arc<KernelSpec>> {
    StencilKind::ALL.iter().map(|k| k.spec()).collect()
}

/// Which size classes to sweep. `quick` limits to L2 (for CI-speed runs).
#[derive(Debug, Clone, Copy)]
pub struct SweepOptions {
    pub quick: bool,
    pub steps: usize,
    /// Worker threads for the cell sweep. `1` = serial (the builders fill
    /// the cache lazily, exactly as before); `> 1` prefills every needed
    /// cell through [`sweep::parallel_map`] first. Reports are identical
    /// either way — cells are deterministic and consumed in fixed order.
    pub jobs: usize,
    /// Worker threads *inside* each Casper cell (the epoch-parallel
    /// engine; `1` = serial). Reports are byte-identical at any value —
    /// the engine identity tests pin that — so this purely trades
    /// cell-level against intra-run parallelism.
    pub spu_threads: usize,
    /// Temporal block depth for every Casper cell (`--temporal-block`).
    /// `1` (default) is plain chaining — the byte-stable paper report.
    /// Values above 1 change traffic counters (and thus cycles), so the
    /// journal context includes it.
    pub temporal_block: usize,
}

impl Default for SweepOptions {
    fn default() -> Self {
        SweepOptions {
            quick: false,
            steps: 1,
            jobs: 1,
            spu_threads: default_spu_threads(),
            temporal_block: 1,
        }
    }
}

impl SweepOptions {
    pub fn classes(&self) -> &'static [SizeClass] {
        if self.quick {
            &[SizeClass::L2]
        } else {
            &[SizeClass::L2, SizeClass::Llc, SizeClass::Dram]
        }
    }
}

/// The sweep's fault-handling configuration: supervisor policy plus the
/// optional checkpoint journal (`--resume`). Separate from
/// [`SweepOptions`] so the latter stays `Copy` for the builders.
#[derive(Debug, Clone, Default)]
pub struct SupervisorConfig {
    pub policy: SupervisorPolicy,
    /// Checkpoint journal path: completed cells are loaded from it and
    /// new completions appended, so an interrupted sweep resumes by
    /// re-running only the missing cells.
    pub journal: Option<PathBuf>,
}

impl SupervisorConfig {
    /// Does this configuration change anything vs a bare serial sweep?
    /// When false (and `jobs <= 1`) the cache keeps the legacy lazy-fill
    /// path, byte-identical to the pre-supervisor harness.
    fn is_active(&self) -> bool {
        self.journal.is_some()
            || self.policy.keep_going
            || self.policy.cell_timeout.is_some()
            || self.policy.faults.is_some()
            || self.policy.events.is_some()
            || self.policy.progress
    }
}

/// Which engine a sweep cell belongs to (failure bookkeeping key).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CellKind {
    Casper,
    Cpu,
    Ablation,
}

impl CellKind {
    pub fn name(self) -> &'static str {
        match self {
            CellKind::Casper => "casper",
            CellKind::Cpu => "cpu",
            CellKind::Ablation => "ablation",
        }
    }
}

/// Cache of (kernel, class) → (casper, cpu) runs shared by experiments,
/// keyed by interned [`KernelId`].
pub struct SweepCache {
    cfg: SimConfig,
    opts: SweepOptions,
    sup: SupervisorConfig,
    kernels: Vec<Arc<KernelSpec>>,
    casper: HashMap<(KernelId, SizeClass), RunStats>,
    cpu: HashMap<(KernelId, SizeClass), CpuRunStats>,
    ablation: HashMap<(KernelId, SizeClass), AblationPoint>,
    /// Journal-loaded ablation pairs not yet joined with their `full`
    /// Casper cycles (joined after prefill, when `casper` is populated).
    ablation_pairs: HashMap<(KernelId, SizeClass), (u64, u64)>,
    /// Terminal failure text per cell, filled by the supervised prefill;
    /// the builders render these as annotated holes.
    failed: HashMap<(CellKind, KernelId, SizeClass), String>,
    /// Open checkpoint journal (workers append completions through it).
    journal: Option<Arc<Mutex<Journal>>>,
    /// Cells actually simulated by this cache (resume diagnostics: a
    /// resumed sweep re-runs only the cells its journal was missing).
    executed: usize,
    /// Cells simulated on the serial (lazy) path. After a `prefill` this
    /// should stay 0 — a nonzero count means [`needed_cells`] drifted
    /// from what the builders actually read (tested below).
    lazy_fills: u64,
}

/// Fig 14 data point: cycles under the three configurations.
#[derive(Debug, Clone, Copy)]
pub struct AblationPoint {
    /// SPUs near L1, baseline mapping (the Fig 14 baseline).
    pub near_l1_base: u64,
    /// SPUs near L1 + stencil-segment mapping.
    pub near_l1_mapped: u64,
    /// Full Casper: near-LLC + mapping.
    pub full: u64,
}

/// One independent simulation cell of the sweep grid.
#[derive(Debug, Clone)]
enum Cell {
    Casper(Arc<KernelSpec>, SizeClass),
    Cpu(Arc<KernelSpec>, SizeClass),
    /// Fig 14 near-L1 pair: (baseline mapping, +stencil mapping) cycles.
    Ablation(Arc<KernelSpec>, SizeClass),
}

/// Result of one sweep cell (paired with its [`Cell`] by index).
enum CellOut {
    Casper(RunStats),
    Cpu(CpuRunStats),
    Ablation(u64, u64),
}

/// The context digest a checkpoint journal is bound to: config, steps,
/// quick flag, temporal block, plan strategy, and kernel set.
/// Deliberately excludes `jobs` and `spu_threads` — neither changes any
/// result (the byte-identity tests pin that), so a journal written at
/// `--jobs 16` resumes at `--jobs 1`. `temporal_block` *is* bound: it
/// changes traffic counters and cycles, so records at different depths
/// must not cross-resume. The pass-plan strategy (env `CASPER_PLAN`,
/// which every cell's `CasperOptions::default()` reads) is bound for the
/// same reason: kernels whose optimized plan differs from greedy run
/// different per-pass stream sets, so their counters differ too.
pub fn journal_context(cfg: &SimConfig, opts: SweepOptions, kernels: &[Arc<KernelSpec>]) -> u64 {
    let ids: Vec<&str> = kernels.iter().map(|s| s.id.as_str()).collect();
    journal::context_digest(&[
        &format!("{cfg:?}"),
        &format!("steps={}", opts.steps),
        &format!("quick={}", opts.quick),
        &format!("temporal_block={}", opts.temporal_block),
        &format!("plan={}", crate::coordinator::default_plan_strategy().name()),
        &ids.join(","),
    ])
}

impl SweepCache {
    /// Cache over the default (paper six) kernel set.
    pub fn new(cfg: &SimConfig, opts: SweepOptions) -> SweepCache {
        SweepCache::with_kernels(cfg, opts, &paper_kernels())
    }

    /// Cache over an explicit kernel set (specs in sweep order).
    pub fn with_kernels(
        cfg: &SimConfig,
        opts: SweepOptions,
        kernels: &[Arc<KernelSpec>],
    ) -> SweepCache {
        SweepCache::with_supervisor(cfg, opts, kernels, &SupervisorConfig::default())
            .expect("default supervisor config opens no journal and cannot fail")
    }

    /// Cache with an explicit supervisor configuration. Opens the
    /// checkpoint journal (if any) and pre-loads every valid record whose
    /// context matches this sweep.
    pub fn with_supervisor(
        cfg: &SimConfig,
        opts: SweepOptions,
        kernels: &[Arc<KernelSpec>],
        sup: &SupervisorConfig,
    ) -> Result<SweepCache> {
        let mut cache = SweepCache {
            cfg: cfg.clone(),
            opts,
            sup: sup.clone(),
            kernels: kernels.to_vec(),
            casper: HashMap::new(),
            cpu: HashMap::new(),
            ablation: HashMap::new(),
            ablation_pairs: HashMap::new(),
            failed: HashMap::new(),
            journal: None,
            executed: 0,
            lazy_fills: 0,
        };
        if let Some(path) = &sup.journal {
            let ctx = journal_context(cfg, opts, kernels);
            let (j, records) = Journal::open(path, ctx)?;
            for r in records {
                match r {
                    Record::Casper { id, level, stats, .. } => {
                        cache.casper.insert((KernelId::new(&id), level), stats);
                    }
                    Record::Cpu { id, level, stats } => {
                        cache.cpu.insert((KernelId::new(&id), level), stats);
                    }
                    Record::Ablation { id, level, near_l1_base, near_l1_mapped } => {
                        cache
                            .ablation_pairs
                            .insert((KernelId::new(&id), level), (near_l1_base, near_l1_mapped));
                    }
                }
            }
            cache.journal = Some(Arc::new(Mutex::new(j)));
        }
        Ok(cache)
    }

    /// The sweep's kernel set (cheap `Arc` clones, in sweep order).
    pub fn kernels(&self) -> Vec<Arc<KernelSpec>> {
        self.kernels.clone()
    }

    /// Cells simulated by this cache (excludes journal-loaded ones).
    pub fn executed_cells(&self) -> usize {
        self.executed
    }

    /// Compute every cell the selected experiments will ask for, fanned
    /// out over `opts.jobs` supervised workers. After this, the lazy
    /// accessors below are pure cache hits, so the fig/table builders run
    /// unchanged — and in the same deterministic order. Kept for
    /// compatibility with pre-supervisor callers; panics on journal IO
    /// errors (use [`SweepCache::prefill_checked`] to handle them).
    pub fn prefill(&mut self, which: &[Experiment]) {
        self.prefill_checked(which).expect("sweep prefill failed");
    }

    /// Supervised prefill. Every needed cell not already cached (or
    /// journal-loaded) runs under [`sweep::supervised_map`]; failures are
    /// recorded per cell for the builders to render as holes.
    pub fn prefill_checked(&mut self, which: &[Experiment]) -> Result<()> {
        if self.opts.jobs <= 1 && !self.sup.is_active() {
            return Ok(()); // legacy serial path: lazy fill, identical to the old flow
        }
        let (want_casper, want_cpu, want_ablation) =
            needed_cells(which, self.opts, &self.kernels);
        // Enumerate cells in the fixed sweep order (kernel-major, then
        // class) so the work list — and thus fault-plan cell indices and
        // any tie-breaking — is stable. Needed-but-already-cached cells
        // (journal hits on `--resume`) emit `cached`; the rest emit
        // `scheduled` with their work-list index, which keys every later
        // lifecycle event for that cell.
        let events = self.sup.policy.events.clone();
        let ev = events.as_ref();
        let mut cells: Vec<Cell> = Vec::new();
        for spec in &self.kernels {
            for &level in &SizeClass::ALL {
                let key = (spec.id.clone(), level);
                let id = spec.id.as_str();
                if want_casper.contains(&key) {
                    if self.casper.contains_key(&key) {
                        emit_cell(ev, "cached", CellKind::Casper, id, level, None);
                    } else {
                        emit_cell(ev, "scheduled", CellKind::Casper, id, level, Some(cells.len()));
                        cells.push(Cell::Casper(spec.clone(), level));
                    }
                }
                if want_cpu.contains(&key) {
                    if self.cpu.contains_key(&key) {
                        emit_cell(ev, "cached", CellKind::Cpu, id, level, None);
                    } else {
                        emit_cell(ev, "scheduled", CellKind::Cpu, id, level, Some(cells.len()));
                        cells.push(Cell::Cpu(spec.clone(), level));
                    }
                }
                if want_ablation.contains(&key) {
                    if self.ablation.contains_key(&key) || self.ablation_pairs.contains_key(&key) {
                        emit_cell(ev, "cached", CellKind::Ablation, id, level, None);
                    } else {
                        let idx = Some(cells.len());
                        emit_cell(ev, "scheduled", CellKind::Ablation, id, level, idx);
                        cells.push(Cell::Ablation(spec.clone(), level));
                    }
                }
            }
        }
        if !cells.is_empty() {
            let cfg = self.cfg.clone();
            let steps = self.opts.steps;
            let spu_threads = self.opts.spu_threads;
            let t_block = self.opts.temporal_block;
            let journal = self.journal.clone();
            let run = move |cell: &Cell| -> Result<CellOut, String> {
                let out = match cell {
                    Cell::Casper(spec, level) => {
                        let d = spec.domain(*level);
                        CellOut::Casper(run_casper_cell(
                            &cfg,
                            spec,
                            &d,
                            steps,
                            spu_threads,
                            t_block,
                        )?)
                    }
                    Cell::Cpu(spec, level) => {
                        let d = spec.domain(*level);
                        CellOut::Cpu(run_cpu_spec(&cfg, spec, &d, steps))
                    }
                    Cell::Ablation(spec, level) => {
                        let d = spec.domain(*level);
                        let mut near_l1 = cfg.clone();
                        near_l1.placement = SpuPlacement::NearL1;
                        near_l1.mapping = MappingPolicy::Baseline;
                        let a = run_casper_cell(&near_l1, spec, &d, steps, spu_threads, t_block)?
                            .cycles;
                        let mut near_l1_mapped = near_l1.clone();
                        near_l1_mapped.mapping = MappingPolicy::StencilSegment;
                        let b =
                            run_casper_cell(&near_l1_mapped, spec, &d, steps, spu_threads, t_block)?
                                .cycles;
                        CellOut::Ablation(a, b)
                    }
                };
                // Journal the completion from the worker, so a kill at any
                // point loses at most the cells still in flight.
                if let Some(j) = &journal {
                    let rec = record_of(cell, &out);
                    let mut guard = j.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                    if let Err(e) = guard.append(&rec) {
                        eprintln!("warning: checkpoint append failed: {e:#}");
                    }
                }
                Ok(out)
            };
            let outcomes =
                sweep::supervised_map(cells.clone(), self.opts.jobs, &self.sup.policy, run);
            for (cell, outcome) in cells.into_iter().zip(outcomes) {
                let (kind, spec, level) = match &cell {
                    Cell::Casper(s, l) => (CellKind::Casper, s.clone(), *l),
                    Cell::Cpu(s, l) => (CellKind::Cpu, s.clone(), *l),
                    Cell::Ablation(s, l) => (CellKind::Ablation, s.clone(), *l),
                };
                match outcome {
                    CellOutcome::Ok(out) => {
                        self.executed += 1;
                        if let Some(sink) = events.as_ref() {
                            sink.emit(result_event(kind, spec.id.as_str(), level, &out));
                        }
                        match out {
                            CellOut::Casper(stats) => {
                                self.casper.insert((spec.id.clone(), level), stats);
                            }
                            CellOut::Cpu(stats) => {
                                self.cpu.insert((spec.id.clone(), level), stats);
                            }
                            CellOut::Ablation(a, b) => {
                                self.ablation_pairs.insert((spec.id.clone(), level), (a, b));
                            }
                        }
                    }
                    // Fail-fast leftovers: neither done nor failed; the
                    // caller aborts before any builder reads them.
                    CellOutcome::Skipped => {}
                    other => {
                        self.failed.insert((kind, spec.id.clone(), level), other.describe());
                    }
                }
            }
        }
        self.join_ablation_pairs();
        Ok(())
    }

    /// Join near-L1 ablation pairs with their `full` Casper cycles. A
    /// pair whose Casper cell failed becomes a dependent ablation
    /// failure; a pair whose Casper cell was skipped (fail-fast) stays
    /// pending.
    fn join_ablation_pairs(&mut self) {
        let pairs: Vec<_> = self.ablation_pairs.drain().collect();
        for ((id, level), (a, b)) in pairs {
            if let Some(full) = self.casper.get(&(id.clone(), level)).map(|s| s.cycles) {
                self.ablation.insert(
                    (id, level),
                    AblationPoint { near_l1_base: a, near_l1_mapped: b, full },
                );
            } else if let Some(why) =
                self.failed.get(&(CellKind::Casper, id.clone(), level)).cloned()
            {
                self.failed
                    .entry((CellKind::Ablation, id, level))
                    .or_insert_with(|| format!("dependent casper cell failed: {why}"));
            } else {
                self.ablation_pairs.insert((id, level), (a, b));
            }
        }
    }

    /// Why the given cell kinds failed for this (kernel, class), if any
    /// did — the builders call this before reading a cell and render the
    /// reason as an annotated hole instead.
    pub fn cell_failure(
        &self,
        spec: &KernelSpec,
        level: SizeClass,
        kinds: &[CellKind],
    ) -> Option<String> {
        let mut msgs = Vec::new();
        for &k in kinds {
            if let Some(why) = self.failed.get(&(k, spec.id.clone(), level)) {
                msgs.push(format!("{} {}", k.name(), why));
            }
        }
        if msgs.is_empty() {
            None
        } else {
            Some(msgs.join("; "))
        }
    }

    /// Every failed cell in deterministic order (kernel sweep order, then
    /// class, then kind) — the report's "failed cells" section.
    pub fn failures(&self) -> Vec<CellFailure> {
        let mut out = Vec::new();
        for spec in &self.kernels {
            for &level in &SizeClass::ALL {
                for kind in [CellKind::Casper, CellKind::Cpu, CellKind::Ablation] {
                    if let Some(why) = self.failed.get(&(kind, spec.id.clone(), level)) {
                        out.push(CellFailure {
                            kind: kind.name().to_string(),
                            kernel: spec.id.to_string(),
                            level: level.name().to_string(),
                            outcome: why.clone(),
                        });
                    }
                }
            }
        }
        out
    }

    pub fn casper(&mut self, spec: &KernelSpec, level: SizeClass) -> &RunStats {
        let key = (spec.id.clone(), level);
        if !self.casper.contains_key(&key) {
            self.lazy_fills += 1;
            let d = spec.domain(level);
            let stats = run_casper_cell(
                &self.cfg,
                spec,
                &d,
                self.opts.steps,
                self.opts.spu_threads,
                self.opts.temporal_block,
            )
            .unwrap_or_else(|e| panic!("casper run failed: {e}"));
            self.casper.insert(key.clone(), stats);
        }
        &self.casper[&key]
    }

    pub fn cpu(&mut self, spec: &KernelSpec, level: SizeClass) -> &CpuRunStats {
        let key = (spec.id.clone(), level);
        if !self.cpu.contains_key(&key) {
            self.lazy_fills += 1;
            let d = spec.domain(level);
            let stats = run_cpu_spec(&self.cfg, spec, &d, self.opts.steps);
            self.cpu.insert(key.clone(), stats);
        }
        &self.cpu[&key]
    }

    pub fn ablation(&mut self, spec: &KernelSpec, level: SizeClass) -> AblationPoint {
        let key = (spec.id.clone(), level);
        if let Some(p) = self.ablation.get(&key) {
            return *p;
        }
        self.lazy_fills += 1;
        let d = spec.domain(level);
        let steps = self.opts.steps;
        let spu_threads = self.opts.spu_threads;
        let t_block = self.opts.temporal_block;
        let mut near_l1 = self.cfg.clone();
        near_l1.placement = SpuPlacement::NearL1;
        near_l1.mapping = MappingPolicy::Baseline;
        let a = run_casper_cell(&near_l1, spec, &d, steps, spu_threads, t_block)
            .unwrap_or_else(|e| panic!("casper run failed: {e}"))
            .cycles;
        let mut near_l1_mapped = near_l1.clone();
        near_l1_mapped.mapping = MappingPolicy::StencilSegment;
        let b = run_casper_cell(&near_l1_mapped, spec, &d, steps, spu_threads, t_block)
            .unwrap_or_else(|e| panic!("casper run failed: {e}"))
            .cycles;
        let full = self.casper(spec, level).cycles;
        let p = AblationPoint { near_l1_base: a, near_l1_mapped: b, full };
        self.ablation.insert(key, p);
        p
    }
}

/// Emit one cell-identity event (`scheduled` / `cached`) when telemetry
/// is on; `index` is the cell's position in the supervised work list.
fn emit_cell(
    events: Option<&EventSink>,
    kind: &str,
    cell: CellKind,
    id: &str,
    level: SizeClass,
    index: Option<usize>,
) {
    if let Some(sink) = events {
        let mut ev = Event::new(kind)
            .str("engine", cell.name())
            .str("kernel", id)
            .str("class", level.name());
        if let Some(i) = index {
            ev = ev.num("cell", i as u64);
        }
        sink.emit(ev);
    }
}

/// The `result` event for a completed cell: the Casper variant carries
/// the run digest (the same 16-hex identity the journal records), so a
/// log reader can audit determinism without parsing the journal.
fn result_event(kind: CellKind, id: &str, level: SizeClass, out: &CellOut) -> Event {
    let ev = Event::new("result")
        .str("engine", kind.name())
        .str("kernel", id)
        .str("class", level.name());
    match out {
        CellOut::Casper(stats) => ev.digest("digest", stats.digest()).num("cycles", stats.cycles),
        CellOut::Cpu(stats) => ev.num("cycles", stats.cycles),
        CellOut::Ablation(a, b) => ev.num("near_l1_base", *a).num("near_l1_mapped", *b),
    }
}

/// Build the journal record for a finished cell.
fn record_of(cell: &Cell, out: &CellOut) -> Record {
    match (cell, out) {
        (Cell::Casper(spec, level), CellOut::Casper(stats)) => Record::Casper {
            id: spec.id.to_string(),
            level: *level,
            digest: stats.digest(),
            stats: stats.clone(),
        },
        (Cell::Cpu(spec, level), CellOut::Cpu(stats)) => {
            Record::Cpu { id: spec.id.to_string(), level: *level, stats: stats.clone() }
        }
        (Cell::Ablation(spec, level), CellOut::Ablation(a, b)) => Record::Ablation {
            id: spec.id.to_string(),
            level: *level,
            near_l1_base: *a,
            near_l1_mapped: *b,
        },
        _ => unreachable!("cell/result kind mismatch"),
    }
}

/// One Casper cell, honouring the sweep's intra-run thread setting. The
/// error is a plain string so the supervisor can carry it across the
/// `catch_unwind` boundary and into a [`CellOutcome::Failed`].
fn run_casper_cell(
    cfg: &SimConfig,
    spec: &KernelSpec,
    d: &crate::stencil::Domain,
    steps: usize,
    spu_threads: usize,
    temporal_block: usize,
) -> Result<RunStats, String> {
    let opts = CasperOptions { spu_threads, temporal_block, ..Default::default() };
    run_casper_spec(cfg, spec, d, steps, opts).map_err(|e| format!("{e:#}"))
}

type CellSet = HashSet<(KernelId, SizeClass)>;

/// Exactly which (kernel, class) cells each selected experiment reads —
/// mirrors the builders below, so prefill never simulates a cell a serial
/// run would not have.
fn needed_cells(
    which: &[Experiment],
    opts: SweepOptions,
    kernels: &[Arc<KernelSpec>],
) -> (CellSet, CellSet, CellSet) {
    let mut casper: CellSet = HashSet::new();
    let mut cpu: CellSet = HashSet::new();
    let mut ablation: CellSet = HashSet::new();
    let all = |set: &mut CellSet| {
        for spec in kernels {
            for &level in opts.classes() {
                set.insert((spec.id.clone(), level));
            }
        }
    };
    for e in which {
        match e {
            Experiment::Fig1 => {
                let level = if opts.quick { SizeClass::L2 } else { SizeClass::Llc };
                for spec in kernels {
                    cpu.insert((spec.id.clone(), level));
                }
            }
            Experiment::Fig10 | Experiment::Fig11 | Experiment::Table4 | Experiment::Table6 => {
                all(&mut casper);
                all(&mut cpu);
            }
            Experiment::Fig12 | Experiment::Fig13 | Experiment::Slices | Experiment::Blocked => {
                all(&mut casper)
            }
            Experiment::Fig14 => {
                all(&mut ablation);
                all(&mut casper); // the `full` configuration
            }
            Experiment::Table5 => {
                all(&mut casper);
                all(&mut cpu);
            }
        }
    }
    (casper, cpu, ablation)
}

fn ratio(ours: f64, paper: f64) -> String {
    if paper == 0.0 {
        "-".into()
    } else {
        format!("{:.2}", ours / paper)
    }
}

/// Run a set of experiments over the default (paper six) kernel set.
pub fn run_experiments(
    cfg: &SimConfig,
    which: &[Experiment],
    opts: SweepOptions,
) -> Result<Report> {
    run_experiments_with(cfg, which, opts, &paper_kernels())
}

/// Run a set of experiments over an explicit kernel set — extended
/// presets and TOML-defined kernels sweep exactly like the paper six;
/// paper-reference cells print `-` where the paper has no number.
pub fn run_experiments_with(
    cfg: &SimConfig,
    which: &[Experiment],
    opts: SweepOptions,
    kernels: &[Arc<KernelSpec>],
) -> Result<Report> {
    run_experiments_supervised(cfg, which, opts, kernels, &SupervisorConfig::default())
}

/// Run experiments under an explicit supervisor configuration: panic
/// isolation, deadlines, retry, checkpoint-resume, fault injection.
///
/// With the default configuration this is byte-identical to the
/// pre-supervisor harness at any job count. Under `keep_going`, failed
/// cells become annotated holes in the tables and are listed in
/// [`Report::failures`]; under fail-fast (default) the first terminal
/// cell failure aborts the run with an error naming the cell.
pub fn run_experiments_supervised(
    cfg: &SimConfig,
    which: &[Experiment],
    opts: SweepOptions,
    kernels: &[Arc<KernelSpec>],
    sup: &SupervisorConfig,
) -> Result<Report> {
    run_experiments_telemetry(cfg, which, opts, kernels, sup).map(|(report, _)| report)
}

/// Machine-readable summary of one sweep (`--metrics-out`): what ran,
/// what was loaded from the journal, what failed, and how long the whole
/// sweep took. Serialized by hand — the crate's only dependency stays
/// `anyhow`.
#[derive(Debug, Clone)]
pub struct SweepSummary {
    /// `(experiment id, emitted table rows)` in report order.
    pub experiments: Vec<(String, usize)>,
    pub kernels: usize,
    /// Cells actually simulated (journal-loaded cells are excluded).
    pub executed_cells: usize,
    pub failed_cells: usize,
    pub wall_ms: u64,
    pub jobs: usize,
    pub spu_threads: usize,
    pub temporal_block: usize,
}

impl SweepSummary {
    pub fn to_json(&self) -> String {
        use crate::trace::chrome::escape;
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"kernels\": {},\n", self.kernels));
        s.push_str(&format!("  \"executed_cells\": {},\n", self.executed_cells));
        s.push_str(&format!("  \"failed_cells\": {},\n", self.failed_cells));
        s.push_str(&format!("  \"wall_ms\": {},\n", self.wall_ms));
        s.push_str(&format!("  \"jobs\": {},\n", self.jobs));
        s.push_str(&format!("  \"spu_threads\": {},\n", self.spu_threads));
        s.push_str(&format!("  \"temporal_block\": {},\n", self.temporal_block));
        let rows: Vec<String> = self
            .experiments
            .iter()
            .map(|(id, n)| format!("\"{}\": {n}", escape(id)))
            .collect();
        s.push_str(&format!("  \"experiment_rows\": {{{}}}\n", rows.join(", ")));
        s.push('}');
        s.push('\n');
        s
    }
}

/// [`run_experiments_supervised`] plus the sweep's [`SweepSummary`]. The
/// report is byte-identical to the plain call at any telemetry setting —
/// the summary and event log only *observe* the sweep.
pub fn run_experiments_telemetry(
    cfg: &SimConfig,
    which: &[Experiment],
    opts: SweepOptions,
    kernels: &[Arc<KernelSpec>],
    sup: &SupervisorConfig,
) -> Result<(Report, SweepSummary)> {
    let sweep_start = std::time::Instant::now();
    if which.is_empty() {
        bail!("no experiments selected");
    }
    if kernels.is_empty() {
        bail!("no kernels selected");
    }
    let mut cache = SweepCache::with_supervisor(cfg, opts, kernels, sup)?;
    cache.prefill_checked(which)?;
    if !sup.policy.keep_going {
        if let Some(first) = cache.failures().into_iter().next() {
            bail!(
                "sweep aborted (fail-fast): {first}; completed cells are preserved{} — rerun \
                 with --keep-going to sweep past failures",
                if sup.journal.is_some() { " in the checkpoint journal" } else { "" }
            );
        }
    }
    let mut report = Report::default();
    for e in which {
        let table = match e {
            Experiment::Fig1 => fig1(cfg, &mut cache, opts),
            Experiment::Fig10 => fig10(&mut cache, opts),
            Experiment::Fig11 => fig11(cfg, &mut cache, opts),
            Experiment::Fig12 => fig12(cfg, &mut cache, opts),
            Experiment::Fig13 => fig13(cfg, &mut cache, opts),
            Experiment::Fig14 => fig14(&mut cache, opts),
            Experiment::Table4 => table4(&mut cache, opts),
            Experiment::Table5 => table5(cfg, &mut cache, opts),
            Experiment::Table6 => table6(cfg, &mut cache, opts),
            Experiment::Slices => slices_table(&mut cache, opts),
            Experiment::Blocked => blocked_table(&mut cache, opts),
        };
        report.tables.push(table);
    }
    report.failures = cache.failures();
    let summary = SweepSummary {
        experiments: report.tables.iter().map(|t| (t.id.clone(), t.rows.len())).collect(),
        kernels: kernels.len(),
        executed_cells: cache.executed_cells(),
        failed_cells: report.failures.len(),
        wall_ms: sweep_start.elapsed().as_millis() as u64,
        jobs: opts.jobs,
        spu_threads: opts.spu_threads,
        temporal_block: opts.temporal_block,
    };
    Ok((report, summary))
}

fn fig1(cfg: &SimConfig, cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "fig1",
        Experiment::Fig1.title(),
        &["kernel", "AI (FLOP/B)", "DRAM roof (GF/s)", "L3 roof (GF/s)", "measured (GF/s)", "% of peak"],
    );
    // Measured GFLOPS from the CPU model at the LLC size class (Fig 1's
    // setting), or L2 in quick mode.
    let level = if opts.quick { SizeClass::L2 } else { SizeClass::Llc };
    let freq = cfg.cpu.freq_ghz;
    let failures: Vec<Option<String>> =
        kernels.iter().map(|s| cache.cell_failure(s, level, &[CellKind::Cpu])).collect();
    let measured: Vec<f64> = kernels
        .iter()
        .zip(&failures)
        .map(|(s, f)| if f.is_some() { 0.0 } else { cache.cpu(s, level).gflops(freq) })
        .collect();
    let m = roofline::Machine::of(cfg);
    for (i, p) in roofline::roofline_specs(cfg, &kernels, Some(&measured)).iter().enumerate() {
        if let Some(why) = &failures[i] {
            t.hole(vec![p.name.clone()], why);
            continue;
        }
        t.row(vec![
            p.name.clone(),
            format!("{:.3}", p.ai),
            format!("{:.1}", p.dram_bound / 1e9),
            format!("{:.1}", p.llc_bound / 1e9),
            format!("{:.1}", measured[i]),
            format!("{:.1}%", 100.0 * measured[i] * 1e9 / m.peak_flops),
        ]);
    }
    // Temporal blocking slides the operating point right: T sweeps per
    // DRAM traversal. Companion rows only when the sweep actually runs
    // blocked — the default report stays the paper's six rows.
    if opts.temporal_block > 1 {
        for spec in &kernels {
            let p = roofline::blocked_point(cfg, spec, opts.temporal_block);
            t.row(vec![
                p.name.clone(),
                format!("{:.3}", p.ai),
                format!("{:.1}", p.dram_bound / 1e9),
                format!("{:.1}", p.llc_bound / 1e9),
                "-".into(),
                "-".into(),
            ]);
        }
        t.note(format!(
            "blocked rows: AI folded by T={} (one DRAM traversal feeds T sweeps); the CPU baseline does not run blocked, so no measured value attaches.",
            opts.temporal_block
        ));
    }
    t.note(format!(
        "peak {:.1} GFLOPS; DRAM bw {:.1} GB/s; LLC bw {:.1} GB/s. Paper: all kernels below the L3 line, above the DRAM line, <20% of peak.",
        m.peak_flops / 1e9,
        m.dram_bw / 1e9,
        m.llc_bw / 1e9
    ));
    t
}

fn fig10(cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "fig10",
        Experiment::Fig10.title(),
        &["kernel", "class", "casper cycles", "cpu cycles", "speedup", "paper speedup", "ours/paper"],
    );
    let mut llc_speedups = Vec::new();
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper, CellKind::Cpu]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let c = cache.casper(spec, level).cycles;
            let p = cache.cpu(spec, level).cycles;
            let s = p as f64 / c as f64;
            if level == SizeClass::Llc {
                llc_speedups.push(s);
            }
            let (paper_cell, ratio_cell) =
                match paperdata::paper_speedup_of(spec.id.as_str(), level) {
                    Some(paper) => (format!("{paper:.2}x"), ratio(s, paper)),
                    None => ("-".into(), "-".into()),
                };
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                c.to_string(),
                p.to_string(),
                format!("{s:.2}x"),
                paper_cell,
                ratio_cell,
            ]);
        }
    }
    if !llc_speedups.is_empty() {
        t.note(format!(
            "LLC-class geomean speedup: {:.2}x (paper reports 1.65x average, up to 4.16x)",
            geomean(&llc_speedups)
        ));
    }
    t
}

fn fig11(cfg: &SimConfig, cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "fig11",
        Experiment::Fig11.title(),
        &["kernel", "class", "casper (J)", "cpu (J)", "normalized", "dynamic-only norm."],
    );
    let mut norms = Vec::new();
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper, CellKind::Cpu]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let ce = casper_energy(cfg, cache.casper(spec, level));
            let pe = cpu_energy(cfg, cache.cpu(spec, level));
            let norm = ce.total_j() / pe.total_j();
            if level == SizeClass::Llc {
                norms.push(norm);
            }
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                format!("{:.4e}", ce.total_j()),
                format!("{:.4e}", pe.total_j()),
                format!("{norm:.2}"),
                format!("{:.2}", ce.dynamic_j() / pe.dynamic_j()),
            ]);
        }
    }
    if !norms.is_empty() {
        t.note(format!(
            "LLC-class geomean normalized energy: {:.2} (paper: 0.45 for LLC sets; 0.65 overall)",
            geomean(&norms)
        ));
    }
    t.note("normalized = total system energy (incl. static); dynamic-only column is comparable to the paper's appendix Table 6 — see EXPERIMENTS.md for the Fig 11 vs Table 6 reconciliation.");
    t
}

fn fig12(cfg: &SimConfig, cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let gpu = GpuModel::default();
    let area = CasperArea::of(cfg);
    let mut t = Table::new(
        "fig12",
        Experiment::Fig12.title(),
        &["kernel", "class", "perf vs GPU", "perf/area vs GPU", "paper perf/area basis"],
    );
    let mut improvements = Vec::new();
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let d = spec.domain(level);
            let g = gpu.cycles_spec(cfg, spec, &d, opts.steps);
            let c = cache.casper(spec, level).cycles;
            // Fig 12 compares the 16 SPUs' area against the full die.
            let ppa = perf_per_area_improvement(c, area.spus_mm2, g, gpu.area_mm2);
            improvements.push(ppa);
            let paper_cell = match paperdata::paper_gpu_ratio_of(spec.id.as_str(), level) {
                Some(r) => format!("{:.0}x", (gpu.area_mm2 / area.spus_mm2) / r),
                None => "-".into(),
            };
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                format!("{:.2}x", g as f64 / c as f64),
                format!("{ppa:.0}x"),
                paper_cell,
            ]);
        }
    }
    t.note(format!(
        "16 SPUs = {:.3} mm² vs Titan V {} mm² (349x area ratio). Geomean perf/area improvement: {:.0}x (paper: 37x average, up to 190x).",
        area.spus_mm2,
        gpu.area_mm2,
        geomean(&improvements)
    ));
    t
}

fn fig13(cfg: &SimConfig, cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let pims = PimsModel::default();
    let mut t = Table::new(
        "fig13",
        Experiment::Fig13.title(),
        &["kernel", "class", "casper cycles", "pims cycles", "speedup vs PIMS"],
    );
    let mut on_chip = Vec::new();
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let d = spec.domain(level);
            let p = pims.cycles_spec(cfg, spec, &d, opts.steps);
            let c = cache.casper(spec, level).cycles;
            let s = p as f64 / c as f64;
            if level != SizeClass::Dram {
                on_chip.push(s);
            }
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                c.to_string(),
                p.to_string(),
                format!("{s:.2}x"),
            ]);
        }
    }
    t.note(format!(
        "on-chip (L2+LLC) geomean speedup vs PIMS: {:.2}x (paper: 5.5x average, up to 10x; DRAM-sized sets favour PIMS)",
        geomean(&on_chip)
    ));
    t
}

fn fig14(cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "fig14",
        Experiment::Fig14.title(),
        &["kernel", "class", "near-L1 cycles", "+mapping", "+near-LLC (full)", "mapping %", "near-cache %"],
    );
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) =
                cache.cell_failure(spec, level, &[CellKind::Ablation, CellKind::Casper])
            {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let p = cache.ablation(spec, level);
            // Fig 14 attribution: total speedup from baseline to full is
            // normalized to 100%; the mapping share is the step from the
            // baseline to +mapping, the placement share is the rest.
            let total = p.near_l1_base as f64 - p.full as f64;
            let (map_pct, near_pct) = if total.abs() < 1e-9 {
                (0.0, 0.0)
            } else {
                let m = (p.near_l1_base as f64 - p.near_l1_mapped as f64) / total * 100.0;
                (m, 100.0 - m)
            };
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                p.near_l1_base.to_string(),
                p.near_l1_mapped.to_string(),
                p.full.to_string(),
                format!("{map_pct:.0}%"),
                format!("{near_pct:.0}%"),
            ]);
        }
    }
    t.note("paper: near-cache placement is the major contributor; mapping contributes up to 30% (Jacobi 1D, LLC), negligible or negative in several cases.");
    t
}

fn table4(cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "table4",
        Experiment::Table4.title(),
        &["kernel", "class", "cpu instrs", "paper cpu", "ratio", "casper instrs/SPU", "paper casper", "ratio"],
    );
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper, CellKind::Cpu]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let cpu = cache.cpu(spec, level).instrs;
            let casper = cache.casper(spec, level).per_spu_instrs;
            let (p_cpu, r_cpu) = match paperdata::cpu_instrs_of(spec.id.as_str(), level) {
                Some(v) => (v.to_string(), ratio(cpu as f64, v as f64)),
                None => ("-".into(), "-".into()),
            };
            let (p_casper, r_casper) = match paperdata::casper_instrs_of(spec.id.as_str(), level) {
                Some(v) => (v.to_string(), ratio(casper as f64, v as f64)),
                None => ("-".into(), "-".into()),
            };
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                cpu.to_string(),
                p_cpu,
                r_cpu,
                casper.to_string(),
                p_casper,
                r_casper,
            ]);
        }
    }
    t.note("Casper column is per-SPU dynamic instructions (the paper's Table 4 Casper scale).");
    t
}

fn table5(cfg: &SimConfig, cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let gpu = GpuModel::default();
    let mut t = Table::new(
        "table5",
        Experiment::Table5.title(),
        &["kernel", "class", "cpu", "paper cpu", "gpu", "paper gpu", "casper", "paper casper"],
    );
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper, CellKind::Cpu]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let d = spec.domain(level);
            let id = spec.id.as_str();
            let opt_cell = |v: Option<u64>| v.map_or_else(|| "-".into(), |x| x.to_string());
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                cache.cpu(spec, level).cycles.to_string(),
                opt_cell(paperdata::cpu_cycles_of(id, level)),
                gpu.cycles_spec(cfg, spec, &d, opts.steps).to_string(),
                opt_cell(paperdata::gpu_cycles_of(id, level)),
                cache.casper(spec, level).cycles.to_string(),
                opt_cell(paperdata::casper_cycles_of(id, level)),
            ]);
        }
    }
    t
}

fn table6(cfg: &SimConfig, cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "table6",
        Experiment::Table6.title(),
        &["kernel", "class", "cpu (J)", "paper cpu", "casper (J)", "paper casper"],
    );
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper, CellKind::Cpu]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let id = spec.id.as_str();
            let pe = cpu_energy(cfg, cache.cpu(spec, level));
            let ce = casper_energy(cfg, cache.casper(spec, level));
            let opt_cell = |v: Option<f64>| v.map_or_else(|| "-".into(), |x| format!("{x:.4e}"));
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                format!("{:.4e}", pe.dynamic_j()),
                opt_cell(paperdata::cpu_energy_of(id, level)),
                format!("{:.4e}", ce.dynamic_j()),
                opt_cell(paperdata::casper_energy_of(id, level)),
            ]);
        }
    }
    t.note("dynamic energy only, matching the paper's appendix Table 6 scale.");
    t
}

fn slices_table(cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "slices",
        Experiment::Slices.title(),
        &["kernel", "class", "remote reqs", "remote imbalance", "dram reads", "dram writes", "dram-rd imbalance", "busiest slice", "noc contention", "bw imbalance"],
    );
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let s = cache.casper(spec, level);
            let remote: u64 = s.slice_remote_reqs.iter().sum();
            let dr: u64 = s.slice_dram_reads.iter().sum();
            let dw: u64 = s.slice_dram_writes.iter().sum();
            // `-` when no remote traffic exists: max_by_key would
            // otherwise name the last slice of an all-zero vector.
            let busiest = if remote == 0 {
                "-".to_string()
            } else {
                s.slice_remote_reqs
                    .iter()
                    .enumerate()
                    .max_by_key(|&(_, &v)| v)
                    .map(|(i, _)| i.to_string())
                    .unwrap_or_else(|| "-".to_string())
            };
            let remote_imb = s.remote_req_imbalance();
            let dram_imb = s.dram_read_imbalance();
            let bw_imb = s.bandwidth_imbalance();
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                remote.to_string(),
                format!("{remote_imb:.2}"),
                dr.to_string(),
                dw.to_string(),
                format!("{dram_imb:.2}"),
                busiest,
                s.noc_contention_cycles.to_string(),
                format!("{bw_imb:.2}"),
            ]);
        }
    }
    t.note("per-slice SliceState counters (ROADMAP: NoC/DRAM imbalance studies). Imbalance = busiest slice / mean over all slices (1.00 = even, 0.00 = no traffic of that kind). noc contention = total cycles requests spent queued at mesh injection points; bw imbalance = busiest slice's LLC port grants over the mean (grants x 64 B = slice data bandwidth).");
    t
}

fn blocked_table(cache: &mut SweepCache, opts: SweepOptions) -> Table {
    let kernels = cache.kernels();
    let mut t = Table::new(
        "blocked",
        Experiment::Blocked.title(),
        &["kernel", "class", "T", "passes/step", "avoided fills", "halo recompute cells", "dram reads", "reduction", "last value"],
    );
    for spec in &kernels {
        for &level in opts.classes() {
            if let Some(why) = cache.cell_failure(spec, level, &[CellKind::Casper]) {
                t.hole(vec![spec.name.clone(), level.name().into()], &why);
                continue;
            }
            let s = cache.casper(spec, level);
            let dr: u64 = s.slice_dram_reads.iter().sum();
            let (red, last) = match &s.reduction {
                None => ("-".to_string(), "-".to_string()),
                Some(r) => (
                    r.op.name().to_string(),
                    r.values.last().map_or_else(|| "-".into(), |v| format!("{v:.6e}")),
                ),
            };
            t.row(vec![
                spec.name.clone(),
                level.name().into(),
                s.temporal_block.to_string(),
                s.passes.to_string(),
                s.avoided_fills().to_string(),
                s.halo_recompute_cells.to_string(),
                dr.to_string(),
                red,
                last,
            ]);
        }
    }
    t.note("temporal blocking keeps T wavefronts resident per LLC slice: avoided fills = line installs served from resident wavefront state instead of DRAM; halo recompute cells = analytic count of cells recomputed at chunk cuts instead of re-fetched. At --temporal-block 1 both columns are 0 and dram reads is the unblocked baseline. reduction/last value report the fused stencil+reduce pass (kernels with a `reduction` spec), computed without a second sweep over the grid.");
    t
}

/// Convenience used by the prelude: all experiments, default options.
pub struct ExperimentSet;

impl ExperimentSet {
    pub fn run_all(cfg: &SimConfig, opts: SweepOptions) -> Result<Report> {
        run_experiments(cfg, &Experiment::ALL, opts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::extended_presets;

    #[test]
    fn experiment_parse_roundtrip() {
        for e in Experiment::ALL.into_iter().chain(Experiment::EXTRA) {
            assert_eq!(Experiment::parse(e.id()), Some(e));
        }
        assert_eq!(Experiment::parse("nope"), None);
        assert!(
            !Experiment::ALL.contains(&Experiment::Slices),
            "extras stay out of the default set"
        );
    }

    #[test]
    fn quick_sweep_produces_all_tables() {
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 1, spu_threads: 1, temporal_block: 1 };
        let report = ExperimentSet::run_all(&cfg, opts).unwrap();
        assert_eq!(report.tables.len(), 9);
        // Every experiment id present, every table non-empty.
        for e in Experiment::ALL {
            let t = report.get(e.id()).unwrap_or_else(|| panic!("{} missing", e.id()));
            assert!(!t.rows.is_empty(), "{} empty", e.id());
        }
        // fig10 quick mode: 6 kernels × 1 class.
        assert_eq!(report.get("fig10").unwrap().rows.len(), 6);
    }

    #[test]
    fn empty_selection_errors() {
        let cfg = SimConfig::default();
        assert!(run_experiments(&cfg, &[], SweepOptions::default()).is_err());
        assert!(run_experiments_with(
            &cfg,
            &[Experiment::Fig10],
            SweepOptions::default(),
            &[]
        )
        .is_err());
    }

    #[test]
    fn parallel_sweep_report_is_byte_identical_to_serial() {
        // The acceptance property of the sweep engine: same cells, same
        // order, same bytes — only the wall clock changes.
        let cfg = SimConfig::default();
        let serial = run_experiments(
            &cfg,
            &Experiment::ALL,
            SweepOptions { quick: true, steps: 1, jobs: 1, spu_threads: 1, temporal_block: 1 },
        )
        .unwrap();
        let parallel = run_experiments(
            &cfg,
            &Experiment::ALL,
            SweepOptions { quick: true, steps: 1, jobs: 4, spu_threads: 1, temporal_block: 1 },
        )
        .unwrap();
        assert_eq!(serial.to_markdown(), parallel.to_markdown());
        for (s, p) in serial.tables.iter().zip(&parallel.tables) {
            assert_eq!(s.to_csv(), p.to_csv(), "{}", s.id);
        }
    }

    #[test]
    fn default_kernel_set_is_the_paper_six() {
        // `run_experiments` must stay byte-identical to an explicit
        // paper-six sweep — the registry refactor must not move the
        // default report.
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 1, spu_threads: 1, temporal_block: 1 };
        let default = run_experiments(&cfg, &[Experiment::Fig10], opts).unwrap();
        let explicit =
            run_experiments_with(&cfg, &[Experiment::Fig10], opts, &paper_kernels()).unwrap();
        assert_eq!(default.to_markdown(), explicit.to_markdown());
    }

    #[test]
    fn extended_kernels_extend_the_tables() {
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
        let mut kernels = paper_kernels();
        kernels.extend(extended_presets().into_iter().map(Arc::new));
        let report =
            run_experiments_with(&cfg, &[Experiment::Fig10, Experiment::Table5], opts, &kernels)
                .unwrap();
        let t = report.get("fig10").unwrap();
        assert_eq!(t.rows.len(), 11, "6 paper + 5 extended kernels at 1 class");
        // Paper-reference cells are dashes for the non-paper kernels
        // (including the multi-pass star17_3d and the fused-reduction
        // jacobi2d_res, swept like any other).
        let extended_names = [
            "HDiff 2D",
            "25-point 3D star",
            "17-row 3D star",
            "Jacobi 2D residual",
            "Wide dual-family 2D",
        ];
        for row in &t.rows {
            if extended_names.contains(&row[0].as_str()) {
                assert_eq!(row[5], "-", "{row:?}");
                assert_eq!(row[6], "-", "{row:?}");
            } else {
                assert!(row[5].ends_with('x'), "{row:?}");
            }
        }
        let t5 = report.get("table5").unwrap();
        for row in &t5.rows {
            if row[0] == "HDiff 2D" {
                assert_eq!(row[3], "-");
                assert_eq!(row[5], "-");
                assert_eq!(row[7], "-");
            }
        }
    }

    #[test]
    fn slices_experiment_regenerates() {
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 1, spu_threads: 1, temporal_block: 1 };
        let report = run_experiments(&cfg, &[Experiment::Slices], opts).unwrap();
        let t = report.get("slices").unwrap();
        assert_eq!(t.rows.len(), 6);
        for row in &t.rows {
            let imb: f64 = row[3].parse().unwrap();
            assert!(imb >= 0.0, "{row:?}");
        }
    }

    #[test]
    fn prefill_covers_every_builder_access() {
        // Guard against `needed_cells` drifting from the builders: after a
        // parallel prefill of ALL experiments (+ extras), running every
        // builder must be pure cache hits — zero serial (lazy) fills.
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
        let mut cache = SweepCache::new(&cfg, opts);
        let mut which: Vec<Experiment> = Experiment::ALL.to_vec();
        which.extend(Experiment::EXTRA);
        cache.prefill(&which);
        assert_eq!(cache.lazy_fills, 0, "prefill itself must not fall back to lazy fills");
        let _ = fig1(&cfg, &mut cache, opts);
        let _ = fig10(&mut cache, opts);
        let _ = fig11(&cfg, &mut cache, opts);
        let _ = fig12(&cfg, &mut cache, opts);
        let _ = fig13(&cfg, &mut cache, opts);
        let _ = fig14(&mut cache, opts);
        let _ = table4(&mut cache, opts);
        let _ = table5(&cfg, &mut cache, opts);
        let _ = table6(&cfg, &mut cache, opts);
        let _ = slices_table(&mut cache, opts);
        let _ = blocked_table(&mut cache, opts);
        assert_eq!(
            cache.lazy_fills, 0,
            "a builder read a cell needed_cells() did not prefill — keep them in sync"
        );
    }

    #[test]
    fn injected_panic_under_keep_going_leaves_survivors_intact() {
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
        let clean = run_experiments(&cfg, &[Experiment::Fig10], opts).unwrap();
        // Cell 0 of the fig10 work list is Casper kernel-0 @ L2 (cells are
        // kernel-major, Casper before Cpu within a (kernel, class)).
        let sup = SupervisorConfig {
            policy: SupervisorPolicy {
                keep_going: true,
                faults: Some(FaultPlan {
                    seed: 1,
                    rate: 0.0,
                    kind: FaultKind::Panic,
                    cells: Some(vec![0]),
                    delay_ms: 0,
                }),
                ..SupervisorPolicy::default()
            },
            journal: None,
        };
        let faulty =
            run_experiments_supervised(&cfg, &[Experiment::Fig10], opts, &paper_kernels(), &sup)
                .unwrap();
        assert_eq!(faulty.failures.len(), 1, "{:?}", faulty.failures);
        assert_eq!(faulty.failures[0].kind, "casper");
        let ft = faulty.get("fig10").unwrap();
        let ct = clean.get("fig10").unwrap();
        assert_eq!(ft.rows.len(), ct.rows.len(), "no row lost to the fault");
        assert!(ft.rows[0][2].starts_with("FAILED:"), "{:?}", ft.rows[0]);
        for (f, c) in ft.rows.iter().zip(&ct.rows).skip(1) {
            assert_eq!(f, c, "survivor rows must be bitwise equal to the clean run");
        }
        assert!(faulty.to_markdown().contains("### failed cells"));
    }

    #[test]
    fn fail_fast_aborts_naming_the_cell() {
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
        let sup = SupervisorConfig {
            policy: SupervisorPolicy {
                faults: Some(FaultPlan {
                    seed: 1,
                    rate: 0.0,
                    kind: FaultKind::Panic,
                    cells: Some(vec![0]),
                    delay_ms: 0,
                }),
                ..SupervisorPolicy::default()
            },
            journal: None,
        };
        let err =
            run_experiments_supervised(&cfg, &[Experiment::Fig10], opts, &paper_kernels(), &sup)
                .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fail-fast"), "{msg}");
        assert!(msg.contains("casper"), "{msg}");
    }

    #[test]
    fn telemetry_observes_without_moving_the_report() {
        let cfg = SimConfig::default();
        let opts = SweepOptions { quick: true, steps: 1, jobs: 2, spu_threads: 1, temporal_block: 1 };
        let plain = run_experiments(&cfg, &[Experiment::Fig10], opts).unwrap();

        let dir = std::env::temp_dir().join(format!("casper-harness-ev-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let sup = SupervisorConfig {
            policy: SupervisorPolicy {
                events: Some(EventSink::create(&path).unwrap()),
                ..SupervisorPolicy::default()
            },
            journal: None,
        };
        let (report, summary) =
            run_experiments_telemetry(&cfg, &[Experiment::Fig10], opts, &paper_kernels(), &sup)
                .unwrap();
        assert_eq!(plain.to_markdown(), report.to_markdown(), "telemetry only observes");

        // fig10 quick: 6 kernels × (casper + cpu) at one class.
        assert_eq!(summary.executed_cells, 12);
        assert_eq!(summary.failed_cells, 0);
        assert_eq!(summary.kernels, 6);
        let json = summary.to_json();
        crate::trace::chrome::validate_json(&json).unwrap();
        assert!(json.contains("\"fig10\": 6"), "{json}");

        let text = std::fs::read_to_string(&path).unwrap();
        for line in text.lines() {
            crate::trace::chrome::validate_json(line).unwrap();
        }
        for kind in ["scheduled", "started", "finished", "result"] {
            let tag = format!("\"event\":\"{kind}\"");
            assert!(text.contains(&tag), "no {kind} events in:\n{text}");
        }
        assert!(text.contains("\"digest\":\""), "casper results must carry the digest");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn needed_cells_are_minimal_for_fig1() {
        let opts = SweepOptions { quick: true, steps: 1, jobs: 4, spu_threads: 1, temporal_block: 1 };
        let kernels = paper_kernels();
        let (casper, cpu, abl) = needed_cells(&[Experiment::Fig1], opts, &kernels);
        assert!(casper.is_empty());
        assert!(abl.is_empty());
        assert_eq!(cpu.len(), kernels.len());
        assert!(cpu.iter().all(|(_, l)| *l == SizeClass::L2));
    }

    #[test]
    fn blocked_sweep_reports_avoided_traffic_and_reductions() {
        let cfg = SimConfig::default();
        let base = SweepOptions { quick: true, steps: 4, jobs: 1, spu_threads: 1, temporal_block: 1 };
        let blocked = SweepOptions { temporal_block: 2, ..base };
        let mut kernels = paper_kernels();
        kernels.extend(extended_presets().into_iter().map(Arc::new));

        let rb = run_experiments_with(&cfg, &[Experiment::Blocked], base, &kernels).unwrap();
        let tb = rb.get("blocked").unwrap();
        assert_eq!(tb.rows.len(), kernels.len());
        for row in &tb.rows {
            assert_eq!(row[2], "1", "{row:?}");
            assert_eq!(row[4], "0", "T=1 avoids nothing: {row:?}");
            assert_eq!(row[5], "0", "T=1 recomputes nothing: {row:?}");
            if row[0] == "Jacobi 2D residual" {
                assert_eq!(row[7], "abs_diff", "{row:?}");
                assert_ne!(row[8], "-", "fused residual must report a value: {row:?}");
            } else {
                assert_eq!(row[7], "-", "{row:?}");
            }
        }

        let r2 = run_experiments_with(&cfg, &[Experiment::Blocked], blocked, &kernels).unwrap();
        let t2 = r2.get("blocked").unwrap();
        for (b, u) in t2.rows.iter().zip(&tb.rows) {
            assert_eq!(b[2], "2", "{b:?}");
            let avoided: u64 = b[4].parse().unwrap();
            assert!(avoided > 0, "T=2 must avoid fills: {b:?}");
            // At the quick (L2) class the working set already fits in the
            // LLC, so reads can only tie; the coordinator engine test pins
            // the strict >=2x drop on an LLC-pressure domain.
            let (dr2, dr1): (u64, u64) = (b[6].parse().unwrap(), u[6].parse().unwrap());
            assert!(dr2 <= dr1, "blocked DRAM reads must not grow: {dr2} vs {dr1} in {b:?}");
            // The fused reduction is functional, so its value is bitwise
            // stable under blocking.
            assert_eq!(b[8], u[8], "{b:?}");
        }
    }

    #[test]
    fn blocked_sweep_adds_fig1_companion_rows_only_above_t1() {
        let cfg = SimConfig::default();
        let base = SweepOptions { quick: true, steps: 1, jobs: 1, spu_threads: 1, temporal_block: 1 };
        let plain = run_experiments(&cfg, &[Experiment::Fig1], base).unwrap();
        let pt = plain.get("fig1").unwrap();
        assert_eq!(pt.rows.len(), 6, "default Fig 1 stays the paper's six rows");

        let blocked = run_experiments(
            &cfg,
            &[Experiment::Fig1],
            SweepOptions { temporal_block: 4, ..base },
        )
        .unwrap();
        let bt = blocked.get("fig1").unwrap();
        assert_eq!(bt.rows.len(), 12, "six kernels + six blocked companion points");
        for (p, b) in pt.rows.iter().zip(bt.rows.iter().skip(6)) {
            assert!(b[0].starts_with(p[0].as_str()) && b[0].ends_with("(T=4)"), "{b:?}");
            let (ai_p, ai_b): (f64, f64) = (p[1].parse().unwrap(), b[1].parse().unwrap());
            // 3e-3 tolerance: both sides are parsed back from 3-decimal
            // table cells, so rounding error stacks up to ~2.5e-3.
            assert!((ai_b - 4.0 * ai_p).abs() < 3e-3, "AI slides right 4x: {ai_p} -> {ai_b}");
            assert_eq!(b[4], "-", "no measured value for blocked points: {b:?}");
        }
        // The unblocked half is byte-identical to the plain table rows.
        for (p, b) in pt.rows.iter().zip(bt.rows.iter()) {
            assert_eq!(p, b);
        }
    }
}
