//! The baseline multi-core CPU model (Table 2): 16 out-of-order cores,
//! 8-wide, one 512-bit SIMD unit each, running the multithreaded +
//! vectorized stencil over the shared memory hierarchy.
//!
//! The model is trace-driven with an interval timing model (see DESIGN.md
//! §5): each core walks its partition of the grid in 8-element vector
//! iterations; every distinct cache line the iteration touches goes
//! through the full L1/L2/LLC/DRAM hierarchy (shared with prefetchers and
//! slice-port contention), and per-iteration time is
//! `max(instrs/width, exposed-miss-latency / MLP)` — the standard interval
//! approximation of an out-of-order core. Dynamic instruction counts
//! follow the Fig 4 accounting exactly (unaligned vector loads cost two
//! line accesses and two load µops).

use crate::config::SimConfig;
use crate::mem::hierarchy::{CpuHierarchy, MemEvents};
use crate::mapping::SliceMapper;
use crate::stencil::{Domain, KernelSpec, StencilDesc, StencilKind};

/// Outcome of a baseline-CPU run.
#[derive(Debug, Clone)]
pub struct CpuRunStats {
    /// End-to-end cycles (slowest core).
    pub cycles: u64,
    /// Total dynamic instructions, all cores (Table 4's CPU column).
    pub instrs: u64,
    /// FP operations executed (MACs × 2).
    pub flops: u64,
    pub mem: MemEvents,
    /// Per-core cycle counts (load balance diagnostics).
    pub per_core_cycles: Vec<u64>,
}

impl CpuRunStats {
    /// Achieved GFLOPS at the configured clock.
    pub fn gflops(&self, freq_ghz: f64) -> f64 {
        if self.cycles == 0 {
            return 0.0;
        }
        self.flops as f64 / (self.cycles as f64 / (freq_ghz * 1e9)) / 1e9
    }
}

/// Options for CPU runs.
#[derive(Debug, Clone, Copy)]
pub struct CpuOptions {
    /// Run the access trace once untimed to warm the caches (default
    /// true; matches the paper's LLC-resident working sets).
    pub warm: bool,
    /// OoO memory-level parallelism bound. Defaults to the L1 MSHR count
    /// (what actually bounds outstanding same-core misses in Table 2).
    pub effective_mlp: u64,
    /// Latency (cycles) the OoO window hides entirely (≈ L2 hit).
    pub hidden_latency: u64,
}

impl Default for CpuOptions {
    fn default() -> Self {
        CpuOptions { warm: true, effective_mlp: 16, hidden_latency: 12 }
    }
}

/// Vector-iteration descriptor derived from a stencil: how many
/// instructions and which relative line offsets one 8-wide iteration
/// touches (Fig 4 accounting).
#[derive(Debug, Clone)]
pub struct IterShape {
    /// Dynamic instructions per vector iteration: loads (2 per unaligned
    /// tap, 1 per aligned) + MACs + store + RFO-free overhead (address
    /// generation + loop control ≈ 2).
    pub instrs: u64,
    /// SIMD MAC µops per iteration (= taps); the single 512-bit unit
    /// (Table 2) retires one per cycle — the real issue floor for
    /// compute-heavy kernels.
    pub simd_macs: u64,
    /// Load µops per iteration (unaligned = 2); two L1 load ports.
    pub load_uops: u64,
    /// FLOPs per iteration.
    pub flops: u64,
    /// Per-tap element offsets relative to the iteration's first output
    /// element (input array).
    pub tap_offsets: Vec<i64>,
}

impl IterShape {
    pub fn of(desc: &StencilDesc, domain: &Domain, lanes: usize) -> IterShape {
        let nx = domain.nx as i64;
        let nxy = (domain.nx * domain.ny) as i64;
        let mut instrs = 0u64;
        let mut load_uops = 0u64;
        let mut tap_offsets = Vec::with_capacity(desc.points.len());
        for p in &desc.points {
            // A vector load of lanes×8 B at element offset dx: aligned iff
            // dx is a multiple of the vector width *and* the base is —
            // statically, only dx ≡ 0 (mod lanes) can stay aligned; any
            // other offset is an unaligned load = 2 line touches (Fig 4).
            let unaligned = p.dx.rem_euclid(lanes as i64) != 0;
            let uops = if unaligned { 2 } else { 1 };
            instrs += uops;
            load_uops += uops;
            tap_offsets.push(p.dx + p.dy * nx + p.dz * nxy);
        }
        let simd_macs = desc.points.len() as u64;
        instrs += simd_macs; // MACs
        instrs += 1; // vector store
        instrs += 2; // loop + address bookkeeping
        IterShape {
            instrs,
            simd_macs,
            load_uops,
            flops: (desc.points.len() * 2 * lanes) as u64,
            tap_offsets,
        }
    }
}

/// One strip of work: an x-range of one interior row.
pub type Strip = (usize, usize, usize, usize); // (z, y, x_start, x_end)

/// Partition the interior over cores: contiguous blocks of (z, y) rows —
/// the OpenMP-static schedule of the paper's multithreaded kernels. 1D
/// grids (a single row) split along x instead so all cores participate.
fn partition_strips(desc: &StencilDesc, domain: &Domain, cores: usize) -> Vec<Vec<Strip>> {
    let [rx, ry, rz] = desc.radius();
    let mut rows = Vec::new();
    for z in rz..domain.nz - rz {
        for y in ry..domain.ny - ry {
            rows.push((z, y));
        }
    }
    if rows.len() >= cores {
        let per = rows.len().div_ceil(cores);
        return (0..cores)
            .map(|c| {
                rows.iter()
                    .copied()
                    .skip(c * per)
                    .take(per)
                    .map(|(z, y)| (z, y, rx, domain.nx - rx))
                    .collect()
            })
            .collect();
    }
    // Few rows (1D / small 2D): split each row's x-range across the cores
    // that remain, vector-width-aligned.
    let mut parts: Vec<Vec<Strip>> = vec![Vec::new(); cores];
    let per_row = cores / rows.len().max(1);
    for (i, (z, y)) in rows.iter().enumerate() {
        let x0 = rx;
        let x1 = domain.nx - rx;
        let n = x1 - x0;
        let chunk = (n.div_ceil(per_row.max(1)) + 7) & !7;
        for k in 0..per_row.max(1) {
            let s = x0 + k * chunk;
            if s >= x1 {
                break;
            }
            let e = (s + chunk).min(x1);
            parts[i * per_row + k].push((*z, *y, s, e));
        }
    }
    parts
}

/// Run a preset stencil on the baseline CPU model.
pub fn run_cpu(cfg: &SimConfig, kind: StencilKind, domain: &Domain, steps: usize) -> CpuRunStats {
    run_cpu_with(cfg, kind, domain, steps, CpuOptions::default())
}

pub fn run_cpu_with(
    cfg: &SimConfig,
    kind: StencilKind,
    domain: &Domain,
    steps: usize,
    opts: CpuOptions,
) -> CpuRunStats {
    run_cpu_spec_with(cfg, &kind.spec(), domain, steps, opts)
}

/// Spec-driven primary entry point: run any [`KernelSpec`] on the
/// baseline CPU model.
pub fn run_cpu_spec(
    cfg: &SimConfig,
    spec: &KernelSpec,
    domain: &Domain,
    steps: usize,
) -> CpuRunStats {
    run_cpu_spec_with(cfg, spec, domain, steps, CpuOptions::default())
}

pub fn run_cpu_spec_with(
    cfg: &SimConfig,
    desc: &KernelSpec,
    domain: &Domain,
    steps: usize,
    opts: CpuOptions,
) -> CpuRunStats {
    // The CPU baseline uses the conventional address mapping (§4.2).
    let mapper = SliceMapper::new(&cfg.llc, crate::config::MappingPolicy::Baseline);
    let mut hier = CpuHierarchy::new(cfg, mapper);

    // Array placement mirrors the Casper segment layout (contiguous A then
    // B) without any remapping.
    let a_base = 0x1000_0000u64;
    let array_bytes = domain.array_bytes() as u64;
    let b_base = a_base + array_bytes.next_multiple_of(2 << 20);

    let lanes = cfg.cpu.simd_lanes();
    let shape = IterShape::of(desc, domain, lanes);
    let parts = partition_strips(desc, domain, cfg.cpu.cores);

    if opts.warm {
        run_trace(cfg, &mut hier, &shape, &parts, domain, a_base, b_base, &opts, true, 1);
        hier.reset_stats(); // clear counters; keep tags warm
    }

    let (cycles, per_core_cycles, instrs, flops) = run_trace(
        cfg, &mut hier, &shape, &parts, domain, a_base, b_base, &opts, false, steps,
    );

    CpuRunStats { cycles, instrs, flops, mem: hier.events(), per_core_cycles }
}

/// Insertion sort + dedup for small, nearly-sorted line lists.
#[inline]
fn insertion_sort_dedup(v: &mut Vec<u64>) {
    for i in 1..v.len() {
        let x = v[i];
        let mut j = i;
        while j > 0 && v[j - 1] > x {
            v[j] = v[j - 1];
            j -= 1;
        }
        v[j] = x;
    }
    v.dedup();
}

/// Drive the per-core traces; returns (max_cycles, per-core, instrs, flops).
#[allow(clippy::too_many_arguments)]
fn run_trace(
    cfg: &SimConfig,
    hier: &mut CpuHierarchy,
    shape: &IterShape,
    parts: &[Vec<Strip>],
    domain: &Domain,
    a_base: u64,
    b_base: u64,
    opts: &CpuOptions,
    untimed: bool,
    steps: usize,
) -> (u64, Vec<u64>, u64, u64) {
    let lanes = cfg.cpu.simd_lanes();
    let cores = cfg.cpu.cores;
    let line = cfg.l1.line_bytes as u64;
    let width = cfg.cpu.issue_width as u64;
    // L1 fill port: one incoming 64 B line per `fill_cycles` — this is
    // what actually bounds streaming kernels on real cores (the paper's
    // CPU numbers are ~10× above the issue bound). Calibrated against the
    // Table 5 CPU column (see EXPERIMENTS.md).
    let fill_cycles = 6u64;
    // DRAM bandwidth feedback: a line consumed from DRAM costs the chip
    // `burst/channels` cycles of bus time; with all cores streaming, each
    // core's fair share makes that `burst × cores / channels` per line.
    let dram_line_cycles = ((cfg.llc.line_bytes as f64 / cfg.dram.bytes_per_cycle_per_channel)
        .ceil() as u64)
        * cores as u64
        / cfg.dram.channels as u64;

    let mut now = vec![0u64; cores];
    let mut instrs = 0u64;
    let mut flops = 0u64;

    // Iterator state per core: (strip_idx, x).
    let mut strip_idx = vec![0usize; cores];
    let mut xpos: Vec<usize> = parts.iter().map(|p| p.first().map_or(0, |s| s.2)).collect();
    let mut line_buf: Vec<u64> = Vec::with_capacity(80);

    for step in 0..steps {
        // Ping-pong arrays per step.
        let (src, dst) = if step % 2 == 0 { (a_base, b_base) } else { (b_base, a_base) };
        for c in 0..cores {
            strip_idx[c] = 0;
            xpos[c] = parts[c].first().map_or(0, |s| s.2);
        }
        // Round-robin: one vector iteration per core per round, so slice
        // ports and DRAM channels interleave fairly.
        loop {
            let mut progress = false;
            for core in 0..cores {
                let strips = &parts[core];
                if strip_idx[core] >= strips.len() {
                    continue;
                }
                progress = true;
                let (z, y, _x0, x_end) = strips[strip_idx[core]];
                let x = xpos[core];
                let e0 = ((z * domain.ny + y) * domain.nx + x) as i64;

                // Collect the distinct lines this iteration touches.
                line_buf.clear();
                for &off in &shape.tap_offsets {
                    let lo = src + ((e0 + off) as u64) * 8;
                    let hi = lo + (lanes as u64 - 1) * 8;
                    let (l0, l1) = (lo & !(line - 1), hi & !(line - 1));
                    line_buf.push(l0);
                    if l1 != l0 {
                        line_buf.push(l1);
                    }
                }
                // Taps are emitted in (dz, dy, dx) order, so the line list
                // is nearly sorted — insertion sort beats quicksort here
                // (§Perf: the sort was ~7% of simulator time).
                insertion_sort_dedup(&mut line_buf);

                let t = now[core];
                let mut exposed = 0u64;
                let mut fills = 0u64;
                let dram_before = hier.dram.accesses;
                for (i, &la) in line_buf.iter().enumerate() {
                    let acc = hier.access(core, la, false, (i % 16) as u64 * 131 + 7, t);
                    exposed += acc.latency.saturating_sub(opts.hidden_latency);
                    fills += acc.l1_fill as u64;
                }
                // The output store (+ write-allocate fill).
                let saddr = dst + e0 as u64 * 8;
                let acc = hier.access(core, saddr & !(line - 1), true, 999, t);
                exposed += acc.latency.saturating_sub(opts.hidden_latency);
                fills += acc.l1_fill as u64;
                let dram_lines = hier.dram.accesses - dram_before;

                if !untimed {
                    // Issue floor: front-end width, the single SIMD MAC
                    // unit, and the two L1 load ports (Table 2).
                    let issue = shape
                        .instrs
                        .div_ceil(width)
                        .max(shape.simd_macs)
                        .max(shape.load_uops.div_ceil(2));
                    let stall = exposed / opts.effective_mlp;
                    let fill = fills * fill_cycles;
                    // DRAM lines this core caused (demand or prefetch)
                    // consume its share of the shared memory bus.
                    let dram_bw = dram_lines * dram_line_cycles;
                    now[core] = t + issue.max(stall).max(fill).max(dram_bw).max(1);
                }
                instrs += shape.instrs;
                flops += shape.flops;

                // Advance the iterator.
                let next_x = x + lanes;
                if next_x >= x_end {
                    strip_idx[core] += 1;
                    xpos[core] = strips
                        .get(strip_idx[core])
                        .map_or(0, |s| s.2);
                } else {
                    xpos[core] = next_x;
                }
            }
            if !progress {
                break;
            }
        }
    }
    let max = now.iter().copied().max().unwrap_or(0);
    (max, now, instrs, flops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SizeClass;

    #[test]
    fn iter_shape_matches_fig4() {
        // Jacobi 1D (taps −1, 0, +1): 0 is aligned (1 load), ±1 unaligned
        // (2 each) → 5 loads + 3 MAC + 1 store + 2 overhead = 11.
        let d = Domain::tiny(StencilKind::Jacobi1D);
        let s = IterShape::of(&StencilKind::Jacobi1D.descriptor(), &d, 8);
        assert_eq!(s.instrs, 5 + 3 + 1 + 2);
        assert_eq!(s.flops, 3 * 2 * 8);
        // 7-point 1D taps are −3..3; only 0 is aligned → 13 loads.
        let s = IterShape::of(&StencilKind::Points7_1D.descriptor(), &d, 8);
        assert_eq!(s.instrs, 13 + 7 + 1 + 2);
    }

    #[test]
    fn partition_covers_all_rows() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::for_level(kind, SizeClass::L2);
        let parts = partition_strips(&kind.descriptor(), &d, cfg.cpu.cores);
        let total: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(total, d.ny - 2);
        // Static schedule: difference between core loads ≤ ceil.
        let max = parts.iter().map(|p| p.len()).max().unwrap();
        let min = parts.iter().map(|p| p.len()).min().unwrap();
        assert!(max - min <= max.div_ceil(cfg.cpu.cores - 1).max(16));
    }

    #[test]
    fn one_dimensional_grids_use_all_cores() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi1D;
        let d = Domain::for_level(kind, SizeClass::L2);
        let parts = partition_strips(&kind.descriptor(), &d, cfg.cpu.cores);
        let active = parts.iter().filter(|p| !p.is_empty()).count();
        assert_eq!(active, cfg.cpu.cores);
        // Full coverage of the interior.
        let total: usize = parts
            .iter()
            .flat_map(|p| p.iter().map(|&(_, _, s, e)| e - s))
            .sum();
        assert_eq!(total, d.nx - 2);
    }

    #[test]
    fn cpu_run_produces_sane_counts() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi1D;
        let d = Domain::tiny(kind); // 256 points
        let stats = run_cpu(&cfg, kind, &d, 1);
        assert!(stats.cycles > 0);
        // 254 interior / 8 lanes ≈ 32 iterations × 11 instrs ≈ 350.
        assert!(stats.instrs > 200 && stats.instrs < 800, "{}", stats.instrs);
        assert!(stats.flops > 0);
    }

    #[test]
    fn llc_sized_run_is_llc_bound_not_dram_bound() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::for_level(kind, SizeClass::Llc);
        let stats = run_cpu(&cfg, kind, &d, 1);
        // Warm LLC: the kernel's demand misses mostly hit in the LLC,
        // so DRAM traffic is a small fraction of LLC traffic.
        assert!(
            (stats.mem.dram_accesses as f64) < 0.35 * stats.mem.llc.accesses() as f64,
            "dram={} llc={}",
            stats.mem.dram_accesses,
            stats.mem.llc.accesses()
        );
    }

    #[test]
    fn dram_sized_run_touches_dram_heavily() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::for_level(kind, SizeClass::Dram);
        let stats = run_cpu(&cfg, kind, &d, 1);
        // 2048² working set (64 MB) cannot live in the 32 MB LLC.
        assert!(stats.mem.dram_accesses > 100_000, "{}", stats.mem.dram_accesses);
    }

    #[test]
    fn more_steps_cost_more_cycles() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi1D;
        let d = Domain::tiny(kind);
        let one = run_cpu(&cfg, kind, &d, 1);
        let three = run_cpu(&cfg, kind, &d, 3);
        assert!(three.cycles > one.cycles);
        assert_eq!(three.instrs, one.instrs * 3);
    }

    #[test]
    fn instr_count_scale_matches_table4_order() {
        // Table 4: Jacobi 1D LLC ≈ 1.31M CPU instructions. Our Fig-4
        // accounting gives 1M/8 × 11 ≈ 1.44M — same order, within 15%.
        let cfg = SimConfig::default();
        let d = Domain::for_level(StencilKind::Jacobi1D, SizeClass::Llc);
        let stats = run_cpu(&cfg, StencilKind::Jacobi1D, &d, 1);
        let paper = 1_312_867f64;
        let ratio = stats.instrs as f64 / paper;
        assert!(ratio > 0.7 && ratio < 1.4, "instrs {} vs paper {paper}", stats.instrs);
    }
}
