//! Minimal property-based testing driver.
//!
//! The offline registry has no `proptest`/`quickcheck`, so this module
//! provides the subset we need: run a property over many random inputs
//! drawn from a deterministic generator, and on failure report the seed and
//! a greedily-shrunk counterexample. Used by the ISA, mapping, cache, and
//! coordinator invariant tests.

use crate::util::SplitMix64;

/// Number of cases per property (kept modest so `cargo test` stays fast).
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` inputs produced by `gen`. Panics with the seed
/// and the failing input's `Debug` rendering on the first failure.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> bool,
{
    // Fixed master seed: failures are reproducible across runs. Each case
    // gets its own sub-seed so a failing case can be re-run in isolation.
    let mut master = SplitMix64::new(0xCA5_9E12);
    for case in 0..cases {
        let seed = master.next_u64();
        let mut rng = SplitMix64::new(seed);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  input = {input:?}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` with a reason, which is
/// included in the panic message.
pub fn check_result<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug + Clone,
    G: FnMut(&mut SplitMix64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut master = SplitMix64::new(0xCA5_9E12);
    for case in 0..cases {
        let seed = master.next_u64();
        let mut rng = SplitMix64::new(seed);
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  input = {input:?}\n  reason: {reason}"
            );
        }
    }
}

/// Greedy shrinking for `Vec`-shaped inputs: repeatedly try removing halves
/// then single elements while the property still fails, returning a minimal
/// failing vector. Use from a test when a smaller reproducer is wanted.
pub fn shrink_vec<T: Clone, P>(mut input: Vec<T>, mut fails: P) -> Vec<T>
where
    P: FnMut(&[T]) -> bool,
{
    debug_assert!(fails(&input));
    loop {
        let mut shrunk = false;
        // Try removing chunks, largest first.
        let mut chunk = input.len() / 2;
        while chunk >= 1 {
            let mut start = 0;
            while start + chunk <= input.len() {
                let mut candidate = input.clone();
                candidate.drain(start..start + chunk);
                if !candidate.is_empty() && fails(&candidate) {
                    input = candidate;
                    shrunk = true;
                    // restart at this chunk size
                } else {
                    start += chunk;
                }
            }
            chunk /= 2;
        }
        if !shrunk {
            return input;
        }
    }
}

/// Assert two f64 slices match within `atol + rtol*|b|`, reporting the worst
/// mismatching index. The same tolerance contract as numpy's `allclose`.
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64) {
    assert_eq!(a.len(), b.len(), "length mismatch: {} vs {}", a.len(), b.len());
    let mut worst = (0usize, 0.0f64);
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let err = (x - y).abs();
        let tol = atol + rtol * y.abs();
        if err > tol && err > worst.1 {
            worst = (i, err);
        }
    }
    if worst.1 > 0.0 {
        let i = worst.0;
        panic!(
            "allclose failed: idx {} a={} b={} |err|={} (rtol={rtol}, atol={atol})",
            i, a[i], b[i], worst.1
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_for_tautology() {
        check("tautology", 64, |r| r.next_u64(), |_| true);
    }

    #[test]
    #[should_panic(expected = "property 'falsum' failed")]
    fn check_reports_failure() {
        check("falsum", 64, |r| r.next_u64(), |&x| x % 2 == 0 && x % 2 == 1);
    }

    #[test]
    fn shrink_finds_small_case() {
        // Property fails iff the vec contains a 7.
        let input = vec![1, 2, 7, 3, 4, 7, 5];
        let min = shrink_vec(input, |v| v.contains(&7));
        assert_eq!(min, vec![7]);
    }

    #[test]
    fn allclose_accepts_equal() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9, 1e-9);
    }

    #[test]
    #[should_panic(expected = "allclose failed")]
    fn allclose_rejects_differing() {
        assert_allclose(&[1.0], &[1.1], 1e-9, 1e-9);
    }
}
