//! Summary statistics used by the benchmark harness and reports.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean; 0.0 for an empty slice. All values must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let log_sum: f64 = xs.iter().map(|x| x.ln()).sum();
    (log_sum / xs.len() as f64).exp()
}

/// Median (by sorting a copy); 0.0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation — robust spread estimate for bench reports.
pub fn median_abs_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// A compact five-number-ish summary of a sample set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub mad: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        if xs.is_empty() {
            min = 0.0;
            max = 0.0;
        }
        Summary {
            n: xs.len(),
            mean: mean(xs),
            median: median(xs),
            mad: median_abs_dev(xs),
            min,
            max,
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} median={:.3} mad={:.3} mean={:.3} min={:.3} max={:.3}",
            self.n, self.median, self.mad, self.mean, self.min, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_simple() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn geomean_simple() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn mad_constant_is_zero() {
        assert_eq!(median_abs_dev(&[5.0, 5.0, 5.0]), 0.0);
    }

    #[test]
    fn summary_minmax() {
        let s = Summary::of(&[2.0, -1.0, 7.0]);
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 7.0);
        assert_eq!(s.n, 3);
    }
}
