//! Human-friendly formatting helpers for reports and CLI output.

/// Format a byte count with binary suffixes (`4.0 MiB`).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a large count with thousands separators (`1_048_576`).
pub fn human_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Format a cycle count as cycles plus wall-time at a given clock (GHz).
pub fn human_time_cycles(cycles: u64, ghz: f64) -> String {
    let secs = cycles as f64 / (ghz * 1e9);
    if secs < 1e-6 {
        format!("{} cyc ({:.1} ns)", human_count(cycles), secs * 1e9)
    } else if secs < 1e-3 {
        format!("{} cyc ({:.1} µs)", human_count(cycles), secs * 1e6)
    } else if secs < 1.0 {
        format!("{} cyc ({:.2} ms)", human_count(cycles), secs * 1e3)
    } else {
        format!("{} cyc ({:.2} s)", human_count(cycles), secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(2 * 1024 * 1024), "2.0 MiB");
    }

    #[test]
    fn counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(1000), "1,000");
        assert_eq!(human_count(1234567), "1,234,567");
    }

    #[test]
    fn cycles() {
        assert!(human_time_cycles(2_000_000_000, 2.0).contains("1.00 s"));
        assert!(human_time_cycles(2000, 2.0).contains("µs") || human_time_cycles(2000, 2.0).contains("ns"));
    }
}
