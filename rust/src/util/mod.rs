//! Small shared utilities: deterministic RNG, statistics, and formatting.
//!
//! The offline build environment provides no `rand` crate; simulations and
//! property tests need *deterministic, seedable* randomness anyway, so we
//! ship a SplitMix64 generator (public-domain algorithm, Steele et al.).

pub mod fmt;
pub mod rng;
pub mod stats;

pub use fmt::{human_bytes, human_count, human_time_cycles};
pub use rng::SplitMix64;
pub use stats::{geomean, mean, median, median_abs_dev, Summary};

/// Worker threads to use when the caller doesn't specify: one per
/// available hardware thread. The single source of truth for every
/// "auto" parallelism default (sweep jobs, golden bands, bench runs).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}
