//! SplitMix64: a tiny, fast, high-quality 64-bit PRNG.
//!
//! Used for grid initialization, workload generation, and property tests.
//! Deterministic under a fixed seed, which makes every experiment in this
//! repository exactly reproducible.

/// SplitMix64 pseudo-random generator (Steele, Lea & Flood, OOPSLA'14).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed. Any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `u64` in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire-style multiply-shift reduction; tiny bias acceptable for
        // simulation workloads (bounds are far below 2^64).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)` (half-open). `hi > lo` required.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fill a slice with uniform values in `[lo, hi)`.
    pub fn fill_f64(&mut self, out: &mut [f64], lo: f64, hi: f64) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.next_f64();
        }
    }

    /// Fork an independent generator (for parallel streams).
    pub fn fork(&mut self) -> SplitMix64 {
        SplitMix64::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(9);
        for bound in [1u64, 2, 3, 17, 1 << 40] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_covers_endpoints() {
        let mut r = SplitMix64::new(11);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..10_000 {
            let v = r.range(3, 6);
            assert!((3..6).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn rough_uniformity() {
        let mut r = SplitMix64::new(5);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[(r.next_f64() * 10.0) as usize] += 1;
        }
        for b in buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }
}
