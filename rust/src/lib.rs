//! # Casper — near-cache stencil acceleration, reproduced as a full system
//!
//! This crate reproduces *"Casper: Accelerating Stencil Computations using
//! Near-Cache Processing"* (Denzler et al., 2021) end to end:
//!
//! - a cycle-level simulator of the proposed hardware — stencil processing
//!   units ([`spu`]) attached to the slices of a sliced last-level cache
//!   ([`mem`]), with the paper's unaligned-load row-decoder support
//!   ([`mem::unaligned`]) and stencil-segment slice hash ([`mapping`]),
//!   connected by a mesh NoC ([`noc`]);
//! - the Casper programming model: the 15-bit instruction set ([`isa`]) and
//!   the Table-1 runtime API ([`coordinator`]);
//! - every comparator the paper evaluates against: a 16-core out-of-order
//!   CPU baseline ([`cpu`]), an NVIDIA Titan V analytical model ([`gpu`]),
//!   and the PIMS HMC near-memory design ([`pims`]);
//! - the paper's measurement machinery: energy ([`energy`]), area
//!   ([`area`]), roofline ([`roofline`]), and an experiment harness
//!   ([`harness`]) that regenerates every figure and table;
//! - a build-time AOT path: JAX/Pallas stencil kernels lowered to HLO text
//!   and executed from Rust via PJRT ([`runtime`]) to cross-validate the
//!   simulator's numerics.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for
//! paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```no_run
//! use casper::prelude::*;
//!
//! let cfg = SimConfig::default();
//! let stencil = StencilKind::Jacobi2D;
//! let domain = Domain::for_level(stencil, SizeClass::Llc);
//! let casper = casper::coordinator::run_casper(&cfg, stencil, &domain, 1);
//! let cpu = casper::cpu::run_cpu(&cfg, stencil, &domain, 1);
//! println!("speedup = {:.2}x", cpu.cycles as f64 / casper.cycles as f64);
//! ```

// CI gates on `clippy -D warnings`. These two style lints fight the
// simulator's deliberate idioms — hot loops index *parallel* SoA arrays
// (tags/stamps/flags, lines/slices) by position, and the timing-model
// entry points thread several scalar knobs — so they are opted out
// crate-wide rather than per-site.
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

pub mod area;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cpu;
pub mod energy;
pub mod gpu;
pub mod harness;
pub mod isa;
pub mod mapping;
pub mod mem;
pub mod noc;
pub mod pims;
pub mod roofline;
pub mod runtime;
pub mod spu;
pub mod stencil;
pub mod testutil;
pub mod trace;
pub mod util;
pub mod verify;

/// Most-used types, re-exported for examples and downstream users.
pub mod prelude {
    pub use crate::config::{SimConfig, SizeClass};
    pub use crate::coordinator::{run_casper, run_casper_spec, CasperRuntime, RunStats};
    pub use crate::cpu::{run_cpu, run_cpu_spec};
    pub use crate::harness::{Experiment, ExperimentSet};
    pub use crate::isa::{CasperInstr, CasperProgram, ProgramBuilder};
    pub use crate::stencil::{
        Domain, Grid, KernelId, KernelRegistry, KernelSpec, StencilKind,
    };
}
