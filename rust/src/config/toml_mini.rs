//! A TOML-subset parser sufficient for simulator config files.
//!
//! Supported: `[section]` headers, `key = value` pairs with integer
//! (decimal, underscores, `0x`), float, boolean, and quoted-string values,
//! `#` comments, and blank lines. Keys are exposed flattened as
//! `section.key`. Duplicate keys are an error (catches config typos).

use std::collections::BTreeMap;
use std::fmt;

/// Structured parse/access errors. Parse variants carry the 1-based
/// line number; bad config files print a named error instead of
/// panicking (the messages keep the `line N:` prefix tests and users
/// rely on).
#[derive(Debug, Clone, PartialEq)]
pub enum TomlError {
    UnterminatedSection { line: usize },
    BadSectionName { line: usize, name: String },
    ExpectedKeyValue { line: usize },
    BadKey { line: usize, key: String },
    BadValue { line: usize, key: String, why: String },
    DuplicateKey { line: usize, key: String },
    TypeMismatch { key: String, expected: &'static str, found: String },
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TomlError::UnterminatedSection { line } => {
                write!(f, "line {line}: unterminated section header")
            }
            TomlError::BadSectionName { line, name } => {
                write!(f, "line {line}: bad section name '{name}'")
            }
            TomlError::ExpectedKeyValue { line } => {
                write!(f, "line {line}: expected 'key = value'")
            }
            TomlError::BadKey { line, key } => write!(f, "line {line}: bad key '{key}'"),
            TomlError::BadValue { line, key, why } => {
                write!(f, "line {line}: bad value for '{key}': {why}")
            }
            TomlError::DuplicateKey { line, key } => {
                write!(f, "line {line}: duplicate key '{key}'")
            }
            TomlError::TypeMismatch { key, expected, found } => {
                write!(f, "'{key}': expected {expected}, found {found}")
            }
        }
    }
}

impl std::error::Error for TomlError {}

/// One parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

/// A parsed document: flat `section.key -> value` map.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a document; errors carry the 1-based line number.
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or(TomlError::UnterminatedSection { line: lineno + 1 })?
                    .trim();
                if name.is_empty() || !name.chars().all(is_key_char) {
                    return Err(TomlError::BadSectionName {
                        line: lineno + 1,
                        name: name.to_string(),
                    });
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or(TomlError::ExpectedKeyValue { line: lineno + 1 })?;
            let key = key.trim();
            if key.is_empty() || !key.chars().all(is_key_char) {
                return Err(TomlError::BadKey { line: lineno + 1, key: key.to_string() });
            }
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(value.trim()).map_err(|why| TomlError::BadValue {
                line: lineno + 1,
                key: full.clone(),
                why,
            })?;
            if doc.map.insert(full.clone(), value).is_some() {
                return Err(TomlError::DuplicateKey { line: lineno + 1, key: full });
            }
        }
        Ok(doc)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    /// Integer accessor; `Ok(None)` if absent, error on type mismatch.
    pub fn get_int(&self, key: &str) -> Result<Option<i64>, TomlError> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Int(v)) => Ok(Some(*v)),
            Some(other) => Err(mismatch(key, "integer", other)),
        }
    }

    /// Float accessor; integers widen to float.
    pub fn get_float(&self, key: &str) -> Result<Option<f64>, TomlError> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Float(v)) => Ok(Some(*v)),
            Some(TomlValue::Int(v)) => Ok(Some(*v as f64)),
            Some(other) => Err(mismatch(key, "float", other)),
        }
    }

    pub fn get_bool(&self, key: &str) -> Result<Option<bool>, TomlError> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Bool(v)) => Ok(Some(*v)),
            Some(other) => Err(mismatch(key, "bool", other)),
        }
    }

    pub fn get_str(&self, key: &str) -> Result<Option<String>, TomlError> {
        match self.map.get(key) {
            None => Ok(None),
            Some(TomlValue::Str(v)) => Ok(Some(v.clone())),
            Some(other) => Err(mismatch(key, "string", other)),
        }
    }
}

fn mismatch(key: &str, expected: &'static str, found: &TomlValue) -> TomlError {
    TomlError::TypeMismatch { key: key.to_string(), expected, found: format!("{found:?}") }
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue, String> {
    if s.is_empty() {
        return Err("empty value".to_string());
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(q) = s.strip_prefix('"') {
        let inner = q.strip_suffix('"').ok_or_else(|| "unterminated string".to_string())?;
        if inner.contains('"') {
            return Err("embedded quote in string".to_string());
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Some(hex) = cleaned.strip_prefix("0x").or_else(|| cleaned.strip_prefix("0X")) {
        let v = i64::from_str_radix(hex, 16).map_err(|_| format!("bad hex integer '{s}'"))?;
        return Ok(TomlValue::Int(v));
    }
    if !cleaned.contains('.') && !cleaned.contains('e') && !cleaned.contains('E') {
        if let Ok(v) = cleaned.parse::<i64>() {
            return Ok(TomlValue::Int(v));
        }
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(TomlValue::Float(v));
    }
    Err(format!("cannot parse value '{s}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
top = 1
[a]
x = 10
y = 2.5
z = true
name = "hello"  # trailing comment
big = 1_000_000
hexy = 0x1F
"#,
        )
        .unwrap();
        assert_eq!(doc.get_int("top").unwrap(), Some(1));
        assert_eq!(doc.get_int("a.x").unwrap(), Some(10));
        assert_eq!(doc.get_float("a.y").unwrap(), Some(2.5));
        assert_eq!(doc.get_bool("a.z").unwrap(), Some(true));
        assert_eq!(doc.get_str("a.name").unwrap(), Some("hello".into()));
        assert_eq!(doc.get_int("a.big").unwrap(), Some(1_000_000));
        assert_eq!(doc.get_int("a.hexy").unwrap(), Some(31));
    }

    #[test]
    fn int_widens_to_float() {
        let doc = TomlDoc::parse("x = 3\n").unwrap();
        assert_eq!(doc.get_float("x").unwrap(), Some(3.0));
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(TomlDoc::parse("x = 1\nx = 2\n").is_err());
    }

    #[test]
    fn type_mismatch_is_error() {
        let doc = TomlDoc::parse("x = \"s\"\n").unwrap();
        assert!(doc.get_int("x").is_err());
    }

    #[test]
    fn missing_key_is_none() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.get_int("nope").unwrap(), None);
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse("s = \"a#b\"\n").unwrap();
        assert_eq!(doc.get_str("s").unwrap(), Some("a#b".into()));
    }

    #[test]
    fn bad_lines_error_with_lineno() {
        let err = TomlDoc::parse("\n\nbogus line\n").unwrap_err();
        assert!(format!("{err:#}").contains("line 3"));
    }

    #[test]
    fn errors_are_structured() {
        assert_eq!(
            TomlDoc::parse("x = 1\nx = 2\n").unwrap_err(),
            TomlError::DuplicateKey { line: 2, key: "x".into() }
        );
        assert_eq!(
            TomlDoc::parse("[oops\n").unwrap_err(),
            TomlError::UnterminatedSection { line: 1 }
        );
        let doc = TomlDoc::parse("x = \"s\"\n").unwrap();
        let err = doc.get_int("x").unwrap_err();
        assert!(matches!(err, TomlError::TypeMismatch { .. }), "{err}");
        assert!(err.to_string().contains("expected integer"), "{err}");
    }
}
