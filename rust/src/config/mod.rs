//! Simulation configuration: the paper's Table 2 parameters, size classes,
//! and a file-based config loader (TOML subset — the offline registry ships
//! no `serde`/`toml`, see DESIGN.md §3).

pub mod toml_mini;

use std::path::Path;

use anyhow::{Context, Result};

use toml_mini::TomlDoc;

/// The three data-set size classes of Table 3: fits in the private L2s,
/// fits in the shared LLC, or exceeds the LLC (DRAM-resident).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum SizeClass {
    L2,
    Llc,
    Dram,
}

impl SizeClass {
    pub const ALL: [SizeClass; 3] = [SizeClass::L2, SizeClass::Llc, SizeClass::Dram];

    pub fn name(self) -> &'static str {
        match self {
            SizeClass::L2 => "L2",
            SizeClass::Llc => "LLC",
            SizeClass::Dram => "DRAM",
        }
    }

    pub fn parse(s: &str) -> Option<SizeClass> {
        match s.to_ascii_lowercase().as_str() {
            "l2" => Some(SizeClass::L2),
            "llc" | "l3" => Some(SizeClass::Llc),
            "dram" => Some(SizeClass::Dram),
            _ => None,
        }
    }

    /// Slot in `[L2, LLC, DRAM]`-ordered tables (paper data, spec domains).
    pub fn index(self) -> usize {
        match self {
            SizeClass::L2 => 0,
            SizeClass::Llc => 1,
            SizeClass::Dram => 2,
        }
    }
}

impl std::fmt::Display for SizeClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Parameters of one cache level (Table 2 rows L1I/D, L2, L3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheConfig {
    /// Total capacity in bytes (per instance: per core for L1/L2, per slice
    /// aggregate for L3 — see `LlcConfig`).
    pub size_bytes: usize,
    /// Associativity (ways).
    pub ways: usize,
    /// Cache line size in bytes.
    pub line_bytes: usize,
    /// Miss status holding registers (outstanding misses) per instance.
    pub mshrs: usize,
    /// Round-trip load-to-use latency in cycles.
    pub latency: u64,
    /// Energy per hit / per miss, in picojoules (Table 2, from [167]).
    pub hit_pj: f64,
    pub miss_pj: f64,
}

impl CacheConfig {
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }
}

/// Shared sliced last-level cache parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LlcConfig {
    /// Per-slice capacity in bytes (2 MB in Table 2, 16 slices = 32 MB).
    pub slice_bytes: usize,
    pub slices: usize,
    pub ways: usize,
    pub line_bytes: usize,
    /// MSHRs per slice.
    pub mshrs_per_slice: usize,
    /// Round-trip latency from a core (36 cycles in Table 2). The paper
    /// states SPU-to-local-slice load-to-use is 8 cycles (§8.1).
    pub core_latency: u64,
    pub spu_local_latency: u64,
    pub hit_pj: f64,
    pub miss_pj: f64,
    /// Block size used by the stencil-segment hash (128 kB, §4.2 fn.2).
    pub stencil_block_bytes: usize,
    /// Ways reserved for concurrent CPU processes while SPUs run (§4.4).
    pub reserved_ways: usize,
}

impl LlcConfig {
    pub fn total_bytes(&self) -> usize {
        self.slice_bytes * self.slices
    }
    pub fn sets_per_slice(&self) -> usize {
        self.slice_bytes / (self.ways * self.line_bytes)
    }
}

/// DRAM parameters (Table 2: 16 GB DDR4, 4 channels, 160 nJ per access).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DramConfig {
    pub channels: usize,
    /// Closed-page access latency seen past the LLC, in CPU cycles.
    pub latency: u64,
    /// Peak per-channel bandwidth in bytes per CPU cycle. DDR4-2400 ≈
    /// 19.2 GB/s per channel ≈ 9.6 B per 2 GHz CPU cycle.
    pub bytes_per_cycle_per_channel: f64,
    pub access_nj: f64,
}

/// Baseline CPU core parameters (Table 2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CpuConfig {
    pub cores: usize,
    pub freq_ghz: f64,
    pub issue_width: usize,
    pub rob: usize,
    pub load_queue: usize,
    pub store_queue: usize,
    /// SIMD width in bits (one 512-bit unit per core).
    pub simd_bits: usize,
    pub energy_per_instr_nj: f64,
}

impl CpuConfig {
    /// f64 lanes per SIMD op.
    pub fn simd_lanes(&self) -> usize {
        self.simd_bits / 64
    }
    /// Peak double-precision FLOPS of the chip (MAC = 2 flops/lane/cycle).
    pub fn peak_flops(&self) -> f64 {
        self.cores as f64 * self.freq_ghz * 1e9 * self.simd_lanes() as f64 * 2.0
    }
}

/// Casper SPU parameters (Table 2 + §3.3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpuConfig {
    /// One SPU per LLC slice.
    pub count: usize,
    pub simd_bits: usize,
    pub load_queue: usize,
    pub instr_buffer: usize,
    pub stream_buffer: usize,
    pub constant_buffer: usize,
    pub energy_per_instr_nj: f64,
    /// Area of one SPU at 22 nm (§8.6).
    pub area_mm2: f64,
}

impl SpuConfig {
    pub fn simd_lanes(&self) -> usize {
        self.simd_bits / 64
    }
}

/// Mesh NoC parameters (Table 2: mesh, XY routing, 64 B/cycle/direction).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NocConfig {
    /// Mesh dimensions; `x * y` must equal the LLC slice count.
    pub mesh_x: usize,
    pub mesh_y: usize,
    /// Per-hop latency in cycles (router + link).
    pub hop_latency: u64,
    /// Link bandwidth in bytes per cycle per direction.
    pub link_bytes_per_cycle: usize,
}

/// Stride prefetcher parameters ("stride prefetchers at all levels").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefetchConfig {
    pub enabled: bool,
    /// Distinct streams tracked per prefetcher.
    pub streams: usize,
    /// Prefetch degree (lines fetched ahead per trigger).
    pub degree: usize,
}

/// Where the SPUs sit — near the LLC slices (Casper) or near the private
/// L1s (the Fig 14 ablation baseline).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpuPlacement {
    NearLlc,
    NearL1,
}

/// Which address→slice hash the stencil segment uses (Fig 14 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MappingPolicy {
    /// Conventional line-interleaved hash for everything.
    Baseline,
    /// 128 kB-block linear hash inside the stencil segment (§4.2).
    StencilSegment,
}

/// Complete system configuration. `SimConfig::default()` reproduces the
/// paper's Table 2 machine exactly.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    pub cpu: CpuConfig,
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    pub llc: LlcConfig,
    pub dram: DramConfig,
    pub spu: SpuConfig,
    pub noc: NocConfig,
    pub prefetch: PrefetchConfig,
    pub placement: SpuPlacement,
    pub mapping: MappingPolicy,
    /// Chip static (leakage + uncore) power in watts, charged over the
    /// runtime of *both* systems — the host CPU is present and powered
    /// whether the kernel runs on its cores or on the SPUs. This is what
    /// separates the paper's Fig 11 (total system energy, Casper wins by
    /// 35%) from its appendix Table 6 (dynamic-only, Casper loses) — see
    /// EXPERIMENTS.md.
    pub chip_static_watts: f64,
    /// RNG seed for grid initialization.
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            cpu: CpuConfig {
                cores: 16,
                freq_ghz: 2.0,
                issue_width: 8,
                rob: 224,
                load_queue: 72,
                store_queue: 64,
                simd_bits: 512,
                energy_per_instr_nj: 0.08,
            },
            l1: CacheConfig {
                size_bytes: 32 * 1024,
                ways: 8,
                line_bytes: 64,
                mshrs: 16,
                latency: 4,
                hit_pj: 15.0,
                miss_pj: 33.0,
            },
            l2: CacheConfig {
                size_bytes: 256 * 1024,
                ways: 8,
                line_bytes: 64,
                mshrs: 16,
                latency: 12,
                hit_pj: 46.0,
                miss_pj: 93.0,
            },
            llc: LlcConfig {
                slice_bytes: 2 * 1024 * 1024,
                slices: 16,
                ways: 16,
                line_bytes: 64,
                mshrs_per_slice: 32,
                core_latency: 36,
                spu_local_latency: 8,
                hit_pj: 945.0,
                miss_pj: 1904.0,
                stencil_block_bytes: 128 * 1024,
                reserved_ways: 1,
            },
            dram: DramConfig {
                channels: 4,
                latency: 200,
                bytes_per_cycle_per_channel: 9.6,
                access_nj: 160.0,
            },
            spu: SpuConfig {
                count: 16,
                simd_bits: 512,
                load_queue: 10,
                instr_buffer: 64,
                stream_buffer: 16,
                constant_buffer: 16,
                energy_per_instr_nj: 0.016,
                area_mm2: 0.146,
            },
            noc: NocConfig {
                mesh_x: 4,
                mesh_y: 4,
                hop_latency: 2,
                link_bytes_per_cycle: 64,
            },
            prefetch: PrefetchConfig {
                enabled: true,
                streams: 16,
                degree: 4,
            },
            placement: SpuPlacement::NearLlc,
            mapping: MappingPolicy::StencilSegment,
            chip_static_watts: 60.0,
            seed: 0xCA5_9E12,
        }
    }
}

impl SimConfig {
    /// Validate cross-field invariants. Called by the CLI and loaders.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.noc.mesh_x * self.noc.mesh_y == self.llc.slices,
            "mesh {}x{} must cover {} LLC slices",
            self.noc.mesh_x,
            self.noc.mesh_y,
            self.llc.slices
        );
        anyhow::ensure!(
            self.spu.count == self.llc.slices,
            "one SPU per LLC slice required ({} SPUs vs {} slices)",
            self.spu.count,
            self.llc.slices
        );
        anyhow::ensure!(self.llc.line_bytes == self.l1.line_bytes, "uniform line size");
        anyhow::ensure!(self.llc.line_bytes == self.l2.line_bytes, "uniform line size");
        anyhow::ensure!(
            self.llc.stencil_block_bytes % self.llc.line_bytes == 0,
            "stencil block must be line-aligned"
        );
        anyhow::ensure!(self.llc.reserved_ways < self.llc.ways, "reserved ways < ways");
        anyhow::ensure!(self.l1.sets() > 0 && self.l2.sets() > 0, "cache geometry");
        Ok(())
    }

    /// Load a config from a TOML-subset file, starting from defaults and
    /// overriding any provided keys (flat `section.key = value` form).
    pub fn from_file(path: &Path) -> Result<SimConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Parse from a string (see [`from_file`](Self::from_file)).
    pub fn from_toml_str(text: &str) -> Result<SimConfig> {
        let doc = TomlDoc::parse(text)?;
        let mut cfg = SimConfig::default();
        // Integers
        macro_rules! geti {
            ($key:expr, $slot:expr) => {
                if let Some(v) = doc.get_int($key)? {
                    $slot = v as _;
                }
            };
        }
        macro_rules! getf {
            ($key:expr, $slot:expr) => {
                if let Some(v) = doc.get_float($key)? {
                    $slot = v;
                }
            };
        }
        geti!("cpu.cores", cfg.cpu.cores);
        getf!("cpu.freq_ghz", cfg.cpu.freq_ghz);
        geti!("cpu.issue_width", cfg.cpu.issue_width);
        geti!("cpu.rob", cfg.cpu.rob);
        geti!("cpu.load_queue", cfg.cpu.load_queue);
        geti!("cpu.store_queue", cfg.cpu.store_queue);
        geti!("cpu.simd_bits", cfg.cpu.simd_bits);
        getf!("cpu.energy_per_instr_nj", cfg.cpu.energy_per_instr_nj);

        geti!("l1.size_bytes", cfg.l1.size_bytes);
        geti!("l1.ways", cfg.l1.ways);
        geti!("l1.mshrs", cfg.l1.mshrs);
        geti!("l1.latency", cfg.l1.latency);
        geti!("l2.size_bytes", cfg.l2.size_bytes);
        geti!("l2.ways", cfg.l2.ways);
        geti!("l2.mshrs", cfg.l2.mshrs);
        geti!("l2.latency", cfg.l2.latency);

        geti!("llc.slice_bytes", cfg.llc.slice_bytes);
        geti!("llc.slices", cfg.llc.slices);
        geti!("llc.ways", cfg.llc.ways);
        geti!("llc.mshrs_per_slice", cfg.llc.mshrs_per_slice);
        geti!("llc.core_latency", cfg.llc.core_latency);
        geti!("llc.spu_local_latency", cfg.llc.spu_local_latency);
        geti!("llc.stencil_block_bytes", cfg.llc.stencil_block_bytes);
        geti!("llc.reserved_ways", cfg.llc.reserved_ways);

        geti!("dram.channels", cfg.dram.channels);
        geti!("dram.latency", cfg.dram.latency);
        getf!("dram.bytes_per_cycle_per_channel", cfg.dram.bytes_per_cycle_per_channel);
        getf!("dram.access_nj", cfg.dram.access_nj);

        geti!("spu.count", cfg.spu.count);
        geti!("spu.simd_bits", cfg.spu.simd_bits);
        geti!("spu.load_queue", cfg.spu.load_queue);
        geti!("spu.instr_buffer", cfg.spu.instr_buffer);
        getf!("spu.energy_per_instr_nj", cfg.spu.energy_per_instr_nj);
        getf!("spu.area_mm2", cfg.spu.area_mm2);

        geti!("noc.mesh_x", cfg.noc.mesh_x);
        geti!("noc.mesh_y", cfg.noc.mesh_y);
        geti!("noc.hop_latency", cfg.noc.hop_latency);
        geti!("noc.link_bytes_per_cycle", cfg.noc.link_bytes_per_cycle);

        if let Some(b) = doc.get_bool("prefetch.enabled")? {
            cfg.prefetch.enabled = b;
        }
        geti!("prefetch.streams", cfg.prefetch.streams);
        geti!("prefetch.degree", cfg.prefetch.degree);

        if let Some(s) = doc.get_str("casper.placement")? {
            cfg.placement = match s.as_str() {
                "near_llc" => SpuPlacement::NearLlc,
                "near_l1" => SpuPlacement::NearL1,
                other => anyhow::bail!("unknown casper.placement '{other}'"),
            };
        }
        if let Some(s) = doc.get_str("casper.mapping")? {
            cfg.mapping = match s.as_str() {
                "baseline" => MappingPolicy::Baseline,
                "stencil_segment" => MappingPolicy::StencilSegment,
                other => anyhow::bail!("unknown casper.mapping '{other}'"),
            };
        }
        getf!("sim.chip_static_watts", cfg.chip_static_watts);
        geti!("sim.seed", cfg.seed);

        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table2() {
        let c = SimConfig::default();
        assert_eq!(c.cpu.cores, 16);
        assert_eq!(c.llc.total_bytes(), 32 * 1024 * 1024);
        assert_eq!(c.llc.sets_per_slice(), 2048);
        assert_eq!(c.l1.sets(), 64);
        assert_eq!(c.spu.simd_lanes(), 8);
        assert!(c.validate().is_ok());
        // Peak fp64 FLOPS of the Table-2 chip: 16 cores * 2 GHz * 8 lanes *
        // 2 flops = 512 GFLOPS (the paper's Fig 1 quotes 537.6 for the Xeon).
        assert!((c.cpu.peak_flops() - 512e9).abs() < 1e6);
    }

    #[test]
    fn validate_rejects_bad_mesh() {
        let mut c = SimConfig::default();
        c.noc.mesh_x = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_spu_slice_mismatch() {
        let mut c = SimConfig::default();
        c.spu.count = 8;
        assert!(c.validate().is_err());
    }

    #[test]
    fn toml_overrides_apply() {
        let text = r#"
# comment
[cpu]
cores = 8

[llc]
slices = 8

[spu]
count = 8

[noc]
mesh_x = 4
mesh_y = 2

[casper]
placement = "near_l1"
mapping = "baseline"
"#;
        let c = SimConfig::from_toml_str(text).unwrap();
        assert_eq!(c.cpu.cores, 8);
        assert_eq!(c.llc.slices, 8);
        assert_eq!(c.placement, SpuPlacement::NearL1);
        assert_eq!(c.mapping, MappingPolicy::Baseline);
    }

    #[test]
    fn toml_bad_value_is_error() {
        assert!(SimConfig::from_toml_str("[casper]\nplacement = \"bogus\"\n").is_err());
    }

    #[test]
    fn size_class_parse() {
        assert_eq!(SizeClass::parse("llc"), Some(SizeClass::Llc));
        assert_eq!(SizeClass::parse("L2"), Some(SizeClass::L2));
        assert_eq!(SizeClass::parse("dram"), Some(SizeClass::Dram));
        assert_eq!(SizeClass::parse("x"), None);
    }
}
