//! Roofline model (Fig 1): arithmetic intensity per kernel vs. the
//! machine's compute peak and the DRAM / L3 bandwidth ceilings.

use std::sync::Arc;

use crate::config::SimConfig;
use crate::stencil::{KernelSpec, StencilKind};

/// The machine ceilings of Fig 1.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    /// Peak fp64 FLOP/s.
    pub peak_flops: f64,
    /// Sustained DRAM bandwidth, B/s.
    pub dram_bw: f64,
    /// Aggregate LLC bandwidth, B/s.
    pub llc_bw: f64,
}

impl Machine {
    pub fn of(cfg: &SimConfig) -> Machine {
        let hz = cfg.cpu.freq_ghz * 1e9;
        Machine {
            peak_flops: cfg.cpu.peak_flops(),
            dram_bw: cfg.dram.channels as f64 * cfg.dram.bytes_per_cycle_per_channel * hz,
            llc_bw: (cfg.llc.slices * cfg.llc.line_bytes) as f64 * hz,
        }
    }

    /// Attainable FLOP/s at arithmetic intensity `ai` under ceiling `bw`.
    pub fn attainable(&self, ai: f64, bw: f64) -> f64 {
        (ai * bw).min(self.peak_flops)
    }

    /// Intensity where the DRAM roof meets the compute peak.
    pub fn dram_knee(&self) -> f64 {
        self.peak_flops / self.dram_bw
    }

    pub fn llc_knee(&self) -> f64 {
        self.peak_flops / self.llc_bw
    }
}

/// One kernel's placement on the roofline.
#[derive(Debug, Clone)]
pub struct RooflinePoint {
    /// Kernel display name (as printed in Fig 1's legend).
    pub name: String,
    pub ai: f64,
    /// Attainable under the DRAM roof.
    pub dram_bound: f64,
    /// Attainable under the LLC roof.
    pub llc_bound: f64,
    /// Measured GFLOP/s (from the CPU model), if provided.
    pub measured: Option<f64>,
}

/// Build the Fig 1 dataset over the six paper kernels. `measured[i]`
/// pairs with `StencilKind::ALL[i]` when given.
pub fn roofline(cfg: &SimConfig, measured: Option<&[f64]>) -> Vec<RooflinePoint> {
    let specs: Vec<Arc<KernelSpec>> = StencilKind::ALL.iter().map(|k| k.spec()).collect();
    roofline_specs(cfg, &specs, measured)
}

/// Build a roofline dataset over any kernel set. `measured[i]` pairs with
/// `specs[i]` when given.
pub fn roofline_specs(
    cfg: &SimConfig,
    specs: &[Arc<KernelSpec>],
    measured: Option<&[f64]>,
) -> Vec<RooflinePoint> {
    let m = Machine::of(cfg);
    specs
        .iter()
        .enumerate()
        .map(|(i, spec)| {
            let ai = spec.arithmetic_intensity();
            RooflinePoint {
                name: spec.name.clone(),
                ai,
                dram_bound: m.attainable(ai, m.dram_bw),
                llc_bound: m.attainable(ai, m.llc_bw),
                measured: measured.map(|v| v[i] * 1e9),
            }
        })
        .collect()
}

/// Arithmetic intensity with temporal blocking folded in: a block of `t`
/// steps moves each grid byte across DRAM once but computes `t` sweeps
/// over it, so the operating point slides right by a factor of `t`. (Halo
/// recomputation at block edges only *adds* FLOPs at the same traffic —
/// this first-order fold ignores it, understating the blocked AI.)
pub fn blocked_ai(ai: f64, t: usize) -> f64 {
    ai * t.max(1) as f64
}

/// A kernel's blocked operating point on the same machine roofs: AI × T,
/// labelled `"<name> (T=<t>)"` — the Fig 1 companion point for a
/// `--temporal-block` sweep. No measured value attaches (the CPU baseline
/// does not run blocked).
pub fn blocked_point(cfg: &SimConfig, spec: &KernelSpec, t: usize) -> RooflinePoint {
    let m = Machine::of(cfg);
    let ai = blocked_ai(spec.arithmetic_intensity(), t);
    RooflinePoint {
        name: format!("{} (T={t})", spec.name),
        ai,
        dram_bound: m.attainable(ai, m.dram_bw),
        llc_bound: m.attainable(ai, m.llc_bw),
        measured: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn machine_ceilings_match_table2() {
        let m = Machine::of(&SimConfig::default());
        assert!((m.peak_flops - 512e9).abs() < 1e6);
        // 4 × 9.6 B/cycle × 2 GHz = 76.8 GB/s.
        assert!((m.dram_bw - 76.8e9).abs() < 1e6);
        // 16 slices × 64 B × 2 GHz = 2048 GB/s; the paper quotes LLC
        // bandwidth as ~10× DRAM ("about 10× in Intel Xeon") — ours is a
        // wider-LLC machine, ~26×, which only strengthens the argument.
        assert!((m.llc_bw - 2048e9).abs() < 1e6);
    }

    #[test]
    fn all_kernels_sit_between_the_roofs() {
        // Fig 1's observation: every stencil lies below the L3 line and
        // above the DRAM line, left of the compute knee.
        let cfg = SimConfig::default();
        let m = Machine::of(&cfg);
        for p in roofline(&cfg, None) {
            assert!(p.ai < m.dram_knee(), "{}: AI right of DRAM knee", p.name);
            assert!(p.llc_bound > p.dram_bound, "{}", p.name);
            assert!(p.llc_bound < m.peak_flops, "{}: LLC roof above peak", p.name);
            // <20% of peak even at the LLC roof — the paper's headline.
            assert!(
                p.llc_bound < 0.2 * m.peak_flops * 6.0,
                "{}: implausibly high bound",
                p.name
            );
        }
    }

    #[test]
    fn measured_values_attach() {
        let cfg = SimConfig::default();
        let pts = roofline(&cfg, Some(&[10.0, 20.0, 30.0, 40.0, 50.0, 60.0]));
        assert_eq!(pts[2].measured, Some(30.0e9));
    }

    #[test]
    fn blocked_point_moves_right_along_the_dram_roof() {
        // Temporal blocking multiplies AI by T; left of the DRAM knee the
        // attainable FLOP/s scale with it, and at T=1 nothing moves.
        let cfg = SimConfig::default();
        let spec = StencilKind::Jacobi2D.descriptor();
        let base = blocked_point(&cfg, &spec, 1);
        assert!((base.ai - spec.arithmetic_intensity()).abs() < 1e-12);
        let b4 = blocked_point(&cfg, &spec, 4);
        assert!((b4.ai - 4.0 * base.ai).abs() < 1e-12);
        assert!(b4.name.ends_with("(T=4)"), "{}", b4.name);
        assert!(b4.ai < Machine::of(&cfg).dram_knee(), "stays bandwidth-bound");
        assert!((b4.dram_bound - 4.0 * base.dram_bound).abs() < 1.0);
        assert_eq!(blocked_ai(0.125, 0), 0.125, "T=0 clamps to 1");
        assert!(b4.measured.is_none());
    }
}
