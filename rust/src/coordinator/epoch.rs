//! Epoch-parallel intra-run SPU execution, phased or pipelined.
//!
//! One serial "round" of the engine loop runs one vector group on every
//! SPU. The epoch engine executes `epoch_rounds` such rounds as one epoch
//! through three explicit stages with owned hand-off state (see
//! `rust/DESIGN-parallel.md` for the full protocol and the determinism
//! argument):
//!
//! 1. **Collect** — functional fan-out, parallel over SPUs: every SPU runs
//!    its groups functionally — input loads read the step-immutable input
//!    array, output writes are staged per SPU — while queueing each LLC
//!    tag access as an *epoch message* tagged `(round, spu, seq)` and
//!    recording the per-instruction request geometry. (Multi-pass
//!    accumulator streams also read the *output* array, but only the
//!    elements the reading group itself is about to overwrite — written
//!    by the previous pass, never within the current `run_step` — so the
//!    step-immutability argument carries over pass by pass.)
//! 2. **Reconcile** — tag reconciliation, parallel over slices: each
//!    slice's worker owns that slice's [`TagBank`] outright and drains its
//!    incoming messages in `(round, spu, seq)` order — exactly the order
//!    the serial round-robin interleaving would have applied them —
//!    producing the tag outcomes (hit / writeback).
//! 3. **Replay** — deterministic serial timing: the exact serial timing
//!    arithmetic (issue, load queue, slice ports, NoC latencies, DRAM
//!    channels) replays in global `(round, spu, seq)` order with the
//!    reconciled outcomes injected — no tag scans left on this path.
//!
//! The stages communicate through an owned [`EpochWork`] struct, which is
//! what enables the **pipelined** mode: the runtime splits into a
//! functional half (SPU program state, the backing store, the lent-out
//! [`TagBank`]s) and a timing half (the detached [`SpuTimer`]s plus the
//! [`TimingMem`] borrow: ports, NoC, DRAM, tracer). A dedicated replay
//! worker drains epoch *e*'s stage-3 replay while the thread pool collects
//! and reconciles epoch *e+1*. The hand-off channel is bounded
//! ([`PIPELINE_DEPTH`]) and drained buffers cycle back for reuse, so
//! memory stays flat regardless of run length.
//!
//! Determinism: tag outcomes depend only on per-slice access *order*
//! (never on timestamps), and timestamps depend only on outcomes plus
//! processing order — which stage 3 reproduces exactly, epoch by epoch,
//! whether it runs inline (phased) or on the worker (pipelined). Hence
//! serial, phased, and pipelined execution are byte-identical;
//! `coordinator::engine`'s identity tests enforce this across kernels,
//! mappings, thread counts, epoch sizes, and both pipeline settings.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use anyhow::Result;

use crate::spu::sharded::{FunMem, SpuTrace, TagOut, TagOutStream, TagReq, TimingMem, NO_LINE};
use crate::spu::{SimStore, Spu, SpuTimer, TagBank};
use crate::trace::{EpochPhases, TraceSink};

use super::api::CasperRuntime;
use super::engine::{bind_chunk, Chunk};
use super::layout::SegmentLayout;

/// Rounds per epoch: large enough to amortize worker spawn + phase
/// hand-off, small enough to bound trace memory (~tens of MB). Tunable
/// via `--epoch-rounds` / `CASPER_EPOCH_ROUNDS`; results are independent
/// of the value.
pub(crate) const DEFAULT_EPOCH_ROUNDS: usize = 2048;

/// In-flight bound of the pipelined engine: at most one epoch queued in
/// the hand-off channel while one more is being replayed.
pub const PIPELINE_DEPTH: usize = 2;

/// The bounded hand-off channel between the functional stages and the
/// replay worker. A one-slot `sync_channel`: the functional side blocks on
/// `send` once one epoch is queued while another is still replaying, so no
/// more than [`PIPELINE_DEPTH`] epochs are ever in flight past the collect
/// stage — epoch memory stays flat no matter how long the run is.
pub fn pipeline_channel<T>() -> (SyncSender<T>, Receiver<T>) {
    mpsc::sync_channel(PIPELINE_DEPTH - 1)
}

/// Owned hand-off between the pipeline stages: everything one epoch
/// carries from the functional side into the timing replay. Buffers cycle
/// back through a return lane, so a pipelined run allocates at most
/// `PIPELINE_DEPTH + 1` of these regardless of length (the phased path
/// reuses a single one).
struct EpochWork {
    /// Per-SPU stage-1 products: instruction records and (emptied during
    /// reconciliation) per-slice tag-request queues. Staged output writes
    /// are drained to the store before hand-off.
    traces: Vec<SpuTrace>,
    /// `streams[spu][slice]`: reconciled outcome cursors for the replay.
    streams: Vec<Vec<TagOutStream>>,
    /// Wall-clock µs spans of this epoch's collect / reconcile stages,
    /// measured from the tracer origin (zeros when untraced). They ride
    /// along so the replay worker can emit the complete phase triple.
    collect_span: [u64; 2],
    reconcile_span: [u64; 2],
}

impl EpochWork {
    fn new(n_spus: usize, n_slices: usize) -> EpochWork {
        EpochWork {
            traces: (0..n_spus).map(|_| SpuTrace::new(n_slices)).collect(),
            streams: (0..n_spus)
                .map(|_| (0..n_slices).map(|_| TagOutStream::default()).collect())
                .collect(),
            collect_span: [0; 2],
            reconcile_span: [0; 2],
        }
    }
}

/// Run one full time step of the engine loop with `threads` workers,
/// epoch by epoch, binding chunks from `parts` exactly as the serial
/// round-robin loop does. `pipeline` overlaps each epoch's serial timing
/// replay with the next epoch's functional fan-out + reconciliation;
/// results are byte-identical either way.
pub(crate) fn run_step(
    rt: &mut CasperRuntime,
    parts: &[Vec<Chunk>],
    layout: &SegmentLayout,
    nx: i64,
    nxy: i64,
    threads: usize,
    epoch_rounds: usize,
    pipeline: bool,
) -> Result<()> {
    let n_spus = rt.spus.len();
    let n_slices = rt.cfg.llc.slices;
    let n_instrs = rt.spus[0].program().instrs.len();
    let way_limit = rt.mem.llc.way_limit();
    let epoch_rounds = epoch_rounds.max(1);
    let mut cursors = vec![0usize; n_spus];

    // Wall-clock stage spans (`--trace`): the stages have no cycle-domain
    // duration (they are an implementation artifact, not simulated time),
    // so they are recorded as real-µs offsets from the tracer's origin.
    // Observation only — `Instant` reads never touch simulation state.
    // `origin` is `None` without a tracer.
    let origin = rt.mem.trace.as_deref().map(|t| t.origin());

    // Split the runtime into the two halves the pipeline stages own: the
    // functional side keeps the SPUs (minus their timers), the backing
    // store, and the lent-out tag banks; the timing side gets the detached
    // timers plus ports/NoC/DRAM/tracer. The split is what lets the replay
    // worker run without `&mut rt`.
    let homes: Vec<usize> = rt.spus.iter().map(|s| s.slice).collect();
    let mut timers: Vec<SpuTimer> = rt.spus.iter_mut().map(|s| s.take_timer()).collect();
    let mut tags: Vec<TagBank> = rt.mem.llc.take_tag_banks();
    debug_assert_eq!(tags.len(), n_slices);
    let spus = &mut rt.spus;
    let (mut fun, mut tim) = rt.mem.split_halves();

    if !pipeline {
        // Phased: the same three stages, inline on one reused EpochWork.
        let mut work = EpochWork::new(n_spus, n_slices);
        while pending(spus, &cursors, parts) {
            let m0 = us_mark(origin);
            collect_epoch(
                spus, &mut cursors, parts, layout, nx, nxy, fun.view(), threads, epoch_rounds,
                n_instrs, &mut work.traces,
            );
            apply_outs(&mut work.traces, &mut *fun.store);
            let m1 = us_mark(origin);
            reconcile_epoch(&mut tags, way_limit, threads, &mut work);
            let m2 = us_mark(origin);
            work.collect_span = [m0, m1];
            work.reconcile_span = [m1, m2];
            let r0 = us_mark(origin);
            replay_epoch(&mut timers, &homes, &mut tim, n_instrs, &mut work);
            let r1 = us_mark(origin);
            if let Some(tr) = tim.trace.as_deref_mut() {
                tr.epoch_phases(EpochPhases {
                    phases: [work.collect_span, work.reconcile_span, [r0, r1]],
                });
            }
        }
    } else {
        timers = std::thread::scope(|scope| {
            let (work_tx, work_rx) = pipeline_channel::<EpochWork>();
            // Unbounded return lane: the worker hands drained buffers back
            // for reuse; it never holds more than PIPELINE_DEPTH of them.
            let (buf_tx, buf_rx) = mpsc::channel::<EpochWork>();
            let homes = &homes;
            let mut tim = tim;
            let mut timers = timers;
            let replay = scope.spawn(move || {
                for mut work in work_rx.iter() {
                    let r0 = us_mark(origin);
                    replay_epoch(&mut timers, homes, &mut tim, n_instrs, &mut work);
                    let r1 = us_mark(origin);
                    if let Some(tr) = tim.trace.as_deref_mut() {
                        tr.epoch_phases(EpochPhases {
                            phases: [work.collect_span, work.reconcile_span, [r0, r1]],
                        });
                    }
                    // Teardown race only: the functional side may already
                    // have dropped the return lane.
                    let _ = buf_tx.send(work);
                }
                timers
            });
            while pending(spus, &cursors, parts) {
                // Arena reuse: prefer a buffer the replay worker has
                // drained; allocate only while the pipeline is filling.
                let mut work = buf_rx
                    .try_recv()
                    .unwrap_or_else(|_| EpochWork::new(n_spus, n_slices));
                let m0 = us_mark(origin);
                collect_epoch(
                    spus, &mut cursors, parts, layout, nx, nxy, fun.view(), threads,
                    epoch_rounds, n_instrs, &mut work.traces,
                );
                apply_outs(&mut work.traces, &mut *fun.store);
                let m1 = us_mark(origin);
                reconcile_epoch(&mut tags, way_limit, threads, &mut work);
                let m2 = us_mark(origin);
                work.collect_span = [m0, m1];
                work.reconcile_span = [m1, m2];
                if work_tx.send(work).is_err() {
                    // The replay worker died; its panic resurfaces at join.
                    break;
                }
            }
            // Close the hand-off lane: the worker finishes the queued
            // epochs and hands the timers back.
            drop(work_tx);
            match replay.join() {
                Ok(timers) => timers,
                Err(payload) => {
                    panic!("epoch replay worker panicked: {}", panic_text(payload.as_ref()))
                }
            }
        });
    }

    // Reunite the halves for the serial coordinator work between steps.
    for (spu, timer) in spus.iter_mut().zip(timers) {
        spu.restore_timer(timer);
    }
    rt.mem.llc.restore_tag_banks(tags);
    Ok(())
}

/// More work this step? Purely functional state (SPU bindings + chunk
/// cursors), which is why the functional side can decide it while the
/// previous epoch is still replaying.
fn pending(spus: &[Spu], cursors: &[usize], parts: &[Vec<Chunk>]) -> bool {
    spus.iter()
        .enumerate()
        .any(|(i, s)| !s.is_done() || cursors[i] < parts[i].len())
}

/// Stage 1: parallel functional execution + trace generation, into the
/// (reused) per-SPU traces. Worker panics are contained per SPU and
/// re-raised with context after the scope joins.
fn collect_epoch(
    spus: &mut [Spu],
    cursors: &mut [usize],
    parts: &[Vec<Chunk>],
    layout: &SegmentLayout,
    nx: i64,
    nxy: i64,
    fun: FunMem<'_>,
    threads: usize,
    epoch_rounds: usize,
    n_instrs: usize,
    traces: &mut [SpuTrace],
) {
    let n_spus = spus.len();
    let cells: Vec<Mutex<(&mut Spu, &mut usize, &mut SpuTrace)>> = spus
        .iter_mut()
        .zip(cursors.iter_mut())
        .zip(traces.iter_mut())
        .map(|((s, c), t)| Mutex::new((s, c, t)))
        .collect();
    let cursor = AtomicUsize::new(0);
    let failures: Mutex<Vec<(usize, Box<dyn Any + Send>)>> = Mutex::new(Vec::new());
    let workers = threads.min(n_spus).max(1);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n_spus {
                    break;
                }
                let mut guard = lock_clean(&cells[i]);
                let cell = &mut *guard;
                let r = catch_unwind(AssertUnwindSafe(|| {
                    run_spu_epoch(
                        &mut *cell.0, &mut *cell.1, &parts[i], layout, nx, nxy, fun,
                        epoch_rounds, n_instrs, &mut *cell.2,
                    );
                }));
                if let Err(payload) = r {
                    lock_clean(&failures).push((i, payload));
                }
            });
        }
    });
    raise_failures(failures, "phase-1 functional fan-out", "SPU");
}

/// One SPU's share of stage 1: up to `epoch_rounds` functional groups,
/// binding chunks from its queue exactly as the serial loop does.
fn run_spu_epoch(
    spu: &mut Spu,
    cur: &mut usize,
    chunks: &[Chunk],
    layout: &SegmentLayout,
    nx: i64,
    nxy: i64,
    fun: FunMem<'_>,
    epoch_rounds: usize,
    n_instrs: usize,
    trace: &mut SpuTrace,
) {
    trace.reset();
    trace.instrs.reserve(epoch_rounds.min(8192) * n_instrs);
    let mut round: u32 = 0;
    while (round as usize) < epoch_rounds {
        if spu.is_done() {
            if *cur < chunks.len() {
                bind_chunk(spu, layout, chunks[*cur], nx, nxy).expect("stream binding failed");
                *cur += 1;
            } else {
                break;
            }
        }
        let _ran = spu.run_group_functional(fun, round, trace);
        debug_assert!(_ran, "bound chunk must yield a group");
        round += 1;
    }
}

/// Apply the staged functional output writes (disjoint across SPUs; never
/// read back within the pass, so ordering is irrelevant — apply in SPU
/// order for determinism of the store anyway). Runs on the functional side
/// of the pipeline: the replay worker never touches the store, which is
/// what makes applying epoch *e+1*'s writes while epoch *e* still replays
/// safe.
fn apply_outs(traces: &mut [SpuTrace], store: &mut SimStore) {
    for tr in traces {
        for run in tr.outs.drain(..) {
            store.write_slice(run.addr, &run.data);
        }
    }
}

/// Stage 2: per-slice tag reconciliation (parallel over slices). Gathers
/// each slice's queues and recycled outcome buffers on the coordinator
/// thread (O(slices × spus) pointer swaps), hands each worker one
/// [`TagBank`] plus plain owned data, then scatters the emptied queues
/// back to the traces (capacity reuse) and the filled outcome vectors
/// into the replay streams. Worker panics are contained per slice and
/// re-raised with context after the scope joins.
fn reconcile_epoch(tags: &mut [TagBank], way_limit: usize, threads: usize, work: &mut EpochWork) {
    let n_slices = tags.len();
    let tasks: Vec<Mutex<Option<(&mut TagBank, Vec<Vec<TagReq>>, Vec<Vec<TagOut>>)>>> = tags
        .iter_mut()
        .enumerate()
        .map(|(s, bank)| {
            let reqs: Vec<Vec<TagReq>> =
                work.traces.iter_mut().map(|t| std::mem::take(&mut t.tagq[s])).collect();
            let outs: Vec<Vec<TagOut>> = work
                .streams
                .iter_mut()
                .map(|per| {
                    let mut v = std::mem::take(&mut per[s].outs);
                    v.clear();
                    v
                })
                .collect();
            Mutex::new(Some((bank, reqs, outs)))
        })
        .collect();
    let done: Vec<Mutex<Option<(Vec<Vec<TagReq>>, Vec<Vec<TagOut>>)>>> =
        (0..n_slices).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let failures: Mutex<Vec<(usize, Box<dyn Any + Send>)>> = Mutex::new(Vec::new());
    let workers = threads.min(n_slices).max(1);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let s = cursor.fetch_add(1, Ordering::Relaxed);
                if s >= n_slices {
                    break;
                }
                let (bank, mut reqs, mut outs) =
                    lock_clean(&tasks[s]).take().expect("slice task claimed twice");
                let r = catch_unwind(AssertUnwindSafe(|| {
                    drain_slice_requests_into(bank, &reqs, way_limit, &mut outs);
                }));
                match r {
                    Ok(()) => {
                        for q in &mut reqs {
                            q.clear();
                        }
                        *lock_clean(&done[s]) = Some((reqs, outs));
                    }
                    Err(payload) => lock_clean(&failures).push((s, payload)),
                }
            });
        }
    });
    raise_failures(failures, "phase-2 tag reconciliation", "slice");
    for (s, slot) in done.into_iter().enumerate() {
        let (reqs, outs) = slot
            .into_inner()
            .unwrap_or_else(|p| p.into_inner())
            .expect("phase-2 worker skipped a slice");
        for (t, q) in work.traces.iter_mut().zip(reqs) {
            t.tagq[s] = q;
        }
        for (per, o) in work.streams.iter_mut().zip(outs) {
            per[s] = TagOutStream::new(o);
        }
    }
}

/// Stage 3: deterministic serial timing replay in global
/// `(round, spu, seq)` order, against the detached timers and the timing
/// half of the memory system only — the whole point of the split.
fn replay_epoch(
    timers: &mut [SpuTimer],
    homes: &[usize],
    tim: &mut TimingMem<'_>,
    n_instrs: usize,
    work: &mut EpochWork,
) {
    let n_spus = timers.len();
    let max_rounds = work.traces.iter().map(|t| t.groups).max().unwrap_or(0);
    for round in 0..max_rounds {
        for spu_id in 0..n_spus {
            if round < work.traces[spu_id].groups {
                let lo = round as usize * n_instrs;
                let recs = &work.traces[spu_id].instrs[lo..lo + n_instrs];
                timers[spu_id].replay_group(tim, homes[spu_id], recs, &mut work.streams[spu_id]);
            }
        }
    }
    debug_assert!(
        work.streams.iter().all(|per| per.iter().all(|s| s.fully_consumed())),
        "replay must consume every reconciled outcome"
    );
}

/// Lock that shrugs off poison: a worker panic is contained by
/// `catch_unwind` and re-raised with context by [`raise_failures`], so a
/// poisoned slot just means "some worker died" — the data itself is a
/// claimed-once task or an append-only failure list, both still sound.
/// Mirrors the harness sweep's supervisor-slot recovery.
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Best-effort text of a worker's panic payload (`&str` / `String`
/// payloads come through verbatim — the common `panic!`/`assert!` cases).
fn panic_text(payload: &(dyn Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Re-raise the first (lowest-id, for determinism) contained worker panic
/// with its phase and SPU/slice context attached.
fn raise_failures(failures: Mutex<Vec<(usize, Box<dyn Any + Send>)>>, phase: &str, unit: &str) {
    let mut failures = failures.into_inner().unwrap_or_else(|p| p.into_inner());
    if failures.is_empty() {
        return;
    }
    failures.sort_by_key(|(id, _)| *id);
    let (id, payload) = failures.swap_remove(0);
    panic!("{phase} worker panicked on {unit} {id}: {}", panic_text(payload.as_ref()));
}

/// Microseconds elapsed since the tracer origin (saturating at u64 — a
/// trace does not run for half a million years); 0 when untraced.
fn us_mark(origin: Option<Instant>) -> u64 {
    origin.map(us_since).unwrap_or(0)
}

fn us_since(origin: Instant) -> u64 {
    origin.elapsed().as_micros() as u64
}

/// Drain one slice's queued messages in deterministic `(round, spu, seq)`
/// order — the exact interleaving the serial round-robin loop applies —
/// against the slice's private tag bank. Returns per-SPU outcome streams
/// in issue order.
pub(crate) fn drain_slice_requests(
    bank: &mut TagBank,
    reqs: &[Vec<TagReq>],
    way_limit: usize,
) -> Vec<Vec<TagOut>> {
    let mut outs: Vec<Vec<TagOut>> = reqs.iter().map(|_| Vec::new()).collect();
    drain_slice_requests_into(bank, reqs, way_limit, &mut outs);
    outs
}

/// [`drain_slice_requests`] into caller-provided (recycled) outcome
/// buffers — the allocation-free path the epoch loop runs.
pub(crate) fn drain_slice_requests_into(
    bank: &mut TagBank,
    reqs: &[Vec<TagReq>],
    way_limit: usize,
    outs: &mut [Vec<TagOut>],
) {
    debug_assert_eq!(reqs.len(), outs.len());
    let n = reqs.len();
    for (q, o) in reqs.iter().zip(outs.iter_mut()) {
        debug_assert!(o.is_empty(), "recycled outcome buffer not cleared");
        o.reserve(q.len());
    }
    let mut pos = vec![0usize; n];
    let Some(max_round) = reqs.iter().filter_map(|q| q.last().map(|r| r.round)).max() else {
        return;
    };
    for round in 0..=max_round {
        for spu in 0..n {
            while pos[spu] < reqs[spu].len() && reqs[spu][pos[spu]].round == round {
                let r = reqs[spu][pos[spu]];
                pos[spu] += 1;
                outs[spu].push(apply_tag_req(bank, &r, way_limit));
            }
        }
    }
    debug_assert!(
        pos.iter().zip(reqs).all(|(&p, q)| p == q.len()),
        "per-SPU queues must be sorted by round"
    );
}

/// Apply one message to the bank — the same access sequence the serial
/// path runs inline. Routed through [`TagBank::tag_access`] /
/// [`TagBank::tag_access_second`] so temporal-block wavefront residency
/// (and its avoided-fill accounting) applies identically in all engines.
fn apply_tag_req(bank: &mut TagBank, r: &TagReq, way_limit: usize) -> TagOut {
    if r.line1 != NO_LINE {
        // §4.1 merged dual-tag access: first line is the data access, the
        // second a tag-only match.
        let o0 = bank.tag_access(r.line0, false, way_limit);
        let o1 = bank.tag_access_second(r.line1, way_limit);
        TagOut::pair(o0, o1)
    } else {
        TagOut::single(bank.tag_access(r.line0, r.write, way_limit))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(round: u32, line0: u64) -> TagReq {
        TagReq { round, line0, line1: NO_LINE, write: false }
    }

    #[test]
    fn reconciliation_orders_by_round_before_spu_id() {
        // SPU 1 touched the line in round 0; SPU 0 only in round 1. The
        // earlier *round* must apply first even though SPU 0 has the lower
        // id — so SPU 1 takes the cold miss and SPU 0 hits.
        let mut bank = TagBank::new(128, 2, 64);
        let reqs = vec![vec![req(1, 0x40)], vec![req(0, 0x40)]];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(!outs[1][0].hit[0], "round-0 message is the cold miss");
        assert!(outs[0][0].hit[0], "round-1 message sees the line resident");
    }

    #[test]
    fn reconciliation_same_round_orders_by_spu_then_seq() {
        // Within one round, all of SPU 0's messages (in issue order)
        // precede SPU 1's — SPU 0 fills both ways before SPU 1 hits.
        let mut bank = TagBank::new(128, 2, 64);
        let reqs = vec![vec![req(0, 0x80), req(0, 0xC0)], vec![req(0, 0x80)]];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(!outs[0][0].hit[0] && !outs[0][1].hit[0]);
        assert!(outs[1][0].hit[0], "later SPU id sees earlier fills");
    }

    #[test]
    fn reconciliation_reports_writebacks_in_order() {
        // 1 set × 2 ways: SPU 0 dirties line 1 (write), SPU 1 then fills
        // two more lines; the second fill evicts the dirty line and must
        // report its writeback.
        let mut bank = TagBank::new(128, 2, 64);
        let reqs = vec![
            vec![TagReq { round: 0, line0: 0x40, line1: NO_LINE, write: true }],
            vec![req(1, 0x80), req(1, 0xC0)],
        ];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(!outs[0][0].hit[0]);
        assert_eq!(outs[1][1].wb[0], 1, "dirty line 1 written back by the eviction");
    }

    #[test]
    fn merged_requests_apply_both_tags() {
        let mut bank = TagBank::new(2 * 1024 * 1024, 16, 64);
        let reqs =
            vec![vec![TagReq { round: 0, line0: 0x0, line1: 0x40, write: false }, req(1, 0x40)]];
        let outs = drain_slice_requests(&mut bank, &reqs, 16);
        assert!(!outs[0][0].hit[0] && !outs[0][0].hit[1], "both lines cold-missed");
        assert!(outs[0][1].hit[0], "second tag line was installed");
    }

    #[test]
    fn resident_bank_avoids_fills_and_installs_nothing() {
        // Temporal blocking: a wavefront-resident bank serves every
        // message as an avoided fill — no tag install, no writeback —
        // through the same drain path the live engine uses.
        let mut bank = TagBank::new(128, 2, 64);
        bank.wavefront_resident = true;
        let reqs = vec![vec![
            req(0, 0x40),
            TagReq { round: 1, line0: 0x80, line1: 0xC0, write: false },
        ]];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(outs[0][0].hit[0] && outs[0][0].avoided[0]);
        assert!(outs[0][1].avoided[0] && outs[0][1].avoided[1]);
        assert_eq!(outs[0][1].wb, [NO_LINE, NO_LINE]);
        assert_eq!(bank.avoided_fills, 3);
        assert!(!bank.cache.probe(0x40), "resident drain must not install tags");
    }

    #[test]
    fn empty_queues_drain_to_empty_streams() {
        let mut bank = TagBank::new(128, 2, 64);
        let reqs: Vec<Vec<TagReq>> = vec![Vec::new(), Vec::new()];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(outs.iter().all(|o| o.is_empty()));
    }

    #[test]
    fn drain_into_recycled_buffers_matches_fresh_drain() {
        // The arena path: draining into recycled (cleared) buffers must
        // produce exactly what the allocating drain produces, against
        // identically warmed banks.
        let reqs = vec![
            vec![req(0, 0x40), req(1, 0x80)],
            vec![TagReq { round: 0, line0: 0x80, line1: 0xC0, write: true }],
        ];
        let mut bank_a = TagBank::new(256, 2, 64);
        let fresh = drain_slice_requests(&mut bank_a, &reqs, 2);
        let mut bank_b = TagBank::new(256, 2, 64);
        // Pre-dirty the recycled buffers with junk capacity, then clear —
        // exactly what reconcile_epoch hands the drain.
        let mut reused: Vec<Vec<TagOut>> = (0..2)
            .map(|_| {
                let mut v = Vec::with_capacity(8);
                v.push(TagOut::single(crate::mem::cache::AccessOutcome {
                    hit: true,
                    writeback: None,
                    prefetch_hit: false,
                    avoided: false,
                }));
                v.clear();
                v
            })
            .collect();
        drain_slice_requests_into(&mut bank_b, &reqs, 2, &mut reused);
        for (f, r) in fresh.iter().zip(&reused) {
            assert_eq!(f.len(), r.len());
            for (a, b) in f.iter().zip(r) {
                assert_eq!(a.hit, b.hit);
                assert_eq!(a.wb, b.wb);
                assert_eq!(a.avoided, b.avoided);
            }
        }
        assert_eq!(bank_a.cache.stats, bank_b.cache.stats, "banks warmed identically");
    }

    #[test]
    fn pipeline_channel_bounds_in_flight_epochs() {
        // One epoch already handed to the worker plus one queued is the
        // cap: a third in-flight epoch must be refused until the worker
        // drains one.
        let (tx, rx) = pipeline_channel::<usize>();
        tx.try_send(0).expect("first epoch hands off");
        let _replaying = rx.recv().expect("worker takes epoch 0");
        tx.try_send(1).expect("second epoch queues behind the replay");
        assert!(tx.try_send(2).is_err(), "third in-flight epoch exceeds PIPELINE_DEPTH");
        assert_eq!(PIPELINE_DEPTH, 2);
    }
}
