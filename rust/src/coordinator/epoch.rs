//! Epoch-parallel intra-run SPU execution.
//!
//! One serial "round" of the engine loop runs one vector group on every
//! SPU. The epoch engine executes `epoch_rounds` such rounds as one epoch
//! in three phases (see `rust/DESIGN-parallel.md` for the full protocol
//! and the determinism argument):
//!
//! 1. **Functional fan-out** (parallel over SPUs): every SPU runs its
//!    groups functionally — input loads read the step-immutable input
//!    array, output writes are staged per SPU — while queueing each LLC
//!    tag access as an *epoch message* tagged `(round, spu, seq)` and
//!    recording the per-instruction request geometry. (Multi-pass
//!    accumulator streams also read the *output* array, but only the
//!    elements the reading group itself is about to overwrite — written
//!    by the previous pass, never within the current `run_step` — so the
//!    step-immutability argument carries over pass by pass.)
//! 2. **Tag reconciliation** (parallel over slices): each slice's worker
//!    owns that slice's [`SliceState`] outright and drains its incoming
//!    messages in `(round, spu, seq)` order — exactly the order the serial
//!    round-robin interleaving would have applied them — producing the tag
//!    outcomes (hit / writeback).
//! 3. **Timing replay** (serial, cheap): the exact serial timing
//!    arithmetic (issue, load queue, slice ports, NoC latencies, DRAM
//!    channels) replays in global `(round, spu, seq)` order with the
//!    reconciled outcomes injected — no tag scans left on this path.
//!
//! Tag outcomes depend only on per-slice access *order* (never on
//! timestamps), and timestamps depend only on outcomes plus processing
//! order — which phase 3 reproduces exactly. Hence serial and
//! epoch-parallel execution are byte-identical; `coordinator::engine`'s
//! identity tests enforce this across kernels, mappings, thread counts,
//! and epoch sizes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::spu::sharded::{SpuTrace, TagOut, TagOutStream, TagReq, NO_LINE};
use crate::spu::{SliceState, Spu};
use crate::trace::{EpochPhases, TraceSink};

use super::api::CasperRuntime;
use super::engine::{bind_chunk, Chunk};
use super::layout::SegmentLayout;

/// Rounds per epoch: large enough to amortize worker spawn + phase
/// hand-off, small enough to bound trace memory (~tens of MB).
pub(crate) const DEFAULT_EPOCH_ROUNDS: usize = 2048;

/// Run one full time step of the engine loop with `threads` workers,
/// epoch by epoch, binding chunks from `parts` exactly as the serial
/// round-robin loop does.
pub(crate) fn run_step(
    rt: &mut CasperRuntime,
    parts: &[Vec<Chunk>],
    layout: &SegmentLayout,
    nx: i64,
    nxy: i64,
    threads: usize,
    epoch_rounds: usize,
) -> Result<()> {
    let n_spus = rt.spus.len();
    let mut cursors = vec![0usize; n_spus];
    let epoch_rounds = epoch_rounds.max(1);
    loop {
        let pending = rt
            .spus
            .iter()
            .enumerate()
            .any(|(i, s)| !s.is_done() || cursors[i] < parts[i].len());
        if !pending {
            break;
        }
        run_epoch(rt, parts, &mut cursors, layout, nx, nxy, threads, epoch_rounds);
    }
    Ok(())
}

/// Execute up to `epoch_rounds` rounds: phase 1 (parallel over SPUs),
/// phase 2 (parallel over slices), phase 3 (serial replay).
fn run_epoch(
    rt: &mut CasperRuntime,
    parts: &[Vec<Chunk>],
    cursors: &mut [usize],
    layout: &SegmentLayout,
    nx: i64,
    nxy: i64,
    threads: usize,
    epoch_rounds: usize,
) {
    let n_spus = rt.spus.len();
    let n_slices = rt.cfg.llc.slices;
    let n_instrs = rt.spus[0].program().instrs.len();

    // Wall-clock phase spans (`--trace`): the three phases have no
    // cycle-domain duration (they are an implementation artifact, not
    // simulated time), so they are recorded as real-µs offsets from the
    // tracer's origin. Observation only — `Instant` reads never touch
    // simulation state. `origin` is `None` without a tracer.
    let origin = rt.mem.trace.as_deref().map(|t| t.origin());
    let m0 = origin.map(us_since);

    // ---- Phase 1: parallel functional execution + trace generation ----
    let slots: Vec<Mutex<Option<SpuTrace>>> = (0..n_spus).map(|_| Mutex::new(None)).collect();
    {
        let mem = &rt.mem;
        let cells: Vec<Mutex<(&mut Spu, usize)>> = rt
            .spus
            .iter_mut()
            .zip(cursors.iter())
            .map(|(s, &c)| Mutex::new((s, c)))
            .collect();
        let cursor = AtomicUsize::new(0);
        let workers = threads.min(n_spus).max(1);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n_spus {
                        break;
                    }
                    let mut guard = cells[i].lock().expect("spu cell poisoned");
                    let cell = &mut *guard;
                    let spu: &mut Spu = &mut *cell.0;
                    let cur = &mut cell.1;
                    let mut trace = SpuTrace::new(n_slices);
                    trace.instrs.reserve(epoch_rounds.min(8192) * n_instrs);
                    let mut round: u32 = 0;
                    while (round as usize) < epoch_rounds {
                        if spu.is_done() {
                            if *cur < parts[i].len() {
                                bind_chunk(spu, layout, parts[i][*cur], nx, nxy)
                                    .expect("stream binding failed");
                                *cur += 1;
                            } else {
                                break;
                            }
                        }
                        let _ran = spu.run_group_functional(mem, round, &mut trace);
                        debug_assert!(_ran, "bound chunk must yield a group");
                        round += 1;
                    }
                    *slots[i].lock().expect("trace slot poisoned") = Some(trace);
                });
            }
        });
        for (i, cell) in cells.into_iter().enumerate() {
            cursors[i] = cell.into_inner().expect("spu cell poisoned").1;
        }
    }
    let mut traces: Vec<SpuTrace> = slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .expect("trace slot poisoned")
                .expect("phase-1 worker skipped an SPU")
        })
        .collect();

    // Apply the staged functional output writes (disjoint across SPUs;
    // never read back within the step, so ordering is irrelevant — apply
    // in SPU order for determinism of the store anyway).
    for tr in &mut traces {
        for run in tr.outs.drain(..) {
            rt.mem.store.write_slice(run.addr, &run.data);
        }
    }
    let m1 = origin.map(us_since);

    // ---- Phase 2: per-slice tag reconciliation (parallel over slices) ----
    let way_limit = rt.mem.llc.way_limit();
    let banks = rt.mem.llc.take_banks();
    debug_assert_eq!(banks.len(), n_slices);
    // per_slice[s][spu] = that SPU's queued messages for slice s.
    let mut per_slice: Vec<Vec<Vec<TagReq>>> =
        (0..n_slices).map(|_| Vec::with_capacity(n_spus)).collect();
    for tr in &mut traces {
        for (s, q) in tr.tagq.iter_mut().enumerate() {
            per_slice[s].push(std::mem::take(q));
        }
    }
    let tasks: Vec<Mutex<Option<(SliceState, Vec<Vec<TagReq>>)>>> = banks
        .into_iter()
        .zip(per_slice)
        .map(|(b, q)| Mutex::new(Some((b, q))))
        .collect();
    let out_slots: Vec<Mutex<Option<(SliceState, Vec<Vec<TagOut>>)>>> =
        (0..n_slices).map(|_| Mutex::new(None)).collect();
    {
        let cursor = AtomicUsize::new(0);
        let workers = threads.min(n_slices).max(1);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let s = cursor.fetch_add(1, Ordering::Relaxed);
                    if s >= n_slices {
                        break;
                    }
                    let (mut bank, reqs) = tasks[s]
                        .lock()
                        .expect("slice task poisoned")
                        .take()
                        .expect("slice task claimed twice");
                    let outs = drain_slice_requests(&mut bank, &reqs, way_limit);
                    *out_slots[s].lock().expect("slice out slot poisoned") = Some((bank, outs));
                });
            }
        });
    }
    let mut restored: Vec<SliceState> = Vec::with_capacity(n_slices);
    let mut outs_by_slice: Vec<Vec<Vec<TagOut>>> = Vec::with_capacity(n_slices);
    for slot in out_slots {
        let (bank, outs) = slot
            .into_inner()
            .expect("slice out slot poisoned")
            .expect("phase-2 worker skipped a slice");
        restored.push(bank);
        outs_by_slice.push(outs);
    }
    rt.mem.llc.restore_banks(restored);

    // Transpose into per-SPU outcome streams: streams[spu][slice].
    let mut streams: Vec<Vec<TagOutStream>> =
        (0..n_spus).map(|_| Vec::with_capacity(n_slices)).collect();
    for outs in outs_by_slice {
        for (spu, v) in outs.into_iter().enumerate() {
            streams[spu].push(TagOutStream::new(v));
        }
    }
    let m2 = origin.map(us_since);

    // ---- Phase 3: deterministic serial timing replay ----
    let groups: Vec<u32> = traces.iter().map(|t| t.groups).collect();
    let max_rounds = groups.iter().copied().max().unwrap_or(0);
    for round in 0..max_rounds {
        for spu_id in 0..n_spus {
            if round < groups[spu_id] {
                let lo = round as usize * n_instrs;
                let recs = &traces[spu_id].instrs[lo..lo + n_instrs];
                let spu = &mut rt.spus[spu_id];
                spu.replay_group_timing(&mut rt.mem, recs, &mut streams[spu_id]);
            }
        }
    }
    debug_assert!(
        streams.iter().all(|per| per.iter().all(|s| s.fully_consumed())),
        "replay must consume every reconciled outcome"
    );

    let m3 = origin.map(us_since);
    if let Some(tr) = rt.mem.trace.as_deref_mut() {
        let (m0, m1, m2, m3) = (m0.unwrap(), m1.unwrap(), m2.unwrap(), m3.unwrap());
        tr.epoch_phases(EpochPhases { phases: [[m0, m1], [m1, m2], [m2, m3]] });
    }
}

/// Microseconds elapsed since `origin` (saturating at u64 — a trace does
/// not run for half a million years).
fn us_since(origin: std::time::Instant) -> u64 {
    origin.elapsed().as_micros() as u64
}

/// Drain one slice's queued messages in deterministic `(round, spu, seq)`
/// order — the exact interleaving the serial round-robin loop applies —
/// against the slice's private tag bank. Returns per-SPU outcome streams
/// in issue order.
pub(crate) fn drain_slice_requests(
    bank: &mut SliceState,
    reqs: &[Vec<TagReq>],
    way_limit: usize,
) -> Vec<Vec<TagOut>> {
    let n = reqs.len();
    let mut pos = vec![0usize; n];
    let mut outs: Vec<Vec<TagOut>> = reqs.iter().map(|q| Vec::with_capacity(q.len())).collect();
    let Some(max_round) = reqs.iter().filter_map(|q| q.last().map(|r| r.round)).max() else {
        return outs;
    };
    for round in 0..=max_round {
        for spu in 0..n {
            while pos[spu] < reqs[spu].len() && reqs[spu][pos[spu]].round == round {
                let r = reqs[spu][pos[spu]];
                pos[spu] += 1;
                outs[spu].push(apply_tag_req(bank, &r, way_limit));
            }
        }
    }
    debug_assert!(
        pos.iter().zip(reqs).all(|(&p, q)| p == q.len()),
        "per-SPU queues must be sorted by round"
    );
    outs
}

/// Apply one message to the bank — the same access sequence the serial
/// path runs inline. Routed through [`SliceState::tag_access`] /
/// [`SliceState::tag_access_second`] so temporal-block wavefront
/// residency (and its avoided-fill accounting) applies identically in
/// both engines.
fn apply_tag_req(bank: &mut SliceState, r: &TagReq, way_limit: usize) -> TagOut {
    if r.line1 != NO_LINE {
        // §4.1 merged dual-tag access: first line is the data access, the
        // second a tag-only match.
        let o0 = bank.tag_access(r.line0, false, way_limit);
        let o1 = bank.tag_access_second(r.line1, way_limit);
        TagOut::pair(o0, o1)
    } else {
        TagOut::single(bank.tag_access(r.line0, r.write, way_limit))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(round: u32, line0: u64) -> TagReq {
        TagReq { round, line0, line1: NO_LINE, write: false }
    }

    #[test]
    fn reconciliation_orders_by_round_before_spu_id() {
        // SPU 1 touched the line in round 0; SPU 0 only in round 1. The
        // earlier *round* must apply first even though SPU 0 has the lower
        // id — so SPU 1 takes the cold miss and SPU 0 hits.
        let mut bank = SliceState::new(128, 2, 64);
        let reqs = vec![vec![req(1, 0x40)], vec![req(0, 0x40)]];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(!outs[1][0].hit[0], "round-0 message is the cold miss");
        assert!(outs[0][0].hit[0], "round-1 message sees the line resident");
    }

    #[test]
    fn reconciliation_same_round_orders_by_spu_then_seq() {
        // Within one round, all of SPU 0's messages (in issue order)
        // precede SPU 1's — SPU 0 fills both ways before SPU 1 hits.
        let mut bank = SliceState::new(128, 2, 64);
        let reqs = vec![vec![req(0, 0x80), req(0, 0xC0)], vec![req(0, 0x80)]];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(!outs[0][0].hit[0] && !outs[0][1].hit[0]);
        assert!(outs[1][0].hit[0], "later SPU id sees earlier fills");
    }

    #[test]
    fn reconciliation_reports_writebacks_in_order() {
        // 1 set × 2 ways: SPU 0 dirties line 1 (write), SPU 1 then fills
        // two more lines; the second fill evicts the dirty line and must
        // report its writeback.
        let mut bank = SliceState::new(128, 2, 64);
        let reqs = vec![
            vec![TagReq { round: 0, line0: 0x40, line1: NO_LINE, write: true }],
            vec![req(1, 0x80), req(1, 0xC0)],
        ];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(!outs[0][0].hit[0]);
        assert_eq!(outs[1][1].wb[0], 1, "dirty line 1 written back by the eviction");
    }

    #[test]
    fn merged_requests_apply_both_tags() {
        let mut bank = SliceState::new(2 * 1024 * 1024, 16, 64);
        let reqs =
            vec![vec![TagReq { round: 0, line0: 0x0, line1: 0x40, write: false }, req(1, 0x40)]];
        let outs = drain_slice_requests(&mut bank, &reqs, 16);
        assert!(!outs[0][0].hit[0] && !outs[0][0].hit[1], "both lines cold-missed");
        assert!(outs[0][1].hit[0], "second tag line was installed");
    }

    #[test]
    fn resident_bank_avoids_fills_and_installs_nothing() {
        // Temporal blocking: a wavefront-resident bank serves every
        // message as an avoided fill — no tag install, no writeback —
        // through the same drain path the live engine uses.
        let mut bank = SliceState::new(128, 2, 64);
        bank.wavefront_resident = true;
        let reqs = vec![vec![
            req(0, 0x40),
            TagReq { round: 1, line0: 0x80, line1: 0xC0, write: false },
        ]];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(outs[0][0].hit[0] && outs[0][0].avoided[0]);
        assert!(outs[0][1].avoided[0] && outs[0][1].avoided[1]);
        assert_eq!(outs[0][1].wb, [NO_LINE, NO_LINE]);
        assert_eq!(bank.avoided_fills, 3);
        assert!(!bank.cache.probe(0x40), "resident drain must not install tags");
    }

    #[test]
    fn empty_queues_drain_to_empty_streams() {
        let mut bank = SliceState::new(128, 2, 64);
        let reqs: Vec<Vec<TagReq>> = vec![Vec::new(), Vec::new()];
        let outs = drain_slice_requests(&mut bank, &reqs, 2);
        assert!(outs.iter().all(|o| o.is_empty()));
    }
}
