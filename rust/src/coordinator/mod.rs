//! The Casper coordinator: the paper's programming model (Table 1) and
//! the execution engine that drives the SPUs.
//!
//! [`CasperRuntime`] exposes the six API calls of Table 1
//! (`initStencilSegment`, `initStencilcode`, `initConstant`, `initStream`,
//! `setNElements`, `startAccelerator`). [`run_casper`] is the high-level
//! driver used by the experiments: it lays out the arrays in the stencil
//! segment (Fig 8), compiles the stencil with the
//! [`ProgramBuilder`](crate::isa::ProgramBuilder), partitions work by
//! output-block ownership (§4.2), runs the SPUs, patches the halo
//! (host-side boundary policy, as in the golden reference), and returns
//! cycles + event counts + the functional result.

pub mod api;
pub mod engine;
pub(crate) mod epoch;
pub mod layout;
pub mod metrics;

pub use api::CasperRuntime;
pub use engine::{
    default_epoch_pipeline, default_epoch_rounds, default_plan_strategy, default_spu_threads,
    run_casper, run_casper_spec, run_casper_spec_traced, run_casper_with, CasperOptions,
};
pub use epoch::{pipeline_channel, PIPELINE_DEPTH};
pub use layout::SegmentLayout;
pub use metrics::{imbalance, ReductionResult, RunStats};
