//! Stencil-segment layout (Fig 8): two arrays (input A, output B) placed
//! so that the same grid point of both arrays maps to the same LLC slice.

use crate::config::LlcConfig;
use crate::stencil::Domain;

/// Where A and B live inside the stencil segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SegmentLayout {
    /// Segment base physical address.
    pub seg_base: u64,
    /// Total segment bytes.
    pub seg_bytes: u64,
    /// Byte offset of array A (always 0).
    pub a_off: u64,
    /// Byte offset of array B: the array stride.
    pub b_off: u64,
    /// Bytes actually used by one array.
    pub array_bytes: u64,
}

impl SegmentLayout {
    /// Compute the layout for a domain. The array stride is rounded up to
    /// `block_bytes × slices` so that A and B block-decompose identically
    /// (grid point i of A and of B share a slice — the Fig 8 property).
    pub fn for_domain(domain: &Domain, llc: &LlcConfig) -> SegmentLayout {
        let array_bytes = domain.array_bytes() as u64;
        let round = (llc.stencil_block_bytes * llc.slices) as u64;
        let stride = array_bytes.div_ceil(round) * round;
        SegmentLayout {
            seg_base: 0, // bound at alloc time
            seg_bytes: 2 * stride,
            a_off: 0,
            b_off: stride,
            array_bytes,
        }
    }

    /// Bind to the allocated segment base.
    pub fn bind(mut self, seg_base: u64) -> SegmentLayout {
        self.seg_base = seg_base;
        self
    }

    pub fn a_base(&self) -> u64 {
        self.seg_base + self.a_off
    }

    pub fn b_base(&self) -> u64 {
        self.seg_base + self.b_off
    }

    /// Byte address of element `i` in array A / B.
    pub fn a_addr(&self, i: u64) -> u64 {
        self.a_base() + i * 8
    }

    pub fn b_addr(&self, i: u64) -> u64 {
        self.b_base() + i * 8
    }

    /// Swap the roles of A and B (time-step ping-pong).
    pub fn swapped(&self) -> SegmentLayout {
        SegmentLayout { a_off: self.b_off, b_off: self.a_off, ..*self }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingPolicy, SimConfig};
    use crate::mapping::{SliceMapper, StencilSegment};
    use crate::stencil::StencilKind;
    use crate::config::SizeClass;

    #[test]
    fn fig8_property_same_point_same_slice() {
        // For every size class and kernel: A[i] and B[i] map to the same
        // LLC slice under the stencil hash.
        let cfg = SimConfig::default();
        for kind in StencilKind::ALL {
            for level in SizeClass::ALL {
                let d = Domain::for_level(kind, level);
                let layout = SegmentLayout::for_domain(&d, &cfg.llc).bind(0x1000_0000);
                let mut m = SliceMapper::new(&cfg.llc, MappingPolicy::StencilSegment);
                m.set_segment(StencilSegment::new(layout.seg_base, layout.seg_bytes));
                for i in (0..d.points() as u64).step_by(4097) {
                    assert_eq!(
                        m.slice_of(layout.a_addr(i)),
                        m.slice_of(layout.b_addr(i)),
                        "{kind} {level} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn stride_is_block_multiple() {
        let cfg = SimConfig::default();
        let d = Domain::new(512, 256, 1); // 1 MB array
        let l = SegmentLayout::for_domain(&d, &cfg.llc);
        assert_eq!(l.b_off % (128 * 1024 * 16) as u64, 0);
        assert!(l.b_off >= d.array_bytes() as u64);
    }

    #[test]
    fn swap_exchanges_arrays() {
        let cfg = SimConfig::default();
        let d = Domain::new(1024, 1024, 1);
        let l = SegmentLayout::for_domain(&d, &cfg.llc).bind(0x1000_0000);
        let s = l.swapped();
        assert_eq!(s.a_base(), l.b_base());
        assert_eq!(s.b_base(), l.a_base());
        assert_eq!(s.swapped(), l);
    }
}
