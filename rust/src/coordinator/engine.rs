//! The experiment-level Casper driver: array layout, work partitioning by
//! output-block ownership (§4.2), chunked SPU execution, boundary policy,
//! and time stepping.

use anyhow::Result;

use crate::config::SimConfig;
use crate::isa::{PlanStrategy, ProgramBuilder};
use crate::spu::Spu;
use crate::stencil::{Domain, KernelSpec, StencilDesc, StencilKind};
use crate::trace::{TraceSink, Tracer};

use super::api::CasperRuntime;
use super::epoch;
use super::layout::SegmentLayout;
use super::metrics::RunStats;

/// Default intra-run SPU worker threads: `CASPER_SPU_THREADS` if set to a
/// positive integer (the CI matrix runs the whole test suite under both
/// engines this way), else 1 (the serial path).
pub fn default_spu_threads() -> usize {
    std::env::var("CASPER_SPU_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Default rounds per epoch: `CASPER_EPOCH_ROUNDS` if set to a positive
/// integer, else the built-in default (2048). Results are independent
/// of the value — it only trades hand-off overhead against epoch memory.
pub fn default_epoch_rounds() -> usize {
    std::env::var("CASPER_EPOCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(epoch::DEFAULT_EPOCH_ROUNDS)
}

/// Default for [`CasperOptions::pipeline`]: `CASPER_EPOCH_PIPELINE=0`
/// disables the epoch pipeline (the CI matrix runs both settings), any
/// other value — including unset — enables it. The pipeline only engages
/// when the epoch engine itself does (`spu_threads > 1`), and results are
/// byte-identical either way.
pub fn default_epoch_pipeline() -> bool {
    std::env::var("CASPER_EPOCH_PIPELINE").map_or(true, |s| s != "0")
}

/// Default pass-plan strategy: `CASPER_PLAN` if set to a recognized
/// strategy name (`greedy` | `optimized` — the CI byte-stability leg runs
/// both), else [`PlanStrategy::Optimized`]. The optimizing planner is
/// order-preserving unless reordering strictly cuts the pass count, so
/// flipping this only changes *results* for kernels where it also changes
/// the pass count (see `docs/KERNELS.md`, "Pass planning").
pub fn default_plan_strategy() -> PlanStrategy {
    std::env::var("CASPER_PLAN")
        .ok()
        .and_then(|s| PlanStrategy::parse(&s))
        .unwrap_or(PlanStrategy::Optimized)
}

/// Options for ablation runs (Fig 14 and the unaligned-hardware study)
/// and for the intra-run execution mode.
#[derive(Debug, Clone, Copy)]
pub struct CasperOptions {
    /// Model the §4.1 unaligned-load hardware (default true).
    pub unaligned_hw: bool,
    /// Warm the LLC with the working set before timing (default true —
    /// the paper's L2/LLC-sized experiments assume the tiled working set
    /// already resides on chip; DRAM-sized sets exceed capacity, so
    /// warming leaves only the tail resident, which is equally realistic).
    pub warm_llc: bool,
    /// Seed for the input grid.
    pub seed: u64,
    /// Worker threads for intra-run SPU execution: `1` = the serial
    /// round-robin path, `> 1` = the epoch-parallel engine. Results are
    /// byte-identical either way (see `rust/DESIGN-parallel.md`).
    pub spu_threads: usize,
    /// Rounds per epoch in the parallel engine (bounds trace memory;
    /// results are independent of the value).
    pub epoch_rounds: usize,
    /// Pipelined epochs (`spu_threads > 1` only): overlap each epoch's
    /// serial timing replay with the next epoch's functional fan-out and
    /// tag reconciliation on a dedicated worker. Byte-identical to the
    /// phased engine (see `rust/DESIGN-parallel.md`, "Pipelined epochs").
    pub pipeline: bool,
    /// Temporal block depth `T` (`--temporal-block`): the sweep keeps `T`
    /// wavefronts resident per slice, so only every `T`-th step probes
    /// the LLC tags / DRAM — intermediate steps recompute halos instead
    /// of re-fetching them. `1` (the default) is plain chaining. The
    /// functional step sequence is unchanged, so the final grid is
    /// bitwise identical for every `T` (pinned by test).
    pub temporal_block: usize,
    /// Pass-plan strategy (`--plan` / `CASPER_PLAN`): how multi-pass
    /// kernels are partitioned into programs. The blackbox equivalence
    /// harness ([`crate::verify`], `casper verify`) checks both
    /// strategies against the plan-aware golden oracle.
    pub plan: PlanStrategy,
}

impl Default for CasperOptions {
    fn default() -> Self {
        CasperOptions {
            unaligned_hw: true,
            warm_llc: true,
            seed: 0xCA5_9E12,
            spu_threads: default_spu_threads(),
            epoch_rounds: default_epoch_rounds(),
            pipeline: default_epoch_pipeline(),
            temporal_block: 1,
            plan: default_plan_strategy(),
        }
    }
}

/// One contiguous piece of work for one SPU: `n` output elements starting
/// at linear element index `start`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    pub start: u64,
    pub n: u64,
}

/// Linear interior runs of a domain (see DESIGN.md §5): one run per
/// interior z-slab, starting at the first fully-interior element and
/// covering the slab's interior rows contiguously. X-edge elements inside
/// a run are computed (streamed over) and patched afterwards — the
/// streaming execution model of §3.2.
pub fn interior_runs(desc: &StencilDesc, domain: &Domain) -> Vec<Chunk> {
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (domain.nx as u64, domain.ny as u64, domain.nz as u64);
    let (rx, ry, rz) = (rx as u64, ry as u64, rz as u64);
    // Degenerate domains (any dimension ≤ its halo) have no interior
    // points: no runs, rather than underflowing the run-length math.
    if nx <= 2 * rx || ny <= 2 * ry || nz <= 2 * rz {
        return Vec::new();
    }
    let mut runs = Vec::new();
    for z in rz..nz - rz {
        let start = (z * ny + ry) * nx + rx;
        let n = (ny - 2 * ry) * nx - 2 * rx;
        runs.push(Chunk { start, n });
    }
    runs
}

/// Split runs into per-SPU chunks by *output-block ownership*: each SPU
/// owns the output elements whose B-address falls in a 128 kB block homed
/// on its slice (§4.2). Returns `chunks[spu] = Vec<Chunk>`.
pub fn partition(
    runs: &[Chunk],
    layout: &SegmentLayout,
    mapper: &crate::mapping::SliceMapper,
    n_spus: usize,
) -> Vec<Vec<Chunk>> {
    let mut per_spu: Vec<Vec<Chunk>> = vec![Vec::new(); n_spus];
    let block_elems = mapper.block_bytes() / 8;
    for run in runs {
        let mut e = run.start;
        let end = run.start + run.n;
        while e < end {
            let slice = mapper.slice_of(layout.b_addr(e));
            // Elements to the next block boundary of the OUTPUT array.
            let off_in_block = (layout.b_addr(e) - layout.seg_base) / 8 % block_elems;
            let to_boundary = block_elems - off_in_block;
            let n = to_boundary.min(end - e);
            // Coalesce with the previous chunk when contiguous.
            match per_spu[slice].last_mut() {
                Some(prev) if prev.start + prev.n == e => prev.n += n,
                _ => per_spu[slice].push(Chunk { start: e, n }),
            }
            e += n;
        }
    }
    per_spu
}

/// Run one preset stencil on Casper for `steps` Jacobi iterations and
/// return the cycle count, event counters, and the functional output grid.
pub fn run_casper(cfg: &SimConfig, kind: StencilKind, domain: &Domain, steps: usize) -> RunStats {
    run_casper_with(cfg, kind, domain, steps, CasperOptions::default())
        .expect("casper run failed")
}

/// Full-control variant over a preset kernel.
pub fn run_casper_with(
    cfg: &SimConfig,
    kind: StencilKind,
    domain: &Domain,
    steps: usize,
    opts: CasperOptions,
) -> Result<RunStats> {
    run_casper_spec(cfg, &kind.spec(), domain, steps, opts)
}

/// The spec-driven primary entry point: run any [`KernelSpec`] — preset
/// or TOML-defined — on Casper.
pub fn run_casper_spec(
    cfg: &SimConfig,
    desc: &KernelSpec,
    domain: &Domain,
    steps: usize,
    opts: CasperOptions,
) -> Result<RunStats> {
    run_casper_spec_traced(cfg, desc, domain, steps, opts, None).map(|(stats, _)| stats)
}

/// [`run_casper_spec`] with an optional cycle-domain [`Tracer`]: the
/// tracer is installed into the memory system after warm-up (so only the
/// measured region is recorded) and handed back alongside the stats for
/// serialization. Tracing is observation-only — `RunStats` (and its
/// digest) are byte-identical with the tracer present or absent, pinned
/// by `tracing_on_and_off_are_byte_identical` below.
pub fn run_casper_spec_traced(
    cfg: &SimConfig,
    desc: &KernelSpec,
    domain: &Domain,
    steps: usize,
    opts: CasperOptions,
    tracer: Option<Box<Tracer>>,
) -> Result<(RunStats, Option<Box<Tracer>>)> {
    // Multi-pass compilation (docs/KERNELS.md): one program per pass of
    // the kernel's plan, under the selected strategy. Envelope-sized
    // kernels get a one-element plan identical to the historical single
    // `build` under either strategy — same program, same execution path,
    // byte-identical results.
    let passes = ProgramBuilder::build_passes_with(desc, opts.plan)?;
    // Temporal blocking grows the effective halo to radius·T per axis;
    // reject blocks the domain cannot host before allocating anything.
    let t_block = opts.temporal_block;
    anyhow::ensure!(t_block >= 1, "temporal block must be >= 1 (got {t_block})");
    if t_block > 1 {
        desc.validate_blocked(domain, t_block)?;
    }
    let mut rt = CasperRuntime::new(cfg);
    rt.mem.unaligned_hw = opts.unaligned_hw;

    // --- Segment allocation & data initialization (Fig 8 lines 4-10) ---
    let layout = SegmentLayout::for_domain(domain, &cfg.llc);
    let seg_base = rt.init_stencil_segment(layout.seg_bytes)?;
    let mut layout = layout.bind(seg_base);
    let input = domain.alloc_random(opts.seed);
    rt.mem.store.write_slice(layout.a_addr(0), &input.data);
    // Jacobi-style ping-pong: B starts as a copy so that boundary elements
    // (never written by SPUs) carry through — same policy as the golden
    // reference.
    rt.mem.store.write_slice(layout.b_addr(0), &input.data);

    rt.init_stencil_code(passes[0].clone())?;

    // Warm-up: stream both arrays through the LLC tags (in address order,
    // as the initialization in Fig 8 lines 10 would), then clear counters.
    if opts.warm_llc {
        let line = cfg.llc.line_bytes as u64;
        for array in [layout.a_base(), layout.b_base()] {
            let mut addr = array;
            while addr < array + layout.array_bytes {
                let slice = rt.mem.mapper.slice_of(addr);
                rt.mem.llc.access(slice, addr, false);
                addr += line;
            }
        }
        rt.mem.llc.reset_stats();
        rt.mem.dram.reset();
        rt.mem.noc.reset();
    }
    // Install the tracer only now: warm-up traffic is setup, not the
    // measured region (it also never claims slice ports, which keeps the
    // port-grant counters exact for the run).
    rt.mem.trace = tracer;

    let nx = domain.nx as i64;
    let nxy = (domain.nx * domain.ny) as i64;
    let runs = interior_runs(desc, domain);

    let mut cycles_done = 0u64;
    // Temporal-block bookkeeping: the linear-element dependency radius
    // (for the analytic halo-recompute counter) and the per-step fused
    // reduction values.
    let [rrx, rry, rrz] = desc.radius();
    let r_lin = rrx as u64
        + rry as u64 * domain.nx as u64
        + rrz as u64 * (domain.nx * domain.ny) as u64;
    let mut halo_recompute_cells = 0u64;
    let mut reduction_values: Vec<f64> = Vec::new();
    // The work partition depends only on the A/B layout parity (the block
    // decomposition of B repeats every two steps as the arrays ping-pong),
    // so compute it at most twice and reuse across all time steps —
    // recomputing it walked every output block per step (§Perf).
    let mut parts_cache: [Option<Vec<Vec<Chunk>>>; 2] = [None, None];
    for step in 0..steps {
        let parts: &Vec<Vec<Chunk>> = parts_cache[step & 1]
            .get_or_insert_with(|| partition(&runs, &layout, &rt.mem.mapper, cfg.spu.count));

        // Wavefront residency (temporal blocking): the first step of each
        // block streams through the LLC normally; the following T−1 steps
        // operate on wavefronts already held in the slices, so every tag
        // probe is served without a fill — both engines resolve probes
        // through the same `SliceState` seam, so the avoided-fill
        // accounting is engine-identical by construction.
        let resident = t_block > 1 && step % t_block != 0;
        rt.mem.llc.set_wavefront_resident(resident);
        if resident {
            // Halo recompute (analytic): each SPU-chunk cut recomputes
            // `2 · r_lin` extra cells per step of depth into the block
            // instead of exchanging them.
            let total_chunks: u64 = parts.iter().map(|p| p.len() as u64).sum();
            let n_cuts = total_chunks.saturating_sub(runs.len() as u64);
            halo_recompute_cells += 2 * r_lin * n_cuts * (step % t_block) as u64;
        }

        // The passes of the plan run back-to-back within the step: pass 0
        // writes partial sums into B, each later pass re-reads its own
        // output row through the accumulator stream and adds its taps.
        // The work partition is identical for every pass (it follows
        // output-block ownership, and every pass writes the same output
        // elements), so `parts` is shared.
        for (pi, pass) in passes.iter().enumerate() {
            // Re-broadcast between passes (and back to pass 0 on later
            // steps), preserving SPU timing/counters/L1 so the whole plan
            // accounts on one timeline. Single-pass kernels never take
            // this branch: their program stays loaded, exactly the
            // historical path.
            if passes.len() > 1 && (step > 0 || pi > 0) {
                rt.set_program(pass.clone())?;
                // Re-broadcast barrier: each pass is its own
                // `startAccelerator` invocation, and the coordinator only
                // re-broadcasts after the leader observed every completion
                // of the previous pass — so no SPU may issue the new
                // program before that point. Applies to every swap,
                // including the step-boundary swap back to pass 0. (Never
                // taken for single-pass kernels, whose timing stays
                // byte-identical to the historical path.)
                for spu in &mut rt.spus {
                    spu.timer.now = spu.timer.now.max(cycles_done);
                }
            }

            // Tracing snapshots (cheap Vec builds, taken only with a
            // tracer installed): per-SPU busy-interval starts and the
            // pass's start cycle.
            let tracing = rt.mem.trace.is_some();
            let pass_start = cycles_done;
            let spu_starts: Vec<u64> = if tracing {
                rt.spus.iter().map(|s| s.finish_time()).collect()
            } else {
                Vec::new()
            };

            if opts.spu_threads > 1 {
                // Epoch-parallel engine: byte-identical to the serial loop
                // below (`rust/DESIGN-parallel.md`; identity tests under
                // this module).
                epoch::run_step(
                    &mut rt,
                    parts,
                    &layout,
                    nx,
                    nxy,
                    opts.spu_threads,
                    opts.epoch_rounds,
                    opts.pipeline,
                )?;
            } else {
                run_step_serial(&mut rt, parts, &layout, nx, nxy)?;
            }

            // Leader aggregation (§5.2): completion messages to SPU 0 —
            // once per pass, since each pass is its own
            // `startAccelerator` invocation on real hardware.
            let msgs0 = rt.mem.noc.messages;
            let cont0 = rt.mem.noc.contention_cycles;
            let mut done = cycles_done;
            let finishes: Vec<(usize, u64)> =
                rt.spus.iter().map(|s| (s.slice, s.finish_time())).collect();
            // A fused-reduction pass carries the SPU's partial scalar in
            // its completion message, doubling the payload (8 → 16 B).
            let payload: u64 = if pass.reduce.is_some() { 16 } else { 8 };
            for &(slice, t) in &finishes {
                done = done.max(rt.mem.noc.send(slice, 0, payload, t));
            }
            cycles_done = done;

            if tracing {
                // Leader sends are the only NoC path that models link
                // contention; attribute this pass's delta to the bucket
                // of its completion cycle.
                let msgs = rt.mem.noc.messages - msgs0;
                let cont = rt.mem.noc.contention_cycles - cont0;
                if let Some(tr) = rt.mem.trace.as_deref_mut() {
                    tr.noc_leader(cycles_done, msgs, cont);
                    tr.pass_span(step, pi, pass_start, cycles_done);
                    for (spu_id, (f, &start)) in finishes.iter().zip(&spu_starts).enumerate() {
                        if f.1 > start {
                            tr.spu_span(spu_id, step, pi, start, f.1);
                        }
                    }
                }
            }
        }

        // Host boundary policy: copy non-interior elements through and
        // repair streamed-over x-edge elements (surface work, not on the
        // accelerator's critical path — see DESIGN.md §5).
        patch_boundary(&mut rt, desc, domain, &layout);

        // Fused reduction (ISA bit 15): the leader combines the per-SPU
        // partials in deterministic `(round, spu, seq)` order, which is
        // architected to equal a linear element-order fold over the full
        // output array — the same fold the golden two-pass reference uses,
        // so fused and two-pass values are bitwise identical.
        if let Some(r) = desc.reduction {
            let n = domain.points();
            let out = rt.mem.store.read_slice(layout.b_addr(0), n);
            let inp = rt.mem.store.read_slice(layout.a_addr(0), n);
            reduction_values.push(crate::stencil::golden::reduce_arrays(r.op, inp, out));
        }

        layout = layout.swapped();
    }

    // After the loop, the *latest output* is in the (pre-swap) B array,
    // i.e. current layout's A array.
    let out_data = rt.mem.store.read_vec(layout.a_addr(0), domain.points());
    let mut output = domain.alloc();
    output.data.copy_from_slice(&out_data);

    // Aggregate stats.
    let mut spu_stats = crate::spu::SpuStats::default();
    let mut per_spu_max = 0u64;
    for s in rt.spus() {
        spu_stats.add(&s.stats);
        // Load-queue stalls accrue on the (detachable) timer; fold them
        // into the aggregate here, where the digest reads them.
        spu_stats.lq_stall_cycles += s.timer.lq_stalls();
        per_spu_max = per_spu_max.max(s.stats.instrs);
    }
    // Per-slice NoC/DRAM counters (tracked by `SliceState`; identical on
    // the serial and epoch-parallel engines — both run the same request
    // arithmetic in the same order).
    let mut slice_remote_reqs = Vec::with_capacity(cfg.llc.slices);
    let mut slice_dram_reads = Vec::with_capacity(cfg.llc.slices);
    let mut slice_dram_writes = Vec::with_capacity(cfg.llc.slices);
    let mut slice_port_grants = Vec::with_capacity(cfg.llc.slices);
    let mut slice_avoided_fills = Vec::with_capacity(cfg.llc.slices);
    for s in 0..cfg.llc.slices {
        let bank = rt.mem.llc.bank(s);
        slice_remote_reqs.push(bank.remote_reqs);
        slice_dram_reads.push(bank.dram_reads);
        slice_dram_writes.push(bank.dram_writes);
        // Warm-up touches tags only, never ports, so the grant count is
        // exactly the measured region's data-array accesses.
        slice_port_grants.push(bank.port.grants);
        slice_avoided_fills.push(bank.tags.avoided_fills);
    }
    let trace = rt.mem.trace.take();
    let stats = RunStats {
        cycles: cycles_done,
        total_instrs: spu_stats.instrs,
        per_spu_instrs: per_spu_max,
        passes: passes.len(),
        spu: spu_stats,
        llc: rt.mem.llc.stats(),
        dram_accesses: rt.mem.dram.accesses,
        noc_messages: rt.mem.noc.messages,
        noc_hops: rt.mem.noc.total_hops,
        noc_contention_cycles: rt.mem.noc.contention_cycles,
        slice_remote_reqs,
        slice_dram_reads,
        slice_dram_writes,
        slice_port_grants,
        temporal_block: t_block,
        slice_avoided_fills,
        halo_recompute_cells,
        reduction: desc
            .reduction
            .map(|r| super::metrics::ReductionResult { op: r.op, values: reduction_values }),
        output,
    };
    Ok((stats, trace))
}

/// The serial round-robin execution of one time step: per-SPU chunk
/// cursors into the cached partition, driven in lockstep rounds. Chunk
/// transitions rebind the streams (`initStream`) and element count
/// (`setNElements`) exactly as Fig 8 does per SPU. Cursors (not queues)
/// so the cached partition is never cloned or consumed.
fn run_step_serial(
    rt: &mut CasperRuntime,
    parts: &[Vec<Chunk>],
    layout: &SegmentLayout,
    nx: i64,
    nxy: i64,
) -> Result<()> {
    let mut cursors = vec![0usize; parts.len()];
    loop {
        let mut progress = false;
        for spu_id in 0..rt.spus.len() {
            if rt.spus[spu_id].is_done() && cursors[spu_id] < parts[spu_id].len() {
                let chunk = parts[spu_id][cursors[spu_id]];
                cursors[spu_id] += 1;
                bind_chunk(&mut rt.spus[spu_id], layout, chunk, nx, nxy)?;
            }
            progress |= {
                let spu = &mut rt.spus[spu_id];
                spu.run_group(&mut rt.mem)
            };
        }
        if !progress {
            break;
        }
    }
    Ok(())
}

/// Bind one chunk's streams on one SPU. Works directly on the SPU so the
/// stream-spec table is read in place — the old path cloned the whole
/// `Vec<StreamSpec>` per chunk transition (§Perf).
pub(crate) fn bind_chunk(
    spu: &mut Spu,
    layout: &SegmentLayout,
    chunk: Chunk,
    nx: i64,
    nxy: i64,
) -> Result<()> {
    let n_streams = spu.program().streams.len();
    for sid in 0..n_streams {
        let spec = spu.program().streams[sid];
        let addr = if spec.is_output || spec.from_output {
            // The output stream — and, in later passes of a multi-pass
            // plan, the accumulator stream that re-reads the pass's own
            // output row (dy = dz = 0) for `out += Σ taps`.
            layout.b_addr(chunk.start)
        } else {
            let off = spec.dy * nx + spec.dz * nxy;
            layout.a_addr(chunk.start.wrapping_add_signed(off))
        };
        spu.set_stream(sid, addr)?;
    }
    spu.set_n_elements(chunk.n);
    Ok(())
}

/// Copy every non-interior element of the output array from the input
/// array (the shared boundary convention), fixing both untouched halo
/// elements and streamed-over x-edges. Runs as bulk row copies through a
/// reused scratch buffer — the old per-element `read_f64`/`write_f64`
/// closure was a measurable slice of short multi-step runs (§Perf).
fn patch_boundary(
    rt: &mut CasperRuntime,
    desc: &StencilDesc,
    domain: &Domain,
    layout: &SegmentLayout,
) {
    let [rx, ry, rz] = desc.radius();
    let (nx, ny, nz) = (domain.nx, domain.ny, domain.nz);
    let mut buf: Vec<f64> = Vec::with_capacity(nx);
    let mut copy_run = |store: &mut crate::spu::SimStore, start: u64, n: usize| {
        if n == 0 {
            return;
        }
        buf.clear();
        buf.extend_from_slice(store.read_slice(layout.a_addr(start), n));
        store.write_slice(layout.b_addr(start), &buf);
    };
    for z in 0..nz {
        for y in 0..ny {
            let interior_row = z >= rz && z < nz - rz && y >= ry && y < ny - ry;
            let row = ((z * ny + y) * nx) as u64;
            if !interior_row {
                copy_run(&mut rt.mem.store, row, nx);
            } else {
                copy_run(&mut rt.mem.store, row, rx);
                copy_run(&mut rt.mem.store, row + (nx - rx) as u64, rx);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingPolicy, SizeClass, SpuPlacement};
    use crate::mapping::{SliceMapper, StencilSegment};
    use crate::stencil::golden;

    #[test]
    fn epoch_parallel_is_byte_identical_to_serial() {
        // The centerpiece identity: serial round-robin and the staged
        // engine (collect → reconcile → replay) must agree on EVERY
        // counter, cycle count, and output bit — across thread counts,
        // epoch sizes (including an epoch of a single round and one far
        // larger than the run), and with the replay stage either inline
        // (phased) or on the dedicated pipeline worker.
        let cfg = SimConfig::default();
        for kind in [StencilKind::Jacobi1D, StencilKind::Jacobi2D, StencilKind::Heat3D] {
            let d = Domain::tiny(kind);
            let serial = run_casper_with(
                &cfg,
                kind,
                &d,
                3,
                CasperOptions { spu_threads: 1, ..Default::default() },
            )
            .unwrap();
            for threads in [2usize, 16] {
                for rounds in [1usize, 3, 1 << 20] {
                    for pipeline in [false, true] {
                        let par = run_casper_with(
                            &cfg,
                            kind,
                            &d,
                            3,
                            CasperOptions {
                                spu_threads: threads,
                                epoch_rounds: rounds,
                                pipeline,
                                ..Default::default()
                            },
                        )
                        .unwrap();
                        let tag = format!(
                            "{kind} threads={threads} epoch_rounds={rounds} pipeline={pipeline}"
                        );
                        assert_eq!(serial.cycles, par.cycles, "{tag}");
                        assert_eq!(serial.spu, par.spu, "{tag}");
                        assert_eq!(serial.llc, par.llc, "{tag}");
                        assert_eq!(serial.dram_accesses, par.dram_accesses, "{tag}");
                        assert_eq!(serial.noc_messages, par.noc_messages, "{tag}");
                        assert_eq!(serial.noc_hops, par.noc_hops, "{tag}");
                        assert_eq!(serial.slice_remote_reqs, par.slice_remote_reqs, "{tag}");
                        assert_eq!(serial.slice_dram_reads, par.slice_dram_reads, "{tag}");
                        assert_eq!(serial.slice_dram_writes, par.slice_dram_writes, "{tag}");
                        assert_eq!(serial.slice_port_grants, par.slice_port_grants, "{tag}");
                        assert_eq!(serial.output, par.output, "{tag}");
                        assert_eq!(serial.digest(), par.digest(), "{tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn epoch_parallel_identity_under_stress_configs() {
        // Crafted conflict pressure: the Baseline mapping scatters
        // consecutive lines across slices, so nearly every load is a
        // cross-slice epoch message; NearL1 adds the private-L1 filter;
        // disabling the §4.1 hardware splits every unaligned load in two.
        // Both replay placements (inline and pipelined worker) must hold
        // the identity under every combination.
        let kind = StencilKind::Blur2D;
        let d = Domain::tiny(kind);
        for mapping in [MappingPolicy::Baseline, MappingPolicy::StencilSegment] {
            for placement in [SpuPlacement::NearLlc, SpuPlacement::NearL1] {
                for unaligned_hw in [true, false] {
                    let mut cfg = SimConfig::default();
                    cfg.mapping = mapping;
                    cfg.placement = placement;
                    let serial = run_casper_with(
                        &cfg,
                        kind,
                        &d,
                        2,
                        CasperOptions { unaligned_hw, spu_threads: 1, ..Default::default() },
                    )
                    .unwrap();
                    for pipeline in [false, true] {
                        let par = run_casper_with(
                            &cfg,
                            kind,
                            &d,
                            2,
                            CasperOptions {
                                unaligned_hw,
                                spu_threads: 8,
                                epoch_rounds: 5,
                                pipeline,
                                ..Default::default()
                            },
                        )
                        .unwrap();
                        let tag = format!(
                            "mapping={mapping:?} placement={placement:?} hw={unaligned_hw} \
                             pipeline={pipeline}"
                        );
                        assert_eq!(serial.cycles, par.cycles, "{tag}");
                        assert_eq!(serial.digest(), par.digest(), "{tag}");
                    }
                }
            }
        }
    }

    #[test]
    fn degenerate_domain_has_no_interior_runs() {
        let desc = StencilKind::Jacobi2D.descriptor();
        assert!(interior_runs(&desc, &Domain::new(6, 2, 1)).is_empty(), "ny == 2*ry");
        assert!(interior_runs(&desc, &Domain::new(2, 6, 1)).is_empty(), "nx == 2*rx");
        assert!(interior_runs(&desc, &Domain::new(1, 1, 1)).is_empty());
        let desc3 = StencilKind::Heat3D.descriptor();
        assert!(interior_runs(&desc3, &Domain::new(8, 8, 2)).is_empty(), "nz == 2*rz");
        // One past degenerate: a single interior point.
        let runs = interior_runs(&desc, &Domain::new(3, 3, 1));
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].n, 1);
    }

    #[test]
    fn degenerate_domain_run_copies_input_through() {
        // A boundary-only domain executes zero SPU work and the host
        // boundary policy copies the input through — on both engines.
        let cfg = SimConfig::default();
        let d = Domain::new(64, 2, 1); // ny == 2*ry for Jacobi2D
        let input = d.alloc_random(CasperOptions::default().seed);
        for threads in [1usize, 4] {
            let stats = run_casper_with(
                &cfg,
                StencilKind::Jacobi2D,
                &d,
                2,
                CasperOptions { spu_threads: threads, ..Default::default() },
            )
            .unwrap();
            assert_eq!(stats.total_instrs, 0, "threads={threads}");
            assert_eq!(stats.output, input, "threads={threads}");
        }
    }

    #[test]
    fn interior_runs_cover_interior() {
        for kind in StencilKind::ALL {
            let d = Domain::tiny(kind);
            let desc = kind.descriptor();
            let runs = interior_runs(&desc, &d);
            let [_, ry, rz] = desc.radius();
            assert_eq!(runs.len(), d.nz - 2 * rz, "{kind}");
            let total: u64 = runs.iter().map(|r| r.n).sum();
            let expect = ((d.ny - 2 * ry) * d.nx - 2 * desc.radius()[0]) as u64
                * (d.nz - 2 * rz) as u64;
            assert_eq!(total, expect, "{kind}");
        }
    }

    #[test]
    fn partition_covers_all_elements_disjointly() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::for_level(kind, SizeClass::L2);
        let layout = SegmentLayout::for_domain(&d, &cfg.llc).bind(0x1000_0000);
        let mut mapper = SliceMapper::new(&cfg.llc, MappingPolicy::StencilSegment);
        mapper.set_segment(StencilSegment::new(layout.seg_base, layout.seg_bytes));
        let runs = interior_runs(&kind.descriptor(), &d);
        let parts = partition(&runs, &layout, &mapper, 16);

        let mut covered = std::collections::BTreeMap::new();
        for (spu, chunks) in parts.iter().enumerate() {
            for c in chunks {
                for e in c.start..c.start + c.n {
                    assert!(covered.insert(e, spu).is_none(), "element {e} double-assigned");
                }
            }
        }
        let want: u64 = runs.iter().map(|r| r.n).sum();
        assert_eq!(covered.len() as u64, want);
        // Ownership really follows the output-block hash.
        for (&e, &spu) in covered.iter().step_by(1009) {
            assert_eq!(mapper.slice_of(layout.b_addr(e)), spu);
        }
    }

    #[test]
    fn cached_partition_matches_fresh_recomputation_both_parities() {
        // The engine computes `partition()` once per layout parity and
        // reuses it across time steps; that is only sound if (a) the
        // function is deterministic and (b) the partition really is a
        // function of parity alone. Check both against fresh recomputes,
        // for both parities, on a multi-block domain.
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::for_level(kind, SizeClass::L2);
        let layout_even = SegmentLayout::for_domain(&d, &cfg.llc).bind(0x1000_0000);
        let layout_odd = layout_even.swapped();
        let mut mapper = SliceMapper::new(&cfg.llc, MappingPolicy::StencilSegment);
        mapper.set_segment(StencilSegment::new(layout_even.seg_base, layout_even.seg_bytes));
        let runs = interior_runs(&kind.descriptor(), &d);

        for layout in [layout_even, layout_odd] {
            let cached = partition(&runs, &layout, &mapper, cfg.spu.count);
            let fresh = partition(&runs, &layout, &mapper, cfg.spu.count);
            assert_eq!(cached, fresh, "partition must be deterministic");
        }
        // Parity two steps apart is the same layout again: the cache keyed
        // on `step & 1` therefore covers every step of a long run.
        assert_eq!(layout_even.swapped().swapped(), layout_even);
    }

    #[test]
    fn casper_matches_golden_all_kernels_tiny() {
        let cfg = SimConfig::default();
        for kind in StencilKind::ALL {
            let d = Domain::tiny(kind);
            let stats = run_casper(&cfg, kind, &d, 1);
            let want = golden::run_kind(kind, &d, 1, CasperOptions::default().seed);
            let diff = stats.output.max_abs_diff(&want);
            assert!(diff < 1e-12, "{kind}: max diff {diff}");
            assert!(stats.cycles > 0);
            assert!(stats.total_instrs > 0);
        }
    }

    #[test]
    fn casper_matches_golden_multistep() {
        let cfg = SimConfig::default();
        for kind in [StencilKind::Jacobi2D, StencilKind::Heat3D] {
            let d = Domain::tiny(kind);
            let stats = run_casper(&cfg, kind, &d, 3);
            let want = golden::run_kind(kind, &d, 3, CasperOptions::default().seed);
            let diff = stats.output.max_abs_diff(&want);
            assert!(diff < 1e-12, "{kind}: max diff {diff}");
        }
    }

    fn star17() -> KernelSpec {
        crate::stencil::extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "star17_3d")
            .expect("star17_3d preset")
    }

    #[test]
    fn star17_multipass_matches_pass_split_golden_bitwise() {
        // The acceptance criterion: the previously-impossible isotropic
        // radius-4 star compiles as a 2-pass plan and the engine's output
        // is BIT FOR BIT the pass-split golden oracle's (the preset's taps
        // are in program order, so all accumulation orders coincide).
        // Runs under whatever CASPER_SPU_THREADS the CI matrix sets.
        let cfg = SimConfig::default();
        let star = star17();
        let d = star.tiny_domain();
        let opts = CasperOptions::default();
        let stats = run_casper_spec(&cfg, &star, &d, 2, opts).unwrap();
        assert_eq!(stats.passes, 2);
        assert!(stats.cycles > 0 && stats.total_instrs > 0);
        let input = d.alloc_random(opts.seed);
        let want = golden::run_multipass(&star, &input, 2);
        assert!(
            stats.output.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "star17_3d diverged bitwise from the pass-split golden oracle"
        );
        // And the pass-split oracle itself agrees with the plain banded
        // reference to rounding (different association order only).
        let approx = golden::run_spec(&star, &d, 2, opts.seed);
        assert!(stats.output.max_abs_diff(&approx) < 1e-12);
    }

    fn wide_mix() -> KernelSpec {
        crate::stencil::extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "wide_mix_2d")
            .expect("wide_mix_2d preset")
    }

    #[test]
    fn plan_strategies_agree_bitwise_when_order_preserving() {
        // star17_3d already sits at its 2-pass minimum, so the optimizing
        // planner keeps program order and only moves the split point —
        // and moving a split point of an order-preserving plan cannot
        // change the accumulation order (the accumulator reload is the
        // exact identity `1.0 · out`). Greedy and Optimized must
        // therefore produce bitwise-identical grids.
        let cfg = SimConfig::default();
        let star = star17();
        let d = star.tiny_domain();
        let mut outs = Vec::new();
        for plan in PlanStrategy::ALL {
            let stats = run_casper_spec(
                &cfg,
                &star,
                &d,
                2,
                CasperOptions { plan, ..Default::default() },
            )
            .unwrap();
            assert_eq!(stats.passes, 2, "{plan}");
            outs.push(stats.output);
        }
        assert_eq!(outs[0], outs[1], "strategies diverged on an order-preserving kernel");
    }

    #[test]
    fn optimized_plan_halves_wide_mix_passes_on_both_engines() {
        // The strict pass-count win, end to end: wide_mix_2d compiles to
        // 4 greedy passes but 2 optimized passes, and under EITHER
        // strategy both engines are bitwise the plan-aware golden oracle
        // executing the same plan.
        let cfg = SimConfig::default();
        let mix = wide_mix();
        let d = mix.tiny_domain();
        let input = d.alloc_random(CasperOptions::default().seed);
        for plan in PlanStrategy::ALL {
            let want_passes = match plan {
                PlanStrategy::Greedy => 4,
                PlanStrategy::Optimized => 2,
            };
            let oracle_plan = mix.pass_plan_with(plan).unwrap();
            assert_eq!(oracle_plan.num_passes(), want_passes, "{plan}");
            let want = golden::run_planned(&mix, &oracle_plan, &input, 2);
            for threads in [1usize, 16] {
                let stats = run_casper_spec(
                    &cfg,
                    &mix,
                    &d,
                    2,
                    CasperOptions { plan, spu_threads: threads, ..Default::default() },
                )
                .unwrap();
                let tag = format!("{plan} threads={threads}");
                assert_eq!(stats.passes, want_passes, "{tag}");
                assert!(
                    stats
                        .output
                        .data
                        .iter()
                        .zip(&want.data)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{tag}: engine diverged bitwise from the plan-aware oracle"
                );
            }
        }
    }

    #[test]
    fn multipass_epoch_parallel_is_byte_identical_to_serial() {
        // The PR-3 identity contract extended to multi-pass plans: serial
        // and the staged engine must agree on every counter, cycle count,
        // and output bit while passes re-broadcast programs between
        // run_step invocations — with replay inline or pipelined. The
        // pipelined leg is the interesting one here: each pass detaches
        // and restores the timer/tag halves around its own scope.
        let cfg = SimConfig::default();
        let star = star17();
        let d = star.tiny_domain();
        let serial = run_casper_spec(
            &cfg,
            &star,
            &d,
            2,
            CasperOptions { spu_threads: 1, ..Default::default() },
        )
        .unwrap();
        for threads in [2usize, 16] {
            for rounds in [1usize, 5] {
                for pipeline in [false, true] {
                    let par = run_casper_spec(
                        &cfg,
                        &star,
                        &d,
                        2,
                        CasperOptions {
                            spu_threads: threads,
                            epoch_rounds: rounds,
                            pipeline,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    let tag =
                        format!("threads={threads} epoch_rounds={rounds} pipeline={pipeline}");
                    assert_eq!(serial.cycles, par.cycles, "{tag}");
                    assert_eq!(serial.spu, par.spu, "{tag}");
                    assert_eq!(serial.output, par.output, "{tag}");
                    assert_eq!(serial.digest(), par.digest(), "{tag}");
                }
            }
        }
    }

    #[test]
    fn tracing_on_and_off_are_byte_identical() {
        // The observability acceptance invariant: installing a tracer
        // must not move a single counter, cycle, or output bit — across
        // engines and replay placements, on a multi-pass kernel. The
        // pipelined leg exercises the tracer living on the replay worker
        // (it rides inside the detached TimingMem half).
        let cfg = SimConfig::default();
        let jacobi: KernelSpec = StencilKind::Jacobi2D.spec().as_ref().clone();
        for spec in [&jacobi, &star17()] {
            let d = spec.tiny_domain();
            for threads in [1usize, 16] {
                for pipeline in [false, true] {
                    let opts =
                        CasperOptions { spu_threads: threads, pipeline, ..Default::default() };
                    let plain = run_casper_spec(&cfg, spec, &d, 2, opts).unwrap();
                    let tracer = Box::new(Tracer::new(&cfg, 256));
                    let (traced, tr) =
                        run_casper_spec_traced(&cfg, spec, &d, 2, opts, Some(tracer)).unwrap();
                    let tr = tr.expect("tracer handed back");
                    let tag =
                        format!("{} threads={threads} pipeline={pipeline}", spec.id.as_str());
                    assert_eq!(plain.digest(), traced.digest(), "{tag}");
                    assert_eq!(plain, traced, "{tag}: full RunStats identity");
                    assert!(tr.samples() > 0, "{tag}: no samples recorded");
                    let want_spans = 2 * traced.passes; // steps × passes
                    assert_eq!(tr.pass_spans().len(), want_spans, "{tag}");
                    assert!(!tr.spu_spans().is_empty(), "{tag}");
                    crate::trace::chrome::validate_json(&tr.to_chrome_string())
                        .unwrap_or_else(|e| panic!("{tag}: invalid trace JSON: {e}"));
                }
            }
        }
    }

    #[test]
    fn traced_buckets_are_engine_identical() {
        // Bucket attribution commutes, and both engines issue identical
        // requests at identical cycles — so the *telemetry itself* (not
        // just the stats) agrees across engines.
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::tiny(kind);
        let mut per_engine = Vec::new();
        for threads in [1usize, 16] {
            let opts = CasperOptions { spu_threads: threads, ..Default::default() };
            let tracer = Box::new(Tracer::new(&cfg, 128));
            let (_, tr) =
                run_casper_spec_traced(&cfg, &kind.spec(), &d, 2, opts, Some(tracer)).unwrap();
            let tr = tr.unwrap();
            let mut flat: Vec<u64> = Vec::new();
            for b in tr.buckets() {
                flat.extend_from_slice(&b.slice_bytes);
                flat.extend_from_slice(&b.slice_hits);
                flat.extend_from_slice(&b.slice_misses);
                flat.extend_from_slice(&b.chan_bytes);
                flat.extend_from_slice(&b.slice_avoided);
                flat.push(b.dram_queue_cycles);
                flat.push(b.noc_messages);
                flat.push(b.noc_contention_cycles);
            }
            per_engine.push(flat);
        }
        assert_eq!(per_engine[0], per_engine[1], "bucketed telemetry diverged across engines");
    }

    #[test]
    fn temporal_blocking_keeps_the_grid_bitwise_and_avoids_fills() {
        // The temporal-block contract: the functional step sequence is
        // unchanged, so the final grid is bitwise identical for every T —
        // on both engines — while the wavefront-residency model records
        // avoided LLC fills on every non-leading step of a block.
        let cfg = SimConfig::default();
        for kind in [StencilKind::Jacobi1D, StencilKind::Jacobi2D, StencilKind::Heat3D] {
            let d = Domain::tiny(kind);
            let base = run_casper_with(
                &cfg,
                kind,
                &d,
                4,
                CasperOptions { spu_threads: 1, ..Default::default() },
            )
            .unwrap();
            assert_eq!(base.temporal_block, 1);
            assert_eq!(base.avoided_fills(), 0, "{kind}: T=1 must avoid nothing");
            assert_eq!(base.halo_recompute_cells, 0, "{kind}");
            for t in [2usize, 3] {
                let serial = run_casper_with(
                    &cfg,
                    kind,
                    &d,
                    4,
                    CasperOptions { spu_threads: 1, temporal_block: t, ..Default::default() },
                )
                .unwrap();
                let tag = format!("{kind} T={t}");
                assert_eq!(serial.temporal_block, t, "{tag}");
                assert!(
                    serial.output.data.iter().zip(&base.output.data).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{tag}: blocked grid diverged bitwise from T=1 chaining"
                );
                assert!(serial.avoided_fills() > 0, "{tag}: resident steps must avoid fills");
                // Both engines agree on every blocked counter too — with
                // replay inline and on the pipeline worker (the resident
                // wavefront flags live in the detached tag banks there).
                for pipeline in [false, true] {
                    let par = run_casper_with(
                        &cfg,
                        kind,
                        &d,
                        4,
                        CasperOptions {
                            spu_threads: 16,
                            temporal_block: t,
                            pipeline,
                            ..Default::default()
                        },
                    )
                    .unwrap();
                    let tag = format!("{tag} pipeline={pipeline}");
                    assert_eq!(serial, par, "{tag}: full RunStats identity across engines");
                    assert_eq!(serial.digest(), par.digest(), "{tag}");
                }
            }
        }
    }

    #[test]
    fn blocked_run_recomputes_halos_at_chunk_cuts() {
        // An L2-sized 1D sweep spans 8 output blocks, so the single
        // interior run is cut 7 ways — every resident step charges
        // 2·r_lin cells per cut to the halo-recompute counter.
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi1D;
        let d = Domain::for_level(kind, SizeClass::L2);
        let blocked = run_casper_with(
            &cfg,
            kind,
            &d,
            4,
            CasperOptions { temporal_block: 4, ..Default::default() },
        )
        .unwrap();
        assert!(blocked.halo_recompute_cells > 0, "chunk cuts must recompute halo cells");
        let plain =
            run_casper_with(&cfg, kind, &d, 4, CasperOptions::default()).unwrap();
        assert_eq!(plain.halo_recompute_cells, 0);
        assert!(
            blocked.output.data.iter().zip(&plain.output.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "blocked grid diverged bitwise from chaining"
        );
        // And the engine's grid matches the banded golden oracle bitwise
        // (Jacobi 1D taps are in program order).
        let input = d.alloc_random(CasperOptions::default().seed);
        let want = golden::run_blocked(&kind.descriptor(), &input, 4, 4, 3);
        assert!(
            blocked.output.data.iter().zip(&want.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "engine diverged bitwise from golden::run_blocked"
        );
    }

    #[test]
    fn temporal_blocking_cuts_dram_line_fills_at_least_2x() {
        // The acceptance criterion: a bandwidth-bound sweep (working set
        // 2× the LLC) at --temporal-block 4 must cut traced DRAM line
        // fills ≥ 2× vs --temporal-block 1, with bitwise-identical grids.
        let mut cfg = SimConfig::default();
        cfg.llc.slice_bytes = 8 * 1024; // 16 slices × 8 kB = 128 kB LLC
        let kind = StencilKind::Jacobi2D;
        let d = Domain::new(256, 64, 1); // two 128 kB arrays = 2× the LLC
        let mut fills = Vec::new();
        let mut reads = Vec::new();
        let mut outs = Vec::new();
        for t in [1usize, 4] {
            let opts = CasperOptions { temporal_block: t, ..Default::default() };
            let tracer = Box::new(Tracer::new(&cfg, 4096));
            let (stats, tr) =
                run_casper_spec_traced(&cfg, &kind.spec(), &d, 4, opts, Some(tracer)).unwrap();
            let tr = tr.expect("tracer handed back");
            fills.push(tr.dram_lines_total());
            reads.push(stats.slice_dram_reads.iter().sum::<u64>());
            if t > 1 {
                assert!(stats.avoided_fills() > 0, "T={t}: no avoided fills recorded");
                assert_eq!(tr.avoided_total(), stats.avoided_fills(), "T={t}");
            }
            outs.push(stats.output);
        }
        assert!(fills[0] > 0, "T=1 must hit DRAM on a 2x-LLC working set");
        assert!(
            fills[1] * 2 <= fills[0],
            "traced DRAM line fills must drop >= 2x: T=1 {} vs T=4 {}",
            fills[0],
            fills[1]
        );
        assert!(
            reads[1] * 2 <= reads[0],
            "slice DRAM read shares must drop >= 2x: T=1 {} vs T=4 {}",
            reads[0],
            reads[1]
        );
        assert!(
            outs[0].data.iter().zip(&outs[1].data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "blocked grid diverged bitwise from chaining"
        );
    }

    #[test]
    fn fused_reduction_matches_golden_two_pass_bitwise() {
        // A Jacobi-style residual kernel runs as ONE fused pass per step
        // (no extra reduction pass), and its per-step values are bitwise
        // the golden two-pass reference's — on both engines.
        let cfg = SimConfig::default();
        let res = crate::stencil::extended_presets()
            .into_iter()
            .find(|s| s.id.as_str() == "jacobi2d_res")
            .expect("jacobi2d_res preset");
        let d = res.tiny_domain();
        let opts = CasperOptions::default();
        let stats = run_casper_spec(&cfg, &res, &d, 3, opts).unwrap();
        assert_eq!(stats.passes, 1, "fused reduction must not add a pass");
        let r = stats.reduction.as_ref().expect("reduction result");
        assert_eq!(r.op, crate::isa::ReduceOp::AbsDiff);
        assert_eq!(r.values.len(), 3, "one value per step");
        let input = d.alloc_random(opts.seed);
        let (want_grid, want_vals) = golden::run_reduced(&res, &input, 3);
        assert!(
            r.values.iter().zip(&want_vals).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fused values diverged bitwise from the two-pass reference: {:?} vs {want_vals:?}",
            r.values
        );
        assert!(
            stats.output.data.iter().zip(&want_grid.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "residual kernel grid diverged bitwise from golden"
        );
        // Engine identity holds with the 16-byte reduce completion
        // messages in play.
        let par = run_casper_spec(
            &cfg,
            &res,
            &d,
            3,
            CasperOptions { spu_threads: 16, ..Default::default() },
        )
        .unwrap();
        assert_eq!(stats, par, "full RunStats identity across engines");
        // The reduce payload is architecturally visible: a plain Jacobi 2D
        // run of the same shape moves fewer NoC payload bytes, yet the
        // residual grid is bitwise the plain kernel's.
        let plain =
            run_casper_spec(&cfg, &StencilKind::Jacobi2D.spec(), &d, 3, opts).unwrap();
        assert!(plain.reduction.is_none());
        assert!(
            stats.output.data.iter().zip(&plain.output.data).all(|(a, b)| a.to_bits() == b.to_bits()),
            "the residual kernel must compute exactly Jacobi 2D"
        );
    }

    #[test]
    fn blocked_halo_too_big_for_domain_is_rejected() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::tiny(kind); // 32×16: T=8 grows the y-halo past ny
        let err = run_casper_with(
            &cfg,
            kind,
            &d,
            1,
            CasperOptions { temporal_block: 8, ..Default::default() },
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("temporally blocked halo"),
            "unexpected error: {err:#}"
        );
        let err0 = run_casper_with(
            &cfg,
            kind,
            &d,
            1,
            CasperOptions { temporal_block: 0, ..Default::default() },
        )
        .unwrap_err();
        assert!(err0.to_string().contains("temporal block must be >= 1"), "{err0:#}");
    }

    #[test]
    fn single_pass_kernels_report_one_pass() {
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let stats = run_casper(&cfg, kind, &Domain::tiny(kind), 1);
        assert_eq!(stats.passes, 1);
    }

    #[test]
    fn mapping_policy_changes_locality() {
        let mut cfg = SimConfig::default();
        let kind = StencilKind::Jacobi1D;
        let d = Domain::for_level(kind, SizeClass::L2);
        cfg.mapping = MappingPolicy::StencilSegment;
        let seg = run_casper(&cfg, kind, &d, 1);
        cfg.mapping = MappingPolicy::Baseline;
        let base = run_casper(&cfg, kind, &d, 1);
        assert!(
            seg.local_fraction() > 0.95,
            "stencil mapping should be almost all local: {}",
            seg.local_fraction()
        );
        assert!(
            base.local_fraction() < 0.2,
            "baseline mapping scatters lines: {}",
            base.local_fraction()
        );
        // And both still compute the right answer.
        let want = golden::run_kind(kind, &d, 1, CasperOptions::default().seed);
        assert!(base.output.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn slice_counters_sum_to_aggregates() {
        // The per-slice DRAM shares partition the DRAM access count, and
        // the per-slice NoC injection counters cover at least every
        // remote SPU load — under both mapping policies.
        for mapping in [MappingPolicy::StencilSegment, MappingPolicy::Baseline] {
            let mut cfg = SimConfig::default();
            cfg.mapping = mapping;
            let kind = StencilKind::Jacobi2D;
            let d = Domain::for_level(kind, SizeClass::L2);
            let stats = run_casper(&cfg, kind, &d, 1);
            assert_eq!(stats.slice_remote_reqs.len(), cfg.llc.slices);
            let dram: u64 = stats.slice_dram_reads.iter().sum::<u64>()
                + stats.slice_dram_writes.iter().sum::<u64>();
            assert_eq!(dram, stats.dram_accesses, "{mapping:?}");
            let remote: u64 = stats.slice_remote_reqs.iter().sum();
            assert!(
                remote >= stats.spu.remote_loads,
                "{mapping:?}: {remote} slice-port remote reqs vs {} SPU remote loads",
                stats.spu.remote_loads
            );
            // Port grants: one per load/store request that reached a
            // slice, covering at least every SPU load that left the L1.
            assert_eq!(stats.slice_port_grants.len(), cfg.llc.slices);
            let grants: u64 = stats.slice_port_grants.iter().sum();
            assert!(grants > 0, "{mapping:?}: measured region must claim ports");
            assert!(stats.bandwidth_imbalance() >= 1.0, "{mapping:?}");
        }
    }

    #[test]
    fn spec_and_kind_entry_points_agree() {
        // `run_casper_spec` over the preset spec is the same simulation
        // as the historical kind-keyed entry point.
        let cfg = SimConfig::default();
        let kind = StencilKind::Jacobi2D;
        let d = Domain::tiny(kind);
        let via_kind = run_casper(&cfg, kind, &d, 2);
        let via_spec =
            run_casper_spec(&cfg, &kind.spec(), &d, 2, CasperOptions::default()).unwrap();
        assert_eq!(via_kind.digest(), via_spec.digest());
    }

    #[test]
    fn per_spu_instr_balance() {
        let cfg = SimConfig::default();
        // LLC-sized 1D: 8 MB of output blocks → all 16 slices get work.
        let d = Domain::for_level(StencilKind::Jacobi1D, SizeClass::Llc);
        let stats = run_casper(&cfg, StencilKind::Jacobi1D, &d, 1);
        let fair = stats.total_instrs / 16;
        assert!(stats.per_spu_instrs < fair * 2, "{} vs fair {}", stats.per_spu_instrs, fair);
    }

    /// Diagnostic dump for calibration: `cargo test --release -- --ignored
    /// dump_fig10 --nocapture`.
    #[test]
    #[ignore]
    fn dump_fig10_numbers() {
        let cfg = SimConfig::default();
        for kind in StencilKind::ALL {
            for level in [SizeClass::L2, SizeClass::Llc, SizeClass::Dram] {
                let d = Domain::for_level(kind, level);
                let c = run_casper(&cfg, kind, &d, 1);
                let p = crate::cpu::run_cpu(&cfg, kind, &d, 1);
                let ce = crate::energy::casper_energy(&cfg, &c);
                let pe = crate::energy::cpu_energy(&cfg, &p);
                println!(
                    "{:<12} {:<5} speedup={:>6.2}x  casper={:>10} cpu={:>10}  e_ratio={:.2} (dyn {:.2})  local={:.2} llc_hit={:.2} dram={} lqstall={} noc_msgs={} llc_acc={}",
                    kind.id(), level.name(),
                    p.cycles as f64 / c.cycles as f64,
                    c.cycles, p.cycles,
                    ce.total_j() / pe.total_j(),
                    ce.dynamic_j() / pe.dynamic_j(),
                    c.local_fraction(), c.llc_hit_rate(), c.dram_accesses,
                    c.spu.lq_stall_cycles, c.noc_messages, c.llc.accesses(),
                );
            }
        }
    }

    #[test]
    fn small_dataset_uses_subset_of_spus() {
        // L2-sized 1D output is 1 MB = 8 blocks → exactly 8 SPUs work
        // (§4.2 block ownership), the rest stay idle.
        let cfg = SimConfig::default();
        let d = Domain::for_level(StencilKind::Jacobi1D, SizeClass::L2);
        let stats = run_casper(&cfg, StencilKind::Jacobi1D, &d, 1);
        assert!(stats.per_spu_instrs >= stats.total_instrs / 8);
    }
}
