//! The Table 1 programmer API, faithfully shaped: this is what Fig 8's
//! `computeStencil` calls would bind to. The high-level experiment driver
//! ([`super::engine::run_casper`]) builds on the same object.

use anyhow::{bail, ensure, Result};

use crate::config::{SimConfig, SpuPlacement};
use crate::isa::CasperProgram;
use crate::mapping::StencilSegment;
use crate::mem::cache::Cache;
use crate::spu::{ShardedMem, Spu};

/// The Casper runtime: owns the SPUs and the sharded memory-system models.
pub struct CasperRuntime {
    pub(crate) cfg: SimConfig,
    pub mem: ShardedMem,
    pub(crate) spus: Vec<Spu>,
    pub(crate) program: Option<CasperProgram>,
    /// Fig-14 NearL1 placement: give every SPU a private L1 tag model.
    near_l1: bool,
}

impl CasperRuntime {
    pub fn new(cfg: &SimConfig) -> CasperRuntime {
        let mut mem = ShardedMem::new(cfg, cfg.mapping);
        // §4.4: one LLC way stays reserved for concurrent CPU processes.
        mem.llc.set_reserved_ways(cfg.llc.reserved_ways);
        let near_l1 = cfg.placement == SpuPlacement::NearL1;
        if near_l1 {
            // Near-L1 SPUs pay the core→LLC latency instead of the
            // SPU-local 8 cycles, but gain a private L1 in front (attached
            // to each SPU at `init_stencil_code`).
            mem.spu_local_latency = cfg.llc.core_latency;
        }
        CasperRuntime { cfg: cfg.clone(), mem, spus: Vec::new(), program: None, near_l1 }
    }

    /// `initStencilSegment(size)`: allocate the physically contiguous
    /// stencil region and register it at every NoC injection point.
    pub fn init_stencil_segment(&mut self, bytes: u64) -> Result<u64> {
        ensure!(bytes > 0 && bytes % 8 == 0, "segment must be a positive multiple of 8 B");
        let base = self.mem.store.alloc_segment(bytes);
        self.mem.mapper.set_segment(StencilSegment::new(base, bytes));
        Ok(base)
    }

    /// `initStencilcode(addr, length)`: broadcast the microcode to every
    /// SPU. We pass the structured program; its 15-bit encoding is what
    /// would sit at `addr`.
    pub fn init_stencil_code(&mut self, program: CasperProgram) -> Result<()> {
        program.validate()?;
        self.spus = (0..self.cfg.spu.count)
            .map(|id| {
                let mut spu = Spu::new(id, id, &self.cfg, program.clone());
                if self.near_l1 {
                    spu.set_l1(Some(Cache::from_config(&self.cfg.l1)));
                }
                spu
            })
            .collect();
        self.program = Some(program);
        Ok(())
    }

    /// Re-broadcast a new program to every SPU *in place* — the
    /// multi-pass path between accelerator passes of one time step.
    /// Unlike [`init_stencil_code`](Self::init_stencil_code) this keeps
    /// the SPU objects (timing state, counters, private L1 tags), so the
    /// passes of a plan account on one continuous timeline.
    pub fn set_program(&mut self, program: CasperProgram) -> Result<()> {
        ensure!(!self.spus.is_empty(), "initStencilcode first");
        program.validate()?;
        for spu in &mut self.spus {
            spu.set_program(program.clone());
        }
        self.program = Some(program);
        Ok(())
    }

    /// `initConstant(const, index)`: set a constant-buffer entry on every
    /// SPU. The [`ProgramBuilder`](crate::isa::ProgramBuilder) already
    /// interns constants; this call overrides one slot (e.g. to retune a
    /// coefficient without regenerating code).
    pub fn init_constant(&mut self, value: f64, index: usize) -> Result<()> {
        let Some(prog) = &mut self.program else { bail!("initStencilcode first") };
        ensure!(index < crate::isa::program::MAX_CONSTANTS, "constant index out of range");
        if prog.constants.len() <= index {
            prog.constants.resize(index + 1, 0.0);
        }
        prog.constants[index] = value;
        // Re-broadcast to SPUs (preserving any private-L1 tag state).
        let prog = prog.clone();
        for spu in &mut self.spus {
            let l1 = spu.take_l1();
            *spu = Spu::new(spu.id, spu.slice, &self.cfg, prog.clone());
            spu.set_l1(l1);
        }
        Ok(())
    }

    /// `initStream(addr, streamID, accID)`: bind one stream base address
    /// on one SPU.
    pub fn init_stream(&mut self, addr: u64, stream_id: usize, spu_id: usize) -> Result<()> {
        ensure!(spu_id < self.spus.len(), "SPU {spu_id} out of range");
        let spu = &mut self.spus[spu_id];
        spu.set_stream(stream_id, addr)?;
        Ok(())
    }

    /// `setNElements(n, accID)`.
    pub fn set_n_elements(&mut self, n: u64, spu_id: usize) -> Result<()> {
        ensure!(spu_id < self.spus.len(), "SPU {spu_id} out of range");
        self.spus[spu_id].set_n_elements(n);
        Ok(())
    }

    /// `startAccelerator()`: run every SPU's bound work to completion.
    /// SPU 0 acts as the leader (§5.2): each SPU reports completion over
    /// the NoC and the leader signals the CPU once all are done. Returns
    /// the leader-observed completion cycle.
    pub fn start_accelerator(&mut self) -> Result<u64> {
        ensure!(self.program.is_some(), "initStencilcode first");
        ensure!(!self.spus.is_empty(), "no SPUs configured");
        // Round-robin lockstep: one vector group per SPU per round keeps
        // the shared-resource (slice port, NoC, DRAM) interleaving honest.
        loop {
            let mut progress = false;
            for spu in &mut self.spus {
                progress |= spu.run_group(&mut self.mem);
            }
            if !progress {
                break;
            }
        }
        // Leader aggregation: completion messages hop to SPU 0's node.
        let leader = 0usize;
        let mut done = 0u64;
        let finishes: Vec<(usize, u64)> =
            self.spus.iter().map(|s| (s.slice, s.finish_time())).collect();
        for (slice, t) in finishes {
            let arrive = self.mem.noc.send(slice, leader, 8, t);
            done = done.max(arrive);
        }
        Ok(done)
    }

    pub fn spus(&self) -> &[Spu] {
        &self.spus
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::ProgramBuilder;
    use crate::stencil::StencilKind;

    fn runtime() -> CasperRuntime {
        CasperRuntime::new(&SimConfig::default())
    }

    #[test]
    fn api_order_is_enforced() {
        let mut rt = runtime();
        assert!(rt.start_accelerator().is_err(), "no code yet");
        assert!(rt.init_constant(0.5, 0).is_err(), "no code yet");
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        rt.init_stencil_code(prog).unwrap();
        assert!(rt.init_stream(0x1000_0000, 0, 99).is_err(), "bad SPU id");
    }

    #[test]
    fn segment_validation() {
        let mut rt = runtime();
        assert!(rt.init_stencil_segment(0).is_err());
        assert!(rt.init_stencil_segment(12).is_err());
        let base = rt.init_stencil_segment(4096).unwrap();
        assert!(rt.mem.mapper.in_segment(base));
        assert!(!rt.mem.mapper.in_segment(base + 4096));
    }

    #[test]
    fn fig8_style_manual_program() {
        // Program a tiny Jacobi-1D by hand through the Table 1 calls on a
        // 4-SPU... 16-SPU system, using only SPU 0 (others get 0 work).
        let mut rt = runtime();
        let seg = rt.init_stencil_segment(1 << 20).unwrap();
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        rt.init_stencil_code(prog).unwrap();
        // 32 input points, ramp data.
        for i in 0..32u64 {
            rt.mem.store.write_f64(seg + i * 8, i as f64);
        }
        let out = seg + (1 << 19);
        rt.init_stream(out + 8, 0, 0).unwrap(); // output B[1]
        rt.init_stream(seg + 8, 1, 0).unwrap(); // input row at A[1]
        rt.set_n_elements(30, 0).unwrap();
        let cycles = rt.start_accelerator().unwrap();
        assert!(cycles > 0);
        // Linear data: interior mean equals the center → B[i] = i.
        for i in 1..31u64 {
            let got = rt.mem.store.read_f64(out + i * 8);
            assert!((got - i as f64).abs() < 1e-12, "i={i} got={got}");
        }
        // Leader observed every SPU (even the idle ones).
        assert_eq!(rt.spus()[0].stats.stores, 4); // 30 elems → 4 groups
    }

    #[test]
    fn set_program_keeps_spu_state() {
        // The multi-pass re-broadcast path: swapping programs must keep
        // the SPU objects (timing, counters) instead of rebuilding them.
        let mut rt = runtime();
        let prog1 = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        assert!(rt.set_program(prog1.clone()).is_err(), "initStencilcode first");
        rt.init_stencil_code(prog1).unwrap();
        rt.spus[0].stats.instrs = 7;
        rt.spus[0].timer.now = 42;
        let prog2 = ProgramBuilder::new()
            .build(&StencilKind::Jacobi2D.descriptor())
            .unwrap();
        rt.set_program(prog2.clone()).unwrap();
        assert_eq!(rt.spus[0].stats.instrs, 7, "counters survive the swap");
        assert_eq!(rt.spus[0].timer.now, 42, "timing survives the swap");
        assert_eq!(rt.spus[0].program(), &prog2);
    }

    #[test]
    fn way_reservation_applied() {
        let rt = runtime();
        assert_eq!(rt.mem.llc.way_limit(), 15);
    }

    #[test]
    fn constant_override() {
        let mut rt = runtime();
        let prog = ProgramBuilder::new()
            .build(&StencilKind::Jacobi1D.descriptor())
            .unwrap();
        rt.init_stencil_code(prog).unwrap();
        rt.init_constant(0.25, 0).unwrap();
        assert_eq!(rt.program.as_ref().unwrap().constants[0], 0.25);
    }
}
