//! Run statistics shared by the Casper and baseline models.

use crate::isa::ReduceOp;
use crate::mem::cache::CacheStats;
use crate::spu::SpuStats;
use crate::stencil::Grid;

/// Per-step scalars produced by a kernel's fused reduction (one value per
/// time step, in step order).
#[derive(Debug, Clone, PartialEq)]
pub struct ReductionResult {
    pub op: ReduceOp,
    pub values: Vec<f64>,
}

/// Result of a full Casper run (all time steps).
#[derive(Debug, Clone, PartialEq)]
pub struct RunStats {
    /// End-to-end cycles (leader-observed completion).
    pub cycles: u64,
    /// Total dynamic Casper instructions across all SPUs.
    pub total_instrs: u64,
    /// Dynamic instructions of the busiest SPU (the paper's Table 4
    /// Casper column reports per-SPU counts).
    pub per_spu_instrs: u64,
    /// Accelerator passes per time step (1 for envelope-sized kernels;
    /// wide kernels run their multi-pass plan back-to-back each step).
    pub passes: usize,
    pub spu: SpuStats,
    pub llc: CacheStats,
    pub dram_accesses: u64,
    pub noc_messages: u64,
    pub noc_hops: u64,
    pub noc_contention_cycles: u64,
    /// Per-slice NoC injection-point counter: requests that arrived from a
    /// remote SPU (one entry per LLC slice, slice order).
    pub slice_remote_reqs: Vec<u64>,
    /// Per-slice DRAM-queue share: line fetches issued on misses.
    pub slice_dram_reads: Vec<u64>,
    /// Per-slice DRAM-queue share: dirty writebacks issued.
    pub slice_dram_writes: Vec<u64>,
    /// Per-slice LLC port grants over the measured region (warm-up never
    /// claims ports, so this is exactly the run's data-array accesses; at
    /// one line per grant, `grants × line_bytes` is the slice's data
    /// bandwidth — the counter behind the peak-LLC-bandwidth claim).
    pub slice_port_grants: Vec<u64>,
    /// Temporal block depth the run executed with (1 = plain chaining).
    pub temporal_block: usize,
    /// Per-slice LLC line fills avoided by temporal-block wavefront
    /// residency (slice order; all zero at `temporal_block == 1`).
    pub slice_avoided_fills: Vec<u64>,
    /// Analytic count of halo cells a blocked sweep recomputes at chunk
    /// cuts instead of re-fetching (0 at `temporal_block == 1`).
    pub halo_recompute_cells: u64,
    /// Per-step fused-reduction values, when the kernel carries a
    /// `[reduction]` section.
    pub reduction: Option<ReductionResult>,
    /// Functional result grid.
    pub output: Grid,
}

/// Max-over-mean imbalance of a per-slice counter: `1.0` is perfectly
/// even, `slices as f64` is fully concentrated on one slice, `0.0` means
/// the counter never fired.
pub fn imbalance(counts: &[u64]) -> f64 {
    let total: u64 = counts.iter().sum();
    if total == 0 || counts.is_empty() {
        return 0.0;
    }
    let mean = total as f64 / counts.len() as f64;
    *counts.iter().max().unwrap() as f64 / mean
}

impl RunStats {
    /// Fraction of SPU loads served by the local slice.
    pub fn local_fraction(&self) -> f64 {
        let total = self.spu.local_loads + self.spu.remote_loads;
        if total == 0 {
            0.0
        } else {
            self.spu.local_loads as f64 / total as f64
        }
    }

    /// LLC hit rate seen by the SPUs.
    pub fn llc_hit_rate(&self) -> f64 {
        self.llc.hit_rate()
    }

    /// NoC imbalance: busiest slice's remote-request count over the mean
    /// (ROADMAP's NoC imbalance studies).
    pub fn remote_req_imbalance(&self) -> f64 {
        imbalance(&self.slice_remote_reqs)
    }

    /// DRAM-queue imbalance over the slices' read (miss-fetch) shares.
    pub fn dram_read_imbalance(&self) -> f64 {
        imbalance(&self.slice_dram_reads)
    }

    /// LLC bandwidth imbalance: busiest slice's port-grant count over the
    /// mean. `1.0` means the paper's peak-bandwidth claim holds evenly
    /// across slices; higher means some ports idle while one saturates.
    pub fn bandwidth_imbalance(&self) -> f64 {
        imbalance(&self.slice_port_grants)
    }

    /// Total LLC line fills avoided by temporal-block wavefront residency.
    pub fn avoided_fills(&self) -> u64 {
        self.slice_avoided_fills.iter().sum()
    }

    /// FNV-1a digest of the functional result alone (dims + every output
    /// bit). Unlike [`RunStats::digest`] this is invariant across
    /// `--temporal-block` depths — blocking moves traffic counters but
    /// never the grid — so CI compares these across T values.
    pub fn grid_digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.mix(self.output.nx as u64);
        h.mix(self.output.ny as u64);
        h.mix(self.output.nz as u64);
        for &v in &self.output.data {
            h.mix(v.to_bits());
        }
        h.0
    }

    /// Order-stable FNV-1a digest of every counter and every output bit.
    /// The determinism tests compare these across `--spu-threads` values:
    /// serial and epoch-parallel runs must produce identical digests.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.mix(self.cycles);
        h.mix(self.total_instrs);
        h.mix(self.per_spu_instrs);
        h.mix(self.passes as u64);
        let s = &self.spu;
        for v in [
            s.instrs,
            s.groups,
            s.loads,
            s.stores,
            s.local_loads,
            s.remote_loads,
            s.merged_unaligned,
            s.split_unaligned,
            s.lq_stall_cycles,
        ] {
            h.mix(v);
        }
        let c = &self.llc;
        for v in [
            c.read_hits,
            c.read_misses,
            c.write_hits,
            c.write_misses,
            c.evictions,
            c.writebacks,
            c.prefetch_fills,
            c.prefetch_hits,
        ] {
            h.mix(v);
        }
        h.mix(self.dram_accesses);
        h.mix(self.noc_messages);
        h.mix(self.noc_hops);
        h.mix(self.noc_contention_cycles);
        for v in [
            &self.slice_remote_reqs,
            &self.slice_dram_reads,
            &self.slice_dram_writes,
            &self.slice_port_grants,
            &self.slice_avoided_fills,
        ] {
            h.mix(v.len() as u64);
            for &x in v.iter() {
                h.mix(x);
            }
        }
        h.mix(self.temporal_block as u64);
        h.mix(self.halo_recompute_cells);
        match &self.reduction {
            None => h.mix(0),
            Some(r) => {
                h.mix(r.op.discriminant());
                h.mix(r.values.len() as u64);
                for &v in &r.values {
                    h.mix(v.to_bits());
                }
            }
        }
        h.mix(self.output.nx as u64);
        h.mix(self.output.ny as u64);
        h.mix(self.output.nz as u64);
        for &v in &self.output.data {
            h.mix(v.to_bits());
        }
        h.0
    }
}

/// FNV-1a over 64-bit words.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xCBF2_9CE4_8422_2325)
    }

    #[inline]
    fn mix(&mut self, v: u64) {
        // Word-at-a-time FNV-1a (byte-order-free: counters, not bytes).
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::Grid;

    fn stats() -> RunStats {
        RunStats {
            cycles: 123,
            total_instrs: 456,
            per_spu_instrs: 78,
            passes: 1,
            spu: SpuStats::default(),
            llc: CacheStats::default(),
            dram_accesses: 9,
            noc_messages: 10,
            noc_hops: 11,
            noc_contention_cycles: 0,
            slice_remote_reqs: vec![4, 0, 2, 6],
            slice_dram_reads: vec![1, 1, 1, 1],
            slice_dram_writes: vec![0, 0, 0, 0],
            slice_port_grants: vec![8, 8, 8, 16],
            temporal_block: 1,
            slice_avoided_fills: vec![0, 0, 0, 0],
            halo_recompute_cells: 0,
            reduction: None,
            output: Grid::random(8, 4, 1, 7),
        }
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = stats();
        let mut b = stats();
        assert_eq!(a.digest(), b.digest());
        b.cycles += 1;
        assert_ne!(a.digest(), b.digest(), "cycle change must move the digest");
        let mut c = stats();
        c.output.data[3] += 1e-15;
        assert_ne!(a.digest(), c.digest(), "single output ULP must move the digest");
        let mut d = stats();
        d.slice_remote_reqs[1] += 1;
        assert_ne!(a.digest(), d.digest(), "slice counter change must move the digest");
        let mut e = stats();
        e.slice_port_grants[0] += 1;
        assert_ne!(a.digest(), e.digest(), "port-grant change must move the digest");
        let mut f = stats();
        f.temporal_block = 4;
        assert_ne!(a.digest(), f.digest(), "temporal block must move the digest");
        assert_eq!(
            a.grid_digest(),
            f.grid_digest(),
            "grid digest ignores counters: it must be invariant across T"
        );
        let mut f2 = stats();
        f2.output.data[0] += 1e-15;
        assert_ne!(a.grid_digest(), f2.grid_digest(), "but it tracks every output ULP");
        let mut g = stats();
        g.slice_avoided_fills[2] += 1;
        assert_ne!(a.digest(), g.digest(), "avoided-fill change must move the digest");
        let mut h = stats();
        h.halo_recompute_cells = 7;
        assert_ne!(a.digest(), h.digest(), "halo recompute must move the digest");
        let mut r = stats();
        r.reduction = Some(ReductionResult { op: ReduceOp::AbsDiff, values: vec![0.5, 0.25] });
        assert_ne!(a.digest(), r.digest(), "reduction values must move the digest");
        let mut r2 = stats();
        r2.reduction = Some(ReductionResult { op: ReduceOp::Sum, values: vec![0.5, 0.25] });
        assert_ne!(r.digest(), r2.digest(), "reduction op must move the digest");
        assert_eq!(r.avoided_fills(), 0);
        assert_eq!(g.avoided_fills(), 1);
    }

    #[test]
    fn imbalance_metrics() {
        assert_eq!(imbalance(&[]), 0.0);
        assert_eq!(imbalance(&[0, 0, 0]), 0.0);
        assert_eq!(imbalance(&[5, 5, 5, 5]), 1.0);
        assert_eq!(imbalance(&[12, 0, 0, 0]), 4.0);
        let s = stats();
        assert_eq!(s.remote_req_imbalance(), 2.0); // max 6, mean 3
        assert_eq!(s.dram_read_imbalance(), 1.0);
        assert_eq!(s.bandwidth_imbalance(), 1.6); // max 16, mean 10
    }
}
