//! Run statistics shared by the Casper and baseline models.

use crate::mem::cache::CacheStats;
use crate::spu::SpuStats;
use crate::stencil::Grid;

/// Result of a full Casper run (all time steps).
#[derive(Debug, Clone, PartialEq)]
pub struct RunStats {
    /// End-to-end cycles (leader-observed completion).
    pub cycles: u64,
    /// Total dynamic Casper instructions across all SPUs.
    pub total_instrs: u64,
    /// Dynamic instructions of the busiest SPU (the paper's Table 4
    /// Casper column reports per-SPU counts).
    pub per_spu_instrs: u64,
    pub spu: SpuStats,
    pub llc: CacheStats,
    pub dram_accesses: u64,
    pub noc_messages: u64,
    pub noc_hops: u64,
    pub noc_contention_cycles: u64,
    /// Functional result grid.
    pub output: Grid,
}

impl RunStats {
    /// Fraction of SPU loads served by the local slice.
    pub fn local_fraction(&self) -> f64 {
        let total = self.spu.local_loads + self.spu.remote_loads;
        if total == 0 {
            0.0
        } else {
            self.spu.local_loads as f64 / total as f64
        }
    }

    /// LLC hit rate seen by the SPUs.
    pub fn llc_hit_rate(&self) -> f64 {
        self.llc.hit_rate()
    }

    /// Order-stable FNV-1a digest of every counter and every output bit.
    /// The determinism tests compare these across `--spu-threads` values:
    /// serial and epoch-parallel runs must produce identical digests.
    pub fn digest(&self) -> u64 {
        let mut h = Fnv::new();
        h.mix(self.cycles);
        h.mix(self.total_instrs);
        h.mix(self.per_spu_instrs);
        let s = &self.spu;
        for v in [
            s.instrs,
            s.groups,
            s.loads,
            s.stores,
            s.local_loads,
            s.remote_loads,
            s.merged_unaligned,
            s.split_unaligned,
            s.lq_stall_cycles,
        ] {
            h.mix(v);
        }
        let c = &self.llc;
        for v in [
            c.read_hits,
            c.read_misses,
            c.write_hits,
            c.write_misses,
            c.evictions,
            c.writebacks,
            c.prefetch_fills,
            c.prefetch_hits,
        ] {
            h.mix(v);
        }
        h.mix(self.dram_accesses);
        h.mix(self.noc_messages);
        h.mix(self.noc_hops);
        h.mix(self.noc_contention_cycles);
        h.mix(self.output.nx as u64);
        h.mix(self.output.ny as u64);
        h.mix(self.output.nz as u64);
        for &v in &self.output.data {
            h.mix(v.to_bits());
        }
        h.0
    }
}

/// FNV-1a over 64-bit words.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xCBF2_9CE4_8422_2325)
    }

    #[inline]
    fn mix(&mut self, v: u64) {
        // Word-at-a-time FNV-1a (byte-order-free: counters, not bytes).
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::Grid;

    fn stats() -> RunStats {
        RunStats {
            cycles: 123,
            total_instrs: 456,
            per_spu_instrs: 78,
            spu: SpuStats::default(),
            llc: CacheStats::default(),
            dram_accesses: 9,
            noc_messages: 10,
            noc_hops: 11,
            noc_contention_cycles: 0,
            output: Grid::random(8, 4, 1, 7),
        }
    }

    #[test]
    fn digest_is_stable_and_sensitive() {
        let a = stats();
        let mut b = stats();
        assert_eq!(a.digest(), b.digest());
        b.cycles += 1;
        assert_ne!(a.digest(), b.digest(), "cycle change must move the digest");
        let mut c = stats();
        c.output.data[3] += 1e-15;
        assert_ne!(a.digest(), c.digest(), "single output ULP must move the digest");
    }
}
