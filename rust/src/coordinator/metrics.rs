//! Run statistics shared by the Casper and baseline models.

use crate::mem::cache::CacheStats;
use crate::spu::SpuStats;
use crate::stencil::Grid;

/// Result of a full Casper run (all time steps).
#[derive(Debug, Clone)]
pub struct RunStats {
    /// End-to-end cycles (leader-observed completion).
    pub cycles: u64,
    /// Total dynamic Casper instructions across all SPUs.
    pub total_instrs: u64,
    /// Dynamic instructions of the busiest SPU (the paper's Table 4
    /// Casper column reports per-SPU counts).
    pub per_spu_instrs: u64,
    pub spu: SpuStats,
    pub llc: CacheStats,
    pub dram_accesses: u64,
    pub noc_messages: u64,
    pub noc_hops: u64,
    pub noc_contention_cycles: u64,
    /// Functional result grid.
    pub output: Grid,
}

impl RunStats {
    /// Fraction of SPU loads served by the local slice.
    pub fn local_fraction(&self) -> f64 {
        let total = self.spu.local_loads + self.spu.remote_loads;
        if total == 0 {
            0.0
        } else {
            self.spu.local_loads as f64 / total as f64
        }
    }

    /// LLC hit rate seen by the SPUs.
    pub fn llc_hit_rate(&self) -> f64 {
        self.llc.hit_rate()
    }
}
