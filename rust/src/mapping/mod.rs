//! Physical-address → LLC-slice mapping (§4.2).
//!
//! Two hash functions coexist, selected per request at the NoC injection
//! point by a stencil-segment range check:
//!
//! - **Baseline hash**: an XOR-fold of the cache-line index bits — the
//!   behaviour prior work reverse-engineered from Intel LLCs [158]:
//!   consecutive cache lines land on *different* slices (load balancing).
//! - **Stencil-segment hash**: a linear hash mapping contiguous 128 kB
//!   blocks of the segment to slices round-robin, so neighbouring grid
//!   points share a slice and SPU loads stay local.

use crate::config::{LlcConfig, MappingPolicy};

/// The stencil segment: one physically contiguous region (from [159]-style
/// allocation) registered with the hardware via two registers (§8.6:
/// start + length; one adder + one comparator per NoC injection point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StencilSegment {
    pub base: u64,
    pub len: u64,
}

impl StencilSegment {
    pub fn new(base: u64, len: u64) -> StencilSegment {
        StencilSegment { base, len }
    }

    #[inline]
    pub fn contains(&self, addr: u64) -> bool {
        addr.wrapping_sub(self.base) < self.len
    }

    pub fn end(&self) -> u64 {
        self.base + self.len
    }
}

/// Address-to-slice mapper: the hardware at every NoC injection point.
#[derive(Debug, Clone)]
pub struct SliceMapper {
    slices: u64,
    line_bytes: u64,
    block_bytes: u64,
    policy: MappingPolicy,
    segment: Option<StencilSegment>,
}

impl SliceMapper {
    pub fn new(llc: &LlcConfig, policy: MappingPolicy) -> SliceMapper {
        assert!(llc.slices.is_power_of_two(), "slice count must be a power of two");
        assert!(llc.line_bytes.is_power_of_two() && llc.stencil_block_bytes.is_power_of_two());
        SliceMapper {
            slices: llc.slices as u64,
            line_bytes: llc.line_bytes as u64,
            block_bytes: llc.stencil_block_bytes as u64,
            policy,
            segment: None,
        }
    }

    /// Register the stencil segment (the `initStencilSegment` effect).
    pub fn set_segment(&mut self, seg: StencilSegment) {
        self.segment = Some(seg);
    }

    pub fn clear_segment(&mut self) {
        self.segment = None;
    }

    pub fn segment(&self) -> Option<StencilSegment> {
        self.segment
    }

    /// Is this address inside the registered stencil segment?
    #[inline]
    pub fn in_segment(&self, addr: u64) -> bool {
        matches!(self.segment, Some(s) if s.contains(addr))
    }

    /// Map a physical address to its home LLC slice. Deterministic: each
    /// address maps to exactly one slice regardless of requester (§4.2).
    #[inline]
    pub fn slice_of(&self, addr: u64) -> usize {
        if self.policy == MappingPolicy::StencilSegment && self.in_segment(addr) {
            self.stencil_hash(addr)
        } else {
            self.baseline_hash(addr)
        }
    }

    /// Baseline hash: XOR-fold the line-index bits down to `log2(slices)`
    /// bits. Consecutive lines get consecutive (different) slices; higher
    /// line bits are folded in so large strides still spread out, the
    /// property [158] documents for Intel's undisclosed function.
    #[inline]
    pub fn baseline_hash(&self, addr: u64) -> usize {
        let line = addr / self.line_bytes;
        let bits = self.slices.trailing_zeros();
        let mask = self.slices - 1;
        let mut h = 0u64;
        let mut v = line;
        while v != 0 {
            h ^= v & mask;
            v >>= bits;
        }
        h as usize
    }

    /// Stencil-segment hash: *segment-relative* 128 kB blocks round-robin
    /// across slices (a bit-select, §8.6), so the first block of the
    /// segment always starts at slice 0.
    #[inline]
    pub fn stencil_hash(&self, addr: u64) -> usize {
        let rel = addr - self.segment.map(|s| s.base).unwrap_or(0);
        ((rel / self.block_bytes) % self.slices) as usize
    }

    /// Do `a` and `b` live in the same slice?
    #[inline]
    pub fn same_slice(&self, a: u64, b: u64) -> bool {
        self.slice_of(a) == self.slice_of(b)
    }

    pub fn slices(&self) -> usize {
        self.slices as usize
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::testutil;
    use crate::util::SplitMix64;

    fn mapper(policy: MappingPolicy) -> SliceMapper {
        SliceMapper::new(&SimConfig::default().llc, policy)
    }

    #[test]
    fn baseline_spreads_consecutive_lines() {
        let m = mapper(MappingPolicy::Baseline);
        // 16 consecutive lines hit 16 distinct slices.
        let mut seen = std::collections::HashSet::new();
        for i in 0..16u64 {
            seen.insert(m.slice_of(i * 64));
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn baseline_is_load_balanced() {
        let m = mapper(MappingPolicy::Baseline);
        let mut counts = vec![0usize; 16];
        let mut rng = SplitMix64::new(1);
        for _ in 0..64_000 {
            let addr = rng.next_u64() % (1 << 34);
            counts[m.slice_of(addr)] += 1;
        }
        for &c in &counts {
            assert!((3000..5000).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn stencil_hash_keeps_blocks_together() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        m.set_segment(StencilSegment::new(0x10000000, 8 << 20));
        let base = 0x10000000u64;
        // All addresses within one 128 kB block share a slice.
        let s0 = m.slice_of(base);
        for off in (0..128 * 1024).step_by(64) {
            assert_eq!(m.slice_of(base + off as u64), s0);
        }
        // Next block: next slice.
        assert_eq!(m.slice_of(base + 128 * 1024), (s0 + 1) % 16);
        // Blocks wrap round-robin: block 16 back to slice s0.
        assert_eq!(m.slice_of(base + 16 * 128 * 1024), s0);
    }

    #[test]
    fn segment_relative_blocks_start_at_slice0() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        // Segment base NOT 2 MB-aligned: hash is segment-relative so the
        // first block still maps to slice 0 (matches the Fig 8 programming
        // model where array offsets, not absolute addresses, pick slices).
        m.set_segment(StencilSegment::new(0x1234_0000, 4 << 20));
        assert_eq!(m.slice_of(0x1234_0000), 0);
        assert_eq!(m.slice_of(0x1234_0000 + 3 * 128 * 1024), 3);
    }

    #[test]
    fn outside_segment_uses_baseline() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        m.set_segment(StencilSegment::new(0x10000000, 1 << 20));
        let b = mapper(MappingPolicy::Baseline);
        for addr in [0u64, 0x1000, 0xFFFFFFF, 0x10000000 + (1 << 20)] {
            assert_eq!(m.slice_of(addr), b.slice_of(addr), "addr {addr:#x}");
        }
    }

    #[test]
    fn baseline_policy_ignores_segment() {
        let mut m = mapper(MappingPolicy::Baseline);
        m.set_segment(StencilSegment::new(0, 1 << 30));
        let plain = mapper(MappingPolicy::Baseline);
        for i in 0..1000u64 {
            assert_eq!(m.slice_of(i * 64), plain.slice_of(i * 64));
        }
    }

    #[test]
    fn every_address_maps_to_exactly_one_slice() {
        // §4.2: "each address is mapped to exactly one cache slice" —
        // the map must be a function (same input → same output) and stay
        // in range. Property test over random addresses and segments.
        testutil::check(
            "mapper determinism",
            512,
            |r: &mut SplitMix64| {
                let base = (r.next_u64() % (1 << 40)) & !63;
                let len = (1 + r.next_u64() % 1024) * 128 * 1024;
                let addr = r.next_u64() % (1 << 41);
                (base, len, addr)
            },
            |&(base, len, addr)| {
                let mut m = mapper(MappingPolicy::StencilSegment);
                m.set_segment(StencilSegment::new(base, len));
                let s1 = m.slice_of(addr);
                let s2 = m.slice_of(addr);
                s1 == s2 && s1 < 16
            },
        );
    }

    #[test]
    fn segment_contains_half_open() {
        let s = StencilSegment::new(100, 50);
        assert!(s.contains(100));
        assert!(s.contains(149));
        assert!(!s.contains(150));
        assert!(!s.contains(99));
    }
}
