//! Physical-address → LLC-slice mapping (§4.2).
//!
//! Two hash functions coexist, selected per request at the NoC injection
//! point by a stencil-segment range check:
//!
//! - **Baseline hash**: an XOR-fold of the cache-line index bits — the
//!   behaviour prior work reverse-engineered from Intel LLCs [158]:
//!   consecutive cache lines land on *different* slices (load balancing).
//! - **Stencil-segment hash**: a linear hash mapping contiguous 128 kB
//!   blocks of the segment to slices round-robin, so neighbouring grid
//!   points share a slice and SPU loads stay local.

use crate::config::{LlcConfig, MappingPolicy};

/// The stencil segment: one physically contiguous region (from [159]-style
/// allocation) registered with the hardware via two registers (§8.6:
/// start + length; one adder + one comparator per NoC injection point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StencilSegment {
    pub base: u64,
    pub len: u64,
}

impl StencilSegment {
    pub fn new(base: u64, len: u64) -> StencilSegment {
        StencilSegment { base, len }
    }

    #[inline]
    pub fn contains(&self, addr: u64) -> bool {
        addr.wrapping_sub(self.base) < self.len
    }

    pub fn end(&self) -> u64 {
        self.base + self.len
    }
}

/// Address-to-slice mapper: the hardware at every NoC injection point.
///
/// Hot-path layout (§Perf, `slice_hash_4M` in `benches/micro_hotpath.rs`):
/// every quantity `slice_of` needs is precomputed at construction —
/// shift amounts and masks instead of the original `/ line_bytes`,
/// `/ block_bytes`, `% slices` runtime divisions (all by non-constant
/// values, i.e. real `div` instructions), the policy folded into one
/// bool, and the segment held as two plain registers (`seg_len == 0` ⇒
/// none) so the range check is a single subtract + compare, exactly the
/// adder + comparator the paper's §8.6 hardware uses.
#[derive(Debug, Clone)]
pub struct SliceMapper {
    slices: u64,
    /// `slices - 1`.
    slice_mask: u64,
    /// `log2(slices)`.
    slice_bits: u32,
    /// XOR-fold rounds that reduce a full 64-bit line index: fixed trip
    /// count (no data-dependent loop exit) — extra rounds fold in zeros.
    fold_rounds: u32,
    /// `log2(line_bytes)`.
    line_shift: u32,
    /// `log2(block_bytes)`.
    block_shift: u32,
    block_bytes: u64,
    policy: MappingPolicy,
    /// `policy == StencilSegment`, hoisted out of `slice_of`.
    use_stencil: bool,
    /// Segment registers; `seg_len == 0` means no segment registered.
    seg_base: u64,
    seg_len: u64,
}

impl SliceMapper {
    pub fn new(llc: &LlcConfig, policy: MappingPolicy) -> SliceMapper {
        assert!(llc.slices.is_power_of_two(), "slice count must be a power of two");
        assert!(llc.line_bytes.is_power_of_two() && llc.stencil_block_bytes.is_power_of_two());
        let slices = llc.slices as u64;
        let slice_bits = slices.trailing_zeros();
        SliceMapper {
            slices,
            slice_mask: slices - 1,
            slice_bits,
            fold_rounds: if slice_bits == 0 { 1 } else { 64u32.div_ceil(slice_bits) },
            line_shift: (llc.line_bytes as u64).trailing_zeros(),
            block_shift: (llc.stencil_block_bytes as u64).trailing_zeros(),
            block_bytes: llc.stencil_block_bytes as u64,
            policy,
            use_stencil: policy == MappingPolicy::StencilSegment,
            seg_base: 0,
            seg_len: 0,
        }
    }

    /// Register the stencil segment (the `initStencilSegment` effect).
    pub fn set_segment(&mut self, seg: StencilSegment) {
        self.seg_base = seg.base;
        self.seg_len = seg.len;
    }

    pub fn clear_segment(&mut self) {
        self.seg_base = 0;
        self.seg_len = 0;
    }

    pub fn segment(&self) -> Option<StencilSegment> {
        (self.seg_len != 0).then(|| StencilSegment::new(self.seg_base, self.seg_len))
    }

    /// Is this address inside the registered stencil segment?
    #[inline]
    pub fn in_segment(&self, addr: u64) -> bool {
        addr.wrapping_sub(self.seg_base) < self.seg_len
    }

    /// Map a physical address to its home LLC slice. Deterministic: each
    /// address maps to exactly one slice regardless of requester (§4.2).
    #[inline]
    pub fn slice_of(&self, addr: u64) -> usize {
        if self.use_stencil && self.in_segment(addr) {
            self.stencil_hash(addr)
        } else {
            self.baseline_hash(addr)
        }
    }

    /// Baseline hash: XOR-fold the line-index bits down to `log2(slices)`
    /// bits. Consecutive lines get consecutive (different) slices; higher
    /// line bits are folded in so large strides still spread out, the
    /// property [158] documents for Intel's undisclosed function.
    #[inline]
    pub fn baseline_hash(&self, addr: u64) -> usize {
        let mut v = addr >> self.line_shift;
        let mut h = 0u64;
        // Fixed trip count covering the full 64-bit index: same result as
        // folding until `v == 0`, without the data-dependent exit branch.
        for _ in 0..self.fold_rounds {
            h ^= v;
            v >>= self.slice_bits;
        }
        (h & self.slice_mask) as usize
    }

    /// Stencil-segment hash: *segment-relative* 128 kB blocks round-robin
    /// across slices (a bit-select, §8.6), so the first block of the
    /// segment always starts at slice 0.
    #[inline]
    pub fn stencil_hash(&self, addr: u64) -> usize {
        let rel = addr.wrapping_sub(self.seg_base);
        ((rel >> self.block_shift) & self.slice_mask) as usize
    }

    /// Do `a` and `b` live in the same slice?
    #[inline]
    pub fn same_slice(&self, a: u64, b: u64) -> bool {
        self.slice_of(a) == self.slice_of(b)
    }

    pub fn policy(&self) -> MappingPolicy {
        self.policy
    }

    pub fn slices(&self) -> usize {
        self.slices as usize
    }

    pub fn block_bytes(&self) -> u64 {
        self.block_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::testutil;
    use crate::util::SplitMix64;

    fn mapper(policy: MappingPolicy) -> SliceMapper {
        SliceMapper::new(&SimConfig::default().llc, policy)
    }

    #[test]
    fn baseline_spreads_consecutive_lines() {
        let m = mapper(MappingPolicy::Baseline);
        // 16 consecutive lines hit 16 distinct slices.
        let mut seen = std::collections::HashSet::new();
        for i in 0..16u64 {
            seen.insert(m.slice_of(i * 64));
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn baseline_is_load_balanced() {
        let m = mapper(MappingPolicy::Baseline);
        let mut counts = vec![0usize; 16];
        let mut rng = SplitMix64::new(1);
        for _ in 0..64_000 {
            let addr = rng.next_u64() % (1 << 34);
            counts[m.slice_of(addr)] += 1;
        }
        for &c in &counts {
            assert!((3000..5000).contains(&c), "imbalanced: {counts:?}");
        }
    }

    #[test]
    fn stencil_hash_keeps_blocks_together() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        m.set_segment(StencilSegment::new(0x10000000, 8 << 20));
        let base = 0x10000000u64;
        // All addresses within one 128 kB block share a slice.
        let s0 = m.slice_of(base);
        for off in (0..128 * 1024).step_by(64) {
            assert_eq!(m.slice_of(base + off as u64), s0);
        }
        // Next block: next slice.
        assert_eq!(m.slice_of(base + 128 * 1024), (s0 + 1) % 16);
        // Blocks wrap round-robin: block 16 back to slice s0.
        assert_eq!(m.slice_of(base + 16 * 128 * 1024), s0);
    }

    #[test]
    fn segment_relative_blocks_start_at_slice0() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        // Segment base NOT 2 MB-aligned: hash is segment-relative so the
        // first block still maps to slice 0 (matches the Fig 8 programming
        // model where array offsets, not absolute addresses, pick slices).
        m.set_segment(StencilSegment::new(0x1234_0000, 4 << 20));
        assert_eq!(m.slice_of(0x1234_0000), 0);
        assert_eq!(m.slice_of(0x1234_0000 + 3 * 128 * 1024), 3);
    }

    #[test]
    fn outside_segment_uses_baseline() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        m.set_segment(StencilSegment::new(0x10000000, 1 << 20));
        let b = mapper(MappingPolicy::Baseline);
        for addr in [0u64, 0x1000, 0xFFFFFFF, 0x10000000 + (1 << 20)] {
            assert_eq!(m.slice_of(addr), b.slice_of(addr), "addr {addr:#x}");
        }
    }

    #[test]
    fn baseline_policy_ignores_segment() {
        let mut m = mapper(MappingPolicy::Baseline);
        m.set_segment(StencilSegment::new(0, 1 << 30));
        let plain = mapper(MappingPolicy::Baseline);
        for i in 0..1000u64 {
            assert_eq!(m.slice_of(i * 64), plain.slice_of(i * 64));
        }
    }

    #[test]
    fn every_address_maps_to_exactly_one_slice() {
        // §4.2: "each address is mapped to exactly one cache slice" —
        // the map must be a function (same input → same output) and stay
        // in range. Property test over random addresses and segments.
        testutil::check(
            "mapper determinism",
            512,
            |r: &mut SplitMix64| {
                let base = (r.next_u64() % (1 << 40)) & !63;
                let len = (1 + r.next_u64() % 1024) * 128 * 1024;
                let addr = r.next_u64() % (1 << 41);
                (base, len, addr)
            },
            |&(base, len, addr)| {
                let mut m = mapper(MappingPolicy::StencilSegment);
                m.set_segment(StencilSegment::new(base, len));
                let s1 = m.slice_of(addr);
                let s2 = m.slice_of(addr);
                s1 == s2 && s1 < 16
            },
        );
    }

    #[test]
    fn optimized_baseline_hash_matches_reference_fold() {
        // Regression for the shift/mask rewrite: the branch-reduced hash
        // must equal the original fold-until-zero definition bit for bit.
        let m = mapper(MappingPolicy::Baseline);
        let mut rng = SplitMix64::new(7);
        for _ in 0..10_000 {
            let addr = rng.next_u64() % (1 << 45);
            let mut v = addr / 64;
            let mut h = 0u64;
            while v != 0 {
                h ^= v & 15;
                v >>= 4;
            }
            assert_eq!(m.baseline_hash(addr), h as usize, "addr={addr:#x}");
        }
    }

    #[test]
    fn segment_roundtrips_through_registers() {
        let mut m = mapper(MappingPolicy::StencilSegment);
        assert_eq!(m.segment(), None);
        let seg = StencilSegment::new(0x2000_0000, 1 << 20);
        m.set_segment(seg);
        assert_eq!(m.segment(), Some(seg));
        m.clear_segment();
        assert_eq!(m.segment(), None);
        assert!(!m.in_segment(0x2000_0000));
    }

    #[test]
    fn segment_contains_half_open() {
        let s = StencilSegment::new(100, 50);
        assert!(s.contains(100));
        assert!(s.contains(149));
        assert!(!s.contains(150));
        assert!(!s.contains(99));
    }
}
