//! Mesh network-on-chip model (Table 2: mesh, XY routing, 64 B/cycle per
//! direction per link).
//!
//! Slices (and their SPUs) sit at mesh nodes. Remote-slice loads pay
//! `2 × hops × hop_latency` (request + response) plus link serialization;
//! links track occupancy so heavy cross-slice traffic (3D stencils, §8.1)
//! congests realistically.

use crate::config::NocConfig;

/// XY mesh coordinates of a node.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NodeCoord {
    pub x: usize,
    pub y: usize,
}

/// The mesh NoC.
#[derive(Debug, Clone)]
pub struct MeshNoc {
    cfg: NocConfig,
    /// Next-free cycle of each directed link, indexed by
    /// `(node * 4 + dir)`; dir: 0=+x, 1=-x, 2=+y, 3=-y.
    link_free: Vec<u64>,
    /// Counters.
    pub messages: u64,
    pub total_hops: u64,
    pub contention_cycles: u64,
}

impl MeshNoc {
    pub fn new(cfg: &NocConfig) -> MeshNoc {
        MeshNoc {
            cfg: *cfg,
            link_free: vec![0; cfg.mesh_x * cfg.mesh_y * 4],
            messages: 0,
            total_hops: 0,
            contention_cycles: 0,
        }
    }

    pub fn nodes(&self) -> usize {
        self.cfg.mesh_x * self.cfg.mesh_y
    }

    /// Node id → coordinates (row-major placement).
    pub fn coord(&self, node: usize) -> NodeCoord {
        NodeCoord { x: node % self.cfg.mesh_x, y: node / self.cfg.mesh_x }
    }

    /// Manhattan hop count between two nodes.
    pub fn hops(&self, from: usize, to: usize) -> u64 {
        let a = self.coord(from);
        let b = self.coord(to);
        (a.x.abs_diff(b.x) + a.y.abs_diff(b.y)) as u64
    }

    /// Contention-free traversal latency of one message: per-hop router +
    /// link latency plus serialization of the extra flits. Used on the SPU
    /// hot path, where the slice *port* (1 access/cycle), not the 64 B/cyc
    /// links, is the contended resource; [`send`](Self::send) models link
    /// occupancy for flows that can actually saturate links.
    pub fn latency(&self, from: usize, to: usize, bytes: usize) -> u64 {
        if from == to {
            return 0;
        }
        let flits = (bytes as u64).div_ceil(self.cfg.link_bytes_per_cycle as u64).max(1);
        self.hops(from, to) * self.cfg.hop_latency + (flits - 1)
    }

    /// Account a message without occupying links (pairs with
    /// [`latency`](Self::latency)).
    pub fn record(&mut self, from: usize, to: usize) {
        self.messages += 1;
        self.total_hops += self.hops(from, to);
    }

    /// Record a message and return its contention-free traversal latency —
    /// the SPU hot-path pairing of [`record`](Self::record) +
    /// [`latency`](Self::latency) in one call.
    #[inline]
    pub fn record_latency(&mut self, from: usize, to: usize, bytes: usize) -> u64 {
        self.record(from, to);
        self.latency(from, to, bytes)
    }

    /// Route one message of `bytes` from `from` to `to`, starting at
    /// `now`. Returns the arrival cycle. XY routing: all X hops first.
    pub fn send(&mut self, from: usize, to: usize, bytes: usize, now: u64) -> u64 {
        self.messages += 1;
        if from == to {
            return now; // local — no NoC traversal
        }
        let flits = (bytes as u64).div_ceil(self.cfg.link_bytes_per_cycle as u64).max(1);
        let mut t = now;
        let mut cur = self.coord(from);
        let dst = self.coord(to);
        // X dimension first, then Y (deadlock-free XY routing).
        while cur.x != dst.x {
            let dir = if dst.x > cur.x { 0 } else { 1 };
            t = self.traverse(cur, dir, flits, t);
            cur.x = if dst.x > cur.x { cur.x + 1 } else { cur.x - 1 };
            self.total_hops += 1;
        }
        while cur.y != dst.y {
            let dir = if dst.y > cur.y { 2 } else { 3 };
            t = self.traverse(cur, dir, flits, t);
            cur.y = if dst.y > cur.y { cur.y + 1 } else { cur.y - 1 };
            self.total_hops += 1;
        }
        t
    }

    /// Round-trip latency of a remote load: request (small) + response
    /// (`bytes`). Returns response-arrival cycle.
    pub fn round_trip(&mut self, from: usize, to: usize, bytes: usize, now: u64) -> u64 {
        let req_arrives = self.send(from, to, 8, now);
        self.send(to, from, bytes, req_arrives)
    }

    fn traverse(&mut self, at: NodeCoord, dir: usize, flits: u64, now: u64) -> u64 {
        let node = at.y * self.cfg.mesh_x + at.x;
        let link = node * 4 + dir;
        let start = now.max(self.link_free[link]);
        self.contention_cycles += start - now;
        self.link_free[link] = start + flits;
        start + self.cfg.hop_latency + (flits - 1)
    }

    pub fn reset(&mut self) {
        self.link_free.fill(0);
        self.messages = 0;
        self.total_hops = 0;
        self.contention_cycles = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn noc() -> MeshNoc {
        MeshNoc::new(&SimConfig::default().noc)
    }

    #[test]
    fn coords_row_major() {
        let n = noc();
        assert_eq!(n.coord(0), NodeCoord { x: 0, y: 0 });
        assert_eq!(n.coord(3), NodeCoord { x: 3, y: 0 });
        assert_eq!(n.coord(4), NodeCoord { x: 0, y: 1 });
        assert_eq!(n.coord(15), NodeCoord { x: 3, y: 3 });
    }

    #[test]
    fn hop_counts() {
        let n = noc();
        assert_eq!(n.hops(0, 0), 0);
        assert_eq!(n.hops(0, 1), 1);
        assert_eq!(n.hops(0, 15), 6); // corner to corner on 4×4
        assert_eq!(n.hops(5, 10), 2);
    }

    #[test]
    fn local_send_is_free() {
        let mut n = noc();
        assert_eq!(n.send(7, 7, 64, 123), 123);
        assert_eq!(n.total_hops, 0);
    }

    #[test]
    fn latency_scales_with_distance() {
        let mut n = noc();
        let near = n.send(0, 1, 64, 0);
        n.reset();
        let far = n.send(0, 15, 64, 0);
        assert!(far > near);
        // 6 hops × 2 cycles = 12 for a single-flit... 64 B = 1 flit.
        assert_eq!(far, 12);
        assert_eq!(near, 2);
    }

    #[test]
    fn contention_on_shared_link() {
        let mut n = noc();
        // Two big messages over the same first link at the same time.
        let a = n.send(0, 3, 256, 0); // 4 flits per link
        let b = n.send(0, 3, 256, 0);
        assert!(b > a);
        assert!(n.contention_cycles > 0);
    }

    #[test]
    fn disjoint_paths_do_not_contend() {
        let mut n = noc();
        n.send(0, 1, 64, 0);
        let before = n.contention_cycles;
        n.send(4, 5, 64, 0); // different row
        assert_eq!(n.contention_cycles, before);
    }

    #[test]
    fn record_latency_matches_record_plus_latency() {
        let mut a = noc();
        let mut b = noc();
        for (f, t, bytes) in [(0usize, 5usize, 8usize), (3, 3, 64), (15, 0, 256)] {
            b.record(f, t);
            assert_eq!(a.record_latency(f, t, bytes), b.latency(f, t, bytes));
        }
        assert_eq!(a.messages, b.messages);
        assert_eq!(a.total_hops, b.total_hops);
    }

    #[test]
    fn round_trip_is_two_traversals() {
        let mut n = noc();
        let t = n.round_trip(0, 2, 64, 0);
        // 2 hops there (+2cyc each) + 2 hops back = 8 cycles.
        assert_eq!(t, 8);
    }

    #[test]
    fn xy_routing_is_deterministic() {
        let mut a = noc();
        let mut b = noc();
        for (f, t) in [(0, 15), (3, 12), (5, 6), (9, 2)] {
            assert_eq!(a.send(f, t, 128, 100), b.send(f, t, 128, 100));
        }
    }
}
