//! Unaligned-load support (§4.1): 64 B loads aligned to any 8 B boundary.
//!
//! The hardware mechanism — a second tag-array read port, one 3:1 mux per
//! SRAM row, and an output rotate network — lets one request pull a 64 B
//! operand that spans two *consecutive* cache lines, provided both lines
//! live in the same LLC slice. Consecutive lines always map to different
//! sets, so the dual tag match never conflicts (§4.1). Across a slice
//! boundary the mechanism cannot help and the access splits into two
//! ordinary requests (§4.2 block-boundary cost).

use crate::config::LlcConfig;
use crate::mapping::SliceMapper;

/// The decomposition of one (possibly unaligned) 64 B SPU load.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnalignedReq {
    /// Line-aligned byte addresses of the lines touched.
    pub lines: [u64; 2],
    /// 1 if the request is line-aligned, else 2.
    pub n_lines: usize,
    /// Home slice of each touched line.
    pub slices: [usize; 2],
    /// True when both lines are homed in the same slice, so the §4.1
    /// shifted-row mechanism serves the request in ONE cache access.
    pub single_access: bool,
    /// Rotate amount in elements (the barrel-shifter setting).
    pub rotate_elems: u8,
}

impl UnalignedReq {
    /// Number of LLC requests the load costs (1 with the Casper hardware
    /// when same-slice, otherwise one per line).
    pub fn llc_requests(&self, unaligned_hw: bool) -> usize {
        if self.n_lines == 1 {
            1
        } else if unaligned_hw && self.single_access {
            1
        } else {
            2
        }
    }
}

/// Decompose a 64 B vector load at 8 B-aligned byte address `addr`.
pub fn decompose(addr: u64, llc: &LlcConfig, mapper: &SliceMapper) -> UnalignedReq {
    let line = llc.line_bytes as u64;
    debug_assert_eq!(addr % 8, 0, "SPU loads are 8 B aligned");
    let first = addr & !(line - 1);
    let end = addr + line - 1; // last byte of the 64 B operand
    let last = end & !(line - 1);
    let s0 = mapper.slice_of(first);
    if first == last {
        return UnalignedReq {
            lines: [first, first],
            n_lines: 1,
            slices: [s0, s0],
            single_access: true,
            rotate_elems: 0,
        };
    }
    let s1 = mapper.slice_of(last);
    UnalignedReq {
        lines: [first, last],
        n_lines: 2,
        slices: [s0, s1],
        single_access: s0 == s1,
        rotate_elems: ((addr - first) / 8) as u8,
    }
}

/// Area overhead of the unaligned-load hardware per LLC slice, mm² (§8.6):
/// dominated by the second tag-array read port.
pub const AREA_PER_SLICE_MM2: f64 = 0.14;
/// ... of which the second tag port alone:
pub const TAG_PORT_AREA_MM2: f64 = 0.12;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{MappingPolicy, SimConfig};
    use crate::mapping::{SliceMapper, StencilSegment};
    use crate::testutil;
    use crate::util::SplitMix64;

    fn setup() -> (LlcConfig, SliceMapper) {
        let cfg = SimConfig::default();
        let mut m = SliceMapper::new(&cfg.llc, MappingPolicy::StencilSegment);
        m.set_segment(StencilSegment::new(0, 64 << 20));
        (cfg.llc, m)
    }

    #[test]
    fn aligned_load_is_single_line() {
        let (llc, m) = setup();
        let r = decompose(128, &llc, &m);
        assert_eq!(r.n_lines, 1);
        assert_eq!(r.rotate_elems, 0);
        assert_eq!(r.llc_requests(true), 1);
        assert_eq!(r.llc_requests(false), 1);
    }

    #[test]
    fn unaligned_same_slice_is_one_access_with_hw() {
        let (llc, m) = setup();
        // addr 24: spans lines 0 and 64; both in block 0 → same slice.
        let r = decompose(24, &llc, &m);
        assert_eq!(r.n_lines, 2);
        assert_eq!(r.lines, [0, 64]);
        assert!(r.single_access);
        assert_eq!(r.rotate_elems, 3);
        assert_eq!(r.llc_requests(true), 1);
        assert_eq!(r.llc_requests(false), 2, "without the hw it costs two");
    }

    #[test]
    fn block_boundary_splits_across_slices() {
        let (llc, m) = setup();
        // Straddle the 128 kB block boundary: last 8 B of block 0 +
        // first 56 B of block 1.
        let addr = 128 * 1024 - 8;
        let r = decompose(addr, &llc, &m);
        assert_eq!(r.n_lines, 2);
        assert_ne!(r.slices[0], r.slices[1]);
        assert!(!r.single_access);
        assert_eq!(r.llc_requests(true), 2, "hardware cannot merge across slices");
    }

    #[test]
    fn consecutive_lines_differ_in_set() {
        // §4.1's no-conflict guarantee: consecutive lines map to different
        // cache sets (set index = low line bits).
        let sets = 2048u64;
        testutil::check("adjacent lines, adjacent sets", 512, |r: &mut SplitMix64| r.next_u64() & !63, |&a| {
            let l0 = a / 64;
            let l1 = l0 + 1;
            (l0 % sets) != (l1 % sets)
        });
    }

    #[test]
    fn rotate_matches_offset_property() {
        let (llc, m) = setup();
        testutil::check(
            "rotate = (addr % 64)/8",
            512,
            |r: &mut SplitMix64| (r.next_u64() % (1 << 25)) & !7,
            |&addr| {
                let r = decompose(addr, &llc, &m);
                r.rotate_elems as u64 == (addr % 64) / 8
                    && (r.n_lines == 1) == (addr % 64 == 0)
            },
        );
    }

    #[test]
    fn baseline_mapping_rarely_merges() {
        // Under the baseline line-interleaved hash, adjacent lines are in
        // different slices, so unaligned loads are never single-access.
        let cfg = SimConfig::default();
        let m = SliceMapper::new(&cfg.llc, MappingPolicy::Baseline);
        let r = decompose(24, &cfg.llc, &m);
        assert!(!r.single_access);
    }
}
