//! DDR4 main-memory model: 4 channels, per-channel bandwidth, closed-page
//! latency, simple queueing (Table 2).

use crate::config::DramConfig;

use super::ratelimit::RateLimiter;

/// Per-channel bandwidth/latency model. Requests are cache-line sized.
#[derive(Debug, Clone)]
pub struct DramModel {
    cfg: DramConfig,
    line_bytes: u64,
    /// Bus cycles per line transfer, hoisted out of [`access`](Self::access)
    /// — the old per-access recompute was a real `f64` divide on the
    /// miss path.
    burst: u64,
    /// Per-channel data-bus scheduler.
    channels: Vec<RateLimiter>,
    /// Event counters.
    pub accesses: u64,
    pub reads: u64,
    pub writes: u64,
    /// Total cycles requests spent queued behind the channel bus.
    pub queue_cycles: u64,
}

impl DramModel {
    pub fn new(cfg: &DramConfig, line_bytes: usize) -> DramModel {
        let burst = (line_bytes as f64 / cfg.bytes_per_cycle_per_channel).ceil() as u64;
        DramModel {
            cfg: *cfg,
            line_bytes: line_bytes as u64,
            burst,
            channels: (0..cfg.channels).map(|_| RateLimiter::new(burst, 32)).collect(),
            accesses: 0,
            reads: 0,
            writes: 0,
            queue_cycles: 0,
        }
    }

    /// Channel selection: line-interleaved across channels (the common
    /// BIOS default for bandwidth-bound streams).
    #[inline]
    fn channel_of(&self, addr: u64) -> usize {
        ((addr / self.line_bytes) % self.cfg.channels as u64) as usize
    }

    /// Issue a line transfer at `now`; returns the completion cycle.
    pub fn access(&mut self, addr: u64, write: bool, now: u64) -> u64 {
        self.accesses += 1;
        if write {
            self.writes += 1;
        } else {
            self.reads += 1;
        }
        let ch = self.channel_of(addr);
        let start = self.channels[ch].claim(now);
        self.queue_cycles += start - now;
        start + self.burst + self.cfg.latency
    }

    /// Aggregate peak bandwidth in bytes/cycle.
    pub fn peak_bytes_per_cycle(&self) -> f64 {
        self.cfg.channels as f64 * self.cfg.bytes_per_cycle_per_channel
    }

    pub fn reset(&mut self) {
        for ch in &mut self.channels {
            ch.reset();
        }
        self.accesses = 0;
        self.reads = 0;
        self.writes = 0;
        self.queue_cycles = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn model() -> DramModel {
        DramModel::new(&SimConfig::default().dram, 64)
    }

    #[test]
    fn uncontended_access_is_latency_plus_burst() {
        let mut d = model();
        let done = d.access(0, false, 100);
        let burst = (64.0f64 / 9.6).ceil() as u64; // 7
        assert_eq!(done, 100 + burst + 200);
    }

    #[test]
    fn same_channel_requests_serialize() {
        let mut d = model();
        // Lines 0 and 4 both map to channel 0 (4 channels).
        let a = d.access(0, false, 0);
        let b = d.access(4 * 64, false, 0);
        assert!(b > a, "second request must queue behind the first");
        assert!(d.queue_cycles > 0);
    }

    #[test]
    fn different_channels_overlap() {
        let mut d = model();
        let a = d.access(0, false, 0);
        let b = d.access(64, false, 0); // line 1 → channel 1
        assert_eq!(a, b, "independent channels should not serialize");
    }

    #[test]
    fn bandwidth_bound_stream() {
        // Streaming N lines through 4 channels should take ≈ N*burst/4
        // cycles of bus time, not N*latency.
        let mut d = model();
        let n = 1000u64;
        let mut last = 0;
        for i in 0..n {
            last = last.max(d.access(i * 64, false, 0));
        }
        let burst = (64.0f64 / 9.6).ceil() as u64;
        let ideal = n * burst / 4 + 200;
        assert!(last <= ideal + burst, "last={last} ideal={ideal}");
        assert!(last >= ideal - burst * 4);
    }

    #[test]
    fn counters() {
        let mut d = model();
        d.access(0, false, 0);
        d.access(64, true, 0);
        assert_eq!((d.accesses, d.reads, d.writes), (2, 1, 1));
        d.reset();
        assert_eq!(d.accesses, 0);
    }
}
